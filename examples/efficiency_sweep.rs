//! Hardware-efficiency sweep (Fig. 9a + 9b): evaluate the full design
//! matrix — HPFA / SFA baselines vs StoX configurations — across the
//! paper's three workloads, and print normalized energy / latency / area
//! / EDP exactly like the paper's bar charts.  Ends with the
//! registry-driven accuracy × energy Pareto front (`stox-cli sweep`
//! path): every registered converter spec scored on the deterministic
//! golden workload and joined with the cost rollup.
//!
//!   cargo run --release --example efficiency_sweep

use stox_net::arch::components::ComponentCosts;
use stox_net::arch::energy::{evaluate_network, DesignConfig};
use stox_net::arch::sweep::{
    default_grid, parse_precision_tags, run_matrix_sweep, GoldenWorkload,
};
use stox_net::imc::{PsConverterSpec, StoxConfig};
use stox_net::model::zoo;

/// Spec-built design point (the open `PsConvert` registry path: the same
/// string you would pass to `stox-cli serve --converter`).
fn spec_design(base: StoxConfig, body: &str, first: &str) -> DesignConfig {
    DesignConfig::from_specs(base, &body.parse().unwrap(), &first.parse().unwrap())
        .expect("registry spec")
}

fn main() -> anyhow::Result<()> {
    let costs = ComponentCosts::default();
    let base = StoxConfig::default(); // 4w4a4bs, r_arr=256

    for (wname, layers) in [
        ("ResNet-20 / CIFAR-10", zoo::resnet20_cifar()),
        ("ResNet-18 / Tiny-ImageNet", zoo::resnet18_tiny()),
        ("ResNet-50 / Tiny-ImageNet", zoo::resnet50_tiny()),
    ] {
        println!(
            "\n===== {wname} ({:.1}M MACs) =====",
            zoo::total_macs(&layers) as f64 / 1e6
        );
        let designs = vec![
            DesignConfig::hpfa(),
            DesignConfig::sfa(),
            DesignConfig::stox(base, 1, false), // 1-HPF
            DesignConfig::stox(base, 1, true),  // 1-QF
            DesignConfig::stox(base, 4, true),  // 4-QF
            DesignConfig::stox(base, 8, true),  // 8-QF
            DesignConfig::stox_mix(
                base,
                true,
                &[
                    ("s0b0c1", 4),
                    ("s0b0c2", 4),
                    ("s0b1c1", 2),
                    ("s0b1c2", 2),
                    ("s0b2c1", 2),
                ],
            ), // Mix-QF
            DesignConfig::stox(StoxConfig { w_slice_bits: 1, ..base }, 1, true),
            // registry-built converters (PsConvert::cost_key path):
            spec_design(base, "sparse:bits=4", "quant:bits=8"), // sparse-ADC baseline
            spec_design(
                StoxConfig { w_slice_bits: 1, ..base },
                "inhomo:base=1,extra=3", // §3.2.3 per-(stream, slice) sampling
                "stox:samples=8",
            ),
        ];
        let results = evaluate_network(&costs, &designs, &layers);
        let hpfa = results[0].0.clone();
        println!(
            "{:<26} {:>9} {:>9} {:>9} {:>10} {:>8}",
            "design", "energy×", "latency×", "area×", "EDP gain", "xbars"
        );
        for (r, _) in &results {
            println!(
                "{:<26} {:>8.2}x {:>8.2}x {:>8.2}x {:>9.1}x {:>8}",
                r.name,
                hpfa.energy_pj / r.energy_pj,
                hpfa.latency_ns / r.latency_ns,
                hpfa.area_um2 / r.area_um2,
                hpfa.edp_pj_ns / r.edp_pj_ns,
                r.xbars
            );
        }
        // per-layer view of the best design (conv1 dominance story, §4.3)
        let stox1 = &results[3].0;
        let first_frac = stox1.per_layer[0].energy_pj / stox1.energy_pj;
        println!(
            "1-QF: conv1 energy share {:.1}%; total {:.2} nJ/inf, {:.1} µs/inf",
            100.0 * first_frac,
            stox1.energy_pj / 1e3,
            stox1.latency_ns / 1e3
        );
    }

    // ----- the Fig. 9a design matrix as one Pareto front -----
    // precision tags × every registered converter spec (plus MTJ-sample
    // and ADC-bit grids): task accuracy on a per-tag golden workload,
    // cost via PsConvert::cost_key, `*` marks the joint non-dominated
    // front — HPFA-class (`ideal` at 8w8a), SFA-class (`sparse`) and
    // StoX cells land on one front
    let tags = parse_precision_tags("4w4a4bs,8w8a4bs", &base)?;
    let workloads: Vec<GoldenWorkload> = tags
        .iter()
        .map(|c| GoldenWorkload::new(*c, 48, 9))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> = tags
        .iter()
        .map(|c| (*c, default_grid(c, &[1, 2, 4, 8, 16, 32], &[1, 2, 4, 8])))
        .collect();
    let pareto = run_matrix_sweep(
        &grid,
        &zoo::resnet20_cifar(),
        "resnet20_cifar",
        9,
        stox_net::util::pool::default_threads(),
        |ti, spec| {
            let gw = &workloads[ti];
            Ok(gw.accuracy(spec.build(gw.cfg())?.as_ref()))
        },
    )?;
    println!(
        "\n===== accuracy × energy design matrix ({} tags, ResNet-20 cost model) =====",
        tags.len()
    );
    println!("{}", pareto.render_table());
    Ok(())
}
