//! Quickstart: load the AOT artifacts, run one batch through the PJRT
//! engine, and cross-check against the native crossbar functional model.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end slice of the stack: python trained the
//! StoX ResNet and lowered it (with its Pallas stochastic-MVM kernels) to
//! HLO text; Rust loads the text, compiles on the PJRT CPU client, and
//! serves inferences without ever touching python again.

use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};
use stox_net::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&dir)?;
    println!(
        "model: {} ({} classes, {}×{}×{} input, {} config)",
        manifest.spec.name,
        manifest.spec.num_classes,
        manifest.spec.image_size,
        manifest.spec.image_size,
        manifest.spec.in_channels,
        manifest.spec.stox.mode,
    );

    // 1. PJRT path: the production request path.
    let engine = Engine::load(&manifest)?;
    println!("PJRT platform: {}", engine.platform);
    let test = TestSet::load(&manifest)?;
    let handle = engine.model(8).expect("batch-8 artifact");
    let imgs: Vec<f32> = (0..8).flat_map(|i| test.image(i).to_vec()).collect();
    let logits = handle.infer(&imgs, 42)?;

    // 2. Native path: the hardware-exact functional simulator.
    let store = WeightStore::load(&manifest)?;
    let native = NativeModel::load(&manifest, &store)?;
    let nlogits = native.forward(&imgs, 8, 42);

    println!("\n image | label | PJRT pred | native pred");
    let classes = manifest.spec.num_classes;
    let mut agree = 0;
    for i in 0..8 {
        let p1 = argmax(&logits[i * classes..(i + 1) * classes]);
        let p2 = argmax(&nlogits[i * classes..(i + 1) * classes]);
        if p1 == p2 {
            agree += 1;
        }
        println!(
            "  {i:4} | {:5} | {p1:9} | {p2:11}",
            test.labels[i]
        );
    }
    println!("\nPJRT vs native agreement: {agree}/8");
    anyhow::ensure!(agree >= 6, "paths diverged — check parity tests");
    println!("quickstart OK");
    Ok(())
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}
