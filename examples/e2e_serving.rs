//! End-to-end serving driver (DESIGN.md §5 "E2E validation"): load the
//! trained + AOT-compiled StoX ResNet, serve the whole exported test set
//! through the dynamic batcher on the PJRT request path, and report
//!
//!   * classification accuracy (vs the python-side checkpoint accuracy),
//!   * wall-clock latency percentiles + throughput,
//!   * *simulated IMC hardware* energy/latency from the tile scheduler —
//!     the same accounting that regenerates Fig. 9.
//!
//!   make artifacts && cargo run --release --example e2e_serving
//!
//! Results of this run are recorded in EXPERIMENTS.md.

use std::sync::mpsc;
use stox_net::arch::components::ComponentCosts;
use stox_net::arch::energy::DesignConfig;
use stox_net::coordinator::server::{submit_all, PjrtExecutor, Server};
use stox_net::coordinator::{BatcherConfig, ServeConfig, TileScheduler};
use stox_net::model::weights::TestSet;
use stox_net::model::Manifest;
use stox_net::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&dir)?;
    let test = TestSet::load(&manifest)?;
    let spec = &manifest.spec;
    let elems = spec.image_size * spec.image_size * spec.in_channels;

    println!("== StoX-Net end-to-end serving ==");
    let engine = Engine::load(&manifest)?;
    println!(
        "PJRT {} | batch variants {:?} | {} test images",
        engine.platform,
        engine.batch_sizes(),
        test.n
    );

    // design point derived from the converter specs that actually serve
    // (PsConvert::cost_key keeps Fig. 9 accounting and the request path
    // in lockstep)
    let design = DesignConfig::from_specs(
        spec.stox_config(),
        &spec.body_converter_spec()?,
        &spec.first_layer_spec()?,
    )?;
    let sched =
        TileScheduler::new(&ComponentCosts::default(), design, &manifest.layers);
    println!(
        "simulated IMC design: {:.2} nJ/inf, {:.1} µs/inf, pipeline bound {:.0} inf/s",
        sched.energy_per_inference_pj() / 1e3,
        sched.single_latency_ns() / 1e3,
        sched.throughput_bound_per_s()
    );

    let server = Server::new(
        Box::new(PjrtExecutor {
            engine,
            classes: spec.num_classes,
            image_elems: elems,
        }),
        ServeConfig {
            batcher: BatcherConfig {
                target_batch: 8,
                max_wait: std::time::Duration::from_millis(2),
            },
            seed: 7,
            max_retries: 2,
        },
    )
    .with_scheduler(sched);

    // closed-loop load generator on a side thread; server loop here.
    let n = test.n;
    let images: Vec<Vec<f32>> = (0..n).map(|i| test.image(i).to_vec()).collect();
    let (tx, rx) = mpsc::channel();
    let client = std::thread::spawn(move || {
        let replies = submit_all(&tx, images.into_iter());
        drop(tx);
        replies
    });
    let t0 = std::time::Instant::now();
    server.run(rx);
    let wall = t0.elapsed();
    let replies = client.join().unwrap();

    let mut correct = 0usize;
    for (i, r) in replies.into_iter().enumerate() {
        let rep = r.recv()?;
        let pred = rep
            .logits()?
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == test.labels[i] {
            correct += 1;
        }
    }

    println!("\n== results ==");
    println!(
        "accuracy       : {}/{} = {:.2}%",
        correct,
        n,
        100.0 * correct as f64 / n as f64
    );
    println!("wall clock     : {wall:?} ({:.1} req/s)", n as f64 / wall.as_secs_f64());
    print!("{}", server.metrics.lock().unwrap().report());
    Ok(())
}
