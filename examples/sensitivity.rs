//! Monte-Carlo layer-sensitivity analysis (Fig. 5): perturb each conv
//! layer's weights with uniform noise at inference on the *native
//! hardware-exact* model and measure the accuracy drop — the signal the
//! paper uses to assign inhomogeneous ("Mix") sampling rates.
//!
//!   make artifacts && cargo run --release --example sensitivity

use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};
use stox_net::util::pool;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&dir)?;
    let store = WeightStore::load(&manifest)?;
    let test = TestSet::load(&manifest)?;
    let model = NativeModel::load(&manifest, &store)?;

    let n = 192.min(test.n);
    let sigma = 0.15f32;
    let trials = 4u32;
    let base = model.accuracy(&test.images, &test.labels, n, 8, 777);
    println!("== Fig. 5: layer-wise error sensitivity (σ={sigma}, {trials} trials, {n} images) ==");
    println!("baseline accuracy: {base:.4}\n");

    let n_layers = model.n_conv_layers();
    let drops = pool::par_map(n_layers, pool::default_threads(), |layer| {
        let mut acc = 0.0;
        for t in 0..trials {
            let p = model.perturb_layer(layer, sigma, 1000 + layer as u32 * 97 + t);
            acc += p.accuracy(&test.images, &test.labels, n, 8, 777);
        }
        base - acc / trials as f64
    });

    for (layer, drop) in drops.iter().enumerate() {
        let bar = "#".repeat((drop.max(0.0) * 200.0).round() as usize);
        let tag = if layer == 0 { " <- conv-1" } else { "" };
        println!("layer {layer:2} | {bar:<40} drop {drop:+.4}{tag}");
    }

    // Derive a Mix assignment like train.mix_from_sensitivity
    let mut order: Vec<usize> = (0..n_layers).collect();
    order.sort_by(|&a, &b| drops[b].partial_cmp(&drops[a]).unwrap());
    let q = (n_layers / 4).max(1);
    let mut mix: Vec<(usize, u32)> = Vec::new();
    for (rank, &li) in order.iter().enumerate() {
        if li == 0 {
            continue; // conv-1 handled by first_layer_samples
        }
        if rank < q {
            mix.push((li, 4));
        } else if rank < 2 * q {
            mix.push((li, 2));
        }
    }
    mix.sort();
    println!("\nderived Mix sampling assignment (layer, samples): {mix:?}");
    println!("(all remaining stochastic layers stay at 1 sample)");
    Ok(())
}
