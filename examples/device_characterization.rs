//! Device characterization (Fig. 2 / Tables 1-2): run the stochastic
//! macro-spin LLG solver across the ±100 µA write-current range, extract
//! the switching-probability sigmoid, fit Eq. 1's tanh abstraction, and
//! derive the converter's energy/latency/area row of Table 2.
//!
//!   cargo run --release --example device_characterization

use stox_net::device::converter::{
    MtjConverter, PAPER_ENERGY_PER_CONVERSION_J, PAPER_LATENCY_S,
};
use stox_net::device::llg::LlgParams;
use stox_net::device::mtj::{SotMtj, SwitchingCurve};

fn main() -> anyhow::Result<()> {
    let mtj = SotMtj::default();
    let llg = LlgParams::default();
    let conv = MtjConverter::default();

    println!("== Table 1 parameters ==");
    println!("SOT-MTJ 90×70×2.5 nm | HM 144×112×3.5 nm (ρ = 160 µΩ·cm)");
    println!("R_LRS {:.0} kΩ | TMR {} | R_ref {:.0} kΩ | VDD {} V",
        mtj.r_lrs / 1e3, mtj.tmr, mtj.r_ref / 1e3, mtj.v_dd);
    println!("derived: R_HRS {:.0} kΩ, R_HM {:.0} Ω, read margin {:.3} V",
        mtj.r_hrs() / 1e3, mtj.r_hm(), mtj.read_margin());
    println!("thermal stability Δ = {:.1}, H_SOT(100µA)/H_k = {:.2}",
        llg.thermal_stability(), llg.h_sot(100e-6) / llg.h_k);

    println!("\n== Fig. 2: P(switch) vs write current (LLG Monte-Carlo) ==");
    let t0 = std::time::Instant::now();
    let curve = SwitchingCurve::extract(llg, &mtj, 21, 300, 42);
    println!("extracted in {:?} ({} trials/point)", t0.elapsed(), curve.trials);
    for (i, p) in curve.currents.iter().zip(&curve.prob) {
        let bar = "#".repeat((p * 50.0).round() as usize);
        println!("{:>7.1} µA | {bar:<50} {p:.3}", i * 1e6);
    }
    let (alpha, sse) = curve.fit_tanh_alpha(mtj.i_write_max);
    println!(
        "\nEq. 1 fit: P(+1) = (tanh(α·I/I_max)+1)/2 with α = {alpha:.2} (sse {sse:.4})"
    );
    println!("monotonicity violations (>5% tol): {}", curve.monotonicity_violations(0.05));

    println!("\n== converter electrical model (Table 2 row) ==");
    println!("write energy (E[I²]·R_HM·t)  : {:.2} fJ", conv.write_energy() * 1e15);
    println!("read  energy (divider+inv)   : {:.2} fJ", conv.read_energy() * 1e15);
    println!("total derived / paper        : {:.2} / {:.2} fJ",
        conv.energy_per_conversion() * 1e15, PAPER_ENERGY_PER_CONVERSION_J * 1e15);
    println!("latency                      : {:.1} ns (paper {:.1} ns)",
        conv.latency() * 1e9, PAPER_LATENCY_S * 1e9);
    println!("area (28 nm scaled)          : {:.2} µm²", conv.area_um2());
    Ok(())
}
