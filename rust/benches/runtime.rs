//! PJRT runtime bench: artifact compile time and request-path execute
//! latency for each batch variant (the production hot path).
//!
//! Skips gracefully when `artifacts/` has not been built.

use std::path::PathBuf;
use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};
use stox_net::runtime::Engine;
use stox_net::util::bench;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("runtime bench: no artifacts/ — run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let test = TestSet::load(&manifest).unwrap();

    let t0 = std::time::Instant::now();
    let engine = Engine::load(&manifest).unwrap();
    println!(
        "engine load+compile ({} variants): {:?}",
        engine.batch_sizes().len(),
        t0.elapsed()
    );

    for b in engine.batch_sizes() {
        let handle = engine.model(b).unwrap();
        let imgs: Vec<f32> = (0..b).flat_map(|i| test.image(i).to_vec()).collect();
        let mut seed = 0u32;
        bench::quick(&format!("pjrt/infer batch={b}"), || {
            seed = seed.wrapping_add(1);
            bench::black_box(handle.infer(&imgs, seed).unwrap());
        });
    }

    // native functional model for comparison (the validation path)
    let store = WeightStore::load(&manifest).unwrap();
    let native = NativeModel::load(&manifest, &store).unwrap();
    let imgs8: Vec<f32> = (0..8).flat_map(|i| test.image(i).to_vec()).collect();
    let mut seed = 0u32;
    bench::bench(
        "native/forward batch=8",
        std::time::Duration::from_millis(200),
        std::time::Duration::from_secs(2),
        || {
            seed = seed.wrapping_add(1);
            bench::black_box(native.forward(&imgs8, 8, seed));
        },
    );
}
