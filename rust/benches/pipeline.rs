//! Fig. 8 regeneration bench: pipeline stage occupancy, ADC sharing
//! sweep, multi-sampling sweep, and layer/network latency model timings —
//! plus the native-model forward before/after (fused digit-domain conv
//! path vs the legacy im2col path) on the committed tiny checkpoint.
//! Writes `BENCH_pipeline.json` (median ns/op per case).

use stox_net::arch::components::PsProcessing;
use stox_net::arch::mapper::map_network;
use stox_net::arch::pipeline::PipelineModel;
use stox_net::imc::StoxConfig;
use stox_net::model::weights::TestSet;
use stox_net::model::{zoo, Manifest, NativeModel, WeightStore};
use stox_net::util::bench::{self, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("pipeline");
    let pipe = PipelineModel::default();

    // ----- Fig. 8 panel -----
    println!("{}", pipe.render_fig8(128, 8, 1));

    // ----- beat-period sweeps -----
    println!("== ADC column-sharing sweep (beat ns, 128 cols) ==");
    for share in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let s = pipe.stages(PsProcessing::AdcFullPrecision { share }, 128);
        println!("share {share:>4} -> beat {:>8.1} ns", s.beat_ns);
    }
    println!("\n== MTJ multi-sampling sweep (beat ns) ==");
    for samples in [1u32, 2, 4, 8] {
        let s = pipe.stages(PsProcessing::StochasticMtj { samples }, 128);
        println!("samples {samples} -> beat {:>6.1} ns (ps stage {:.1} ns)", s.beat_ns, s.t_ps_ns);
    }

    // ----- network latency under both designs -----
    let layers = map_network(&zoo::resnet20_cifar(), &StoxConfig::default(), 128);
    let lat_adc = pipe.network_latency_ns(&layers, |_| PsProcessing::AdcFullPrecision { share: 8 });
    let lat_mtj = pipe.network_latency_ns(&layers, |_| PsProcessing::StochasticMtj { samples: 1 });
    println!(
        "\nResNet-20 single-inference latency: ADC(8:1) {:.1} µs vs MTJ x1 {:.1} µs ({:.1}x)",
        lat_adc / 1e3,
        lat_mtj / 1e3,
        lat_adc / lat_mtj
    );

    println!("\n== timing the model itself ==");
    suite.quick("pipeline/network_latency resnet20", || {
        bench::black_box(
            pipe.network_latency_ns(&layers, |_| PsProcessing::StochasticMtj { samples: 1 }),
        );
    });

    // ----- native forward: fused digit-domain conv vs legacy im2col -----
    let fixture = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data/tiny_inhomo");
    if fixture.join("manifest.json").exists() {
        let m = Manifest::load(&fixture).expect("fixture manifest");
        let store = WeightStore::load(&m).expect("fixture weights");
        let test = TestSet::load(&m).expect("fixture testset");
        let n = test.n.min(4);
        let images = &test.images[..n * m.spec.image_size * m.spec.image_size * m.spec.in_channels];
        println!("\n== native forward: fused digit-domain vs legacy im2col ==");
        let mut legacy = NativeModel::load(&m, &store).expect("model");
        legacy.set_fused_conv(false);
        let mut seed = 0u32;
        let before = suite.quick("forward/tiny legacy im2col", || {
            seed = seed.wrapping_add(1);
            bench::black_box(legacy.forward(images, n, seed));
        });
        let fused = NativeModel::load(&m, &store).expect("model");
        let after = suite.quick("forward/tiny fused digit-domain", || {
            seed = seed.wrapping_add(1);
            bench::black_box(fused.forward(images, n, seed));
        });
        println!(
            "-> fused-conv median speedup: {:.2}x",
            suite.median_ns(before) / suite.median_ns(after)
        );

        // ----- layer-pipelined forward vs sequential whole-batch -----
        let threads = stox_net::util::pool::default_threads();
        println!("\n== native forward: layer pipeline vs sequential ({n} images, {threads} threads) ==");
        let mut sequential = NativeModel::load(&m, &store).expect("model");
        sequential.set_pipeline(false);
        let seq_case = suite.quick("forward/tiny sequential whole-batch", || {
            seed = seed.wrapping_add(1);
            bench::black_box(sequential.forward(images, n, seed));
        });
        let pipelined = NativeModel::load(&m, &store).expect("model");
        let pipe_case = suite.quick(
            &format!("forward/tiny layer-pipelined [{threads} threads]"),
            || {
                seed = seed.wrapping_add(1);
                bench::black_box(pipelined.forward(images, n, seed));
            },
        );
        println!(
            "-> layer-pipeline median speedup: {:.2}x (analytical bound {:.2}x)",
            suite.median_ns(seq_case) / suite.median_ns(pipe_case),
            stox_net::arch::pipeline::software_pipeline_speedup(n, threads)
        );
    } else {
        println!("(tiny_inhomo fixture missing — skipping forward bench)");
    }

    suite.write_json().expect("bench artifact written");
}
