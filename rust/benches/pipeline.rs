//! Fig. 8 regeneration bench: pipeline stage occupancy, ADC sharing
//! sweep, multi-sampling sweep, and layer/network latency model timings.

use stox_net::arch::components::PsProcessing;
use stox_net::arch::mapper::map_network;
use stox_net::arch::pipeline::PipelineModel;
use stox_net::imc::StoxConfig;
use stox_net::model::zoo;
use stox_net::util::bench;

fn main() {
    let pipe = PipelineModel::default();

    // ----- Fig. 8 panel -----
    println!("{}", pipe.render_fig8(128, 8, 1));

    // ----- beat-period sweeps -----
    println!("== ADC column-sharing sweep (beat ns, 128 cols) ==");
    for share in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let s = pipe.stages(PsProcessing::AdcFullPrecision { share }, 128);
        println!("share {share:>4} -> beat {:>8.1} ns", s.beat_ns);
    }
    println!("\n== MTJ multi-sampling sweep (beat ns) ==");
    for samples in [1u32, 2, 4, 8] {
        let s = pipe.stages(PsProcessing::StochasticMtj { samples }, 128);
        println!("samples {samples} -> beat {:>6.1} ns (ps stage {:.1} ns)", s.beat_ns, s.t_ps_ns);
    }

    // ----- network latency under both designs -----
    let layers = map_network(&zoo::resnet20_cifar(), &StoxConfig::default(), 128);
    let lat_adc = pipe.network_latency_ns(&layers, |_| PsProcessing::AdcFullPrecision { share: 8 });
    let lat_mtj = pipe.network_latency_ns(&layers, |_| PsProcessing::StochasticMtj { samples: 1 });
    println!(
        "\nResNet-20 single-inference latency: ADC(8:1) {:.1} µs vs MTJ x1 {:.1} µs ({:.1}x)",
        lat_adc / 1e3,
        lat_mtj / 1e3,
        lat_adc / lat_mtj
    );

    println!("\n== timing the model itself ==");
    bench::quick("pipeline/network_latency resnet20", || {
        bench::black_box(
            pipe.network_latency_ns(&layers, |_| PsProcessing::StochasticMtj { samples: 1 }),
        );
    });
}
