//! Training-path bench: the cost of PS-quantization-aware training
//! relative to inference on the same layer stack (the committed
//! `tiny_inhomo` fixture), plus the capture-hook overhead in isolation.
//!
//! Cases (written to `BENCH_train.json` for the CI perf trajectory):
//!
//! * `capture/…` — `StoxMvm::run` vs `StoxMvm::run_capture` on a
//!   mid-size crossbar: the per-slice PS capture rides the forward's
//!   accumulation pass, so the overhead should be the capture writes
//!   only (one f32 store per PS element);
//! * `step/…` — one full `Trainer::step` (stochastic forward with
//!   capture, digit-STE backward, SGD) vs one `NativeModel::forward` of
//!   the same batch — the train:infer cost ratio.

use std::path::PathBuf;
use stox_net::imc::{PsConverterSpec, StoxConfig, StoxMvm};
use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};
use stox_net::stats::rng::CounterRng;
use stox_net::train::{TrainConfig, Trainer};
use stox_net::util::bench::{self, BenchSuite};

fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
    let rng = CounterRng::new(seed);
    (0..n).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect()
}

fn main() {
    let mut suite = BenchSuite::new("train");

    // capture-hook overhead on a ResNet-20 mid-layer shape
    let (b, m, n) = (8usize, 576usize, 64usize);
    let a = rand_vec(b * m, 1);
    let w = rand_vec(m * n, 2);
    let cfg = StoxConfig::default();
    let conv = "inhomo:base=1,extra=3"
        .parse::<PsConverterSpec>()
        .unwrap()
        .build(&cfg)
        .unwrap();
    let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
    let mut seed = 0u32;
    println!("== capture-hook overhead (B={b}, M={m}, N={n}, inhomo) ==");
    let fwd = suite.quick("capture/forward run_sequential", || {
        seed = seed.wrapping_add(1);
        bench::black_box(mvm.run_sequential(&a, b, conv.as_ref(), seed));
    });
    let cap = suite.quick("capture/forward run_capture", || {
        seed = seed.wrapping_add(1);
        bench::black_box(mvm.run_capture(&a, b, conv.as_ref(), seed));
    });
    println!(
        "-> capture overhead: {:.2}x the plain forward\n",
        suite.median_ns(cap) / suite.median_ns(fwd)
    );

    // full step vs inference forward on the committed tiny fixture
    let fixture =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/tiny_inhomo");
    if !fixture.join("manifest.json").exists() {
        println!("(tiny_inhomo fixture missing — skipping trainer-step bench)");
        suite.write_json().expect("bench artifact written");
        return;
    }
    let manifest = Manifest::load(&fixture).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let test = TestSet::load(&manifest).unwrap();
    let hp = TrainConfig { steps: 1, batch: 4, log_every: 0, ..TrainConfig::default() };
    let batch = hp.batch;
    let mut trainer = Trainer::new(&manifest, &store, manifest.spec.stox_config(), None, hp)
        .unwrap();
    let model = NativeModel::load(&manifest, &store).unwrap();
    let img = test.h * test.w * test.c;
    let xb = &test.images[..batch * img];
    let yb = &test.labels[..batch];
    println!("== trainer step vs inference forward (tiny fixture, batch {batch}) ==");
    let infer = suite.quick("step/inference forward", || {
        seed = seed.wrapping_add(1);
        bench::black_box(model.forward(xb, batch, seed));
    });
    let mut it = 0usize;
    let step = suite.quick("step/train step (fwd+bwd+sgd)", || {
        it += 1;
        bench::black_box(trainer.step(xb, yb, batch, it, 1e-4).unwrap());
    });
    println!(
        "-> train step costs {:.2}x an inference forward\n",
        suite.median_ns(step) / suite.median_ns(infer)
    );

    suite.write_json().expect("bench artifact written");
}
