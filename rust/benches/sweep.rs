//! Sweep-path bench: times the registry-driven accuracy × energy Pareto
//! sweep (`arch::sweep::run_sweep`) over the default grid on the golden
//! workload, at 1 thread vs the pool fan-out — the perf tracking the
//! ISSUE asks for, and a smoke report of the front itself.
//!
//! Run with `cargo bench --bench sweep`.

use stox_net::arch::sweep::{default_grid, run_sweep, GoldenWorkload};
use stox_net::imc::StoxConfig;
use stox_net::model::zoo;
use stox_net::util::bench;

fn main() {
    let cfg = StoxConfig::default();
    let layers = zoo::resnet20_cifar();
    let gw = GoldenWorkload::new(cfg, 32, 1).expect("golden workload");
    let specs = default_grid(&cfg, &[1, 2, 4, 8], &[2, 4, 8]);
    println!(
        "sweep grid: {} specs, {} golden inputs\n",
        specs.len(),
        gw.n_inputs()
    );

    for threads in [1usize, stox_net::util::pool::default_threads()] {
        bench::quick(&format!("sweep/golden32/threads={threads}"), || {
            let r = run_sweep(
                &specs,
                &cfg,
                &layers,
                "resnet20_cifar",
                1,
                threads,
                |spec| Ok(gw.accuracy(spec.build(&cfg)?.as_ref())),
            )
            .expect("sweep");
            bench::black_box(r.points.len());
        });
    }

    // the front itself, once — the bench doubles as a smoke report
    let r = run_sweep(&specs, &cfg, &layers, "resnet20_cifar", 1, 4, |spec| {
        Ok(gw.accuracy(spec.build(&cfg)?.as_ref()))
    })
    .expect("sweep");
    println!("\n{}", r.render_table());
}
