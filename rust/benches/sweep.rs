//! Sweep-path bench: times the registry-driven accuracy × energy Pareto
//! sweep (`arch::sweep::run_sweep`) over the default grid on the golden
//! workload at 1 thread vs the pool fan-out, the two-tag design-matrix
//! sweep (`run_matrix_sweep`), and — on the model path — the
//! programming-reuse speedup: one `Arc`-shared programming pass vs a
//! reload-per-spec sweep over the committed tiny checkpoint (the ISSUE 3
//! measurement backing the shared-weight-programming refactor).
//!
//! Run with `cargo bench --bench sweep`.

use stox_net::arch::sweep::{
    default_grid, parse_precision_tags, run_matrix_sweep, run_sweep, GoldenWorkload,
};
use stox_net::imc::{PsConverterSpec, StoxConfig};
use stox_net::model::weights::TestSet;
use stox_net::model::{zoo, Manifest, NativeModel, WeightStore};
use stox_net::util::bench::{self, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("sweep");
    let cfg = StoxConfig::default();
    let layers = zoo::resnet20_cifar();
    let gw = GoldenWorkload::new(cfg, 32, 1).expect("golden workload");
    let specs = default_grid(&cfg, &[1, 2, 4, 8], &[2, 4, 8]);
    println!(
        "sweep grid: {} specs, {} golden inputs\n",
        specs.len(),
        gw.n_inputs()
    );

    for threads in [1usize, stox_net::util::pool::default_threads()] {
        suite.quick(&format!("sweep/golden32/threads={threads}"), || {
            let r = run_sweep(
                &specs,
                &cfg,
                &layers,
                "resnet20_cifar",
                1,
                threads,
                |spec| Ok(gw.accuracy(spec.build(&cfg)?.as_ref())),
            )
            .expect("sweep");
            bench::black_box(r.points.len());
        });
    }

    // the two-axis design matrix: precision tags × the same spec grid
    let tags = parse_precision_tags("4w4a4bs,8w8a4bs", &cfg).expect("tags");
    let gws: Vec<GoldenWorkload> = tags
        .iter()
        .map(|c| GoldenWorkload::new(*c, 32, 1).expect("golden workload"))
        .collect();
    let grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> = tags
        .iter()
        .map(|c| (*c, default_grid(c, &[1, 2, 4, 8], &[2, 4, 8])))
        .collect();
    suite.quick("sweep/matrix2x/golden32", || {
        let r = run_matrix_sweep(
            &grid,
            &layers,
            "resnet20_cifar",
            1,
            stox_net::util::pool::default_threads(),
            |ti, spec| Ok(gws[ti].accuracy(spec.build(gws[ti].cfg())?.as_ref())),
        )
        .expect("matrix sweep");
        bench::black_box(r.points.len());
    });

    // programming-reuse on the model path: N converter specs against the
    // committed tiny checkpoint — shared Arc programming vs the old
    // reload-and-reprogram-per-spec shape
    let fixture = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data/tiny_inhomo");
    if fixture.join("manifest.json").exists() {
        let m = Manifest::load(&fixture).expect("fixture manifest");
        let store = WeightStore::load(&m).expect("fixture weights");
        let test = TestSet::load(&m).expect("fixture testset");
        let model_cfg = m.spec.stox_config();
        let model_specs: Vec<PsConverterSpec> = [
            "ideal",
            "sa",
            "sparse:bits=4",
            "stox:alpha=4,samples=1",
            "stox:alpha=4,samples=2",
            "inhomo:alpha=4,base=1,extra=3",
        ]
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
        let n = test.n.min(4);
        let base =
            NativeModel::load_with_config(&m, &store, model_cfg).expect("model");
        println!();
        suite.quick("sweep/model-6spec/shared-programming", || {
            let mut acc = 0.0;
            for spec in &model_specs {
                let view = base.share_with_converter_spec(spec).expect("view");
                acc += view.accuracy(&test.images, &test.labels, n, 4, 7);
            }
            bench::black_box(acc);
        });
        suite.quick("sweep/model-6spec/reload-per-spec", || {
            let mut acc = 0.0;
            for spec in &model_specs {
                let model = NativeModel::load(&m, &store)
                    .expect("model")
                    .with_converter_spec(spec)
                    .expect("converter");
                acc += model.accuracy(&test.images, &test.labels, n, 4, 7);
            }
            bench::black_box(acc);
        });
    } else {
        println!("(tiny_inhomo fixture missing — skipping model-path bench)");
    }

    // the front itself, once — the bench doubles as a smoke report
    let r = run_sweep(&specs, &cfg, &layers, "resnet20_cifar", 1, 4, |spec| {
        Ok(gw.accuracy(spec.build(&cfg)?.as_ref()))
    })
    .expect("sweep");
    println!("\n{}", r.render_table());

    suite.write_json().expect("bench artifact written");
}
