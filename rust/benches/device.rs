//! Device-layer bench: LLG integration cost and switching-curve
//! extraction (the Fig. 2 experiment) plus single conversions.

use stox_net::device::llg::{LlgParams, LlgSim};
use stox_net::device::mtj::{SotMtj, SwitchingCurve};
use stox_net::imc::{PsConvert, PsConverterSpec, StoxConfig};
use stox_net::stats::rng::CounterRng;
use stox_net::util::bench;

fn main() {
    println!("== LLG macro-spin solver ==");
    let p = LlgParams::default();
    let mut seed = 0u32;
    bench::quick("llg/2ns pulse (2000 steps)", || {
        seed = seed.wrapping_add(1);
        let mut sim = LlgSim::new(p, seed);
        bench::black_box(sim.switch_trial(60e-6, 2e-9));
    });

    println!("\n== switching-curve extraction (Fig. 2, small) ==");
    bench::bench(
        "curve/9pts x 16 trials",
        std::time::Duration::from_millis(200),
        std::time::Duration::from_secs(2),
        || {
            bench::black_box(SwitchingCurve::extract(
                p,
                &SotMtj::default(),
                9,
                16,
                7,
            ));
        },
    );

    println!("\n== stochastic conversion (Eq. 1 fast path) ==");
    let rng = CounterRng::new(3);
    let cfg = StoxConfig::default();
    let build = |s: &str| {
        s.parse::<PsConverterSpec>().unwrap().build(&cfg).unwrap()
    };
    let mtj1 = build("stox:alpha=4,samples=1");
    let mtj8 = build("stox:alpha=4,samples=8");
    let mut c = 0u32;
    bench::quick("convert/MTJ x1 (1k PS)", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            c = c.wrapping_add(1);
            acc += mtj1.convert(0.1, c.wrapping_add(i), &rng);
        }
        bench::black_box(acc);
    });
    bench::quick("convert/MTJ x8 (1k PS)", || {
        let mut acc = 0.0;
        for i in 0..1000 {
            c = c.wrapping_add(1);
            acc += mtj8.convert(0.1, c.wrapping_add(i), &rng);
        }
        bench::black_box(acc);
    });
}
