//! Coordinator bench: dynamic-batcher throughput, tile-scheduler
//! throughput, and the end-to-end serving loop on a synthetic executor
//! (isolates L3 from model-execution cost).

use std::sync::mpsc;
use std::time::{Duration, Instant};
use stox_net::arch::components::ComponentCosts;
use stox_net::arch::energy::DesignConfig;
use stox_net::coordinator::batcher::{BatcherConfig, DynamicBatcher};
use stox_net::coordinator::server::{submit_all, Executor, ServeConfig, Server};
use stox_net::coordinator::TileScheduler;
use stox_net::imc::StoxConfig;
use stox_net::model::zoo;
use stox_net::serve::{ReplicaConfig, ReplicaServer, ResilienceConfig};
use stox_net::util::bench;

struct NoopExec;

impl Executor for NoopExec {
    fn execute(&self, _im: &[f32], batch: usize, _s: u32) -> anyhow::Result<Vec<f32>> {
        Ok(vec![0.0; batch * 10])
    }
    fn classes(&self) -> usize {
        10
    }
    fn image_elems(&self) -> usize {
        16
    }
    fn max_batch(&self) -> usize {
        8
    }
}

fn main() {
    println!("== dynamic batcher ==");
    bench::quick("batcher/push+flush 1k reqs (batch 8)", || {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        let now = Instant::now();
        let mut flushed = 0;
        for i in 0..1000 {
            b.push(i, now);
            while let Some(batch) = b.try_flush(now) {
                flushed += batch.items.len();
            }
        }
        bench::black_box(flushed);
    });

    println!("\n== tile scheduler ==");
    let costs = ComponentCosts::default();
    let layers = zoo::resnet20_cifar();
    bench::quick("scheduler/schedule 100 batches", || {
        let mut s = TileScheduler::new(
            &costs,
            DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        for i in 0..100 {
            bench::black_box(s.schedule_batch(8, i as f64 * 100.0));
        }
    });

    println!("\n== serving loop (noop executor) ==");
    bench::bench(
        "server/1k requests end-to-end",
        Duration::from_millis(100),
        Duration::from_secs(2),
        || {
            let server = Server::new(
                Box::new(NoopExec),
                ServeConfig {
                    batcher: BatcherConfig {
                        target_batch: 8,
                        max_wait: Duration::from_micros(200),
                    },
                    seed: 0,
                    max_retries: 0,
                },
            );
            let (tx, rx) = mpsc::channel();
            let client = std::thread::spawn(move || {
                let r = submit_all(&tx, (0..1000).map(|_| vec![0.0f32; 16]));
                drop(tx);
                r
            });
            server.run(rx);
            let replies = client.join().unwrap();
            bench::black_box(replies.len());
        },
    );

    println!("\n== replica tier (noop executor) ==");
    // resilience off = the PR-6 hot path; on = health tracking + fault
    // checks on every batch (quantifies the self-healing overhead, which
    // should be noise against even a noop executor)
    for resilience in [false, true] {
        for replicas in [1usize, 2, 4] {
            let label = if resilience { "self-healing" } else { "baseline" };
            bench::bench(
                &format!("replica-server/{replicas}x 1k requests {label}"),
                Duration::from_millis(100),
                Duration::from_secs(2),
                || {
                    let server = ReplicaServer::new(
                        (0..replicas).map(|_| NoopExec).collect(),
                        ReplicaConfig {
                            replicas,
                            batcher: BatcherConfig {
                                target_batch: 8,
                                max_wait: Duration::from_micros(200),
                            },
                            seed: 0,
                            // deep enough that the 1k burst never sheds
                            queue_depth: 4096,
                            deadline: None,
                            slo: Duration::from_millis(50),
                            steal: true,
                            resilience: ResilienceConfig {
                                enabled: resilience,
                                ..Default::default()
                            },
                        },
                    );
                    let (tx, rx) = mpsc::channel();
                    let client = std::thread::spawn(move || {
                        let r = submit_all(&tx, (0..1000).map(|_| vec![0.0f32; 16]));
                        drop(tx);
                        r
                    });
                    server.run(rx);
                    let replies = client.join().unwrap();
                    bench::black_box(replies.len());
                },
            );
        }
    }
}
