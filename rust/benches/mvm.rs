//! MVM hot-path bench: the Rust-native Algorithm 1 crossbar MVM across
//! converter types and configurations (the L3 functional hot loop).
//!
//! Regenerates the per-conversion cost story behind Table 2 / Fig. 9 at
//! the functional level: MTJ sampling cost scales with samples; the
//! converter choice does not change the analog PS work.
//!
//! All converters are constructed through the `PsConverterSpec` registry
//! (the production path); the final section isolates the converter-path
//! redesign itself — legacy per-element enum dispatch vs the
//! slice-vectorized `PsConvert::convert_slice`.

use stox_net::imc::{PsConvert, PsConverter, PsConverterSpec, StoxConfig, StoxMvm};
use stox_net::stats::rng::CounterRng;
use stox_net::util::bench;

fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
    let rng = CounterRng::new(seed);
    (0..n).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect()
}

fn main() {
    // a mid-network ResNet-20 layer: M = 3·3·64 = 576 rows, 64 cols
    let (b, m, n) = (8usize, 576usize, 64usize);
    let a = rand_vec(b * m, 1);
    let w = rand_vec(m * n, 2);

    println!("== stox MVM (B={b}, M={m}, N={n}) ==");
    for (name, cfg, spec) in [
        ("4w4a4bs ideal-ADC", StoxConfig::default(), "ideal"),
        ("4w4a4bs 1b-SA", StoxConfig::default(), "sa"),
        ("4w4a4bs MTJ x1", StoxConfig::default(), "stox:samples=1"),
        (
            "4w4a4bs MTJ x8",
            StoxConfig { n_samples: 8, ..Default::default() },
            "stox:samples=8",
        ),
        (
            "4w4a1bs MTJ x1 (sliced)",
            StoxConfig { w_slice_bits: 1, ..Default::default() },
            "stox:samples=1",
        ),
        (
            "2w2a1bs MTJ x1",
            StoxConfig {
                a_bits: 2,
                w_bits: 2,
                w_slice_bits: 1,
                ..Default::default()
            },
            "stox:samples=1",
        ),
        (
            "4w4a1bs inhomo 1..4",
            StoxConfig { w_slice_bits: 1, ..Default::default() },
            "inhomo:base=1,extra=3",
        ),
        ("4w4a4bs sparse-ADC 4b", StoxConfig::default(), "sparse:bits=4"),
    ] {
        let conv = spec
            .parse::<PsConverterSpec>()
            .unwrap()
            .build(&cfg)
            .unwrap();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let mut seed = 0u32;
        bench::quick(&format!("mvm/{name}"), || {
            seed = seed.wrapping_add(1);
            bench::black_box(mvm.run(&a, b, conv.as_ref(), seed));
        });
    }

    println!("\n== converter path: legacy scalar dispatch vs convert_slice ==");
    // one full PS column set of the layer above, converted in isolation —
    // the seam the PsConvert redesign vectorizes
    let ps = rand_vec(16 * 1024, 7);
    let mut out = vec![0.0f32; ps.len()];
    let rng = CounterRng::new(5);
    for (name, legacy, spec) in [
        (
            "MTJ x4",
            PsConverter::StochasticMtj { alpha: 4.0, n_samples: 4 },
            "stox:alpha=4,samples=4",
        ),
        ("quant-ADC 8b", PsConverter::QuantAdc { bits: 8 }, "quant:bits=8"),
        ("ideal-ADC", PsConverter::IdealAdc, "ideal"),
    ] {
        bench::quick(&format!("convert/scalar-dispatch {name} (16k PS)"), || {
            for (idx, (&p, o)) in ps.iter().zip(out.iter_mut()).enumerate() {
                *o = legacy.convert(p, idx as u32, &rng);
            }
            bench::black_box(&out);
        });
        let conv = spec
            .parse::<PsConverterSpec>()
            .unwrap()
            .build(&StoxConfig::default())
            .unwrap();
        bench::quick(&format!("convert/slice {name} (16k PS)"), || {
            conv.convert_slice(&ps, &mut out, 0, 1, &rng);
            bench::black_box(&out);
        });
    }

    println!("\n== crossbar programming (weight reload) ==");
    bench::quick("program/4w4a4bs 576x64", || {
        bench::black_box(StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap());
    });

    println!("\n== PS collection (Fig. 4 probe path) ==");
    let mvm = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
    bench::quick("collect_ps/4w4a4bs", || {
        bench::black_box(mvm.collect_ps(&a, b));
    });
}
