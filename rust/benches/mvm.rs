//! MVM hot-path bench: the Rust-native Algorithm 1 crossbar MVM across
//! converter types and configurations (the L3 functional hot loop).
//!
//! The headline section is the integer digit-plane kernel before/after:
//! the retained pre-PR f32 kernel (`StoxMvm::program_reference`) against
//! the i8/i32 kernel (`StoxMvm::program`) on the ResNet-20 mid-layer case
//! (B=8, M=576, N=64, MTJ ×1) — the EXPERIMENTS.md §Perf acceptance case.
//! Results are also written to `BENCH_mvm.json` (median ns/op per case)
//! for the CI perf-trajectory artifact.
//!
//! All converters are constructed through the `PsConverterSpec` registry
//! (the production path); the converter section isolates the converter
//! dispatch redesign — legacy per-element enum dispatch vs the
//! slice-vectorized `PsConvert::convert_slice`.

use stox_net::arch::components::PsProcessing;
use stox_net::imc::{
    decompose_activations, im2col, ConvArena, MacBackend, PsConvert, PsConverter,
    PsConverterSpec, PsIntCache, StoxConfig, StoxMvm,
};
use stox_net::obs;
use stox_net::stats::rng::CounterRng;
use stox_net::util::bench::{self, BenchSuite};

fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
    let rng = CounterRng::new(seed);
    (0..n).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect()
}

/// Delegating wrapper that deliberately does NOT override
/// `convert_batch`, so the trait's default per-slice loop runs — the
/// "before" side of the batched-conversion comparison.
struct PerSlice(Box<dyn PsConvert>);

impl PsConvert for PerSlice {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    ) {
        self.0.convert_slice(ps, out, counter_base, counter_stride, rng);
    }
    #[allow(clippy::too_many_arguments)]
    fn convert_slice_at(
        &self,
        stream: usize,
        w_slice: usize,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    ) {
        self.0.convert_slice_at(stream, w_slice, ps, out, counter_base, counter_stride, rng);
    }
    #[allow(clippy::too_many_arguments)]
    fn convert_slice_int_at(
        &self,
        stream: usize,
        w_slice: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        self.0.convert_slice_int_at(
            stream, w_slice, ps_int, ps_scale, out, counter_base, counter_stride, rng, cache,
        );
    }
    fn samples(&self) -> u32 {
        self.0.samples()
    }
    fn cost_key(&self) -> PsProcessing {
        self.0.cost_key()
    }
    fn label(&self) -> String {
        format!("{} [per-slice]", self.0.label())
    }
}

fn main() {
    let mut suite = BenchSuite::new("mvm");

    // a mid-network ResNet-20 layer: M = 3·3·64 = 576 rows, 64 cols
    let (b, m, n) = (8usize, 576usize, 64usize);
    let a = rand_vec(b * m, 1);
    let w = rand_vec(m * n, 2);

    println!("== integer digit-plane kernel before/after (B={b}, M={m}, N={n}, MTJ x1) ==");
    let mtj1 = "stox:samples=1"
        .parse::<PsConverterSpec>()
        .unwrap()
        .build(&StoxConfig::default())
        .unwrap();
    let pre = StoxMvm::program_reference(&w, m, n, StoxConfig::default()).unwrap();
    let post = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
    assert!(post.is_integer_kernel());
    let mut seed = 0u32;
    // kernel-only comparison: both sides strictly sequential, so the
    // ratio isolates the i8/i32 layout + threshold memo from threading
    let before = suite.quick("mvm/4w4a4bs MTJ x1 [pre-PR f32 kernel, sequential]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(pre.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    let after = suite.quick("mvm/4w4a4bs MTJ x1 [integer kernel, sequential]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(post.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    println!(
        "-> integer-kernel median speedup (sequential, kernel-only): {:.2}x\n",
        suite.median_ns(before) / suite.median_ns(after)
    );
    // end-to-end comparison: the auto-dispatching run() both before and
    // after — includes the new (b, k) sub-batch split, i.e. what every
    // consumer of StoxMvm::run actually observes
    let before_e2e = suite.quick("mvm/4w4a4bs MTJ x1 [pre-PR kernel, auto-parallel]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(pre.run(&a, b, mtj1.as_ref(), seed));
    });
    let after_e2e = suite.quick("mvm/4w4a4bs MTJ x1 [integer kernel, auto-parallel]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(post.run(&a, b, mtj1.as_ref(), seed));
    });
    println!(
        "-> end-to-end median speedup (run() before vs after): {:.2}x\n",
        suite.median_ns(before_e2e) / suite.median_ns(after_e2e)
    );

    println!("== SIMD MAC backends (B={b}, M={m}, N={n}, MTJ x1, sequential) ==");
    let mut scalar_ns = f64::NAN;
    for backend in [
        MacBackend::Scalar,
        MacBackend::Avx2,
        MacBackend::Neon,
        MacBackend::Portable,
    ] {
        if !backend.available() {
            println!("(backend '{}' unavailable in this build — skipped)", backend.label());
            continue;
        }
        let mut mvm = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
        mvm.set_mac_backend(backend).unwrap();
        let idx = suite.quick(&format!("mac/4w4a4bs MTJ x1 [{}]", backend.label()), || {
            seed = seed.wrapping_add(1);
            bench::black_box(mvm.run_sequential(&a, b, mtj1.as_ref(), seed));
        });
        if backend == MacBackend::Scalar {
            scalar_ns = suite.median_ns(idx);
        } else {
            println!(
                "-> {} vs scalar: {:.2}x",
                backend.label(),
                scalar_ns / suite.median_ns(idx)
            );
        }
    }

    println!(
        "\n== i16 accumulation tier (int_ps_bound {} <= 32767) ==",
        StoxConfig::default().int_ps_bound()
    );
    let mut wide = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
    wide.set_i16_tier(false).unwrap();
    let mut narrow = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
    narrow.set_i16_tier(true).unwrap();
    let i32_case = suite.quick("mac/4w4a4bs MTJ x1 [i32 tier]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(wide.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    let i16_case = suite.quick("mac/4w4a4bs MTJ x1 [i16 tier]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(narrow.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    println!(
        "-> i16 tier median speedup: {:.2}x\n",
        suite.median_ns(i32_case) / suite.median_ns(i16_case)
    );

    println!("== batched PS conversion (convert_batch) before/after ==");
    let per_slice = PerSlice(
        "stox:samples=1"
            .parse::<PsConverterSpec>()
            .unwrap()
            .build(&StoxConfig::default())
            .unwrap(),
    );
    let before_conv = suite.quick("convert_batch/MTJ x1 [per-slice loop]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(post.run_sequential(&a, b, &per_slice, seed));
    });
    let after_conv = suite.quick("convert_batch/MTJ x1 [batched]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(post.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    println!(
        "-> batched-conversion median speedup: {:.2}x\n",
        suite.median_ns(before_conv) / suite.median_ns(after_conv)
    );

    println!("== stox MVM (B={b}, M={m}, N={n}) ==");
    for (name, cfg, spec) in [
        ("4w4a4bs ideal-ADC", StoxConfig::default(), "ideal"),
        ("4w4a4bs 1b-SA", StoxConfig::default(), "sa"),
        (
            "4w4a4bs MTJ x8",
            StoxConfig { n_samples: 8, ..Default::default() },
            "stox:samples=8",
        ),
        (
            "4w4a1bs MTJ x1 (sliced)",
            StoxConfig { w_slice_bits: 1, ..Default::default() },
            "stox:samples=1",
        ),
        (
            "2w2a1bs MTJ x1",
            StoxConfig {
                a_bits: 2,
                w_bits: 2,
                w_slice_bits: 1,
                ..Default::default()
            },
            "stox:samples=1",
        ),
        (
            "4w4a1bs inhomo 1..4",
            StoxConfig { w_slice_bits: 1, ..Default::default() },
            "inhomo:base=1,extra=3",
        ),
        ("4w4a4bs sparse-ADC 4b", StoxConfig::default(), "sparse:bits=4"),
    ] {
        let conv = spec
            .parse::<PsConverterSpec>()
            .unwrap()
            .build(&cfg)
            .unwrap();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let mut seed = 0u32;
        suite.quick(&format!("mvm/{name}"), || {
            seed = seed.wrapping_add(1);
            bench::black_box(mvm.run(&a, b, conv.as_ref(), seed));
        });
    }

    println!("\n== sub-batch (b, k) split at batch=1 (single-image serving shape) ==");
    let single = rand_vec(m, 3);
    let threads = stox_net::util::pool::default_threads();
    suite.quick("ksplit/4w4a4bs MTJ x1 batch=1 [sequential]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(post.run_sequential(&single, 1, mtj1.as_ref(), seed));
    });
    suite.quick(
        &format!("ksplit/4w4a4bs MTJ x1 batch=1 [{threads} threads]"),
        || {
            seed = seed.wrapping_add(1);
            bench::black_box(post.run_ksplit(&single, 1, mtj1.as_ref(), seed, threads));
        },
    );

    println!("\n== fused digit-domain conv before/after (x [2,16,16,16], w [3,3,16,32]) ==");
    let (cb, ch, cw, cin, cout) = (2usize, 16usize, 16usize, 16usize, 32usize);
    let x = rand_vec(cb * ch * cw * cin, 4);
    let cwts = rand_vec(3 * 3 * cin * cout, 5);
    let ccfg = StoxConfig::default();
    let cm = 3 * 3 * cin;
    let conv_pre = StoxMvm::program_reference(&cwts, cm, cout, ccfg).unwrap();
    let conv_int = StoxMvm::program(&cwts, cm, cout, ccfg).unwrap();
    suite.quick("conv/im2col + pre-PR f32 kernel", || {
        seed = seed.wrapping_add(1);
        let (patches, ho, wo) = im2col(&x, cb, ch, cw, cin, 3, 3, 1);
        bench::black_box(conv_pre.run(&patches, cb * ho * wo, mtj1.as_ref(), seed));
    });
    suite.quick("conv/im2col + integer kernel", || {
        seed = seed.wrapping_add(1);
        let (patches, ho, wo) = im2col(&x, cb, ch, cw, cin, 3, 3, 1);
        bench::black_box(conv_int.run(&patches, cb * ho * wo, mtj1.as_ref(), seed));
    });
    let mut arena = ConvArena::new();
    suite.quick("conv/fused digit-domain", || {
        seed = seed.wrapping_add(1);
        let acts = decompose_activations(&mut arena, &x, cb, ch, cw, cin, &ccfg);
        bench::black_box(conv_int.run_conv_digits(&acts, 3, 3, 1, mtj1.as_ref(), seed));
    });

    println!("\n== converter path: legacy scalar dispatch vs convert_slice ==");
    // one full PS column set of the layer above, converted in isolation —
    // the seam the PsConvert redesign vectorizes
    let ps = rand_vec(16 * 1024, 7);
    let mut out = vec![0.0f32; ps.len()];
    let rng = CounterRng::new(5);
    for (name, legacy, spec) in [
        (
            "MTJ x4",
            PsConverter::StochasticMtj { alpha: 4.0, n_samples: 4 },
            "stox:alpha=4,samples=4",
        ),
        ("quant-ADC 8b", PsConverter::QuantAdc { bits: 8 }, "quant:bits=8"),
        ("ideal-ADC", PsConverter::IdealAdc, "ideal"),
    ] {
        suite.quick(&format!("convert/scalar-dispatch {name} (16k PS)"), || {
            for (idx, (&p, o)) in ps.iter().zip(out.iter_mut()).enumerate() {
                *o = legacy.convert(p, idx as u32, &rng);
            }
            bench::black_box(&out);
        });
        let conv = spec
            .parse::<PsConverterSpec>()
            .unwrap()
            .build(&StoxConfig::default())
            .unwrap();
        suite.quick(&format!("convert/slice {name} (16k PS)"), || {
            conv.convert_slice(&ps, &mut out, 0, 1, &rng);
            bench::black_box(&out);
        });
    }

    println!("\n== crossbar programming (weight reload) ==");
    suite.quick("program/4w4a4bs 576x64", || {
        bench::black_box(StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap());
    });

    println!("\n== PS collection (Fig. 4 probe path) ==");
    let mvm = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
    suite.quick("collect_ps/4w4a4bs", || {
        bench::black_box(mvm.collect_ps(&a, b));
    });

    println!("\n== observability overhead (digit-plane hot path, B={b}, M={m}, N={n}) ==");
    // the <2% hot-path bound EXPERIMENTS.md §Observability commits to:
    // attaching hardware counters (a dozen relaxed atomic adds per
    // stripe) and raising the span level must not move the kernel median
    let plain = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
    let obs_off = suite.quick("obs/4w4a4bs MTJ x1 [counters off, tracing off]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(plain.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    let reg = obs::CounterRegistry::new();
    let mut counted = StoxMvm::program(&w, m, n, StoxConfig::default()).unwrap();
    counted.attach_counters(&reg, "imc.bench.");
    let obs_counters = suite.quick("obs/4w4a4bs MTJ x1 [counters on, tracing off]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(counted.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    obs::span::install(obs::TraceLevel::Request);
    let obs_trace = suite.quick("obs/4w4a4bs MTJ x1 [counters on, tracing request]", || {
        seed = seed.wrapping_add(1);
        bench::black_box(counted.run_sequential(&a, b, mtj1.as_ref(), seed));
    });
    obs::span::set_level(obs::TraceLevel::Off);
    let off_ns = suite.median_ns(obs_off);
    let on_ns = suite.median_ns(obs_counters).max(suite.median_ns(obs_trace));
    println!(
        "-> observability overhead: {:+.2}% (bound +2%)",
        100.0 * (on_ns / off_ns - 1.0)
    );
    assert!(
        on_ns <= off_ns * 1.02,
        "observability overhead {:.2}% exceeds the 2% hot-path bound \
         (off {off_ns:.0} ns/op, on {on_ns:.0} ns/op)",
        100.0 * (on_ns / off_ns - 1.0)
    );

    suite.write_json().expect("bench artifact written");
}
