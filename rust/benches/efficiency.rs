//! Fig. 9 regeneration bench: evaluates the full design matrix over all
//! three workloads (the architecture-model rollup) and times it.
//!
//! Run with `cargo bench --bench efficiency` — the printed tables ARE the
//! Fig. 9a/9b reproduction; timings confirm the model is cheap enough to
//! sit inside the coordinator.

use stox_net::arch::components::ComponentCosts;
use stox_net::arch::energy::{evaluate_design, evaluate_network, DesignConfig};
use stox_net::imc::StoxConfig;
use stox_net::model::zoo;
use stox_net::util::bench;

fn main() {
    let costs = ComponentCosts::default();
    let base = StoxConfig::default();

    // ----- Fig. 9a table -----
    let layers = zoo::resnet20_cifar();
    let designs = vec![
        DesignConfig::hpfa(),
        DesignConfig::sfa(),
        DesignConfig::stox(base, 1, true),
        DesignConfig::stox(base, 4, true),
        DesignConfig::stox(base, 8, true),
        DesignConfig::stox_mix(
            base,
            true,
            &[("s0b0c1", 4), ("s0b0c2", 4), ("s0b1c1", 2), ("s0b1c2", 2), ("s0b2c1", 2)],
        ),
    ];
    println!("== Fig. 9a (ResNet-20/CIFAR, normalized to HPFA) ==");
    let results = evaluate_network(&costs, &designs, &layers);
    let hpfa = results[0].0.clone();
    for (r, _) in &results {
        println!(
            "{:<26} energy {:>7.2}x  latency {:>7.2}x  area {:>6.2}x  EDP {:>7.1}x",
            r.name,
            hpfa.energy_pj / r.energy_pj,
            hpfa.latency_ns / r.latency_ns,
            hpfa.area_um2 / r.area_um2,
            hpfa.edp_pj_ns / r.edp_pj_ns
        );
    }

    // ----- Fig. 9b table -----
    println!("\n== Fig. 9b (EDP gain vs HPFA per workload) ==");
    for (name, layers) in [
        ("ResNet-20/CIFAR", zoo::resnet20_cifar()),
        ("ResNet-18/Tiny", zoo::resnet18_tiny()),
        ("ResNet-50/Tiny", zoo::resnet50_tiny()),
    ] {
        let h = evaluate_design(&costs, &DesignConfig::hpfa(), &layers);
        let s1 = evaluate_design(&costs, &DesignConfig::stox(base, 1, true), &layers);
        let s4 = evaluate_design(&costs, &DesignConfig::stox(base, 4, true), &layers);
        println!(
            "{:<18} 1-QF {:>7.1}x   4-QF {:>7.1}x",
            name,
            h.edp_pj_ns / s1.edp_pj_ns,
            h.edp_pj_ns / s4.edp_pj_ns
        );
    }

    // ----- timings -----
    println!("\n== model evaluation cost ==");
    bench::quick("evaluate_design/resnet20", || {
        bench::black_box(evaluate_design(
            &costs,
            &DesignConfig::stox(base, 1, true),
            &layers,
        ));
    });
    let r50 = zoo::resnet50_tiny();
    bench::quick("evaluate_design/resnet50", || {
        bench::black_box(evaluate_design(
            &costs,
            &DesignConfig::stox(base, 1, true),
            &r50,
        ));
    });
}
