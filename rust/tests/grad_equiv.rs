//! Gradient equivalence (ISSUE 5): the Rust digit-STE backward
//! (`train::grad::stox_matmul_backward`) must match the numpy reference
//! goldens (`python/compile/gen_grad_golden.py` →
//! `rust/tests/data/grad_golden.json`) within 1e-5 for every converter
//! with a defined surrogate, and the surrogate derivatives must match
//! finite differences of their transfer curves.
//!
//! Golden inputs are derived from each case's seed through the shared
//! counter RNG — bit-identically on both sides — so the file stores only
//! the expected gradients.  Forward PS captures are exact digit-domain
//! values (integers scaled by a power of two), hence also bit-identical;
//! the only cross-language slack is last-ulp libm `tanh` inside the
//! smooth surrogates, far below the 1e-5 tolerance.

use std::path::PathBuf;
use stox_net::imc::{PsConverterSpec, PsSurrogate, StoxConfig, StoxMvm};
use stox_net::stats::rng::CounterRng;
use stox_net::train::grad::{apply_clip_ste, stox_matmul_backward};
use stox_net::util::json::Json;
use stox_net::util::prop;

fn golden() -> Json {
    let p =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/grad_golden.json");
    Json::parse(&std::fs::read_to_string(&p).expect("grad_golden.json present"))
        .expect("grad_golden.json parses")
}

fn cfg_of(j: &Json) -> StoxConfig {
    StoxConfig {
        a_bits: j.get("a_bits").unwrap().as_u32().unwrap(),
        w_bits: j.get("w_bits").unwrap().as_u32().unwrap(),
        a_stream_bits: j.get("a_stream_bits").unwrap().as_u32().unwrap(),
        w_slice_bits: j.get("w_slice_bits").unwrap().as_u32().unwrap(),
        r_arr: j.get("r_arr").unwrap().as_usize().unwrap(),
        ..Default::default()
    }
}

/// Consecutive `uniform_in(-1, 1)` blocks from one counter stream —
/// the golden generator's `derive_inputs`, bit for bit.
fn derive(seed: u32, sizes: &[usize]) -> Vec<Vec<f32>> {
    let rng = CounterRng::new(seed);
    let mut base = 0u32;
    sizes
        .iter()
        .map(|&sz| {
            let v = (0..sz)
                .map(|i| rng.uniform_in(base + i as u32, -1.0, 1.0))
                .collect();
            base += sz as u32;
            v
        })
        .collect()
}

fn nums(j: &Json) -> Vec<f32> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

fn check_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-5,
            "{what}[{i}]: rust {g} vs numpy {w} (|diff| {})",
            (g - w).abs()
        );
    }
}

#[test]
fn backward_matches_numpy_goldens() {
    let g = golden();
    let cases = g.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 12, "golden must cover every surrogate family");
    let mut seen_specs = std::collections::BTreeSet::new();
    for case in cases {
        let name = case.get("name").unwrap().as_str().unwrap();
        let kind = case.get("kind").unwrap().as_str().unwrap();
        let cfg = cfg_of(case.get("cfg").unwrap());
        let spec_str = case.get("spec").unwrap().as_str().unwrap();
        seen_specs.insert(spec_str.split(':').next().unwrap().to_string());
        let spec: PsConverterSpec = spec_str.parse().unwrap();
        let conv = spec.build(&cfg).unwrap();
        let b = case.get("batch").unwrap().as_usize().unwrap();
        let m = case.get("m").unwrap().as_usize().unwrap();
        let n = case.get("n").unwrap().as_usize().unwrap();
        let seed = case.get("seed").unwrap().as_u32().unwrap();
        if kind == "single" {
            let parts = derive(seed, &[b * m, m * n, b * n]);
            let (a, w, up) = (&parts[0], &parts[1], &parts[2]);
            let mvm = StoxMvm::program(w, m, n, cfg).unwrap();
            // backward depends only on the captured PS, not the draws
            let (_, ps) = mvm.run_capture(a, b, conv.as_ref(), 0);
            let grads =
                stox_matmul_backward(a, w, b, m, n, &cfg, conv.as_ref(), &ps, up);
            let mut d_a = grads.d_patches;
            apply_clip_ste(&mut d_a, a);
            check_close(&d_a, &nums(case.get("d_a").unwrap()), &format!("{name}.d_a"));
            check_close(&grads.d_w, &nums(case.get("d_w").unwrap()), &format!("{name}.d_w"));
        } else {
            let h = case.get("hidden").unwrap().as_usize().unwrap();
            let parts = derive(seed, &[b * m, m * h, h * n, b * n]);
            let (a0, w1, w2, up) = (&parts[0], &parts[1], &parts[2], &parts[3]);
            let mvm1 = StoxMvm::program(w1, m, h, cfg).unwrap();
            let (out1, ps1) = mvm1.run_capture(a0, b, conv.as_ref(), 0);
            let x1: Vec<f32> = out1.iter().map(|v| v.clamp(-1.0, 1.0)).collect();
            let mvm2 = StoxMvm::program(w2, h, n, cfg).unwrap();
            let (_, ps2) = mvm2.run_capture(&x1, b, conv.as_ref(), 0);
            let g2 =
                stox_matmul_backward(&x1, w2, b, h, n, &cfg, conv.as_ref(), &ps2, up);
            let mut d_x1 = g2.d_patches;
            apply_clip_ste(&mut d_x1, &out1);
            let g1 =
                stox_matmul_backward(a0, w1, b, m, h, &cfg, conv.as_ref(), &ps1, &d_x1);
            let mut d_a0 = g1.d_patches;
            apply_clip_ste(&mut d_a0, a0);
            check_close(&d_a0, &nums(case.get("d_a").unwrap()), &format!("{name}.d_a"));
            check_close(&g1.d_w, &nums(case.get("d_w1").unwrap()), &format!("{name}.d_w1"));
            check_close(&g2.d_w, &nums(case.get("d_w2").unwrap()), &format!("{name}.d_w2"));
        }
    }
    // every surrogate family is pinned
    for want in ["ideal", "quant", "sparse", "sa", "expected", "stox", "inhomo"] {
        assert!(seen_specs.contains(want), "golden missing converter '{want}'");
    }
}

/// Finite-difference proptest on the surrogate path: `PsSurrogate::grad`
/// is the derivative of `PsSurrogate::value` away from the piecewise
/// kinks, for every variant and a range of slopes.
#[test]
fn surrogate_gradients_match_finite_differences() {
    prop::check("surrogate fd", 300, |g| {
        let alpha = g.f32_in(0.5, 8.0);
        let s = match g.usize_in(0, 3) {
            0 => PsSurrogate::Identity,
            1 => PsSurrogate::ClipSte,
            2 => PsSurrogate::HardTanh { alpha },
            _ => PsSurrogate::Tanh { alpha },
        };
        let ps = g.f32_in(-1.2, 1.2);
        let near = |x: f32, k: f32| (x.abs() - k).abs() < 2e-2;
        match s {
            PsSurrogate::ClipSte if near(ps, 1.0) => return Ok(()),
            PsSurrogate::HardTanh { alpha } if near(alpha * ps, 1.0) => return Ok(()),
            _ => {}
        }
        let eps = 1e-3f64;
        let f = |x: f64| s.value(x as f32) as f64;
        let fd = (f(ps as f64 + eps) - f(ps as f64 - eps)) / (2.0 * eps);
        let an = s.grad(ps) as f64;
        if (fd - an).abs() > 1e-2 * an.abs().max(1.0) {
            return Err(format!("{s:?} at ps {ps}: fd {fd} vs grad {an}"));
        }
        Ok(())
    });
}

/// The backward is a VJP: exactly linear in the upstream gradient.
/// Scaling by a power of two is exact in f32, so the check is bitwise.
#[test]
fn backward_is_exactly_linear_in_upstream_gradient() {
    let cfg = StoxConfig { r_arr: 32, ..StoxConfig::default() };
    let (b, m, n) = (2usize, 40usize, 5usize);
    let parts = derive(9001, &[b * m, m * n, b * n]);
    let (a, w, up) = (&parts[0], &parts[1], &parts[2]);
    for spec_str in ["expected:alpha=4", "sa", "inhomo:alpha=4,base=1,extra=3"] {
        let spec: PsConverterSpec = spec_str.parse().unwrap();
        let conv = spec.build(&cfg).unwrap();
        let mvm = StoxMvm::program(w, m, n, cfg).unwrap();
        let (_, ps) = mvm.run_capture(a, b, conv.as_ref(), 0);
        let g1 = stox_matmul_backward(a, w, b, m, n, &cfg, conv.as_ref(), &ps, up);
        let up2: Vec<f32> = up.iter().map(|v| v * 2.0).collect();
        let g2 = stox_matmul_backward(a, w, b, m, n, &cfg, conv.as_ref(), &ps, &up2);
        for (x1, x2) in g1.d_patches.iter().zip(&g2.d_patches) {
            assert_eq!(x1 * 2.0, *x2, "{spec_str}: d_a linearity");
        }
        for (x1, x2) in g1.d_w.iter().zip(&g2.d_w) {
            assert_eq!(x1 * 2.0, *x2, "{spec_str}: d_w linearity");
        }
    }
}
