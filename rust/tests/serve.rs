//! Integration pins for the sharded replica serving tier (ISSUE 6) over
//! the committed tiny checkpoint fixture (`rust/tests/data/tiny_inhomo/`):
//!
//! * N-replica serving is **bit-identical** to the single-threaded
//!   [`Server`] loop for the same request stream, seed, and batcher
//!   config — central batch formation + sequence-numbered seeds make the
//!   replica count and shard assignment invisible to the logits;
//! * the Poisson load generator produces a rate curve whose SLO counters
//!   are populated and whose `BENCH_serving.json` artifact round-trips
//!   through the JSON parser with the documented schema.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;
use stox_net::coordinator::server::{submit_all, NativeExecutor, ServeConfig, Server};
use stox_net::coordinator::BatcherConfig;
use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};
use stox_net::serve::{run_sweep, LoadGenConfig, ReplicaConfig, ReplicaServer};
use stox_net::util::json::Json;

fn fixture() -> (Manifest, WeightStore, TestSet) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/tiny_inhomo");
    let m = Manifest::load(dir).expect("tiny_inhomo fixture present");
    let store = WeightStore::load(&m).unwrap();
    let test = TestSet::load(&m).unwrap();
    (m, store, test)
}

fn fixture_images(test: &TestSet, n: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| test.image(i % test.n).to_vec()).collect()
}

/// Collect the logits of every reply in submission order, panicking on
/// any shed (rejected / deadline-exceeded) request — these runs are
/// sized so nothing is shed.
fn run_replica_tier(
    model: &NativeModel,
    cfg: ReplicaConfig,
    images: Vec<Vec<f32>>,
) -> (Vec<Vec<f32>>, ReplicaServer<NativeExecutor>) {
    let server = ReplicaServer::from_native(model, cfg);
    let (tx, rx) = mpsc::channel();
    let rxs = submit_all(&tx, images.into_iter());
    drop(tx);
    server.run(rx);
    let logits = rxs
        .into_iter()
        .map(|r| r.recv().unwrap().result.expect("request not shed"))
        .collect();
    (logits, server)
}

/// The tentpole determinism pin: for the same pre-queued request stream,
/// seed, and batcher config, the N-replica tier returns bit-identical
/// logits to the single-`Server` coordinator — sharding and work
/// stealing never touch the numerics.
#[test]
fn replica_tier_bit_identical_to_single_server() {
    let (m, store, test) = fixture();
    let batcher = BatcherConfig {
        target_batch: 3,
        // pre-queued requests flush by size/drain, never by deadline
        max_wait: Duration::from_secs(10),
    };
    let images = fixture_images(&test, test.n);

    // single-threaded reference
    let single = Server::new(
        Box::new(NativeExecutor { model: NativeModel::load(&m, &store).unwrap() }),
        ServeConfig { batcher, seed: 5, max_retries: 0 },
    );
    let (tx, rx) = mpsc::channel();
    let rxs = submit_all(&tx, images.clone().into_iter());
    drop(tx);
    single.run(rx);
    let reference: Vec<Vec<f32>> = rxs
        .into_iter()
        .map(|r| r.recv().unwrap().result.unwrap())
        .collect();

    for replicas in [1usize, 3] {
        let model = NativeModel::load(&m, &store).unwrap();
        let cfg = ReplicaConfig {
            replicas,
            batcher,
            seed: 5,
            queue_depth: 1024,
            deadline: None,
            slo: Duration::from_secs(1),
            ..Default::default()
        };
        let (logits, server) = run_replica_tier(&model, cfg, images.clone());
        assert_eq!(
            logits, reference,
            "{replicas}-replica tier diverged from the single server"
        );
        assert_eq!(server.metrics.requests(), test.n as u64);
        // 8 fixture images at target 3 → batches of 3, 3, 2
        assert_eq!(server.metrics.batches(), 3);
        assert_eq!(server.metrics.rejected(), 0);
        assert_eq!(server.metrics.deadline_exceeded(), 0);
    }
}

/// The serving-tier golden flows (metrics-JSON consistency, admission
/// shedding with explicit replies, deadline shedding, retry exhaustion,
/// replica-vs-single determinism) now live in the declarative scenario
/// suite — `scenarios/serve_*.yaml`.  This thin shim keeps them under
/// plain `cargo test -q` via the same in-process harness `stox-cli test`
/// uses.  It is the only test in this binary touching the repo
/// `scenarios/` dir (golden bless is not re-entrant).
#[test]
fn serve_scenarios_pass_via_harness() {
    let suite = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios");
    let rep = stox_net::harness::run_suite(
        &suite,
        &stox_net::harness::SuiteOptions { filter: Some("serve_".into()), update: false },
    )
    .unwrap();
    assert!(rep.results.len() >= 5, "expected the serve_* scenarios");
    assert!(rep.ok(), "serve scenarios failed:\n{}", rep.render_table());
}

/// The load generator sweeps offered rates, every submitted request is
/// accounted for (served + shed), SLO counters populate, and the
/// `BENCH_serving.json` artifact round-trips with offered/achieved-rps
/// extras merged next to the timing fields.
#[test]
fn loadgen_sweep_curve_and_artifact() {
    let (m, store, test) = fixture();
    let model = NativeModel::load(&m, &store).unwrap();
    let cfg = ReplicaConfig {
        replicas: 2,
        batcher: BatcherConfig { target_batch: 4, max_wait: Duration::from_millis(2) },
        seed: 0,
        queue_depth: 1024,
        deadline: None,
        // generous SLO: the pin is that counters populate, not the value
        slo: Duration::from_secs(5),
        ..Default::default()
    };
    let lg = LoadGenConfig {
        start_rps: 40.0,
        growth: 2.0,
        steps: 3,
        requests_per_step: 16,
        // never cut early on a loaded CI machine — run all 3 points
        saturation_frac: 0.0,
        seed: 7,
    };
    let images = fixture_images(&test, test.n);
    let (points, suite) = run_sweep(&model, &cfg, &images, &lg);

    assert_eq!(points.len(), 3, "sat-frac 0 runs every rate point");
    assert!(
        points.windows(2).all(|w| w[1].offered_rps > w[0].offered_rps),
        "offered rates grow monotonically"
    );
    for p in &points {
        assert_eq!(
            p.ok + p.rejected + p.deadline_exceeded,
            p.requests as u64,
            "every request is served or explicitly shed at {} rps",
            p.offered_rps
        );
        assert!(p.ok > 0, "some requests served at {} rps", p.offered_rps);
        assert!(p.achieved_rps > 0.0);
        // populated SLO counters: attainment reflects served requests
        assert!((0.0..=1.0).contains(&p.slo_attainment));
        // percentiles are monotone in p (bin-interpolated, so min can sit
        // anywhere inside p50's bin — only the ordering is pinned)
        assert!(p.p50_us <= p.p99_us && p.p99_us <= p.p999_us);
        assert!(p.min_us >= 0.0 && p.mean_us > 0.0);
    }

    let dir = std::env::temp_dir().join("stox_serve_loadgen_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = suite.write_json_to(&dir).unwrap();
    assert!(path.ends_with("BENCH_serving.json"));
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(j.get("bench").and_then(|b| b.as_str()), Some("serving"));
    let cases = j.get("cases").and_then(|c| c.as_arr()).unwrap();
    assert_eq!(cases.len(), points.len());
    for (case, p) in cases.iter().zip(&points) {
        assert_eq!(
            case.get("offered_rps").and_then(|v| v.as_f64()),
            Some(p.offered_rps)
        );
        assert!(case.get("achieved_rps").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(case.get("median_ns").and_then(|v| v.as_f64()).is_some());
        assert!(case.get("slo_attainment").and_then(|v| v.as_f64()).is_some());
    }
    let _ = std::fs::remove_file(path);
}

