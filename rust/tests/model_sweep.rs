//! Model-path design-matrix coverage (ISSUE 3): the committed tiny
//! checkpoint fixture (`rust/tests/data/tiny_inhomo/`, exported by
//! `python/compile/export_fixture.py`) carries `mode:
//! "inhomo:base=1,extra=3"` in its manifest, pinning manifest-driven
//! converter selection through the registry end-to-end (no `--converter`
//! override anywhere), and backs the shared-weight-programming regression
//! tests: per-spec model views must share one programming pass per
//! precision tag and be bit-identical to the old reload-per-spec path.

use std::path::PathBuf;
use stox_net::arch::components::ComponentCosts;
use stox_net::arch::energy::{evaluate_design, DesignConfig};
use stox_net::arch::sweep::{parse_precision_tags, run_matrix_sweep};
use stox_net::imc::{PsConverterSpec, StoxConfig};
use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/tiny_inhomo")
}

fn fixture() -> (Manifest, WeightStore, TestSet) {
    let m = Manifest::load(fixture_dir()).expect("tiny_inhomo fixture present");
    let store = WeightStore::load(&m).unwrap();
    let test = TestSet::load(&m).unwrap();
    (m, store, test)
}

/// The fused digit-domain conv path (ISSUE 4, the default) must produce
/// bit-identical logits to the legacy im2col path across seeds and batch
/// sizes — the model-level pin of the kernel's fused-conv equivalence.
#[test]
fn model_fused_conv_bit_identical_to_legacy_im2col() {
    let (m, store, test) = fixture();
    let fused = NativeModel::load(&m, &store).unwrap();
    let mut legacy = NativeModel::load(&m, &store).unwrap();
    legacy.set_fused_conv(false);
    let img = test.h * test.w * test.c;
    for (batch, seed) in [(1usize, 7u32), (2, 7), (2, 99)] {
        let a = fused.forward(&test.images[..batch * img], batch, seed);
        let b = legacy.forward(&test.images[..batch * img], batch, seed);
        assert_eq!(a, b, "fused != legacy at batch {batch}, seed {seed}");
    }
}

/// The manifest's extended mode string resolves through the registry with
/// no CLI override: the body (and QF first layer) run the §3.2.3
/// inhomogeneous converter, the forward pass is finite and deterministic,
/// and the energy accounting follows the same specs via `cost_key()`.
#[test]
fn manifest_inhomo_mode_resolves_through_registry() {
    let (m, store, test) = fixture();
    assert_eq!(m.spec.stox.mode, "inhomo:base=1,extra=3");
    let body = m.spec.body_converter_spec().unwrap();
    assert_eq!(
        body,
        PsConverterSpec::InhomogeneousMtj {
            alpha: 4.0,
            base_samples: 1,
            extra_samples: 3
        }
    );
    // QF first layer inherits the manifest mode (with its own read count
    // defaulting handled by the spec grammar)
    let first = m.spec.first_layer_spec().unwrap();
    assert_eq!(first.mode_name(), "inhomo");

    let model = NativeModel::load(&m, &store).unwrap();
    let img = test.h * test.w * test.c;
    let logits = model.forward(&test.images[..2 * img], 2, 7);
    assert_eq!(logits.len(), 2 * m.spec.num_classes);
    assert!(logits.iter().all(|v| v.is_finite()));
    let logits2 = model.forward(&test.images[..2 * img], 2, 7);
    assert_eq!(logits, logits2, "inhomo forward must be seed-deterministic");

    // cost rollup stays in lockstep with the manifest-selected converters
    let design =
        DesignConfig::from_specs(m.spec.stox_config(), &body, &first).unwrap();
    let report = evaluate_design(&ComponentCosts::default(), &design, &m.layers);
    assert!(report.energy_pj > 0.0 && report.conversions > 0);
}

/// Regression (ISSUE 3 satellite): a sweep evaluating its converter specs
/// against shared programmed crossbars produces byte-identical front JSON
/// to the old path that reloaded + re-programmed the checkpoint per spec.
#[test]
fn shared_programming_sweep_bit_identical_to_reload() {
    let (m, store, test) = fixture();
    let cfg = m.spec.stox_config();
    let specs: Vec<PsConverterSpec> = [
        "ideal",
        "sa",
        "sparse:bits=4",
        "stox:alpha=4,samples=2",
        "inhomo:alpha=4,base=1,extra=3",
    ]
    .iter()
    .map(|s| s.parse().unwrap())
    .collect();
    let grid = vec![(cfg, specs)];
    let n = test.n.min(8);

    // fast path: one load + one programming pass, Arc-shared across specs
    let base = NativeModel::load_with_config(&m, &store, cfg).unwrap();
    let shared = run_matrix_sweep(&grid, &m.layers, "tiny", 3, 2, |_, spec| {
        let view = base.share_with_converter_spec(spec)?;
        assert!(
            base.shares_programming_with(&view),
            "per-spec view must share the programming pass"
        );
        Ok(view.accuracy(&test.images, &test.labels, n, 4, 77))
    })
    .unwrap();

    // slow path: fresh load + programming per spec (the pre-refactor shape)
    let reload = run_matrix_sweep(&grid, &m.layers, "tiny", 3, 1, |_, spec| {
        let model = NativeModel::load(&m, &store)?.with_converter_spec(spec)?;
        assert!(
            !base.shares_programming_with(&model),
            "a fresh load must not alias the shared programming"
        );
        Ok(model.accuracy(&test.images, &test.labels, n, 4, 77))
    })
    .unwrap();

    assert_eq!(
        shared.to_json().to_string(),
        reload.to_json().to_string(),
        "shared-programming sweep must be bit-identical to per-spec reload"
    );
}

/// The precision axis of a `--model` sweep: one programming pass per tag,
/// shared by every converter spec of that tag, and the matrix result
/// carries both tags' cells.
#[test]
fn model_matrix_one_programming_pass_per_tag() {
    let (m, store, test) = fixture();
    let tags = parse_precision_tags("4w4a4bs,8w8a4bs", &m.spec.stox_config()).unwrap();
    // the manifest helper derives the same configs from tag strings
    assert_eq!(m.spec.precision_config("8w8a4bs").unwrap().tag(), "8w8a4bs");

    let models: Vec<NativeModel> = tags
        .iter()
        .map(|c| NativeModel::load_with_config(&m, &store, *c).unwrap())
        .collect();
    for model in &models {
        for s in ["ideal", "stox:alpha=4,samples=2"] {
            let spec: PsConverterSpec = s.parse().unwrap();
            let view = model.share_with_converter_spec(&spec).unwrap();
            assert!(
                model.shares_programming_with(&view),
                "{s}: view must reuse the tag's programming pass"
            );
        }
    }
    assert!(
        !models[0].shares_programming_with(&models[1]),
        "different precision tags are different programmings"
    );

    let specs: Vec<PsConverterSpec> = ["ideal", "stox:alpha=4,samples=1"]
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    let grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> =
        tags.iter().map(|c| (*c, specs.clone())).collect();
    let n = test.n.min(4);
    let r = run_matrix_sweep(&grid, &m.layers, "tiny", 0, 2, |ti, spec| {
        let view = models[ti].share_with_converter_spec(spec)?;
        Ok(view.accuracy(&test.images, &test.labels, n, 4, 7))
    })
    .unwrap();
    assert_eq!(r.points.len(), 4);
    assert!(r.point_at("4w4a4bs", "ideal").is_some());
    assert!(r.point_at("8w8a4bs", "ideal").is_some());
    assert!(r.point_at("8w8a4bs", "stox:alpha=4,samples=1").is_some());
    // precision axis shows up in the cost rollup on the model path too
    let lo = r.point_at("4w4a4bs", "ideal").unwrap();
    let hi = r.point_at("8w8a4bs", "ideal").unwrap();
    assert!(lo.energy_pj < hi.energy_pj);
}
