//! Redesign safety net: the trait/registry converters must be
//! **bit-identical** to the legacy `PsConverter` enum on the exact
//! fixtures that pin python parity (`rust/tests/data/mvm_golden.json`),
//! and the two new converters must run end-to-end on the same shapes.

use stox_net::imc::{stox_mvm, PsConvert, PsConverter, PsConverterSpec, StoxConfig};
use stox_net::util::json::Json;

fn golden() -> Vec<Json> {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/data/mvm_golden.json"
    );
    let text = std::fs::read_to_string(path).expect("golden vectors present");
    match Json::parse(&text).unwrap() {
        Json::Arr(v) => v,
        _ => panic!("bad golden file"),
    }
}

fn f32s(j: &Json) -> Vec<f32> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

struct Case {
    b: usize,
    m: usize,
    n: usize,
    cfg: StoxConfig,
    mode: String,
    /// Full converter spec string (`mode[:k=v,..]`) rebuilt from the
    /// fixture's params — what the registry parses.
    spec: String,
    seed: u32,
    a: Vec<f32>,
    w: Vec<f32>,
    /// Oracle (python `ref.stox_mvm`) output for this case.
    out: Vec<f32>,
}

/// Modes the legacy `PsConverter` enum can express (the enum-equivalence
/// fixtures); `sparse` / `inhomo` exist only behind the registry.
fn enum_mode(mode: &str) -> bool {
    matches!(mode, "stox" | "sa" | "expected" | "ideal")
}

fn cases() -> Vec<Case> {
    golden()
        .iter()
        .map(|case| {
            let mode = case.get("mode").unwrap().as_str().unwrap().to_string();
            let alpha = case.get("alpha").unwrap().as_f64().unwrap() as f32;
            let spec = match mode.as_str() {
                "sparse" => format!(
                    "sparse:bits={}",
                    case.get("bits").unwrap().as_u32().unwrap()
                ),
                "inhomo" => format!(
                    "inhomo:alpha={alpha},base={},extra={}",
                    case.get("base").unwrap().as_u32().unwrap(),
                    case.get("extra").unwrap().as_u32().unwrap()
                ),
                m => m.to_string(),
            };
            Case {
                b: case.get("b").unwrap().as_usize().unwrap(),
                m: case.get("m").unwrap().as_usize().unwrap(),
                n: case.get("n").unwrap().as_usize().unwrap(),
                cfg: StoxConfig {
                    a_bits: case.get("a_bits").unwrap().as_u32().unwrap(),
                    w_bits: case.get("w_bits").unwrap().as_u32().unwrap(),
                    a_stream_bits: 1,
                    w_slice_bits: case.get("w_slice_bits").unwrap().as_u32().unwrap(),
                    r_arr: case.get("r_arr").unwrap().as_usize().unwrap(),
                    n_samples: case.get("n_samples").unwrap().as_u32().unwrap(),
                    alpha,
                },
                mode,
                spec,
                seed: case.get("seed").unwrap().as_u32().unwrap(),
                a: f32s(case.get("a").unwrap()),
                w: f32s(case.get("w").unwrap()),
                out: f32s(case.get("out").unwrap()),
            }
        })
        .collect()
}

fn legacy_converter(mode: &str, cfg: &StoxConfig) -> PsConverter {
    match mode {
        "sa" => PsConverter::SenseAmp,
        "expected" => PsConverter::ExpectedMtj { alpha: cfg.alpha },
        "ideal" => PsConverter::IdealAdc,
        _ => PsConverter::StochasticMtj {
            alpha: cfg.alpha,
            n_samples: cfg.n_samples,
        },
    }
}

/// Every golden fixture, run once through the legacy enum and once through
/// the registry-built trait converter: outputs must match bit for bit.
#[test]
fn registry_converters_bit_identical_to_enum_on_golden_fixtures() {
    for (ci, c) in cases().iter().enumerate() {
        if !enum_mode(&c.mode) {
            continue; // registry-only converters: see the oracle test below
        }
        let legacy = legacy_converter(&c.mode, &c.cfg);
        let spec =
            PsConverterSpec::from_mode(&c.mode, c.cfg.alpha, c.cfg.n_samples).unwrap();
        let built = spec.build(&c.cfg).unwrap();
        let via_enum =
            stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, &legacy, c.seed).unwrap();
        let via_trait =
            stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, built.as_ref(), c.seed)
                .unwrap();
        assert_eq!(
            via_enum, via_trait,
            "case {ci} (mode {}): trait path diverged from enum path",
            c.mode
        );
    }
}

/// The registry's quant ADC must also match the enum's QuantAdc bitwise on
/// the fixture workloads (no fixture uses it, so drive it directly).
#[test]
fn quant_adc_trait_matches_enum_on_fixture_shapes() {
    for (ci, c) in cases().iter().enumerate().take(3) {
        for bits in [1u32, 4, 8] {
            let legacy = PsConverter::QuantAdc { bits };
            let built = PsConverterSpec::QuantAdc { bits }.build(&c.cfg).unwrap();
            let via_enum =
                stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, &legacy, c.seed).unwrap();
            let via_trait =
                stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, built.as_ref(), c.seed)
                    .unwrap();
            assert_eq!(via_enum, via_trait, "case {ci} quant {bits}b");
        }
    }
}

/// The registry-only converters (`sparse`, `inhomo`) are pinned against
/// the python oracle: their golden fixtures carry `ref.stox_mvm` outputs
/// computed with the shared counter RNG, so the Rust converters must
/// reproduce them to f32 rounding (same tolerance as `tests/parity.rs`).
#[test]
fn sparse_and_inhomo_match_python_oracle() {
    let mut pinned = 0usize;
    for (ci, c) in cases().iter().enumerate() {
        if enum_mode(&c.mode) {
            continue;
        }
        let spec: PsConverterSpec = c.spec.parse().unwrap();
        let conv = spec.build(&c.cfg).unwrap();
        let got = stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, conv.as_ref(), c.seed)
            .unwrap();
        assert_eq!(got.len(), c.out.len(), "case {ci} ({}) shape", c.spec);
        let mut max_err = 0.0f32;
        for (g, w) in got.iter().zip(&c.out) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(
            max_err < 1e-5,
            "case {ci} ({}): max err vs oracle {max_err}",
            c.spec
        );
        pinned += 1;
    }
    assert!(pinned >= 4, "expected >= 4 oracle-pinned sparse/inhomo cases");
}

/// New converters run end-to-end through the MVM on the fixture shapes:
/// bounded outputs, deterministic per seed.
#[test]
fn new_converters_run_on_fixture_shapes() {
    for (ci, c) in cases().iter().enumerate().take(3) {
        for spec_str in ["sparse:bits=4", "inhomo:base=1,extra=3"] {
            let spec: PsConverterSpec = spec_str.parse().unwrap();
            let conv = spec.build(&c.cfg).unwrap();
            let o1 = stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, conv.as_ref(), c.seed)
                .unwrap();
            let o2 = stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, conv.as_ref(), c.seed)
                .unwrap();
            assert_eq!(o1, o2, "case {ci} {spec_str}: seed determinism");
            for &v in &o1 {
                assert!(
                    v.abs() <= 1.0 + 1e-5,
                    "case {ci} {spec_str}: out of range {v}"
                );
            }
        }
    }
}

/// `inhomo` with extra=0 collapses to uniform n-sample MTJ reads; the
/// only difference from `stox` is where the 1/n normalization is applied,
/// so the MVM outputs agree to f32 rounding.
#[test]
fn inhomogeneous_with_no_extra_matches_uniform_stox() {
    let c = &cases()[0];
    for base in [1u32, 2, 4] {
        let uniform = PsConverterSpec::StochasticMtj {
            alpha: c.cfg.alpha,
            n_samples: base,
        }
        .build(&c.cfg)
        .unwrap();
        let inhomo = PsConverterSpec::InhomogeneousMtj {
            alpha: c.cfg.alpha,
            base_samples: base,
            extra_samples: 0,
        }
        .build(&c.cfg)
        .unwrap();
        let ou = stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, uniform.as_ref(), c.seed)
            .unwrap();
        let oi = stox_mvm(&c.a, &c.w, c.b, c.m, c.n, c.cfg, inhomo.as_ref(), c.seed)
            .unwrap();
        let mut max_err = 0.0f32;
        for (u, i) in ou.iter().zip(&oi) {
            max_err = max_err.max((u - i).abs());
        }
        assert!(max_err < 1e-5, "base {base}: max err {max_err}");
    }
}

/// The trait's scalar `convert` and the enum's inherent scalar path agree
/// bitwise for every ported converter over a sweep of inputs/counters.
#[test]
fn trait_scalar_matches_enum_scalar() {
    use stox_net::stats::rng::CounterRng;
    let rng = CounterRng::new(17);
    let convs = [
        PsConverter::IdealAdc,
        PsConverter::QuantAdc { bits: 5 },
        PsConverter::SenseAmp,
        PsConverter::ExpectedMtj { alpha: 3.0 },
        PsConverter::StochasticMtj { alpha: 4.0, n_samples: 3 },
    ];
    for conv in convs {
        for k in 0..200u32 {
            let ps = (k as f32 / 100.0) - 1.0;
            let scalar = conv.convert(ps, k, &rng); // inherent (legacy)
            let via_trait = PsConvert::convert(&conv, ps, k, &rng); // trait
            assert_eq!(
                scalar.to_bits(),
                via_trait.to_bits(),
                "{conv:?} ps={ps} counter={k}"
            );
        }
    }
}
