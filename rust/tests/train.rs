//! Training-loop integration (ISSUE 5): smoke training on the committed
//! tiny fixture, bit-reproducibility per seed, manifest round-trip
//! through the registry, and the committed trained fixture strictly
//! beating the random-init fixture on the committed test set.

use std::path::PathBuf;
use stox_net::imc::PsConverterSpec;
use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};
use stox_net::train::{export_checkpoint, TrainConfig, Trainer};

fn data(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data").join(name)
}

fn hp(steps: usize, seed: u32) -> TrainConfig {
    TrainConfig { steps, batch: 4, seed, log_every: 0, ..TrainConfig::default() }
}

fn tmp_out(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("stox-train-{tag}-{}", std::process::id()))
}

/// The acceptance criterion: the committed PS-quantization-aware trained
/// fixture (`tiny_inhomo_trained`, exported by
/// `python/compile/train_fixture.py`, the numpy mirror of `train/`)
/// strictly beats the random-init fixture on the committed test set —
/// under the manifest-selected inhomogeneous converter, no override
/// anywhere.  The trained logit margins are +5..+16, so the 8/8 score is
/// robust to last-ulp cross-language differences.
#[test]
fn committed_trained_fixture_beats_random_init() {
    let mr = Manifest::load(data("tiny_inhomo")).unwrap();
    let mt = Manifest::load(data("tiny_inhomo_trained")).unwrap();
    assert_eq!(mt.spec.stox.mode, "inhomo:base=1,extra=3");
    let tr = TestSet::load(&mr).unwrap();
    let tt = TestSet::load(&mt).unwrap();
    assert_eq!(tr.images, tt.images, "both fixtures carry the same test set");
    assert_eq!(tr.labels, tt.labels);
    let random = NativeModel::load(&mr, &WeightStore::load(&mr).unwrap()).unwrap();
    let trained = NativeModel::load(&mt, &WeightStore::load(&mt).unwrap()).unwrap();
    for seed in [0u32, 7, 777] {
        let ra = random.accuracy(&tr.images, &tr.labels, tr.n, 8, seed);
        let ta = trained.accuracy(&tt.images, &tt.labels, tt.n, 8, seed);
        assert!(ta > ra, "seed {seed}: trained {ta} must strictly beat random {ra}");
        assert_eq!(ta, 1.0, "seed {seed}: the trained fixture memorizes its 8 images");
    }
}

/// Smoke training (the CI `train-smoke` contract, in-process): a few
/// steps on the tiny fixture decrease the loss monotone-ish, the export
/// reloads via `NativeModel::load` through the registry (manifest
/// `mode: "inhomo:…"`, no `--converter` override), and the reloaded
/// checkpoint scores at least the random-init fixture.
#[test]
fn train_smoke_loss_decreases_and_roundtrips() {
    let manifest = Manifest::load(data("tiny_inhomo")).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let test = TestSet::load(&manifest).unwrap();
    let cfg = manifest.spec.stox_config();
    let mut trainer = Trainer::new(&manifest, &store, cfg, None, hp(20, 7)).unwrap();
    assert_eq!(trainer.body_mode(), "inhomo:alpha=4,base=1,extra=3");
    let record = trainer.train(&test.images, &test.labels, test.n).unwrap();
    assert_eq!(record.losses.len(), 20);
    assert!(record.losses.iter().all(|l| l.is_finite()));
    let head: f32 = record.losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = record.losses[15..].iter().sum::<f32>() / 5.0;
    assert!(
        tail < 0.85 * head,
        "PS-aware training must reduce the loss: head {head} -> tail {tail}"
    );

    let out = tmp_out("smoke");
    export_checkpoint(&trainer, &manifest, &record, &out).unwrap();
    let m2 = Manifest::load(&out).unwrap();
    assert_eq!(
        m2.spec.stox.mode, "inhomo:alpha=4,base=1,extra=3",
        "exported mode is the trained spec, registry-resolvable"
    );
    let model = NativeModel::load(&m2, &WeightStore::load(&m2).unwrap()).unwrap();
    let t2 = TestSet::load(&m2).unwrap();
    let acc = model.accuracy(&t2.images, &t2.labels, t2.n, 8, 0);
    let base = NativeModel::load(&manifest, &store)
        .unwrap()
        .accuracy(&test.images, &test.labels, test.n, 8, 0);
    assert!(
        acc >= base,
        "20-step checkpoint ({acc}) must score at least random-init ({base})"
    );
    let _ = std::fs::remove_dir_all(&out);
}

/// `--seed N` bit-reproducibility: identical loss trajectories and
/// identical trained parameters across runs; a different seed diverges.
#[test]
fn training_is_bit_reproducible_per_seed() {
    let manifest = Manifest::load(data("tiny_inhomo")).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let test = TestSet::load(&manifest).unwrap();
    let cfg = manifest.spec.stox_config();
    let run = |seed: u32| {
        let mut t = Trainer::new(&manifest, &store, cfg, None, hp(6, seed)).unwrap();
        let r = t.train(&test.images, &test.labels, test.n).unwrap();
        (r.losses, t.fc_w.clone(), t.conv1.w.clone())
    };
    let (l1, fc1, c1) = run(3);
    let (l2, fc2, c2) = run(3);
    assert_eq!(l1, l2, "same seed, same loss trajectory (bitwise)");
    assert_eq!(fc1, fc2, "same seed, same trained fc weights (bitwise)");
    assert_eq!(c1, c2, "same seed, same trained conv1 weights (bitwise)");
    let (l3, _, _) = run(4);
    assert_ne!(l1, l3, "different seed must draw different batches/samples");
}

/// A `--converter` override trains every stochastic layer under that
/// spec and the export carries it as the manifest mode — turning any
/// registry converter into a trainable design point.
#[test]
fn converter_override_trains_and_exports_its_spec() {
    let manifest = Manifest::load(data("tiny_inhomo")).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let test = TestSet::load(&manifest).unwrap();
    let cfg = manifest.spec.stox_config();
    let spec: PsConverterSpec = "stox:alpha=4,samples=2".parse().unwrap();
    let mut t = Trainer::new(&manifest, &store, cfg, Some(&spec), hp(4, 1)).unwrap();
    assert_eq!(t.body_mode(), "stox:alpha=4,samples=2");
    let r = t.train(&test.images, &test.labels, test.n).unwrap();
    let out = tmp_out("override");
    export_checkpoint(&t, &manifest, &r, &out).unwrap();
    let m2 = Manifest::load(&out).unwrap();
    assert_eq!(m2.spec.stox.mode, "stox:alpha=4,samples=2");
    // loads through the registry and evaluates with the trained converter
    let model = NativeModel::load(&m2, &WeightStore::load(&m2).unwrap()).unwrap();
    let t2 = TestSet::load(&m2).unwrap();
    let acc = model.accuracy(&t2.images, &t2.labels, t2.n, 4, 0);
    assert!((0.0..=1.0).contains(&acc));
    let _ = std::fs::remove_dir_all(&out);
}

/// The precision axis: training at a `--precision` tag other than the
/// trained one re-derives the hardware config (`StoxConfig::from_tag`)
/// and the export records it, so the reload programs crossbars at the
/// trained precision.
#[test]
fn precision_override_round_trips_through_export() {
    let manifest = Manifest::load(data("tiny_inhomo")).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let test = TestSet::load(&manifest).unwrap();
    let cfg = manifest.spec.precision_config("4w4a1bs").unwrap();
    assert_eq!(cfg.w_slice_bits, 1);
    let mut t = Trainer::new(&manifest, &store, cfg, None, hp(2, 5)).unwrap();
    let r = t.train(&test.images, &test.labels, test.n).unwrap();
    let out = tmp_out("precision");
    export_checkpoint(&t, &manifest, &r, &out).unwrap();
    let m2 = Manifest::load(&out).unwrap();
    assert_eq!(m2.spec.stox_config().tag(), "4w4a1bs");
    assert!(NativeModel::load(&m2, &WeightStore::load(&m2).unwrap()).is_ok());
    let _ = std::fs::remove_dir_all(&out);
}
