//! Property-based tests on coordinator + crossbar invariants, driven by
//! the in-tree `util::prop` harness (offline stand-in for proptest).

use std::time::{Duration, Instant};
use stox_net::arch::components::{ComponentCosts, PsProcessing};
use stox_net::arch::energy::{evaluate_design, DesignConfig};
use stox_net::arch::mapper::{map_layer, LayerShape};
use stox_net::coordinator::batcher::{BatcherConfig, DynamicBatcher, FlushReason};
use stox_net::imc::{stox_mvm, PsConverter, StoxConfig};
use stox_net::model::zoo;
use stox_net::util::prop::{check, Gen};

// ---------------------------------------------------------------------
// Crossbar arithmetic invariants
// ---------------------------------------------------------------------

fn random_cfg(g: &mut Gen) -> StoxConfig {
    let (a_bits, w_bits, w_slice) =
        *g.pick(&[(1u32, 1u32, 1u32), (2, 2, 1), (2, 2, 2), (4, 4, 1), (4, 4, 4), (8, 8, 2)]);
    StoxConfig {
        a_bits,
        w_bits,
        a_stream_bits: 1,
        w_slice_bits: w_slice,
        r_arr: *g.pick(&[16usize, 32, 64, 256]),
        n_samples: g.usize_in(1, 4) as u32,
        alpha: g.f32_in(0.5, 8.0),
    }
}

#[test]
fn prop_mvm_output_always_bounded() {
    check("mvm output in [-1,1]", 40, |g| {
        let b = g.usize_in(1, 3);
        let m = g.usize_in(1, 120);
        let n = g.usize_in(1, 12);
        let cfg = random_cfg(g);
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let conv = PsConverter::StochasticMtj {
            alpha: cfg.alpha,
            n_samples: cfg.n_samples,
        };
        let out = stox_mvm(&a, &w, b, m, n, cfg, &conv, 9).unwrap();
        for &v in &out {
            if !(v.abs() <= 1.0 + 1e-5) {
                return Err(format!("out of range: {v} cfg {cfg:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mvm_deterministic_per_seed() {
    check("mvm seed determinism", 25, |g| {
        let b = g.usize_in(1, 2);
        let m = g.usize_in(4, 80);
        let n = g.usize_in(1, 8);
        let cfg = random_cfg(g);
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let conv = PsConverter::StochasticMtj {
            alpha: cfg.alpha,
            n_samples: cfg.n_samples,
        };
        let o1 = stox_mvm(&a, &w, b, m, n, cfg, &conv, 4).unwrap();
        let o2 = stox_mvm(&a, &w, b, m, n, cfg, &conv, 4).unwrap();
        if o1 != o2 {
            return Err("same seed, different output".into());
        }
        Ok(())
    });
}

#[test]
fn prop_ideal_mvm_linear_in_inputs() {
    // ideal converter: doubling a column of w (within range) scales that
    // output column's quantized value accordingly (monotonicity check).
    check("ideal mvm monotone in weights", 25, |g| {
        let m = g.usize_in(4, 60);
        let cfg = StoxConfig {
            a_bits: 8,
            w_bits: 8,
            a_stream_bits: 1,
            w_slice_bits: 1,
            r_arr: 64,
            n_samples: 1,
            alpha: 1.0,
        };
        let a = g.vec_f32(m, 0.05, 1.0); // strictly positive
        let w_small = g.vec_f32(m, 0.1, 0.4);
        let w_big: Vec<f32> = w_small.iter().map(|v| v * 2.0).collect();
        let o_small =
            stox_mvm(&a, &w_small, 1, m, 1, cfg, &PsConverter::IdealAdc, 0).unwrap();
        let o_big =
            stox_mvm(&a, &w_big, 1, m, 1, cfg, &PsConverter::IdealAdc, 0).unwrap();
        if o_big[0] + 1e-4 < o_small[0] {
            return Err(format!("not monotone: {} vs {}", o_big[0], o_small[0]));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------

#[test]
fn prop_batcher_never_loses_or_duplicates() {
    check("batcher conservation", 30, |g| {
        let target = g.usize_in(1, 10);
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: target,
            max_wait: Duration::from_millis(g.usize_in(1, 20) as u64),
        });
        let now = Instant::now();
        let n_req = g.usize_in(1, 100);
        let mut pushed = Vec::new();
        let mut flushed = Vec::new();
        for i in 0..n_req {
            pushed.push(b.push(i, now));
            if g.bool() {
                while let Some(batch) = b.try_flush(now) {
                    if batch.items.len() > target {
                        return Err("batch exceeds target".into());
                    }
                    flushed.extend(batch.items.iter().map(|p| p.id));
                }
            }
        }
        while let Some(batch) = b.drain_all() {
            flushed.extend(batch.items.iter().map(|p| p.id));
        }
        if flushed.len() != n_req {
            return Err(format!("lost requests: {} vs {}", flushed.len(), n_req));
        }
        let mut sorted = flushed.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != n_req {
            return Err("duplicated requests".into());
        }
        // FIFO order within flush stream
        if flushed.windows(2).any(|w| w[1] < w[0]) {
            return Err("out-of-order flush".into());
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_deadline_flush() {
    check("deadline flush", 20, |g| {
        let wait_ms = g.usize_in(1, 10) as u64;
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 100,
            max_wait: Duration::from_millis(wait_ms),
        });
        let t0 = Instant::now();
        b.push(0u32, t0);
        let later = t0 + Duration::from_millis(wait_ms + 1);
        match b.try_flush(later) {
            Some(batch) if batch.reason == FlushReason::Deadline => Ok(()),
            other => Err(format!("expected deadline flush, got {other:?}")),
        }
    });
}

// ---------------------------------------------------------------------
// Mapper / energy-model invariants
// ---------------------------------------------------------------------

#[test]
fn prop_mapper_counts_consistent() {
    check("mapper identities", 30, |g| {
        let cfg = random_cfg(g);
        let shape = LayerShape::conv(
            "l",
            *g.pick(&[1usize, 3, 5, 7]),
            g.usize_in(1, 128),
            g.usize_in(1, 256),
            g.usize_in(1, 32),
            true,
        );
        let m = map_layer(&shape, &cfg, 128);
        // conversions = P·I·J·K·N exactly
        let want = (shape.positions()
            * cfg.n_streams()
            * cfg.n_slices()
            * cfg.n_arrs(shape.m())
            * shape.cout) as u64;
        if m.conversions != want {
            return Err(format!("conversions {} != {}", m.conversions, want));
        }
        // subarrays cover all rows
        if m.n_arrs * cfg.r_arr < shape.m() {
            return Err("subarrays don't cover rows".into());
        }
        if m.xbars != m.n_arrs * m.n_slices * m.col_tiles {
            return Err("xbar count identity".into());
        }
        Ok(())
    });
}

#[test]
fn prop_energy_monotone_in_samples() {
    let costs = ComponentCosts::default();
    let layers = zoo::resnet20_cifar();
    check("energy monotone in MTJ samples", 8, |g| {
        let s = g.usize_in(1, 7) as u32;
        let lo = evaluate_design(
            &costs,
            &DesignConfig::stox(StoxConfig::default(), s, true),
            &layers,
        );
        let hi = evaluate_design(
            &costs,
            &DesignConfig::stox(StoxConfig::default(), s + 1, true),
            &layers,
        );
        if hi.energy_pj <= lo.energy_pj {
            return Err(format!("{} samples {} pJ vs {}", s, lo.energy_pj, hi.energy_pj));
        }
        Ok(())
    });
}

#[test]
fn prop_adc_designs_dominate_stox_cost() {
    let costs = ComponentCosts::default();
    check("StoX EDP below ADC baselines", 6, |g| {
        let layers = match g.usize_in(0, 2) {
            0 => zoo::resnet20_cifar(),
            1 => zoo::resnet18_tiny(),
            _ => zoo::resnet50_tiny(),
        };
        let hpfa = evaluate_design(&costs, &DesignConfig::hpfa(), &layers);
        let sfa = evaluate_design(&costs, &DesignConfig::sfa(), &layers);
        let stox = evaluate_design(
            &costs,
            &DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        if stox.edp_pj_ns >= sfa.edp_pj_ns || sfa.edp_pj_ns >= hpfa.edp_pj_ns {
            return Err("EDP ordering violated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pipeline_beat_max_of_stages() {
    use stox_net::arch::pipeline::PipelineModel;
    let pipe = PipelineModel::default();
    check("beat = max stage", 30, |g| {
        let cols = g.usize_in(1, 512);
        let ps = match g.usize_in(0, 2) {
            0 => PsProcessing::AdcFullPrecision { share: *g.pick(&[1usize, 8, 128]) },
            1 => PsProcessing::SenseAmp,
            _ => PsProcessing::StochasticMtj { samples: g.usize_in(1, 8) as u32 },
        };
        let s = pipe.stages(ps, cols);
        let want = s.t_xbar_ns.max(s.t_ps_ns).max(s.t_sna_ns);
        if (s.beat_ns - want).abs() > 1e-12 {
            return Err("beat != max stage".into());
        }
        Ok(())
    });
}
