//! Property-based tests on coordinator + crossbar invariants, driven by
//! the in-tree `util::prop` harness (offline stand-in for proptest).

use std::time::{Duration, Instant};
use stox_net::arch::components::{ComponentCosts, PsProcessing};
use stox_net::arch::energy::{evaluate_design, DesignConfig};
use stox_net::arch::mapper::{map_layer, LayerShape};
use stox_net::coordinator::batcher::{BatcherConfig, DynamicBatcher, FlushReason};
use stox_net::imc::{
    decompose_activations, stox_mvm, ConvArena, MacBackend, PsConvert, PsConverter,
    PsConverterSpec, PsIntCache, QuantAdcConv, SparseAdcConv, StoxConfig, StoxMvm,
};
use stox_net::model::zoo;
use stox_net::stats::rng::CounterRng;
use stox_net::util::prop::{check, Gen};

// ---------------------------------------------------------------------
// Crossbar arithmetic invariants
// ---------------------------------------------------------------------

fn random_cfg(g: &mut Gen) -> StoxConfig {
    let (a_bits, w_bits, w_slice) =
        *g.pick(&[(1u32, 1u32, 1u32), (2, 2, 1), (2, 2, 2), (4, 4, 1), (4, 4, 4), (8, 8, 2)]);
    StoxConfig {
        a_bits,
        w_bits,
        a_stream_bits: 1,
        w_slice_bits: w_slice,
        r_arr: *g.pick(&[16usize, 32, 64, 256]),
        n_samples: g.usize_in(1, 4) as u32,
        alpha: g.f32_in(0.5, 8.0),
    }
}

#[test]
fn prop_mvm_output_always_bounded() {
    check("mvm output in [-1,1]", 40, |g| {
        let b = g.usize_in(1, 3);
        let m = g.usize_in(1, 120);
        let n = g.usize_in(1, 12);
        let cfg = random_cfg(g);
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let conv = PsConverter::StochasticMtj {
            alpha: cfg.alpha,
            n_samples: cfg.n_samples,
        };
        let out = stox_mvm(&a, &w, b, m, n, cfg, &conv, 9).unwrap();
        for &v in &out {
            if !(v.abs() <= 1.0 + 1e-5) {
                return Err(format!("out of range: {v} cfg {cfg:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mvm_deterministic_per_seed() {
    check("mvm seed determinism", 25, |g| {
        let b = g.usize_in(1, 2);
        let m = g.usize_in(4, 80);
        let n = g.usize_in(1, 8);
        let cfg = random_cfg(g);
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let conv = PsConverter::StochasticMtj {
            alpha: cfg.alpha,
            n_samples: cfg.n_samples,
        };
        let o1 = stox_mvm(&a, &w, b, m, n, cfg, &conv, 4).unwrap();
        let o2 = stox_mvm(&a, &w, b, m, n, cfg, &conv, 4).unwrap();
        if o1 != o2 {
            return Err("same seed, different output".into());
        }
        Ok(())
    });
}

#[test]
fn prop_ideal_mvm_linear_in_inputs() {
    // ideal converter: doubling a column of w (within range) scales that
    // output column's quantized value accordingly (monotonicity check).
    check("ideal mvm monotone in weights", 25, |g| {
        let m = g.usize_in(4, 60);
        let cfg = StoxConfig {
            a_bits: 8,
            w_bits: 8,
            a_stream_bits: 1,
            w_slice_bits: 1,
            r_arr: 64,
            n_samples: 1,
            alpha: 1.0,
        };
        let a = g.vec_f32(m, 0.05, 1.0); // strictly positive
        let w_small = g.vec_f32(m, 0.1, 0.4);
        let w_big: Vec<f32> = w_small.iter().map(|v| v * 2.0).collect();
        let o_small =
            stox_mvm(&a, &w_small, 1, m, 1, cfg, &PsConverter::IdealAdc, 0).unwrap();
        let o_big =
            stox_mvm(&a, &w_big, 1, m, 1, cfg, &PsConverter::IdealAdc, 0).unwrap();
        if o_big[0] + 1e-4 < o_small[0] {
            return Err(format!("not monotone: {} vs {}", o_big[0], o_small[0]));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Integer digit-plane kernel invariants (the perf_opt tentpole)
// ---------------------------------------------------------------------

/// Registry spec strings covering every converter family, including the
/// registry-only ones (`sparse`, `inhomo`).
const KERNEL_SPECS: [&str; 7] = [
    "ideal",
    "quant:bits=6",
    "sparse:bits=4",
    "sa",
    "expected:alpha=3",
    "stox:alpha=4,samples=2",
    "inhomo:alpha=4,base=1,extra=2",
];

/// The tentpole contract: the integer digit-plane kernel (i8 planes, i32
/// PS accumulation, integer conversion entry point) is bit-identical to
/// the retained f32 reference kernel across random shapes — odd `m` vs
/// `r_arr` splits included — random configs (1-bit slices included) and
/// every registry converter.
#[test]
fn prop_integer_kernel_bit_identical_to_reference() {
    check("integer kernel == f32 reference", 30, |g| {
        let b = g.usize_in(1, 3);
        let m = g.usize_in(1, 150);
        let n = g.usize_in(1, 20);
        let cfg = random_cfg(g);
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let int = StoxMvm::program(&w, m, n, cfg).map_err(|e| e.to_string())?;
        let refk =
            StoxMvm::program_reference(&w, m, n, cfg).map_err(|e| e.to_string())?;
        if !int.is_integer_kernel() {
            return Err(format!("config {cfg:?} must use the integer kernel"));
        }
        let seed = g.usize_in(0, 10_000) as u32;
        let o1 = int.run_sequential(&a, b, conv.as_ref(), seed);
        let o2 = refk.run_sequential(&a, b, conv.as_ref(), seed);
        if o1.iter().zip(&o2).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("{spec} diverged under {cfg:?}"));
        }
        // Fig. 4 probe shares the planes and the exactness argument
        let p1 = int.collect_ps(&a, b);
        let p2 = refk.collect_ps(&a, b);
        if p1.iter().zip(&p2).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("collect_ps diverged under {cfg:?}"));
        }
        Ok(())
    });
}

/// The sub-batch (b, k) split must be bit-identical to the sequential
/// kernel at batch = 1 (the single-image serving shape) and any other
/// small batch, for every thread count.
#[test]
fn prop_ksplit_bit_identical_to_sequential() {
    check("k-split == sequential", 20, |g| {
        let batch = g.usize_in(1, 3);
        let m = g.usize_in(30, 300); // several subarrays at small r_arr
        let n = g.usize_in(1, 12);
        let cfg = StoxConfig {
            r_arr: *g.pick(&[16usize, 32, 64]),
            ..random_cfg(g)
        };
        let a = g.vec_f32(batch * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let mvm = StoxMvm::program(&w, m, n, cfg).map_err(|e| e.to_string())?;
        let seed = g.usize_in(0, 10_000) as u32;
        let seq = mvm.run_sequential(&a, batch, conv.as_ref(), seed);
        for threads in [2usize, 5] {
            let par = mvm.run_ksplit(&a, batch, conv.as_ref(), seed, threads);
            if par.iter().zip(&seq).any(|(x, y)| x.to_bits() != y.to_bits()) {
                return Err(format!(
                    "{spec} k-split diverged (batch {batch}, {threads} threads)"
                ));
            }
        }
        Ok(())
    });
}

/// The fused digit-domain conv path (decompose pixels once, gather digit
/// stripes) must be bit-identical to im2col + run for random geometries,
/// strides and subarray splits.
#[test]
fn prop_fused_conv_bit_identical_to_im2col() {
    check("fused conv == im2col + run", 15, |g| {
        let (b, h, w) = (g.usize_in(1, 2), g.usize_in(3, 8), g.usize_in(3, 8));
        let cin = g.usize_in(1, 6);
        let cout = g.usize_in(1, 8);
        let k = *g.pick(&[1usize, 3]);
        let stride = g.usize_in(1, 2);
        let cfg = StoxConfig {
            r_arr: *g.pick(&[8usize, 16, 64]),
            w_slice_bits: 1,
            ..StoxConfig::default()
        };
        let x = g.vec_f32(b * h * w * cin, -1.5, 1.5); // out-of-range clips
        let wts = g.vec_f32(k * k * cin * cout, -1.0, 1.0);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let seed = g.usize_in(0, 10_000) as u32;
        let (want, ho, wo) = {
            let (patches, ho, wo) = stox_net::imc::im2col(&x, b, h, w, cin, k, k, stride);
            let mvm =
                StoxMvm::program(&wts, k * k * cin, cout, cfg).map_err(|e| e.to_string())?;
            (mvm.run(&patches, b * ho * wo, conv.as_ref(), seed), ho, wo)
        };
        let mvm = StoxMvm::program(&wts, k * k * cin, cout, cfg).map_err(|e| e.to_string())?;
        let mut arena = ConvArena::new();
        let acts = decompose_activations(&mut arena, &x, b, h, w, cin, &cfg);
        let (got, ho2, wo2) = mvm.run_conv_digits(&acts, k, k, stride, conv.as_ref(), seed);
        if (ho, wo) != (ho2, wo2) {
            return Err(format!("shape mismatch ({ho},{wo}) vs ({ho2},{wo2})"));
        }
        if got.iter().zip(&want).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("{spec} fused conv diverged (k={k}, stride={stride})"));
        }
        Ok(())
    });
}

/// The integer conversion entry point must equal the float entry point on
/// raw slices too (independent of the kernel): random levels, scales,
/// counter layouts, repeated calls through one cache.
#[test]
fn prop_int_conversion_entry_matches_float_entry() {
    check("convert_slice_int_at == convert_slice_at", 25, |g| {
        let cfg = random_cfg(g);
        let n = g.usize_in(1, 64);
        let bound = g.usize_in(1, 4096);
        let ps_int: Vec<i32> = (0..n)
            .map(|_| g.usize_in(0, 2 * bound) as i32 - bound as i32)
            .collect();
        let scale = 1.0f32 / bound as f32;
        let base = g.usize_in(0, 1 << 20) as u32;
        let stride = g.usize_in(1, 64) as u32;
        let rng = CounterRng::new(g.usize_in(0, 1000) as u32);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let mut cache = PsIntCache::new();
        cache.reset(bound);
        let psn: Vec<f32> = ps_int.iter().map(|&p| p as f32 * scale).collect();
        let (i, j) = (
            g.usize_in(0, cfg.n_streams() - 1),
            g.usize_in(0, cfg.n_slices() - 1),
        );
        let mut want = vec![0.0f32; n];
        conv.convert_slice_at(i, j, &psn, &mut want, base, stride, &rng);
        for _pass in 0..2 {
            let mut got = vec![0.0f32; n];
            conv.convert_slice_int_at(
                i, j, &ps_int, scale, &mut got, base, stride, &rng, &mut cache,
            );
            if got.iter().zip(&want).any(|(x, y)| x.to_bits() != y.to_bits()) {
                return Err(format!("{spec} int entry diverged at ({i},{j})"));
            }
        }
        Ok(())
    });
}

/// Every SIMD MAC backend that is available in this build must be
/// bit-identical to the pinned scalar reference kernel — random shapes,
/// random configs, every registry converter.  Integer addition is exact
/// and associative, so lane reordering must not change a single bit.
#[test]
fn prop_simd_mac_bit_identical_to_scalar() {
    check("SIMD MAC == scalar MAC", 20, |g| {
        let b = g.usize_in(1, 3);
        let m = g.usize_in(1, 150);
        let n = g.usize_in(1, 20);
        let cfg = random_cfg(g);
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let seed = g.usize_in(0, 10_000) as u32;
        let mut base = StoxMvm::program(&w, m, n, cfg).map_err(|e| e.to_string())?;
        base.set_mac_backend(MacBackend::Scalar).map_err(|e| e.to_string())?;
        let want = base.run_sequential(&a, b, conv.as_ref(), seed);
        let want_ps = base.collect_ps(&a, b);
        for backend in [MacBackend::Avx2, MacBackend::Neon, MacBackend::Portable] {
            if !backend.available() {
                continue;
            }
            let mut mvm = StoxMvm::program(&w, m, n, cfg).map_err(|e| e.to_string())?;
            mvm.set_mac_backend(backend).map_err(|e| e.to_string())?;
            let got = mvm.run_sequential(&a, b, conv.as_ref(), seed);
            if got.iter().zip(&want).any(|(x, y)| x.to_bits() != y.to_bits()) {
                return Err(format!("{spec}: {} diverged from scalar", backend.label()));
            }
            let got_ps = mvm.collect_ps(&a, b);
            if got_ps.iter().zip(&want_ps).any(|(x, y)| x.to_bits() != y.to_bits()) {
                return Err(format!("collect_ps diverged on {}", backend.label()));
            }
        }
        Ok(())
    });
}

/// The i16 accumulation tier must be bit-identical to the i32 tier
/// whenever the config's worst-case PS bound admits it (`int16_kernel_ok`)
/// — the prefix sums never leave i16 range, so the narrower accumulator
/// computes the exact same integers.
#[test]
fn prop_i16_tier_bit_identical_to_i32() {
    check("i16 tier == i32 tier", 20, |g| {
        let b = g.usize_in(1, 3);
        let m = g.usize_in(1, 150);
        let n = g.usize_in(1, 16);
        let cfg = random_cfg(g);
        if !cfg.int16_kernel_ok() {
            return Ok(()); // gate: the tier may not be forced on
        }
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let seed = g.usize_in(0, 10_000) as u32;
        let mut wide = StoxMvm::program(&w, m, n, cfg).map_err(|e| e.to_string())?;
        wide.set_i16_tier(false).map_err(|e| e.to_string())?;
        let mut narrow = StoxMvm::program(&w, m, n, cfg).map_err(|e| e.to_string())?;
        narrow.set_i16_tier(true).map_err(|e| e.to_string())?;
        let o32 = wide.run_sequential(&a, b, conv.as_ref(), seed);
        let o16 = narrow.run_sequential(&a, b, conv.as_ref(), seed);
        if o16.iter().zip(&o32).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("{spec} i16 tier diverged under {cfg:?}"));
        }
        let p32 = wide.collect_ps(&a, b);
        let p16 = narrow.collect_ps(&a, b);
        if p16.iter().zip(&p32).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("collect_ps i16 tier diverged under {cfg:?}"));
        }
        Ok(())
    });
}

/// `PsConvert::convert_batch` must be bit-identical to looping
/// `convert_slice_int_at` over the coords in order — for every registry
/// converter, including the three that override the default batch entry.
#[test]
fn prop_convert_batch_bit_identical_to_per_slice() {
    check("convert_batch == per-slice loop", 25, |g| {
        let cfg = random_cfg(g);
        let n = g.usize_in(1, 48);
        let n_slices = g.usize_in(1, 6);
        let bound = g.usize_in(1, 4096);
        let scale = 1.0f32 / bound as f32;
        let stride = g.usize_in(1, 64) as u32;
        let rng = CounterRng::new(g.usize_in(0, 1000) as u32);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let coords: Vec<(usize, usize, u32)> = (0..n_slices)
            .map(|_| {
                (
                    g.usize_in(0, cfg.n_streams() - 1),
                    g.usize_in(0, cfg.n_slices() - 1),
                    g.usize_in(0, 1 << 20) as u32,
                )
            })
            .collect();
        let ps_int: Vec<i32> = (0..n_slices * n)
            .map(|_| g.usize_in(0, 2 * bound) as i32 - bound as i32)
            .collect();
        let mut want = vec![0.0f32; n_slices * n];
        let mut cache_a = PsIntCache::new();
        cache_a.reset(bound);
        for (gi, &(i, j, base)) in coords.iter().enumerate() {
            conv.convert_slice_int_at(
                i,
                j,
                &ps_int[gi * n..(gi + 1) * n],
                scale,
                &mut want[gi * n..(gi + 1) * n],
                base,
                stride,
                &rng,
                &mut cache_a,
            );
        }
        let mut got = vec![0.0f32; n_slices * n];
        let mut cache_b = PsIntCache::new();
        cache_b.reset(bound);
        conv.convert_batch(&coords, stride, n, &ps_int, scale, &mut got, &rng, &mut cache_b);
        if got.iter().zip(&want).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("{spec} convert_batch diverged"));
        }
        Ok(())
    });
}

/// Per-image execution through `run_conv_digits_offset` (the layer
/// pipeline's building block) must reproduce the whole-batch fused conv
/// bit for bit: the RNG counter contract keys every draw by absolute
/// patch index, so splitting the batch must not move a single sample.
#[test]
fn prop_offset_conv_per_image_matches_whole_batch() {
    check("offset conv per image == whole batch", 12, |g| {
        let (b, h, w) = (g.usize_in(2, 3), g.usize_in(3, 7), g.usize_in(3, 7));
        let cin = g.usize_in(1, 4);
        let cout = g.usize_in(1, 8);
        let k = *g.pick(&[1usize, 3]);
        let stride = g.usize_in(1, 2);
        let cfg = StoxConfig {
            r_arr: *g.pick(&[8usize, 16, 64]),
            w_slice_bits: 1,
            ..StoxConfig::default()
        };
        let x = g.vec_f32(b * h * w * cin, -1.5, 1.5);
        let wts = g.vec_f32(k * k * cin * cout, -1.0, 1.0);
        let spec: PsConverterSpec =
            g.pick(&KERNEL_SPECS).parse().map_err(|e| format!("{e}"))?;
        let conv = spec.build(&cfg).map_err(|e| e.to_string())?;
        let seed = g.usize_in(0, 10_000) as u32;
        let mvm = StoxMvm::program(&wts, k * k * cin, cout, cfg).map_err(|e| e.to_string())?;
        let mut arena = ConvArena::new();
        let acts = decompose_activations(&mut arena, &x, b, h, w, cin, &cfg);
        let (want, ho, wo) = mvm.run_conv_digits(&acts, k, k, stride, conv.as_ref(), seed);
        let img = h * w * cin;
        let mut got = Vec::with_capacity(want.len());
        for bi in 0..b {
            let mut img_arena = ConvArena::new();
            let ai = decompose_activations(
                &mut img_arena,
                &x[bi * img..(bi + 1) * img],
                1,
                h,
                w,
                cin,
                &cfg,
            );
            let (part, ho2, wo2) = mvm.run_conv_digits_offset(
                &ai,
                k,
                k,
                stride,
                conv.as_ref(),
                seed,
                bi * ho * wo,
            );
            if (ho, wo) != (ho2, wo2) {
                return Err(format!("shape mismatch ({ho},{wo}) vs ({ho2},{wo2})"));
            }
            got.extend(part);
        }
        if got.iter().zip(&want).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("{spec} offset conv diverged (k={k}, stride={stride})"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// PS-conversion API invariants (the PsConvert redesign)
// ---------------------------------------------------------------------

/// `convert_slice` must equal element-wise scalar `convert` — bit for bit
/// — for every ported converter, across random slices, counter bases and
/// strides (the slice vectorization must not change a single sample).
#[test]
fn prop_convert_slice_equals_elementwise_convert() {
    check("convert_slice == element-wise convert", 40, |g| {
        let n = g.usize_in(1, 200);
        let ps = g.vec_f32(n, -1.5, 1.5);
        let base = g.usize_in(0, 1 << 20) as u32;
        let stride = g.usize_in(1, 64) as u32;
        let rng = CounterRng::new(g.usize_in(0, 1000) as u32);
        let convs = [
            PsConverter::IdealAdc,
            PsConverter::QuantAdc { bits: g.usize_in(1, 8) as u32 },
            PsConverter::SenseAmp,
            PsConverter::ExpectedMtj { alpha: g.f32_in(0.5, 8.0) },
            PsConverter::StochasticMtj {
                alpha: g.f32_in(0.5, 8.0),
                n_samples: g.usize_in(1, 6) as u32,
            },
        ];
        let mut out = vec![0.0f32; n];
        for conv in convs {
            PsConvert::convert_slice(&conv, &ps, &mut out, base, stride, &rng);
            for (idx, (&p, &o)) in ps.iter().zip(&out).enumerate() {
                let c = base.wrapping_add((idx as u32).wrapping_mul(stride));
                let want = conv.convert(p, c, &rng); // legacy scalar path
                if o.to_bits() != want.to_bits() {
                    return Err(format!(
                        "{conv:?} idx {idx}: slice {o} != scalar {want}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// On dense input (no all-zero slice to skip) the sparse ADC is exactly
/// the plain quant ADC.
#[test]
fn prop_sparse_adc_equals_quant_adc_on_dense_input() {
    check("SparseAdc dense == QuantAdc", 30, |g| {
        let n = g.usize_in(1, 128);
        let mut ps = g.vec_f32(n, -1.0, 1.0);
        for v in ps.iter_mut() {
            if *v == 0.0 {
                *v = 0.25; // force density
            }
        }
        let bits = g.usize_in(1, 8) as u32;
        let rng = CounterRng::new(3);
        let mut o_sparse = vec![0.0f32; n];
        let mut o_quant = vec![0.0f32; n];
        SparseAdcConv { bits }.convert_slice(&ps, &mut o_sparse, 0, 1, &rng);
        QuantAdcConv { bits }.convert_slice(&ps, &mut o_quant, 0, 1, &rng);
        if o_sparse
            .iter()
            .zip(&o_quant)
            .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(format!("diverged at bits={bits}"));
        }
        Ok(())
    });
}

/// The registry path (`spec string → PsConverterSpec → build`) yields a
/// converter whose full-MVM output is bit-identical to the legacy enum's.
#[test]
fn prop_registry_path_matches_enum_in_mvm() {
    check("registry converter == enum in MVM", 15, |g| {
        let b = g.usize_in(1, 2);
        let m = g.usize_in(4, 80);
        let n = g.usize_in(1, 8);
        let cfg = random_cfg(g);
        let a = g.vec_f32(b * m, -1.0, 1.0);
        let w = g.vec_f32(m * n, -1.0, 1.0);
        let (legacy, mode): (PsConverter, &str) = match g.usize_in(0, 3) {
            0 => (PsConverter::IdealAdc, "ideal"),
            1 => (PsConverter::SenseAmp, "sa"),
            2 => (PsConverter::ExpectedMtj { alpha: cfg.alpha }, "expected"),
            _ => (
                PsConverter::StochasticMtj {
                    alpha: cfg.alpha,
                    n_samples: cfg.n_samples,
                },
                "stox",
            ),
        };
        let spec = PsConverterSpec::from_mode(mode, cfg.alpha, cfg.n_samples)
            .map_err(|e| e.to_string())?;
        let built = spec.build(&cfg).map_err(|e| e.to_string())?;
        let o1 = stox_mvm(&a, &w, b, m, n, cfg, &legacy, 11).unwrap();
        let o2 = stox_mvm(&a, &w, b, m, n, cfg, built.as_ref(), 11).unwrap();
        if o1 != o2 {
            return Err(format!("mode {mode}: registry path diverged"));
        }
        Ok(())
    });
}

/// Spec strings round-trip through Display/FromStr for random parameters.
#[test]
fn prop_spec_display_roundtrip() {
    check("spec display round-trip", 30, |g| {
        let spec = match g.usize_in(0, 6) {
            0 => PsConverterSpec::IdealAdc,
            1 => PsConverterSpec::QuantAdc { bits: g.usize_in(1, 16) as u32 },
            2 => PsConverterSpec::SparseAdc { bits: g.usize_in(1, 16) as u32 },
            3 => PsConverterSpec::SenseAmp,
            4 => PsConverterSpec::ExpectedMtj { alpha: g.f32_in(0.1, 9.0) },
            5 => PsConverterSpec::StochasticMtj {
                alpha: g.f32_in(0.1, 9.0),
                n_samples: g.usize_in(1, 16) as u32,
            },
            _ => PsConverterSpec::InhomogeneousMtj {
                alpha: g.f32_in(0.1, 9.0),
                base_samples: g.usize_in(1, 8) as u32,
                extra_samples: g.usize_in(0, 8) as u32,
            },
        };
        let round: PsConverterSpec =
            spec.to_string().parse().map_err(|e| format!("{e}"))?;
        if round != spec {
            return Err(format!("{spec} round-tripped to {round}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------

#[test]
fn prop_batcher_never_loses_or_duplicates() {
    check("batcher conservation", 30, |g| {
        let target = g.usize_in(1, 10);
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: target,
            max_wait: Duration::from_millis(g.usize_in(1, 20) as u64),
        });
        let now = Instant::now();
        let n_req = g.usize_in(1, 100);
        let mut pushed = Vec::new();
        let mut flushed = Vec::new();
        for i in 0..n_req {
            pushed.push(b.push(i, now));
            if g.bool() {
                while let Some(batch) = b.try_flush(now) {
                    if batch.items.len() > target {
                        return Err("batch exceeds target".into());
                    }
                    flushed.extend(batch.items.iter().map(|p| p.id));
                }
            }
        }
        while let Some(batch) = b.drain_all() {
            flushed.extend(batch.items.iter().map(|p| p.id));
        }
        if flushed.len() != n_req {
            return Err(format!("lost requests: {} vs {}", flushed.len(), n_req));
        }
        let mut sorted = flushed.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != n_req {
            return Err("duplicated requests".into());
        }
        // FIFO order within flush stream
        if flushed.windows(2).any(|w| w[1] < w[0]) {
            return Err("out-of-order flush".into());
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_deadline_flush() {
    check("deadline flush", 20, |g| {
        let wait_ms = g.usize_in(1, 10) as u64;
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 100,
            max_wait: Duration::from_millis(wait_ms),
        });
        let t0 = Instant::now();
        b.push(0u32, t0);
        let later = t0 + Duration::from_millis(wait_ms + 1);
        match b.try_flush(later) {
            Some(batch) if batch.reason == FlushReason::Deadline => Ok(()),
            other => Err(format!("expected deadline flush, got {other:?}")),
        }
    });
}

// ---------------------------------------------------------------------
// Mapper / energy-model invariants
// ---------------------------------------------------------------------

#[test]
fn prop_mapper_counts_consistent() {
    check("mapper identities", 30, |g| {
        let cfg = random_cfg(g);
        let shape = LayerShape::conv(
            "l",
            *g.pick(&[1usize, 3, 5, 7]),
            g.usize_in(1, 128),
            g.usize_in(1, 256),
            g.usize_in(1, 32),
            true,
        );
        let m = map_layer(&shape, &cfg, 128);
        // conversions = P·I·J·K·N exactly
        let want = (shape.positions()
            * cfg.n_streams()
            * cfg.n_slices()
            * cfg.n_arrs(shape.m())
            * shape.cout) as u64;
        if m.conversions != want {
            return Err(format!("conversions {} != {}", m.conversions, want));
        }
        // subarrays cover all rows
        if m.n_arrs * cfg.r_arr < shape.m() {
            return Err("subarrays don't cover rows".into());
        }
        if m.xbars != m.n_arrs * m.n_slices * m.col_tiles {
            return Err("xbar count identity".into());
        }
        Ok(())
    });
}

#[test]
fn prop_energy_monotone_in_samples() {
    let costs = ComponentCosts::default();
    let layers = zoo::resnet20_cifar();
    check("energy monotone in MTJ samples", 8, |g| {
        let s = g.usize_in(1, 7) as u32;
        let lo = evaluate_design(
            &costs,
            &DesignConfig::stox(StoxConfig::default(), s, true),
            &layers,
        );
        let hi = evaluate_design(
            &costs,
            &DesignConfig::stox(StoxConfig::default(), s + 1, true),
            &layers,
        );
        if hi.energy_pj <= lo.energy_pj {
            return Err(format!("{} samples {} pJ vs {}", s, lo.energy_pj, hi.energy_pj));
        }
        Ok(())
    });
}

#[test]
fn prop_adc_designs_dominate_stox_cost() {
    let costs = ComponentCosts::default();
    check("StoX EDP below ADC baselines", 6, |g| {
        let layers = match g.usize_in(0, 2) {
            0 => zoo::resnet20_cifar(),
            1 => zoo::resnet18_tiny(),
            _ => zoo::resnet50_tiny(),
        };
        let hpfa = evaluate_design(&costs, &DesignConfig::hpfa(), &layers);
        let sfa = evaluate_design(&costs, &DesignConfig::sfa(), &layers);
        let stox = evaluate_design(
            &costs,
            &DesignConfig::stox(StoxConfig::default(), 1, true),
            &layers,
        );
        if stox.edp_pj_ns >= sfa.edp_pj_ns || sfa.edp_pj_ns >= hpfa.edp_pj_ns {
            return Err("EDP ordering violated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pipeline_beat_max_of_stages() {
    use stox_net::arch::pipeline::PipelineModel;
    let pipe = PipelineModel::default();
    check("beat = max stage", 30, |g| {
        let cols = g.usize_in(1, 512);
        let ps = match g.usize_in(0, 2) {
            0 => PsProcessing::AdcFullPrecision { share: *g.pick(&[1usize, 8, 128]) },
            1 => PsProcessing::SenseAmp,
            _ => PsProcessing::StochasticMtj { samples: g.usize_in(1, 8) as u32 },
        };
        let s = pipe.stages(ps, cols);
        let want = s.t_xbar_ns.max(s.t_ps_ns).max(s.t_sna_ns);
        if (s.beat_ns - want).abs() > 1e-12 {
            return Err("beat != max stage".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Serving-tier reply conservation
// ---------------------------------------------------------------------

/// A trivially deterministic executor for serving-schedule properties:
/// logits are a pure function of (first input element, seed, index).
struct EchoExec {
    classes: usize,
    elems: usize,
}

impl stox_net::coordinator::server::Executor for EchoExec {
    fn execute(&self, images: &[f32], batch: usize, seed: u32) -> stox_net::Result<Vec<f32>> {
        Ok((0..batch * self.classes)
            .map(|i| seed as f32 + images[(i / self.classes) * self.elems] + i as f32)
            .collect())
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn image_elems(&self) -> usize {
        self.elems
    }
    fn max_batch(&self) -> usize {
        8
    }
}

#[test]
fn prop_replica_tier_replies_exactly_once_fault_free() {
    use std::sync::mpsc;
    use stox_net::coordinator::server::submit_all;
    use stox_net::serve::{ReplicaConfig, ReplicaServer, ResilienceConfig};

    // Random fault-free schedules: replica count, batch size, request
    // count, admission depth, stealing, and the self-healing switches all
    // vary — yet every request must get exactly one reply, the
    // ok/rejected partition must be total, and (when admission cannot
    // shed) two runs of the same schedule must be bit-identical.
    check("exactly one reply per request", 20, |g| {
        let replicas = g.usize_in(1, 4);
        let requests = g.usize_in(1, 32);
        // a tight queue exercises rejection (timing-dependent, so the
        // bit-identity comparison is only made with an open queue)
        let tight = g.bool();
        let queue_depth = if tight { g.usize_in(1, requests) } else { requests };
        let cfg = ReplicaConfig {
            replicas,
            batcher: BatcherConfig {
                target_batch: g.usize_in(1, 5),
                max_wait: Duration::from_millis(50),
            },
            seed: g.usize_in(0, 10_000) as u32,
            queue_depth,
            deadline: None,
            slo: Duration::from_secs(1),
            steal: g.bool(),
            resilience: ResilienceConfig {
                enabled: g.bool(),
                hedge: g.bool(),
                ..Default::default()
            },
        };
        let elems = 4usize;
        let run = || -> Result<Vec<Result<Vec<f32>, String>>, String> {
            let shards = (0..replicas).map(|_| EchoExec { classes: 3, elems }).collect();
            let server = ReplicaServer::new(shards, cfg.clone());
            let (tx, rx) = mpsc::channel();
            let rxs = submit_all(&tx, (0..requests).map(|r| vec![r as f32 * 0.01; elems]));
            drop(tx);
            server.run(rx);
            let mut out = Vec::new();
            for rxr in rxs {
                let rep = rxr.recv().map_err(|_| "reply channel dropped".to_string())?;
                if rxr.try_recv().is_ok() {
                    return Err("duplicate reply on one request channel".to_string());
                }
                out.push(rep.result);
            }
            Ok(out)
        };
        let a = run()?;
        let ok = a.iter().filter(|r| r.is_ok()).count();
        let rejected = a
            .iter()
            .filter(|r| r.as_ref().err().map(String::as_str) == Some(stox_net::serve::REJECTED))
            .count();
        if ok + rejected != requests {
            return Err(format!(
                "accounting hole: {ok} ok + {rejected} rejected != {requests} submitted"
            ));
        }
        if !tight {
            if rejected != 0 {
                return Err(format!("open queue rejected {rejected} requests"));
            }
            let b = run()?;
            if a != b {
                return Err("same schedule, different replies".to_string());
            }
        }
        Ok(())
    });
}
