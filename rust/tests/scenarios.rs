//! Scenario-harness integration (ISSUE 7): the committed `scenarios/`
//! suite runs green in-process (the same entry point `stox-cli test`
//! uses), covers the full converter × precision matrix, and the harness
//! itself is property-tested — YAML round-trip, comparator match modes
//! under generated perturbations, and the snapshot re-bless invariant.

use std::path::PathBuf;
use stox_net::harness::{parse_yaml, run_scenario, run_suite, to_yaml, Status, SuiteOptions};
use stox_net::util::json::Json;
use stox_net::util::prop;

fn suite_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("stox_scen_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The acceptance criterion: every committed scenario passes (first run
/// may bless missing goldens — that still counts as non-failing, and CI
/// re-runs to verify), there are ≥15 of them, and together they cover
/// all 7 registered converters at ≥2 precision tags.
#[test]
fn committed_suite_passes_and_covers_the_matrix() {
    let rep = run_suite(&suite_dir(), &SuiteOptions::default()).unwrap();
    assert!(rep.ok(), "committed scenarios must pass:\n{}", rep.render_table());
    assert!(
        rep.results.len() >= 15,
        "suite must ship >= 15 scenarios, found {}",
        rep.results.len()
    );

    let mut converters: Vec<String> = Vec::new();
    let mut tags: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(suite_dir()).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().and_then(|x| x.to_str()) != Some("yaml") {
            continue;
        }
        let doc = parse_yaml(&std::fs::read_to_string(&p).unwrap()).unwrap();
        if let Some(c) = doc.at(&["config", "converter"]).and_then(|v| v.as_str()) {
            let mode = c.split(':').next().unwrap().to_string();
            if !converters.contains(&mode) {
                converters.push(mode);
            }
        }
        if let Some(t) = doc.at(&["config", "precision"]).and_then(|v| v.as_str()) {
            for tag in t.split(',') {
                let tag = tag.trim().to_string();
                if !tags.contains(&tag) {
                    tags.push(tag);
                }
            }
        }
    }
    for want in ["ideal", "quant", "sparse", "sa", "expected", "stox", "inhomo"] {
        assert!(
            converters.iter().any(|c| c == want),
            "matrix coverage: converter '{want}' has no scenario (found {converters:?})"
        );
    }
    assert!(
        tags.len() >= 2,
        "matrix coverage: need >= 2 precision tags, found {tags:?}"
    );
}

/// Round-trip property: any tree the writer can emit parses back to the
/// identical `Json` value — scenario files and blessed goldens share one
/// value model with no lossy corner.
#[test]
fn yaml_roundtrip_property() {
    const WORDS: &[&str] = &[
        "ideal",
        "stox:alpha=4,samples=1",
        "4w4a4bs",
        "pareto front",
        "true",
        "a/b/0/c",
        "cells/4w4a4bs|ideal/edp_pj_ns",
        "",
        "it's",
        "x #y",
        "k: v",
        "-1.5e2",
    ];
    fn gen_tree(g: &mut prop::Gen, depth: usize) -> Json {
        match g.usize_in(0, if depth == 0 { 3 } else { 5 }) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num(f64::from((g.f32_in(-1e4, 1e4) * 4.0).round() / 4.0)),
            3 => Json::Str((*g.pick(WORDS)).to_string()),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| gen_tree(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| {
                        let key = format!("{}_{i}", g.pick(&["key", "path", "cfg", "v"]));
                        (key, gen_tree(g, depth - 1))
                    })
                    .collect(),
            ),
        }
    }
    prop::check("yaml round-trip", 200, |g| {
        let tree = gen_tree(g, 3);
        let text = to_yaml(&tree);
        let back = parse_yaml(&text)
            .map_err(|e| format!("reparse failed: {e}\n--- emitted ---\n{text}"))?;
        if back != tree {
            return Err(format!("round-trip mismatch\n--- emitted ---\n{text}"));
        }
        Ok(())
    });
}

/// Comparator property: a tolerance check accepts any perturbation within
/// its atol envelope and rejects one placed safely outside it; subset
/// ignores extra actual keys; exact rejects any numeric change.
#[test]
fn comparator_modes_against_generated_perturbations() {
    use stox_net::harness::run_checks;
    let dir = tmp_dir("cmp_prop");
    prop::check("match modes vs perturbations", 100, |g| {
        let n = g.usize_in(1, 6);
        let base: Vec<f64> =
            (0..n).map(|_| f64::from((g.f32_in(-50.0, 50.0) * 8.0).round() / 8.0)).collect();
        let atol = 1e-3;
        let within = g.f32_in(-0.9, 0.9) as f64 * atol;
        let outside = (2.0 + g.f32_in(0.0, 3.0)) as f64 * atol * if g.bool() { 1.0 } else { -1.0 };
        let idx = g.usize_in(0, n - 1);

        let doc = |vals: &[f64], extra: bool| {
            let mut fields = vec![(
                "xs",
                Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect()),
            )];
            if extra {
                fields.push(("unpinned", Json::Num(42.0)));
            }
            Json::obj(fields)
        };
        let expected = doc(&base, false);
        let mut near = base.clone();
        near[idx] += within;
        let mut far = base.clone();
        far[idx] += outside;

        let check = |mode: &str, value: Json| {
            Json::obj(vec![
                ("path", Json::Str("xs".into())),
                ("mode", Json::Str(mode.into())),
                ("atol", Json::Num(atol)),
                ("value", value),
            ])
        };
        let tol_ok = run_checks(
            &doc(&near, true),
            &[check("tolerance", expected.get("xs").unwrap().clone())],
            &dir,
            false,
        )
        .unwrap();
        if !tol_ok.diffs.is_empty() {
            return Err(format!(
                "tolerance rejected an in-envelope perturbation: {:?}",
                tol_ok.diffs
            ));
        }
        let tol_bad = run_checks(
            &doc(&far, false),
            &[check("tolerance", expected.get("xs").unwrap().clone())],
            &dir,
            false,
        )
        .unwrap();
        if tol_bad.diffs.is_empty() {
            return Err("tolerance accepted an out-of-envelope perturbation".into());
        }
        // subset: expected keys only — the extra actual key is ignored
        let sub = run_checks(
            &Json::obj(vec![("doc", doc(&near, true))]),
            &[Json::obj(vec![
                ("path", Json::Str("doc".into())),
                ("mode", Json::Str("subset".into())),
                ("atol", Json::Num(atol)),
                ("value", expected.clone()),
            ])],
            &dir,
            false,
        )
        .unwrap();
        if !sub.diffs.is_empty() {
            return Err(format!("subset flagged an extra unpinned key: {:?}", sub.diffs));
        }
        // exact rejects the same in-envelope change tolerance accepted
        if within != 0.0 {
            let exact = run_checks(
                &doc(&near, false),
                &[Json::obj(vec![
                    ("path", Json::Str("xs".into())),
                    ("mode", Json::Str("exact".into())),
                    ("value", expected.get("xs").unwrap().clone()),
                ])],
                &dir,
                false,
            )
            .unwrap();
            if exact.diffs.is_empty() {
                return Err("exact accepted a perturbed value".into());
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// The bless-flow invariant: a fresh golden blesses on first run, the
/// re-run passes byte-stably, a corrupted golden fails with a structured
/// diff, and `update` re-blesses back to green.
#[test]
fn snapshot_rebless_then_rerun_passes() {
    let dir = tmp_dir("rebless");
    let scenario = dir.join("parse_pin.yaml");
    std::fs::write(
        &scenario,
        "stage: parse\nconfig:\n  converter: inhomo:base=1,extra=3\n  precision: 8w8a4bs\nexpect:\n  - path: spec\n    mode: exact\n    golden: spec.golden.json\n  - path: tag\n    value: 8w8a4bs\n",
    )
    .unwrap();

    let r1 = run_scenario(&scenario, false).unwrap();
    assert_eq!(r1.status, Status::Blessed, "first run blesses: {:?}", r1.diffs);
    assert_eq!(r1.blessed, vec!["spec.golden.json".to_string()]);
    let blessed_bytes = std::fs::read(dir.join("spec.golden.json")).unwrap();

    let r2 = run_scenario(&scenario, false).unwrap();
    assert_eq!(r2.status, Status::Pass, "re-run verifies: {:?}", r2.diffs);
    assert_eq!(
        std::fs::read(dir.join("spec.golden.json")).unwrap(),
        blessed_bytes,
        "verify run must not rewrite the golden"
    );

    std::fs::write(dir.join("spec.golden.json"), "\"inhomo:alpha=9,base=1,extra=3\"").unwrap();
    let r3 = run_scenario(&scenario, false).unwrap();
    assert_eq!(r3.status, Status::Fail);
    assert!(r3.diffs[0].path == "spec", "diff anchors the path: {:?}", r3.diffs);
    assert!(dir.join("parse_pin.actual.json").exists(), "failure snapshot written");

    let r4 = run_scenario(&scenario, true).unwrap();
    assert_eq!(r4.status, Status::Blessed, "update re-blesses");
    let r5 = run_scenario(&scenario, false).unwrap();
    assert_eq!(r5.status, Status::Pass, "re-blessed suite is green again");
    assert!(!dir.join("parse_pin.actual.json").exists(), "snapshot cleared on pass");
    assert_eq!(
        std::fs::read(dir.join("spec.golden.json")).unwrap(),
        blessed_bytes,
        "re-bless reproduces the original bytes (byte-stable serialization)"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Ordering and monotonic predicates on generated data: a strictly
/// sorted sequence passes ascending and fails descending; one injected
/// inversion flips both verdicts.
#[test]
fn ordering_and_monotonic_properties() {
    use stox_net::harness::run_checks;
    let dir = tmp_dir("ord_prop");
    prop::check("ordering/monotonic", 100, |g| {
        let n = g.usize_in(3, 8);
        let mut vals: Vec<f64> = Vec::with_capacity(n);
        let mut acc = g.f32_in(-10.0, 10.0) as f64;
        for _ in 0..n {
            acc += 0.25 + g.f32_in(0.0, 2.0) as f64;
            vals.push(acc);
        }
        let doc = Json::obj(vec![(
            "seq",
            Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect()),
        )]);
        let mono = |dirn: &str, strict: bool| {
            Json::obj(vec![
                ("path", Json::Str("seq".into())),
                ("mode", Json::Str("monotonic".into())),
                ("direction", Json::Str(dirn.into())),
                ("strict", Json::Bool(strict)),
            ])
        };
        let up = run_checks(&doc, &[mono("ascending", true)], &dir, false).unwrap();
        if !up.diffs.is_empty() {
            return Err(format!("ascending rejected a sorted sequence: {:?}", up.diffs));
        }
        let down = run_checks(&doc, &[mono("descending", false)], &dir, false).unwrap();
        if down.diffs.is_empty() {
            return Err("descending accepted a sorted sequence".into());
        }
        // inject an inversion
        let k = g.usize_in(1, n - 1);
        let mut broken = vals.clone();
        broken[k] = broken[k - 1] - 1.0;
        let bdoc = Json::obj(vec![(
            "seq",
            Json::Arr(broken.iter().map(|&v| Json::Num(v)).collect()),
        )]);
        let up2 = run_checks(&bdoc, &[mono("ascending", true)], &dir, false).unwrap();
        if up2.diffs.is_empty() {
            return Err("ascending accepted an inversion".into());
        }
        // ordering over explicit paths agrees with monotonic over the array
        let paths: Vec<Json> =
            (0..n).map(|i| Json::Str(format!("seq/{i}"))).collect();
        let ord = run_checks(
            &doc,
            &[Json::obj(vec![
                ("mode", Json::Str("ordering".into())),
                ("direction", Json::Str("ascending".into())),
                ("paths", Json::Arr(paths)),
            ])],
            &dir,
            false,
        )
        .unwrap();
        if !ord.diffs.is_empty() {
            return Err(format!("path ordering rejected a sorted sequence: {:?}", ord.diffs));
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}
