//! End-to-end integration: artifacts → PJRT engine → coordinator →
//! accuracy, plus PJRT ↔ native-crossbar cross-validation.
//!
//! These tests require `make artifacts`; they skip silently otherwise so
//! `cargo test` stays green on a fresh checkout.  The committed-fixture
//! inference pins (per-converter logits goldens, trained-margin checks)
//! are NOT artifact-gated — they live in the declarative scenario suite
//! and run here through [`infer_scenarios_pass_via_harness`].

use std::path::PathBuf;
use std::sync::mpsc;
use stox_net::coordinator::server::{submit_all, NativeExecutor, PjrtExecutor, Server};
use stox_net::coordinator::{BatcherConfig, ServeConfig};
use stox_net::model::weights::TestSet;
use stox_net::model::{Manifest, NativeModel, WeightStore};
use stox_net::runtime::Engine;

fn manifest() -> Option<Manifest> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json")
        .exists()
        .then(|| Manifest::load(p).unwrap())
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// The converter × precision inference matrix over the committed
/// `tiny_inhomo*` fixtures, driven by the declarative scenario harness
/// (`scenarios/infer_*.yaml`) — the same in-process path as
/// `stox-cli test --suite scenarios/ --filter infer_`.  Unlike the PJRT
/// tests below this never skips: the fixtures are committed.  It is the
/// only test in this binary touching the repo `scenarios/` dir (golden
/// bless is not re-entrant).
#[test]
fn infer_scenarios_pass_via_harness() {
    let suite = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios");
    let rep = stox_net::harness::run_suite(
        &suite,
        &stox_net::harness::SuiteOptions { filter: Some("infer_".into()), update: false },
    )
    .unwrap();
    assert!(rep.results.len() >= 16, "expected the infer_* scenarios");
    assert!(rep.ok(), "infer scenarios failed:\n{}", rep.render_table());
}

#[test]
fn pjrt_accuracy_matches_checkpoint() {
    let Some(m) = manifest() else { return };
    let engine = Engine::load(&m).unwrap();
    let test = TestSet::load(&m).unwrap();
    let handle = engine.model(8).unwrap();
    let classes = m.spec.num_classes;

    let n = 128.min(test.n);
    let mut correct = 0;
    for i in (0..n).step_by(8) {
        let imgs: Vec<f32> =
            (i..i + 8).flat_map(|k| test.image(k).to_vec()).collect();
        let logits = handle.infer(&imgs, i as u32).unwrap();
        for k in 0..8 {
            if argmax(&logits[k * classes..(k + 1) * classes]) as i32
                == test.labels[i + k]
            {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / n as f64;
    // checkpoint reported ~0.96 on the full set; allow sampling slack
    assert!(acc > 0.80, "PJRT accuracy {acc}");
}

#[test]
fn native_model_agrees_with_pjrt() {
    let Some(m) = manifest() else { return };
    let engine = Engine::load(&m).unwrap();
    let store = WeightStore::load(&m).unwrap();
    let native = NativeModel::load(&m, &store).unwrap();
    let test = TestSet::load(&m).unwrap();
    let handle = engine.model(8).unwrap();
    let classes = m.spec.num_classes;

    let imgs: Vec<f32> = (0..8).flat_map(|k| test.image(k).to_vec()).collect();
    let lp = handle.infer(&imgs, 42).unwrap();
    let ln = native.forward(&imgs, 8, 42);
    let mut agree = 0;
    for k in 0..8 {
        if argmax(&lp[k * classes..(k + 1) * classes])
            == argmax(&ln[k * classes..(k + 1) * classes])
        {
            agree += 1;
        }
    }
    // same counter-based bits on both sides; tanh ULP edge cases may flip
    // an occasional prediction on ambiguous inputs
    assert!(agree >= 7, "agreement {agree}/8");
}

#[test]
fn served_pipeline_accuracy() {
    let Some(m) = manifest() else { return };
    let engine = Engine::load(&m).unwrap();
    let test = TestSet::load(&m).unwrap();
    let spec = &m.spec;
    let elems = spec.image_size * spec.image_size * spec.in_channels;
    let server = Server::new(
        Box::new(PjrtExecutor {
            engine,
            classes: spec.num_classes,
            image_elems: elems,
        }),
        ServeConfig {
            batcher: BatcherConfig {
                target_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
            },
            seed: 3,
            max_retries: 0,
        },
    );
    let n = 64.min(test.n);
    let images: Vec<Vec<f32>> = (0..n).map(|i| test.image(i).to_vec()).collect();
    let (tx, rx) = mpsc::channel();
    let client = std::thread::spawn(move || {
        let r = submit_all(&tx, images.into_iter());
        drop(tx);
        r
    });
    server.run(rx);
    let replies = client.join().unwrap();
    let mut correct = 0;
    for (i, r) in replies.into_iter().enumerate() {
        let rep = r.recv().unwrap();
        if argmax(rep.logits().unwrap()) as i32 == test.labels[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.75, "served accuracy {acc}");
    let metrics = server.metrics.lock().unwrap().report();
    assert_eq!(metrics.requests, n as u64);
    assert!(metrics.mean_batch > 1.0, "batching happened");
}

#[test]
fn native_executor_serves() {
    let Some(m) = manifest() else { return };
    let store = WeightStore::load(&m).unwrap();
    let native = NativeModel::load(&m, &store).unwrap();
    let test = TestSet::load(&m).unwrap();
    let server = Server::new(
        Box::new(NativeExecutor { model: native }),
        ServeConfig::default(),
    );
    let n = 16;
    let images: Vec<Vec<f32>> = (0..n).map(|i| test.image(i).to_vec()).collect();
    let (tx, rx) = mpsc::channel();
    let client = std::thread::spawn(move || {
        let r = submit_all(&tx, images.into_iter());
        drop(tx);
        r
    });
    server.run(rx);
    for r in client.join().unwrap() {
        assert_eq!(r.recv().unwrap().logits().unwrap().len(), 10);
    }
}
