//! Sweep coverage (ISSUE 2): a fixed-seed run over a fixed spec set must
//! produce a byte-stable Pareto JSON (pinned by a golden file), the front
//! must be non-dominated (property-tested), and the stochastic-MTJ spec
//! must dominate the full-precision-ADC spec on EDP as in the paper.

use std::path::PathBuf;
use stox_net::arch::sweep::{pareto_front_flags, run_sweep, GoldenWorkload, SweepResult};
use stox_net::imc::{PsConverterSpec, StoxConfig};
use stox_net::model::zoo;
use stox_net::util::prop;

/// Fixed spec set (≥ 3, covering ADC / SA / MTJ / sparse / inhomo) — the
/// golden sweep input.  Canonical strings, so the JSON is reproducible.
fn fixed_specs() -> Vec<PsConverterSpec> {
    [
        "ideal",
        "quant:bits=8",
        "sparse:bits=4",
        "sa",
        "expected:alpha=4",
        "stox:alpha=4,samples=1",
        "stox:alpha=4,samples=4",
        "inhomo:alpha=4,base=1,extra=3",
    ]
    .iter()
    .map(|s| s.parse().unwrap())
    .collect()
}

fn fixed_sweep(threads: usize) -> SweepResult {
    let cfg = StoxConfig::default();
    let gw = GoldenWorkload::new(cfg, 48, 2024).unwrap();
    run_sweep(
        &fixed_specs(),
        &cfg,
        &zoo::resnet20_cifar(),
        "resnet20_cifar",
        2024,
        threads,
        |spec| Ok(gw.accuracy(spec.build(&cfg)?.as_ref())),
    )
    .unwrap()
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/sweep_golden.json")
}

/// The same (specs, seed) input must serialize to the same bytes on every
/// run and every thread count, and match the committed golden file.
/// Regenerate intentionally with `UPDATE_SWEEP_GOLDEN=1 cargo test`; on a
/// checkout without the golden file the first run blesses it.
#[test]
fn sweep_json_is_byte_stable() {
    let j1 = fixed_sweep(1).to_json().to_string();
    let j2 = fixed_sweep(8).to_json().to_string();
    assert_eq!(j1, j2, "sweep must not depend on thread count");
    let j3 = fixed_sweep(1).to_json().to_string();
    assert_eq!(j1, j3, "sweep must be deterministic run-to-run");

    let path = golden_path();
    if std::env::var("UPDATE_SWEEP_GOLDEN").is_ok() || !path.exists() {
        // bless: the builder container has no rustc, so the file is first
        // produced by a toolchain run (see ROADMAP — commit it then; until
        // that lands, the determinism assertions above are the gate).
        // Ignore write errors so read-only checkouts still pass the
        // determinism half of this test.
        eprintln!(
            "sweep_golden.json was missing — blessed a fresh golden at {} \
             (byte comparison SKIPPED this run; commit the file to arm it)",
            path.display()
        );
        let _ = std::fs::write(&path, &j1);
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        j1,
        want.trim_end(),
        "sweep JSON diverged from rust/tests/data/sweep_golden.json; \
         rerun with UPDATE_SWEEP_GOLDEN=1 if the change is intentional"
    );
}

/// The marked front is exactly the non-dominated set: no front point is
/// strictly dominated, and every off-front point is covered by a front
/// point that is at least as good on both axes.
#[test]
fn pareto_front_is_non_dominated_and_covering() {
    prop::check("pareto front", 200, |g| {
        let n = g.usize_in(1, 40);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|_| {
                (
                    // coarse values force acc/EDP ties so the duplicate
                    // handling is exercised, not just the generic case
                    (g.usize_in(0, 10) as f64) / 10.0,
                    (g.usize_in(1, 20) as f64) * 5.0,
                )
            })
            .collect();
        let flags = pareto_front_flags(&pts);
        if !flags.iter().any(|&f| f) {
            return Err("front is empty".into());
        }
        for (i, &fi) in flags.iter().enumerate() {
            if fi {
                for (j, q) in pts.iter().enumerate() {
                    let strictly_dominates = j != i
                        && q.1 <= pts[i].1
                        && q.0 >= pts[i].0
                        && (q.1 < pts[i].1 || q.0 > pts[i].0);
                    if strictly_dominates {
                        return Err(format!(
                            "front point {i} {:?} dominated by {j} {q:?}",
                            pts[i]
                        ));
                    }
                }
            } else {
                let covered = flags.iter().enumerate().any(|(j, &fj)| {
                    fj && pts[j].1 <= pts[i].1 && pts[j].0 >= pts[i].0
                });
                if !covered {
                    return Err(format!(
                        "off-front point {i} {:?} not covered by the front",
                        pts[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The sweep result itself satisfies the same dominance contract.
#[test]
fn sweep_front_is_non_dominated() {
    let r = fixed_sweep(4);
    let front = r.front();
    assert!(!front.is_empty());
    for p in &r.points {
        if p.on_front {
            for q in &r.points {
                let strictly_dominates = q.spec != p.spec
                    && q.edp_pj_ns <= p.edp_pj_ns
                    && q.accuracy >= p.accuracy
                    && (q.edp_pj_ns < p.edp_pj_ns || q.accuracy > p.accuracy);
                assert!(
                    !strictly_dominates,
                    "front point {} dominated by {}",
                    p.spec, q.spec
                );
            }
        }
    }
}

/// The paper's ordering: stochastic MTJ processing dominates the
/// full-precision ADC on EDP, with the sparse low-bit ADC in between;
/// the ideal (label-defining) readout scores accuracy 1.0.
#[test]
fn stochastic_mtj_dominates_fp_adc_on_edp() {
    let r = fixed_sweep(2);
    let mtj = r.point("stox:alpha=4,samples=1").unwrap();
    let fp = r.point("ideal").unwrap();
    let sparse = r.point("sparse:bits=4").unwrap();
    assert!(
        mtj.edp_pj_ns < fp.edp_pj_ns,
        "MTJ EDP {} must beat FP-ADC EDP {}",
        mtj.edp_pj_ns,
        fp.edp_pj_ns
    );
    assert!(
        mtj.edp_pj_ns < sparse.edp_pj_ns && sparse.edp_pj_ns < fp.edp_pj_ns,
        "sparse ADC must sit between MTJ and FP ADC on EDP"
    );
    assert_eq!(fp.accuracy, 1.0, "ideal readout defines the golden labels");
    // multi-sampling trades EDP for accuracy (§3.2.3) — allow a small
    // per-input quantum of slack on the 48-input golden set
    let m4 = r.point("stox:alpha=4,samples=4").unwrap();
    assert!(m4.edp_pj_ns > mtj.edp_pj_ns);
    assert!(
        m4.accuracy >= mtj.accuracy - 3.0 / 48.0,
        "4-sample accuracy {} collapsed below 1-sample {}",
        m4.accuracy,
        mtj.accuracy
    );
}
