//! Sweep coverage (ISSUE 2 + ISSUE 3): a fixed-seed design-matrix run
//! (precision tags × converter specs) must produce a byte-stable Pareto
//! JSON (pinned by a golden file), the front must be non-dominated
//! (property-tested), energy/latency must be monotone along the precision
//! axis, and the stochastic-MTJ cells must dominate the full-precision-ADC
//! cells on EDP as in Fig. 9a.

use std::path::PathBuf;
use stox_net::arch::components::ComponentCosts;
use stox_net::arch::energy::{evaluate_design, DesignConfig};
use stox_net::arch::sweep::{
    pareto_front_flags, parse_precision_tags, run_matrix_sweep, run_sweep, GoldenWorkload,
    SweepResult,
};
use stox_net::imc::{PsConverterSpec, StoxConfig};
use stox_net::model::zoo;
use stox_net::util::json::Json;
use stox_net::util::prop;

/// Number of golden-workload inputs of the pinned sweep — the accuracy
/// quantum (1/`GOLDEN_INPUTS`) and the oracle tolerance derive from it.
const GOLDEN_INPUTS: usize = 48;
/// Precision axis of the pinned design matrix (Fig. 9a's low- and
/// high-precision corners).
const GOLDEN_TAGS: &str = "4w4a4bs,8w8a4bs";

/// Fixed spec set (≥ 3, covering ADC / SA / MTJ / sparse / inhomo) — the
/// golden sweep's converter axis.  Canonical strings, so the JSON is
/// reproducible.
fn fixed_specs() -> Vec<PsConverterSpec> {
    [
        "ideal",
        "quant:bits=8",
        "sparse:bits=4",
        "sa",
        "expected:alpha=4",
        "stox:alpha=4,samples=1",
        "stox:alpha=4,samples=4",
        "inhomo:alpha=4,base=1,extra=3",
    ]
    .iter()
    .map(|s| s.parse().unwrap())
    .collect()
}

/// The pinned design-matrix sweep: both precision tags × the fixed spec
/// set, golden-workload accuracy, seed 2024.
fn fixed_sweep(threads: usize) -> SweepResult {
    let base = StoxConfig::default();
    let tags = parse_precision_tags(GOLDEN_TAGS, &base).unwrap();
    let gws: Vec<GoldenWorkload> = tags
        .iter()
        .map(|c| GoldenWorkload::new(*c, GOLDEN_INPUTS, 2024).unwrap())
        .collect();
    let grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> =
        tags.iter().map(|c| (*c, fixed_specs())).collect();
    run_matrix_sweep(
        &grid,
        &zoo::resnet20_cifar(),
        "resnet20_cifar",
        2024,
        threads,
        |ti, spec| Ok(gws[ti].accuracy(spec.build(gws[ti].cfg())?.as_ref())),
    )
    .unwrap()
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/sweep_golden.json")
}

/// Compare a sweep result against a golden produced by the *python
/// oracle* (`python/compile/gen_sweep_golden.py`): tags, specs, labels
/// and the pure-f64 cost rollups must match exactly, accuracies to a few
/// golden-input quanta (the oracle reproduces the Rust f32 pipeline
/// except for last-ulp libm `tanh` differences, which can flip individual
/// stochastic samples — the same tolerance class as
/// `tests/converter_equiv.rs`).  Front membership is ordering-sensitive
/// on those ulps, so it is covered by the dominance property tests rather
/// than the oracle golden.
fn assert_matches_oracle_golden(got: &SweepResult, want: &Json) {
    let want_points = want.get("points").and_then(|p| p.as_arr()).expect("points");
    assert_eq!(
        want.get("workload").and_then(|w| w.as_str()),
        Some(got.workload.as_str())
    );
    assert_eq!(want_points.len(), got.points.len(), "point count");
    let tol = 3.0 / GOLDEN_INPUTS as f64 + 1e-12;
    for p in &got.points {
        let w = want_points
            .iter()
            .find(|w| {
                w.get("tag").and_then(|t| t.as_str()) == Some(p.tag.as_str())
                    && w.get("spec").and_then(|s| s.as_str()) == Some(p.spec.as_str())
            })
            .unwrap_or_else(|| panic!("golden missing cell ({}, {})", p.tag, p.spec));
        let num = |key: &str| w.get(key).and_then(|v| v.as_f64()).expect("numeric field");
        assert_eq!(
            w.get("label").and_then(|l| l.as_str()),
            Some(p.label.as_str()),
            "label of ({}, {})",
            p.tag,
            p.spec
        );
        for (key, got_v) in [
            ("energy_pj", p.energy_pj),
            ("latency_ns", p.latency_ns),
            ("area_um2", p.area_um2),
            ("edp_pj_ns", p.edp_pj_ns),
            ("conversions", p.conversions as f64),
            ("xbars", p.xbars as f64),
        ] {
            assert_eq!(
                num(key),
                got_v,
                "{key} of ({}, {}) diverged from the oracle golden",
                p.tag,
                p.spec
            );
        }
        let acc = num("accuracy");
        assert!(
            (acc - p.accuracy).abs() <= tol,
            "accuracy of ({}, {}): oracle {} vs rust {} (tol {tol})",
            p.tag,
            p.spec,
            acc,
            p.accuracy
        );
    }
}

/// The same (grid, seed) input must serialize to the same bytes on every
/// run and every thread count, and match the committed golden file.
///
/// The golden is an envelope `{"generator": .., "result": ..}`:
/// `generator == "rust"` pins bytes exactly (canonicalized through the
/// JSON writer); `generator == "python-oracle"` pins the cost rollups
/// exactly and accuracies to the oracle tolerance (see
/// [`assert_matches_oracle_golden`]).  Regenerate intentionally with
/// `UPDATE_SWEEP_GOLDEN=1 cargo test` (writes a rust-generated golden);
/// on a checkout without the golden file the first run blesses it.  The
/// CI `bench` job runs exactly that bless + re-verify sequence and
/// uploads the rust-blessed file as the `sweep-golden-rust-blessed`
/// artifact — committing it verbatim upgrades this pin from oracle
/// tolerance to exact byte equality (the ROADMAP follow-up; the
/// offline dev container has no Rust toolchain to bless locally).
#[test]
fn sweep_json_is_byte_stable() {
    let result = fixed_sweep(1);
    let j1 = result.to_json().to_string();
    let j2 = fixed_sweep(8).to_json().to_string();
    assert_eq!(j1, j2, "sweep must not depend on thread count");
    let j3 = fixed_sweep(1).to_json().to_string();
    assert_eq!(j1, j3, "sweep must be deterministic run-to-run");

    let path = golden_path();
    if std::env::var("UPDATE_SWEEP_GOLDEN").is_ok() || !path.exists() {
        // bless a rust-generated golden (exact byte pinning from then on).
        // Ignore write errors so read-only checkouts still pass the
        // determinism half of this test.
        eprintln!(
            "blessing a rust-generated sweep golden at {} \
             (commit it to pin the bytes)",
            path.display()
        );
        let _ = std::fs::write(
            &path,
            format!("{{\"generator\":\"rust\",\"result\":{j1}}}"),
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let envelope = Json::parse(&text).expect("golden parses");
    let generator = envelope
        .get("generator")
        .and_then(|g| g.as_str())
        .unwrap_or("rust")
        .to_string();
    let want = envelope
        .get("result")
        .expect("golden envelope {generator, result}");
    if generator == "rust" {
        assert_eq!(
            j1,
            want.to_string(),
            "sweep JSON diverged from rust/tests/data/sweep_golden.json; \
             rerun with UPDATE_SWEEP_GOLDEN=1 if the change is intentional"
        );
    } else {
        assert_matches_oracle_golden(&result, want);
    }
}

/// The marked front is exactly the non-dominated set: no front point is
/// strictly dominated, and every off-front point is covered by a front
/// point that is at least as good on both axes.
#[test]
fn pareto_front_is_non_dominated_and_covering() {
    prop::check("pareto front", 200, |g| {
        let n = g.usize_in(1, 40);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|_| {
                (
                    // coarse values force acc/EDP ties so the duplicate
                    // handling is exercised, not just the generic case
                    (g.usize_in(0, 10) as f64) / 10.0,
                    (g.usize_in(1, 20) as f64) * 5.0,
                )
            })
            .collect();
        let flags = pareto_front_flags(&pts);
        if !flags.iter().any(|&f| f) {
            return Err("front is empty".into());
        }
        for (i, &fi) in flags.iter().enumerate() {
            if fi {
                for (j, q) in pts.iter().enumerate() {
                    let strictly_dominates = j != i
                        && q.1 <= pts[i].1
                        && q.0 >= pts[i].0
                        && (q.1 < pts[i].1 || q.0 > pts[i].0);
                    if strictly_dominates {
                        return Err(format!(
                            "front point {i} {:?} dominated by {j} {q:?}",
                            pts[i]
                        ));
                    }
                }
            } else {
                let covered = flags.iter().enumerate().any(|(j, &fj)| {
                    fj && pts[j].1 <= pts[i].1 && pts[j].0 >= pts[i].0
                });
                if !covered {
                    return Err(format!(
                        "off-front point {i} {:?} not covered by the front",
                        pts[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The matrix-sweep result itself satisfies the dominance contract: the
/// joint front across both precision tags never contains a point that is
/// dominated on both axes.
#[test]
fn sweep_front_is_non_dominated() {
    let r = fixed_sweep(4);
    let front = r.front();
    assert!(!front.is_empty());
    for p in &r.points {
        if p.on_front {
            for q in &r.points {
                let strictly_dominates = (q.spec != p.spec || q.tag != p.tag)
                    && q.edp_pj_ns <= p.edp_pj_ns
                    && q.accuracy >= p.accuracy
                    && (q.edp_pj_ns < p.edp_pj_ns || q.accuracy > p.accuracy);
                assert!(
                    !strictly_dominates,
                    "front point ({}, {}) dominated by ({}, {})",
                    p.tag, p.spec, q.tag, q.spec
                );
            }
        }
    }
}

/// The Fig. 9a matrix claims (MTJ < sparse ADC < FP ADC on EDP within
/// each tag, the precision axis ordering, the CSV/table artifacts, and
/// the full pinned cell matrix) now live in the declarative scenario
/// suite — `scenarios/sweep_fig9a_ordering.yaml` and
/// `scenarios/sweep_matrix_pinned.yaml`.  This thin shim keeps them under
/// plain `cargo test -q` via the same in-process harness `stox-cli test`
/// uses.  It is the only test in this binary touching the repo
/// `scenarios/` dir (golden bless is not re-entrant).
#[test]
fn sweep_scenarios_pass_via_harness() {
    let suite = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios");
    let rep = stox_net::harness::run_suite(
        &suite,
        &stox_net::harness::SuiteOptions { filter: Some("sweep_".into()), update: false },
    )
    .unwrap();
    assert!(rep.results.len() >= 2, "expected the sweep_* scenarios");
    assert!(rep.ok(), "sweep scenarios failed:\n{}", rep.render_table());
}

/// The single-tag `run_sweep` is exactly the one-row special case of the
/// matrix: same points, same front, tag column filled in.
#[test]
fn single_tag_sweep_is_one_row_of_the_matrix() {
    let cfg = StoxConfig::default();
    let gw = GoldenWorkload::new(cfg, 24, 7).unwrap();
    let specs = fixed_specs();
    let single = run_sweep(
        &specs,
        &cfg,
        &zoo::resnet20_cifar(),
        "resnet20_cifar",
        7,
        2,
        |spec| Ok(gw.accuracy(spec.build(&cfg)?.as_ref())),
    )
    .unwrap();
    let grid = vec![(cfg, specs)];
    let matrix = run_matrix_sweep(
        &grid,
        &zoo::resnet20_cifar(),
        "resnet20_cifar",
        7,
        2,
        |_, spec| Ok(gw.accuracy(spec.build(&cfg)?.as_ref())),
    )
    .unwrap();
    assert_eq!(single.to_json().to_string(), matrix.to_json().to_string());
    assert!(single.points.iter().all(|p| p.tag == "4w4a4bs"));
}

/// Precision-axis property (ISSUE 3 satellite): for a fixed converter
/// spec whose cost key is config-independent, the cost rollup's energy
/// and latency are monotone non-decreasing in both weight and activation
/// bits (1-bit slices so every width divides).
///
/// `inhomo` is deliberately excluded: its cost key is the (now exact,
/// fractional — `PsProcessing::StochasticMtjFrac`) *mean* per-(stream,
/// slice) read count, which falls as the significance grid refines, so
/// its pipeline beat can legitimately shrink when weight bits grow —
/// monotonicity in precision is not a property of that converter.
#[test]
fn energy_latency_monotone_in_precision_bits() {
    let layers = zoo::resnet20_cifar();
    let costs = ComponentCosts::default();
    let specs: Vec<PsConverterSpec> = [
        "stox:alpha=4,samples=2",
        "ideal",
        "quant:bits=8",
        "sparse:bits=4",
        "sa",
    ]
    .iter()
    .map(|s| s.parse().unwrap())
    .collect();
    prop::check("precision monotone", 60, |g| {
        let bits = [1u32, 2, 4, 8];
        let w = *g.pick(&bits);
        let a = *g.pick(&bits);
        let spec = g.pick(&specs).clone();
        let eval = |w_bits: u32, a_bits: u32| {
            let cfg = StoxConfig {
                w_bits,
                a_bits,
                w_slice_bits: 1,
                a_stream_bits: 1,
                ..StoxConfig::default()
            };
            let design = DesignConfig::from_specs(cfg, &spec, &spec)
                .expect("valid 1-bit-slice config");
            evaluate_design(&costs, &design, &layers)
        };
        let base = eval(w, a);
        for (w2, a2) in [(w * 2, a), (w, a * 2), (w * 2, a * 2)] {
            if w2 > 8 || a2 > 8 {
                continue;
            }
            let hi = eval(w2, a2);
            if hi.energy_pj < base.energy_pj {
                return Err(format!(
                    "{spec}: energy dropped {} -> {} going {}w{}a -> {}w{}a",
                    base.energy_pj, hi.energy_pj, w, a, w2, a2
                ));
            }
            if hi.latency_ns < base.latency_ns {
                return Err(format!(
                    "{spec}: latency dropped {} -> {} going {}w{}a -> {}w{}a",
                    base.latency_ns, hi.latency_ns, w, a, w2, a2
                ));
            }
        }
        Ok(())
    });
}

/// Matrix-front property: random small design matrices (random tag pairs
/// × random spec subsets) never mark a dominated point as on-front.
#[test]
fn random_matrix_fronts_are_non_dominated() {
    let all_tags = ["2w2a1bs", "4w4a4bs", "4w4a1bs", "8w8a4bs", "8w8a2bs"];
    let layers = zoo::resnet20_cifar();
    prop::check("matrix front non-dominated", 10, |g| {
        let base = StoxConfig::default();
        let t1 = *g.pick(&all_tags);
        let mut t2 = *g.pick(&all_tags);
        if t2 == t1 {
            t2 = "8w8a4bs";
        }
        let tag_list = if t1 == t2 { t1.to_string() } else { format!("{t1},{t2}") };
        let tags = parse_precision_tags(&tag_list, &base).map_err(|e| e.to_string())?;
        let mut specs = fixed_specs();
        specs.truncate(g.usize_in(2, specs.len()));
        let gws: Vec<GoldenWorkload> = tags
            .iter()
            .map(|c| GoldenWorkload::new(*c, 8, g.usize_in(0, 1000) as u32).unwrap())
            .collect();
        let grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> =
            tags.iter().map(|c| (*c, specs.clone())).collect();
        let r = run_matrix_sweep(&grid, &layers, "resnet20_cifar", 1, 2, |ti, spec| {
            Ok(gws[ti].accuracy(spec.build(gws[ti].cfg())?.as_ref()))
        })
        .map_err(|e| e.to_string())?;
        for p in &r.points {
            if !p.on_front {
                continue;
            }
            for q in &r.points {
                let strictly_dominates = (q.spec != p.spec || q.tag != p.tag)
                    && q.edp_pj_ns <= p.edp_pj_ns
                    && q.accuracy >= p.accuracy
                    && (q.edp_pj_ns < p.edp_pj_ns || q.accuracy > p.accuracy);
                if strictly_dominates {
                    return Err(format!(
                        "front point ({}, {}) dominated by ({}, {})",
                        p.tag, p.spec, q.tag, q.spec
                    ));
                }
            }
        }
        Ok(())
    });
}
