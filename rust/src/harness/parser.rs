//! YAML-subset parser and writer for scenario files.
//!
//! Scenario files are plain-text YAML restricted to the subset the suite
//! actually needs, parsed straight into [`Json`] so the comparator,
//! reporter, and golden files all share one value model:
//!
//! * block mappings — `key: value` and `key:` followed by an indented
//!   block (two-space indentation, tabs rejected);
//! * block sequences — `- item`, including `- key: value` items that
//!   open a mapping on the dash line;
//! * scalars — `null`/`~`, `true`/`false`, finite numbers, bare strings
//!   (converter specs like `stox:alpha=4,samples=1` stay strings because
//!   their `:` is not followed by a space), `"…"` with JSON escapes, and
//!   `'…'` with `''` as the quote escape;
//! * flow values — anything starting with `[` or `{` is handed to the
//!   JSON parser verbatim (so `value: [1, 2, 3]` works);
//! * `#` comments (start of line or preceded by whitespace) and blank
//!   lines.
//!
//! [`to_yaml`] is the inverse: it serializes any `Json` tree back into
//! this subset (sorted keys, two-space indent), and the round-trip
//! `parse_yaml(to_yaml(j)) == j` is property-tested in
//! `rust/tests/scenarios.rs`.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct Line {
    indent: usize,
    text: String,
    num: usize,
}

/// Parse a scenario document into [`Json`].
///
/// Errors carry the 1-based line number of the offending construct.
pub fn parse_yaml(text: &str) -> crate::Result<Json> {
    let mut lines: Vec<Line> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let stripped = strip_comment(raw);
        if stripped.trim().is_empty() {
            continue;
        }
        let body = stripped.trim_end();
        let indent = body.len() - body.trim_start().len();
        anyhow::ensure!(
            !body[..indent].contains('\t'),
            "line {}: tab indentation is not supported",
            idx + 1
        );
        lines.push(Line {
            indent,
            text: body.trim_start().to_string(),
            num: idx + 1,
        });
    }
    if lines.is_empty() {
        return Ok(Json::Null);
    }
    let mut pos = 0usize;
    let top = lines[0].indent;
    let v = if !is_seq_item(&lines[0].text) && split_entry(&lines[0].text).is_none() {
        // a bare top-level scalar document
        let s = parse_scalar(&lines[0].text, lines[0].num)?;
        pos = 1;
        s
    } else {
        parse_block(&mut lines, &mut pos, top)?
    };
    anyhow::ensure!(
        pos == lines.len(),
        "line {}: content outside the document structure",
        lines[pos].num
    );
    Ok(v)
}

/// Serialize a [`Json`] tree into the scenario YAML subset: sorted keys
/// (inherited from the `BTreeMap` object model), two-space indents,
/// strings quoted only when a bare token would be misread.
pub fn to_yaml(j: &Json) -> String {
    let mut out = String::new();
    match j {
        Json::Obj(m) if !m.is_empty() => write_map(m, 0, &mut out),
        Json::Arr(v) if !v.is_empty() => write_seq(v, 0, &mut out),
        other => {
            out.push_str(&scalar_token(other));
            out.push('\n');
        }
    }
    out
}

// ---------- reading ----------

fn strip_comment(raw: &str) -> &str {
    let bytes = raw.as_bytes();
    let (mut in_s, mut in_d) = (false, false);
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_d => in_s = !in_s,
            b'"' if !in_s => in_d = !in_d,
            b'#' if !in_s && !in_d => {
                if i == 0 || bytes[i - 1].is_ascii_whitespace() {
                    return &raw[..i];
                }
            }
            _ => {}
        }
    }
    raw
}

fn is_seq_item(text: &str) -> bool {
    text == "-" || text.starts_with("- ")
}

/// Split a mapping entry at the first `:` that ends the line or is
/// followed by a space — so converter specs (`stox:alpha=4`) and URLs on
/// the value side never split.
fn split_entry(text: &str) -> Option<(&str, &str)> {
    let bytes = text.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if b == b':' && (i + 1 == bytes.len() || bytes[i + 1] == b' ') {
            let key = text[..i].trim();
            if key.is_empty() || key.starts_with('"') || key.starts_with('\'') {
                return None;
            }
            let val = if i + 1 == bytes.len() { "" } else { text[i + 2..].trim() };
            return Some((key, val));
        }
    }
    None
}

fn parse_block(lines: &mut Vec<Line>, pos: &mut usize, indent: usize) -> crate::Result<Json> {
    if is_seq_item(&lines[*pos].text) {
        parse_seq(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_seq(lines: &mut Vec<Line>, pos: &mut usize, indent: usize) -> crate::Result<Json> {
    let mut items = Vec::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let (text, num) = (lines[*pos].text.clone(), lines[*pos].num);
        if !is_seq_item(&text) {
            break;
        }
        let rest = text[1..].trim_start().to_string();
        if rest.is_empty() {
            // `-` alone: the item is the indented block that follows
            *pos += 1;
            anyhow::ensure!(
                *pos < lines.len() && lines[*pos].indent > indent,
                "line {num}: empty sequence item"
            );
            let inner = lines[*pos].indent;
            items.push(parse_block(lines, pos, inner)?);
        } else if split_entry(&rest).is_some() {
            // `- key: …`: the dash opens a mapping whose first entry sits
            // on the dash line; reinterpret it at the post-dash column
            let offset = text.len() - rest.len();
            lines[*pos].indent = indent + offset;
            lines[*pos].text = rest;
            let inner = indent + offset;
            items.push(parse_map(lines, pos, inner)?);
        } else {
            items.push(parse_scalar(&rest, num)?);
            *pos += 1;
        }
    }
    Ok(Json::Arr(items))
}

fn parse_map(lines: &mut Vec<Line>, pos: &mut usize, indent: usize) -> crate::Result<Json> {
    let mut map = BTreeMap::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let (text, num) = (lines[*pos].text.clone(), lines[*pos].num);
        if is_seq_item(&text) {
            break;
        }
        let Some((key, val)) = split_entry(&text) else {
            anyhow::bail!("line {num}: expected 'key: value'");
        };
        anyhow::ensure!(
            !map.contains_key(key),
            "line {num}: duplicate key '{key}'"
        );
        *pos += 1;
        let value = if val.is_empty() {
            if *pos < lines.len() && lines[*pos].indent > indent {
                let inner = lines[*pos].indent;
                parse_block(lines, pos, inner)?
            } else {
                Json::Null
            }
        } else {
            parse_scalar(val, num)?
        };
        map.insert(key.to_string(), value);
    }
    Ok(Json::Obj(map))
}

fn parse_scalar(tok: &str, num: usize) -> crate::Result<Json> {
    match tok {
        "null" | "~" => return Ok(Json::Null),
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    let first = tok.as_bytes()[0];
    if first == b'"' || first == b'[' || first == b'{' {
        return Json::parse(tok)
            .map_err(|e| anyhow::anyhow!("line {num}: bad flow value {tok:?}: {e}"));
    }
    if first == b'\'' {
        anyhow::ensure!(
            tok.len() >= 2 && tok.ends_with('\''),
            "line {num}: unterminated single-quoted string"
        );
        return Ok(Json::Str(tok[1..tok.len() - 1].replace("''", "'")));
    }
    if matches!(first, b'0'..=b'9' | b'-' | b'+' | b'.') {
        if let Ok(n) = tok.parse::<f64>() {
            if n.is_finite() {
                return Ok(Json::Num(n));
            }
        }
    }
    Ok(Json::Str(tok.to_string()))
}

// ---------- writing ----------

fn write_map(m: &BTreeMap<String, Json>, indent: usize, out: &mut String) {
    for (k, v) in m {
        let _ = write!(out, "{:indent$}{}:", "", key_token(k));
        match v {
            Json::Obj(inner) if !inner.is_empty() => {
                out.push('\n');
                write_map(inner, indent + 2, out);
            }
            Json::Arr(inner) if !inner.is_empty() => {
                out.push('\n');
                write_seq(inner, indent + 2, out);
            }
            other => {
                let _ = writeln!(out, " {}", scalar_token(other));
            }
        }
    }
}

fn write_seq(v: &[Json], indent: usize, out: &mut String) {
    for item in v {
        match item {
            Json::Obj(inner) if !inner.is_empty() => {
                let _ = writeln!(out, "{:indent$}-", "");
                write_map(inner, indent + 2, out);
            }
            Json::Arr(inner) if !inner.is_empty() => {
                let _ = writeln!(out, "{:indent$}-", "");
                write_seq(inner, indent + 2, out);
            }
            other => {
                let _ = writeln!(out, "{:indent$}- {}", "", scalar_token(other));
            }
        }
    }
}

fn key_token(k: &str) -> String {
    // parser keys are bare; the writer only emits keys the parser can
    // read back (scenario field names and artifact keys satisfy this)
    debug_assert!(
        split_entry(&format!("{k}:")).is_some(),
        "unwritable mapping key {k:?}"
    );
    k.to_string()
}

fn scalar_token(j: &Json) -> String {
    match j {
        Json::Str(s) => {
            if needs_quotes(s) {
                Json::Str(s.clone()).to_string()
            } else {
                s.clone()
            }
        }
        // empty containers have no block form in this subset — flow JSON
        Json::Obj(m) if m.is_empty() => "{}".to_string(),
        Json::Arr(v) if v.is_empty() => "[]".to_string(),
        other => other.to_string(),
    }
}

fn needs_quotes(s: &str) -> bool {
    if s.is_empty() || s != s.trim() {
        return true;
    }
    if matches!(s, "null" | "~" | "true" | "false") {
        return true;
    }
    let first = s.as_bytes()[0];
    if matches!(
        first,
        b'"' | b'\'' | b'[' | b'{' | b'#' | b'&' | b'*' | b'!' | b'|' | b'>' | b'%' | b'@'
    ) {
        return true;
    }
    if s == "-" || s.starts_with("- ") {
        return true;
    }
    // would be re-read as a number
    if matches!(first, b'0'..=b'9' | b'-' | b'+' | b'.')
        && s.parse::<f64>().map(|n| n.is_finite()).unwrap_or(false)
    {
        return true;
    }
    // a `: ` or trailing `:` would be re-read as a mapping entry;
    // control characters and comment markers need escaping
    s.ends_with(':')
        || s.contains(": ")
        || s.contains(" #")
        || s.chars().any(|c| (c as u32) < 0x20)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scenario_shape() {
        let doc = "\
# a comment
name: infer_stox_4w4a4bs
stage: infer
config:
  fixture: tiny_inhomo
  converter: stox:alpha=4,samples=1
  seed: 7
expect:
  - path: accuracy
    mode: range
    min: 0.25
  - path: deterministic
    mode: exact
    value: true
";
        let j = parse_yaml(doc).unwrap();
        assert_eq!(j.at(&["name"]).unwrap().as_str(), Some("infer_stox_4w4a4bs"));
        assert_eq!(
            j.at(&["config", "converter"]).unwrap().as_str(),
            Some("stox:alpha=4,samples=1"),
            "converter specs must stay strings"
        );
        assert_eq!(j.at(&["config", "seed"]).unwrap().as_f64(), Some(7.0));
        let expect = j.get("expect").unwrap().as_arr().unwrap();
        assert_eq!(expect.len(), 2);
        assert_eq!(expect[0].get("min").unwrap().as_f64(), Some(0.25));
        assert_eq!(expect[1].get("value").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn scalars_and_flow() {
        let j = parse_yaml(
            "a: null\nb: ~\nc: true\nd: -1.5e2\ne: [1, 2, \"x\"]\nf: 'it''s'\ng: \"q: v\"\n",
        )
        .unwrap();
        assert!(j.get("a").unwrap().is_null());
        assert!(j.get("b").unwrap().is_null());
        assert_eq!(j.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("d").unwrap().as_f64(), Some(-150.0));
        assert_eq!(j.get("e").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("f").unwrap().as_str(), Some("it's"));
        assert_eq!(j.get("g").unwrap().as_str(), Some("q: v"));
    }

    #[test]
    fn nested_sequences_and_dash_blocks() {
        let doc = "\
grid:
  -
    - 1
    - 2
  -
    - 3
checks:
  - mode: ordering
    paths:
      - a/b
      - a/c
";
        let j = parse_yaml(doc).unwrap();
        let g = j.get("grid").unwrap().as_arr().unwrap();
        assert_eq!(g[0].as_arr().unwrap().len(), 2);
        assert_eq!(g[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
        let paths = j.at(&["checks"]).unwrap().as_arr().unwrap()[0]
            .get("paths")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(paths[1].as_str(), Some("a/c"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_yaml("a:\n\tb: 1\n").is_err(), "tabs rejected");
        assert!(parse_yaml("a: 1\na: 2\n").is_err(), "duplicate keys rejected");
        assert!(parse_yaml("key 'no colon'\nx: 1\n").is_err());
        assert!(parse_yaml("e: [1, 2\n").is_err(), "bad flow rejected");
    }

    #[test]
    fn roundtrips_a_nested_tree() {
        let doc = "\
name: t
config:
  specs:
    - ideal
    - stox:alpha=4,samples=1
  empty: {}
  none: null
  quoted: \"4w4a4bs\"
";
        let j = parse_yaml(doc).unwrap();
        let j2 = parse_yaml(&to_yaml(&j)).unwrap();
        assert_eq!(j, j2);
        // a quoted number-like string survives the round trip as a string
        assert_eq!(j2.at(&["config", "quoted"]).unwrap().as_str(), Some("4w4a4bs"));
    }
}
