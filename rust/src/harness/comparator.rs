//! Expectation matching: the scenario `expect:` block against the
//! executor's actual-output document.
//!
//! Every check addresses a `/`-separated [`path`](lookup) into the actual
//! document and names a match mode:
//!
//! | mode        | semantics                                                        |
//! |-------------|------------------------------------------------------------------|
//! | `exact`     | byte-for-byte JSON equality (bit-pins)                           |
//! | `tolerance` | recursive numeric compare, `|a−e| ≤ atol + rtol·|e|`             |
//! | `subset`    | every field of the expected value exists and matches in actual   |
//! | `ordering`  | the values at `paths` are strictly sorted per `direction`        |
//! | `monotonic` | an array (optionally projected through `key`) is sorted          |
//! | `range`     | a number lies inside the inclusive `[min, max]` interval         |
//!
//! The expected value comes from an inline `value:` or from a `golden:`
//! file next to the scenario.  Golden files are canonical JSON (sorted
//! keys, the byte-stable [`Json`] writer); a missing golden — or any
//! golden under `UPDATE_SCENARIOS=1` / `--update` — is *blessed* from the
//! actual output, mirroring the `sweep_golden.json` bless idiom, so CI
//! can regenerate and re-verify the whole suite in one run.

use crate::util::json::Json;
use std::path::Path;

/// One structured mismatch: where in the actual document, and what went
/// wrong — the unit both the terminal table and `scenarios_report.json`
/// render.
#[derive(Debug, Clone)]
pub struct Diff {
    /// `/`-separated location inside the actual output document.
    pub path: String,
    /// Human-readable expected-vs-actual description.
    pub detail: String,
}

impl Diff {
    fn new(path: impl Into<String>, detail: impl Into<String>) -> Self {
        Diff { path: path.into(), detail: detail.into() }
    }

    /// The diff as a JSON object (for the machine-readable report).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::Str(self.path.clone())),
            ("detail", Json::Str(self.detail.clone())),
        ])
    }
}

/// Outcome of one scenario's expectation block.
#[derive(Debug, Default)]
pub struct CheckOutcome {
    /// All mismatches, in check order (empty == pass).
    pub diffs: Vec<Diff>,
    /// Golden files (re)written this run, relative to the scenario dir.
    pub blessed: Vec<String>,
}

/// Resolve a `/`-separated path inside a document.  Each segment is an
/// object key, or an index when the current node is an array — keys
/// themselves (converter specs, matrix cells like `4w4a4bs|ideal`) never
/// contain `/`.
pub fn lookup<'a>(doc: &'a Json, path: &str) -> Option<&'a Json> {
    let mut cur = doc;
    for seg in path.split('/') {
        cur = match cur {
            Json::Arr(v) => v.get(seg.parse::<usize>().ok()?)?,
            other => other.get(seg)?,
        };
    }
    Some(cur)
}

/// Run every check of an `expect:` list against `actual`.  `scenario_dir`
/// anchors `golden:` references; `update` forces re-blessing them.
pub fn run_checks(
    actual: &Json,
    checks: &[Json],
    scenario_dir: &Path,
    update: bool,
) -> crate::Result<CheckOutcome> {
    let mut out = CheckOutcome::default();
    for (idx, check) in checks.iter().enumerate() {
        let mode = check.get("mode").and_then(|m| m.as_str()).unwrap_or("exact");
        match mode {
            "ordering" => check_ordering(actual, check, idx, &mut out.diffs)?,
            "monotonic" => check_monotonic(actual, check, idx, &mut out.diffs)?,
            "range" => check_range(actual, check, idx, &mut out.diffs)?,
            "exact" | "tolerance" | "subset" => {
                check_valued(actual, check, idx, mode, scenario_dir, update, &mut out)?
            }
            other => anyhow::bail!("check #{idx}: unknown match mode '{other}'"),
        }
    }
    Ok(out)
}

fn req_path<'a>(check: &'a Json, idx: usize) -> crate::Result<&'a str> {
    check
        .get("path")
        .and_then(|p| p.as_str())
        .ok_or_else(|| anyhow::anyhow!("check #{idx}: missing 'path'"))
}

fn resolve<'a>(
    actual: &'a Json,
    path: &str,
    idx: usize,
    diffs: &mut Vec<Diff>,
) -> Option<&'a Json> {
    match lookup(actual, path) {
        Some(v) => Some(v),
        None => {
            diffs.push(Diff::new(
                path,
                format!("check #{idx}: path not present in the actual output"),
            ));
            None
        }
    }
}

fn check_valued(
    actual: &Json,
    check: &Json,
    idx: usize,
    mode: &str,
    scenario_dir: &Path,
    update: bool,
    out: &mut CheckOutcome,
) -> crate::Result<()> {
    let path = req_path(check, idx)?;
    let Some(got) = resolve(actual, path, idx, &mut out.diffs) else {
        return Ok(());
    };
    let expected = match check.get("golden").and_then(|g| g.as_str()) {
        Some(file) => {
            let gp = scenario_dir.join(file);
            if update || !gp.exists() {
                if let Some(parent) = gp.parent() {
                    let _ = std::fs::create_dir_all(parent);
                }
                std::fs::write(&gp, got.to_string()).map_err(|e| {
                    anyhow::anyhow!("check #{idx}: cannot bless {}: {e}", gp.display())
                })?;
                out.blessed.push(file.to_string());
                return Ok(());
            }
            let text = std::fs::read_to_string(&gp)?;
            Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("check #{idx}: golden {file} unparsable: {e}"))?
        }
        None => check
            .get("value")
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("check #{idx}: needs 'value' or 'golden'"))?,
    };
    let atol = check.get("atol").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let rtol = check.get("rtol").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let before = out.diffs.len();
    match mode {
        "exact" => {
            if *got != expected {
                out.diffs.push(Diff::new(
                    path,
                    format!(
                        "check #{idx}: expected {} got {}",
                        clip(&expected.to_string()),
                        clip(&got.to_string())
                    ),
                ));
            }
        }
        "tolerance" => compare_tree(got, &expected, atol, rtol, false, path, idx, &mut out.diffs),
        "subset" => compare_tree(got, &expected, atol, rtol, true, path, idx, &mut out.diffs),
        _ => unreachable!("valued mode"),
    }
    // keep failure reports readable: one check caps its diff fan-out
    if out.diffs.len() > before + 8 {
        let dropped = out.diffs.len() - before - 8;
        out.diffs.truncate(before + 8);
        out.diffs.push(Diff::new(path, format!("check #{idx}: … {dropped} more mismatches")));
    }
    Ok(())
}

/// Recursive structural compare.  `subset` relaxes objects (expected keys
/// only); arrays always compare by position and full length — artifact
/// rows are ordered, so a length change is a real diff.
#[allow(clippy::too_many_arguments)]
fn compare_tree(
    got: &Json,
    want: &Json,
    atol: f64,
    rtol: f64,
    subset: bool,
    path: &str,
    idx: usize,
    diffs: &mut Vec<Diff>,
) {
    match (got, want) {
        (Json::Num(a), Json::Num(e)) => {
            if !((a - e).abs() <= atol + rtol * e.abs()) {
                diffs.push(Diff::new(
                    path,
                    format!("check #{idx}: |{a} - {e}| > atol {atol} + rtol {rtol}·|{e}|"),
                ));
            }
        }
        (Json::Obj(a), Json::Obj(e)) => {
            for (k, ev) in e {
                let sub = format!("{path}/{k}");
                match a.get(k) {
                    Some(av) => compare_tree(av, ev, atol, rtol, subset, &sub, idx, diffs),
                    None => diffs.push(Diff::new(sub, format!("check #{idx}: key missing"))),
                }
            }
            if !subset {
                for k in a.keys().filter(|k| !e.contains_key(*k)) {
                    diffs.push(Diff::new(
                        format!("{path}/{k}"),
                        format!("check #{idx}: unexpected key"),
                    ));
                }
            }
        }
        (Json::Arr(a), Json::Arr(e)) => {
            if a.len() != e.len() {
                diffs.push(Diff::new(
                    path,
                    format!("check #{idx}: length {} != expected {}", a.len(), e.len()),
                ));
                return;
            }
            for (i, (av, ev)) in a.iter().zip(e).enumerate() {
                compare_tree(av, ev, atol, rtol, subset, &format!("{path}/{i}"), idx, diffs);
            }
        }
        (a, e) if a == e => {}
        (a, e) => diffs.push(Diff::new(
            path,
            format!("check #{idx}: expected {} got {}", clip(&e.to_string()), clip(&a.to_string())),
        )),
    }
}

fn check_ordering(
    actual: &Json,
    check: &Json,
    idx: usize,
    diffs: &mut Vec<Diff>,
) -> crate::Result<()> {
    let paths = check
        .get("paths")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| anyhow::anyhow!("check #{idx}: ordering needs 'paths'"))?;
    let ascending = direction(check, idx)?;
    let mut vals: Vec<(String, f64)> = Vec::new();
    for p in paths {
        let p = p
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("check #{idx}: ordering path not a string"))?;
        let Some(v) = resolve(actual, p, idx, diffs) else { return Ok(()) };
        let Some(n) = v.as_f64() else {
            diffs.push(Diff::new(p, format!("check #{idx}: not a number")));
            return Ok(());
        };
        vals.push((p.to_string(), n));
    }
    for w in vals.windows(2) {
        let ok = if ascending { w[0].1 < w[1].1 } else { w[0].1 > w[1].1 };
        if !ok {
            diffs.push(Diff::new(
                w[1].0.clone(),
                format!(
                    "check #{idx}: ordering violated — {} = {} vs {} = {} ({})",
                    w[0].0,
                    w[0].1,
                    w[1].0,
                    w[1].1,
                    if ascending { "ascending" } else { "descending" }
                ),
            ));
        }
    }
    Ok(())
}

fn check_monotonic(
    actual: &Json,
    check: &Json,
    idx: usize,
    diffs: &mut Vec<Diff>,
) -> crate::Result<()> {
    let path = req_path(check, idx)?;
    let ascending = direction(check, idx)?;
    let strict = check.get("strict").and_then(|v| v.as_bool()).unwrap_or(false);
    let key = check.get("key").and_then(|v| v.as_str());
    let Some(node) = resolve(actual, path, idx, diffs) else { return Ok(()) };
    let Some(arr) = node.as_arr() else {
        diffs.push(Diff::new(path, format!("check #{idx}: not an array")));
        return Ok(());
    };
    let mut vals = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let v = match key {
            Some(k) => item.get(k),
            None => Some(item),
        };
        match v.and_then(|v| v.as_f64()) {
            Some(n) => vals.push(n),
            None => {
                diffs.push(Diff::new(
                    format!("{path}/{i}"),
                    format!("check #{idx}: element not a number"),
                ));
                return Ok(());
            }
        }
    }
    for (i, w) in vals.windows(2).enumerate() {
        let ok = match (ascending, strict) {
            (true, true) => w[0] < w[1],
            (true, false) => w[0] <= w[1],
            (false, true) => w[0] > w[1],
            (false, false) => w[0] >= w[1],
        };
        if !ok {
            diffs.push(Diff::new(
                format!("{path}/{}", i + 1),
                format!("check #{idx}: monotonicity violated — {} then {}", w[0], w[1]),
            ));
        }
    }
    Ok(())
}

fn check_range(
    actual: &Json,
    check: &Json,
    idx: usize,
    diffs: &mut Vec<Diff>,
) -> crate::Result<()> {
    let path = req_path(check, idx)?;
    let Some(node) = resolve(actual, path, idx, diffs) else { return Ok(()) };
    let Some(n) = node.as_f64() else {
        diffs.push(Diff::new(path, format!("check #{idx}: not a number")));
        return Ok(());
    };
    if let Some(min) = check.get("min").and_then(|v| v.as_f64()) {
        if n < min {
            diffs.push(Diff::new(path, format!("check #{idx}: {n} < min {min}")));
        }
    }
    if let Some(max) = check.get("max").and_then(|v| v.as_f64()) {
        if n > max {
            diffs.push(Diff::new(path, format!("check #{idx}: {n} > max {max}")));
        }
    }
    anyhow::ensure!(
        check.get("min").is_some() || check.get("max").is_some(),
        "check #{idx}: range needs 'min' and/or 'max'"
    );
    Ok(())
}

fn direction(check: &Json, idx: usize) -> crate::Result<bool> {
    match check.get("direction").and_then(|d| d.as_str()).unwrap_or("ascending") {
        "ascending" => Ok(true),
        "descending" => Ok(false),
        d => anyhow::bail!("check #{idx}: bad direction '{d}' (ascending|descending)"),
    }
}

fn clip(s: &str) -> String {
    const MAX: usize = 160;
    if s.len() <= MAX {
        return s.to_string();
    }
    let mut cut = MAX;
    while !s.is_char_boundary(cut) {
        cut -= 1;
    }
    format!("{}… ({} bytes)", &s[..cut], s.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc() -> Json {
        Json::parse(
            r#"{"acc": 0.875, "flags": {"det": true, "extra": 1},
                "curve": [{"edp": 1.0}, {"edp": 2.5}, {"edp": 2.5}],
                "cells": {"a": {"edp": 3.0}, "b": {"edp": 7.0}}}"#,
        )
        .unwrap()
    }

    fn checks(yaml_like_json: &str) -> Vec<Json> {
        Json::parse(yaml_like_json).unwrap().as_arr().unwrap().to_vec()
    }

    #[test]
    fn lookup_paths() {
        let d = doc();
        assert_eq!(lookup(&d, "curve/1/edp").unwrap().as_f64(), Some(2.5));
        assert_eq!(lookup(&d, "cells/b/edp").unwrap().as_f64(), Some(7.0));
        assert!(lookup(&d, "cells/missing/edp").is_none());
    }

    #[test]
    fn exact_tolerance_subset() {
        let d = doc();
        let cs = checks(
            r#"[{"path": "acc", "mode": "exact", "value": 0.875},
                {"path": "acc", "mode": "tolerance", "value": 0.9, "atol": 0.05},
                {"path": "flags", "mode": "subset", "value": {"det": true}}]"#,
        );
        let out = run_checks(&d, &cs, std::path::Path::new("."), false).unwrap();
        assert!(out.diffs.is_empty(), "{:?}", out.diffs);

        let bad = checks(
            r#"[{"path": "acc", "mode": "tolerance", "value": 0.9, "atol": 0.01},
                {"path": "flags", "mode": "subset", "value": {"det": false}},
                {"path": "flags", "mode": "exact", "value": {"det": true}}]"#,
        );
        let out = run_checks(&d, &bad, std::path::Path::new("."), false).unwrap();
        assert_eq!(out.diffs.len(), 3, "{:?}", out.diffs);
    }

    #[test]
    fn ordering_monotonic_range() {
        let d = doc();
        let cs = checks(
            r#"[{"mode": "ordering", "paths": ["cells/a/edp", "cells/b/edp"], "direction": "ascending"},
                {"mode": "monotonic", "path": "curve", "key": "edp", "direction": "ascending"},
                {"mode": "range", "path": "acc", "min": 0.5, "max": 1.0}]"#,
        );
        let out = run_checks(&d, &cs, std::path::Path::new("."), false).unwrap();
        assert!(out.diffs.is_empty(), "{:?}", out.diffs);

        let bad = checks(
            r#"[{"mode": "ordering", "paths": ["cells/b/edp", "cells/a/edp"]},
                {"mode": "monotonic", "path": "curve", "key": "edp", "strict": true},
                {"mode": "range", "path": "acc", "min": 0.9}]"#,
        );
        let out = run_checks(&d, &bad, std::path::Path::new("."), false).unwrap();
        assert_eq!(out.diffs.len(), 3, "{:?}", out.diffs);
    }

    #[test]
    fn golden_bless_then_verify_then_diff() {
        let d = doc();
        let dir = std::env::temp_dir().join(format!("stox_cmp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cs = checks(r#"[{"path": "cells", "mode": "exact", "golden": "cells.golden.json"}]"#);

        let out = run_checks(&d, &cs, &dir, false).unwrap();
        assert_eq!(out.blessed, vec!["cells.golden.json"]);
        assert!(out.diffs.is_empty());

        let out = run_checks(&d, &cs, &dir, false).unwrap();
        assert!(out.blessed.is_empty() && out.diffs.is_empty(), "re-run verifies");

        std::fs::write(dir.join("cells.golden.json"), r#"{"a":{"edp":3},"b":{"edp":8}}"#).unwrap();
        let out = run_checks(&d, &cs, &dir, false).unwrap();
        assert_eq!(out.diffs.len(), 1, "perturbed golden must diff");

        let out = run_checks(&d, &cs, &dir, true).unwrap();
        assert_eq!(out.blessed.len(), 1, "update re-blesses");
        let out = run_checks(&d, &cs, &dir, false).unwrap();
        assert!(out.diffs.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_mode_is_an_error() {
        let cs = checks(r#"[{"path": "acc", "mode": "fuzzy", "value": 1}]"#);
        assert!(run_checks(&doc(), &cs, std::path::Path::new("."), false).is_err());
    }
}
