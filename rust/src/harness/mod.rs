//! Declarative scenario harness: the YAML-driven end-to-end suite behind
//! `stox-cli test --suite scenarios/` (ROADMAP direction 5).
//!
//! A scenario file declares a fixture + pipeline stage + expectations:
//!
//! ```yaml
//! stage: infer                 # infer | sweep | train | serve | chaos | nonideal | parse
//! config:
//!   fixture: tiny_inhomo       # rust/tests/data/<name>
//!   converter: stox:alpha=4,samples=1
//!   precision: 4w4a4bs
//!   seed: 7
//! expect:
//!   - path: accuracy           # '/'-separated path into the stage output
//!     mode: range              # exact | tolerance | subset | ordering | monotonic | range
//!     min: 0.5
//!   - path: logits0
//!     mode: exact
//!     golden: infer_stox.golden.json   # bless-on-missing / UPDATE_SCENARIOS=1
//! ```
//!
//! Negative-path scenarios pin exact error strings instead:
//!
//! ```yaml
//! stage: parse
//! config:
//!   converter: warp:x=1
//! expect_error: "no PS converter registered for mode 'warp' (known: ...)"
//! ```
//!
//! The pipeline is parser ([`parse_yaml`]) → executor ([`run_stage`], all
//! in-process entry points) → comparator ([`run_checks`], structured
//! [`Diff`]s, golden bless) → reporter ([`SuiteReport`], summary table +
//! `scenarios_report.json`).  On failure the actual stage output is
//! written next to the scenario as `<name>.actual.json` and removed again
//! on the next passing run.

pub mod comparator;
pub mod executor;
pub mod parser;
pub mod reporter;

pub use comparator::{lookup, run_checks, CheckOutcome, Diff};
pub use executor::{fixture_dir, run_stage};
pub use parser::{parse_yaml, to_yaml};
pub use reporter::{ScenarioResult, Status, SuiteReport};

use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Suite-level knobs (CLI flags of `stox-cli test`).
#[derive(Debug, Default)]
pub struct SuiteOptions {
    /// Only run scenarios whose file stem contains this substring.
    pub filter: Option<String>,
    /// Re-bless every golden the suite compares (also enabled by the
    /// `UPDATE_SCENARIOS=1` environment variable).
    pub update: bool,
}

/// Run a single scenario file.  `update` re-blesses its goldens.
///
/// Returns `Err` only for harness-level problems (unreadable file,
/// malformed YAML, malformed check); a scenario whose *stage* errors or
/// whose checks mismatch yields a [`Status::Fail`] result instead.
pub fn run_scenario(path: &Path, update: bool) -> crate::Result<ScenarioResult> {
    let start = Instant::now();
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("scenario")
        .to_string();
    let dir = path.parent().unwrap_or_else(|| Path::new(".")).to_path_buf();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let doc = parse_yaml(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;

    let mut diffs = Vec::new();
    let mut blessed = Vec::new();
    let mut actual_doc: Option<Json> = None;
    let expect_error = doc.get("expect_error").and_then(|v| v.as_str());

    match run_stage(&doc) {
        Err(e) => {
            let got = e.to_string();
            match expect_error {
                Some(want) if want == got => {}
                Some(want) => diffs.push(Diff {
                    path: "expect_error".into(),
                    detail: format!("expected error {want:?}, got {got:?}"),
                }),
                None => diffs.push(Diff {
                    path: "stage".into(),
                    detail: format!("stage failed: {got}"),
                }),
            }
        }
        Ok(actual) => {
            if let Some(want) = expect_error {
                diffs.push(Diff {
                    path: "expect_error".into(),
                    detail: format!("expected error {want:?}, but the stage succeeded"),
                });
            } else {
                let checks =
                    doc.get("expect").and_then(|v| v.as_arr()).cloned().unwrap_or_default();
                let outcome = run_checks(&actual, &checks, &dir, update)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
                diffs = outcome.diffs;
                blessed = outcome.blessed;
            }
            actual_doc = Some(actual);
        }
    }

    let status = if !diffs.is_empty() {
        Status::Fail
    } else if !blessed.is_empty() {
        Status::Blessed
    } else {
        Status::Pass
    };

    // failure snapshot next to the scenario; cleared on the next pass
    let snap = dir.join(format!("{name}.actual.json"));
    if status == Status::Fail {
        if let Some(a) = &actual_doc {
            let _ = std::fs::write(&snap, a.to_string());
        }
    } else {
        let _ = std::fs::remove_file(&snap);
    }

    Ok(ScenarioResult {
        name,
        file: path.display().to_string(),
        status,
        diffs,
        blessed,
        millis: start.elapsed().as_millis(),
    })
}

/// Run every `*.yaml` scenario under `dir` (sorted by filename) and
/// aggregate the results.  Never early-exits on a failing scenario — the
/// report carries all failures so CI shows the full picture.
pub fn run_suite(dir: &Path, opts: &SuiteOptions) -> crate::Result<SuiteReport> {
    let update = opts.update
        || std::env::var("UPDATE_SCENARIOS").map(|v| v == "1").unwrap_or(false);
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read suite dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("yaml"))
        .collect();
    files.sort();
    anyhow::ensure!(!files.is_empty(), "no *.yaml scenarios in {}", dir.display());

    let mut report = SuiteReport::default();
    for f in files {
        if let Some(filter) = &opts.filter {
            let stem = f.file_stem().and_then(|s| s.to_str()).unwrap_or("");
            if !stem.contains(filter.as_str()) {
                continue;
            }
        }
        report.results.push(run_scenario(&f, update)?);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_suite(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("stox_suite_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn parse_stage_scenario_passes_and_negative_path_pins_error() {
        let dir = tmp_suite("basic");
        std::fs::write(
            dir.join("a_parse_ok.yaml"),
            "stage: parse\nconfig:\n  converter: stox:alpha=4,samples=2\nexpect:\n  - path: spec\n    mode: exact\n    value: stox:alpha=4,samples=2\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("b_parse_err.yaml"),
            "stage: parse\nconfig:\n  converter: warp\nexpect_error: \"no PS converter registered for mode 'warp' (known: ideal, quant, sparse, sa, expected, stox, inhomo)\"\n",
        )
        .unwrap();
        let rep = run_suite(&dir, &SuiteOptions::default()).unwrap();
        assert_eq!(rep.results.len(), 2);
        assert!(rep.ok(), "{}", rep.render_table());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failing_scenario_writes_snapshot_then_pass_removes_it() {
        let dir = tmp_suite("snap");
        let file = dir.join("c_fail.yaml");
        std::fs::write(
            &file,
            "stage: parse\nconfig:\n  precision: 8w8a4bs\nexpect:\n  - path: tag\n    mode: exact\n    value: 4w4a4bs\n",
        )
        .unwrap();
        let r = run_scenario(&file, false).unwrap();
        assert_eq!(r.status, Status::Fail);
        assert!(!r.diffs.is_empty());
        let snap = dir.join("c_fail.actual.json");
        assert!(snap.exists(), "failure snapshot written");
        let got = Json::parse(&std::fs::read_to_string(&snap).unwrap()).unwrap();
        assert_eq!(got.get("tag").and_then(|v| v.as_str()), Some("8w8a4bs"));

        std::fs::write(
            &file,
            "stage: parse\nconfig:\n  precision: 8w8a4bs\nexpect:\n  - path: tag\n    mode: exact\n    value: 8w8a4bs\n",
        )
        .unwrap();
        let r = run_scenario(&file, false).unwrap();
        assert_eq!(r.status, Status::Pass);
        assert!(!snap.exists(), "snapshot cleared on pass");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unexpected_stage_error_and_filter() {
        let dir = tmp_suite("filter");
        std::fs::write(dir.join("x_bad.yaml"), "stage: parse\nconfig:\n  converter: nope\n")
            .unwrap();
        std::fs::write(
            dir.join("y_ok.yaml"),
            "stage: parse\nconfig:\n  precision: 4w4a4bs\nexpect:\n  - path: ok\n    value: true\n",
        )
        .unwrap();
        let all = run_suite(&dir, &SuiteOptions::default()).unwrap();
        assert_eq!(all.failed(), 1, "unexpected stage error is a failure");
        let only_ok = run_suite(
            &dir,
            &SuiteOptions { filter: Some("y_".into()), update: false },
        )
        .unwrap();
        assert_eq!(only_ok.results.len(), 1);
        assert!(only_ok.ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
