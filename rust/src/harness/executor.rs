//! Scenario execution: each `stage:` maps onto the crate's in-process
//! entry points — no subprocesses, so scenarios are as fast and as
//! deterministic as the unit tests they replace.
//!
//! | stage      | entry points                                                     |
//! |------------|------------------------------------------------------------------|
//! | `infer`    | [`NativeModel::load_with_config`] + converter/precision overrides |
//! | `sweep`    | [`run_matrix_sweep`] over [`GoldenWorkload`]s                     |
//! | `train`    | [`Trainer`] twice per seed + [`export_checkpoint`] round-trip     |
//! | `serve`    | [`ReplicaServer`] (and the single [`Server`] as reference)        |
//! | `chaos`    | [`ReplicaServer`] under a [`FaultPlan`] vs a fault-free twin      |
//! | `nonideal` | [`NonidealCrossbar`] RMS-error ablation vs the ideal MVM          |
//! | `parse`    | [`PsConverterSpec::from_mode`] / [`StoxConfig::from_tag`]         |
//!
//! The output of a stage is one [`Json`] document whose fields the
//! scenario's `expect:` block addresses by `/`-path; timing-dependent
//! quantities (wall-clock latency, shard assignment under stealing) are
//! deliberately *not* folded into pinnable scalars — scenarios pin the
//! deterministic contract (logits, counters, orderings) and leave the
//! rest to `subset`/`range` checks.

use crate::arch::sweep::{parse_precision_tags, run_matrix_sweep, GoldenWorkload};
use crate::coordinator::server::{submit_all, Executor, NativeExecutor, Reply, ServeConfig, Server};
use crate::coordinator::BatcherConfig;
use crate::imc::{Nonideality, NonidealCrossbar, PsConvert, PsConverterSpec, StoxConfig, StoxMvm};
use crate::model::weights::TestSet;
use crate::model::{zoo, Manifest, NativeModel, WeightStore};
use crate::obs::CounterRegistry;
use crate::serve::{FaultPlan, ReplicaConfig, ReplicaServer, ResilienceConfig, ShardFaults};
use crate::stats::rng::CounterRng;
use crate::train::{export_checkpoint, TrainConfig, Trainer};
use crate::util::json::Json;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

/// Run the scenario's stage and return the actual-output document.
///
/// An `Err` is a *stage* failure (bad config, parse error, …): the
/// runner matches it against the scenario's `expect_error:` string, so
/// negative-path scenarios pin exact error messages.
pub fn run_stage(scenario: &Json) -> crate::Result<Json> {
    let stage = scenario
        .get("stage")
        .and_then(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("scenario missing 'stage'"))?;
    let empty = Json::obj(vec![]);
    let cfg = scenario.get("config").unwrap_or(&empty);
    match stage {
        "infer" => stage_infer(cfg),
        "sweep" => stage_sweep(cfg),
        "train" => stage_train(cfg),
        "serve" => stage_serve(cfg),
        "chaos" => stage_chaos(cfg),
        "nonideal" => stage_nonideal(cfg),
        "parse" => stage_parse(cfg),
        other => anyhow::bail!(
            "unknown stage '{other}' (infer|sweep|train|serve|chaos|nonideal|parse)"
        ),
    }
}

/// Resolve a committed fixture by name: `rust/tests/data/<name>` relative
/// to the working directory, falling back to the compile-time crate root
/// so the harness works both from `cargo test` and from an installed
/// `stox-cli` run elsewhere in the checkout.
pub fn fixture_dir(name: &str) -> PathBuf {
    let rel = PathBuf::from("rust/tests/data").join(name);
    if rel.join("manifest.json").exists() {
        return rel;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data").join(name)
}

// ---------- config accessors ----------

fn s<'a>(cfg: &'a Json, key: &str) -> Option<&'a str> {
    cfg.get(key).and_then(|v| v.as_str())
}

fn n_usize(cfg: &Json, key: &str, default: usize) -> usize {
    cfg.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
}

fn n_u32(cfg: &Json, key: &str, default: u32) -> u32 {
    cfg.get(key).and_then(|v| v.as_u32()).unwrap_or(default)
}

fn n_f32(cfg: &Json, key: &str, default: f32) -> f32 {
    cfg.get(key).and_then(|v| v.as_f64()).map(|v| v as f32).unwrap_or(default)
}

fn flag(cfg: &Json, key: &str, default: bool) -> bool {
    cfg.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
}

fn load_fixture(cfg: &Json) -> crate::Result<(Manifest, WeightStore, TestSet)> {
    let name = s(cfg, "fixture").unwrap_or("tiny_inhomo");
    let m = Manifest::load(fixture_dir(name))?;
    let store = WeightStore::load(&m)?;
    let test = TestSet::load(&m)?;
    Ok((m, store, test))
}

fn hw_config(cfg: &Json, m: &Manifest) -> crate::Result<StoxConfig> {
    match s(cfg, "precision") {
        Some(tag) => m.spec.precision_config(tag),
        None => Ok(m.spec.stox_config()),
    }
}

fn f32s_to_json(v: &[f32]) -> Json {
    // f32 → f64 is exact, and the JSON writer round-trips f64, so these
    // arrays bit-pin the logits when used with `exact` golden checks
    Json::Arr(v.iter().map(|&x| Json::Num(f64::from(x))).collect())
}

// ---------- infer ----------

fn stage_infer(cfg: &Json) -> crate::Result<Json> {
    let (m, store, test) = load_fixture(cfg)?;
    let hw = hw_config(cfg, &m)?;
    let mut model = NativeModel::load_with_config(&m, &store, hw)?;
    let mut converter = Json::Null;
    if let Some(c) = s(cfg, "converter") {
        let spec = PsConverterSpec::from_mode(c, hw.alpha, hw.n_samples)?;
        converter = Json::Str(spec.to_string());
        model = model.with_converter_spec(&spec)?;
    }
    // `counters: true` attaches a fresh hardware-counter registry while
    // the crossbars are still exclusively owned; the snapshot emitted at
    // the end covers every run this stage performs and is exactly
    // reproducible on these paths, so scenarios pin it with `exact`
    // goldens (the memo hit/miss determinism contract of
    // `PsIntCache::take_stats`)
    let registry = if flag(cfg, "counters", false) {
        let reg = CounterRegistry::new();
        model.attach_counters(&reg)?;
        Some(reg)
    } else {
        None
    };
    // `pipeline: false` forces the sequential whole-batch forward; the
    // default exercises the layer-pipelined path wherever it is eligible
    model.set_pipeline(flag(cfg, "pipeline", true));
    let seed = n_u32(cfg, "seed", 7);
    let batch = n_usize(cfg, "batch", 8);
    let n = test.n;
    let img_sz = model.image_size * model.image_size * model.in_channels;
    let classes = model.num_classes;

    let accuracy = model.accuracy(&test.images, &test.labels, n, batch, seed);
    let l1 = model.forward(&test.images[..n * img_sz], n, seed);
    let l2 = model.forward(&test.images[..n * img_sz], n, seed);
    let l3 = model.forward(&test.images[..n * img_sz], n, seed.wrapping_add(1));

    // the layer pipeline must not move a single sample relative to the
    // sequential forward (absolute-index RNG counter contract)
    let pipeline_was_on = flag(cfg, "pipeline", true);
    model.set_pipeline(false);
    let l_seq = model.forward(&test.images[..n * img_sz], n, seed);
    model.set_pipeline(pipeline_was_on);
    let pipeline_matches = l1 == l_seq;

    // logit margin of the labeled class per image — the trained-fixture
    // ordering claims (margins strictly positive, trained ≫ random-init)
    let mut margins = Vec::with_capacity(n);
    for i in 0..n {
        let row = &l1[i * classes..(i + 1) * classes];
        let lab = test.labels[i] as usize;
        let best_other = row
            .iter()
            .enumerate()
            .filter(|(k, _)| *k != lab)
            .map(|(_, &v)| v)
            .fold(f32::NEG_INFINITY, f32::max);
        margins.push(row[lab] - best_other);
    }
    let min_margin = margins.iter().copied().fold(f32::INFINITY, f32::min);

    let mut out = vec![
        ("fixture", Json::Str(s(cfg, "fixture").unwrap_or("tiny_inhomo").to_string())),
        ("tag", Json::Str(hw.tag())),
        ("converter", converter),
        ("classes", Json::Num(classes as f64)),
        ("images", Json::Num(n as f64)),
        ("accuracy", Json::Num(accuracy)),
        ("deterministic", Json::Bool(l1 == l2)),
        ("seed_invariant", Json::Bool(l1 == l3)),
        ("pipeline_matches_sequential", Json::Bool(pipeline_matches)),
        ("logits0", f32s_to_json(&l1[..classes])),
        ("margins", f32s_to_json(&margins)),
        ("min_margin", Json::Num(f64::from(min_margin))),
    ];
    if let Some(reg) = &registry {
        out.push(("counters", reg.to_json()));
    }

    // trained-vs-random ordering: score a reference fixture with its own
    // manifest config on the same images/seed and report the gap
    if let Some(rf) = s(cfg, "ref_fixture") {
        let rm = Manifest::load(fixture_dir(rf))?;
        let rstore = WeightStore::load(&rm)?;
        let rtest = TestSet::load(&rm)?;
        let rmodel = NativeModel::load(&rm, &rstore)?;
        let racc = rmodel.accuracy(&rtest.images, &rtest.labels, rtest.n, batch, seed);
        out.push(("ref_accuracy", Json::Num(racc)));
        out.push(("accuracy_gap", Json::Num(accuracy - racc)));
    }
    Ok(Json::obj(out))
}

// ---------- sweep ----------

fn default_sweep_specs() -> Vec<PsConverterSpec> {
    [
        "ideal",
        "quant:bits=8",
        "sparse:bits=4",
        "sa",
        "expected:alpha=4",
        "stox:alpha=4,samples=1",
        "stox:alpha=4,samples=4",
        "inhomo:alpha=4,base=1,extra=3",
    ]
    .iter()
    .map(|s| s.parse().expect("builtin specs parse"))
    .collect()
}

fn stage_sweep(cfg: &Json) -> crate::Result<Json> {
    let inputs = n_usize(cfg, "inputs", 48);
    let seed = n_u32(cfg, "seed", 2024);
    let tags = s(cfg, "precision").unwrap_or("4w4a4bs,8w8a4bs");
    let base = StoxConfig::default();
    let tag_cfgs = parse_precision_tags(tags, &base)?;
    let specs: Vec<PsConverterSpec> = match cfg.get("specs").and_then(|v| v.as_arr()) {
        Some(list) => list
            .iter()
            .map(|t| {
                t.as_str()
                    .ok_or_else(|| anyhow::anyhow!("sweep spec not a string"))
                    .and_then(|t| t.parse::<PsConverterSpec>())
            })
            .collect::<crate::Result<_>>()?,
        None => default_sweep_specs(),
    };
    let workloads: Vec<GoldenWorkload> = tag_cfgs
        .iter()
        .map(|c| GoldenWorkload::new(*c, inputs, seed))
        .collect::<crate::Result<_>>()?;
    let grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> =
        tag_cfgs.iter().map(|c| (*c, specs.clone())).collect();
    let layers = zoo::resnet20_cifar();
    let run = |threads: usize| {
        run_matrix_sweep(&grid, &layers, "resnet20_cifar", seed, threads, |ti, spec| {
            Ok(workloads[ti].accuracy(spec.build(workloads[ti].cfg())?.as_ref()))
        })
    };
    let r = run(1)?;
    let json = r.to_json();
    let thread_invariant = if flag(cfg, "check_threads", true) {
        run(2)?.to_json().to_string() == json.to_string()
    } else {
        true
    };

    // flatten to `tag|spec` cells so checks address matrix cells directly
    let cells = Json::Obj(
        r.points
            .iter()
            .map(|p| {
                (
                    format!("{}|{}", p.tag, p.spec),
                    Json::obj(vec![
                        ("label", Json::Str(p.label.clone())),
                        ("accuracy", Json::Num(p.accuracy)),
                        ("energy_pj", Json::Num(p.energy_pj)),
                        ("latency_ns", Json::Num(p.latency_ns)),
                        ("area_um2", Json::Num(p.area_um2)),
                        ("edp_pj_ns", Json::Num(p.edp_pj_ns)),
                        ("conversions", Json::Num(p.conversions as f64)),
                        ("xbars", Json::Num(p.xbars as f64)),
                        ("on_front", Json::Bool(p.on_front)),
                    ]),
                )
            })
            .collect(),
    );
    let csv = r.to_csv();
    Ok(Json::obj(vec![
        ("workload", Json::Str(r.workload.clone())),
        ("seed", Json::Num(seed as f64)),
        ("points", Json::Num(r.points.len() as f64)),
        ("front_size", Json::Num(r.front().len() as f64)),
        ("thread_invariant", Json::Bool(thread_invariant)),
        ("cells", cells),
        ("csv_header", Json::Str(csv.lines().next().unwrap_or("").to_string())),
        ("csv_rows", Json::Num(csv.lines().count().saturating_sub(1) as f64)),
        ("table_has_front", Json::Bool(r.render_table().contains("pareto front"))),
        ("result", json),
    ]))
}

// ---------- train ----------

fn stage_train(cfg: &Json) -> crate::Result<Json> {
    let (m, store, test) = load_fixture(cfg)?;
    let hw = hw_config(cfg, &m)?;
    let conv_override = match s(cfg, "converter") {
        Some(c) => Some(PsConverterSpec::from_mode(c, hw.alpha, hw.n_samples)?),
        None => None,
    };
    let tc = TrainConfig {
        steps: n_usize(cfg, "steps", 20),
        batch: n_usize(cfg, "batch", 4),
        lr: n_f32(cfg, "lr", 0.05),
        momentum: n_f32(cfg, "momentum", 0.9),
        weight_decay: n_f32(cfg, "weight_decay", 5e-4),
        seed: n_u32(cfg, "seed", 7),
        cosine_lr: flag(cfg, "cosine_lr", true),
        log_every: 0, // 0 = silent; scenarios run quiet
    };
    let run = || -> crate::Result<(Trainer, crate::train::TrainRecord)> {
        let mut t = Trainer::new(&m, &store, hw, conv_override.as_ref(), tc.clone())?;
        let rec = t.train(&test.images, &test.labels, test.n)?;
        Ok((t, rec))
    };
    let (trainer, rec) = run()?;
    let (_, rec2) = run()?;
    let reproducible = rec.losses == rec2.losses && rec.final_loss == rec2.final_loss;

    let k = 5.min(rec.losses.len());
    let head: f32 = rec.losses[..k].iter().sum::<f32>() / k as f32;
    let tail: f32 = rec.losses[rec.losses.len() - k..].iter().sum::<f32>() / k as f32;

    // export → reload round-trip through the registry (no override)
    let out_dir = std::env::temp_dir().join(format!(
        "stox_scenario_train_{}_{}",
        std::process::id(),
        tc.seed
    ));
    export_checkpoint(&trainer, &m, &rec, &out_dir)?;
    let m2 = Manifest::load(&out_dir)?;
    let s2 = WeightStore::load(&m2)?;
    let reloaded = NativeModel::load(&m2, &s2)?;
    let t2 = TestSet::load(&m2)?;
    let racc = reloaded.accuracy(&t2.images, &t2.labels, t2.n, 8, 0);
    let export_mode = m2.spec.stox.mode.clone();
    let _ = std::fs::remove_dir_all(&out_dir);

    Ok(Json::obj(vec![
        ("steps", Json::Num(rec.steps as f64)),
        ("seed", Json::Num(rec.seed as f64)),
        ("body_mode", Json::Str(rec.body_spec.clone())),
        ("export_mode", Json::Str(export_mode)),
        ("reproducible", Json::Bool(reproducible)),
        ("loss_first", Json::Num(f64::from(rec.losses[0]))),
        ("loss_final", Json::Num(f64::from(rec.final_loss))),
        ("loss_ratio", Json::Num(f64::from(tail / head))),
        ("loss_decreased", Json::Bool(tail < 0.85 * head)),
        ("reloaded_accuracy", Json::Num(racc)),
    ]))
}

// ---------- serve ----------

/// An executor that always fails — the retry-exhaustion scenario's shard,
/// mirroring the transient-error mock in `coordinator::server` tests.
struct FailingExec {
    classes: usize,
    elems: usize,
}

impl Executor for FailingExec {
    fn execute(&self, _images: &[f32], _batch: usize, _seed: u32) -> crate::Result<Vec<f32>> {
        Err(anyhow::anyhow!("injected executor failure"))
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn image_elems(&self) -> usize {
        self.elems
    }
    fn max_batch(&self) -> usize {
        8
    }
}

fn collect_replies(
    rxs: Vec<mpsc::Receiver<Reply>>,
) -> crate::Result<Vec<Result<Vec<f32>, String>>> {
    rxs.into_iter()
        .map(|r| Ok(r.recv().map_err(|_| anyhow::anyhow!("reply channel dropped"))?.result))
        .collect()
}

fn error_kinds(replies: &[Result<Vec<f32>, String>]) -> Json {
    let mut kinds: Vec<&String> = replies.iter().filter_map(|r| r.as_ref().err()).collect();
    kinds.sort();
    kinds.dedup();
    Json::Arr(kinds.into_iter().map(|k| Json::Str(k.clone())).collect())
}

fn stage_serve(cfg: &Json) -> crate::Result<Json> {
    if s(cfg, "mode") == Some("failing") {
        return stage_serve_failing(cfg);
    }
    let (m, store, test) = load_fixture(cfg)?;
    let model = NativeModel::load(&m, &store)?;
    let requests = n_usize(cfg, "requests", test.n);
    let batcher = BatcherConfig {
        target_batch: n_usize(cfg, "target_batch", 4),
        max_wait: Duration::from_millis(u64::from(n_u32(cfg, "max_wait_ms", 10_000))),
    };
    let seed = n_u32(cfg, "seed", 5);
    let queue_depth = n_usize(cfg, "queue_depth", 1024);
    let deadline = cfg
        .get("deadline_ms")
        .and_then(|v| v.as_f64())
        .map(|ms| Duration::from_millis(ms as u64));
    let rcfg = ReplicaConfig {
        replicas: n_usize(cfg, "replicas", 2),
        batcher,
        seed,
        queue_depth,
        deadline,
        slo: Duration::from_millis(u64::from(n_u32(cfg, "slo_ms", 5_000))),
        steal: flag(cfg, "steal", true),
        resilience: ResilienceConfig::default(),
    };
    let images: Vec<Vec<f32>> =
        (0..requests).map(|i| test.image(i % test.n).to_vec()).collect();

    let server = ReplicaServer::from_native(&model, rcfg);
    let (tx, rx) = mpsc::channel();
    let rxs = submit_all(&tx, images.clone().into_iter());
    drop(tx);
    server.run(rx);
    let replies = collect_replies(rxs)?;

    let ok = replies.iter().filter(|r| r.is_ok()).count();
    let rejected = replies
        .iter()
        .filter(|r| r.as_ref().err().map(|e| e == crate::serve::REJECTED) == Some(true))
        .count();
    let deadline_exceeded = replies
        .iter()
        .filter(|r| {
            r.as_ref().err().map(|e| e == crate::serve::DEADLINE_EXCEEDED) == Some(true)
        })
        .count();

    // bit-identity vs the single-Server loop is only defined when nothing
    // can be shed — skip the reference run otherwise
    let compare_default = deadline.is_none() && queue_depth >= requests;
    let matches_single = if flag(cfg, "compare_single", compare_default) {
        let single = Server::new(
            Box::new(NativeExecutor { model: model.replica_view() }),
            ServeConfig { batcher, seed, max_retries: 0 },
        );
        let (tx, rx) = mpsc::channel();
        let rxs = submit_all(&tx, images.into_iter());
        drop(tx);
        single.run(rx);
        let reference = collect_replies(rxs)?;
        Json::Bool(replies == reference)
    } else {
        Json::Null
    };

    let metrics = server.metrics.to_json();
    let shard_requests_sum: f64 = metrics
        .get("shards")
        .and_then(|s| s.as_arr())
        .map(|arr| {
            arr.iter()
                .filter_map(|s| s.get("requests").and_then(|v| v.as_f64()))
                .sum()
        })
        .unwrap_or(f64::NAN);

    Ok(Json::obj(vec![
        ("requests_submitted", Json::Num(requests as f64)),
        ("ok", Json::Num(ok as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("deadline_exceeded", Json::Num(deadline_exceeded as f64)),
        (
            "accounted",
            Json::Bool(ok + rejected + deadline_exceeded == requests),
        ),
        ("error_kinds", error_kinds(&replies)),
        ("matches_single_server", matches_single),
        ("batches", Json::Num(server.metrics.batches() as f64)),
        ("shard_requests_sum", Json::Num(shard_requests_sum)),
        ("metrics", metrics),
    ]))
}

fn stage_serve_failing(cfg: &Json) -> crate::Result<Json> {
    let requests = n_usize(cfg, "requests", 4);
    let max_retries = n_u32(cfg, "max_retries", 2);
    let exec = FailingExec { classes: 4, elems: 4 };
    let server = Server::new(
        Box::new(exec),
        ServeConfig {
            batcher: BatcherConfig {
                target_batch: n_usize(cfg, "target_batch", requests),
                max_wait: Duration::from_millis(5),
            },
            seed: 0,
            max_retries,
        },
    );
    let (tx, rx) = mpsc::channel();
    let rxs = submit_all(&tx, (0..requests).map(|_| vec![0.0f32; 4]));
    drop(tx);
    server.run(rx);
    let replies = collect_replies(rxs)?;
    let ok = replies.iter().filter(|r| r.is_ok()).count();
    let retries = server.metrics.lock().unwrap().retries;
    Ok(Json::obj(vec![
        ("requests_submitted", Json::Num(requests as f64)),
        ("ok", Json::Num(ok as f64)),
        ("errors", Json::Num((replies.len() - ok) as f64)),
        ("error_kinds", error_kinds(&replies)),
        ("retries", Json::Num(retries as f64)),
    ]))
}

// ---------- chaos ----------

/// Collect every reply and verify the exactly-once contract.  The servers
/// have finished by the time this runs, so a duplicate reply would
/// already be buffered on its channel — `try_recv` after the first
/// `recv` is a complete check, not a race.
fn collect_once(rxs: Vec<mpsc::Receiver<Reply>>) -> crate::Result<(Vec<Reply>, bool)> {
    let mut replies = Vec::with_capacity(rxs.len());
    let mut exactly_once = true;
    for rx in rxs {
        replies.push(rx.recv().map_err(|_| anyhow::anyhow!("reply channel dropped"))?);
        if rx.try_recv().is_ok() {
            exactly_once = false;
        }
    }
    Ok((replies, exactly_once))
}

/// Run the self-healing replica tier under a scenario-described
/// [`FaultPlan`] and pin its invariants against a fault-free reference
/// run (resilience off, no faults — the PR-6 serving path) over the same
/// request stream: every request gets exactly one reply, the accounting
/// partition is total, and — because requeued batches carry their
/// original seed — every `Ok` reply is bit-identical to the fault-free
/// tier's reply for the same request.
///
/// An optional `second_wave` submits that many extra requests ~60 ms
/// after the initial burst, so reintegration scenarios can observe
/// probes firing *after* an eviction instead of racing a pre-queued
/// burst that dispatches entirely before the first failure lands.
fn stage_chaos(cfg: &Json) -> crate::Result<Json> {
    let (m, store, test) = load_fixture(cfg)?;
    let model = NativeModel::load(&m, &store)?;
    let requests = n_usize(cfg, "requests", 10);
    let second_wave = n_usize(cfg, "second_wave", 0);
    let total = requests + second_wave;
    let replicas = n_usize(cfg, "replicas", 2);
    let seed = n_u32(cfg, "seed", 5);
    let brownout = flag(cfg, "brownout", false);
    let rcfg = ReplicaConfig {
        replicas,
        batcher: BatcherConfig {
            target_batch: n_usize(cfg, "target_batch", 2),
            // burst-fed: batches are cut by size and the final drain,
            // never by a wall-clock timeout
            max_wait: Duration::from_secs(3600),
        },
        seed,
        queue_depth: n_usize(cfg, "queue_depth", total.max(1)),
        deadline: None,
        slo: Duration::from_secs(5),
        steal: flag(cfg, "steal", false),
        resilience: ResilienceConfig {
            enabled: true,
            evict_consecutive: n_u32(cfg, "evict_consecutive", 2),
            probe_interval: n_u32(cfg, "probe_interval", 0),
            max_requeues: n_u32(cfg, "max_requeues", 3),
            brownout_queue: if brownout { Some(0) } else { None },
            ..Default::default()
        },
    };
    rcfg.validate()?;

    let mut plan = FaultPlan::uniform_transient(seed, replicas, n_f32(cfg, "severity", 0.0));
    if let Some(cs) = cfg.get("crash_shard").and_then(|v| v.as_usize()) {
        anyhow::ensure!(cs < replicas, "crash_shard {cs} out of range ({replicas} replicas)");
        let f: &mut ShardFaults = &mut plan.shards[cs];
        f.crash_at_batch = Some(n_usize(cfg, "crash_at", 0) as u64);
        f.recover_at_batch =
            cfg.get("recover_at").and_then(|v| v.as_usize()).map(|v| v as u64);
    }
    let fault_free = plan.is_disabled();

    let images: Vec<Vec<f32>> = (0..total).map(|i| test.image(i % test.n).to_vec()).collect();
    let submit = |server: &ReplicaServer<NativeExecutor>| -> crate::Result<(Vec<Reply>, bool)> {
        let (tx, rx) = mpsc::channel();
        let mut rxs = submit_all(&tx, images[..requests].iter().cloned());
        let wave2 = if second_wave > 0 {
            let tx2 = tx.clone();
            let tail: Vec<Vec<f32>> = images[requests..].to_vec();
            Some(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(60));
                submit_all(&tx2, tail.into_iter())
            }))
        } else {
            None
        };
        drop(tx);
        server.run(rx);
        if let Some(h) = wave2 {
            rxs.extend(h.join().expect("wave-2 submitter panicked"));
        }
        collect_once(rxs)
    };

    let mut server = ReplicaServer::from_native(&model, rcfg.clone()).with_fault_plan(plan);
    let degraded_model;
    if brownout {
        let spec = s(cfg, "brownout_spec").unwrap_or("stox:samples=1");
        degraded_model = model.share_with_converter_spec(&spec.parse::<PsConverterSpec>()?)?;
        server = server.with_degraded_native(&degraded_model);
    }
    let (replies, exactly_once) = submit(&server)?;

    let is_err = |r: &Reply, kind: &str| r.result.as_ref().err().map(String::as_str) == Some(kind);
    let ok = replies.iter().filter(|r| r.result.is_ok()).count();
    let degraded_n = replies.iter().filter(|r| r.degraded).count();
    let rejected = replies.iter().filter(|r| is_err(r, crate::serve::REJECTED)).count();
    let deadline_exceeded =
        replies.iter().filter(|r| is_err(r, crate::serve::DEADLINE_EXCEEDED)).count();
    let errors = replies.len() - ok - rejected - deadline_exceeded;
    let checksum: f64 = replies
        .iter()
        .filter_map(|r| r.result.as_ref().ok())
        .map(|l| l.iter().map(|&v| f64::from(v)).sum::<f64>())
        .sum();

    // brown-out legs intentionally change the logits (short-sampling
    // executors), so the bit-identity claims are only defined without it
    let (matches_fault_free, ok_match) = if brownout {
        (Json::Null, Json::Null)
    } else {
        let reference = ReplicaServer::from_native(
            &model,
            ReplicaConfig { resilience: ResilienceConfig::default(), ..rcfg },
        );
        let (refr, _) = submit(&reference)?;
        let full = replies.len() == refr.len()
            && replies
                .iter()
                .zip(&refr)
                .all(|(a, b)| a.result == b.result && a.degraded == b.degraded);
        let ok_only = replies.iter().zip(&refr).all(|(a, b)| match &a.result {
            Ok(v) => b.result.as_ref().ok() == Some(v),
            Err(_) => true,
        });
        (Json::Bool(full), Json::Bool(ok_only))
    };

    Ok(Json::obj(vec![
        ("requests_submitted", Json::Num(total as f64)),
        ("fault_free", Json::Bool(fault_free)),
        ("ok", Json::Num(ok as f64)),
        ("degraded", Json::Num(degraded_n as f64)),
        ("errors", Json::Num(errors as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("deadline_exceeded", Json::Num(deadline_exceeded as f64)),
        (
            "accounted",
            Json::Bool(ok + errors + rejected + deadline_exceeded == total),
        ),
        ("exactly_once", Json::Bool(exactly_once)),
        ("evicted", Json::Num(server.metrics.evicted() as f64)),
        ("reintegrated", Json::Num(server.metrics.reintegrated() as f64)),
        ("requeued", Json::Num(server.metrics.requeued() as f64)),
        ("probes", Json::Num(server.metrics.probes() as f64)),
        ("checksum", Json::Num(checksum)),
        ("matches_fault_free", matches_fault_free),
        ("ok_replies_match_fault_free", ok_match),
    ]))
}

// ---------- nonideal ----------

fn stage_nonideal(cfg: &Json) -> crate::Result<Json> {
    let seeds = n_u32(cfg, "seeds", 4);
    let (b, m, n) = (4usize, 576usize, 64usize);
    let rng = CounterRng::new(3);
    let a: Vec<f32> = (0..b * m).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect();
    let w: Vec<f32> =
        (0..m * n).map(|i| rng.uniform_in((b * m + i) as u32, -1.0, 1.0)).collect();
    let hw = StoxConfig::default();
    let build = |spec: &str| -> crate::Result<Box<dyn PsConvert>> {
        PsConverterSpec::from_mode(spec, hw.alpha, hw.n_samples)?.build(&hw)
    };
    let ideal = StoxMvm::program(&w, m, n, hw)?.run(&a, b, build("expected")?.as_ref(), 0);
    let rms = |xb: &NonidealCrossbar, conv: &dyn PsConvert| -> f64 {
        let mut acc = 0.0f64;
        for seed in 0..seeds {
            let o = xb.run(&a, b, conv, seed);
            acc += o
                .iter()
                .zip(&ideal)
                .map(|(g, t)| f64::from(g - t).powi(2))
                .sum::<f64>()
                / o.len() as f64;
        }
        (acc / f64::from(seeds)).sqrt()
    };
    let severities = [
        ("ideal", Nonideality::default()),
        ("sigma_g_10", Nonideality { sigma_g: 0.10, ..Default::default() }),
        ("sigma_g_25", Nonideality { sigma_g: 0.25, ..Default::default() }),
        ("ir_drop_10", Nonideality { ir_drop: 0.10, ..Default::default() }),
        ("read_noise_5", Nonideality { sigma_read: 0.05, ..Default::default() }),
        (
            "combined",
            Nonideality { sigma_g: 0.10, ir_drop: 0.05, sigma_read: 0.03, ..Default::default() },
        ),
    ];
    let conv_sa = build("sa")?;
    let conv_m1 = build("stox:samples=1")?;
    let conv_m4 = build("stox:samples=4")?;
    let mut cases = Vec::new();
    for (name, sev) in severities {
        let xb = NonidealCrossbar::program(&w, m, n, hw, sev, 11)?;
        cases.push((
            name,
            Json::obj(vec![
                ("sa", Json::Num(rms(&xb, conv_sa.as_ref()))),
                ("m1", Json::Num(rms(&xb, conv_m1.as_ref()))),
                ("m4", Json::Num(rms(&xb, conv_m4.as_ref()))),
            ]),
        ));
    }
    let mut out = vec![
        ("seeds", Json::Num(f64::from(seeds))),
        ("cases", Json::obj(cases)),
    ];

    // hard-fault severity ladder: sweep one fault axis and report the
    // RMS error per rung, for `monotonic`-mode degradation scenarios
    if let Some(kind) = s(cfg, "ladder_kind") {
        let sevs: Vec<f64> = match cfg.get("ladder_severities").and_then(|v| v.as_arr()) {
            Some(a) => a.iter().filter_map(|x| x.as_f64()).collect(),
            None => vec![0.0, 0.1, 0.3, 0.6],
        };
        let conv = build(s(cfg, "ladder_converter").unwrap_or("sa"))?;
        let mut ladder = Vec::with_capacity(sevs.len());
        for &sv in &sevs {
            let xb = NonidealCrossbar::program(&w, m, n, hw, ladder_fault(kind, sv as f32)?, 11)?;
            ladder.push(Json::obj(vec![
                ("severity", Json::Num(sv)),
                ("rms", Json::Num(rms(&xb, conv.as_ref()))),
            ]));
        }
        out.push(("ladder", Json::Arr(ladder)));
    }
    Ok(Json::obj(out))
}

/// One rung of a hard-fault severity ladder: `kind` names the fault
/// axis, `sv` its severity (fault density, or the drift coefficient
/// evaluated at elapsed time 1).
fn ladder_fault(kind: &str, sv: f32) -> crate::Result<Nonideality> {
    Ok(match kind {
        "stuck_zero" => Nonideality { stuck_zero: sv, ..Default::default() },
        "stuck_one" => Nonideality { stuck_one: sv, ..Default::default() },
        "stuck_mtj" => Nonideality { stuck_mtj: sv, ..Default::default() },
        "drift" => Nonideality { drift: sv, drift_time: 1.0, ..Default::default() },
        "dropout" => Nonideality { sample_dropout: sv, ..Default::default() },
        other => anyhow::bail!(
            "unknown ladder_kind '{other}' (stuck_zero|stuck_one|stuck_mtj|drift|dropout)"
        ),
    })
}

// ---------- parse ----------

fn stage_parse(cfg: &Json) -> crate::Result<Json> {
    let mut out = vec![("ok", Json::Bool(true))];
    if let Some(c) = s(cfg, "converter") {
        let spec = PsConverterSpec::from_mode(c, 4.0, 1)?;
        let built = spec.build(&StoxConfig::default())?;
        out.push(("spec", Json::Str(spec.to_string())));
        out.push(("label", Json::Str(built.label())));
    }
    if let Some(tag) = s(cfg, "precision") {
        let hw = StoxConfig::from_tag(tag, &StoxConfig::default())?;
        out.push(("tag", Json::Str(hw.tag())));
    }
    Ok(Json::obj(out))
}
