//! Suite reporting: the human-readable summary table printed by
//! `stox-cli test` and the machine-readable `scenarios_report.json`
//! artifact CI uploads.

use super::comparator::Diff;
use crate::util::json::Json;

/// Outcome of one scenario run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Every check matched.
    Pass,
    /// One or more goldens were (re)written this run — checks matched
    /// afterwards, but the run is not evidence until re-verified.
    Blessed,
    /// At least one check mismatched, or the stage errored unexpectedly.
    Fail,
}

impl Status {
    fn as_str(self) -> &'static str {
        match self {
            Status::Pass => "pass",
            Status::Blessed => "blessed",
            Status::Fail => "FAIL",
        }
    }
}

/// Result of one scenario file.
#[derive(Debug)]
pub struct ScenarioResult {
    /// Scenario name (file stem).
    pub name: String,
    /// Path of the scenario file, as given to the runner.
    pub file: String,
    /// Pass / blessed / fail.
    pub status: Status,
    /// Structured mismatches (empty on pass).
    pub diffs: Vec<Diff>,
    /// Golden files written this run (bless-on-missing or `--update`).
    pub blessed: Vec<String>,
    /// Wall-clock milliseconds the stage + checks took.
    pub millis: u128,
}

/// Aggregated results of one `run_suite` invocation.
#[derive(Debug, Default)]
pub struct SuiteReport {
    /// Per-scenario results, in execution (sorted-filename) order.
    pub results: Vec<ScenarioResult>,
}

impl SuiteReport {
    /// Number of scenarios that passed (including blessed ones).
    pub fn passed(&self) -> usize {
        self.results.iter().filter(|r| r.status != Status::Fail).count()
    }

    /// Number of scenarios that failed.
    pub fn failed(&self) -> usize {
        self.results.iter().filter(|r| r.status == Status::Fail).count()
    }

    /// Number of scenarios that wrote at least one golden this run.
    pub fn blessed(&self) -> usize {
        self.results.iter().filter(|r| r.status == Status::Blessed).count()
    }

    /// True when nothing failed.
    pub fn ok(&self) -> bool {
        self.failed() == 0
    }

    /// The per-suite summary table plus a one-line verdict.
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {:<34} | {:<7} | {:>8} | diffs\n", "scenario", "status", "ms"));
        s.push_str(&format!("|{:-<36}|{:-<9}|{:->10}|------\n", "", "", ""));
        for r in &self.results {
            let note = if r.status == Status::Fail {
                r.diffs
                    .first()
                    .map(|d| format!("{}: {}", d.path, d.detail))
                    .unwrap_or_default()
            } else if !r.blessed.is_empty() {
                format!("blessed {} golden(s)", r.blessed.len())
            } else {
                String::new()
            };
            s.push_str(&format!(
                "| {:<34} | {:<7} | {:>8} | {}\n",
                r.name,
                r.status.as_str(),
                r.millis,
                note
            ));
        }
        s.push_str(&format!(
            "\n{} passed, {} failed, {} blessed, {} total\n",
            self.passed(),
            self.failed(),
            self.blessed(),
            self.results.len()
        ));
        s
    }

    /// Machine-readable report (`scenarios_report.json` schema):
    /// `{passed, failed, blessed, total, scenarios: [{name, file, status,
    /// millis, diffs: [{path, detail}], blessed: [..]}]}`.
    pub fn to_json(&self) -> Json {
        let scenarios: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("file", Json::Str(r.file.clone())),
                    ("status", Json::Str(r.status.as_str().to_string())),
                    ("millis", Json::Num(r.millis as f64)),
                    ("diffs", Json::Arr(r.diffs.iter().map(|d| d.to_json()).collect())),
                    (
                        "blessed",
                        Json::Arr(r.blessed.iter().map(|b| Json::Str(b.clone())).collect()),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("passed", Json::Num(self.passed() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("blessed", Json::Num(self.blessed() as f64)),
            ("total", Json::Num(self.results.len() as f64)),
            ("scenarios", Json::Arr(scenarios)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(name: &str, status: Status, diffs: Vec<Diff>) -> ScenarioResult {
        ScenarioResult {
            name: name.into(),
            file: format!("scenarios/{name}.yaml"),
            status,
            diffs,
            blessed: vec![],
            millis: 3,
        }
    }

    #[test]
    fn report_counts_and_table() {
        let rep = SuiteReport {
            results: vec![
                fake("a", Status::Pass, vec![]),
                fake(
                    "b",
                    Status::Fail,
                    vec![Diff { path: "accuracy".into(), detail: "0.5 != 1".into() }],
                ),
                fake("c", Status::Blessed, vec![]),
            ],
        };
        assert_eq!(rep.passed(), 2);
        assert_eq!(rep.failed(), 1);
        assert_eq!(rep.blessed(), 1);
        assert!(!rep.ok());
        let t = rep.render_table();
        assert!(t.contains("FAIL"));
        assert!(t.contains("accuracy: 0.5 != 1"));
        assert!(t.contains("2 passed, 1 failed, 1 blessed, 3 total"));
        let j = rep.to_json();
        assert_eq!(j.get("failed").and_then(|v| v.as_usize()), Some(1));
        let scen = j.get("scenarios").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(scen[1].get("status").and_then(|v| v.as_str()), Some("FAIL"));
    }
}
