//! Fixed-bin histogram + streaming percentile helpers.
//!
//! Used by: Fig. 4 (PS output distribution), coordinator latency metrics,
//! and the Monte-Carlo sensitivity harness.

/// Fixed-range, fixed-bin histogram over f32 samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f32,
    hi: f32,
    bins: Vec<u64>,
    /// samples outside [lo, hi)
    pub under: u64,
    pub over: u64,
    count: u64,
    sum: f64,
    sum2: f64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Self {
            lo,
            hi,
            bins: vec![0; n_bins],
            under: 0,
            over: 0,
            count: 0,
            sum: 0.0,
            sum2: 0.0,
        }
    }

    pub fn add(&mut self, x: f32) {
        self.count += 1;
        self.sum += x as f64;
        self.sum2 += (x as f64) * (x as f64);
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let t = (x - self.lo) / (self.hi - self.lo);
            let idx = ((t * self.bins.len() as f32) as usize).min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    /// [`Histogram::add`] with the range comparison and bin index computed
    /// in `f64` — for µs-scale latency samples whose f32 rounding would
    /// lose sub-µs precision over long runs.  Binning semantics are
    /// unchanged: `[lo, hi)` in range, `x >= hi` counts as `over`.
    pub fn add_f64(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.sum2 += x * x;
        let (lo, hi) = (self.lo as f64, self.hi as f64);
        if x < lo {
            self.under += 1;
        } else if x >= hi {
            self.over += 1;
        } else {
            let t = (x - lo) / (hi - lo);
            let idx = ((t * self.bins.len() as f64) as usize).min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f32>) {
        for x in xs {
            self.add(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum2 / self.count as f64 - m * m).max(0.0).sqrt()
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Bin centers, aligned with `bins()`.
    pub fn centers(&self) -> Vec<f32> {
        let w = (self.hi - self.lo) / self.bins.len() as f32;
        (0..self.bins.len())
            .map(|i| self.lo + w * (i as f32 + 0.5))
            .collect()
    }

    /// Percentile over binned data, linearly interpolated within bins.
    ///
    /// Interpolation rule: `p` (clamped to `[0, 100]`) selects the target
    /// cumulative mass `p/100 · in_range` over the **in-range** samples
    /// (`under`/`over` samples carry no position and are excluded); the
    /// first occupied bin whose cumulative count reaches the target
    /// answers, placing the result at the fraction of the bin's width
    /// matching the fraction of its count needed.  Consequences, pinned
    /// by `percentile_edge_cases`:
    ///
    /// * empty histogram (or only out-of-range samples) → `NaN` — the
    ///   serving metrics map this to JSON `null`;
    /// * `p = 0` → the *left* edge of the first occupied bin;
    /// * `p = 100` (and anything above, after clamping) → the *right*
    ///   edge of the last occupied bin — never the histogram's `hi`
    ///   bound, which a pre-fix fall-through used to return for `p > 100`;
    /// * a single sample at `p = 50` → the center of its bin.
    pub fn percentile(&self, p: f64) -> f32 {
        if self.count == 0 {
            return f32::NAN;
        }
        let in_range: u64 = self.bins.iter().sum();
        if in_range == 0 {
            return f32::NAN;
        }
        let target = p.clamp(0.0, 100.0) / 100.0 * in_range as f64;
        let mut acc = 0.0;
        let w = (self.hi - self.lo) / self.bins.len() as f32;
        for (i, &b) in self.bins.iter().enumerate() {
            let next = acc + b as f64;
            if next >= target && b > 0 {
                let frac = (target - acc) / b as f64;
                return self.lo + w * (i as f32 + frac as f32);
            }
            acc = next;
        }
        self.hi
    }

    /// Normalized mass per bin (sums to 1 over in-range samples).
    pub fn density(&self) -> Vec<f64> {
        let total: u64 = self.bins.iter().sum();
        if total == 0 {
            return vec![0.0; self.bins.len()];
        }
        self.bins.iter().map(|&b| b as f64 / total as f64).collect()
    }

    /// Compact ASCII rendering (for CLI table/figure output).
    pub fn render(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let centers = self.centers();
        let mut out = String::new();
        for (c, &b) in centers.iter().zip(&self.bins) {
            let bar = (b as f64 / max as f64 * width as f64).round() as usize;
            out.push_str(&format!("{c:+.3} | {:<width$} {b}\n", "#".repeat(bar)));
        }
        out
    }
}

/// Latency histogram in microseconds: the single implementation behind
/// both serving tiers' latency metrics ([`crate::coordinator::Metrics`]
/// and `serve::ShardStats` used to hand-roll one copy each).
///
/// Two recording paths with deliberately different precision:
/// [`LatencyHistogram::record_us`] compares and bins in `f64` (at µs
/// scale an f32 cast quantizes to ~0.06 µs steps by 1 s and misreports
/// min/p999 — the replica tier's contract), while
/// [`LatencyHistogram::record_us_f32`] keeps the coordinator's original
/// f32 binning so the dedupe stays byte-identical with its pre-existing
/// reports.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    h: Histogram,
    min_us: f64,
}

impl LatencyHistogram {
    /// Fixed bins over `[0, hi_us)` microseconds.
    pub fn new(hi_us: f32, n_bins: usize) -> Self {
        Self { h: Histogram::new(0.0, hi_us, n_bins), min_us: f64::INFINITY }
    }

    /// Record one latency sample, binning in `f64` (see type docs).
    pub fn record_us(&mut self, us: f64) {
        self.h.add_f64(us);
        self.min_us = self.min_us.min(us);
    }

    /// Record one latency sample, binning in `f32` (legacy coordinator
    /// semantics; see type docs).
    pub fn record_us_f32(&mut self, us: f32) {
        self.h.add(us);
        self.min_us = self.min_us.min(us as f64);
    }

    pub fn count(&self) -> u64 {
        self.h.count()
    }

    pub fn mean_us(&self) -> f64 {
        self.h.mean()
    }

    /// Latency percentile; `NaN` before any sample lands in range (the
    /// [`Histogram::percentile`] interpolation rule).
    pub fn percentile_us(&self, p: f64) -> f32 {
        self.h.percentile(p)
    }

    /// Smallest observed latency in µs, tracked in `f64`; `0.0` when
    /// nothing was recorded (the `ShardStats::min_latency_us` contract).
    pub fn min_us(&self) -> f64 {
        if self.min_us.is_finite() {
            self.min_us
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_binning() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.extend([0.05, 0.15, 0.15, 0.95, -1.0, 2.0]);
        assert_eq!(h.bins()[0], 1);
        assert_eq!(h.bins()[1], 2);
        assert_eq!(h.bins()[9], 1);
        assert_eq!(h.under, 1);
        assert_eq!(h.over, 1);
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn moments() {
        let mut h = Histogram::new(-10.0, 10.0, 100);
        h.extend([1.0, 2.0, 3.0]);
        assert!((h.mean() - 2.0).abs() < 1e-9);
        assert!((h.std() - (2.0f64 / 3.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn percentile_uniform() {
        let mut h = Histogram::new(0.0, 1.0, 100);
        for i in 0..10_000 {
            h.add(i as f32 / 10_000.0);
        }
        assert!((h.percentile(50.0) - 0.5).abs() < 0.02);
        assert!((h.percentile(99.0) - 0.99).abs() < 0.02);
    }

    #[test]
    fn density_sums_to_one() {
        let mut h = Histogram::new(-1.0, 1.0, 7);
        h.extend((0..100).map(|i| (i as f32 / 50.0) - 1.0 + 1e-4));
        let d: f64 = h.density().iter().sum();
        assert!((d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn add_f64_matches_add_binning_and_keeps_precision() {
        let mut h32 = Histogram::new(0.0, 1.0, 10);
        let mut h64 = Histogram::new(0.0, 1.0, 10);
        for x in [0.05f64, 0.15, 0.95, -1.0, 2.0, 0.999999] {
            h32.add(x as f32);
            h64.add_f64(x);
        }
        assert_eq!(h32.bins(), h64.bins());
        assert_eq!((h32.under, h32.over), (h64.under, h64.over));
        // f64 moments keep precision a f32 cast would drop
        let mut h = Histogram::new(0.0, 10_000_000.0, 10);
        let x = 1_234_567.891_011_f64; // not representable in f32
        h.add_f64(x);
        assert_eq!(h.mean(), x);
    }

    #[test]
    fn edge_bin_inclusion() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.add(0.5);
        assert_eq!(h.bins()[1], 1);
        h.add(0.49999);
        assert_eq!(h.bins()[0], 1);
    }

    // pins the documented interpolation rule of Histogram::percentile
    #[test]
    fn percentile_edge_cases() {
        // empty histogram → NaN (mapped to JSON null by the serving tier)
        let h = Histogram::new(0.0, 10.0, 10);
        assert!(h.percentile(50.0).is_nan());
        // only out-of-range samples → NaN: under/over mass carries no
        // position, so percentiles are defined over in-range mass only
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-5.0);
        h.add(20.0);
        assert!(h.percentile(50.0).is_nan());
        // single sample in bin [3, 4): p=0 → left edge, p=50 → center,
        // p=100 → right edge of the occupied bin
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(3.2);
        assert_eq!(h.percentile(0.0), 3.0);
        assert_eq!(h.percentile(50.0), 3.5);
        assert_eq!(h.percentile(100.0), 4.0);
        // p clamps to [0, 100]: out-of-domain p answers at the data's
        // edges, never the histogram's hi bound (the pre-fix fall-through
        // returned hi = 10.0 for p > 100)
        assert_eq!(h.percentile(-10.0), 3.0);
        assert_eq!(h.percentile(150.0), 4.0);
    }

    #[test]
    fn latency_histogram_two_recording_paths() {
        // f64 path: keeps sub-µs precision in min and mean
        let mut l = LatencyHistogram::new(10_000_000.0, 20_000);
        assert_eq!(l.min_us(), 0.0, "empty → 0 by contract");
        assert!(l.percentile_us(50.0).is_nan());
        let x = 1_234_567.891_011_f64; // not representable in f32
        l.record_us(x);
        assert_eq!(l.min_us(), x);
        assert_eq!(l.mean_us(), x);
        assert_eq!(l.count(), 1);
        // f32 path matches Histogram::add binning exactly
        let mut a = LatencyHistogram::new(60_000_000.0, 12_000);
        let mut b = Histogram::new(0.0, 60_000_000.0, 12_000);
        for us in [100.0f32, 5_000.0, 4_999.9, 59_999_999.0] {
            a.record_us_f32(us);
            b.add(us);
        }
        assert_eq!(a.percentile_us(50.0), b.percentile(50.0));
        assert_eq!(a.mean_us(), b.mean());
    }
}
