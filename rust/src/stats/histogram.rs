//! Fixed-bin histogram + streaming percentile helpers.
//!
//! Used by: Fig. 4 (PS output distribution), coordinator latency metrics,
//! and the Monte-Carlo sensitivity harness.

/// Fixed-range, fixed-bin histogram over f32 samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f32,
    hi: f32,
    bins: Vec<u64>,
    /// samples outside [lo, hi)
    pub under: u64,
    pub over: u64,
    count: u64,
    sum: f64,
    sum2: f64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Self {
            lo,
            hi,
            bins: vec![0; n_bins],
            under: 0,
            over: 0,
            count: 0,
            sum: 0.0,
            sum2: 0.0,
        }
    }

    pub fn add(&mut self, x: f32) {
        self.count += 1;
        self.sum += x as f64;
        self.sum2 += (x as f64) * (x as f64);
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let t = (x - self.lo) / (self.hi - self.lo);
            let idx = ((t * self.bins.len() as f32) as usize).min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    /// [`Histogram::add`] with the range comparison and bin index computed
    /// in `f64` — for µs-scale latency samples whose f32 rounding would
    /// lose sub-µs precision over long runs.  Binning semantics are
    /// unchanged: `[lo, hi)` in range, `x >= hi` counts as `over`.
    pub fn add_f64(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.sum2 += x * x;
        let (lo, hi) = (self.lo as f64, self.hi as f64);
        if x < lo {
            self.under += 1;
        } else if x >= hi {
            self.over += 1;
        } else {
            let t = (x - lo) / (hi - lo);
            let idx = ((t * self.bins.len() as f64) as usize).min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f32>) {
        for x in xs {
            self.add(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum2 / self.count as f64 - m * m).max(0.0).sqrt()
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Bin centers, aligned with `bins()`.
    pub fn centers(&self) -> Vec<f32> {
        let w = (self.hi - self.lo) / self.bins.len() as f32;
        (0..self.bins.len())
            .map(|i| self.lo + w * (i as f32 + 0.5))
            .collect()
    }

    /// Percentile over binned data (linear within bins); p in [0, 100].
    pub fn percentile(&self, p: f64) -> f32 {
        if self.count == 0 {
            return f32::NAN;
        }
        let in_range: u64 = self.bins.iter().sum();
        if in_range == 0 {
            return f32::NAN;
        }
        let target = (p / 100.0 * in_range as f64).max(0.0);
        let mut acc = 0.0;
        let w = (self.hi - self.lo) / self.bins.len() as f32;
        for (i, &b) in self.bins.iter().enumerate() {
            let next = acc + b as f64;
            if next >= target && b > 0 {
                let frac = if b == 0 { 0.0 } else { (target - acc) / b as f64 };
                return self.lo + w * (i as f32 + frac as f32);
            }
            acc = next;
        }
        self.hi
    }

    /// Normalized mass per bin (sums to 1 over in-range samples).
    pub fn density(&self) -> Vec<f64> {
        let total: u64 = self.bins.iter().sum();
        if total == 0 {
            return vec![0.0; self.bins.len()];
        }
        self.bins.iter().map(|&b| b as f64 / total as f64).collect()
    }

    /// Compact ASCII rendering (for CLI table/figure output).
    pub fn render(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let centers = self.centers();
        let mut out = String::new();
        for (c, &b) in centers.iter().zip(&self.bins) {
            let bar = (b as f64 / max as f64 * width as f64).round() as usize;
            out.push_str(&format!("{c:+.3} | {:<width$} {b}\n", "#".repeat(bar)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_binning() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.extend([0.05, 0.15, 0.15, 0.95, -1.0, 2.0]);
        assert_eq!(h.bins()[0], 1);
        assert_eq!(h.bins()[1], 2);
        assert_eq!(h.bins()[9], 1);
        assert_eq!(h.under, 1);
        assert_eq!(h.over, 1);
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn moments() {
        let mut h = Histogram::new(-10.0, 10.0, 100);
        h.extend([1.0, 2.0, 3.0]);
        assert!((h.mean() - 2.0).abs() < 1e-9);
        assert!((h.std() - (2.0f64 / 3.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn percentile_uniform() {
        let mut h = Histogram::new(0.0, 1.0, 100);
        for i in 0..10_000 {
            h.add(i as f32 / 10_000.0);
        }
        assert!((h.percentile(50.0) - 0.5).abs() < 0.02);
        assert!((h.percentile(99.0) - 0.99).abs() < 0.02);
    }

    #[test]
    fn density_sums_to_one() {
        let mut h = Histogram::new(-1.0, 1.0, 7);
        h.extend((0..100).map(|i| (i as f32 / 50.0) - 1.0 + 1e-4));
        let d: f64 = h.density().iter().sum();
        assert!((d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn add_f64_matches_add_binning_and_keeps_precision() {
        let mut h32 = Histogram::new(0.0, 1.0, 10);
        let mut h64 = Histogram::new(0.0, 1.0, 10);
        for x in [0.05f64, 0.15, 0.95, -1.0, 2.0, 0.999999] {
            h32.add(x as f32);
            h64.add_f64(x);
        }
        assert_eq!(h32.bins(), h64.bins());
        assert_eq!((h32.under, h32.over), (h64.under, h64.over));
        // f64 moments keep precision a f32 cast would drop
        let mut h = Histogram::new(0.0, 10_000_000.0, 10);
        let x = 1_234_567.891_011_f64; // not representable in f32
        h.add_f64(x);
        assert_eq!(h.mean(), x);
    }

    #[test]
    fn edge_bin_inclusion() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.add(0.5);
        assert_eq!(h.bins()[1], 1);
        h.add(0.49999);
        assert_eq!(h.bins()[0], 1);
    }
}
