//! Statistics substrate: counter-based RNG (bit-identical with python),
//! histograms, percentile sketches, Monte-Carlo drivers.

pub mod histogram;
pub mod rng;

pub use histogram::{Histogram, LatencyHistogram};
pub use rng::{mix32, uniform01, CounterRng};
