//! Counter-based RNG — bit-identical with `python/compile/kernels/rng.py`.
//!
//! Every stochastic MTJ conversion event is keyed by a `(seed, counter)`
//! pair hashed with the 32-bit lowbias avalanche mix.  Identical bits on
//! the python (L1/L2) and Rust (L3 functional simulator) sides make the
//! whole stochastic MVM a pure, replayable function — asserted by the
//! known-answer tests below, which mirror `python/tests/test_rng.py`.

const M1: u32 = 0x7feb_352d;
const M2: u32 = 0x846c_a68b;
const GOLDEN: u32 = 0x9e37_79b9;

/// 32-bit avalanche mix (lowbias32 by E. Wellons).
#[inline(always)]
pub fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(M1);
    x ^= x >> 15;
    x = x.wrapping_mul(M2);
    x ^= x >> 16;
    x
}

/// Hash a seed with an event counter → u32 (python `rng.hash_counter`).
#[inline(always)]
pub fn hash_counter(seed: u32, counter: u32) -> u32 {
    mix32(counter ^ mix32(seed ^ GOLDEN))
}

/// U[0,1) f32 from (seed, counter) using the top 24 bits — exactly
/// representable in f32, so python and Rust produce the same float.
#[inline(always)]
pub fn uniform01(seed: u32, counter: u32) -> f32 {
    (hash_counter(seed, counter) >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// Convenience stateful wrapper: a seeded stream with a pre-mixed seed
/// (hoists the inner `mix32(seed ^ GOLDEN)` out of hot loops).
#[derive(Debug, Clone, Copy)]
pub struct CounterRng {
    mixed_seed: u32,
}

impl CounterRng {
    pub fn new(seed: u32) -> Self {
        Self { mixed_seed: mix32(seed ^ GOLDEN) }
    }

    #[inline(always)]
    pub fn uniform(&self, counter: u32) -> f32 {
        (mix32(counter ^ self.mixed_seed) >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Raw 24-bit draw (the integer whose scaling yields `uniform`);
    /// `draw24(c) < ceil(p·2²⁴)` is exactly equivalent to
    /// `uniform(c) < p` for f32 `p` — the branch used by the hot
    /// stochastic-MTJ path to skip the float conversion per sample.
    #[inline(always)]
    pub fn draw24(&self, counter: u32) -> u32 {
        mix32(counter ^ self.mixed_seed) >> 8
    }

    /// Uniform in [lo, hi).
    #[inline(always)]
    pub fn uniform_in(&self, counter: u32, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform(counter)
    }

    /// Standard normal via Box-Muller over two counters (2k, 2k+1).
    pub fn normal(&self, counter_pair: u32) -> f32 {
        let u1 = self
            .uniform(counter_pair.wrapping_mul(2))
            .max(f32::MIN_POSITIVE);
        let u2 = self.uniform(counter_pair.wrapping_mul(2).wrapping_add(1));
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mirrors python/tests/test_rng.py::KAT — cross-language contract.
    #[test]
    fn known_answer_vectors() {
        let counters = [0u32, 1, 2, 3, 1000, 1 << 31, u32::MAX];
        let expect_seed0: [u32; 7] = [
            0xae6f80f1, 0xa07c7a97, 0x0e77ceb6, 0x7e1bd18e, 0xd6663a0c,
            0x182be288, 0x5f3ddee1,
        ];
        let expect_seed1: [u32; 7] = [
            0x8e374fe0, 0xa290702b, 0xe80e9316, 0x1d6d21d7, 0xb5be8342,
            0xf3bf5257, 0xca4d4754,
        ];
        let expect_beef: [u32; 7] = [
            0x754afac9, 0x551c946e, 0x07cd45f7, 0x5a2886e3, 0x36964039,
            0xa8862eea, 0x94fb713e,
        ];
        for (i, &c) in counters.iter().enumerate() {
            assert_eq!(hash_counter(0, c), expect_seed0[i], "seed 0 counter {c}");
            assert_eq!(hash_counter(1, c), expect_seed1[i], "seed 1 counter {c}");
            assert_eq!(
                hash_counter(0xdead_beef, c),
                expect_beef[i],
                "seed beef counter {c}"
            );
        }
    }

    #[test]
    fn uniform_matches_python_values() {
        // First three uniforms for seed 0 from python test run.
        let got: Vec<f32> = [0u32, 1, 2].iter().map(|&c| uniform01(0, c)).collect();
        let want = [0.6813888549804688, 0.6268993616104126, 0.05651557445526123];
        for (g, w) in got.iter().zip(want) {
            assert_eq!(*g, w as f32);
        }
    }

    #[test]
    fn uniform_range_and_grid() {
        for c in 0..10_000u32 {
            let u = uniform01(7, c);
            assert!((0.0..1.0).contains(&u));
            let scaled = u * (1u32 << 24) as f32;
            assert_eq!(scaled, scaled.round(), "multiple of 2^-24");
        }
    }

    #[test]
    fn uniform_mean_variance() {
        let n = 1 << 16;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for c in 0..n {
            let u = uniform01(3, c) as f64;
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn counter_rng_matches_free_functions() {
        let r = CounterRng::new(42);
        for c in [0u32, 5, 999, u32::MAX] {
            assert_eq!(r.uniform(c), uniform01(42, c));
        }
    }

    #[test]
    fn normal_moments() {
        let r = CounterRng::new(11);
        let n = 40_000u32;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for c in 0..n {
            let x = r.normal(c) as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn avalanche() {
        let x = 123_456_789u32;
        let base = mix32(x);
        let mut total = 0u32;
        for bit in 0..32 {
            total += (base ^ mix32(x ^ (1 << bit))).count_ones();
        }
        let avg = total as f32 / 32.0;
        assert!((10.0..22.0).contains(&avg), "avalanche {avg}");
    }
}
