//! In-tree infrastructure: this environment is fully offline, so the
//! usual ecosystem crates are replaced by small, tested local versions.
//!
//! * [`json`]  — JSON parser/serializer (manifest.json, results output);
//! * [`cli`]   — flag parsing for `stox-cli` and the examples;
//! * [`pool`]  — scoped thread-pool fan-out (Monte-Carlo, batch serving);
//! * [`bench`] — measurement harness used by `rust/benches/*`
//!   (criterion-style warmup + timed iterations + percentile report);
//! * [`prop`]  — tiny property-test driver on top of [`crate::stats::rng`].

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
