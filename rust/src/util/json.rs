//! Minimal JSON: full parser (RFC 8259 subset sufficient for our
//! artifacts) + serializer.  No external dependencies.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- accessors ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["spec", "stox", "a_bits"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_f64().map(|n| n as u32)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---------- constructors ----------
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---------- parsing ----------
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing data at byte {}", p.i);
        Ok(v)
    }

    // ---------- serialization ----------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek()? == c,
            "expected '{}' at byte {}, found '{}'",
            c as char,
            self.i,
            self.peek()? as char
        );
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u");
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.at(&["c"]).unwrap().as_str().unwrap(), "x");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,true,null,"s"],"m":{"n":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"spec":{"stox":{"a_bits":4,"alpha":4.0},"first_layer":"qf","layer_samples":null},"layers":[{"name":"conv1","kh":3,"stochastic":true}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.at(&["spec", "stox", "a_bits"]).unwrap().as_u32(), Some(4));
        assert_eq!(
            j.at(&["layers"]).unwrap().as_arr().unwrap()[0]
                .get("stochastic")
                .unwrap()
                .as_bool(),
            Some(true)
        );
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "café é");
    }

    #[test]
    fn serialize_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
