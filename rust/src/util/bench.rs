//! Measurement harness for `rust/benches/*` (criterion-style: warmup,
//! timed iterations, mean/p50/p95 report).  Each bench target is a plain
//! `fn main()` (`harness = false`).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Run `f` repeatedly: warm up for `warmup`, then measure for `measure`
/// (at least 10 iterations), and print the report line.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    // warmup
    let t0 = Instant::now();
    while t0.elapsed() < warmup {
        black_box_unit(&mut f);
    }
    // measure
    let mut samples = Vec::new();
    let t1 = Instant::now();
    while t1.elapsed() < measure || samples.len() < 10 {
        let s = Instant::now();
        black_box_unit(&mut f);
        samples.push(s.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        p95: samples[samples.len() * 95 / 100],
        min: samples[0],
    };
    println!("{r}");
    r
}

/// Convenience with default windows (0.3 s warmup / 1 s measure).
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_millis(300), Duration::from_secs(1), f)
}

#[inline]
fn black_box_unit<F: FnMut()>(f: &mut F) {
    f();
    std::hint::black_box(());
}

/// Re-export of `std::hint::black_box` for bench bodies.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_sane_stats() {
        let r = bench(
            "noop",
            Duration::from_millis(5),
            Duration::from_millis(20),
            || {},
        );
        assert!(r.iters >= 10);
        assert!(r.p50 <= r.p95);
        assert!(r.min <= r.mean);
    }
}
