//! Measurement harness for `rust/benches/*` (criterion-style: warmup,
//! timed iterations, mean/p50/p95 report).  Each bench target is a plain
//! `fn main()` (`harness = false`).
//!
//! [`BenchSuite`] additionally records every case and emits a
//! machine-readable `BENCH_<name>.json` artifact (median ns/op per case)
//! — the perf-trajectory format CI uploads per run and EXPERIMENTS.md
//! quotes (set `STOX_BENCH_DIR` to redirect the output directory).

use crate::util::json::Json;
use std::path::PathBuf;
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Run `f` repeatedly: warm up for `warmup`, then measure for `measure`
/// (at least 10 iterations), and print the report line.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    // warmup
    let t0 = Instant::now();
    while t0.elapsed() < warmup {
        black_box_unit(&mut f);
    }
    // measure
    let mut samples = Vec::new();
    let t1 = Instant::now();
    while t1.elapsed() < measure || samples.len() < 10 {
        let s = Instant::now();
        black_box_unit(&mut f);
        samples.push(s.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        p95: samples[samples.len() * 95 / 100],
        min: samples[0],
    };
    println!("{r}");
    r
}

/// Convenience with default windows (0.3 s warmup / 1 s measure).
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_millis(300), Duration::from_secs(1), f)
}

#[inline]
fn black_box_unit<F: FnMut()>(f: &mut F) {
    f();
    std::hint::black_box(());
}

/// Re-export of `std::hint::black_box` for bench bodies.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A named collection of bench cases that serializes to
/// `BENCH_<name>.json` — median/mean/p95/min ns per case, in run order.
pub struct BenchSuite {
    name: String,
    results: Vec<BenchResult>,
    /// per-case extra fields merged into the case object (the serving
    /// sweep's offered/achieved-rps and SLO metadata ride here)
    extras: Vec<Vec<(String, Json)>>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), results: Vec::new(), extras: Vec::new() }
    }

    /// Run and record a case with the default windows (see [`quick`]);
    /// returns its index into [`BenchSuite::median_ns`].
    pub fn quick<F: FnMut()>(&mut self, case: &str, f: F) -> usize {
        let r = quick(case, f);
        self.record(r)
    }

    /// Record an externally measured case (custom windows); returns its
    /// index into [`BenchSuite::median_ns`].
    pub fn record(&mut self, r: BenchResult) -> usize {
        self.record_with(r, Vec::new())
    }

    /// Record a case carrying extra per-case JSON fields (e.g. the
    /// serving sweep's `offered_rps`/`achieved_rps`/SLO columns); the
    /// extras are merged into the case object after the standard timing
    /// fields, so a case cannot lose `median_ns` et al. to a collision.
    pub fn record_with(&mut self, r: BenchResult, extras: Vec<(String, Json)>) -> usize {
        self.results.push(r);
        self.extras.push(extras);
        self.results.len() - 1
    }

    /// Median ns/op of a recorded case (by [`BenchSuite::quick`] index).
    pub fn median_ns(&self, idx: usize) -> f64 {
        self.results[idx].p50.as_nanos() as f64
    }

    fn to_json(&self) -> Json {
        let cases: Vec<Json> = self
            .results
            .iter()
            .zip(&self.extras)
            .map(|(r, extras)| {
                let mut m: std::collections::BTreeMap<String, Json> = extras
                    .iter()
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect();
                m.insert("name".into(), Json::Str(r.name.clone()));
                m.insert("iters".into(), Json::Num(r.iters as f64));
                m.insert("median_ns".into(), Json::Num(r.p50.as_nanos() as f64));
                m.insert("mean_ns".into(), Json::Num(r.mean.as_nanos() as f64));
                m.insert("p95_ns".into(), Json::Num(r.p95.as_nanos() as f64));
                m.insert("min_ns".into(), Json::Num(r.min.as_nanos() as f64));
                Json::Obj(m)
            })
            .collect();
        Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("cases", Json::Arr(cases)),
        ])
    }

    /// Write `BENCH_<name>.json` into `STOX_BENCH_DIR` (default: the
    /// current directory) and return the path.
    pub fn write_json(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("STOX_BENCH_DIR").unwrap_or_else(|_| ".".into());
        self.write_json_to(std::path::Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into an explicit directory (the
    /// env-independent path [`BenchSuite::write_json`] delegates to).
    pub fn write_json_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        println!("bench artifact: {}", path.display());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_writes_json_artifact() {
        // write_json_to avoids mutating process env (set_var races with
        // parallel tests reading e.g. STOX_THREADS via getenv)
        let dir = std::env::temp_dir().join("stox_bench_suite_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut suite = BenchSuite::new("unittest");
        let r = bench(
            "noop-case",
            Duration::from_millis(2),
            Duration::from_millis(10),
            || {},
        );
        let idx = suite.record(r);
        assert!(suite.median_ns(idx) >= 0.0);
        let path = suite.write_json_to(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").and_then(|b| b.as_str()), Some("unittest"));
        let cases = j.get("cases").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(
            cases[0].get("name").and_then(|n| n.as_str()),
            Some("noop-case")
        );
        assert!(cases[0].get("median_ns").and_then(|m| m.as_f64()).is_some());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn record_with_merges_extras_without_clobbering_timing_fields() {
        let dir = std::env::temp_dir().join("stox_bench_extras_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut suite = BenchSuite::new("unittest_extras");
        let r = bench(
            "rate-100",
            Duration::from_millis(2),
            Duration::from_millis(10),
            || {},
        );
        suite.record_with(
            r,
            vec![
                ("offered_rps".into(), Json::Num(100.0)),
                ("median_ns".into(), Json::Num(-1.0)), // must not clobber
            ],
        );
        let path = suite.write_json_to(&dir).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let case = &j.get("cases").and_then(|c| c.as_arr()).unwrap()[0];
        assert_eq!(case.get("offered_rps").and_then(|v| v.as_f64()), Some(100.0));
        let med = case.get("median_ns").and_then(|v| v.as_f64()).unwrap();
        assert!(med >= 0.0, "timing field wins over a colliding extra");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn produces_sane_stats() {
        let r = bench(
            "noop",
            Duration::from_millis(5),
            Duration::from_millis(20),
            || {},
        );
        assert!(r.iters >= 10);
        assert!(r.p50 <= r.p95);
        assert!(r.min <= r.mean);
    }
}
