//! Tiny property-test driver: deterministic random cases from the shared
//! counter RNG, with failing-case reporting (offline stand-in for
//! proptest).

use crate::stats::rng::CounterRng;

/// A source of random test values for one case.
pub struct Gen {
    rng: CounterRng,
    counter: u32,
}

impl Gen {
    pub fn new(case: u32, seed: u32) -> Self {
        Self {
            rng: CounterRng::new(seed ^ case.wrapping_mul(0x9e37_79b9)),
            counter: 0,
        }
    }

    fn next_u(&mut self) -> f32 {
        let u = self.rng.uniform(self.counter);
        self.counter = self.counter.wrapping_add(1);
        u
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_u()
    }

    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        lo + (self.next_u() * ((hi_incl - lo + 1) as f32)) as usize % (hi_incl - lo + 1)
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u() < 0.5
    }
}

/// Run `cases` random cases of `prop`; panics with the failing case index
/// on the first failure (re-run that case by seeding `Gen::new(i, seed)`).
pub fn check(name: &str, cases: u32, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let mut g = Gen::new(case, 0xC0FF_EE00);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed on case {case}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(0, 1);
        for _ in 0..1000 {
            let x = g.f32_in(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
            let n = g.usize_in(1, 5);
            assert!((1..=5).contains(&n));
        }
    }

    #[test]
    fn deterministic_per_case() {
        let a: Vec<f32> = {
            let mut g = Gen::new(7, 1);
            g.vec_f32(5, 0.0, 1.0)
        };
        let b: Vec<f32> = {
            let mut g = Gen::new(7, 1);
            g.vec_f32(5, 0.0, 1.0)
        };
        assert_eq!(a, b);
    }

    #[test]
    fn check_passes() {
        check("trivial", 25, |g| {
            let x = g.f32_in(0.0, 1.0);
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic]
    fn check_reports_failure() {
        check("fails", 5, |_| Err("boom".into()));
    }
}
