//! Tiny flag parser: `--key value`, `--flag`, positional subcommand.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]); first non-flag token
    /// becomes the subcommand, later ones positional.
    pub fn parse_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn string(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --requests 512 --native --batch=8");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize("requests", 0), 512);
        assert_eq!(a.usize("batch", 0), 8);
        assert!(a.flag("native"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize("n", 7), 7);
        assert_eq!(a.f32("alpha", 4.0), 4.0);
        assert_eq!(a.string("s", "d"), "d");
    }

    #[test]
    fn boolean_followed_by_flag() {
        let a = parse("cmd --native --requests 5");
        assert!(a.flag("native"));
        assert_eq!(a.usize("requests", 0), 5);
    }

    #[test]
    fn positional() {
        let a = parse("cmd pos1 pos2 --k v");
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.get("k"), Some("v"));
    }
}
