//! Scoped thread fan-out (offline stand-in for rayon's `par_iter`).

/// Map `f` over `0..n` with up to `threads` OS threads; returns results in
/// index order.  `f` must be `Sync` (called concurrently by reference).
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_scratch(n, threads, || (), |_scratch, i| f(i))
}

/// [`par_map`] with per-worker scratch state: each worker thread calls
/// `init` exactly once and threads the resulting value (mutably) through
/// every task it claims — the hook hot kernels use to reuse their
/// decomposition/accumulator buffers across tasks instead of allocating
/// per task.  Results are returned in index order and are identical to the
/// sequential `(0..n).map(...)` evaluation whenever `f` ignores the
/// scratch's history (the kernel scratches are overwritten per task).
pub fn par_map_scratch<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        let mut scratch = init();
        return (0..n).map(|i| f(&mut scratch, i)).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(&mut scratch, i);
                    **slots[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Number of worker threads to default to (`STOX_THREADS` overrides;
/// `STOX_THREADS=1` forces the sequential paths — used by the perf
/// harness to measure fan-out gains).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("STOX_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let v = par_map(100, 8, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(par_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        par_map(16, 4, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(10));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }

    #[test]
    fn scratch_is_reused_within_a_worker_and_results_stay_ordered() {
        // each worker gets one scratch Vec; tasks grow it and report its
        // address stability by pushing into it — results must still land
        // in index order regardless of which worker ran them
        let v = par_map_scratch(
            64,
            4,
            Vec::<usize>::new,
            |scratch, i| {
                scratch.push(i);
                i * 3
            },
        );
        assert_eq!(v, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn scratch_sequential_path_single_init() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let inits = AtomicUsize::new(0);
        let v = par_map_scratch(
            5,
            1,
            || inits.fetch_add(1, Ordering::SeqCst),
            |s, i| *s + i,
        );
        assert_eq!(inits.load(Ordering::SeqCst), 1);
        assert_eq!(v, vec![0, 1, 2, 3, 4]);
    }
}
