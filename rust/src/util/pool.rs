//! Scoped thread fan-out (offline stand-in for rayon's `par_iter`).

/// Map `f` over `0..n` with up to `threads` OS threads; returns results in
/// index order.  `f` must be `Sync` (called concurrently by reference).
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_scratch(n, threads, || (), |_scratch, i| f(i))
}

/// [`par_map`] with per-worker scratch state: each worker thread calls
/// `init` exactly once and threads the resulting value (mutably) through
/// every task it claims — the hook hot kernels use to reuse their
/// decomposition/accumulator buffers across tasks instead of allocating
/// per task.  Results are returned in index order and are identical to the
/// sequential `(0..n).map(...)` evaluation whenever `f` ignores the
/// scratch's history (the kernel scratches are overwritten per task).
pub fn par_map_scratch<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        let mut scratch = init();
        return (0..n).map(|i| f(&mut scratch, i)).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    // Lock-free result placement: each index is claimed by exactly one
    // worker (the `fetch_add` below hands every index out once), so the
    // writes through `slots` are disjoint and the `thread::scope` join
    // publishes them to the main thread.  No per-slot Mutex on the
    // completion path — this fan-out is the inner loop of the software
    // pipeline and the ksplit kernel.
    struct Slots<T>(*mut Option<T>);
    unsafe impl<T: Send> Sync for Slots<T> {}
    let slots = Slots(out.as_mut_ptr());
    // A panicking task must not take the whole process down with the
    // opaque "a scoped thread panicked" message: each task runs under
    // `catch_unwind`, the first panic poisons the pool (workers stop
    // claiming new indices), and the join path re-raises one loud panic
    // naming the task index.  The scratch of a panicked worker is never
    // reused — the worker exits its claim loop immediately.
    let poisoned = std::sync::atomic::AtomicBool::new(false);
    let first_panic: std::sync::Mutex<Option<(usize, String)>> = std::sync::Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    if poisoned.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        f(&mut scratch, i)
                    })) {
                        // SAFETY: i < n is in bounds and owned solely by
                        // this worker; the scope join orders the write
                        // before the main thread reads `out`.
                        Ok(v) => unsafe { *slots.0.add(i) = Some(v) },
                        Err(payload) => {
                            let mut g = first_panic.lock().unwrap();
                            if g.is_none() {
                                *g = Some((i, panic_message(payload.as_ref())));
                            }
                            drop(g);
                            poisoned.store(true, std::sync::atomic::Ordering::Relaxed);
                            break;
                        }
                    }
                }
            });
        }
    });
    if let Some((i, msg)) = first_panic.into_inner().unwrap() {
        panic!("par_map_scratch: task {i} panicked: {msg}");
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Best-effort extraction of a panic payload's message (the `&str` /
/// `String` payloads `panic!` produces; anything else is labeled).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Parse a `STOX_THREADS` override: a non-negative integer, where `0`
/// clamps to `1` (i.e. "no fan-out" — same as `STOX_THREADS=1`, kept so
/// scripted sweeps can use 0 as their sequential leg).  Anything
/// unparseable is an error carrying the offending value — perf runs must
/// not quietly fall back to `available_parallelism` and measure the wrong
/// thread count.
pub fn parse_stox_threads(v: &str) -> crate::Result<usize> {
    let n: usize = v.trim().parse().map_err(|_| {
        anyhow::anyhow!(
            "invalid STOX_THREADS value '{v}': expected a non-negative integer \
             (0 and 1 both force the sequential paths)"
        )
    })?;
    Ok(n.max(1))
}

/// Number of worker threads to default to (`STOX_THREADS` overrides;
/// `STOX_THREADS=1` — or `0`, which clamps to 1 — forces the sequential
/// paths, used by the perf harness to measure fan-out gains).
///
/// Panics on an unparseable `STOX_THREADS` (see [`parse_stox_threads`]).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("STOX_THREADS") {
        return parse_stox_threads(&v).unwrap();
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let v = par_map(100, 8, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(par_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        par_map(16, 4, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(10));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }

    #[test]
    fn scratch_is_reused_within_a_worker_and_results_stay_ordered() {
        // each worker gets one scratch Vec; tasks grow it and report its
        // address stability by pushing into it — results must still land
        // in index order regardless of which worker ran them
        let v = par_map_scratch(
            64,
            4,
            Vec::<usize>::new,
            |scratch, i| {
                scratch.push(i);
                i * 3
            },
        );
        assert_eq!(v, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn stox_threads_parses_and_clamps_zero() {
        // pure parser — no env mutation (parallel tests read STOX_THREADS)
        assert_eq!(parse_stox_threads("4").unwrap(), 4);
        assert_eq!(parse_stox_threads(" 2 ").unwrap(), 2);
        assert_eq!(parse_stox_threads("1").unwrap(), 1);
        // 0 clamps to the sequential path rather than erroring
        assert_eq!(parse_stox_threads("0").unwrap(), 1);
    }

    #[test]
    fn stox_threads_fails_loudly_with_offending_value() {
        for bad in ["", "four", "-1", "2.5", "0x8"] {
            let err = parse_stox_threads(bad).unwrap_err().to_string();
            assert!(err.contains("STOX_THREADS"), "{err}");
            assert!(err.contains(bad), "error must carry the value: {err}");
        }
    }

    #[test]
    #[should_panic(expected = "par_map_scratch: task 7 panicked: boom at 7")]
    fn panicking_task_fails_loudly_with_its_index() {
        par_map(16, 4, |i| {
            if i == 7 {
                panic!("boom at 7");
            }
            i
        });
    }

    #[test]
    fn panic_in_one_task_does_not_corrupt_other_results() {
        // the poison flag stops the pool promptly, but every result
        // produced *before* the panic must have landed in its own slot —
        // verified by catching the re-raised panic and checking no other
        // task observed a torn write (tasks record their writes here)
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(|| {
            par_map(32, 4, |i| {
                if i == 3 {
                    panic!("die");
                }
                done.fetch_add(1, Ordering::SeqCst);
                i
            })
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("task 3 panicked: die"), "{msg}");
        assert!(done.load(Ordering::SeqCst) < 32, "task 3 never completed");
    }

    #[test]
    fn scratch_sequential_path_single_init() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let inits = AtomicUsize::new(0);
        let v = par_map_scratch(
            5,
            1,
            || inits.fetch_add(1, Ordering::SeqCst),
            |s, i| *s + i,
        );
        assert_eq!(inits.load(Ordering::SeqCst), 1);
        assert_eq!(v, vec![0, 1, 2, 3, 4]);
    }
}
