//! Scoped thread fan-out (offline stand-in for rayon's `par_iter`).

/// Map `f` over `0..n` with up to `threads` OS threads; returns results in
/// index order.  `f` must be `Sync` (called concurrently by reference).
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Number of worker threads to default to (`STOX_THREADS` overrides;
/// `STOX_THREADS=1` forces the sequential paths — used by the perf
/// harness to measure fan-out gains).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("STOX_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let v = par_map(100, 8, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(par_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        par_map(16, 4, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(10));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }
}
