//! Native hardware-exact inference: the StoX ResNet forward pass running
//! entirely on the Rust crossbar functional model ([`crate::imc`]).
//!
//! Mirrors `python/compile/model.py` layer-for-layer and seed-for-seed
//! (same `_layer_seed` derivation, same weight normalization, same BN),
//! so the same checkpoint produces matching predictions on both sides —
//! the cross-layer validation behind `rust/tests/parity.rs`.  It is also
//! what the sensitivity analysis (Fig. 5) and the Fig. 4 PS-distribution
//! collection run on.
//!
//! # Weight programming is shared, converter dispatch is per-view
//!
//! Programming a checkpoint onto crossbars ([`StoxMvm::program`]:
//! quantize → slice → partition) depends only on the weights and the
//! [`StoxConfig`] precision — never on the PS converter, which is applied
//! per column slice at run time.  The programmed crossbars are therefore
//! held behind `Arc` and shared: [`NativeModel::load_with_config`]
//! programs once per precision tag, and
//! [`NativeModel::share_with_converter_spec`] derives per-converter views
//! that reuse the same programmed arrays — the `sweep --model` fast path
//! (one load + program per tag, N converter specs for free).

use super::weights::{Manifest, WeightStore};
use crate::imc::{
    decompose_activations, im2col, ConvArena, PsConvert, PsConverterSpec, StoxConfig, StoxMvm,
};
use crate::obs::{span, CounterRegistry, TraceLevel};
use crate::stats::rng::mix32;
use std::sync::Arc;

/// One batch-norm affine (folded running stats).
#[derive(Debug, Clone)]
struct BnFold {
    scale: Vec<f32>, // gamma / sqrt(var + eps)
    shift: Vec<f32>, // beta - mean * scale
}

impl BnFold {
    fn new(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32]) -> Self {
        let scale: Vec<f32> = gamma
            .iter()
            .zip(var)
            .map(|(g, v)| g / (v + 1e-5).sqrt())
            .collect();
        let shift = beta
            .iter()
            .zip(mean)
            .zip(&scale)
            .map(|((b, m), s)| b - m * s)
            .collect();
        Self { scale, shift }
    }

    fn apply(&self, x: &mut [f32], channels: usize) {
        for (i, v) in x.iter_mut().enumerate() {
            let c = i % channels;
            *v = *v * self.scale[c] + self.shift[c];
        }
    }
}

struct ConvOp {
    /// programmed crossbars (None → full-precision first layer); `Arc` so
    /// per-converter model views share one programming pass
    mvm: Option<Arc<StoxMvm>>,
    raw_w: Vec<f32>, // [kh,kw,cin,cout] (normalized for stox; raw for fp)
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    /// converter spec (kept so shallow clones / overrides can rebuild)
    conv_spec: PsConverterSpec,
    /// built converter — one registry construction per layer, reused for
    /// every forward pass
    converter: Box<dyn PsConvert>,
    layer_idx: usize,
}

/// Loaded spec + programmed layers.
pub struct NativeModel {
    pub num_classes: usize,
    pub image_size: usize,
    pub in_channels: usize,
    first_qf: bool,
    conv1: ConvOp,
    bn1: BnFold,
    /// blocks\[stage\]\[block\] = (conv1, bn1, conv2, bn2, stride)
    blocks: Vec<Vec<(ConvOp, BnFold, ConvOp, BnFold, usize)>>,
    fc_w: Vec<f32>, // [w3, classes]
    fc_b: Vec<f32>,
    w3: usize,
    /// PS-distribution probe: when set, every normalized PS of stochastic
    /// layers is recorded into this histogram (Fig. 4 collection).
    pub ps_probe: Option<std::sync::Mutex<crate::stats::Histogram>>,
    /// Run crossbar-mapped convs through the fused digit-domain path
    /// (decompose each input pixel once, no im2col patch matrix) — on by
    /// default; [`NativeModel::set_fused_conv`] keeps the legacy im2col
    /// path reachable for A/B benchmarking (`benches/pipeline.rs`).
    use_fused_conv: bool,
    /// Layer-pipelined batch execution (on by default): images fan out to
    /// workers that each run *all* layers of their image, so layer k of
    /// image i overlaps layer k−1 of image i+1 — the software realization
    /// of the Fig. 8 inter-layer pipeline.  Bit-identical to the
    /// sequential whole-batch forward (the RNG counter contract keys
    /// every draw by absolute patch index); [`NativeModel::set_pipeline`]
    /// keeps the sequential path reachable for A/B benchmarking.
    use_pipeline: bool,
}

/// Mirrors `model._layer_seed`: independent stream per (step, layer).
pub fn layer_seed(step_seed: u32, layer_idx: u32) -> u32 {
    mix32(step_seed ^ 0xA511_E9B3u32.wrapping_add(layer_idx))
}

fn normalize_weights(w: &[f32]) -> Vec<f32> {
    let scale = w.iter().fold(0.0f32, |m, v| m.max(v.abs())) + 1e-8;
    w.iter().map(|v| v / scale).collect()
}

/// Rebuild a ConvOp's converter from its spec (shallow clones, overrides).
fn rebuild_converter(spec: &PsConverterSpec, mvm: Option<&StoxMvm>) -> Box<dyn PsConvert> {
    let cfg = mvm.map(|m| m.cfg).unwrap_or_default();
    spec.build(&cfg).expect("converter spec was buildable at load time")
}

impl NativeModel {
    /// Load + program the checkpoint at its trained hardware config.
    pub fn load(manifest: &Manifest, store: &WeightStore) -> crate::Result<Self> {
        Self::load_with_config(manifest, store, manifest.spec.stox_config())
    }

    /// Load + program the checkpoint at an explicit hardware config —
    /// e.g. a `--precision` tag other than the trained one
    /// ([`StoxConfig::from_tag`]).  Every crossbar-mapped layer is
    /// quantized and programmed exactly once per call; evaluate many
    /// converter specs against one programming pass with
    /// [`NativeModel::share_with_converter_spec`].
    pub fn load_with_config(
        manifest: &Manifest,
        store: &WeightStore,
        cfg: StoxConfig,
    ) -> crate::Result<Self> {
        cfg.validate()?;
        let spec = &manifest.spec;
        let _widths = spec.widths();
        let first_qf = spec.first_layer == "qf";
        let samples_for = |layer_idx: usize| -> u32 {
            if layer_idx == 0 {
                return spec.first_layer_samples;
            }
            if let Some(ls) = &spec.layer_samples {
                for (li, n) in ls {
                    if *li == layer_idx {
                        return *n;
                    }
                }
            }
            spec.stox.n_samples
        };

        let mut layer_idx = 0usize;
        let mk_stox_conv = |w_raw: &[f32],
                            shape: &[usize],
                            stride: usize,
                            layer_idx: usize,
                            mode: &str,
                            n_samples: u32|
         -> crate::Result<ConvOp> {
            let (kh, kw, cin, cout) = (shape[0], shape[1], shape[2], shape[3]);
            let wn = normalize_weights(w_raw);
            let mvm = Arc::new(StoxMvm::program(&wn, kh * kw * cin, cout, cfg)?);
            // the registry is the single parse/construct path: manifest
            // mode strings ("stox", "sa", "expected", "ideal", or any
            // extended `name:k=v` form) all resolve here
            let conv_spec = PsConverterSpec::from_mode(mode, cfg.alpha, n_samples)?;
            let converter = conv_spec.build(&cfg)?;
            Ok(ConvOp {
                mvm: Some(mvm),
                raw_w: wn,
                kh,
                kw,
                cin,
                cout,
                stride,
                conv_spec,
                converter,
                layer_idx,
            })
        };

        // conv1
        let (c1_shape, c1_data) = store.param("['conv1']")?;
        let conv1 = if first_qf {
            let mode = spec
                .first_layer_mode
                .clone()
                .unwrap_or_else(|| spec.stox.mode.clone());
            mk_stox_conv(c1_data, c1_shape, 1, 0, &mode, samples_for(0))?
        } else {
            ConvOp {
                mvm: None,
                raw_w: c1_data.to_vec(),
                kh: c1_shape[0],
                kw: c1_shape[1],
                cin: c1_shape[2],
                cout: c1_shape[3],
                stride: 1,
                conv_spec: PsConverterSpec::IdealAdc,
                converter: PsConverterSpec::IdealAdc.build(&cfg)?,
                layer_idx: 0,
            }
        };
        layer_idx += 1;

        let bn = |prefix: &str| -> crate::Result<BnFold> {
            let (_, gamma) = store.param(&format!("{prefix}['gamma']"))?;
            let (_, beta) = store.param(&format!("{prefix}['beta']"))?;
            let (_, mean) = store.state(&format!(
                "{}['mean']",
                prefix.trim_start_matches("['params']")
            ))?;
            let (_, var) = store.state(&format!(
                "{}['var']",
                prefix.trim_start_matches("['params']")
            ))?;
            Ok(BnFold::new(gamma, beta, mean, var))
        };
        let bn1 = bn("['bn1']")?;

        let mut blocks = Vec::new();
        for s in 0..3 {
            let mut stage = Vec::new();
            for b in 0..spec.blocks_per_stage {
                let stride = if s > 0 && b == 0 { 2 } else { 1 };
                let p = format!("['stages'][{s}][{b}]");
                let (sh1, w1) = store.param(&format!("{p}['conv1']"))?;
                let c1 = mk_stox_conv(
                    w1,
                    sh1,
                    stride,
                    layer_idx,
                    &spec.stox.mode,
                    samples_for(layer_idx),
                )?;
                layer_idx += 1;
                let b1 = bn(&format!("{p}['bn1']"))?;
                let (sh2, w2) = store.param(&format!("{p}['conv2']"))?;
                let c2 = mk_stox_conv(
                    w2,
                    sh2,
                    1,
                    layer_idx,
                    &spec.stox.mode,
                    samples_for(layer_idx),
                )?;
                layer_idx += 1;
                let b2 = bn(&format!("{p}['bn2']"))?;
                stage.push((c1, b1, c2, b2, stride));
            }
            blocks.push(stage);
        }

        let (fcw_shape, fcw) = store.param("['fc_w']")?;
        let (_, fcb) = store.param("['fc_b']")?;
        Ok(Self {
            num_classes: spec.num_classes,
            image_size: spec.image_size,
            in_channels: spec.in_channels,
            first_qf,
            conv1,
            bn1,
            blocks,
            fc_w: fcw.to_vec(),
            fc_b: fcb.to_vec(),
            w3: fcw_shape[0],
            ps_probe: None,
            use_fused_conv: true,
            use_pipeline: true,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn run_conv(
        &self,
        op: &ConvOp,
        x: &[f32],
        b: usize,
        h: usize,
        w: usize,
        step_seed: u32,
        clip_input: bool,
        arena: &mut ConvArena,
        img_base: Option<usize>,
    ) -> (Vec<f32>, usize, usize) {
        let _sp = span::span_with(TraceLevel::Layer, "layer", || {
            format!("conv.l{:02}", op.layer_idx)
        });
        // Fused digit-domain path: each input pixel is quantized and
        // decomposed exactly once *before* patch extraction, the stripe
        // gather reads the shared digit planes, and no `patches`/`xin`
        // buffer is ever materialized.  `quantize_unit` clamps its input,
        // so the legacy path's pre-clipped `xin` copy is redundant here —
        // bit-identical outputs (pinned by `model_fused_conv` tests).
        if let Some(mvm) = &op.mvm {
            if self.use_fused_conv && mvm.is_integer_kernel() && self.ps_probe.is_none() {
                let acts = decompose_activations(arena, x, b, h, w, op.cin, &mvm.cfg);
                let seed = layer_seed(step_seed, op.layer_idx as u32);
                if let Some(base) = img_base {
                    // pipelined per-image execution: strictly sequential
                    // kernel (the pipeline owns the worker threads) with
                    // the image's absolute first-patch index as the RNG
                    // counter offset — bit-identical to its rows of the
                    // whole-batch call below
                    let pad = (op.kh - 1) / 2;
                    let ho = (h + 2 * pad - op.kh) / op.stride + 1;
                    let wo = (w + 2 * pad - op.kw) / op.stride + 1;
                    return mvm.run_conv_digits_offset(
                        &acts,
                        op.kh,
                        op.kw,
                        op.stride,
                        op.converter.as_ref(),
                        seed,
                        base * ho * wo,
                    );
                }
                return mvm.run_conv_digits(
                    &acts,
                    op.kh,
                    op.kw,
                    op.stride,
                    op.converter.as_ref(),
                    seed,
                );
            }
        }
        // the pipeline gate (`pipeline_eligible`) only dispatches per-image
        // work when every crossbar-mapped layer takes the fused path above,
        // so a legacy-path layer here can only be the full-precision first
        // layer — whose per-image rows are computed independently anyway
        debug_assert!(img_base.is_none() || op.mvm.is_none());
        let xin: Vec<f32> = if clip_input {
            x.iter().map(|v| v.clamp(-1.0, 1.0)).collect()
        } else {
            x.to_vec()
        };
        match &op.mvm {
            Some(mvm) => {
                let (patches, ho, wo) =
                    im2col(&xin, b, h, w, op.cin, op.kh, op.kw, op.stride);
                let seed = layer_seed(step_seed, op.layer_idx as u32);
                if let Some(probe) = &self.ps_probe {
                    // probe path: record normalized PS of this layer
                    self.record_ps(mvm, &patches, b * ho * wo, probe);
                }
                let out =
                    mvm.run(&patches, b * ho * wo, op.converter.as_ref(), seed);
                (out, ho, wo)
            }
            None => {
                let (out, ho, wo) = fp_conv2d(
                    &xin, b, h, w, op.cin, &op.raw_w, op.kh, op.kw, op.cout,
                    op.stride,
                );
                (out, ho, wo)
            }
        }
    }

    /// Toggle the fused digit-domain conv path (default on).  The legacy
    /// im2col path stays bit-identical — this switch exists for the
    /// before/after perf cases and as an escape hatch.
    pub fn set_fused_conv(&mut self, on: bool) {
        self.use_fused_conv = on;
    }

    fn record_ps(
        &self,
        mvm: &StoxMvm,
        patches: &[f32],
        batch: usize,
        probe: &std::sync::Mutex<crate::stats::Histogram>,
    ) {
        // run with the ideal converter, collecting raw PS via a histogram
        // converter shim: reuse run() but with IdealAdc and record outputs
        // of individual subarrays through the PS-level API.
        let ps = mvm.collect_ps(patches, batch);
        let mut h = probe.lock().unwrap();
        h.extend(ps);
    }

    /// Whether the layer-pipelined batch forward can run: the per-image
    /// offset kernel exists only on the fused digit-domain path, so every
    /// crossbar-mapped layer must hold the integer kernel (the
    /// full-precision first layer is fine — its rows are independent),
    /// the fused path must be on, and no PS probe may be attached.
    fn pipeline_eligible(&self) -> bool {
        if !self.use_fused_conv || self.ps_probe.is_some() {
            return false;
        }
        let ok = |op: &ConvOp| op.mvm.as_deref().is_none_or(StoxMvm::is_integer_kernel);
        ok(&self.conv1)
            && self
                .blocks
                .iter()
                .all(|s| s.iter().all(|b| ok(&b.0) && ok(&b.2)))
    }

    /// Toggle the layer-pipelined batch forward (default on).  The
    /// sequential whole-batch path stays bit-identical — this switch
    /// exists for the before/after perf cases, the scenario pin, and as
    /// an escape hatch.
    pub fn set_pipeline(&mut self, on: bool) {
        self.use_pipeline = on;
    }

    /// Forward a batch (NHWC in [-1,1]); returns logits [B × classes].
    ///
    /// With ≥ 2 images, ≥ 2 worker threads, and every layer on the fused
    /// integer path, the batch runs **layer-pipelined**: each worker
    /// carries one image through all layers (its own [`ConvArena`]), so
    /// layer k of image i overlaps layer k−1 of image i+1.  Bit-identical
    /// to the sequential whole-batch pass — the RNG counter contract keys
    /// every stochastic draw by absolute patch index, which
    /// [`StoxMvm::run_conv_digits_offset`] preserves per image.
    pub fn forward(&self, x: &[f32], batch: usize, step_seed: u32) -> Vec<f32> {
        let threads = crate::util::pool::default_threads();
        if self.use_pipeline && threads > 1 && batch >= 2 && self.pipeline_eligible() {
            let img = self.image_size * self.image_size * self.in_channels;
            debug_assert!(x.len() >= batch * img);
            let parts = crate::util::pool::par_map_scratch(
                batch,
                threads,
                ConvArena::new,
                |arena, i| {
                    self.forward_chunk(&x[i * img..(i + 1) * img], 1, Some(i), step_seed, arena)
                },
            );
            let mut out = Vec::with_capacity(batch * self.num_classes);
            for p in parts {
                out.extend(p);
            }
            return out;
        }
        // one digit-plane arena serves every layer of this pass (grown to
        // the largest layer, no per-layer patch/xin allocations)
        let mut arena = ConvArena::new();
        self.forward_chunk(x, batch, None, step_seed, &mut arena)
    }

    /// One whole-network pass over `batch` images: the sequential forward
    /// body (`img_base = None`) and the pipeline workers' per-image body
    /// (`img_base = Some(absolute image index)`) are the *same* code, so
    /// the bit-identity contract cannot drift between them.
    fn forward_chunk(
        &self,
        x: &[f32],
        batch: usize,
        img_base: Option<usize>,
        step_seed: u32,
        arena: &mut ConvArena,
    ) -> Vec<f32> {
        let (mut h, mut hh, mut ww) = self.run_conv(
            &self.conv1,
            x,
            batch,
            self.image_size,
            self.image_size,
            step_seed,
            self.first_qf, // python clips input only on the stox path
            arena,
            img_base,
        );
        self.bn1.apply(&mut h, self.conv1.cout);
        let mut c = self.conv1.cout;

        for stage in &self.blocks {
            for (c1, b1, c2, b2, stride) in stage {
                let shortcut = shortcut(&h, batch, hh, ww, c, c1.cout, *stride);
                let (mut o1, h1, w1) =
                    self.run_conv(c1, &h, batch, hh, ww, step_seed, true, arena, img_base);
                b1.apply(&mut o1, c1.cout);
                let (mut o2, h2, w2) =
                    self.run_conv(c2, &o1, batch, h1, w1, step_seed, true, arena, img_base);
                b2.apply(&mut o2, c2.cout);
                for (o, s) in o2.iter_mut().zip(&shortcut) {
                    *o += s;
                }
                h = o2;
                hh = h2;
                ww = w2;
                c = c2.cout;
            }
        }

        // global average pool + FC
        let mut logits = vec![0.0f32; batch * self.num_classes];
        let hw = (hh * ww) as f32;
        for bi in 0..batch {
            let mut pooled = vec![0.0f32; c];
            for p in 0..hh * ww {
                for ch in 0..c {
                    pooled[ch] += h[(bi * hh * ww + p) * c + ch];
                }
            }
            for v in pooled.iter_mut() {
                *v /= hw;
            }
            for k in 0..self.num_classes {
                let mut acc = self.fc_b[k];
                for ch in 0..self.w3 {
                    acc += pooled[ch] * self.fc_w[ch * self.num_classes + k];
                }
                logits[bi * self.num_classes + k] = acc;
            }
        }
        logits
    }

    /// Classification accuracy over a labeled set.
    pub fn accuracy(
        &self,
        images: &[f32],
        labels: &[i32],
        n: usize,
        batch: usize,
        seed: u32,
    ) -> f64 {
        let img_sz = self.image_size * self.image_size * self.in_channels;
        let mut correct = 0usize;
        let mut i = 0;
        while i < n {
            let b = batch.min(n - i);
            let logits =
                self.forward(&images[i * img_sz..(i + b) * img_sz], b, seed + i as u32);
            for bi in 0..b {
                let row = &logits[bi * self.num_classes..(bi + 1) * self.num_classes];
                // first-max argmax: ties resolve to the lowest class index,
                // matching numpy/jnp argmax (the python evaluation) and
                // arch::sweep::argmax, so accuracies are comparable across
                // the native, sweep, and python paths
                let mut pred = 0usize;
                for (k, &v) in row.iter().enumerate() {
                    if v > row[pred] {
                        pred = k;
                    }
                }
                if pred as i32 == labels[i + bi] {
                    correct += 1;
                }
            }
            i += b;
        }
        correct as f64 / n as f64
    }

    /// Uniformly perturb the weights of stochastic conv layer `target`
    /// (index over conv layers in order, conv1 = 0) by U(-sigma,sigma)·max|w|
    /// — the Fig. 5 Monte-Carlo probe.  Returns a perturbed clone.
    pub fn perturb_layer(&self, target: usize, sigma: f32, seed: u32) -> Self
    where
        Self: Sized,
    {
        let mut clone = self.clone_shallow();
        let rng = crate::stats::rng::CounterRng::new(seed);
        let mut idx = 0usize;
        let mut maybe = |op: &ConvOp| -> Option<ConvOp> {
            let hit = idx == target;
            idx += 1;
            if !hit {
                return None;
            }
            let maxw = op.raw_w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let w2: Vec<f32> = op
                .raw_w
                .iter()
                .enumerate()
                .map(|(i, v)| v + rng.uniform_in(i as u32, -sigma, sigma) * maxw)
                .collect();
            let mvm = op.mvm.as_ref().map(|m| {
                Arc::new(
                    StoxMvm::program(&normalize_weights(&w2), m.m, m.n, m.cfg).unwrap(),
                )
            });
            Some(ConvOp { mvm, raw_w: w2, ..op.clone_shallow() })
        };
        if let Some(op) = maybe(&self.conv1) {
            clone.conv1 = op;
        }
        for (si, stage) in self.blocks.iter().enumerate() {
            for (bi, (c1, _, c2, _, _)) in stage.iter().enumerate() {
                if let Some(op) = maybe(c1) {
                    clone.blocks[si][bi].0 = op;
                }
                if let Some(op) = maybe(c2) {
                    clone.blocks[si][bi].2 = op;
                }
            }
        }
        clone
    }

    /// Attach deterministic hardware counters to every crossbar-mapped
    /// conv layer: layer `idx` at precision tag `t` tallies its
    /// architectural events into `imc.l{idx:02}.{t}.{event}` counters of
    /// `reg` (taxonomy and determinism contract in
    /// [`StoxMvm::attach_counters`]).  Counters must attach while this
    /// model still owns its crossbars exclusively — call right after
    /// loading, before any [`NativeModel::replica_view`] or
    /// [`NativeModel::share_with_converter_spec`] clones the `Arc`s.
    pub fn attach_counters(&mut self, reg: &CounterRegistry) -> crate::Result<()> {
        fn attach(op: &mut ConvOp, reg: &CounterRegistry) -> crate::Result<()> {
            if let Some(mvm) = &mut op.mvm {
                let m = Arc::get_mut(mvm).ok_or_else(|| {
                    anyhow::anyhow!(
                        "attach_counters needs exclusive crossbars (layer {}): attach \
                         before taking replica views or converter shares",
                        op.layer_idx
                    )
                })?;
                let scope = format!("imc.l{:02}.{}.", op.layer_idx, m.cfg.tag());
                m.attach_counters(reg, &scope);
            }
            Ok(())
        }
        attach(&mut self.conv1, reg)?;
        for stage in self.blocks.iter_mut() {
            for blk in stage.iter_mut() {
                attach(&mut blk.0, reg)?;
                attach(&mut blk.2, reg)?;
            }
        }
        Ok(())
    }

    /// Replace the PS converter of every crossbar-mapped conv layer with
    /// one built from `spec` (the full-precision first layer, when
    /// present, is untouched).  This is the serving-side hook that lets
    /// any registry converter — including `sparse` and `inhomo` — run
    /// end-to-end through the native model regardless of what mode the
    /// checkpoint was trained with.
    pub fn with_converter_spec(mut self, spec: &PsConverterSpec) -> crate::Result<Self> {
        fn apply(op: &mut ConvOp, spec: &PsConverterSpec) -> crate::Result<()> {
            if let Some(m) = &op.mvm {
                op.converter = spec.build(&m.cfg)?;
                op.conv_spec = spec.clone();
            }
            Ok(())
        }
        apply(&mut self.conv1, spec)?;
        for stage in self.blocks.iter_mut() {
            for blk in stage.iter_mut() {
                apply(&mut blk.0, spec)?;
                apply(&mut blk.2, spec)?;
            }
        }
        Ok(self)
    }

    /// Cheap per-converter view over this model's single programming pass:
    /// clones the model sharing the programmed crossbars (`Arc::clone`,
    /// no re-quantization or re-programming) and swaps every
    /// crossbar-mapped layer's converter to `spec` — semantically
    /// identical to reloading the checkpoint and calling
    /// [`NativeModel::with_converter_spec`] (pinned bit-identical by
    /// `rust/tests/model_sweep.rs`), but O(converters) instead of
    /// O(weights).  This is what makes `sweep --model` perform exactly
    /// one weight load + program per precision tag regardless of how many
    /// converter specs are swept.
    pub fn share_with_converter_spec(&self, spec: &PsConverterSpec) -> crate::Result<Self> {
        self.clone_shallow().with_converter_spec(spec)
    }

    /// True iff every crossbar-mapped layer of `self` shares its
    /// programmed crossbars (pointer-equal `Arc`) with the corresponding
    /// layer of `other` — the regression hook asserting that per-spec
    /// model views reuse one programming pass instead of re-programming.
    pub fn shares_programming_with(&self, other: &Self) -> bool {
        fn same(a: &ConvOp, b: &ConvOp) -> bool {
            match (&a.mvm, &b.mvm) {
                (Some(x), Some(y)) => Arc::ptr_eq(x, y),
                (None, None) => true,
                _ => false,
            }
        }
        if !same(&self.conv1, &other.conv1) || self.blocks.len() != other.blocks.len() {
            return false;
        }
        self.blocks.iter().zip(&other.blocks).all(|(s, o)| {
            s.len() == o.len()
                && s.iter()
                    .zip(o)
                    .all(|(x, y)| same(&x.0, &y.0) && same(&x.2, &y.2))
        })
    }

    /// A replica view of this model for the serving tier: shares the
    /// programmed crossbars (pointer-equal `Arc`s, no re-quantization or
    /// re-programming) and keeps the converter spec — program once, serve
    /// everywhere.  Each replica is independently `Send`, so N shards can
    /// execute batches concurrently against one programming pass;
    /// `forward` is deterministic per `(images, batch, seed)`, so which
    /// replica runs a batch never changes its logits.
    pub fn replica_view(&self) -> Self {
        self.clone_shallow()
    }

    /// Number of conv layers (perturbation targets).
    pub fn n_conv_layers(&self) -> usize {
        1 + self.blocks.iter().map(|s| s.len() * 2).sum::<usize>()
    }

    fn clone_shallow(&self) -> Self {
        Self {
            num_classes: self.num_classes,
            image_size: self.image_size,
            in_channels: self.in_channels,
            first_qf: self.first_qf,
            conv1: self.conv1.clone_shallow(),
            bn1: self.bn1.clone(),
            blocks: self
                .blocks
                .iter()
                .map(|s| {
                    s.iter()
                        .map(|(a, b, c, d, st)| {
                            (a.clone_shallow(), b.clone(), c.clone_shallow(), d.clone(), *st)
                        })
                        .collect()
                })
                .collect(),
            fc_w: self.fc_w.clone(),
            fc_b: self.fc_b.clone(),
            w3: self.w3,
            ps_probe: None,
            use_fused_conv: self.use_fused_conv,
            use_pipeline: self.use_pipeline,
        }
    }
}

impl ConvOp {
    /// Clone sharing the programmed crossbars (`Arc`); only the converter
    /// is rebuilt.  No re-quantization, no re-programming.
    fn clone_shallow(&self) -> Self {
        Self {
            mvm: self.mvm.clone(),
            raw_w: self.raw_w.clone(),
            kh: self.kh,
            kw: self.kw,
            cin: self.cin,
            cout: self.cout,
            stride: self.stride,
            conv_spec: self.conv_spec.clone(),
            converter: rebuild_converter(&self.conv_spec, self.mvm.as_deref()),
            layer_idx: self.layer_idx,
        }
    }
}

/// Parameter-free ResNet-20 shortcut: strided subsample + zero channel pad.
fn shortcut(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    stride: usize,
) -> Vec<f32> {
    let ho = h / stride;
    let wo = w / stride;
    let mut out = vec![0.0f32; b * ho * wo * cout];
    for bi in 0..b {
        for y in 0..ho {
            for xx in 0..wo {
                let src = ((bi * h + y * stride) * w + xx * stride) * cin;
                let dst = ((bi * ho + y) * wo + xx) * cout;
                out[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
            }
        }
    }
    out
}

/// Plain full-precision NHWC convolution (the HPF first layer).
#[allow(clippy::too_many_arguments)]
pub fn fp_conv2d(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    weights: &[f32], // [kh,kw,cin,cout]
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
) -> (Vec<f32>, usize, usize) {
    let pad = (kh - 1) / 2;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w + 2 * pad - kw) / stride + 1;
    let mut out = vec![0.0f32; b * ho * wo * cout];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let dst = ((bi * ho + oy) * wo + ox) * cout;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * cin;
                        for ci in 0..cin {
                            let xv = x[src + ci];
                            if xv == 0.0 {
                                continue;
                            }
                            let wbase = ((ky * kw + kx) * cin + ci) * cout;
                            for co in 0..cout {
                                out[dst + co] += xv * weights[wbase + co];
                            }
                        }
                    }
                }
            }
        }
    }
    (out, ho, wo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_seed_matches_python_derivation() {
        // python: mix32(step_seed ^ uint32(0xA511E9B3 + layer_idx))
        assert_eq!(layer_seed(0, 0), mix32(0xA511_E9B3));
        assert_eq!(layer_seed(7, 3), mix32(7 ^ 0xA511_E9B3u32.wrapping_add(3)));
    }

    #[test]
    fn fp_conv_identity_kernel() {
        // 1x1 kernel with identity weights = passthrough
        let x: Vec<f32> = (0..1 * 2 * 2 * 2).map(|i| i as f32).collect();
        let mut w = vec![0.0f32; 2 * 2]; // [1,1,2,2]
        w[0] = 1.0; // (ci=0,co=0)
        w[3] = 1.0; // (ci=1,co=1)
        let (out, ho, wo) = fp_conv2d(&x, 1, 2, 2, 2, &w, 1, 1, 2, 1);
        assert_eq!((ho, wo), (2, 2));
        assert_eq!(out, x);
    }

    #[test]
    fn shortcut_stride_and_pad() {
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect(); // [1,4,4,1]
        let s = shortcut(&x, 1, 4, 4, 1, 2, 2);
        assert_eq!(s.len(), 1 * 2 * 2 * 2);
        assert_eq!(s[0], 0.0 * 1.0); // (0,0) ch0 = x[0]
        assert_eq!(s[1], 0.0); // zero-padded channel
        assert_eq!(s[2], 2.0); // (0,1) ch0 = x[2]
    }

    #[test]
    fn bn_fold() {
        let bn = BnFold::new(&[2.0], &[1.0], &[0.5], &[4.0]);
        let mut x = vec![0.5f32, 2.5];
        bn.apply(&mut x, 1);
        // (0.5-0.5)/2*2+1 = 1 ; (2.5-0.5)/2*2+1 = 3
        assert!((x[0] - 1.0).abs() < 1e-5);
        assert!((x[1] - 3.0).abs() < 1e-3);
    }
}
