//! DNN workload zoo + exported-weight loading + native hardware-exact
//! inference.
//!
//! * [`zoo`] — layer-shape inventories for the paper's evaluation
//!   networks: ResNet-20 (CIFAR), ResNet-18/50 (Tiny-ImageNet shapes) and
//!   the reduced ResNet-20 actually trained in this reproduction;
//! * [`weights`] — loads `artifacts/manifest.json` + `weights.bin`
//!   exported by the python AOT path;
//! * [`infer`] — native Rust forward pass of the StoX ResNet (crossbar
//!   functional model all the way down), mirroring `compile/model.py`
//!   layer-for-layer and seed-for-seed.

pub mod infer;
pub mod weights;
pub mod zoo;

pub use infer::NativeModel;
pub use weights::{Manifest, WeightStore};
