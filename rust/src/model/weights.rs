//! Loaders for the python-AOT exports: `manifest.json`, `weights.bin`,
//! `testset.bin`.
//!
//! Tensor names are jax `keystr` paths, e.g.
//! `['params']['stages'][0][1]['conv1']` — stored verbatim; [`WeightStore`]
//! offers path-based lookup so `infer.rs` can mirror `model.py`'s pytree.

use crate::imc::{PsConverterSpec, StoxConfig};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct StoxSpecJson {
    pub a_bits: u32,
    pub w_bits: u32,
    pub a_stream_bits: u32,
    pub w_slice_bits: u32,
    pub r_arr: usize,
    pub n_samples: u32,
    pub alpha: f32,
    pub mode: String,
}

#[derive(Debug, Clone)]
pub struct ModelSpecJson {
    pub name: String,
    pub num_classes: usize,
    pub in_channels: usize,
    pub image_size: usize,
    pub base_width: usize,
    pub width_mult: f64,
    pub blocks_per_stage: usize,
    pub stox: StoxSpecJson,
    pub first_layer: String,
    pub first_layer_samples: u32,
    pub first_layer_mode: Option<String>,
    pub layer_samples: Option<Vec<(usize, u32)>>,
}

impl StoxSpecJson {
    /// The functional-simulator hardware config this spec trained for —
    /// the one place the manifest json becomes a [`StoxConfig`].
    pub fn to_config(&self) -> StoxConfig {
        StoxConfig {
            a_bits: self.a_bits,
            w_bits: self.w_bits,
            a_stream_bits: self.a_stream_bits,
            w_slice_bits: self.w_slice_bits,
            r_arr: self.r_arr,
            n_samples: self.n_samples,
            alpha: self.alpha,
        }
    }
}

impl ModelSpecJson {
    /// Stage widths, mirroring `ModelSpec.widths()`.
    pub fn widths(&self) -> [usize; 3] {
        let w = ((self.base_width as f64 * self.width_mult).round() as usize).max(4);
        [w, 2 * w, 4 * w]
    }

    /// Hardware config of the trained checkpoint.
    pub fn stox_config(&self) -> StoxConfig {
        self.stox.to_config()
    }

    /// Hardware config for a paper §4.1 precision tag (`XwYa[Zbs]`),
    /// derived from the trained config — `r_arr`, `alpha`, `n_samples`
    /// and the DAC stream width carry over, the tag overrides the
    /// operand/slice widths ([`StoxConfig::from_tag`]).  This is how
    /// `sweep --model --precision …` re-programs one checkpoint across
    /// the Fig. 9a precision axis.
    pub fn precision_config(&self, tag: &str) -> crate::Result<StoxConfig> {
        StoxConfig::from_tag(tag, &self.stox_config())
    }

    /// Converter spec of the stochastic body layers (trained mode + the
    /// checkpoint's alpha / n_samples defaults) via the registry grammar.
    pub fn body_converter_spec(&self) -> crate::Result<PsConverterSpec> {
        PsConverterSpec::from_mode(&self.stox.mode, self.stox.alpha, self.stox.n_samples)
    }

    /// Converter spec of the first conv layer: QF → the trained stochastic
    /// mode (`first_layer_mode` falling back to the body mode) with
    /// `first_layer_samples`; HPF → an ideal (full-precision ADC) readout.
    pub fn first_layer_spec(&self) -> crate::Result<PsConverterSpec> {
        if self.first_layer == "qf" {
            let mode = self
                .first_layer_mode
                .clone()
                .unwrap_or_else(|| self.stox.mode.clone());
            PsConverterSpec::from_mode(&mode, self.stox.alpha, self.first_layer_samples)
        } else {
            Ok(PsConverterSpec::IdealAdc)
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
}

#[derive(Debug, Clone)]
pub struct WeightsJson {
    pub file: String,
    pub tensors: Vec<TensorEntry>,
    pub total_f32: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub kind: String,
    pub batch: usize,
}

#[derive(Debug, Clone)]
pub struct TestsetJson {
    pub file: String,
    pub dataset: String,
    pub n: usize,
    pub image_shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub spec: ModelSpecJson,
    pub layers: Vec<crate::arch::mapper::LayerShape>,
    pub models: Vec<ArtifactEntry>,
    pub weights: WeightsJson,
    pub testset: TestsetJson,
    pub dir: PathBuf,
}

fn req<'a>(j: &'a Json, key: &str) -> crate::Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| anyhow::anyhow!("manifest: missing key '{key}'"))
}

fn req_str(j: &Json, key: &str) -> crate::Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("manifest: '{key}' not a string"))?
        .to_string())
}

fn req_usize(j: &Json, key: &str) -> crate::Result<usize> {
    req(j, key)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("manifest: '{key}' not a number"))
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;

        let sj = req(&j, "spec")?;
        let stj = req(sj, "stox")?;
        let spec = ModelSpecJson {
            name: req_str(sj, "name")?,
            num_classes: req_usize(sj, "num_classes")?,
            in_channels: req_usize(sj, "in_channels")?,
            image_size: req_usize(sj, "image_size")?,
            base_width: req_usize(sj, "base_width")?,
            width_mult: req(sj, "width_mult")?.as_f64().unwrap_or(1.0),
            blocks_per_stage: req_usize(sj, "blocks_per_stage")?,
            stox: StoxSpecJson {
                a_bits: req_usize(stj, "a_bits")? as u32,
                w_bits: req_usize(stj, "w_bits")? as u32,
                a_stream_bits: req_usize(stj, "a_stream_bits")? as u32,
                w_slice_bits: req_usize(stj, "w_slice_bits")? as u32,
                r_arr: req_usize(stj, "r_arr")?,
                n_samples: req_usize(stj, "n_samples")? as u32,
                alpha: req(stj, "alpha")?.as_f64().unwrap_or(4.0) as f32,
                mode: req_str(stj, "mode")?,
            },
            first_layer: req_str(sj, "first_layer")?,
            first_layer_samples: req_usize(sj, "first_layer_samples")? as u32,
            first_layer_mode: sj
                .get("first_layer_mode")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string()),
            layer_samples: sj.get("layer_samples").and_then(|v| {
                v.as_arr().map(|arr| {
                    arr.iter()
                        .filter_map(|pair| {
                            let p = pair.as_arr()?;
                            Some((p[0].as_usize()?, p[1].as_u32()?))
                        })
                        .collect()
                })
            }),
        };

        let layers = req(&j, "layers")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|l| {
                Ok(crate::arch::mapper::LayerShape {
                    name: req_str(l, "name")?,
                    kh: req_usize(l, "kh")?,
                    kw: req_usize(l, "kw")?,
                    cin: req_usize(l, "cin")?,
                    cout: req_usize(l, "cout")?,
                    h_out: req_usize(l, "h_out")?,
                    w_out: req_usize(l, "w_out")?,
                    stride: l.get("stride").and_then(|v| v.as_usize()).unwrap_or(1),
                    stochastic: req(l, "stochastic")?.as_bool().unwrap_or(true),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;

        let models = req(&j, "models")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|m| {
                Ok(ArtifactEntry {
                    file: req_str(m, "file")?,
                    kind: req_str(m, "kind")?,
                    batch: m.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;

        let wj = req(&j, "weights")?;
        let weights = WeightsJson {
            file: req_str(wj, "file")?,
            total_f32: req_usize(wj, "total_f32")?,
            tensors: req(wj, "tensors")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|t| {
                    Ok(TensorEntry {
                        name: req_str(t, "name")?,
                        shape: t
                            .get("shape")
                            .and_then(|v| v.as_arr())
                            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                            .unwrap_or_default(),
                        offset: req_usize(t, "offset")?,
                        numel: req_usize(t, "numel")?,
                    })
                })
                .collect::<crate::Result<Vec<_>>>()?,
        };

        let tj = req(&j, "testset")?;
        let testset = TestsetJson {
            file: req_str(tj, "file")?,
            dataset: req_str(tj, "dataset")?,
            n: req_usize(tj, "n")?,
            image_shape: req(tj, "image_shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
        };

        Ok(Manifest { spec, layers, models, weights, testset, dir })
    }

    pub fn model_hlo_path(&self, batch: usize) -> Option<PathBuf> {
        self.models
            .iter()
            .find(|m| m.batch == batch)
            .map(|m| self.dir.join(&m.file))
    }
}

/// All exported tensors, resident in one flat buffer.
pub struct WeightStore {
    buf: Vec<f32>,
    entries: Vec<TensorEntry>,
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> crate::Result<Self> {
        let path = manifest.dir.join(&manifest.weights.file);
        let bytes = std::fs::read(&path)?;
        anyhow::ensure!(
            bytes.len() == manifest.weights.total_f32 * 4,
            "weights.bin size mismatch: {} vs {}",
            bytes.len(),
            manifest.weights.total_f32 * 4
        );
        let buf: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self { buf, entries: manifest.weights.tensors.clone() })
    }

    /// Exact-name lookup (jax keystr), returns (shape, data).
    pub fn get(&self, name: &str) -> crate::Result<(&[usize], &[f32])> {
        let e = self
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("tensor not found: {name}"))?;
        Ok((&e.shape, &self.buf[e.offset..e.offset + e.numel]))
    }

    /// Build the keystr for a parameter path, e.g.
    /// `param(&["stages"], ...)`; helper used by infer.rs.
    pub fn param(&self, path: &str) -> crate::Result<(&[usize], &[f32])> {
        self.get(&format!("['params']{path}"))
    }

    pub fn state(&self, path: &str) -> crate::Result<(&[usize], &[f32])> {
        self.get(&format!("['states']{path}"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.name.as_str())
    }
}

/// The exported held-out test set ([N,H,W,C] f32 + [N] i32 labels).
pub struct TestSet {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl TestSet {
    pub fn load(manifest: &Manifest) -> crate::Result<Self> {
        let path = manifest.dir.join(&manifest.testset.file);
        let bytes = std::fs::read(&path)?;
        let n = manifest.testset.n;
        let [h, w, c] = [
            manifest.testset.image_shape[0],
            manifest.testset.image_shape[1],
            manifest.testset.image_shape[2],
        ];
        let img_f32 = n * h * w * c;
        anyhow::ensure!(
            bytes.len() == img_f32 * 4 + n * 4,
            "testset.bin size mismatch"
        );
        let images: Vec<f32> = bytes[..img_f32 * 4]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let labels: Vec<i32> = bytes[img_f32 * 4..]
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Self { images, labels, n, h, w, c })
    }

    /// Image `i` as a slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.h * self.w * self.c;
        &self.images[i * sz..(i + 1) * sz]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn manifest_loads() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(dir).unwrap();
        assert!(m.spec.num_classes == 10);
        assert!(!m.layers.is_empty());
        assert!(m.model_hlo_path(8).is_some());
        assert!(m.model_hlo_path(999).is_none());
    }

    #[test]
    fn weights_load_and_lookup() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(dir).unwrap();
        let w = WeightStore::load(&m).unwrap();
        let (shape, data) = w.param("['conv1']").unwrap();
        assert_eq!(shape.len(), 4);
        assert!(!data.is_empty());
        assert!(w.get("bogus").is_err());
        // BN state exists
        assert!(w.state("['bn1']['mean']").is_ok());
    }

    #[test]
    fn testset_loads() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(dir).unwrap();
        let t = TestSet::load(&m).unwrap();
        assert_eq!(t.labels.len(), t.n);
        assert!(t.image(0).iter().all(|v| v.abs() <= 1.0));
        assert!(t.labels.iter().all(|&l| (0..10).contains(&l)));
    }
}
