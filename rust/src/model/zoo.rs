//! Layer-shape inventories for the paper's evaluation workloads.
//!
//! The architecture model (Fig. 9) needs only layer shapes.  ResNet-20
//! runs on CIFAR (32×32); ResNet-18/50 on Tiny ImageNet (64×64, 200
//! classes) as in Fig. 9b.  The first layer of each inventory is index 0
//! (the HPF/QF special case); FC layers are marked non-stochastic
//! (kept digital, as in the paper's evaluation).

use crate::arch::mapper::LayerShape;

fn conv(
    name: String,
    k: usize,
    cin: usize,
    cout: usize,
    h: usize,
    stochastic: bool,
) -> LayerShape {
    LayerShape {
        name,
        kh: k,
        kw: k,
        cin,
        cout,
        h_out: h,
        w_out: h,
        stride: 1,
        stochastic,
    }
}

/// ResNet-20 for CIFAR-10: conv1 + 3 stages × 3 blocks × 2 convs + FC.
pub fn resnet20_cifar() -> Vec<LayerShape> {
    let mut layers = vec![conv("conv1".into(), 3, 3, 16, 32, true)];
    let widths = [16usize, 32, 64];
    let sizes = [32usize, 16, 8];
    let mut cin = 16;
    for (s, (&w, &hw)) in widths.iter().zip(&sizes).enumerate() {
        for b in 0..3 {
            layers.push(conv(format!("s{s}b{b}c1"), 3, cin, w, hw, true));
            layers.push(conv(format!("s{s}b{b}c2"), 3, w, w, hw, true));
            cin = w;
        }
    }
    layers.push(conv("fc".into(), 1, 64, 10, 1, false));
    layers
}

/// ResNet-18 with Tiny-ImageNet geometry (64×64 input, 200 classes).
pub fn resnet18_tiny() -> Vec<LayerShape> {
    let mut layers = vec![conv("conv1".into(), 7, 3, 64, 32, true)];
    // after maxpool: 16×16
    let widths = [64usize, 128, 256, 512];
    let sizes = [16usize, 8, 4, 2];
    let mut cin = 64;
    for (s, (&w, &hw)) in widths.iter().zip(&sizes).enumerate() {
        for b in 0..2 {
            layers.push(conv(format!("s{s}b{b}c1"), 3, cin, w, hw, true));
            layers.push(conv(format!("s{s}b{b}c2"), 3, w, w, hw, true));
            if b == 0 && s > 0 {
                // 1×1 projection shortcut on the downsampling block
                layers.push(conv(format!("s{s}proj"), 1, cin, w, hw, true));
            }
            cin = w;
        }
    }
    layers.push(conv("fc".into(), 1, 512, 200, 1, false));
    layers
}

/// ResNet-50 (bottleneck) with Tiny-ImageNet geometry.
pub fn resnet50_tiny() -> Vec<LayerShape> {
    let mut layers = vec![conv("conv1".into(), 7, 3, 64, 32, true)];
    let widths = [64usize, 128, 256, 512];
    let blocks = [3usize, 4, 6, 3];
    let sizes = [16usize, 8, 4, 2];
    let mut cin = 64;
    for s in 0..4 {
        let w = widths[s];
        let hw = sizes[s];
        for b in 0..blocks[s] {
            layers.push(conv(format!("s{s}b{b}c1"), 1, cin, w, hw, true));
            layers.push(conv(format!("s{s}b{b}c2"), 3, w, w, hw, true));
            layers.push(conv(format!("s{s}b{b}c3"), 1, w, 4 * w, hw, true));
            if b == 0 {
                layers.push(conv(format!("s{s}proj"), 1, cin, 4 * w, hw, true));
            }
            cin = 4 * w;
        }
    }
    layers.push(conv("fc".into(), 1, 2048, 200, 1, false));
    layers
}

/// Workload lookup by name (CLI surface).
pub fn by_name(name: &str) -> Option<Vec<LayerShape>> {
    match name {
        "resnet20-cifar" => Some(resnet20_cifar()),
        "resnet18-tiny" => Some(resnet18_tiny()),
        "resnet50-tiny" => Some(resnet50_tiny()),
        _ => None,
    }
}

/// Total MACs of a workload (sanity metric; ResNet-20 ≈ 41 M on CIFAR).
pub fn total_macs(layers: &[LayerShape]) -> u64 {
    layers.iter().map(|l| l.macs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet20_inventory() {
        let l = resnet20_cifar();
        assert_eq!(l.len(), 1 + 18 + 1);
        assert_eq!(l[0].name, "conv1");
        assert!(!l.last().unwrap().stochastic);
        // canonical ResNet-20/CIFAR MAC count ≈ 41M
        let m = total_macs(&l);
        assert!((30e6..60e6).contains(&(m as f64)), "{m}");
    }

    #[test]
    fn resnet18_inventory() {
        let l = resnet18_tiny();
        // conv1 + 16 block convs + 3 projections + fc
        assert_eq!(l.len(), 1 + 16 + 3 + 1);
        assert_eq!(l.last().unwrap().cout, 200);
    }

    #[test]
    fn resnet50_inventory() {
        let l = resnet50_tiny();
        // conv1 + 3*(3+4+6+3) convs + 4 projections + fc
        assert_eq!(l.len(), 1 + 48 + 4 + 1);
        assert!(total_macs(&l) > total_macs(&resnet18_tiny()));
    }

    #[test]
    fn lookup() {
        assert!(by_name("resnet20-cifar").is_some());
        assert!(by_name("nope").is_none());
    }
}
