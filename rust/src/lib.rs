#![cfg_attr(feature = "portable-simd", feature(portable_simd))]
//! StoX-Net: stochastic processing of partial sums for efficient in-memory
//! computing DNN accelerators — full-system reproduction.
//!
//! Layer map (DESIGN.md):
//! * [`device`] — SOT-MTJ physics: macro-spin LLG solver, switching
//!   probability extraction, the analog-to-stochastic converter circuit.
//! * [`imc`] — functional crossbar model: quantization, bit slicing and
//!   streaming, array partitioning, Algorithm 1 end to end.  PS conversion
//!   is an open trait (`imc::PsConvert`) that digitizes whole column
//!   slices per call; converters (ideal / quant / sparse ADC, 1b-SA,
//!   expected / stochastic / inhomogeneous MTJ, plus anything registered
//!   at runtime) are parsed and constructed through the
//!   `imc::PsConverterSpec` registry and report their `cost_key` to the
//!   energy model.  Bit-identical with the python oracle via the shared
//!   counter-based RNG.
//! * [`model`] — DNN workload zoo (ResNet-20/18/50 shapes), exported-weight
//!   loading, native hardware-exact inference.
//! * [`arch`] — ISAAC-like architecture accounting: component cost DB
//!   (Table 2), layer→crossbar mapping, Fig. 8 pipeline model, the
//!   energy/latency/area/EDP rollups behind Fig. 9.
//! * [`coordinator`] — the serving engine: request queue, dynamic batcher,
//!   tile scheduler, metrics.
//! * [`runtime`] — PJRT bridge: loads `artifacts/*.hlo.txt` produced by the
//!   python AOT path and executes them on the request path.
//! * [`serve`] — sharded replica serving tier: N replicas over one set of
//!   programmed crossbars (`Arc` seam), admission control, continuous
//!   batching with work stealing, SLO metrics, and the Poisson load
//!   generator behind `BENCH_serving.json`.
//! * [`obs`] — unified telemetry plane: deterministic hardware counters
//!   (lock-free registries snapshotted as byte-stable JSON) and
//!   request-path spans with Chrome-trace export, gated by the default
//!   `obs` cargo feature and the `STOX_TRACE` level contract.
//! * [`stats`] — RNG, histograms, percentile sketches, Monte-Carlo driver.
//! * [`harness`] — declarative scenario harness (`stox-cli test`): YAML
//!   scenarios drive the in-process infer/sweep/train/serve entry points
//!   and compare against goldens with explicit match modes (exact /
//!   tolerance / subset / ordering / monotonic / range).
//! * [`train`] — PS-quantization-aware training (§3.3): reverse-mode
//!   backprop over the stochastic digit-plane forward (STE quantizers,
//!   per-slice PS capture, the converters' tanh surrogates), SGD with
//!   momentum, and checkpoint export that round-trips through the
//!   manifest + `ConverterRegistry` path.

pub mod arch;
pub mod coordinator;
pub mod device;
pub mod harness;
pub mod imc;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
