//! Stochastic macro-spin Landau-Lifshitz-Gilbert solver with SOT.
//!
//! Single-domain free layer with in-plane easy axis ŷ (the standard
//! stochastic SOT-neuron configuration [Sengupta'16]): the spin-Hall
//! polarization σ ∥ ŷ acts as an (anti-)damping torque on the ±ŷ states,
//! giving a sigmoidal switching probability versus charge current — the
//! physics behind Eq. 1's tanh abstraction.
//!
//!   dm/dt = -γ' m×H_eff - γ'α m×(m×H_eff) - γ' β_DL m×(m×σ)
//!
//! with γ' = γ/(1+α²), H_eff = H_k(m·ŷ)ŷ - M_s(m·ẑ)ẑ (easy axis +
//! thin-film demag) + H_th (thermal field, Box-Muller over the shared
//! counter RNG).  Integration: stochastic Heun, dt ≈ 1 ps.

use crate::stats::rng::CounterRng;

pub const GAMMA: f64 = 1.760_859e11; // gyromagnetic ratio (rad/s/T)
pub const MU0: f64 = 1.256_637e-6; // vacuum permeability
pub const KB: f64 = 1.380_649e-23; // Boltzmann
pub const HBAR: f64 = 1.054_572e-34;
pub const E_CHARGE: f64 = 1.602_177e-19;

/// Macro-spin parameters; defaults reproduce Table 1's device.
#[derive(Debug, Clone, Copy)]
pub struct LlgParams {
    /// saturation magnetization (A/m)
    pub ms: f64,
    /// uniaxial anisotropy field along ŷ (A/m)
    pub h_k: f64,
    /// Gilbert damping
    pub alpha: f64,
    /// free-layer volume (m³) — 90nm × 70nm ellipse × 2.5nm (Table 1)
    pub volume: f64,
    /// spin-Hall angle of the heavy metal
    pub theta_sh: f64,
    /// HM cross-section the charge current flows through (m²)
    pub hm_area: f64,
    /// free-layer thickness (m)
    pub t_free: f64,
    /// temperature (K)
    pub temperature: f64,
    /// integration step (s)
    pub dt: f64,
}

impl Default for LlgParams {
    /// CoFeB-like free layer.  The anti-damping switching threshold for an
    /// in-plane easy axis is `β_c ≈ α(H_k/2 + M_s/2)` (the thin-film demag
    /// dominates); with α = 0.01, M_s = 8×10⁵ A/m this sits near a 40 µA
    /// write current, placing the stochastic transition inside the paper's
    /// 0–±100 µA range (Fig. 2).
    fn default() -> Self {
        Self {
            ms: 8.0e5,
            h_k: 1.5e4,
            alpha: 0.010,
            volume: std::f64::consts::FRAC_PI_4 * 90e-9 * 70e-9 * 2.5e-9,
            theta_sh: 0.3,
            hm_area: 112e-9 * 3.5e-9, // Table 1 HM width × thickness
            t_free: 2.5e-9,
            temperature: 300.0,
            dt: 1e-12,
        }
    }
}

impl LlgParams {
    /// Thermal stability factor Δ = μ0 Ms H_k V / (2 kT).
    pub fn thermal_stability(&self) -> f64 {
        MU0 * self.ms * self.h_k * self.volume / (2.0 * KB * self.temperature)
    }

    /// Damping-like SOT field amplitude (A/m) for charge current `i_a`.
    pub fn h_sot(&self, i_a: f64) -> f64 {
        let j = i_a / self.hm_area;
        HBAR * self.theta_sh * j / (2.0 * E_CHARGE * MU0 * self.ms * self.t_free)
    }

    /// Std-dev of each thermal field component per step (A/m).
    pub fn h_thermal_sigma(&self) -> f64 {
        (2.0 * self.alpha * KB * self.temperature
            / (MU0 * MU0 * self.ms * self.volume * GAMMA * self.dt)
            * (1.0 + self.alpha * self.alpha))
            .sqrt()
    }
}

#[inline]
fn cross(a: [f64; 3], b: [f64; 3]) -> [f64; 3] {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

#[inline]
fn norm(v: [f64; 3]) -> [f64; 3] {
    let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
    [v[0] / n, v[1] / n, v[2] / n]
}

/// One macro-spin trajectory integrator.
pub struct LlgSim {
    pub p: LlgParams,
    rng: CounterRng,
    counter: u32,
}

impl LlgSim {
    pub fn new(p: LlgParams, seed: u32) -> Self {
        Self { p, rng: CounterRng::new(seed), counter: 0 }
    }

    fn thermal_field(&mut self) -> [f64; 3] {
        let s = self.p.h_thermal_sigma();
        let mut h = [0.0; 3];
        for hc in &mut h {
            *hc = s * self.rng.normal(self.counter) as f64;
            self.counter = self.counter.wrapping_add(1);
        }
        h
    }

    /// Deterministic torque dm/dt at magnetization `m` for current `i_a`,
    /// with external field `h_ext` added to H_eff.
    fn torque(&self, m: [f64; 3], i_a: f64, h_th: [f64; 3]) -> [f64; 3] {
        let p = &self.p;
        // H_eff: easy axis ŷ, thin-film demag -Ms m_z ẑ, thermal
        let h_eff = [
            h_th[0],
            p.h_k * m[1] + h_th[1],
            -p.ms * m[2] + h_th[2],
        ];
        let sigma = [0.0, 1.0, 0.0]; // spin polarization (HM current ∥ x̂)
        let beta = p.h_sot(i_a);
        let gamma_p = GAMMA * MU0 / (1.0 + p.alpha * p.alpha);

        let m_x_h = cross(m, h_eff);
        let m_x_mh = cross(m, m_x_h);
        let m_x_s = cross(m, sigma);
        let m_x_ms = cross(m, m_x_s);
        let mut dm = [0.0; 3];
        for k in 0..3 {
            dm[k] = -gamma_p
                * (m_x_h[k] + p.alpha * m_x_mh[k] + beta * m_x_ms[k]);
        }
        dm
    }

    /// Integrate one pulse of length `t_pulse` at current `i_a`, starting
    /// from `m0`; returns the final magnetization (Heun / RK2 stochastic).
    pub fn run_pulse(&mut self, m0: [f64; 3], i_a: f64, t_pulse: f64) -> [f64; 3] {
        let steps = (t_pulse / self.p.dt).round() as usize;
        let dt = self.p.dt;
        let mut m = norm(m0);
        for _ in 0..steps {
            let h_th = self.thermal_field();
            let k1 = self.torque(m, i_a, h_th);
            let m_pred = norm([
                m[0] + dt * k1[0],
                m[1] + dt * k1[1],
                m[2] + dt * k1[2],
            ]);
            let k2 = self.torque(m_pred, i_a, h_th);
            m = norm([
                m[0] + 0.5 * dt * (k1[0] + k2[0]),
                m[1] + 0.5 * dt * (k1[1] + k2[1]),
                m[2] + 0.5 * dt * (k1[2] + k2[2]),
            ]);
        }
        m
    }

    /// Relax at zero current from near -ŷ, then apply the write pulse and
    /// report whether the device switched to +ŷ.
    pub fn switch_trial(&mut self, i_a: f64, t_pulse: f64) -> bool {
        // slight initial tilt so torques are nonzero
        let m0 = norm([0.05, -1.0, 0.02]);
        let m = self.run_pulse(m0, i_a, t_pulse);
        m[1] > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thermal_stability_in_plausible_range() {
        let d = LlgParams::default().thermal_stability();
        assert!((10.0..120.0).contains(&d), "Δ = {d}");
    }

    #[test]
    fn magnetization_stays_unit_norm() {
        let mut sim = LlgSim::new(LlgParams::default(), 1);
        let m = sim.run_pulse([0.0, -1.0, 0.05], 50e-6, 0.2e-9);
        let n = (m[0] * m[0] + m[1] * m[1] + m[2] * m[2]).sqrt();
        assert!((n - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_current_no_switch() {
        // At Δ≈30+ the state must survive a 2 ns idle period.
        let mut switched = 0;
        for t in 0..20 {
            let mut sim = LlgSim::new(LlgParams::default(), 100 + t);
            if sim.switch_trial(0.0, 2e-9) {
                switched += 1;
            }
        }
        assert!(switched <= 1, "{switched}/20 switched at I=0");
    }

    #[test]
    fn large_positive_current_switches() {
        // 100 µA sits above the anti-damping threshold but inside the
        // stochastic band (P ≈ 0.9); 140 µA is deep in saturation.
        let count = |i_a: f64, base: u32| -> u32 {
            (0..20)
                .filter(|t| {
                    LlgSim::new(LlgParams::default(), base + t).switch_trial(i_a, 2e-9)
                })
                .count() as u32
        };
        let at_100 = count(100e-6, 200);
        let at_140 = count(140e-6, 600);
        assert!(at_100 >= 14, "{at_100}/20 switched at +100µA");
        assert!(at_140 >= 18, "{at_140}/20 switched at +140µA");
    }

    #[test]
    fn negative_current_holds_minus_state() {
        let mut switched = 0;
        for t in 0..20 {
            let mut sim = LlgSim::new(LlgParams::default(), 300 + t);
            if sim.switch_trial(-100e-6, 2e-9) {
                switched += 1;
            }
        }
        assert!(switched <= 2, "{switched}/20 switched at -100µA");
    }

    #[test]
    fn sot_field_scale() {
        let p = LlgParams::default();
        let h = p.h_sot(100e-6);
        // must be a sizeable fraction of H_k for ns switching
        assert!(h > 0.1 * p.h_k && h < 10.0 * p.h_k, "H_sot = {h}");
    }
}
