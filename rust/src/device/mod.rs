//! SOT-MTJ device substrate (paper §3.1, Fig. 2, Table 1).
//!
//! The paper characterizes its analog-to-stochastic converter with a
//! MATLAB macro-spin Landau-Lifshitz-Gilbert simulator + the Spinlib
//! SOT-MTJ compact model and a GF 22FDX voltage-divider circuit.  We build
//! the same chain in Rust (DESIGN.md §3 substitution table):
//!
//! * [`llg`] — stochastic macro-spin LLG solver with spin-orbit torque and
//!   thermal fluctuation field (Heun scheme);
//! * [`mtj`] — the SOT-MTJ device: Table 1 geometry/resistances, switching
//!   probability extraction, and the tanh(α·x) fit that grounds Eq. 1;
//! * [`converter`] — the voltage-divider + inverter converter circuit:
//!   transfer curve, per-conversion energy/latency/area (Table 2 row).

pub mod converter;
pub mod llg;
pub mod mtj;

pub use converter::MtjConverter;
pub use llg::{LlgParams, LlgSim};
pub use mtj::{SotMtj, SwitchingCurve};
