//! The analog-to-stochastic converter circuit (Fig. 2 right):
//! SOT-MTJ + reference MTJ voltage divider + CMOS inverter.
//!
//! The paper reduces this circuit to three scalars that enter the
//! architecture model (Table 2 row "MTJ-Converter"): energy/conversion
//! ≈ 6.14 fJ, latency 2 ns, area 1.47 µm² (28 nm-scaled).  We derive the
//! energy from the electrical model (write dissipation in the HM path +
//! read dissipation in the divider) and carry the paper's calibrated
//! constants alongside; `tests` assert the derivation lands within a
//! small factor of the calibrated value.

use super::mtj::SotMtj;

/// Paper-calibrated Table 2 constants (28 nm node).
pub const PAPER_ENERGY_PER_CONVERSION_J: f64 = 6.14e-15;
pub const PAPER_SET_ENERGY_J: f64 = 6.35e-15;
pub const PAPER_RESET_ENERGY_J: f64 = 5.94e-15;
pub const PAPER_LATENCY_S: f64 = 2e-9;
pub const PAPER_AREA_UM2: f64 = 1.47;
/// As-drawn area in GF 22FDSOI before the 28 nm scaling (§3.1).
pub const AREA_22FDSOI_UM2: f64 = 0.9108;

/// Behavioral model of one stochastic MTJ converter instance.
#[derive(Debug, Clone, Copy)]
pub struct MtjConverter {
    pub mtj: SotMtj,
    /// read-phase duration as a fraction of the conversion window
    pub read_duty: f64,
    /// inverter + latch switched capacitance per read (F)
    pub c_read: f64,
}

impl Default for MtjConverter {
    fn default() -> Self {
        Self {
            mtj: SotMtj::default(),
            read_duty: 0.25,
            c_read: 0.9e-15,
        }
    }
}

impl MtjConverter {
    /// Write (set/reset) energy: dissipation of the column current in the
    /// HM write path over the pulse, at mean |I| = i_max/2 for a uniform
    /// current distribution.
    pub fn write_energy(&self) -> f64 {
        let i_rms2 = self.mtj.i_write_max * self.mtj.i_write_max / 3.0; // E[I²], I~U(-max,max)
        i_rms2 * self.mtj.r_hm() * self.mtj.t_pulse
    }

    /// Read energy: divider static draw during the read phase + inverter
    /// switched capacitance.
    pub fn read_energy(&self) -> f64 {
        let t_read = self.read_duty * self.mtj.t_pulse;
        let r_div_avg =
            0.5 * (self.mtj.r_lrs + self.mtj.r_hrs()) + self.mtj.r_ref;
        let static_e = self.mtj.v_dd * self.mtj.v_dd / r_div_avg * t_read;
        let dyn_e = self.c_read * self.mtj.v_dd * self.mtj.v_dd;
        static_e + dyn_e
    }

    /// Total derived energy per conversion (J).
    pub fn energy_per_conversion(&self) -> f64 {
        self.write_energy() + self.read_energy()
    }

    /// Conversion latency (s): one write pulse + read.
    pub fn latency(&self) -> f64 {
        self.mtj.t_pulse
    }

    /// Area per instance (µm², 28 nm-scaled) — the converter is MTJ +
    /// divider + inverter; dominated by the two transistor stacks.
    pub fn area_um2(&self) -> f64 {
        // 22FDSOI drawn area scaled to 28 nm: (28/22)² ≈ 1.62
        AREA_22FDSOI_UM2 * (28.0 / 22.0) * (28.0 / 22.0)
    }

    /// Inverter output for a divider voltage: '1' when the MTJ is in the
    /// high-resistance state (digital readout of the stochastic bit).
    pub fn read_bit(&self, mtj_high: bool) -> bool {
        let v_mid =
            0.5 * (self.mtj.divider_voltage(true) + self.mtj.divider_voltage(false));
        self.mtj.divider_voltage(mtj_high) > v_mid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_energy_close_to_paper() {
        let c = MtjConverter::default();
        let e = c.energy_per_conversion();
        // electrical derivation must land within ~2.5x of the calibrated
        // 6.14 fJ (the PDK-level extraction we cannot rerun here)
        assert!(
            e > PAPER_ENERGY_PER_CONVERSION_J / 2.5
                && e < PAPER_ENERGY_PER_CONVERSION_J * 2.5,
            "derived {e:.3e} vs paper {PAPER_ENERGY_PER_CONVERSION_J:.3e}"
        );
    }

    #[test]
    fn write_energy_dominates() {
        let c = MtjConverter::default();
        assert!(c.write_energy() > c.read_energy());
    }

    #[test]
    fn latency_is_2ns() {
        assert_eq!(MtjConverter::default().latency(), 2e-9);
    }

    #[test]
    fn area_scaling() {
        let a = MtjConverter::default().area_um2();
        assert!((a - PAPER_AREA_UM2).abs() / PAPER_AREA_UM2 < 0.01, "area {a}");
    }

    #[test]
    fn readout_separates_states() {
        let c = MtjConverter::default();
        assert!(c.read_bit(true));
        assert!(!c.read_bit(false));
    }

    #[test]
    fn set_reset_asymmetry_small() {
        // Paper: 6.35 vs 5.94 fJ — asymmetry under 10%
        let asym = (PAPER_SET_ENERGY_J - PAPER_RESET_ENERGY_J)
            / PAPER_ENERGY_PER_CONVERSION_J;
        assert!(asym.abs() < 0.1);
    }
}
