//! SOT-MTJ device model: Table 1 parameters, switching-probability
//! extraction from the LLG solver, and the tanh fit that grounds Eq. 1.

use super::llg::{LlgParams, LlgSim};
use crate::util::pool;

/// Table 1 device parameters (electrical side).
#[derive(Debug, Clone, Copy)]
pub struct SotMtj {
    /// low-resistance (parallel) state (Ω) — Table 1: 57 kΩ
    pub r_lrs: f64,
    /// tunnel magnetoresistance ratio — Table 1: 4.4 (440%)
    pub tmr: f64,
    /// heavy-metal resistivity (Ω·m) — Table 1: 160 µΩ·cm
    pub hm_resistivity: f64,
    /// HM length / width / thickness (m) — Table 1: 144 × 112 × 3.5 nm
    pub hm_dims: [f64; 3],
    /// reference MTJ in the divider (Ω) — Table 1: 140 kΩ
    pub r_ref: f64,
    /// supply voltage (V)
    pub v_dd: f64,
    /// write-current range (A) — Table 1: 0–±100 µA
    pub i_write_max: f64,
    /// conversion (pulse) time (s) — paper: 2 ns
    pub t_pulse: f64,
    /// HM bias current placing the device at its 50% switching point
    /// (standard stochastic-neuron biasing [Sengupta'16]); the bipolar
    /// column current is superposed on this bias.
    pub i_bias: f64,
    /// column-current → HM-current gain of the divider front-end
    pub signal_gain: f64,
}

impl Default for SotMtj {
    fn default() -> Self {
        Self {
            r_lrs: 57e3,
            tmr: 4.4,
            hm_resistivity: 160e-8,
            hm_dims: [144e-9, 112e-9, 3.5e-9],
            r_ref: 140e3,
            v_dd: 1.0,
            i_write_max: 100e-6,
            t_pulse: 2e-9,
            i_bias: 82e-6,
            signal_gain: 0.25,
        }
    }
}

impl SotMtj {
    /// high-resistance (antiparallel) state: R_AP = R_P (1 + TMR)
    pub fn r_hrs(&self) -> f64 {
        self.r_lrs * (1.0 + self.tmr)
    }

    /// Heavy-metal write-path resistance ρL/(w·t).
    pub fn r_hm(&self) -> f64 {
        let [l, w, t] = self.hm_dims;
        self.hm_resistivity * l / (w * t)
    }

    /// Divider output voltage in each state (read path).
    pub fn divider_voltage(&self, high_state: bool) -> f64 {
        let r = if high_state { self.r_hrs() } else { self.r_lrs };
        self.v_dd * r / (r + self.r_ref)
    }

    /// Read margin seen by the inverter (V).
    pub fn read_margin(&self) -> f64 {
        self.divider_voltage(true) - self.divider_voltage(false)
    }
}

/// Empirical switching-probability curve P(+1) vs write current.
#[derive(Debug, Clone)]
pub struct SwitchingCurve {
    /// probed currents (A)
    pub currents: Vec<f64>,
    /// empirical switch probability at each current
    pub prob: Vec<f64>,
    /// trials per point
    pub trials: u32,
}

impl SwitchingCurve {
    /// Monte-Carlo extraction from the LLG solver (Fig. 2's experiment):
    /// sweep `n_points` currents over ±i_max, `trials` pulses each.
    pub fn extract(
        llg: LlgParams,
        mtj: &SotMtj,
        n_points: usize,
        trials: u32,
        seed: u32,
    ) -> Self {
        let currents: Vec<f64> = (0..n_points)
            .map(|i| {
                mtj.i_write_max * (2.0 * i as f64 / (n_points - 1) as f64 - 1.0)
            })
            .collect();
        let prob: Vec<f64> =
            pool::par_map(currents.len(), pool::default_threads(), |pi| {
                // signal current superposed on the 50%-point bias
                let i_hm = mtj.i_bias + mtj.signal_gain * currents[pi];
                let mut hits = 0u32;
                for t in 0..trials {
                    let s = seed
                        .wrapping_add(pi as u32 * 7919)
                        .wrapping_add(t.wrapping_mul(104_729));
                    let mut sim = LlgSim::new(llg, s);
                    if sim.switch_trial(i_hm, mtj.t_pulse) {
                        hits += 1;
                    }
                }
                hits as f64 / trials as f64
            });
        Self { currents, prob, trials }
    }

    /// Least-squares fit of P(i) = (tanh(α·i/i_max)+1)/2: coarse
    /// multiplicative sweep + two rounds of local refinement — the bridge
    /// from device physics to Eq. 1's abstraction.
    pub fn fit_tanh_alpha(&self, i_max: f64) -> (f64, f64) {
        let sse_at = |alpha: f64| -> f64 {
            self.currents
                .iter()
                .zip(&self.prob)
                .map(|(&i, &p)| {
                    let model = 0.5 * ((alpha * i / i_max).tanh() + 1.0);
                    (model - p) * (model - p)
                })
                .sum()
        };
        let mut best = (1.0, f64::INFINITY);
        let mut alpha = 0.2;
        while alpha < 60.0 {
            let sse = sse_at(alpha);
            if sse < best.1 {
                best = (alpha, sse);
            }
            alpha *= 1.05;
        }
        // local refinement around the coarse winner
        let mut step = best.0 * 0.05;
        for _ in 0..2 {
            let center = best.0;
            let mut a = (center - 10.0 * step).max(1e-3);
            while a <= center + 10.0 * step {
                let sse = sse_at(a);
                if sse < best.1 {
                    best = (a, sse);
                }
                a += step;
            }
            step *= 0.1;
        }
        best
    }

    /// Monotonicity violations (noise metric for the extraction).
    pub fn monotonicity_violations(&self, tol: f64) -> usize {
        self.prob
            .windows(2)
            .filter(|w| w[1] + tol < w[0])
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_derived_quantities() {
        let m = SotMtj::default();
        assert!((m.r_hrs() - 57e3 * 5.4).abs() < 1.0);
        // ρL/(wt) = 160e-8 * 144e-9 / (112e-9*3.5e-9) ≈ 588 Ω
        assert!((m.r_hm() - 587.75).abs() < 5.0, "r_hm = {}", m.r_hm());
        assert!(m.read_margin() > 0.2, "margin {}", m.read_margin());
    }

    #[test]
    fn divider_levels_ordered() {
        let m = SotMtj::default();
        assert!(m.divider_voltage(true) > m.divider_voltage(false));
        assert!(m.divider_voltage(true) < m.v_dd);
    }

    #[test]
    fn switching_curve_is_sigmoidal() {
        // Small extraction (fast in release; ~seconds in debug): 9 points,
        // 24 trials.
        let curve = SwitchingCurve::extract(
            LlgParams::default(),
            &SotMtj::default(),
            9,
            24,
            42,
        );
        let p = &curve.prob;
        assert!(p[0] < 0.2, "P(-100µA) = {}", p[0]);
        assert!(p[8] > 0.8, "P(+100µA) = {}", p[8]);
        let mid = p[4];
        assert!((0.15..=0.85).contains(&mid), "P(0) = {mid}");
        assert!(curve.monotonicity_violations(0.25) == 0);
    }

    #[test]
    fn tanh_fit_reasonable() {
        // Fit on synthetic data with known alpha
        let i_max = 100e-6;
        let currents: Vec<f64> =
            (0..21).map(|i| i_max * (i as f64 / 10.0 - 1.0)).collect();
        let prob: Vec<f64> = currents
            .iter()
            .map(|&i| 0.5 * ((4.0 * i / i_max).tanh() + 1.0))
            .collect();
        let curve = SwitchingCurve { currents, prob, trials: 0 };
        let (alpha, sse) = curve.fit_tanh_alpha(i_max);
        assert!((alpha - 4.0).abs() < 0.25, "alpha {alpha}");
        assert!(sse < 1e-4);
    }
}
