//! Crossbar non-idealities — the analog error sources the paper's
//! stochastic conversion must tolerate on real hardware (extension per
//! DESIGN.md: the paper's future-work axis of robustness).
//!
//! Two severity families:
//!
//! **Soft (parametric) errors** perturb the analog path continuously:
//!
//! * **conductance variation** — per-cell programming error, lognormal-ish
//!   multiplicative spread σ_g on each weight digit; static per crossbar
//!   (drawn once at programming time from the counter RNG);
//! * **IR drop** — wire resistance attenuates rows far from the driver:
//!   row r sees its contribution scaled by `1 - ir_drop · r / R_arr`
//!   (first-order PUMA-style model);
//! * **read noise** — zero-mean Gaussian on each PS sample (thermal +
//!   shot noise of the column), σ_read in normalized-PS units;
//! * **conductance drift** — every programmed cell decays toward zero
//!   over the elapsed "time" since programming:
//!   `g ← g · exp(−drift · drift_time)` (retention-loss model).
//!
//! **Hard faults** break devices outright:
//!
//! * **stuck-at-zero / stuck-at-one cells** — a fraction of cells is
//!   stuck open (digit reads 0) or shorted (digit reads the max slice
//!   digit), regardless of what was programmed;
//! * **stuck MTJ converters** — a fraction of per-(array, column)
//!   output converters is pinned: every conversion on that column of
//!   that array reads a constant ±1 (a dead sense path);
//! * **sample dropout** — each conversion independently returns 0 with
//!   probability `sample_dropout` (a dropped stochastic read).
//!
//! All fault membership is drawn at *programming* time from severity-keyed
//! counter RNG streams, one stream per fault type, with the draw counter
//! equal to the cell / converter index.  Because membership is the event
//! `uniform(index) < severity`, the faulty set at a lower severity is a
//! **subset** of the set at a higher severity on the same die
//! (`prog_seed`) — severity ladders degrade monotonically instead of
//! jumping between unrelated fault patterns.
//!
//! [`NonidealCrossbar`] wraps a programmed [`StoxMvm`] and perturbs its
//! PS stream; because the stochastic MTJ converter already tolerates PS
//! noise by construction (Eq. 1's sloped tanh), the interesting output is
//! the accuracy-vs-severity curve (`stox-cli nonideal`).

use super::convert::PsConvert;
use super::mvm::StoxMvm;
use super::quant::{self, StoxConfig};
use crate::stats::rng::CounterRng;

/// Severity knobs; all default to 0 (ideal).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Nonideality {
    /// per-cell conductance spread (relative σ, e.g. 0.1 = 10 %)
    pub sigma_g: f32,
    /// full-array IR-drop coefficient (fraction lost at the far row)
    pub ir_drop: f32,
    /// additive read noise per conversion (normalized-PS σ)
    pub sigma_read: f32,
    /// fraction of cells stuck open — their digit reads 0
    pub stuck_zero: f32,
    /// fraction of cells stuck shorted — their digit reads the max
    /// slice digit `(1 << w_slice_bits) − 1`
    pub stuck_one: f32,
    /// fraction of per-(array, column) MTJ output converters pinned to a
    /// constant ±1 reading
    pub stuck_mtj: f32,
    /// conductance drift rate (relative decay per unit `drift_time`)
    pub drift: f32,
    /// elapsed "time" since programming, in drift units
    pub drift_time: f32,
    /// per-conversion probability that the stochastic read is dropped
    /// (the conversion returns 0)
    pub sample_dropout: f32,
}

impl Nonideality {
    pub fn is_ideal(&self) -> bool {
        *self == Self::default()
    }
}

/// Programming-time RNG stream salts, one per independent fault type so a
/// ladder over one severity never reshuffles another fault's membership.
const GAIN_SALT: u32 = 0x5EED_CE11;
const STUCK_ZERO_SALT: u32 = 0x5A00_0C11;
const STUCK_ONE_SALT: u32 = 0x5A01_0C11;
const STUCK_MTJ_SALT: u32 = 0x5A17_0C11;
/// Run-time salt of the per-conversion dropout draw.
const DROPOUT_SALT: u32 = 0x0D20_0007;

/// A programmed crossbar with analog error models applied.
pub struct NonidealCrossbar {
    /// programmed with the f32 reference plane layout
    /// ([`StoxMvm::program_reference`]); kept for the ideal-path
    /// comparison and the quantization metadata
    mvm: StoxMvm,
    nonideal: Nonideality,
    /// the *effective* weight digits the analog array actually realizes:
    /// programmed digit × cell gain × drift attenuation, with stuck cells
    /// overridden — precomputed once at programming time so the MVM hot
    /// loop stays a plain multiply-accumulate.  At zero severity every
    /// factor is exactly 1.0 (and no overrides fire), so these planes are
    /// bit-identical to the programmed ones.
    eff_planes: Vec<f32>,
    /// per-(array, column) stuck-converter override: `Some(±1.0)` pins
    /// every conversion of that column of that array
    mtj_stuck: Vec<Option<f32>>,
}

impl NonidealCrossbar {
    /// Program the crossbar and freeze its per-cell variation and fault
    /// pattern (seeded — a different `prog_seed` is a different physical
    /// die).
    pub fn program(
        w: &[f32],
        m: usize,
        n: usize,
        cfg: StoxConfig,
        nonideal: Nonideality,
        prog_seed: u32,
    ) -> crate::Result<Self> {
        let mvm = StoxMvm::program_reference(w, m, n, cfg)?;
        let planes = mvm
            .planes_f32_ref()
            .expect("nonideal crossbar programs the f32 reference layout");

        let gain_rng = CounterRng::new(prog_seed ^ GAIN_SALT);
        let zero_rng = CounterRng::new(prog_seed ^ STUCK_ZERO_SALT);
        let one_rng = CounterRng::new(prog_seed ^ STUCK_ONE_SALT);
        // exp(−0·t) and exp(−d·0) are exactly 1.0, so the drift factor is
        // an exact identity whenever drift is off
        let atten = (-nonideal.drift * nonideal.drift_time).exp();
        let max_digit = ((1u32 << cfg.w_slice_bits) - 1) as f32;
        let eff_planes: Vec<f32> = planes
            .iter()
            .enumerate()
            .map(|(idx, &digit)| {
                let c = idx as u32;
                let g = (1.0 + nonideal.sigma_g * gain_rng.normal(c)).max(0.0);
                let mut v = digit * g * atten;
                if nonideal.stuck_one > 0.0 && one_rng.uniform(c) < nonideal.stuck_one {
                    v = max_digit; // shorted: max conductance, no drift
                }
                if nonideal.stuck_zero > 0.0 && zero_rng.uniform(c) < nonideal.stuck_zero {
                    v = 0.0; // stuck-open wins when both faults hit a cell
                }
                v
            })
            .collect();

        let mtj_rng = CounterRng::new(prog_seed ^ STUCK_MTJ_SALT);
        let mtj_stuck: Vec<Option<f32>> = (0..mvm.n_arrs() * n)
            .map(|idx| {
                let c = idx as u32;
                // separate membership and sign counters: the pinned value
                // of a converter does not change as severity grows
                if nonideal.stuck_mtj > 0.0 && mtj_rng.uniform(2 * c) < nonideal.stuck_mtj {
                    Some(if mtj_rng.uniform(2 * c + 1) < 0.5 { -1.0 } else { 1.0 })
                } else {
                    None
                }
            })
            .collect();

        Ok(Self { mvm, nonideal, eff_planes, mtj_stuck })
    }

    pub fn cfg(&self) -> &StoxConfig {
        &self.mvm.cfg
    }

    /// Run a batch through the non-ideal array (mirrors `StoxMvm::run`
    /// with the error models injected into the analog path).
    pub fn run<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        let cfg = &self.mvm.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let m = self.mvm.m;
        let n = self.mvm.n;
        let n_arrs = self.mvm.n_arrs();
        let samples = conv.samples() as f32;
        let rng = CounterRng::new(seed);
        let noise_rng = CounterRng::new(seed ^ 0x0C0_FFEE);
        let drop_rng = CounterRng::new(seed ^ DROPOUT_SALT);
        let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
        let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);
        let lev = (((1u64 << cfg.a_bits) - 1) * ((1u64 << cfg.w_bits) - 1)) as f32;
        let norm = 1.0 / (lev * n_arrs as f32 * samples);
        let inv_r = 1.0 / cfg.r_arr as f32;

        let all_planes: &[f32] = &self.eff_planes;
        let mut out = vec![0.0f32; batch * n];
        let mut digits = vec![0i32; i_n];
        let mut xd = vec![0.0f32; cfg.r_arr * i_n];
        let mut ps = vec![0.0f32; i_n * n];
        // per-slice scratch: noisy normalized PS in, converted values out
        let mut psn = vec![0.0f32; n];
        let mut cv = vec![0.0f32; n];
        let mut noise_c = 0u32;

        for b in 0..batch {
            for k in 0..n_arrs {
                let row0 = k * cfg.r_arr;
                let rows = (m - row0).min(cfg.r_arr);
                for rr in 0..rows {
                    let u = quant::quantize_unit(a[b * m + row0 + rr], cfg.a_bits);
                    quant::signed_digits(u, cfg.a_bits, cfg.a_stream_bits, &mut digits);
                    // IR drop: rows electrically farther from the driver
                    // contribute attenuated current
                    let atten =
                        1.0 - self.nonideal.ir_drop * rr as f32 * inv_r;
                    for (i, &d) in digits.iter().enumerate() {
                        xd[rr * i_n + i] = d as f32 * atten;
                    }
                }
                for j in 0..j_n {
                    ps.iter_mut().for_each(|v| *v = 0.0);
                    let plane_sz = cfg.r_arr * n;
                    let w_sl =
                        &all_planes[(k * j_n + j) * plane_sz..(k * j_n + j + 1) * plane_sz];
                    for rr in 0..rows {
                        let wrow = &w_sl[rr * n..(rr + 1) * n];
                        let xr = &xd[rr * i_n..rr * i_n + i_n];
                        for (i, &x) in xr.iter().enumerate() {
                            let acc = &mut ps[i * n..(i + 1) * n];
                            for c in 0..n {
                                acc[c] += x * wrow[c];
                            }
                        }
                    }
                    for i in 0..i_n {
                        let scale = sa[i] * sw[j] * norm;
                        for (c, pn) in psn.iter_mut().enumerate() {
                            let mut v = ps[i * n + c] * inv_r;
                            if self.nonideal.sigma_read > 0.0 {
                                v += self.nonideal.sigma_read
                                    * noise_rng.normal(noise_c);
                                noise_c = noise_c.wrapping_add(1);
                            }
                            *pn = v;
                        }
                        // same frozen counter layout as StoxMvm::run_range:
                        // the column slice is (base(0), stride I·J)
                        let base0 = ((((b * n_arrs + k) * n) * i_n + i) as u32)
                            .wrapping_mul(j_n as u32)
                            .wrapping_add(j as u32);
                        let stride = (i_n * j_n) as u32;
                        conv.convert_slice_at(i, j, &psn, &mut cv, base0, stride, &rng);
                        for (c, v) in cv.iter_mut().enumerate() {
                            // dropout is keyed to the same per-conversion
                            // counter the converter used, under its own
                            // seed stream — deterministic, converter-blind
                            if self.nonideal.sample_dropout > 0.0 {
                                let cc = base0
                                    .wrapping_add((c as u32).wrapping_mul(stride));
                                if drop_rng.uniform(cc) < self.nonideal.sample_dropout {
                                    *v = 0.0;
                                }
                            }
                            // a pinned converter reads its stuck value no
                            // matter what the column current was
                            if let Some(s) = self.mtj_stuck[k * n + c] {
                                *v = s;
                            }
                        }
                        for (c, &v) in cv.iter().enumerate() {
                            out[b * n + c] += v * scale;
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::converters::PsConverter;
    use super::*;

    fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
        let rng = CounterRng::new(seed);
        (0..n).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect()
    }

    fn setup(nonideal: Nonideality) -> (Vec<f32>, NonidealCrossbar) {
        let (m, n) = (96usize, 8usize);
        let a = rand_vec(2 * m, 1);
        let w = rand_vec(m * n, 2);
        let cfg = StoxConfig { r_arr: 96, w_slice_bits: 1, ..Default::default() };
        let xb = NonidealCrossbar::program(&w, m, n, cfg, nonideal, 7).unwrap();
        (a, xb)
    }

    fn rms(a: &[f32], b: &[f32]) -> f32 {
        (a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / a.len() as f32)
            .sqrt()
    }

    #[test]
    fn zero_severity_matches_ideal_path() {
        let (a, xb) = setup(Nonideality::default());
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        let got = xb.run(&a, 2, &conv, 9);
        let want = xb.mvm.run(&a, 2, &conv, 9);
        assert_eq!(got, want, "ideal nonideal == StoxMvm");
    }

    #[test]
    fn error_grows_with_severity() {
        let conv = PsConverter::ExpectedMtj { alpha: 4.0 };
        let (a, ideal) = setup(Nonideality::default());
        let base = ideal.run(&a, 2, &conv, 0);
        let mut last_err = 0.0f32;
        for sigma in [0.05f32, 0.15, 0.4] {
            let (_, xb) = setup(Nonideality { sigma_g: sigma, ..Default::default() });
            let got = xb.run(&a, 2, &conv, 0);
            let err: f32 = got
                .iter()
                .zip(&base)
                .map(|(g, b)| (g - b).abs())
                .fold(0.0, f32::max);
            assert!(err >= last_err * 0.5, "σ_g={sigma}: err {err} vs {last_err}");
            last_err = err;
        }
        assert!(last_err > 1e-4, "large variation must visibly perturb");
    }

    #[test]
    fn ir_drop_attenuates_output() {
        // all-positive operands: IR drop strictly reduces the PS magnitude
        let (m, n) = (64usize, 4usize);
        let a = vec![0.8f32; m];
        let w = vec![0.5f32; m * n];
        let cfg = StoxConfig { r_arr: 64, w_slice_bits: 1, ..Default::default() };
        let ideal = NonidealCrossbar::program(&w, m, n, cfg, Nonideality::default(), 1)
            .unwrap();
        let droopy = NonidealCrossbar::program(
            &w, m, n, cfg,
            Nonideality { ir_drop: 0.3, ..Default::default() }, 1,
        )
        .unwrap();
        let conv = PsConverter::IdealAdc;
        let o1 = ideal.run(&a, 1, &conv, 0);
        let o2 = droopy.run(&a, 1, &conv, 0);
        for (x, y) in o1.iter().zip(&o2) {
            assert!(y < x, "{y} !< {x}");
            assert!(*y > 0.0);
        }
    }

    #[test]
    fn read_noise_decorrelates_reads_but_multisampling_averages() {
        let (a, xb) = setup(Nonideality { sigma_read: 0.2, ..Default::default() });
        let exp = PsConverter::ExpectedMtj { alpha: 2.0 };
        let (_, ideal) = setup(Nonideality::default());
        let base = ideal.run(&a, 2, &exp, 0);
        // stochastic 8-sample read under noise stays closer to the ideal
        // expectation than a 1-sample read (multi-sampling as error tool)
        let mse = |ns: u32, seed: u32| -> f32 {
            let c = PsConverter::StochasticMtj { alpha: 2.0, n_samples: ns };
            let o = xb.run(&a, 2, &c, seed);
            o.iter().zip(&base).map(|(g, b)| (g - b) * (g - b)).sum::<f32>()
                / o.len() as f32
        };
        let e1: f32 = (0..8).map(|s| mse(1, s)).sum::<f32>() / 8.0;
        let e8: f32 = (0..8).map(|s| mse(8, s)).sum::<f32>() / 8.0;
        assert!(e8 < e1, "8-sample {e8} !< 1-sample {e1}");
    }

    #[test]
    fn programming_is_deterministic_per_seed() {
        let (a, xb1) = setup(Nonideality { sigma_g: 0.2, ..Default::default() });
        let (_, xb2) = setup(Nonideality { sigma_g: 0.2, ..Default::default() });
        let conv = PsConverter::SenseAmp;
        assert_eq!(xb1.run(&a, 2, &conv, 3), xb2.run(&a, 2, &conv, 3));
    }

    /// Stuck-cell severity ladders degrade monotonically: membership is
    /// the event `uniform(cell) < severity` on one RNG stream per fault
    /// type, so each rung's faulty set contains the previous rung's.
    #[test]
    fn stuck_cell_ladders_degrade_monotonically() {
        let conv = PsConverter::ExpectedMtj { alpha: 4.0 };
        let (a, ideal) = setup(Nonideality::default());
        let base = ideal.run(&a, 2, &conv, 0);
        for mk in [
            (|s: f32| Nonideality { stuck_zero: s, ..Default::default() })
                as fn(f32) -> Nonideality,
            (|s: f32| Nonideality { stuck_one: s, ..Default::default() }),
        ] {
            let mut last = 0.0f32;
            for sev in [0.0f32, 0.1, 0.3, 0.6] {
                let (_, xb) = setup(mk(sev));
                let err = rms(&xb.run(&a, 2, &conv, 0), &base);
                assert!(
                    err >= last,
                    "ladder must be monotone: sev {sev} rms {err} < {last}"
                );
                last = err;
            }
            assert!(last > 1e-3, "60 % dead cells must visibly perturb");
        }
    }

    /// Conductance drift is a uniform retention loss: with a linear
    /// converter every output scales by exactly `exp(−drift·t)`, and more
    /// elapsed time means more decay.
    #[test]
    fn drift_attenuates_with_elapsed_time() {
        let (m, n) = (64usize, 4usize);
        let a = vec![0.8f32; m];
        let w = vec![0.5f32; m * n];
        let cfg = StoxConfig { r_arr: 64, w_slice_bits: 1, ..Default::default() };
        let conv = PsConverter::IdealAdc;
        let run_at = |t: f32| -> Vec<f32> {
            NonidealCrossbar::program(
                &w, m, n, cfg,
                Nonideality { drift: 0.5, drift_time: t, ..Default::default() },
                1,
            )
            .unwrap()
            .run(&a, 1, &conv, 0)
        };
        let fresh = run_at(0.0);
        let aged = run_at(1.0);
        let older = run_at(3.0);
        let atten = (-0.5f32).exp();
        for ((f, g), h) in fresh.iter().zip(&aged).zip(&older) {
            assert!(*f > 0.0);
            assert!(
                (g / f - atten).abs() < 1e-3,
                "uniform decay by exp(−0.5): {g}/{f}"
            );
            assert!(h < g, "more elapsed time, more decay");
        }
    }

    /// A fully stuck converter plane reads the same pinned values no
    /// matter what activations are applied — the column outputs become
    /// input-independent constants.
    #[test]
    fn stuck_mtj_pins_converter_outputs() {
        let nonideal = Nonideality { stuck_mtj: 1.0, ..Default::default() };
        let (a, xb) = setup(nonideal);
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        let o1 = xb.run(&a, 2, &conv, 9);
        let other = rand_vec(a.len(), 77);
        let o2 = xb.run(&other, 2, &conv, 9);
        assert_eq!(o1, o2, "pinned converters ignore the input");
        let (_, ideal) = setup(Nonideality::default());
        assert_ne!(o1, ideal.run(&a, 2, &conv, 9), "and are visibly wrong");
        // partial severity: deterministic per seed, and not all pinned
        let (_, half) = setup(Nonideality { stuck_mtj: 0.5, ..Default::default() });
        let h1 = half.run(&a, 2, &conv, 9);
        let h2 = half.run(&other, 2, &conv, 9);
        assert_ne!(h1, h2, "surviving converters still see the input");
    }

    /// Sample dropout is deterministic per seed and total at severity 1
    /// (every conversion dropped ⇒ the output is exactly zero).
    #[test]
    fn sample_dropout_is_deterministic_and_total_at_one() {
        let (a, xb) = setup(Nonideality { sample_dropout: 0.3, ..Default::default() });
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        assert_eq!(xb.run(&a, 2, &conv, 9), xb.run(&a, 2, &conv, 9));
        let (_, ideal) = setup(Nonideality::default());
        assert_ne!(
            xb.run(&a, 2, &conv, 9),
            ideal.run(&a, 2, &conv, 9),
            "30 % dropout must perturb"
        );
        let (_, dead) = setup(Nonideality { sample_dropout: 1.0, ..Default::default() });
        assert!(
            dead.run(&a, 2, &conv, 9).iter().all(|&v| v == 0.0),
            "all conversions dropped ⇒ all-zero output"
        );
    }
}
