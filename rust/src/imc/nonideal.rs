//! Crossbar non-idealities — the analog error sources the paper's
//! stochastic conversion must tolerate on real hardware (extension per
//! DESIGN.md: the paper's future-work axis of robustness).
//!
//! Models (all applied to the *normalized* PS before conversion, matching
//! how they perturb the column current):
//!
//! * **conductance variation** — per-cell programming error, lognormal-ish
//!   multiplicative spread σ_g on each weight digit; static per crossbar
//!   (drawn once at programming time from the counter RNG);
//! * **IR drop** — wire resistance attenuates rows far from the driver:
//!   row r sees its contribution scaled by `1 - ir_drop · r / R_arr`
//!   (first-order PUMA-style model);
//! * **read noise** — zero-mean Gaussian on each PS sample (thermal +
//!   shot noise of the column), σ_read in normalized-PS units.
//!
//! [`NonidealCrossbar`] wraps a programmed [`StoxMvm`] and perturbs its
//! PS stream; because the stochastic MTJ converter already tolerates PS
//! noise by construction (Eq. 1's sloped tanh), the interesting output is
//! the accuracy-vs-severity curve (`stox-cli nonideal`).

use super::convert::PsConvert;
use super::mvm::StoxMvm;
use super::quant::{self, StoxConfig};
use crate::stats::rng::CounterRng;

/// Severity knobs; all default to 0 (ideal).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Nonideality {
    /// per-cell conductance spread (relative σ, e.g. 0.1 = 10 %)
    pub sigma_g: f32,
    /// full-array IR-drop coefficient (fraction lost at the far row)
    pub ir_drop: f32,
    /// additive read noise per conversion (normalized-PS σ)
    pub sigma_read: f32,
}

impl Nonideality {
    pub fn is_ideal(&self) -> bool {
        *self == Self::default()
    }
}

/// A programmed crossbar with analog error models applied.
pub struct NonidealCrossbar {
    /// programmed with the f32 reference plane layout
    /// ([`StoxMvm::program_reference`]): the analog error models multiply
    /// digits by f32 cell gains, so the integer planes would never be
    /// executed here — storing f32 directly avoids a duplicate copy and
    /// the run loop borrows the planes in place.
    mvm: StoxMvm,
    nonideal: Nonideality,
    /// static per-cell multiplicative error, same layout as the weight
    /// digits; drawn once at programming (device-to-device variation)
    cell_gain: Vec<Vec<Vec<f32>>>,
}

impl NonidealCrossbar {
    /// Program the crossbar and freeze its per-cell variation (seeded —
    /// a different `prog_seed` is a different physical die).
    pub fn program(
        w: &[f32],
        m: usize,
        n: usize,
        cfg: StoxConfig,
        nonideal: Nonideality,
        prog_seed: u32,
    ) -> crate::Result<Self> {
        let mvm = StoxMvm::program_reference(w, m, n, cfg)?;
        let rng = CounterRng::new(prog_seed ^ 0x5EED_CE11);
        let n_arrs = mvm.n_arrs();
        let n_slices = cfg.n_slices();
        let mut cell_gain = Vec::with_capacity(n_arrs);
        let mut c = 0u32;
        for _ in 0..n_arrs {
            let mut per_slice = Vec::with_capacity(n_slices);
            for _ in 0..n_slices {
                let gains: Vec<f32> = (0..cfg.r_arr * n)
                    .map(|_| {
                        let g = 1.0 + nonideal.sigma_g * rng.normal(c);
                        c = c.wrapping_add(1);
                        g.max(0.0)
                    })
                    .collect();
                per_slice.push(gains);
            }
            cell_gain.push(per_slice);
        }
        Ok(Self { mvm, nonideal, cell_gain })
    }

    pub fn cfg(&self) -> &StoxConfig {
        &self.mvm.cfg
    }

    /// Run a batch through the non-ideal array (mirrors `StoxMvm::run`
    /// with the three error models injected into the analog path).
    pub fn run<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        let cfg = &self.mvm.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let m = self.mvm.m;
        let n = self.mvm.n;
        let n_arrs = self.mvm.n_arrs();
        let samples = conv.samples() as f32;
        let rng = CounterRng::new(seed);
        let noise_rng = CounterRng::new(seed ^ 0x0C0_FFEE);
        let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
        let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);
        let lev = (((1u64 << cfg.a_bits) - 1) * ((1u64 << cfg.w_bits) - 1)) as f32;
        let norm = 1.0 / (lev * n_arrs as f32 * samples);
        let inv_r = 1.0 / cfg.r_arr as f32;

        let all_planes: &[f32] = self
            .mvm
            .planes_f32_ref()
            .expect("nonideal crossbar programs the f32 reference layout");
        let mut out = vec![0.0f32; batch * n];
        let mut digits = vec![0i32; i_n];
        let mut xd = vec![0.0f32; cfg.r_arr * i_n];
        let mut ps = vec![0.0f32; i_n * n];
        // per-slice scratch: noisy normalized PS in, converted values out
        let mut psn = vec![0.0f32; n];
        let mut cv = vec![0.0f32; n];
        let mut noise_c = 0u32;

        for b in 0..batch {
            for k in 0..n_arrs {
                let row0 = k * cfg.r_arr;
                let rows = (m - row0).min(cfg.r_arr);
                for rr in 0..rows {
                    let u = quant::quantize_unit(a[b * m + row0 + rr], cfg.a_bits);
                    quant::signed_digits(u, cfg.a_bits, cfg.a_stream_bits, &mut digits);
                    // IR drop: rows electrically farther from the driver
                    // contribute attenuated current
                    let atten =
                        1.0 - self.nonideal.ir_drop * rr as f32 * inv_r;
                    for (i, &d) in digits.iter().enumerate() {
                        xd[rr * i_n + i] = d as f32 * atten;
                    }
                }
                for j in 0..j_n {
                    ps.iter_mut().for_each(|v| *v = 0.0);
                    let plane_sz = cfg.r_arr * n;
                    let w_sl =
                        &all_planes[(k * j_n + j) * plane_sz..(k * j_n + j + 1) * plane_sz];
                    let gains = &self.cell_gain[k][j];
                    for rr in 0..rows {
                        let wrow = &w_sl[rr * n..(rr + 1) * n];
                        let grow = &gains[rr * n..(rr + 1) * n];
                        let xr = &xd[rr * i_n..rr * i_n + i_n];
                        for (i, &x) in xr.iter().enumerate() {
                            let acc = &mut ps[i * n..(i + 1) * n];
                            for c in 0..n {
                                acc[c] += x * wrow[c] * grow[c];
                            }
                        }
                    }
                    for i in 0..i_n {
                        let scale = sa[i] * sw[j] * norm;
                        for (c, pn) in psn.iter_mut().enumerate() {
                            let mut v = ps[i * n + c] * inv_r;
                            if self.nonideal.sigma_read > 0.0 {
                                v += self.nonideal.sigma_read
                                    * noise_rng.normal(noise_c);
                                noise_c = noise_c.wrapping_add(1);
                            }
                            *pn = v;
                        }
                        // same frozen counter layout as StoxMvm::run_range:
                        // the column slice is (base(0), stride I·J)
                        let base0 = ((((b * n_arrs + k) * n) * i_n + i) as u32)
                            .wrapping_mul(j_n as u32)
                            .wrapping_add(j as u32);
                        let stride = (i_n * j_n) as u32;
                        conv.convert_slice_at(i, j, &psn, &mut cv, base0, stride, &rng);
                        for (c, &v) in cv.iter().enumerate() {
                            out[b * n + c] += v * scale;
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::converters::PsConverter;
    use super::*;

    fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
        let rng = CounterRng::new(seed);
        (0..n).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect()
    }

    fn setup(nonideal: Nonideality) -> (Vec<f32>, NonidealCrossbar) {
        let (m, n) = (96usize, 8usize);
        let a = rand_vec(2 * m, 1);
        let w = rand_vec(m * n, 2);
        let cfg = StoxConfig { r_arr: 96, w_slice_bits: 1, ..Default::default() };
        let xb = NonidealCrossbar::program(&w, m, n, cfg, nonideal, 7).unwrap();
        (a, xb)
    }

    #[test]
    fn zero_severity_matches_ideal_path() {
        let (a, xb) = setup(Nonideality::default());
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        let got = xb.run(&a, 2, &conv, 9);
        let want = xb.mvm.run(&a, 2, &conv, 9);
        assert_eq!(got, want, "ideal nonideal == StoxMvm");
    }

    #[test]
    fn error_grows_with_severity() {
        let conv = PsConverter::ExpectedMtj { alpha: 4.0 };
        let (a, ideal) = setup(Nonideality::default());
        let base = ideal.run(&a, 2, &conv, 0);
        let mut last_err = 0.0f32;
        for sigma in [0.05f32, 0.15, 0.4] {
            let (_, xb) = setup(Nonideality { sigma_g: sigma, ..Default::default() });
            let got = xb.run(&a, 2, &conv, 0);
            let err: f32 = got
                .iter()
                .zip(&base)
                .map(|(g, b)| (g - b).abs())
                .fold(0.0, f32::max);
            assert!(err >= last_err * 0.5, "σ_g={sigma}: err {err} vs {last_err}");
            last_err = err;
        }
        assert!(last_err > 1e-4, "large variation must visibly perturb");
    }

    #[test]
    fn ir_drop_attenuates_output() {
        // all-positive operands: IR drop strictly reduces the PS magnitude
        let (m, n) = (64usize, 4usize);
        let a = vec![0.8f32; m];
        let w = vec![0.5f32; m * n];
        let cfg = StoxConfig { r_arr: 64, w_slice_bits: 1, ..Default::default() };
        let ideal = NonidealCrossbar::program(&w, m, n, cfg, Nonideality::default(), 1)
            .unwrap();
        let droopy = NonidealCrossbar::program(
            &w, m, n, cfg,
            Nonideality { ir_drop: 0.3, ..Default::default() }, 1,
        )
        .unwrap();
        let conv = PsConverter::IdealAdc;
        let o1 = ideal.run(&a, 1, &conv, 0);
        let o2 = droopy.run(&a, 1, &conv, 0);
        for (x, y) in o1.iter().zip(&o2) {
            assert!(y < x, "{y} !< {x}");
            assert!(*y > 0.0);
        }
    }

    #[test]
    fn read_noise_decorrelates_reads_but_multisampling_averages() {
        let (a, xb) = setup(Nonideality { sigma_read: 0.2, ..Default::default() });
        let exp = PsConverter::ExpectedMtj { alpha: 2.0 };
        let (_, ideal) = setup(Nonideality::default());
        let base = ideal.run(&a, 2, &exp, 0);
        // stochastic 8-sample read under noise stays closer to the ideal
        // expectation than a 1-sample read (multi-sampling as error tool)
        let mse = |ns: u32, seed: u32| -> f32 {
            let c = PsConverter::StochasticMtj { alpha: 2.0, n_samples: ns };
            let o = xb.run(&a, 2, &c, seed);
            o.iter().zip(&base).map(|(g, b)| (g - b) * (g - b)).sum::<f32>()
                / o.len() as f32
        };
        let e1: f32 = (0..8).map(|s| mse(1, s)).sum::<f32>() / 8.0;
        let e8: f32 = (0..8).map(|s| mse(8, s)).sum::<f32>() / 8.0;
        assert!(e8 < e1, "8-sample {e8} !< 1-sample {e1}");
    }

    #[test]
    fn programming_is_deterministic_per_seed() {
        let (a, xb1) = setup(Nonideality { sigma_g: 0.2, ..Default::default() });
        let (_, xb2) = setup(Nonideality { sigma_g: 0.2, ..Default::default() });
        let conv = PsConverter::SenseAmp;
        assert_eq!(xb1.run(&a, 2, &conv, 3), xb2.run(&a, 2, &conv, 3));
    }
}
