//! The open PS-conversion surface: the [`PsConvert`] trait (slice-at-a-time
//! conversion), the converter implementations, and the [`PsConverterSpec`] /
//! [`ConverterRegistry`] construction path.
//!
//! The paper's whole contribution lives at this boundary — ADC vs 1b-SA vs
//! stochastic MTJ, plus §3.2.3's inhomogeneous sampling — so the converter
//! family must be *open* (related designs: arXiv:2408.06390's approximate
//! ADCs, arXiv:2411.19344's Stoch-IMC) and *fast* (one dispatch per PS
//! column slice instead of one per element).
//!
//! Frozen contracts (enforced by `tests/parity.rs` + `tests/converter_equiv.rs`):
//!
//! * the canonical counter layout `base(c) = (((b·K + k)·N + c)·I + i)·J + j`
//!   — a column slice is `(base(0), stride = I·J)`;
//! * the stochastic MTJ per-sample counter `base(c)·n_samples + s` and the
//!   `draw24 < ceil(p·2²⁴)` threshold trick, which together make the Rust
//!   side bit-identical with the python oracle (`ref.stox_mvm`).

use super::quant::StoxConfig;
use crate::arch::components::PsProcessing;
use crate::stats::rng::CounterRng;
use crate::util::json::Json;
use std::sync::OnceLock;

// ---------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------

/// A partial-sum converter: digitizes one crossbar column slice of
/// normalized partial sums (`ps[c] ∈ [-1, 1]`) per call.
///
/// The slice granularity is the point of the API: the MVM kernel pays one
/// (virtual) dispatch per `(batch, subarray, weight-slice, stream)` group
/// instead of one enum match per element, and implementations can
/// precompute per-slice state (quantizer levels, tanh thresholds) and emit
/// branch-free inner loops.
pub trait PsConvert: Send + Sync {
    /// Convert `ps` into `out` (same length). The canonical event counter
    /// of element `idx` is `counter_base + idx·counter_stride` (wrapping);
    /// `rng` carries the pre-mixed seed.
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    );

    /// Significance-aware entry point: the kernel passes the activation
    /// `stream` (i) and weight `w_slice` (j) coordinates of the PS group
    /// so converters like [`InhomogeneousMtjConv`] can vary their sampling
    /// length with bit significance. The default ignores them.
    #[allow(clippy::too_many_arguments)]
    fn convert_slice_at(
        &self,
        stream: usize,
        w_slice: usize,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    ) {
        let _ = (stream, w_slice);
        self.convert_slice(ps, out, counter_base, counter_stride, rng);
    }

    /// Integer digit-domain entry point (the `StoxMvm` integer kernel's
    /// conversion seam): the kernel hands over the raw `i32` PS
    /// accumulator of one column slice plus the normalization factor —
    /// element `c`'s normalized PS is **exactly** `ps_int[c] as f32 *
    /// ps_scale` (the integer kernel's exactness contract).  `cache` is
    /// caller-owned per-run scratch ([`PsIntCache`]); converters with
    /// per-level work (the tanh→threshold of the stochastic MTJ) memoize
    /// it there across calls — partial sums concentrate on few distinct
    /// integer levels (the Fig. 4 observation), so the memo eliminates
    /// most `tanh` evaluations of a run.
    ///
    /// Implementations MUST be bit-identical to materializing the
    /// normalized PS and calling [`PsConvert::convert_slice_at`]; the
    /// default does exactly that (property-pinned in `tests/proptests.rs`).
    #[allow(clippy::too_many_arguments)]
    fn convert_slice_int_at(
        &self,
        stream: usize,
        w_slice: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        let psn = cache.materialize(ps_int, ps_scale);
        self.convert_slice_at(stream, w_slice, psn, out, counter_base, counter_stride, rng);
    }

    /// Batched integer entry point: digitizes a whole `(batch, subarray)`
    /// group — every `(stream i, w_slice j)` column slice of one stripe —
    /// in a single converter call.  `coords[g] = (i, j, counter_base)` and
    /// slice `g` occupies `ps_int[g·n .. (g+1)·n]` / `out[g·n .. (g+1)·n]`;
    /// all slices share `counter_stride`.  The kernel accumulates the whole
    /// group first and converts second, so stochastic converters pay one
    /// dispatch (and one memo/threshold warm-up) per group instead of one
    /// per slice.
    ///
    /// Implementations MUST be bit-identical to looping
    /// [`PsConvert::convert_slice_int_at`] over the slices in `coords`
    /// order — the default does exactly that, and the equivalence is
    /// property-pinned in `tests/proptests.rs` for every registry builtin.
    #[allow(clippy::too_many_arguments)]
    fn convert_batch(
        &self,
        coords: &[(usize, usize, u32)],
        counter_stride: u32,
        n: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        for (g, &(stream, w_slice, base)) in coords.iter().enumerate() {
            self.convert_slice_int_at(
                stream,
                w_slice,
                &ps_int[g * n..(g + 1) * n],
                ps_scale,
                &mut out[g * n..(g + 1) * n],
                base,
                counter_stride,
                rng,
                cache,
            );
        }
    }

    /// Scalar convenience (tests, device-level probes): converts one PS.
    fn convert(&self, ps: f32, counter_base: u32, rng: &CounterRng) -> f32 {
        let mut out = [0.0f32; 1];
        self.convert_slice(&[ps], &mut out, counter_base, 0, rng);
        out[0]
    }

    /// Temporal samples consumed per PS conversion; the MVM kernel folds
    /// `1/samples()` into its output normalization, so converters whose
    /// `convert_slice` emits *unnormalized* sample totals (the stochastic
    /// MTJ parity contract) report their sample count here, while
    /// converters that already emit normalized values report 1.
    fn samples(&self) -> u32 {
        1
    }

    /// Training-side surrogate of this converter's transfer curve (§3.3):
    /// the `train/` subsystem backpropagates through this instead of the
    /// stochastic reads.  The default is the paper's Eq. 1 tanh surrogate
    /// at [`DEFAULT_ALPHA`], so every converter — including registry
    /// extensions that never override it — is trainable out of the box;
    /// the built-ins override it with their exact curve (identity for the
    /// ideal ADC, clip-STE for the quantizing ADCs, hardtanh for 1b-SA,
    /// `tanh(α·ps)` with the converter's own α for the MTJ family).
    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::Tanh { alpha: DEFAULT_ALPHA }
    }

    /// Backward hook: writes `d converted / d ps` of the surrogate for one
    /// PS column slice at significance coordinates `(stream, w_slice)` —
    /// the same coordinates the forward's [`PsConvert::convert_slice_at`]
    /// receives, so converters whose backward varies per (stream, slice)
    /// group (e.g. a future schedule-aware inhomogeneous surrogate) can
    /// key off them.  The default ignores the coordinates and applies
    /// [`PsConvert::surrogate`] elementwise.
    fn grad_slice_at(&self, stream: usize, w_slice: usize, ps: &[f32], out: &mut [f32]) {
        let _ = (stream, w_slice);
        self.surrogate().grad_slice(ps, out);
    }

    /// Which Table-2 component row this converter charges — the hook the
    /// `arch/energy.rs` rollup (and the tile scheduler behind serving
    /// metrics) uses to keep energy accounting in lockstep with the
    /// functional converter actually running.
    fn cost_key(&self) -> PsProcessing;

    /// Human-readable label for reports and benches.
    fn label(&self) -> String;
}

// ---------------------------------------------------------------------
// Training-side surrogate (§3.3 backward)
// ---------------------------------------------------------------------

/// The backward abstraction of a PS converter (§3.3): training
/// backpropagates through the converter's *expected* (infinite-sample)
/// transfer curve, not through individual stochastic reads.  Each variant
/// pairs the surrogate value function with its derivative; the derivative
/// is what [`PsConvert::grad_slice_at`] hands to the `train/` tape.
///
/// Conventions (mirrored exactly by `python/compile/gen_grad_golden.py`):
///
/// * `Identity` — ideal full-precision readout, `d out/d ps = 1`;
/// * `ClipSte` — STE of a clamping quantizer (quant/sparse ADC):
///   derivative 1 inside `[-1, 1]` (inclusive), 0 outside;
/// * `HardTanh` — the 1b-SA sign readout trains as `clip(α·ps, -1, 1)`
///   (Eq. 5's hardtanh STE): derivative `α` while `|α·ps| ≤ 1`, else 0;
/// * `Tanh` — the stochastic/expected/inhomogeneous MTJ family's Eq. 1
///   surrogate `tanh(α·ps)`: derivative `α·(1 − tanh²(α·ps))`, the
///   paper's saturation clamp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PsSurrogate {
    /// Full-precision readout: the identity.
    Identity,
    /// Straight-through clamping quantizer (N-bit ADCs).
    ClipSte,
    /// Hardtanh STE of the deterministic sign readout.
    HardTanh {
        /// Eq. 1 tanh slope (the linear-region gain).
        alpha: f32,
    },
    /// Eq. 1 tanh surrogate of the MTJ family.
    Tanh {
        /// Eq. 1 tanh slope.
        alpha: f32,
    },
}

impl PsSurrogate {
    /// Surrogate transfer value at normalized PS `ps` (the deterministic
    /// curve the finite-difference proptests differentiate).
    #[inline]
    pub fn value(&self, ps: f32) -> f32 {
        match *self {
            PsSurrogate::Identity => ps,
            PsSurrogate::ClipSte => ps.clamp(-1.0, 1.0),
            PsSurrogate::HardTanh { alpha } => (alpha * ps).clamp(-1.0, 1.0),
            PsSurrogate::Tanh { alpha } => (alpha * ps).tanh(),
        }
    }

    /// Surrogate derivative `d value / d ps` at `ps`.
    #[inline]
    pub fn grad(&self, ps: f32) -> f32 {
        match *self {
            PsSurrogate::Identity => 1.0,
            PsSurrogate::ClipSte => {
                if ps.abs() <= 1.0 {
                    1.0
                } else {
                    0.0
                }
            }
            PsSurrogate::HardTanh { alpha } => {
                if (alpha * ps).abs() <= 1.0 {
                    alpha
                } else {
                    0.0
                }
            }
            PsSurrogate::Tanh { alpha } => {
                let t = (alpha * ps).tanh();
                alpha * (1.0 - t * t)
            }
        }
    }

    /// Vectorized [`PsSurrogate::grad`] over one PS column slice.
    pub fn grad_slice(&self, ps: &[f32], out: &mut [f32]) {
        for (o, &p) in out.iter_mut().zip(ps) {
            *o = self.grad(p);
        }
    }
}

// ---------------------------------------------------------------------
// Integer-domain conversion cache
// ---------------------------------------------------------------------

/// Caller-owned scratch for [`PsConvert::convert_slice_int_at`]: a dense
/// memo table over the integer PS levels of one kernel run plus a
/// materialization buffer for converters without an integer fast path.
/// One cache serves one (kernel run, converter) pair; the kernel resets
/// it with the run's PS bound before the first conversion.
#[derive(Default)]
pub struct PsIntCache {
    /// Memoized per-level `u32` payloads (sampling thresholds, or f32
    /// bits for value-memoizing converters), indexed `ps_int + offset`.
    /// `u32::MAX` marks an unfilled slot — unreachable as a real payload
    /// (thresholds are ≤ 2²⁴; `tanh` of a finite input never returns the
    /// NaN with those bits).  Empty disables memoization.
    memo: Vec<u32>,
    offset: i32,
    /// scratch for the default materialize-and-delegate path
    psn: Vec<f32>,
    /// memo lookups answered from the table since the last
    /// [`PsIntCache::take_stats`]
    hits: u64,
    /// memo lookups that computed their payload (including lookups with
    /// memoization disabled) since the last [`PsIntCache::take_stats`]
    misses: u64,
    /// stochastic ±1 MTJ reads drawn through this cache since the last
    /// [`PsIntCache::take_stats`]
    draws: u64,
}

impl PsIntCache {
    /// Level ranges beyond this disable the memo (compute directly)
    /// instead of allocating a multi-MB table.
    const MAX_MEMO_LEVELS: usize = 1 << 20;

    pub fn new() -> Self {
        Self::default()
    }

    /// Prepare the memo for integer PS levels in `[-bound, bound]`
    /// (discarding any previously memoized payloads).
    pub fn reset(&mut self, bound: usize) {
        self.memo.clear();
        if bound <= Self::MAX_MEMO_LEVELS {
            self.offset = bound as i32;
            self.memo.resize(2 * bound + 1, u32::MAX);
        }
    }

    /// Memoized `u32` payload of level `v`; `f` computes it on a miss.
    #[inline]
    fn memo_at(&mut self, v: i32, f: impl FnOnce() -> u32) -> u32 {
        if self.memo.is_empty() {
            self.misses += 1;
            return f();
        }
        let idx = (v + self.offset) as usize;
        let t = self.memo[idx];
        if t != u32::MAX {
            self.hits += 1;
            t
        } else {
            self.misses += 1;
            let t = f();
            self.memo[idx] = t;
            t
        }
    }

    /// Drain the telemetry tallies accumulated since the last call:
    /// `(memo hits, memo misses, MTJ draws)`.  The kernel flushes these
    /// into its [`crate::obs`] counters once per stripe, so the cache's
    /// hot-path cost stays three plain (non-atomic) increments.
    ///
    /// Determinism caveat: on the parallel kernel paths that share one
    /// cache per *worker* (`StoxMvm::run`'s ksplit/batch splits), the
    /// hit/miss split depends on the dynamic task→worker assignment; the
    /// per-image pipelined and sequential paths — everything the scenario
    /// goldens measure — build a fresh cache per call and are exactly
    /// reproducible.  `draws` is workload-linear and deterministic on
    /// every path.
    pub fn take_stats(&mut self) -> (u64, u64, u64) {
        let out = (self.hits, self.misses, self.draws);
        self.hits = 0;
        self.misses = 0;
        self.draws = 0;
        out
    }

    /// Materialize the normalized PS (`ps_int[c]·scale`) for the default
    /// delegate path.
    fn materialize(&mut self, ps_int: &[i32], scale: f32) -> &[f32] {
        self.psn.clear();
        self.psn.extend(ps_int.iter().map(|&p| p as f32 * scale));
        &self.psn
    }
}

// ---------------------------------------------------------------------
// Shared kernels
// ---------------------------------------------------------------------

/// Midtread uniform quantizer over [-1, 1] — must stay expression-identical
/// with the legacy enum path (`2·u/levels − 1`, not a reciprocal multiply)
/// for bit-exact equivalence.
#[inline]
fn quant_midtread(ps: f32, levels: f32) -> f32 {
    let u = ((ps.clamp(-1.0, 1.0) + 1.0) * 0.5 * levels).round_ties_even();
    2.0 * u / levels - 1.0
}

/// Slice-vectorized Eq. 1 sampling: writes the *unnormalized* ±1 sample
/// totals. Per element `idx`, sample `s` uses counter
/// `(counter_base + idx·stride)·counter_block + s` — with
/// `counter_block == n_samples` this is the frozen python-parity layout.
/// Converters that vary the read count per call (inhomogeneous sampling)
/// pass their *maximum* count as `counter_block` so each element owns a
/// disjoint counter range and no draw is ever reused across groups.
/// Thresholds are precomputed per chunk so the tanh pass and the sampling
/// pass both run as tight loops.
#[allow(clippy::too_many_arguments)]
fn stochastic_slice(
    alpha: f32,
    n_samples: u32,
    counter_block: u32,
    ps: &[f32],
    out: &mut [f32],
    counter_base: u32,
    counter_stride: u32,
    rng: &CounterRng,
) {
    debug_assert!(counter_block >= n_samples);
    const LANES: usize = 64;
    let mut thr = [0u32; LANES];
    let mut c0 = counter_base;
    let mut idx = 0usize;
    while idx < ps.len() {
        let hi = (idx + LANES).min(ps.len());
        for (t, &p) in thr.iter_mut().zip(&ps[idx..hi]) {
            // u < p  ⟺  draw24 < ceil(p·2²⁴): u is k·2⁻²⁴ exactly and the
            // f64 scaling of an f32 p by 2²⁴ is exact, so the integer
            // comparison is bit-equivalent to the python side while
            // skipping the per-sample int→float conversion.
            let pr = 0.5 * ((alpha * p).tanh() + 1.0);
            *t = ((pr as f64) * 16_777_216.0).ceil() as u32;
        }
        for (o, &t) in out[idx..hi].iter_mut().zip(thr.iter()) {
            let base = c0.wrapping_mul(counter_block);
            let mut total = 0i32;
            for s in 0..n_samples {
                total += if rng.draw24(base.wrapping_add(s)) < t { 1 } else { -1 };
            }
            *o = total as f32;
            c0 = c0.wrapping_add(counter_stride);
        }
        idx = hi;
    }
}

/// Integer-domain core shared by the stochastic MTJ fast paths: per
/// element, the `ceil(p·2²⁴)` threshold is memoized by integer PS level
/// in `cache`, then `n_samples` ±1 draws are summed in counter blocks of
/// `counter_block` — the exact frozen layout of [`stochastic_slice`]
/// (`base = c0·block`, `draw24 < thr`) — and the total is written as-is
/// (`post_scale == None`, the parity contract's unnormalized counts) or
/// scaled once (`Some(1/n)`, the inhomogeneous normalized means).
#[allow(clippy::too_many_arguments)]
fn stochastic_slice_int(
    alpha: f32,
    n_samples: u32,
    counter_block: u32,
    post_scale: Option<f32>,
    ps_int: &[i32],
    ps_scale: f32,
    out: &mut [f32],
    counter_base: u32,
    counter_stride: u32,
    rng: &CounterRng,
    cache: &mut PsIntCache,
) {
    debug_assert!(counter_block >= n_samples);
    cache.draws += ps_int.len() as u64 * n_samples as u64;
    let mut c0 = counter_base;
    for (o, &pi) in out.iter_mut().zip(ps_int) {
        let thr = cache.memo_at(pi, || {
            let pr = 0.5 * ((alpha * (pi as f32 * ps_scale)).tanh() + 1.0);
            ((pr as f64) * 16_777_216.0).ceil() as u32
        });
        let base = c0.wrapping_mul(counter_block);
        let mut total = 0i32;
        for s in 0..n_samples {
            total += if rng.draw24(base.wrapping_add(s)) < thr { 1 } else { -1 };
        }
        *o = match post_scale {
            Some(inv) => total as f32 * inv,
            None => total as f32,
        };
        c0 = c0.wrapping_add(counter_stride);
    }
}

// ---------------------------------------------------------------------
// Converter implementations
// ---------------------------------------------------------------------

/// Infinite-precision readout (HPFA-style functional reference): a plain
/// copy — the kernel's scale factor applies the rest.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct IdealAdcConv;

impl PsConvert for IdealAdcConv {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        _counter_base: u32,
        _counter_stride: u32,
        _rng: &CounterRng,
    ) {
        out.copy_from_slice(ps);
    }

    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::Identity
    }

    fn cost_key(&self) -> PsProcessing {
        PsProcessing::AdcFullPrecision { share: 16 }
    }

    fn label(&self) -> String {
        "ideal-ADC".into()
    }
}

/// N-bit SAR ADC (midtread uniform over the normalized PS range).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantAdcConv {
    /// ADC resolution in bits (1..=16).
    pub bits: u32,
}

impl PsConvert for QuantAdcConv {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        _counter_base: u32,
        _counter_stride: u32,
        _rng: &CounterRng,
    ) {
        let levels = ((1u64 << self.bits) - 1) as f32;
        for (o, &p) in out.iter_mut().zip(ps) {
            *o = quant_midtread(p, levels);
        }
    }

    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::ClipSte
    }

    fn cost_key(&self) -> PsProcessing {
        if self.bits >= 8 {
            PsProcessing::AdcFullPrecision { share: 16 }
        } else {
            PsProcessing::AdcSparse { share: 16 }
        }
    }

    fn label(&self) -> String {
        format!("quant-ADC({}b)", self.bits)
    }
}

/// Sparsity-aware low-bit ADC (the Fig. 9 sparse-ADC baseline /
/// arXiv:2408.06390): column slices whose partial sums are all exactly
/// zero skip conversion entirely (output 0, no ADC action); everything
/// else quantizes like [`QuantAdcConv`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparseAdcConv {
    /// ADC resolution in bits (1..=16) for non-skipped slices.
    pub bits: u32,
}

impl PsConvert for SparseAdcConv {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        _counter_base: u32,
        _counter_stride: u32,
        _rng: &CounterRng,
    ) {
        if ps.iter().all(|&p| p == 0.0) {
            out.fill(0.0);
            return;
        }
        let levels = ((1u64 << self.bits) - 1) as f32;
        for (o, &p) in out.iter_mut().zip(ps) {
            *o = quant_midtread(p, levels);
        }
    }

    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::ClipSte
    }

    fn cost_key(&self) -> PsProcessing {
        PsProcessing::AdcSparse { share: 16 }
    }

    fn label(&self) -> String {
        format!("sparse-ADC({}b)", self.bits)
    }
}

/// Deterministic 1-bit sign readout ("1b-SA", the HPF+1b-SA baseline).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SenseAmpConv;

impl PsConvert for SenseAmpConv {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        _counter_base: u32,
        _counter_stride: u32,
        _rng: &CounterRng,
    ) {
        for (o, &p) in out.iter_mut().zip(ps) {
            *o = if p >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// 1b-SA trains as `clip(α·ps)` (the hardtanh STE of `sign`); the
    /// unit struct carries no α, so the paper's fitted [`DEFAULT_ALPHA`]
    /// supplies the linear-region gain.
    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::HardTanh { alpha: DEFAULT_ALPHA }
    }

    fn cost_key(&self) -> PsProcessing {
        PsProcessing::SenseAmp
    }

    fn label(&self) -> String {
        "1b-SA".into()
    }
}

/// Infinite-sample limit `tanh(α·ps)` — training-time surrogate and the
/// variance-free reference. Charged as a 1-sample MTJ in the cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpectedMtjConv {
    /// Eq. 1 tanh slope.
    pub alpha: f32,
}

impl PsConvert for ExpectedMtjConv {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        _counter_base: u32,
        _counter_stride: u32,
        _rng: &CounterRng,
    ) {
        for (o, &p) in out.iter_mut().zip(ps) {
            *o = (self.alpha * p).tanh();
        }
    }

    /// Integer fast path: memoizes the `tanh` *value* (as f32 bits) per
    /// integer PS level.
    #[allow(clippy::too_many_arguments)]
    fn convert_slice_int_at(
        &self,
        _stream: usize,
        _w_slice: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        _counter_base: u32,
        _counter_stride: u32,
        _rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        for (o, &pi) in out.iter_mut().zip(ps_int) {
            let bits =
                cache.memo_at(pi, || (self.alpha * (pi as f32 * ps_scale)).tanh().to_bits());
            *o = f32::from_bits(bits);
        }
    }

    /// Batched fast path: one non-virtual loop over the group, sharing the
    /// per-level value memo across all slices (coordinates are ignored —
    /// the expected curve is significance-blind).
    #[allow(clippy::too_many_arguments)]
    fn convert_batch(
        &self,
        coords: &[(usize, usize, u32)],
        _counter_stride: u32,
        n: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        _rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        for g in 0..coords.len() {
            for (o, &pi) in out[g * n..(g + 1) * n]
                .iter_mut()
                .zip(&ps_int[g * n..(g + 1) * n])
            {
                let bits =
                    cache.memo_at(pi, || (self.alpha * (pi as f32 * ps_scale)).tanh().to_bits());
                *o = f32::from_bits(bits);
            }
        }
    }

    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::Tanh { alpha: self.alpha }
    }

    fn cost_key(&self) -> PsProcessing {
        PsProcessing::StochasticMtj { samples: 1 }
    }

    fn label(&self) -> String {
        "expected-MTJ".into()
    }
}

/// The paper's contribution: ±1 reads with `P(+1) = (tanh(α·ps)+1)/2`,
/// `n_samples` reads summed (Eq. 1 + §3.2.3 multi-sampling). Emits the
/// unnormalized ±1 total; the kernel divides by [`PsConvert::samples`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StochasticMtjConv {
    /// Eq. 1 tanh slope.
    pub alpha: f32,
    /// Temporal ±1 reads summed per conversion.
    pub n_samples: u32,
}

impl PsConvert for StochasticMtjConv {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    ) {
        stochastic_slice(
            self.alpha,
            self.n_samples,
            self.n_samples,
            ps,
            out,
            counter_base,
            counter_stride,
            rng,
        );
    }

    /// Integer fast path: the `ceil(p·2²⁴)` sampling threshold depends
    /// only on the integer PS level, so it is memoized per level across
    /// the whole run — same thresholds, same draws, same bits as
    /// `stochastic_slice`.
    #[allow(clippy::too_many_arguments)]
    fn convert_slice_int_at(
        &self,
        _stream: usize,
        _w_slice: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        stochastic_slice_int(
            self.alpha,
            self.n_samples,
            self.n_samples,
            None,
            ps_int,
            ps_scale,
            out,
            counter_base,
            counter_stride,
            rng,
            cache,
        );
    }

    /// Batched fast path: one non-virtual loop of the shared sampling core
    /// over the group — same thresholds (one memo for all slices), same
    /// counter blocks, same bits as the per-slice path.
    #[allow(clippy::too_many_arguments)]
    fn convert_batch(
        &self,
        coords: &[(usize, usize, u32)],
        counter_stride: u32,
        n: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        for (g, &(_, _, base)) in coords.iter().enumerate() {
            stochastic_slice_int(
                self.alpha,
                self.n_samples,
                self.n_samples,
                None,
                &ps_int[g * n..(g + 1) * n],
                ps_scale,
                &mut out[g * n..(g + 1) * n],
                base,
                counter_stride,
                rng,
                cache,
            );
        }
    }

    fn samples(&self) -> u32 {
        self.n_samples
    }

    /// Sampling averages out in expectation: the backward is the Eq. 1
    /// tanh surrogate regardless of the read count (§3.3).
    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::Tanh { alpha: self.alpha }
    }

    fn cost_key(&self) -> PsProcessing {
        PsProcessing::StochasticMtj { samples: self.n_samples }
    }

    fn label(&self) -> String {
        format!("MTJ×{}", self.n_samples)
    }
}

/// §3.2.3's inhomogeneous sampling, at (stream, slice) granularity: the
/// sample length grows with the bit significance `i·d_a + j·d_w` of the
/// PS group, from `base` reads at the LSB up to `base + extra` at the MSB
/// (linear in normalized significance). Outputs are normalized sample
/// means (`Σ±1 / n(i,j)`), so [`PsConvert::samples`] is 1 and the kernel
/// normalization stays uniform.
///
/// This is the converter the closed enum could not express: `layer_samples`
/// only approximated the scheme per layer, while the MSB slices are where
/// extra reads actually pay (the Fig. 5 sensitivity signal).
#[derive(Debug, Clone, PartialEq)]
pub struct InhomogeneousMtjConv {
    /// Eq. 1 tanh slope.
    pub alpha: f32,
    base: u32,
    extra: u32,
    j_n: usize,
    /// samples per (stream i, weight-slice j), indexed `i·j_n + j`
    table: Vec<u32>,
}

impl InhomogeneousMtjConv {
    /// Build the per-(stream, slice) sample table for hardware config
    /// `cfg`: `base_samples` reads at the LSB group growing linearly to
    /// `base_samples + extra_samples` at the MSB group.
    pub fn new(alpha: f32, base_samples: u32, extra_samples: u32, cfg: &StoxConfig) -> Self {
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let (da, dw) = (cfg.a_stream_bits, cfg.w_slice_bits);
        let base = base_samples.max(1);
        let sig_max = (i_n as u32 - 1) * da + (j_n as u32 - 1) * dw;
        let mut table = vec![0u32; i_n * j_n];
        for i in 0..i_n {
            for j in 0..j_n {
                let sig = i as u32 * da + j as u32 * dw;
                let n = if sig_max == 0 {
                    base + extra_samples
                } else {
                    base + (extra_samples as f64 * sig as f64 / sig_max as f64).round()
                        as u32
                };
                table[i * j_n + j] = n.max(1);
            }
        }
        Self { alpha, base, extra: extra_samples, j_n, table }
    }

    /// Sample length of the (stream, slice) PS group.
    pub fn samples_at(&self, stream: usize, w_slice: usize) -> u32 {
        self.table
            .get(stream * self.j_n + w_slice)
            .copied()
            .unwrap_or(self.base)
    }

    /// Mean sample length over the (stream × slice) grid — the effective
    /// conversion cost.
    pub fn mean_samples(&self) -> f64 {
        self.table.iter().map(|&n| n as f64).sum::<f64>() / self.table.len() as f64
    }

    /// Max read count over the grid — the per-element counter block size,
    /// so every (stream, slice) group draws from a disjoint counter range
    /// even though read counts differ (no RNG draw is ever shared).
    fn n_max(&self) -> u32 {
        self.base + self.extra
    }

    fn convert_with(&self, n: u32, ps: &[f32], out: &mut [f32], cb: u32, cs: u32, rng: &CounterRng) {
        stochastic_slice(self.alpha, n, self.n_max(), ps, out, cb, cs, rng);
        let inv = 1.0 / n as f32;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

impl PsConvert for InhomogeneousMtjConv {
    /// Significance-blind entry point: treats the slice as least
    /// significant (`base` reads).
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    ) {
        self.convert_with(self.base, ps, out, counter_base, counter_stride, rng);
    }

    #[allow(clippy::too_many_arguments)]
    fn convert_slice_at(
        &self,
        stream: usize,
        w_slice: usize,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    ) {
        let n = self.samples_at(stream, w_slice);
        self.convert_with(n, ps, out, counter_base, counter_stride, rng);
    }

    /// Integer fast path: thresholds depend only on (α, level) — one memo
    /// serves every (stream, slice) group even though read counts differ.
    /// Counter layout and the final `·1/n` normalization replicate
    /// `InhomogeneousMtjConv::convert_with` bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    fn convert_slice_int_at(
        &self,
        stream: usize,
        w_slice: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        let n = self.samples_at(stream, w_slice);
        stochastic_slice_int(
            self.alpha,
            n,
            self.n_max(),
            Some(1.0 / n as f32),
            ps_int,
            ps_scale,
            out,
            counter_base,
            counter_stride,
            rng,
            cache,
        );
    }

    /// Batched fast path: the significance schedule is applied per group
    /// coordinate inside one non-virtual loop; the level→threshold memo is
    /// shared across the whole group (read counts differ, thresholds
    /// don't).
    #[allow(clippy::too_many_arguments)]
    fn convert_batch(
        &self,
        coords: &[(usize, usize, u32)],
        counter_stride: u32,
        n: usize,
        ps_int: &[i32],
        ps_scale: f32,
        out: &mut [f32],
        rng: &CounterRng,
        cache: &mut PsIntCache,
    ) {
        for (g, &(stream, w_slice, base)) in coords.iter().enumerate() {
            let ns = self.samples_at(stream, w_slice);
            stochastic_slice_int(
                self.alpha,
                ns,
                self.n_max(),
                Some(1.0 / ns as f32),
                &ps_int[g * n..(g + 1) * n],
                ps_scale,
                &mut out[g * n..(g + 1) * n],
                base,
                counter_stride,
                rng,
                cache,
            );
        }
    }

    /// Every (stream, slice) group's expected output is the same
    /// normalized `tanh(α·ps)` mean — the schedule changes variance, not
    /// expectation — so one tanh surrogate serves the whole grid; the
    /// per-slice schedule still reaches the backward through the
    /// `(stream, w_slice)` coordinates of [`PsConvert::grad_slice_at`].
    fn surrogate(&self) -> PsSurrogate {
        PsSurrogate::Tanh { alpha: self.alpha }
    }

    /// Exact fractional energy accounting: the per-(stream, slice) read
    /// counts average to `mean_samples()`, charged as millisamples so
    /// inhomogeneous energy is exact instead of mean-rounded.
    fn cost_key(&self) -> PsProcessing {
        let ms = (self.mean_samples() * 1000.0).round() as u32;
        PsProcessing::StochasticMtjFrac { millisamples: ms.max(1) }
    }

    fn label(&self) -> String {
        format!("inhomo-MTJ({}..{})", self.base, self.base + self.extra)
    }
}

// ---------------------------------------------------------------------
// Spec + registry
// ---------------------------------------------------------------------

/// Serializable converter specification — the single parsing/construction
/// path for every call site (`model/infer.rs`, `main.rs`, examples,
/// benches). Parse with [`std::str::FromStr`] / [`PsConverterSpec::from_mode`]
/// (grammar `name[:k=v[,k=v…]]`, e.g. `stox:alpha=4,samples=2`,
/// `sparse:bits=4`), round-trip through [`std::fmt::Display`] and
/// [`PsConverterSpec::to_json`], and build a converter with
/// [`PsConverterSpec::build`].
#[derive(Debug, Clone, PartialEq)]
pub enum PsConverterSpec {
    /// Infinite-precision readout (mode `ideal`) → [`IdealAdcConv`].
    IdealAdc,
    /// N-bit SAR ADC (mode `quant:bits=N`) → [`QuantAdcConv`].
    QuantAdc {
        /// ADC resolution, 1..=16.
        bits: u32,
    },
    /// Sparsity-aware low-bit ADC (mode `sparse:bits=N`) →
    /// [`SparseAdcConv`].
    SparseAdc {
        /// ADC resolution, 1..=16.
        bits: u32,
    },
    /// Deterministic 1-bit sense amplifier (mode `sa`) → [`SenseAmpConv`].
    SenseAmp,
    /// Infinite-sample tanh limit (mode `expected:alpha=A`) →
    /// [`ExpectedMtjConv`].
    ExpectedMtj {
        /// Eq. 1 tanh slope.
        alpha: f32,
    },
    /// Stochastic SOT-MTJ sampling (mode `stox:alpha=A,samples=N`) →
    /// [`StochasticMtjConv`].
    StochasticMtj {
        /// Eq. 1 tanh slope.
        alpha: f32,
        /// Temporal reads per conversion.
        n_samples: u32,
    },
    /// §3.2.3 inhomogeneous sampling (mode `inhomo:alpha=A,base=B,extra=E`)
    /// → [`InhomogeneousMtjConv`].
    InhomogeneousMtj {
        /// Eq. 1 tanh slope.
        alpha: f32,
        /// Reads of the least-significant (stream, slice) group.
        base_samples: u32,
        /// Additional reads granted linearly up to the MSB group.
        extra_samples: u32,
    },
    /// A mode the built-in set does not know: resolved (or rejected) by
    /// whatever [`ConverterRegistry`] builds it — the open end of the API.
    Custom {
        /// Registry key the spec resolves under.
        name: String,
        /// Raw `k=v` parameters, in parse order.
        params: Vec<(String, f32)>,
    },
}

/// Default α of Eq. 1 when neither the mode string nor the caller supplies
/// one (the paper's fitted value).
pub const DEFAULT_ALPHA: f32 = 4.0;

fn param(params: &[(String, f32)], key: &str) -> Option<f32> {
    params.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
}

impl PsConverterSpec {
    /// Registry key of this spec.
    pub fn mode_name(&self) -> &str {
        match self {
            PsConverterSpec::IdealAdc => "ideal",
            PsConverterSpec::QuantAdc { .. } => "quant",
            PsConverterSpec::SparseAdc { .. } => "sparse",
            PsConverterSpec::SenseAmp => "sa",
            PsConverterSpec::ExpectedMtj { .. } => "expected",
            PsConverterSpec::StochasticMtj { .. } => "stox",
            PsConverterSpec::InhomogeneousMtj { .. } => "inhomo",
            PsConverterSpec::Custom { name, .. } => name,
        }
    }

    /// Parse a mode string with caller-supplied defaults (typically the
    /// trained config's `alpha` / `n_samples`). Grammar:
    /// `name[:key=value[,key=value…]]`; unknown names become
    /// [`PsConverterSpec::Custom`] and surface an error at build time
    /// unless a registry knows them.
    pub fn from_mode(mode: &str, default_alpha: f32, default_samples: u32) -> crate::Result<Self> {
        let mode = mode.trim();
        let (name, rest) = match mode.split_once(':') {
            Some((n, r)) => (n.trim(), r.trim()),
            None => (mode, ""),
        };
        anyhow::ensure!(!name.is_empty(), "empty converter mode");
        let mut params: Vec<(String, f32)> = Vec::new();
        if !rest.is_empty() {
            for kv in rest.split(',') {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("bad converter param '{kv}' (want k=v)"))?;
                let v: f32 = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad converter param value '{kv}'"))?;
                params.push((k.trim().to_string(), v));
            }
        }
        Self::from_parts(name, &params, default_alpha, default_samples)
    }

    fn from_parts(
        name: &str,
        params: &[(String, f32)],
        default_alpha: f32,
        default_samples: u32,
    ) -> crate::Result<Self> {
        let alpha = param(params, "alpha").unwrap_or(default_alpha);
        let samples = param(params, "samples")
            .map(|v| v as u32)
            .unwrap_or(default_samples)
            .max(1);
        let bits = |d: u32| -> crate::Result<u32> {
            let b = param(params, "bits").map(|v| v as u32).unwrap_or(d);
            anyhow::ensure!((1..=16).contains(&b), "converter bits {b} out of range 1..=16");
            Ok(b)
        };
        Ok(match name {
            "ideal" | "adc" => PsConverterSpec::IdealAdc,
            "quant" => PsConverterSpec::QuantAdc { bits: bits(8)? },
            "sparse" => PsConverterSpec::SparseAdc { bits: bits(4)? },
            "sa" | "sense" => PsConverterSpec::SenseAmp,
            "expected" => PsConverterSpec::ExpectedMtj { alpha },
            "stox" | "mtj" | "stochastic" => {
                PsConverterSpec::StochasticMtj { alpha, n_samples: samples }
            }
            "inhomo" | "inhomogeneous" | "mix" => PsConverterSpec::InhomogeneousMtj {
                alpha,
                base_samples: param(params, "base").map(|v| v as u32).unwrap_or(samples).max(1),
                extra_samples: param(params, "extra").map(|v| v as u32).unwrap_or(3),
            },
            _ => PsConverterSpec::Custom {
                name: name.to_string(),
                params: params.to_vec(),
            },
        })
    }

    /// Build through the process-wide default registry.
    pub fn build(&self, cfg: &StoxConfig) -> crate::Result<Box<dyn PsConvert>> {
        default_registry().build(self, cfg)
    }

    /// JSON form (`{"mode": ..., params…}`) — the coordinator/config wire
    /// format.
    pub fn to_json(&self) -> Json {
        let mut entries: Vec<(&str, Json)> = vec![("mode", Json::Str(self.mode_name().into()))];
        match self {
            PsConverterSpec::QuantAdc { bits } | PsConverterSpec::SparseAdc { bits } => {
                entries.push(("bits", Json::Num(*bits as f64)));
            }
            PsConverterSpec::ExpectedMtj { alpha } => {
                entries.push(("alpha", Json::Num(*alpha as f64)));
            }
            PsConverterSpec::StochasticMtj { alpha, n_samples } => {
                entries.push(("alpha", Json::Num(*alpha as f64)));
                entries.push(("samples", Json::Num(*n_samples as f64)));
            }
            PsConverterSpec::InhomogeneousMtj { alpha, base_samples, extra_samples } => {
                entries.push(("alpha", Json::Num(*alpha as f64)));
                entries.push(("base", Json::Num(*base_samples as f64)));
                entries.push(("extra", Json::Num(*extra_samples as f64)));
            }
            PsConverterSpec::Custom { params, .. } => {
                for (k, v) in params {
                    entries.push((k.as_str(), Json::Num(*v as f64)));
                }
            }
            _ => {}
        }
        Json::obj(entries)
    }

    /// Parse the JSON form written by [`PsConverterSpec::to_json`].
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let name = j
            .get("mode")
            .and_then(|m| m.as_str())
            .ok_or_else(|| anyhow::anyhow!("converter spec json: missing 'mode'"))?;
        let params: Vec<(String, f32)> = match j {
            Json::Obj(m) => m
                .iter()
                .filter(|(k, _)| k.as_str() != "mode")
                .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n as f32)))
                .collect(),
            _ => Vec::new(),
        };
        Self::from_parts(name, &params, DEFAULT_ALPHA, 1)
    }
}

impl std::str::FromStr for PsConverterSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::from_mode(s, DEFAULT_ALPHA, 1)
    }
}

impl std::fmt::Display for PsConverterSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PsConverterSpec::IdealAdc => write!(f, "ideal"),
            PsConverterSpec::QuantAdc { bits } => write!(f, "quant:bits={bits}"),
            PsConverterSpec::SparseAdc { bits } => write!(f, "sparse:bits={bits}"),
            PsConverterSpec::SenseAmp => write!(f, "sa"),
            PsConverterSpec::ExpectedMtj { alpha } => write!(f, "expected:alpha={alpha}"),
            PsConverterSpec::StochasticMtj { alpha, n_samples } => {
                write!(f, "stox:alpha={alpha},samples={n_samples}")
            }
            PsConverterSpec::InhomogeneousMtj { alpha, base_samples, extra_samples } => {
                write!(f, "inhomo:alpha={alpha},base={base_samples},extra={extra_samples}")
            }
            PsConverterSpec::Custom { name, params } => {
                write!(f, "{name}")?;
                for (i, (k, v)) in params.iter().enumerate() {
                    write!(f, "{}{k}={v}", if i == 0 { ":" } else { "," })?;
                }
                Ok(())
            }
        }
    }
}

type BuilderFn =
    Box<dyn Fn(&PsConverterSpec, &StoxConfig) -> crate::Result<Box<dyn PsConvert>> + Send + Sync>;

/// Name → builder map. [`ConverterRegistry::builtin`] carries the seven
/// in-tree converters; [`ConverterRegistry::register`] adds (or overrides)
/// designs without touching the kernel — the open end of the redesign.
pub struct ConverterRegistry {
    entries: Vec<(String, BuilderFn)>,
}

impl ConverterRegistry {
    pub fn empty() -> Self {
        Self { entries: Vec::new() }
    }

    /// The in-tree converter family.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register("ideal", |_s, _c| Ok(Box::new(IdealAdcConv) as Box<dyn PsConvert>));
        r.register("quant", |s, _c| match *s {
            PsConverterSpec::QuantAdc { bits } => {
                Ok(Box::new(QuantAdcConv { bits }) as Box<dyn PsConvert>)
            }
            _ => anyhow::bail!("quant builder got spec {s}"),
        });
        r.register("sparse", |s, _c| match *s {
            PsConverterSpec::SparseAdc { bits } => {
                Ok(Box::new(SparseAdcConv { bits }) as Box<dyn PsConvert>)
            }
            _ => anyhow::bail!("sparse builder got spec {s}"),
        });
        r.register("sa", |_s, _c| Ok(Box::new(SenseAmpConv) as Box<dyn PsConvert>));
        r.register("expected", |s, _c| match *s {
            PsConverterSpec::ExpectedMtj { alpha } => {
                Ok(Box::new(ExpectedMtjConv { alpha }) as Box<dyn PsConvert>)
            }
            _ => anyhow::bail!("expected builder got spec {s}"),
        });
        r.register("stox", |s, _c| match *s {
            PsConverterSpec::StochasticMtj { alpha, n_samples } => {
                Ok(Box::new(StochasticMtjConv { alpha, n_samples }) as Box<dyn PsConvert>)
            }
            _ => anyhow::bail!("stox builder got spec {s}"),
        });
        r.register("inhomo", |s, cfg| match *s {
            PsConverterSpec::InhomogeneousMtj { alpha, base_samples, extra_samples } => {
                Ok(Box::new(InhomogeneousMtjConv::new(alpha, base_samples, extra_samples, cfg))
                    as Box<dyn PsConvert>)
            }
            _ => anyhow::bail!("inhomo builder got spec {s}"),
        });
        r
    }

    /// Register `name`; an existing entry of the same name is replaced
    /// (latest wins).
    pub fn register<F>(&mut self, name: &str, build: F)
    where
        F: Fn(&PsConverterSpec, &StoxConfig) -> crate::Result<Box<dyn PsConvert>>
            + Send
            + Sync
            + 'static,
    {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 = Box::new(build);
        } else {
            self.entries.push((name.to_string(), Box::new(build)));
        }
    }

    /// Construct the converter for `spec` under hardware config `cfg`.
    pub fn build(
        &self,
        spec: &PsConverterSpec,
        cfg: &StoxConfig,
    ) -> crate::Result<Box<dyn PsConvert>> {
        let name = spec.mode_name();
        let (_, b) = self
            .entries
            .iter()
            .find(|(n, _)| n == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no PS converter registered for mode '{name}' (known: {})",
                    self.names().join(", ")
                )
            })?;
        b(spec, cfg)
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }
}

/// Process-wide registry of the built-in converters (use a local
/// [`ConverterRegistry`] to extend the family).
pub fn default_registry() -> &'static ConverterRegistry {
    static REG: OnceLock<ConverterRegistry> = OnceLock::new();
    REG.get_or_init(ConverterRegistry::builtin)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> CounterRng {
        CounterRng::new(9)
    }

    fn cfg() -> StoxConfig {
        StoxConfig::default() // 4w4a4bs: I=4 streams, J=1 slice
    }

    #[test]
    fn ideal_is_copy() {
        let ps = [0.37f32, -0.5, 0.0];
        let mut out = [0.0f32; 3];
        IdealAdcConv.convert_slice(&ps, &mut out, 0, 1, &rng());
        assert_eq!(out, ps);
    }

    #[test]
    fn scalar_convenience_matches_slice() {
        let c = StochasticMtjConv { alpha: 4.0, n_samples: 3 };
        let mut out = [0.0f32; 1];
        c.convert_slice(&[0.2], &mut out, 77, 5, &rng());
        assert_eq!(out[0], c.convert(0.2, 77, &rng()));
    }

    #[test]
    fn stochastic_slice_respects_stride() {
        // element idx of a strided slice must see counter base + idx·stride
        let c = StochasticMtjConv { alpha: 4.0, n_samples: 2 };
        let ps = [0.1f32, 0.1, 0.1, 0.1];
        let mut out = [0.0f32; 4];
        c.convert_slice(&ps, &mut out, 100, 7, &rng());
        for (idx, &o) in out.iter().enumerate() {
            let want = c.convert(0.1, 100u32.wrapping_add(idx as u32 * 7), &rng());
            assert_eq!(o, want, "idx {idx}");
        }
    }

    #[test]
    fn sparse_adc_skips_zero_slices_and_quantizes_dense() {
        let sp = SparseAdcConv { bits: 4 };
        let q = QuantAdcConv { bits: 4 };
        let zeros = [0.0f32; 8];
        let mut out = [9.0f32; 8];
        sp.convert_slice(&zeros, &mut out, 0, 1, &rng());
        assert!(out.iter().all(|&v| v == 0.0), "all-zero slice skipped");
        // note: a real 4b ADC reads midtread(0) = 1/15, not 0 — the skip
        // is the approximation that buys the energy.
        let dense = [0.3f32, -0.8, 0.0, 1.0];
        let mut o1 = [0.0f32; 4];
        let mut o2 = [0.0f32; 4];
        sp.convert_slice(&dense, &mut o1, 0, 1, &rng());
        q.convert_slice(&dense, &mut o2, 0, 1, &rng());
        assert_eq!(o1, o2, "dense slice == plain quant");
    }

    #[test]
    fn inhomo_table_monotone_in_significance() {
        let cfg = StoxConfig { a_bits: 4, w_bits: 4, w_slice_bits: 1, ..cfg() }; // I=4, J=4
        let c = InhomogeneousMtjConv::new(4.0, 1, 3, &cfg);
        assert_eq!(c.samples_at(0, 0), 1, "LSB gets base");
        assert_eq!(c.samples_at(3, 3), 4, "MSB gets base+extra");
        for i in 0..3 {
            assert!(c.samples_at(i + 1, 0) >= c.samples_at(i, 0));
            assert!(c.samples_at(0, i + 1) >= c.samples_at(0, i));
        }
        let m = c.mean_samples();
        assert!(m > 1.0 && m < 4.0, "mean {m}");
        assert_eq!(c.samples(), 1, "outputs are normalized means");
    }

    #[test]
    fn inhomo_outputs_are_means_in_range() {
        let c = InhomogeneousMtjConv::new(4.0, 2, 4, &cfg());
        let ps = [0.4f32; 16];
        let mut out = [0.0f32; 16];
        c.convert_slice_at(3, 0, &ps, &mut out, 0, 1, &rng());
        for &v in &out {
            assert!(v.abs() <= 1.0, "{v}");
        }
    }

    #[test]
    fn spec_parse_and_display_roundtrip() {
        for s in [
            "ideal",
            "quant:bits=8",
            "sparse:bits=4",
            "sa",
            "expected:alpha=2",
            "stox:alpha=4,samples=2",
            "inhomo:alpha=4,base=1,extra=3",
        ] {
            let spec: PsConverterSpec = s.parse().unwrap();
            let round: PsConverterSpec = spec.to_string().parse().unwrap();
            assert_eq!(spec, round, "display round-trip of {s}");
        }
    }

    #[test]
    fn spec_defaults_flow_from_caller() {
        let s = PsConverterSpec::from_mode("stox", 2.5, 6).unwrap();
        assert_eq!(s, PsConverterSpec::StochasticMtj { alpha: 2.5, n_samples: 6 });
        let s = PsConverterSpec::from_mode("stox:samples=2", 2.5, 6).unwrap();
        assert_eq!(s, PsConverterSpec::StochasticMtj { alpha: 2.5, n_samples: 2 });
    }

    #[test]
    fn spec_json_roundtrip() {
        for s in ["stox:alpha=3,samples=2", "sparse:bits=5", "inhomo:base=2,extra=1", "sa"] {
            let spec: PsConverterSpec = s.parse().unwrap();
            let j = spec.to_json();
            let back = PsConverterSpec::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(spec, back, "json round-trip of {s}");
        }
    }

    #[test]
    fn unknown_mode_is_custom_until_registered() {
        let spec: PsConverterSpec = "frobnicator:gain=2".parse().unwrap();
        assert_eq!(spec.mode_name(), "frobnicator");
        assert!(spec.build(&cfg()).is_err(), "not in the default registry");
        let mut reg = ConverterRegistry::builtin();
        reg.register("frobnicator", |_s, _c| {
            Ok(Box::new(SenseAmpConv) as Box<dyn PsConvert>)
        });
        let c = reg.build(&spec, &cfg()).unwrap();
        assert_eq!(c.convert(0.5, 0, &rng()), 1.0);
    }

    #[test]
    fn registry_builds_every_builtin() {
        let reg = default_registry();
        for s in [
            "ideal", "quant:bits=6", "sparse", "sa", "expected", "stox:samples=3", "inhomo",
        ] {
            let spec: PsConverterSpec = s.parse().unwrap();
            let c = reg.build(&spec, &cfg()).unwrap();
            let v = c.convert(0.3, 0, &rng());
            assert!(v.is_finite(), "{s} -> {v}");
        }
    }

    /// The integer entry point must be bit-identical to materializing the
    /// normalized PS and calling the float entry point — for every
    /// builtin, with and without a usable memo, across repeated calls
    /// (memo hits) and multiple (stream, slice) groups.
    #[test]
    fn int_entry_matches_float_entry_for_every_builtin() {
        let cfg = StoxConfig { w_slice_bits: 1, ..cfg() }; // I=4, J=4
        let specs = [
            "ideal",
            "quant:bits=5",
            "sparse:bits=4",
            "sa",
            "expected:alpha=3",
            "stox:alpha=4,samples=3",
            "inhomo:alpha=4,base=1,extra=3",
        ];
        let r = rng();
        let bound = 64usize;
        let ps_int: Vec<i32> = (0..24).map(|i| ((i * 7) % 129) - 64).collect();
        let scale = 1.0f32 / 64.0;
        for s in specs {
            let spec: PsConverterSpec = s.parse().unwrap();
            let conv = spec.build(&cfg).unwrap();
            for memo_bound in [bound, PsIntCache::MAX_MEMO_LEVELS + 1] {
                let mut cache = PsIntCache::new();
                cache.reset(memo_bound);
                for (i, j) in [(0usize, 0usize), (3, 2), (1, 3)] {
                    let psn: Vec<f32> =
                        ps_int.iter().map(|&p| p as f32 * scale).collect();
                    let mut want = vec![0.0f32; ps_int.len()];
                    conv.convert_slice_at(i, j, &psn, &mut want, 1000, 7, &r);
                    // twice: second pass hits the memo
                    for pass in 0..2 {
                        let mut got = vec![0.0f32; ps_int.len()];
                        conv.convert_slice_int_at(
                            i, j, &ps_int, scale, &mut got, 1000, 7, &r, &mut cache,
                        );
                        for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
                            assert_eq!(
                                g.to_bits(),
                                w.to_bits(),
                                "{s} (i={i}, j={j}, pass {pass}) idx {idx}: {g} vs {w}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// The batched entry point must be bit-identical to looping the
    /// per-slice integer entry point in coords order — for every builtin
    /// (the three MTJ overrides and the default loop alike), with memo
    /// state evolving across the group.
    #[test]
    fn batch_entry_matches_per_slice_loop_for_every_builtin() {
        let cfg = StoxConfig { w_slice_bits: 1, ..cfg() }; // I=4, J=4
        let specs = [
            "ideal",
            "quant:bits=5",
            "sparse:bits=4",
            "sa",
            "expected:alpha=3",
            "stox:alpha=4,samples=3",
            "inhomo:alpha=4,base=1,extra=3",
        ];
        let r = rng();
        let n = 24usize;
        // three (i, j) groups with the kernel's [j][i] interleaving and
        // distinct counter bases, sharing one stride
        let coords = [(0usize, 0usize, 500u32), (3, 2, 740), (1, 3, 980)];
        let stride = 7u32;
        let ps_int: Vec<i32> = (0..coords.len() * n).map(|i| ((i as i32 * 11) % 129) - 64).collect();
        let scale = 1.0f32 / 64.0;
        for s in specs {
            let spec: PsConverterSpec = s.parse().unwrap();
            let conv = spec.build(&cfg).unwrap();
            let mut want = vec![0.0f32; ps_int.len()];
            let mut c1 = PsIntCache::new();
            c1.reset(64);
            for (g, &(i, j, base)) in coords.iter().enumerate() {
                conv.convert_slice_int_at(
                    i,
                    j,
                    &ps_int[g * n..(g + 1) * n],
                    scale,
                    &mut want[g * n..(g + 1) * n],
                    base,
                    stride,
                    &r,
                    &mut c1,
                );
            }
            let mut got = vec![0.0f32; ps_int.len()];
            let mut c2 = PsIntCache::new();
            c2.reset(64);
            conv.convert_batch(&coords, stride, n, &ps_int, scale, &mut got, &r, &mut c2);
            for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "{s} idx {idx}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn cache_stats_count_hits_misses_and_draws() {
        let c = StochasticMtjConv { alpha: 4.0, n_samples: 3 };
        let r = rng();
        let mut cache = PsIntCache::new();
        cache.reset(64);
        let ps_int = [5i32, 5, -3, 5];
        let mut out = [0.0f32; 4];
        c.convert_slice_int_at(0, 0, &ps_int, 1.0 / 64.0, &mut out, 0, 1, &r, &mut cache);
        // levels {5, -3}: two misses, two repeat-5 hits; 4 elements × 3 reads
        assert_eq!(cache.take_stats(), (2, 2, 12));
        assert_eq!(cache.take_stats(), (0, 0, 0), "take_stats drains");
        // memo disabled: every lookup computes (a miss), draws unchanged
        let mut nocache = PsIntCache::new();
        c.convert_slice_int_at(0, 0, &ps_int, 1.0 / 64.0, &mut out, 0, 1, &r, &mut nocache);
        assert_eq!(nocache.take_stats(), (0, 4, 12));
    }

    #[test]
    fn cost_keys_map_to_table2_rows() {
        let cfg = cfg();
        assert_eq!(
            IdealAdcConv.cost_key(),
            PsProcessing::AdcFullPrecision { share: 16 }
        );
        assert_eq!(
            SparseAdcConv { bits: 4 }.cost_key(),
            PsProcessing::AdcSparse { share: 16 }
        );
        assert_eq!(SenseAmpConv.cost_key(), PsProcessing::SenseAmp);
        assert_eq!(
            StochasticMtjConv { alpha: 4.0, n_samples: 5 }.cost_key(),
            PsProcessing::StochasticMtj { samples: 5 }
        );
        // inhomo charges its exact fractional mean: I=4 streams, J=1
        // slice, reads 1,2,3,4 -> mean 2.5 -> 2500 millisamples
        assert_eq!(
            InhomogeneousMtjConv::new(4.0, 1, 3, &cfg).cost_key(),
            PsProcessing::StochasticMtjFrac { millisamples: 2500 }
        );
    }

    #[test]
    fn surrogates_match_transfer_curves() {
        // derivative conventions of §3.3 (mirrored by gen_grad_golden.py)
        assert_eq!(IdealAdcConv.surrogate(), PsSurrogate::Identity);
        assert_eq!(QuantAdcConv { bits: 6 }.surrogate(), PsSurrogate::ClipSte);
        assert_eq!(SparseAdcConv { bits: 4 }.surrogate(), PsSurrogate::ClipSte);
        assert_eq!(
            SenseAmpConv.surrogate(),
            PsSurrogate::HardTanh { alpha: DEFAULT_ALPHA }
        );
        assert_eq!(
            ExpectedMtjConv { alpha: 3.0 }.surrogate(),
            PsSurrogate::Tanh { alpha: 3.0 }
        );
        assert_eq!(
            StochasticMtjConv { alpha: 2.0, n_samples: 5 }.surrogate(),
            PsSurrogate::Tanh { alpha: 2.0 }
        );
        assert_eq!(
            InhomogeneousMtjConv::new(2.5, 1, 3, &cfg()).surrogate(),
            PsSurrogate::Tanh { alpha: 2.5 }
        );
        // grad values at a few probe points
        let s = PsSurrogate::Tanh { alpha: 4.0 };
        let t = (4.0f32 * 0.1).tanh();
        assert_eq!(s.grad(0.1), 4.0 * (1.0 - t * t));
        assert_eq!(PsSurrogate::Identity.grad(7.0), 1.0);
        assert_eq!(PsSurrogate::ClipSte.grad(0.9), 1.0);
        assert_eq!(PsSurrogate::ClipSte.grad(1.1), 0.0);
        let h = PsSurrogate::HardTanh { alpha: 4.0 };
        assert_eq!(h.grad(0.2), 4.0);
        assert_eq!(h.grad(0.3), 0.0); // |4*0.3| > 1
    }

    #[test]
    fn grad_slice_default_applies_surrogate_elementwise() {
        let c = StochasticMtjConv { alpha: 4.0, n_samples: 3 };
        let ps = [0.0f32, 0.2, -0.6, 1.0];
        let mut out = [0.0f32; 4];
        c.grad_slice_at(1, 0, &ps, &mut out);
        for (o, &p) in out.iter().zip(&ps) {
            assert_eq!(*o, c.surrogate().grad(p));
        }
        // unknown/custom converters fall back to the default tanh
        struct Frob;
        impl PsConvert for Frob {
            fn convert_slice(
                &self,
                ps: &[f32],
                out: &mut [f32],
                _cb: u32,
                _cs: u32,
                _rng: &CounterRng,
            ) {
                out.copy_from_slice(ps);
            }
            fn cost_key(&self) -> PsProcessing {
                PsProcessing::SenseAmp
            }
            fn label(&self) -> String {
                "frob".into()
            }
        }
        assert_eq!(
            Frob.surrogate(),
            PsSurrogate::Tanh { alpha: DEFAULT_ALPHA },
            "default surrogate keeps registry extensions trainable"
        );
    }
}
