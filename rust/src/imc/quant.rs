//! Fixed-point quantization + bit slicing/streaming codecs.
//!
//! Value model (DESIGN.md §2 / ref.py):
//!   * a value v ∈ [-1,1] is coded as `u = round_ties_even((v+1)/2·(2^b-1))`
//!     — `round_ties_even` matches `jnp.round`;
//!   * u is decomposed into base-2^d *signed* digits `x_i = 2 d_i - (2^d-1)`
//!     (±1 for 1-bit digits), LSB first, so `Σ 2^{i·d} x_i = 2u - (2^b-1)`;
//!   * inputs stream digits over time (DAC side), weights map digits onto
//!     separate crossbar slices (two cells per weight → signed current).


/// Hardware configuration of one StoX crossbar-mapped MVM — mirrors
/// `python/compile/kernels/ref.py::StoxConfig` and the paper's `XwYaZbs`
/// naming (X=w_bits, Y=a_bits, Z=w_slice_bits).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StoxConfig {
    pub a_bits: u32,
    pub w_bits: u32,
    pub a_stream_bits: u32,
    pub w_slice_bits: u32,
    pub r_arr: usize,
    pub n_samples: u32,
    pub alpha: f32,
}

impl Default for StoxConfig {
    /// The paper's baseline: 4w4a4bs, α=4, R_arr=256, 1 sample.
    fn default() -> Self {
        Self {
            a_bits: 4,
            w_bits: 4,
            a_stream_bits: 1,
            w_slice_bits: 4,
            r_arr: 256,
            n_samples: 1,
            alpha: 4.0,
        }
    }
}

impl StoxConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.a_bits >= 1 && self.w_bits >= 1, "bits >= 1");
        anyhow::ensure!(
            self.a_bits % self.a_stream_bits == 0,
            "a_bits must be divisible by a_stream_bits"
        );
        anyhow::ensure!(
            self.w_bits % self.w_slice_bits == 0,
            "w_bits must be divisible by w_slice_bits"
        );
        anyhow::ensure!(self.n_samples >= 1, "n_samples >= 1");
        anyhow::ensure!(self.r_arr >= 1, "r_arr >= 1");
        Ok(())
    }

    pub fn n_streams(&self) -> usize {
        (self.a_bits / self.a_stream_bits) as usize
    }

    pub fn n_slices(&self) -> usize {
        (self.w_bits / self.w_slice_bits) as usize
    }

    /// Number of PS subarrays for an `m`-row operand (Algorithm 1's
    /// `ceil(K_h·K_w·C_in / R_arr)`).
    pub fn n_arrs(&self, m: usize) -> usize {
        m.div_ceil(self.r_arr).max(1)
    }

    /// Paper §4.1 tag, e.g. "4w4a4bs".
    pub fn tag(&self) -> String {
        format!("{}w{}a{}bs", self.w_bits, self.a_bits, self.w_slice_bits)
    }

    /// Parse a paper §4.1 precision tag (`XwYa[Zbs]`, e.g. `4w4a4bs` or
    /// `8w8a`) into a hardware config derived from `base`: the tag sets
    /// `w_bits`/`a_bits` (and `w_slice_bits` when the `Zbs` part is
    /// present), everything else — `r_arr`, `alpha`, `n_samples`, the DAC
    /// stream width — carries over from `base`.  When `Zbs` is omitted the
    /// slice width defaults to `min(base.w_slice_bits, w_bits)`.  The
    /// result is [`StoxConfig::validate`]d, so tags that break the
    /// divisibility rules (e.g. `6w4a4bs`) are rejected with the reason.
    ///
    /// This is the precision axis of the Fig. 9a design matrix
    /// (`stox-cli sweep --precision 4w4a4bs,8w8a4bs`); round-trips with
    /// [`StoxConfig::tag`].
    pub fn from_tag(tag: &str, base: &StoxConfig) -> crate::Result<Self> {
        let t = tag.trim();
        let bad = || anyhow::anyhow!("bad precision tag '{t}' (want XwYa[Zbs], e.g. 4w4a4bs)");
        let (w_str, rest) = t.split_once('w').ok_or_else(bad)?;
        let (a_str, slice_str) = rest.split_once('a').ok_or_else(bad)?;
        let w_bits: u32 = w_str.trim().parse().map_err(|_| bad())?;
        let a_bits: u32 = a_str.trim().parse().map_err(|_| bad())?;
        anyhow::ensure!(w_bits >= 1 && a_bits >= 1, "precision tag '{t}': bits must be >= 1");
        let slice_str = slice_str.trim();
        let w_slice_bits: u32 = if slice_str.is_empty() {
            base.w_slice_bits.min(w_bits)
        } else {
            let digits = slice_str.strip_suffix("bs").ok_or_else(bad)?;
            digits.trim().parse().map_err(|_| bad())?
        };
        let a_stream_bits = base.a_stream_bits.min(a_bits);
        anyhow::ensure!(
            w_slice_bits >= 1 && a_stream_bits >= 1,
            "precision tag '{t}': zero-width slices/streams"
        );
        let cfg = StoxConfig { a_bits, w_bits, a_stream_bits, w_slice_bits, ..*base };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Required baseline ADC resolution for this mapping (§2.1):
    /// `N = log2(N_row) + I + W - 2`.
    pub fn adc_bits(&self) -> u32 {
        (self.r_arr as f64).log2().ceil() as u32 + self.a_stream_bits
            + self.w_slice_bits
            - 2
    }

    /// Worst-case `|PS|` of one subarray column in the integer digit
    /// domain: `r_arr · (2^a_stream_bits − 1) · (2^w_slice_bits − 1)`
    /// (every digit at its extreme).  The integer kernel accumulates in
    /// `i32` and converts to `f32` once, which is bit-identical to the
    /// legacy f32 accumulation iff this bound stays ≤ 2²⁴ (all
    /// intermediate sums are then exactly representable in f32).
    pub fn int_ps_bound(&self) -> u64 {
        self.r_arr as u64
            * ((1u64 << self.a_stream_bits) - 1)
            * ((1u64 << self.w_slice_bits) - 1)
    }

    /// Whether the exact integer digit-plane kernel applies to this
    /// config: digits must fit `i8` (`|x_i| = 2^d − 1 ≤ 127`, i.e. digit
    /// widths ≤ 7 bits) and [`StoxConfig::int_ps_bound`] must stay within
    /// f32's exact-integer range.  Everything the paper sweeps (1–4 bit
    /// streams/slices, `r_arr` ≤ 1024) qualifies; exotic configs fall back
    /// to the retained f32 reference kernel with identical results.
    pub fn int_kernel_ok(&self) -> bool {
        self.a_stream_bits <= 7 && self.w_slice_bits <= 7 && self.int_ps_bound() <= 1 << 24
    }

    /// Whether the `i16` accumulation tier applies on top of
    /// [`StoxConfig::int_kernel_ok`]: every per-column partial sum —
    /// including all intermediate prefix sums, since
    /// [`StoxConfig::int_ps_bound`] bounds the sum of absolute products —
    /// must fit an `i16` accumulator.  Doubles SIMD lanes over the `i32`
    /// path with bit-identical results (integer addition is exact).  The
    /// paper's baseline 4w4a4bs @ `r_arr = 256` qualifies (bound 3840).
    pub fn int16_kernel_ok(&self) -> bool {
        self.int_kernel_ok() && self.int_ps_bound() <= i16::MAX as u64
    }
}

/// Quantize v ∈ [-1,1] to the integer code u ∈ [0, 2^bits - 1].
/// Round-half-to-even to match `jnp.round` exactly.
#[inline]
pub fn quantize_unit(v: f32, bits: u32) -> i32 {
    let levels = ((1u32 << bits) - 1) as f32;
    let v = v.clamp(-1.0, 1.0);
    ((v + 1.0) * 0.5 * levels).round_ties_even() as i32
}

/// Represented value of code u: `2u/(2^bits - 1) - 1`.
#[inline]
pub fn dequantize_unit(u: i32, bits: u32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    2.0 * u as f32 / levels - 1.0
}

/// Signed base-2^digit_bits digits of code u, LSB first (physical DAC
/// levels / differential cell currents): `x_i = 2 d_i - (2^digit_bits - 1)`.
pub fn signed_digits(u: i32, bits: u32, digit_bits: u32, out: &mut [i32]) {
    let n_digits = (bits / digit_bits) as usize;
    debug_assert_eq!(out.len(), n_digits);
    let base = 1i32 << digit_bits;
    for (i, o) in out.iter_mut().enumerate() {
        let d = (u >> (i as u32 * digit_bits)) & (base - 1);
        *o = 2 * d - (base - 1);
    }
}

/// [`signed_digits`] writing `i8` digits — the integer digit-plane kernel
/// layout (4× denser than f32 digits).  Caller guarantees
/// `digit_bits <= 7` so every digit `|x_i| = 2^digit_bits − 1` fits
/// (see [`StoxConfig::int_kernel_ok`]).
pub fn signed_digits_i8(u: i32, bits: u32, digit_bits: u32, out: &mut [i8]) {
    let n_digits = (bits / digit_bits) as usize;
    debug_assert_eq!(out.len(), n_digits);
    debug_assert!(digit_bits <= 7, "i8 digits need digit_bits <= 7");
    let base = 1i32 << digit_bits;
    for (i, o) in out.iter_mut().enumerate() {
        let d = (u >> (i as u32 * digit_bits)) & (base - 1);
        *o = (2 * d - (base - 1)) as i8;
    }
}

/// Shift-and-add scales `2^{i·digit_bits}`, LSB first.
pub fn digit_scales(bits: u32, digit_bits: u32) -> Vec<f32> {
    (0..(bits / digit_bits))
        .map(|i| (1u64 << (i * digit_bits)) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_levels() {
        for bits in [1u32, 2, 4, 8] {
            let lev = (1 << bits) - 1;
            for k in 0..=lev {
                let v = 2.0 * k as f32 / lev as f32 - 1.0;
                assert_eq!(quantize_unit(v, bits), k as i32);
                assert!((dequantize_unit(k as i32, bits) - v).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn quantize_clips() {
        assert_eq!(quantize_unit(-7.0, 4), 0);
        assert_eq!(quantize_unit(7.0, 4), 15);
    }

    #[test]
    fn quantize_ties_to_even_matches_jnp() {
        // (0+1)/2*15 = 7.5 -> 8 (even); for 2 bits (v=1/3+eps cases) etc.
        assert_eq!(quantize_unit(0.0, 4), 8);
        // 6.5 -> 6 under ties-even (0.8666..*7.5)
        let v = 2.0 * 6.5 / 15.0 - 1.0;
        assert_eq!(quantize_unit(v, 4), 6);
    }

    #[test]
    fn digit_identity() {
        // Σ 2^{i·d} x_i == 2u - (2^bits - 1)
        for bits in [2u32, 4, 8] {
            for digit_bits in [1u32, 2] {
                if bits % digit_bits != 0 {
                    continue;
                }
                let n = (bits / digit_bits) as usize;
                let scales = digit_scales(bits, digit_bits);
                let mut digits = vec![0i32; n];
                for u in 0..(1i32 << bits) {
                    signed_digits(u, bits, digit_bits, &mut digits);
                    let s: f32 = digits
                        .iter()
                        .zip(&scales)
                        .map(|(&d, &s)| d as f32 * s)
                        .sum();
                    assert_eq!(s as i32, 2 * u - ((1 << bits) - 1));
                }
            }
        }
    }

    #[test]
    fn one_bit_digits_are_pm1() {
        let mut d = vec![0i32; 4];
        signed_digits(0b1010, 4, 1, &mut d);
        assert_eq!(d, vec![-1, 1, -1, 1]);
    }

    #[test]
    fn i8_digits_match_i32_digits() {
        for bits in [1u32, 2, 4, 8] {
            for digit_bits in [1u32, 2, 4] {
                if bits % digit_bits != 0 {
                    continue;
                }
                let n = (bits / digit_bits) as usize;
                let mut d32 = vec![0i32; n];
                let mut d8 = vec![0i8; n];
                for u in 0..(1i32 << bits) {
                    signed_digits(u, bits, digit_bits, &mut d32);
                    signed_digits_i8(u, bits, digit_bits, &mut d8);
                    for (a, b) in d32.iter().zip(&d8) {
                        assert_eq!(*a, *b as i32, "u={u} bits={bits}/{digit_bits}");
                    }
                }
            }
        }
    }

    #[test]
    fn int_kernel_gate() {
        // the paper's whole design space qualifies
        assert!(StoxConfig::default().int_kernel_ok());
        assert!(StoxConfig { w_slice_bits: 1, ..Default::default() }.int_kernel_ok());
        assert!(StoxConfig {
            a_bits: 8,
            w_bits: 8,
            a_stream_bits: 2,
            w_slice_bits: 2,
            r_arr: 1024,
            ..Default::default()
        }
        .int_kernel_ok());
        // 8-bit digits overflow i8 — reference fallback
        assert!(!StoxConfig {
            a_bits: 8,
            w_bits: 8,
            a_stream_bits: 8,
            w_slice_bits: 1,
            ..Default::default()
        }
        .int_kernel_ok());
        // PS bound beyond 2^24 — reference fallback
        let huge = StoxConfig {
            a_bits: 4,
            w_bits: 4,
            a_stream_bits: 4,
            w_slice_bits: 4,
            r_arr: 1 << 20,
            ..Default::default()
        };
        assert!(huge.int_ps_bound() > 1 << 24);
        assert!(!huge.int_kernel_ok());
        assert_eq!(StoxConfig::default().int_ps_bound(), 3840); // 256 · 1 · 15
    }

    #[test]
    fn int16_tier_gate() {
        // baseline 4w4a4bs: bound 3840 ≤ 32767 — i16 tier applies
        assert!(StoxConfig::default().int16_kernel_ok());
        // 4-bit streams × 4-bit slices @ 256 rows: 256·15·15 = 57600 > 32767
        let wide = StoxConfig { a_stream_bits: 4, ..Default::default() };
        assert!(wide.int_kernel_ok() && !wide.int16_kernel_ok());
        // i16 tier implies the integer kernel gate
        let huge = StoxConfig { r_arr: 1 << 20, a_stream_bits: 4, ..Default::default() };
        assert!(!huge.int16_kernel_ok());
    }

    #[test]
    fn config_helpers() {
        let cfg = StoxConfig::default();
        assert_eq!(cfg.n_streams(), 4);
        assert_eq!(cfg.n_slices(), 1);
        assert_eq!(cfg.n_arrs(576), 3);
        assert_eq!(cfg.n_arrs(1), 1);
        assert_eq!(cfg.tag(), "4w4a4bs");
        // N = log2(256) + 1 + 4 - 2 = 11 for 4-bit slices; 8 for 1-bit
        assert_eq!(cfg.adc_bits(), 11);
        let cfg1 = StoxConfig { w_slice_bits: 1, ..cfg };
        assert_eq!(cfg1.adc_bits(), 8);
    }

    #[test]
    fn config_validation() {
        let bad = StoxConfig { a_bits: 4, a_stream_bits: 3, ..Default::default() };
        assert!(bad.validate().is_err());
        assert!(StoxConfig::default().validate().is_ok());
    }

    #[test]
    fn tag_round_trips_through_from_tag() {
        let base = StoxConfig::default();
        for tag in ["4w4a4bs", "8w8a4bs", "8w8a2bs", "2w2a1bs", "8w4a1bs"] {
            let cfg = StoxConfig::from_tag(tag, &base).unwrap();
            assert_eq!(cfg.tag(), tag, "round trip of {tag}");
            // non-precision knobs carry over from base
            assert_eq!(cfg.r_arr, base.r_arr);
            assert_eq!(cfg.alpha, base.alpha);
            assert_eq!(cfg.n_samples, base.n_samples);
        }
    }

    #[test]
    fn from_tag_defaults_slice_width_when_omitted() {
        let base = StoxConfig::default(); // 4-bit slices
        let cfg = StoxConfig::from_tag("8w8a", &base).unwrap();
        assert_eq!((cfg.w_bits, cfg.a_bits, cfg.w_slice_bits), (8, 8, 4));
        // slice default clamps to the tag's weight width
        let cfg2 = StoxConfig::from_tag("2w2a", &base).unwrap();
        assert_eq!(cfg2.w_slice_bits, 2);
    }

    #[test]
    fn from_tag_rejects_malformed_and_indivisible() {
        let base = StoxConfig::default();
        for bad in ["", "4w", "4w4", "w4a4bs", "4w4a4", "4x4a4bs", "6w4a4bs"] {
            assert!(
                StoxConfig::from_tag(bad, &base).is_err(),
                "tag '{bad}' must be rejected"
            );
        }
    }
}
