//! Algorithm 1 end-to-end: the StoX crossbar MVM, bit-identical with the
//! python oracle (`ref.stox_mvm`) when driven by the stochastic MTJ
//! converter.
//!
//! [`StoxMvm`] is the production shape: weights are quantized, sliced and
//! partitioned into subarrays **once** (crossbar programming), then many
//! activations run through [`StoxMvm::run`].  `stox_mvm` is the one-shot
//! convenience used by tests.
//!
//! # The integer digit-plane kernel (EXPERIMENTS.md §Perf)
//!
//! Crossbar arithmetic happens in the *quantized digit domain*: weight
//! slices and activation streams are small signed odd integers.  The hot
//! kernel therefore stores weight digits as contiguous `i8` planes (4×
//! denser than the legacy f32 layout — a 256×64 plane is 16 KB and
//! L1-resident), decomposes activations into `i8` digit stripes once per
//! (batch row, subarray), and accumulates partial sums in `i32` with the
//! inner loop blocked over output columns so it autovectorizes.  This is
//! **exact**: every digit product and every `r_arr`-bounded sum is an
//! integer far below 2²⁴ ([`StoxConfig::int_kernel_ok`]), so converting
//! the `i32` accumulator to f32 before normalization is bit-identical to
//! the legacy f32 MAC path — the frozen RNG counter contract and all
//! golden files pin unchanged.  Configs outside the exactness bound fall
//! back to the retained f32 reference kernel ([`StoxMvm::program_reference`]
//! forces it), and `tests/proptests.rs` pins integer == reference exactly.
//!
//! The kernel is generic over [`PsConvert`]: conversion happens one PS
//! *column slice* at a time, through the integer entry point
//! (`convert_slice_int_at`) so converters can memoize per-level work
//! (the stochastic MTJ's tanh→threshold) across the run.

use super::convert::{PsConvert, PsIntCache};
use super::quant::{self, StoxConfig};
use super::simd::{self, MacBackend};
use crate::obs::{span, Counter, CounterRegistry, TraceLevel};
use crate::stats::rng::CounterRng;

/// Programmed weight-slice digit planes, flattened `[k][j][r][c]`
/// (subarray, slice, row, column — one contiguous allocation).
enum WeightPlanes {
    /// `i8` digit planes — the integer digit-plane kernel layout.
    I8(Vec<i8>),
    /// Legacy f32 planes — the retained reference kernel's layout, used
    /// when the config is outside the integer exactness bound (or forced
    /// by [`StoxMvm::program_reference`] for A/B benchmarking).
    F32(Vec<f32>),
}

/// A crossbar-programmed weight matrix ready for repeated MVMs.
pub struct StoxMvm {
    pub cfg: StoxConfig,
    pub m: usize,
    pub n: usize,
    n_arrs: usize,
    planes: WeightPlanes,
    /// SIMD MAC backend chosen at programming time ([`MacBackend::detect`];
    /// `STOX_SIMD` overrides) — every backend is bit-identical to scalar.
    backend: MacBackend,
    /// `i16` accumulation tier active ([`StoxConfig::int16_kernel_ok`] at
    /// programming time) — double lanes, bit-identical results.
    i16_tier: bool,
    /// Deterministic hardware counters ([`StoxMvm::attach_counters`]);
    /// `None` (the default) keeps the kernel telemetry-free.
    counters: Option<Box<KernelCounters>>,
}

/// Deterministic hardware counters of one programmed crossbar: one
/// [`Counter`] per architectural event class, flushed once per (batch
/// row, subarray) stripe at the end of `run_stripe_int` so the MAC and
/// conversion hot loops stay free of atomics.  Every tally is a linear
/// function of the workload and the programmed digits, so two same-seed
/// runs produce identical totals (the [`crate::obs`] determinism
/// contract).
struct KernelCounters {
    /// digit-domain multiply-accumulates executed (zero-skips excluded)
    macs: Counter,
    /// row×slice MAC iterations skipped because the activation digit is 0
    /// (every MAC backend shares the `x == 0 → continue` semantics)
    zero_digit_skips: Counter,
    /// activation DAC drives: stripe rows × streams
    dac_actions: Counter,
    /// bit-cell accesses: stripe rows × streams × 2 cells × slices
    cell_actions: Counter,
    /// PS conversions: column slices × columns per stripe
    conversions: Counter,
    /// output I/O transfers: streams × columns once per batch row
    out_io: Counter,
    /// batched converter dispatches ([`PsConvert::convert_batch`] calls)
    convert_batch_calls: Counter,
    /// (stream, slice) groups digitized across those dispatches
    convert_batch_groups: Counter,
    /// stripe rows accumulated on the `i16` tier
    i16_rows: Counter,
    /// stochastic MTJ ±1 reads ([`PsIntCache`] draw tally)
    mtj_draws: Counter,
    /// [`PsIntCache`] memo lookups answered from the table
    memo_hits: Counter,
    /// [`PsIntCache`] memo lookups that computed their payload
    memo_misses: Counter,
}

/// Per-worker scratch of the integer kernel: activation digit stripe,
/// PS accumulators, conversion buffers and the per-level threshold memo —
/// allocated once per worker thread and reused across (batch, subarray)
/// tasks.
struct IntScratch {
    /// stripe digits, row-major [r][i] (matches the digit-plane gather)
    xd: Vec<i8>,
    /// one row's stream digits
    digits: Vec<i8>,
    /// integer PS accumulator of one column slice (the probe path)
    ps_int: Vec<i32>,
    /// integer PS accumulators of one whole (b, k) group, layout [j][i][c]
    /// — filled for all slices first so one [`PsConvert::convert_batch`]
    /// call digitizes the group
    ps_group: Vec<i32>,
    /// (stream, slice, counter base) of each group slice, [j][i] order
    coords: Vec<(usize, usize, u32)>,
    /// converter-level memo ([`PsIntCache`])
    cache: PsIntCache,
    /// scaled conversion terms of one (b, k) group, layout [j][i][c] —
    /// folded into the output in exactly the sequential accumulation order
    contrib: Vec<f32>,
}

impl IntScratch {
    fn new(mvm: &StoxMvm) -> Self {
        let cfg = &mvm.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let mut cache = PsIntCache::new();
        cache.reset(cfg.int_ps_bound() as usize);
        Self {
            xd: vec![0; cfg.r_arr * i_n],
            digits: vec![0; i_n],
            ps_int: vec![0; mvm.n],
            ps_group: vec![0; j_n * i_n * mvm.n],
            coords: Vec::with_capacity(j_n * i_n),
            cache,
            contrib: vec![0.0; j_n * i_n * mvm.n],
        }
    }
}

impl StoxMvm {
    /// Program the crossbar: quantize + slice + partition `w` ([M×N],
    /// values in [-1,1], row-major).  Stores `i8` digit planes (the
    /// integer kernel layout) whenever [`StoxConfig::int_kernel_ok`]
    /// holds — every paper config — and the legacy f32 planes otherwise.
    pub fn program(w: &[f32], m: usize, n: usize, cfg: StoxConfig) -> crate::Result<Self> {
        Self::program_impl(w, m, n, cfg, cfg.int_kernel_ok())
    }

    /// Program with the retained pre-integer f32 plane layout regardless
    /// of config — the reference kernel for equivalence proptests and the
    /// before/after perf cases in `benches/mvm.rs`.  Bit-identical
    /// results, legacy speed.
    pub fn program_reference(
        w: &[f32],
        m: usize,
        n: usize,
        cfg: StoxConfig,
    ) -> crate::Result<Self> {
        Self::program_impl(w, m, n, cfg, false)
    }

    fn program_impl(
        w: &[f32],
        m: usize,
        n: usize,
        cfg: StoxConfig,
        int_planes: bool,
    ) -> crate::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(w.len() == m * n, "weight shape mismatch");
        let n_arrs = cfg.n_arrs(m);
        let j_n = cfg.n_slices();
        let plane_sz = cfg.r_arr * n;
        let mut digits = vec![0i32; j_n];
        // rows beyond m stay 0 (absent cells contribute no current)
        let mut wd8 = vec![0i8; if int_planes { n_arrs * j_n * plane_sz } else { 0 }];
        let mut wd32 = vec![0.0f32; if int_planes { 0 } else { n_arrs * j_n * plane_sz }];
        for r in 0..m {
            let k = r / cfg.r_arr;
            let rr = r % cfg.r_arr;
            for c in 0..n {
                let u = quant::quantize_unit(w[r * n + c], cfg.w_bits);
                quant::signed_digits(u, cfg.w_bits, cfg.w_slice_bits, &mut digits);
                for (j, &d) in digits.iter().enumerate() {
                    let idx = ((k * j_n + j) * cfg.r_arr + rr) * n + c;
                    if int_planes {
                        wd8[idx] = d as i8;
                    } else {
                        wd32[idx] = d as f32;
                    }
                }
            }
        }
        let planes = if int_planes {
            WeightPlanes::I8(wd8)
        } else {
            WeightPlanes::F32(wd32)
        };
        // backend + accumulation tier are per-crossbar ("per-layer")
        // decisions made once at programming time
        let (backend, i16_tier) = if int_planes {
            (MacBackend::detect(), cfg.int16_kernel_ok())
        } else {
            (MacBackend::Scalar, false)
        };
        Ok(Self { cfg, m, n, n_arrs, planes, backend, i16_tier, counters: None })
    }

    /// Attach deterministic hardware counters under `scope` (e.g.
    /// `"imc.l00.4w4a4bs."`) in `reg`: every subsequent integer-kernel
    /// run tallies its architectural events — MACs, zero-digit row skips,
    /// DAC/cell actions, PS conversions, output I/O, converter dispatch
    /// and memo statistics — into `{scope}{event}` counters.  The f32
    /// reference kernel is not instrumented (it models no architectural
    /// events the integer kernel doesn't), and a crossbar without an
    /// attachment pays one untaken branch per stripe.
    ///
    /// Determinism: every tally except the memo hit/miss split is a
    /// linear per-stripe sum and byte-reproducible on every execution
    /// path; the hit/miss split is additionally reproducible on the
    /// sequential and per-image pipelined paths (see
    /// [`PsIntCache::take_stats`]), which is what `stox-cli infer` and
    /// the scenario goldens measure.
    pub fn attach_counters(&mut self, reg: &CounterRegistry, scope: &str) {
        let c = |name: &str| reg.counter(&format!("{scope}{name}"));
        self.counters = Some(Box::new(KernelCounters {
            macs: c("macs"),
            zero_digit_skips: c("zero_digit_skips"),
            dac_actions: c("dac_actions"),
            cell_actions: c("cell_actions"),
            conversions: c("conversions"),
            out_io: c("out_io"),
            convert_batch_calls: c("convert_batch_calls"),
            convert_batch_groups: c("convert_batch_groups"),
            i16_rows: c("i16_rows"),
            mtj_draws: c("mtj_draws"),
            memo_hits: c("memo_hits"),
            memo_misses: c("memo_misses"),
        }));
    }

    /// Detach the counters attached by [`StoxMvm::attach_counters`].
    pub fn detach_counters(&mut self) {
        self.counters = None;
    }

    pub fn n_arrs(&self) -> usize {
        self.n_arrs
    }

    /// Whether this crossbar runs the integer digit-plane kernel
    /// (i8 planes) rather than the retained f32 reference kernel.
    pub fn is_integer_kernel(&self) -> bool {
        matches!(self.planes, WeightPlanes::I8(_))
    }

    /// The SIMD MAC backend this crossbar dispatches to (README §SIMD) —
    /// the label benches record next to their before/after timings.
    pub fn mac_backend(&self) -> MacBackend {
        self.backend
    }

    /// Force a specific MAC backend (equivalence proptests, the
    /// scalar-vs-SIMD bench cases).  Errors when the backend is not
    /// available in this build/host; results are bit-identical either way.
    pub fn set_mac_backend(&mut self, backend: MacBackend) -> crate::Result<()> {
        anyhow::ensure!(
            backend.available(),
            "MAC backend '{}' is not available in this build/host",
            backend.label()
        );
        self.backend = backend;
        Ok(())
    }

    /// Whether the `i16` accumulation tier is active (selected per layer
    /// at programming time when [`StoxConfig::int16_kernel_ok`] holds).
    pub fn i16_tier(&self) -> bool {
        self.i16_tier
    }

    /// Toggle the `i16` accumulation tier (the i32-vs-i16 bench cases and
    /// equivalence proptests).  Errors when the config's PS bound does not
    /// fit `i16` — forcing it on anyway could overflow.
    pub fn set_i16_tier(&mut self, on: bool) -> crate::Result<()> {
        anyhow::ensure!(
            !on || self.cfg.int16_kernel_ok(),
            "i16 tier needs int16_kernel_ok (int_ps_bound {} > {})",
            self.cfg.int_ps_bound(),
            i16::MAX
        );
        self.i16_tier = on;
        Ok(())
    }

    /// Dispatch one column-slice MAC through the selected backend and
    /// accumulation tier — bit-identical to [`simd::mac_i32_scalar`] on
    /// every (backend, tier) pair.
    fn mac(&self, w_pl: &[i8], xd: &[i8], rows: usize, stream: usize, ps: &mut [i32]) {
        let i_n = self.cfg.n_streams();
        if self.i16_tier {
            simd::mac_i16(self.backend, w_pl, xd, rows, i_n, stream, self.n, ps);
        } else {
            simd::mac_i32(self.backend, w_pl, xd, rows, i_n, stream, self.n, ps);
        }
    }

    /// Flat byte range of subarray `k`, slice `j` within the plane store.
    fn plane_range(&self, k: usize, j: usize) -> std::ops::Range<usize> {
        let plane_sz = self.cfg.r_arr * self.n;
        let base = (k * self.cfg.n_slices() + j) * plane_sz;
        base..base + plane_sz
    }

    /// Borrow the stored planes directly when this crossbar already holds
    /// the f32 reference layout — lets wrappers avoid duplicating them.
    pub(crate) fn planes_f32_ref(&self) -> Option<&[f32]> {
        match &self.planes {
            WeightPlanes::F32(p) => Some(p),
            WeightPlanes::I8(_) => None,
        }
    }

    /// Run a batch of activations (`a`: [B×M] row-major, values in [-1,1])
    /// through the crossbar with the given PS converter; returns [B×N].
    ///
    /// Parallelism (all paths bit-identical — the RNG counter space is
    /// keyed by absolute indices and f32 folds replay the sequential
    /// order):
    ///
    /// * `batch ≥ 2·threads` — batch rows fan out in contiguous chunks;
    /// * otherwise, when there are ≥ 2 (batch row, subarray) tasks, the
    ///   sub-batch split ([`StoxMvm::run_ksplit`]) fans out over subarrays
    ///   too — the single-image serving shape where the batch fan-out
    ///   alone never triggers;
    /// * `STOX_THREADS=1` forces the sequential kernel.
    pub fn run<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        let threads = crate::util::pool::default_threads();
        if batch >= 2 * threads && threads > 1 {
            let chunk = batch.div_ceil(threads);
            let n_chunks = batch.div_ceil(chunk);
            let parts = crate::util::pool::par_map(n_chunks, threads, |ci| {
                let b0 = ci * chunk;
                let b1 = ((ci + 1) * chunk).min(batch);
                self.run_range(a, b0, b1, conv, seed)
            });
            let mut out = Vec::with_capacity(batch * self.n);
            for p in parts {
                out.extend(p);
            }
            return out;
        }
        if threads > 1 && batch * self.n_arrs >= 2 && self.is_integer_kernel() {
            return self.run_ksplit(a, batch, conv, seed, threads);
        }
        self.run_range(a, 0, batch, conv, seed)
    }

    /// The sequential kernel over the whole batch — the bit-identity
    /// reference every parallel path is pinned against.
    pub fn run_sequential<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        self.run_range(a, 0, batch, conv, seed)
    }

    /// Sequential kernel over batch rows [b0, b1).
    fn run_range<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        b0: usize,
        b1: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        match &self.planes {
            WeightPlanes::I8(planes) => {
                self.run_range_int(planes, a, b0, b1, conv, seed, None)
            }
            WeightPlanes::F32(planes) => self.run_range_ref(planes, a, b0, b1, conv, seed),
        }
    }

    /// Integer digit-plane kernel over batch rows [b0, b1).  `capture`,
    /// when present, must hold `batch · K · I · J · N` f32 and receives
    /// every normalized per-slice PS in the canonical `[b][k][i][j][col]`
    /// layout of [`StoxMvm::collect_ps`] — same pass, same bits.
    #[allow(clippy::too_many_arguments)]
    fn run_range_int<C: PsConvert + ?Sized>(
        &self,
        planes: &[i8],
        a: &[f32],
        b0: usize,
        b1: usize,
        conv: &C,
        seed: u32,
        mut capture: Option<&mut [f32]>,
    ) -> Vec<f32> {
        let batch = b1 - b0;
        debug_assert!(a.len() >= b1 * self.m);
        if self.n == 0 || batch == 0 {
            return vec![0.0f32; batch * self.n];
        }
        let cfg = &self.cfg;
        let rng = CounterRng::new(seed);
        let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
        let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);
        let norm = self.out_norm(conv.samples());
        let group = cfg.n_streams() * cfg.n_slices() * self.n;

        let mut out = vec![0.0f32; batch * self.n];
        let mut scratch = IntScratch::new(self);
        for b in b0..b1 {
            for k in 0..self.n_arrs {
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                self.decompose_stripe(a, b, row0, rows, &mut scratch);
                let cap = capture.as_deref_mut().map(|buf| {
                    let g0 = ((b - b0) * self.n_arrs + k) * group;
                    &mut buf[g0..g0 + group]
                });
                self.run_stripe_int(
                    planes, rows, b, k, conv, &rng, &sa, &sw, norm, &mut scratch, cap,
                );
                let orow = &mut out[(b - b0) * self.n..(b - b0 + 1) * self.n];
                // fold the (j, i) terms in exactly the sequential order
                for terms in scratch.contrib.chunks_exact(self.n) {
                    for (o, &v) in orow.iter_mut().zip(terms) {
                        *o += v;
                    }
                }
            }
        }
        out
    }

    /// Sub-batch fan-out over (batch row, subarray) tasks — the
    /// single-image serving path where `batch < 2·threads` never triggers
    /// the batch fan-out.  Bit-identical to [`StoxMvm::run_sequential`]:
    /// each task produces its (b, k) group's scaled conversion terms and
    /// the calling thread folds them in exactly the sequential
    /// accumulation order (f32 addition is order-sensitive, so the fold
    /// replays it rather than summing per-thread partials).
    pub fn run_ksplit<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
        threads: usize,
    ) -> Vec<f32> {
        let WeightPlanes::I8(planes) = &self.planes else {
            // reference layout: no stripe kernel to fan out — stay sequential
            return self.run_range(a, 0, batch, conv, seed);
        };
        if self.n == 0 || batch == 0 {
            return vec![0.0f32; batch * self.n];
        }
        let cfg = &self.cfg;
        let rng = CounterRng::new(seed);
        let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
        let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);
        let norm = self.out_norm(conv.samples());
        debug_assert!(a.len() >= batch * self.m);

        let n_tasks = batch * self.n_arrs;
        let parts = crate::util::pool::par_map_scratch(
            n_tasks,
            threads,
            || IntScratch::new(self),
            |scratch, t| {
                let b = t / self.n_arrs;
                let k = t % self.n_arrs;
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                self.decompose_stripe(a, b, row0, rows, scratch);
                self.run_stripe_int(
                    planes, rows, b, k, conv, &rng, &sa, &sw, norm, scratch, None,
                );
                scratch.contrib.clone()
            },
        );
        let mut out = vec![0.0f32; batch * self.n];
        for (t, part) in parts.iter().enumerate() {
            let b = t / self.n_arrs;
            let orow = &mut out[b * self.n..(b + 1) * self.n];
            // tasks arrive in (b, k) order and each part holds its (j, i)
            // terms in order — the fold replays the sequential accumulation
            for terms in part.chunks_exact(self.n) {
                for (o, &v) in orow.iter_mut().zip(terms) {
                    *o += v;
                }
            }
        }
        out
    }

    /// Algorithm 1 output normalization factor.
    fn out_norm(&self, samples: u32) -> f32 {
        let cfg = &self.cfg;
        let lev = (((1u64 << cfg.a_bits) - 1) * ((1u64 << cfg.w_bits) - 1)) as f32;
        1.0 / (lev * self.n_arrs as f32 * samples as f32)
    }

    /// Quantize + decompose the activation stripe of (batch row `b`,
    /// subarray rows [row0, row0+rows)) into `scratch.xd` ([r][i] i8).
    fn decompose_stripe(
        &self,
        a: &[f32],
        b: usize,
        row0: usize,
        rows: usize,
        scratch: &mut IntScratch,
    ) {
        let cfg = &self.cfg;
        let i_n = cfg.n_streams();
        for rr in 0..rows {
            let u = quant::quantize_unit(a[b * self.m + row0 + rr], cfg.a_bits);
            quant::signed_digits_i8(u, cfg.a_bits, cfg.a_stream_bits, &mut scratch.digits);
            scratch.xd[rr * i_n..(rr + 1) * i_n].copy_from_slice(&scratch.digits);
        }
    }

    /// Integer kernel core for one (b, k) group: for every (slice j,
    /// stream i) accumulate the column slice in i32, convert it through
    /// the integer entry point, and write the scaled terms into
    /// `scratch.contrib` ([j][i][c] — the sequential fold order).
    /// `ps_out`, when present, receives this group's normalized PS at
    /// offset `(i·J + j)·n` — the `[i][j][col]` block of the canonical
    /// `collect_ps` capture layout, bit-identical to the probe
    /// (`ps_int·inv_r`, the integer kernel's exactness contract).
    #[allow(clippy::too_many_arguments)]
    fn run_stripe_int<C: PsConvert + ?Sized>(
        &self,
        planes: &[i8],
        rows: usize,
        b: usize,
        k: usize,
        conv: &C,
        rng: &CounterRng,
        sa: &[f32],
        sw: &[f32],
        norm: f32,
        scratch: &mut IntScratch,
        mut ps_out: Option<&mut [f32]>,
    ) {
        let _sp = span::span(TraceLevel::Kernel, "stripe", "kernel");
        let cfg = &self.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let n = self.n;
        let inv_r = 1.0 / cfg.r_arr as f32;
        let IntScratch { xd, ps_group, coords, cache, contrib, .. } = scratch;
        // phase 1 — accumulate every (j, i) slice of the group
        coords.clear();
        for j in 0..j_n {
            let w_pl = &planes[self.plane_range(k, j)];
            for i in 0..i_n {
                let g = j * i_n + i;
                let ps_int = &mut ps_group[g * n..(g + 1) * n];
                if self.i16_tier {
                    simd::mac_i16(self.backend, w_pl, xd, rows, i_n, i, n, ps_int);
                } else {
                    simd::mac_i32(self.backend, w_pl, xd, rows, i_n, i, n, ps_int);
                }
                if let Some(cap) = ps_out.as_deref_mut() {
                    let dst = &mut cap[(i * j_n + j) * n..(i * j_n + j + 1) * n];
                    for (d, &p) in dst.iter_mut().zip(ps_int.iter()) {
                        *d = p as f32 * inv_r;
                    }
                }
                // canonical counter layout shared with python (frozen
                // contract): base(c) = (((b·K + k)·N + c)·I + i)·J + j, so
                // the whole column slice is (base(0), stride I·J) —
                // wrapping arithmetic is congruent mod 2³² wherever the
                // truncation lands.
                let base0 = ((((b * self.n_arrs + k) * n) * i_n + i) as u32)
                    .wrapping_mul(j_n as u32)
                    .wrapping_add(j as u32);
                coords.push((i, j, base0));
            }
        }
        // phase 2 — digitize the whole group in one converter call
        // (threshold draws and PsIntCache lookups amortize across slices;
        // bit-identical to per-slice conversion by the trait contract)
        let stride = (i_n * j_n) as u32;
        conv.convert_batch(coords, stride, n, ps_group, inv_r, contrib, rng, cache);
        // phase 3 — apply the shift-and-add significance scales in place
        for j in 0..j_n {
            for i in 0..i_n {
                let scale = sa[i] * sw[j] * norm;
                for o in contrib[(j * i_n + i) * n..(j * i_n + i + 1) * n].iter_mut() {
                    *o *= scale;
                }
            }
        }
        // telemetry flush — one pass per stripe, atomics only when attached
        if let Some(ctr) = &self.counters {
            // zero activation digits over the stripe: each one skips a row
            // of every slice's MAC (the shared `x == 0 → continue`)
            let mut zero_rows = 0u64;
            for &x in xd[..rows * i_n].iter() {
                if x == 0 {
                    zero_rows += 1;
                }
            }
            let (rows_u, i_u, j_u, n_u) = (rows as u64, i_n as u64, j_n as u64, n as u64);
            ctr.macs.add((rows_u * i_u - zero_rows) * j_u * n_u);
            ctr.zero_digit_skips.add(zero_rows * j_u);
            ctr.dac_actions.add(rows_u * i_u);
            ctr.cell_actions.add(rows_u * i_u * 2 * j_u);
            ctr.conversions.add(i_u * j_u * n_u);
            if k == 0 {
                // output transfer is per batch row, not per subarray
                ctr.out_io.add(i_u * n_u);
            }
            ctr.convert_batch_calls.incr();
            ctr.convert_batch_groups.add(coords.len() as u64);
            if self.i16_tier {
                ctr.i16_rows.add(rows_u);
            }
            let (hits, misses, draws) = cache.take_stats();
            ctr.memo_hits.add(hits);
            ctr.memo_misses.add(misses);
            ctr.mtj_draws.add(draws);
        }
    }

    /// Sequential forward **plus per-slice PS capture** — the training
    /// tape's hook (`train/`): returns the converted outputs of
    /// [`StoxMvm::run_sequential`] bit-for-bit, together with every
    /// normalized array-level partial sum in the canonical
    /// `[b][k][i][j][col]` order of [`StoxMvm::collect_ps`].  The §3.3
    /// surrogate backward is evaluated at exactly these PS values, so the
    /// capture shares the forward's single accumulation pass on the
    /// integer kernel (reference-layout crossbars fall back to a second
    /// probe pass with identical bits).
    pub fn run_capture<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
    ) -> (Vec<f32>, Vec<f32>) {
        match &self.planes {
            WeightPlanes::I8(planes) => self.run_capture_int(planes, a, batch, conv, seed),
            WeightPlanes::F32(_) => (
                self.run_sequential(a, batch, conv, seed),
                self.collect_ps(a, batch),
            ),
        }
    }

    /// Integer-kernel body of [`StoxMvm::run_capture`]: exactly
    /// [`StoxMvm::run_range`]'s sequential driver with the capture buffer
    /// threaded through — one code path, so the bit-identity contract
    /// cannot drift.
    fn run_capture_int<C: PsConvert + ?Sized>(
        &self,
        planes: &[i8],
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
    ) -> (Vec<f32>, Vec<f32>) {
        let group = self.cfg.n_streams() * self.cfg.n_slices() * self.n;
        let mut ps_all = vec![0.0f32; batch * self.n_arrs * group];
        let out = self.run_range_int(planes, a, 0, batch, conv, seed, Some(&mut ps_all));
        (out, ps_all)
    }

    /// Retained f32 reference kernel over batch rows [b0, b1) — the
    /// pre-integer hot loop, kept verbatim for configs outside the
    /// exactness bound and as the equivalence/benchmark baseline.
    fn run_range_ref<C: PsConvert + ?Sized>(
        &self,
        planes: &[f32],
        a: &[f32],
        b0: usize,
        b1: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        let batch = b1 - b0;
        debug_assert!(a.len() >= b1 * self.m);
        let cfg = &self.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let rng = CounterRng::new(seed);
        let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
        let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);
        let norm = self.out_norm(conv.samples());
        let inv_r = 1.0 / cfg.r_arr as f32;

        let mut out = vec![0.0f32; batch * self.n];
        // activation digits of one (b, k) stripe, row-major [r][i] so the
        // inner loop reads them contiguously
        let mut xd = vec![0.0f32; cfg.r_arr * i_n];
        let mut digits = vec![0i32; i_n];
        // per-stream PS accumulators [i][n] (I·N f32 — L1-resident)
        let mut ps = vec![0.0f32; i_n * self.n];
        // per-slice scratch: normalized PS in, converted values out
        let mut psn = vec![0.0f32; self.n];
        let mut cv = vec![0.0f32; self.n];

        for b in b0..b1 {
            for k in 0..self.n_arrs {
                // decompose this subarray's activation stripe
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                for rr in 0..rows {
                    let u = quant::quantize_unit(a[b * self.m + row0 + rr], cfg.a_bits);
                    quant::signed_digits(u, cfg.a_bits, cfg.a_stream_bits, &mut digits);
                    for (i, &d) in digits.iter().enumerate() {
                        xd[rr * i_n + i] = d as f32;
                    }
                }
                for j in 0..j_n {
                    ps.iter_mut().for_each(|v| *v = 0.0);
                    let w_sl = &planes[self.plane_range(k, j)];
                    // one pass over the slice rows feeds every stream
                    for rr in 0..rows {
                        let wrow = &w_sl[rr * self.n..(rr + 1) * self.n];
                        let xr = &xd[rr * i_n..rr * i_n + i_n];
                        for (i, &x) in xr.iter().enumerate() {
                            let acc = &mut ps[i * self.n..(i + 1) * self.n];
                            for (p, &wv) in acc.iter_mut().zip(wrow) {
                                *p += x * wv;
                            }
                        }
                    }
                    for i in 0..i_n {
                        let scale = sa[i] * sw[j] * norm;
                        let ps_i = &ps[i * self.n..(i + 1) * self.n];
                        for (pn, &p) in psn.iter_mut().zip(ps_i) {
                            *pn = p * inv_r;
                        }
                        // same frozen counter layout as run_stripe_int
                        let base0 = ((((b * self.n_arrs + k) * self.n) * i_n
                            + i) as u32)
                            .wrapping_mul(j_n as u32)
                            .wrapping_add(j as u32);
                        let stride = (i_n * j_n) as u32;
                        conv.convert_slice_at(i, j, &psn, &mut cv, base0, stride, &rng);
                        let orow =
                            &mut out[(b - b0) * self.n..(b - b0 + 1) * self.n];
                        for (o, &v) in orow.iter_mut().zip(cv.iter()) {
                            *o += v * scale;
                        }
                    }
                }
            }
        }
        out
    }
}

impl StoxMvm {
    /// Enumerate all normalized array-level partial sums for a batch
    /// (the Fig. 4 distribution probe).  Order: [b][k][i][j][col].
    pub fn collect_ps(&self, a: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(a.len(), batch * self.m);
        match &self.planes {
            WeightPlanes::I8(planes) => self.collect_ps_int(planes, a, batch),
            WeightPlanes::F32(planes) => self.collect_ps_ref(planes, a, batch),
        }
    }

    /// Integer digit-plane probe: same i32 accumulation as the hot
    /// kernel, so the emitted values are bit-identical to the f32 path.
    fn collect_ps_int(&self, planes: &[i8], a: &[f32], batch: usize) -> Vec<f32> {
        let cfg = &self.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let inv_r = 1.0 / cfg.r_arr as f32;
        let mut out = Vec::with_capacity(batch * self.n_arrs * i_n * j_n * self.n);
        let mut scratch = IntScratch::new(self);
        for b in 0..batch {
            for k in 0..self.n_arrs {
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                self.decompose_stripe(a, b, row0, rows, &mut scratch);
                for i in 0..i_n {
                    for j in 0..j_n {
                        let w_pl = &planes[self.plane_range(k, j)];
                        self.mac(w_pl, &scratch.xd, rows, i, &mut scratch.ps_int);
                        out.extend(scratch.ps_int.iter().map(|&p| p as f32 * inv_r));
                    }
                }
            }
        }
        out
    }

    /// Reference (f32 plane) probe — pre-integer code path.
    fn collect_ps_ref(&self, planes: &[f32], a: &[f32], batch: usize) -> Vec<f32> {
        let cfg = &self.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let inv_r = 1.0 / cfg.r_arr as f32;
        let mut out =
            Vec::with_capacity(batch * self.n_arrs * i_n * j_n * self.n);
        let mut xd = vec![vec![0.0f32; cfg.r_arr]; i_n];
        let mut digits = vec![0i32; i_n];
        let mut ps_row = vec![0.0f32; self.n];
        for b in 0..batch {
            for k in 0..self.n_arrs {
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                for rr in 0..rows {
                    let u = quant::quantize_unit(a[b * self.m + row0 + rr], cfg.a_bits);
                    quant::signed_digits(u, cfg.a_bits, cfg.a_stream_bits, &mut digits);
                    for (i, &d) in digits.iter().enumerate() {
                        xd[i][rr] = d as f32;
                    }
                }
                for i in 0..i_n {
                    for j in 0..j_n {
                        ps_row.iter_mut().for_each(|v| *v = 0.0);
                        let w_sl = &planes[self.plane_range(k, j)];
                        for rr in 0..rows {
                            let x = xd[i][rr];
                            if x == 0.0 {
                                continue;
                            }
                            let wrow = &w_sl[rr * self.n..(rr + 1) * self.n];
                            for (p, &wv) in ps_row.iter_mut().zip(wrow) {
                                *p += x * wv;
                            }
                        }
                        out.extend(ps_row.iter().map(|p| p * inv_r));
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// Fused digit-domain convolution
// ---------------------------------------------------------------------

/// Reusable scratch for the fused digit-domain conv path: holds the
/// per-pixel activation digit planes of the current layer, grown to the
/// largest layer seen and never shrunk — `NativeModel::forward` threads
/// one arena through every layer instead of allocating im2col patch
/// buffers per layer.
#[derive(Default)]
pub struct ConvArena {
    digits: Vec<i8>,
    pad: Vec<i8>,
}

impl ConvArena {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Pre-decomposed NHWC activation digits (a view into a [`ConvArena`]):
/// each input pixel's quantized code is decomposed into its I signed
/// stream digits exactly **once**, laid out `[b][y][x][c][i]` (stream
/// fastest) so an im2col row gather over consecutive channels is one
/// contiguous copy of `cin·I` digits.
pub struct ActivationDigits<'a> {
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    i_n: usize,
    digits: &'a [i8],
    /// digit pattern of the padding value `quantize(0.0)`, inserted for
    /// out-of-bounds taps — exactly what `im2col`'s zero fill quantizes to
    pad: &'a [i8],
}

/// Decompose every pixel of `x` ([b,h,w,c] NHWC) once into signed digit
/// stripes, reusing `arena`'s buffer.  Values are clamped by
/// [`quant::quantize_unit`] itself, so the legacy path's pre-clipped
/// `xin` copy is unnecessary — `quantize(clamp(v)) == quantize(v)` for
/// every input.
pub fn decompose_activations<'a>(
    arena: &'a mut ConvArena,
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    cfg: &StoxConfig,
) -> ActivationDigits<'a> {
    assert_eq!(x.len(), b * h * w * c, "activation shape mismatch");
    assert!(
        cfg.a_stream_bits <= 7,
        "digit-domain conv needs i8 stream digits (int_kernel_ok)"
    );
    let i_n = cfg.n_streams();
    arena.digits.clear();
    arena.digits.resize(x.len() * i_n, 0);
    let mut dig = vec![0i8; i_n];
    for (p, &v) in x.iter().enumerate() {
        let u = quant::quantize_unit(v, cfg.a_bits);
        quant::signed_digits_i8(u, cfg.a_bits, cfg.a_stream_bits, &mut dig);
        arena.digits[p * i_n..(p + 1) * i_n].copy_from_slice(&dig);
    }
    arena.pad.clear();
    arena.pad.resize(i_n, 0);
    let u0 = quant::quantize_unit(0.0, cfg.a_bits);
    quant::signed_digits_i8(u0, cfg.a_bits, cfg.a_stream_bits, &mut arena.pad);
    ActivationDigits {
        b,
        h,
        w,
        c,
        i_n,
        digits: &arena.digits,
        pad: &arena.pad,
    }
}

impl ActivationDigits<'_> {
    /// Gather the digit stripe of subarray rows [row0, row0+rows) of the
    /// patch at (bi, oy, ox) into `xd` ([r][i] row-major): one contiguous
    /// copy per kernel tap run, the pad pattern for out-of-bounds taps.
    #[allow(clippy::too_many_arguments)]
    fn gather_stripe(
        &self,
        kw: usize,
        stride: usize,
        pad: usize,
        bi: usize,
        oy: usize,
        ox: usize,
        row0: usize,
        rows: usize,
        xd: &mut [i8],
    ) {
        let (h, w, cin, i_n) = (self.h, self.w, self.c, self.i_n);
        let mut rr = 0usize;
        while rr < rows {
            let row = row0 + rr;
            let tap = row / cin;
            let ci0 = row % cin;
            let len = (cin - ci0).min(rows - rr);
            let ky = tap / kw;
            let kx = tap % kw;
            let iy = (oy * stride + ky) as isize - pad as isize;
            let ix = (ox * stride + kx) as isize - pad as isize;
            let dst = &mut xd[rr * i_n..(rr + len) * i_n];
            if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                let pix = ((bi * h + iy as usize) * w + ix as usize) * cin + ci0;
                dst.copy_from_slice(&self.digits[pix * i_n..(pix + len) * i_n]);
            } else {
                for d in dst.chunks_exact_mut(i_n) {
                    d.copy_from_slice(self.pad);
                }
            }
            rr += len;
        }
    }
}

impl StoxMvm {
    /// Fused digit-domain convolution (SAME padding, (kh, kw, cin) feature
    /// order — the [`im2col`] contract): runs this crossbar over every
    /// output position of `acts`, gathering each patch's digit stripes
    /// straight from the pre-decomposed planes.  Bit-identical to
    /// `im2col` + [`StoxMvm::run`] without materializing the patch matrix
    /// or re-decomposing any pixel kh·kw times; requires the integer
    /// kernel (`self.m == kh·kw·acts_channels`, [`StoxConfig::int_kernel_ok`]).
    pub fn run_conv_digits<C: PsConvert + ?Sized>(
        &self,
        acts: &ActivationDigits<'_>,
        kh: usize,
        kw: usize,
        stride: usize,
        conv: &C,
        seed: u32,
    ) -> (Vec<f32>, usize, usize) {
        let (out, _, ho, wo) =
            self.run_conv_digits_impl(acts, kh, kw, stride, conv, seed, false);
        (out, ho, wo)
    }

    /// Fused digit-domain convolution **plus per-slice PS capture** — the
    /// training tape's fast-conv hook: bit-identical outputs *and* capture
    /// to `im2col` + [`StoxMvm::run_capture`] over `batch = patches`
    /// (pinned by `fused_conv_capture_matches_im2col_capture`), without
    /// materializing the patch matrix or re-decomposing any pixel
    /// kh·kw times.  The capture is the canonical `[p][k][i][j][col]`
    /// layout of [`StoxMvm::collect_ps`] with the patch index in the
    /// batch-row slot.
    pub fn run_conv_digits_capture<C: PsConvert + ?Sized>(
        &self,
        acts: &ActivationDigits<'_>,
        kh: usize,
        kw: usize,
        stride: usize,
        conv: &C,
        seed: u32,
    ) -> (Vec<f32>, Vec<f32>, usize, usize) {
        let (out, ps, ho, wo) =
            self.run_conv_digits_impl(acts, kh, kw, stride, conv, seed, true);
        (out, ps.expect("capture requested"), ho, wo)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_conv_digits_impl<C: PsConvert + ?Sized>(
        &self,
        acts: &ActivationDigits<'_>,
        kh: usize,
        kw: usize,
        stride: usize,
        conv: &C,
        seed: u32,
        want_capture: bool,
    ) -> (Vec<f32>, Option<Vec<f32>>, usize, usize) {
        assert_eq!(self.m, kh * kw * acts.c, "conv geometry mismatch");
        assert_eq!(acts.i_n, self.cfg.n_streams(), "activation digit width mismatch");
        let WeightPlanes::I8(planes) = &self.planes else {
            panic!("run_conv_digits requires the integer digit-plane kernel");
        };
        let pad = (kh - 1) / 2;
        let ho = (acts.h + 2 * pad - kh) / stride + 1;
        let wo = (acts.w + 2 * pad - kw) / stride + 1;
        let patches = acts.b * ho * wo;
        let group = self.cfg.n_streams() * self.cfg.n_slices() * self.n;

        let threads = crate::util::pool::default_threads();
        if threads > 1 && patches >= 2 * threads {
            let chunk = patches.div_ceil(threads);
            let n_chunks = patches.div_ceil(chunk);
            let parts = crate::util::pool::par_map_scratch(
                n_chunks,
                threads,
                || IntScratch::new(self),
                |scratch, ci| {
                    let p0 = ci * chunk;
                    let p1 = ((ci + 1) * chunk).min(patches);
                    // chunks cover disjoint contiguous patch ranges, so
                    // per-chunk capture buffers concatenate (in chunk
                    // order) into the canonical [p][k][i][j][col] layout
                    let mut ps = want_capture
                        .then(|| vec![0.0f32; (p1 - p0) * self.n_arrs * group]);
                    let out = self.conv_digits_range(
                        planes,
                        acts,
                        kw,
                        stride,
                        pad,
                        ho,
                        wo,
                        p0,
                        p1,
                        0,
                        conv,
                        seed,
                        scratch,
                        ps.as_deref_mut(),
                    );
                    (out, ps)
                },
            );
            let mut out = Vec::with_capacity(patches * self.n);
            let mut ps_all = want_capture
                .then(|| Vec::with_capacity(patches * self.n_arrs * group));
            for (o, ps) in parts {
                out.extend(o);
                if let (Some(all), Some(part)) = (ps_all.as_mut(), ps) {
                    all.extend(part);
                }
            }
            return (out, ps_all, ho, wo);
        }
        let mut scratch = IntScratch::new(self);
        let mut ps_all =
            want_capture.then(|| vec![0.0f32; patches * self.n_arrs * group]);
        let out = self.conv_digits_range(
            planes,
            acts,
            kw,
            stride,
            pad,
            ho,
            wo,
            0,
            patches,
            0,
            conv,
            seed,
            &mut scratch,
            ps_all.as_deref_mut(),
        );
        (out, ps_all, ho, wo)
    }

    /// Strictly sequential fused conv with an **absolute patch-counter
    /// offset** — the layer-pipelined forward's per-image kernel.  The RNG
    /// counter contract keys every draw by the absolute patch index (the
    /// batch-row slot of the frozen layout), so running image `i` alone
    /// with `patch_base = i · ho · wo` is bit-identical to its rows of the
    /// whole-batch [`StoxMvm::run_conv_digits`] — that is what lets
    /// `model/infer.rs` overlap layer k of image i with layer k−1 of image
    /// i+1 without perturbing a single bit.  Never spawns worker threads
    /// itself (the pipeline owns the parallelism).
    pub fn run_conv_digits_offset<C: PsConvert + ?Sized>(
        &self,
        acts: &ActivationDigits<'_>,
        kh: usize,
        kw: usize,
        stride: usize,
        conv: &C,
        seed: u32,
        patch_base: usize,
    ) -> (Vec<f32>, usize, usize) {
        assert_eq!(self.m, kh * kw * acts.c, "conv geometry mismatch");
        assert_eq!(acts.i_n, self.cfg.n_streams(), "activation digit width mismatch");
        let WeightPlanes::I8(planes) = &self.planes else {
            panic!("run_conv_digits_offset requires the integer digit-plane kernel");
        };
        let pad = (kh - 1) / 2;
        let ho = (acts.h + 2 * pad - kh) / stride + 1;
        let wo = (acts.w + 2 * pad - kw) / stride + 1;
        let patches = acts.b * ho * wo;
        let mut scratch = IntScratch::new(self);
        let out = self.conv_digits_range(
            planes, acts, kw, stride, pad, ho, wo, 0, patches, patch_base, conv, seed,
            &mut scratch, None,
        );
        (out, ho, wo)
    }

    /// Fused conv kernel over patch rows [p0, p1).  `capture`, when
    /// present, must hold `(p1 − p0) · K · I · J · N` f32 and receives
    /// every normalized per-slice PS of the range in the canonical
    /// `[p][k][i][j][col]` layout — the patch index plays the batch-row
    /// role, exactly as `im2col` + [`StoxMvm::run_capture`] over
    /// `batch = patches` lays it out (and keys its RNG counters).
    /// `counter_off` shifts only the RNG batch-row index (the pipelined
    /// per-image path passes the image's absolute first-patch index);
    /// geometry stays keyed by the local patch index.
    #[allow(clippy::too_many_arguments)]
    fn conv_digits_range<C: PsConvert + ?Sized>(
        &self,
        planes: &[i8],
        acts: &ActivationDigits<'_>,
        kw: usize,
        stride: usize,
        pad: usize,
        ho: usize,
        wo: usize,
        p0: usize,
        p1: usize,
        counter_off: usize,
        conv: &C,
        seed: u32,
        scratch: &mut IntScratch,
        mut capture: Option<&mut [f32]>,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; (p1 - p0) * self.n];
        if self.n == 0 || p1 == p0 {
            return out;
        }
        let cfg = &self.cfg;
        let rng = CounterRng::new(seed);
        let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
        let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);
        let norm = self.out_norm(conv.samples());
        let group = cfg.n_streams() * cfg.n_slices() * self.n;

        for p in p0..p1 {
            let bi = p / (ho * wo);
            let rem = p % (ho * wo);
            let oy = rem / wo;
            let ox = rem % wo;
            for k in 0..self.n_arrs {
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                acts.gather_stripe(kw, stride, pad, bi, oy, ox, row0, rows, &mut scratch.xd);
                let cap = capture.as_deref_mut().map(|buf| {
                    let g0 = ((p - p0) * self.n_arrs + k) * group;
                    &mut buf[g0..g0 + group]
                });
                self.run_stripe_int(
                    planes, rows, counter_off + p, k, conv, &rng, &sa, &sw, norm, scratch, cap,
                );
                let orow = &mut out[(p - p0) * self.n..(p - p0 + 1) * self.n];
                for terms in scratch.contrib.chunks_exact(self.n) {
                    for (o, &v) in orow.iter_mut().zip(terms) {
                        *o += v;
                    }
                }
            }
        }
        out
    }
}

/// One-shot Algorithm 1 (program + run); mirrors `ref.stox_mvm`.
pub fn stox_mvm<C: PsConvert + ?Sized>(
    a: &[f32],
    w: &[f32],
    batch: usize,
    m: usize,
    n: usize,
    cfg: StoxConfig,
    conv: &C,
    seed: u32,
) -> crate::Result<Vec<f32>> {
    Ok(StoxMvm::program(w, m, n, cfg)?.run(a, batch, conv, seed))
}

/// im2col patch extraction, NHWC, SAME-style padding, (kh, kw, cin) feature
/// order — identical to `stox_layers._im2col` so rows map to crossbars the
/// same way on both sides.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
) -> (Vec<f32>, usize, usize) {
    let pad = (kh - 1) / 2;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w_ + 2 * pad - kw) / stride + 1;
    let m = kh * kw * c;
    let mut out = vec![0.0f32; b * ho * wo * m];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let dst0 = ((bi * ho + oy) * wo + ox) * m;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w_ as isize {
                            continue;
                        }
                        let src0 = ((bi * h + iy as usize) * w_ + ix as usize) * c;
                        let dst = dst0 + (ky * kw + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src0..src0 + c]);
                    }
                }
            }
        }
    }
    (out, ho, wo)
}

/// Crossbar-mapped convolution: im2col + Algorithm 1 (`stox_conv2d` in
/// python).  `w` is [kh,kw,cin,cout] row-major and must already be
/// normalized into [-1,1].
#[allow(clippy::too_many_arguments)]
pub fn stox_conv2d<C: PsConvert + ?Sized>(
    x: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    cin: usize,
    weights: &[f32],
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    cfg: StoxConfig,
    conv: &C,
    seed: u32,
) -> crate::Result<(Vec<f32>, usize, usize)> {
    let (patches, ho, wo) = im2col(x, b, h, w_, cin, kh, kw, stride);
    let m = kh * kw * cin;
    let mvm = StoxMvm::program(weights, m, cout, cfg)?;
    let out = mvm.run(&patches, b * ho * wo, conv, seed);
    Ok((out, ho, wo))
}

#[cfg(test)]
mod tests {
    use super::super::converters::PsConverter;
    use super::*;

    fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
        let rng = CounterRng::new(seed);
        (0..n).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect()
    }

    fn cfg_small() -> StoxConfig {
        StoxConfig { r_arr: 64, w_slice_bits: 1, ..Default::default() }
    }

    #[test]
    fn ideal_matches_quantized_matmul() {
        let (b, m, n) = (3, 100, 7);
        let a = rand_vec(b * m, 1);
        let w = rand_vec(m * n, 2);
        let cfg = StoxConfig { a_bits: 8, w_bits: 8, r_arr: 64, w_slice_bits: 1, ..Default::default() };
        let got = stox_mvm(&a, &w, b, m, n, cfg, &PsConverter::IdealAdc, 0).unwrap();
        // reference: plain f64 matmul / (n_arrs * r_arr)
        let k = cfg.n_arrs(m);
        for bi in 0..b {
            for c in 0..n {
                let mut acc = 0.0f64;
                for r in 0..m {
                    acc += a[bi * m + r] as f64 * w[r * n + c] as f64;
                }
                let want = acc / (k * cfg.r_arr) as f64;
                let g = got[bi * n + c] as f64;
                assert!((g - want).abs() < 2e-2, "b{bi} c{c}: {g} vs {want}");
            }
        }
    }

    #[test]
    fn output_bounded() {
        let (b, m, n) = (2, 300, 5);
        let a = rand_vec(b * m, 3);
        let w = rand_vec(m * n, 4);
        for conv in [
            PsConverter::IdealAdc,
            PsConverter::SenseAmp,
            PsConverter::ExpectedMtj { alpha: 4.0 },
            PsConverter::StochasticMtj { alpha: 4.0, n_samples: 3 },
            PsConverter::QuantAdc { bits: 4 },
        ] {
            let out =
                stox_mvm(&a, &w, b, m, n, cfg_small(), &conv, 5).unwrap();
            for &v in &out {
                assert!(v.abs() <= 1.0 + 1e-5, "{conv:?} -> {v}");
            }
        }
    }

    #[test]
    fn stochastic_deterministic_per_seed() {
        let (b, m, n) = (2, 90, 4);
        let a = rand_vec(b * m, 5);
        let w = rand_vec(m * n, 6);
        let c = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        let o1 = stox_mvm(&a, &w, b, m, n, cfg_small(), &c, 42).unwrap();
        let o2 = stox_mvm(&a, &w, b, m, n, cfg_small(), &c, 42).unwrap();
        let o3 = stox_mvm(&a, &w, b, m, n, cfg_small(), &c, 43).unwrap();
        assert_eq!(o1, o2);
        assert_ne!(o1, o3);
    }

    #[test]
    fn stochastic_converges_to_expected() {
        let (b, m, n) = (1, 64, 6);
        let a = rand_vec(b * m, 7);
        let w = rand_vec(m * n, 8);
        let cfg = StoxConfig { alpha: 2.0, ..cfg_small() };
        let exp = stox_mvm(&a, &w, b, m, n, cfg, &PsConverter::ExpectedMtj { alpha: 2.0 }, 0)
            .unwrap();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let mut acc = vec![0.0f32; n];
        let trials = 300;
        for s in 0..trials {
            let o = mvm.run(
                &a,
                b,
                &PsConverter::StochasticMtj { alpha: 2.0, n_samples: 4 },
                s,
            );
            for (ac, v) in acc.iter_mut().zip(o) {
                *ac += v / trials as f32;
            }
        }
        for (e, g) in exp.iter().zip(&acc) {
            assert!((e - g).abs() < 0.02, "{e} vs {g}");
        }
    }

    #[test]
    fn more_samples_reduce_variance() {
        let (b, m, n) = (1, 128, 8);
        let a = rand_vec(b * m, 9);
        let w = rand_vec(m * n, 10);
        let cfg = StoxConfig { alpha: 2.0, r_arr: 128, w_slice_bits: 1, ..Default::default() };
        let exp =
            stox_mvm(&a, &w, b, m, n, cfg, &PsConverter::ExpectedMtj { alpha: 2.0 }, 0)
                .unwrap();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let mse = |ns: u32| -> f32 {
            let o = mvm.run(
                &a,
                b,
                &PsConverter::StochasticMtj { alpha: 2.0, n_samples: ns },
                3,
            );
            o.iter().zip(&exp).map(|(g, e)| (g - e) * (g - e)).sum::<f32>()
                / n as f32
        };
        let (e1, e4, e16) = (mse(1), mse(4), mse(16));
        assert!(e1 > e4 && e4 > e16, "{e1} {e4} {e16}");
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1: patches == input
        let x: Vec<f32> = (0..2 * 3 * 3 * 2).map(|i| i as f32).collect();
        let (p, ho, wo) = im2col(&x, 2, 3, 3, 2, 1, 1, 1);
        assert_eq!((ho, wo), (3, 3));
        assert_eq!(p, x);
    }

    #[test]
    fn im2col_shapes_and_padding() {
        let x = vec![1.0f32; 1 * 4 * 4 * 3];
        let (p, ho, wo) = im2col(&x, 1, 4, 4, 3, 3, 3, 1);
        assert_eq!((ho, wo), (4, 4));
        assert_eq!(p.len(), 16 * 27);
        // corner patch: 4 of 9 taps in-bounds
        let corner = &p[0..27];
        let nonzero = corner.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nonzero, 4 * 3);
        // strided
        let (_, ho2, wo2) = im2col(&x, 1, 4, 4, 3, 3, 3, 2);
        assert_eq!((ho2, wo2), (2, 2));
    }

    #[test]
    fn conv_shapes() {
        let x = rand_vec(1 * 8 * 8 * 4, 11);
        let w = rand_vec(3 * 3 * 4 * 6, 12);
        let cfg = StoxConfig { r_arr: 36, ..Default::default() };
        let (out, ho, wo) = stox_conv2d(
            &x, 1, 8, 8, 4, &w, 3, 3, 6, 2, cfg, &PsConverter::IdealAdc, 0,
        )
        .unwrap();
        assert_eq!((ho, wo), (4, 4));
        assert_eq!(out.len(), 1 * 4 * 4 * 6);
    }

    #[test]
    fn programming_rejects_bad_shapes() {
        assert!(StoxMvm::program(&[0.0; 10], 3, 4, StoxConfig::default()).is_err());
    }

    #[test]
    fn paper_configs_select_the_integer_kernel() {
        let w = rand_vec(96 * 4, 13);
        let mvm = StoxMvm::program(&w, 96, 4, StoxConfig::default()).unwrap();
        assert!(mvm.is_integer_kernel());
        let r = StoxMvm::program_reference(&w, 96, 4, StoxConfig::default()).unwrap();
        assert!(!r.is_integer_kernel());
        // 8-bit stream digits overflow i8 — automatic reference fallback
        let wide = StoxConfig {
            a_bits: 8,
            w_bits: 8,
            a_stream_bits: 8,
            w_slice_bits: 1,
            ..Default::default()
        };
        let f = StoxMvm::program(&w, 96, 4, wide).unwrap();
        assert!(!f.is_integer_kernel());
    }

    /// Attached hardware counters are byte-reproducible across same-seed
    /// runs and satisfy the analytic identities the EDP cross-check
    /// relies on (`arch/energy.rs::EnergyModel::from_counters`).
    #[test]
    fn attached_counters_are_deterministic_and_analytic() {
        let (b, m, n) = (2usize, 96usize, 5usize);
        let a = rand_vec(b * m, 21);
        let w = rand_vec(m * n, 22);
        let cfg = StoxConfig::default();
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        let snap = |seed: u32| {
            let reg = CounterRegistry::new();
            let mut mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
            mvm.attach_counters(&reg, "imc.l00.4w4a4bs.");
            let _ = mvm.run_sequential(&a, b, &conv, seed);
            reg.to_json().to_string()
        };
        assert_eq!(snap(7), snap(7), "same-seed snapshots are byte-identical");
        // the tallies count events, not outcomes: they are invariant in
        // the RNG seed too (only the drawn values differ across seeds)
        assert_eq!(snap(7), snap(8), "event counts are seed-invariant");

        let reg = CounterRegistry::new();
        let mut mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        mvm.attach_counters(&reg, "");
        let _ = mvm.run_sequential(&a, b, &conv, 7);
        let (bu, mu, nu) = (b as u64, m as u64, n as u64);
        let ku = cfg.n_arrs(m) as u64;
        let (iu, ju) = (cfg.n_streams() as u64, cfg.n_slices() as u64);
        assert_eq!(reg.get("conversions"), bu * ku * iu * ju * nu);
        assert_eq!(reg.get("dac_actions"), bu * iu * mu);
        assert_eq!(reg.get("cell_actions"), bu * iu * mu * 2 * ju);
        assert_eq!(reg.get("out_io"), bu * iu * nu);
        assert_eq!(reg.get("convert_batch_calls"), bu * ku);
        assert_eq!(reg.get("convert_batch_groups"), bu * ku * iu * ju);
        assert_eq!(reg.get("mtj_draws"), reg.get("conversions") * 2);
        assert_eq!(
            reg.get("memo_hits") + reg.get("memo_misses"),
            reg.get("conversions"),
            "one memo lookup per converted element"
        );
        assert_eq!(
            reg.get("macs") + reg.get("zero_digit_skips") * nu,
            bu * iu * mu * ju * nu,
            "executed MACs + skipped rows × columns cover the dense count"
        );
        if mvm.i16_tier() {
            assert_eq!(reg.get("i16_rows"), bu * mu);
        } else {
            assert_eq!(reg.get("i16_rows"), 0);
        }
        // detaching stops the tallies
        mvm.detach_counters();
        let before = reg.get("macs");
        let _ = mvm.run_sequential(&a, b, &conv, 7);
        assert_eq!(reg.get("macs"), before);
    }

    /// The tentpole contract: integer digit-plane kernel == retained f32
    /// reference kernel, bit for bit, stochastic converter included.
    #[test]
    fn integer_kernel_matches_f32_reference() {
        let (b, m, n) = (3usize, 150usize, 9usize);
        let a = rand_vec(b * m, 14);
        let w = rand_vec(m * n, 15);
        for cfg in [
            StoxConfig::default(),
            cfg_small(),
            StoxConfig { a_bits: 8, w_bits: 8, w_slice_bits: 2, a_stream_bits: 2, r_arr: 48, ..Default::default() },
        ] {
            let int = StoxMvm::program(&w, m, n, cfg).unwrap();
            let refk = StoxMvm::program_reference(&w, m, n, cfg).unwrap();
            assert!(int.is_integer_kernel());
            for conv in [
                PsConverter::IdealAdc,
                PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 },
                PsConverter::ExpectedMtj { alpha: 4.0 },
            ] {
                let o1 = int.run_sequential(&a, b, &conv, 7);
                let o2 = refk.run_sequential(&a, b, &conv, 7);
                assert_eq!(o1, o2, "{conv:?} {}", cfg.tag());
            }
            // the Fig. 4 probe too
            assert_eq!(int.collect_ps(&a, b), refk.collect_ps(&a, b), "{}", cfg.tag());
        }
    }

    #[test]
    fn parallel_batch_matches_sequential() {
        // the fan-out path must be bit-identical to run_range(0, batch)
        let (m, n) = (96usize, 10usize);
        let batch = 64usize; // large enough to trigger the parallel path
        let a = rand_vec(batch * m, 21);
        let w = rand_vec(m * n, 22);
        let cfg = cfg_small();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 3 };
        let par = mvm.run(&a, batch, &conv, 5);
        let seq = mvm.run_range(&a, 0, batch, &conv, 5);
        assert_eq!(par, seq);
    }

    #[test]
    fn ksplit_matches_sequential() {
        // single-image shape: batch below 2·threads, multiple subarrays
        let (m, n) = (300usize, 12usize);
        let a = rand_vec(2 * m, 23);
        let w = rand_vec(m * n, 24);
        let cfg = StoxConfig { r_arr: 64, w_slice_bits: 1, ..Default::default() };
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        for conv in [
            PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 },
            PsConverter::IdealAdc,
        ] {
            for batch in [1usize, 2] {
                let seq = mvm.run_sequential(&a, batch, &conv, 9);
                for threads in [2usize, 3, 8] {
                    let par = mvm.run_ksplit(&a, batch, &conv, 9, threads);
                    assert_eq!(par, seq, "{conv:?} batch {batch} threads {threads}");
                }
            }
        }
    }

    /// The training capture hook is the sequential forward plus the
    /// Fig. 4 probe, bit for bit — for the integer kernel, the reference
    /// fallback, and significance-aware converters.
    #[test]
    fn run_capture_matches_forward_and_probe() {
        use super::super::convert::{InhomogeneousMtjConv, PsConverterSpec};
        let (b, m, n) = (2usize, 150usize, 7usize);
        let a = rand_vec(b * m, 31);
        let w = rand_vec(m * n, 32);
        let cfg = StoxConfig { r_arr: 64, w_slice_bits: 2, ..Default::default() };
        let inhomo = InhomogeneousMtjConv::new(4.0, 1, 3, &cfg);
        let stox: PsConverterSpec = "stox:alpha=4,samples=2".parse().unwrap();
        let stox = stox.build(&cfg).unwrap();
        for (label, mvm) in [
            ("integer", StoxMvm::program(&w, m, n, cfg).unwrap()),
            ("reference", StoxMvm::program_reference(&w, m, n, cfg).unwrap()),
        ] {
            for (cname, conv) in
                [("stox", stox.as_ref()), ("inhomo", &inhomo as &dyn PsConvert)]
            {
                let (out, ps) = mvm.run_capture(&a, b, conv, 13);
                assert_eq!(
                    out,
                    mvm.run_sequential(&a, b, conv, 13),
                    "{label}/{cname}: forward must be unchanged"
                );
                assert_eq!(
                    ps,
                    mvm.collect_ps(&a, b),
                    "{label}/{cname}: capture must equal the probe"
                );
            }
        }
    }

    /// Fused digit-domain conv == im2col + run, bit for bit — including
    /// padding taps, strides and subarray splits that land mid-tap.
    #[test]
    fn fused_conv_matches_im2col_path() {
        let (b, h, w, cin, cout) = (2usize, 6usize, 5usize, 3usize, 7usize);
        let x = rand_vec(b * h * w * cin, 25);
        let wts = rand_vec(3 * 3 * cin * cout, 26);
        for (r_arr, stride) in [(16usize, 1usize), (8, 2), (64, 1)] {
            let cfg = StoxConfig { r_arr, w_slice_bits: 1, ..Default::default() };
            let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
            let (want, ho, wo) =
                stox_conv2d(&x, b, h, w, cin, &wts, 3, 3, cout, stride, cfg, &conv, 31)
                    .unwrap();
            let mvm = StoxMvm::program(&wts, 3 * 3 * cin, cout, cfg).unwrap();
            let mut arena = ConvArena::new();
            let acts = decompose_activations(&mut arena, &x, b, h, w, cin, &cfg);
            let (got, ho2, wo2) = mvm.run_conv_digits(&acts, 3, 3, stride, &conv, 31);
            assert_eq!((ho, wo), (ho2, wo2));
            assert_eq!(got, want, "r_arr {r_arr} stride {stride}");
        }
    }

    /// Every available MAC backend must reproduce the scalar reference
    /// bit for bit, at the full kernel level (accumulation + conversion +
    /// fold), stochastic converter included.
    #[test]
    fn forced_mac_backends_are_bit_identical() {
        let (b, m, n) = (2usize, 150usize, 33usize); // n hits SIMD blocks + tail
        let a = rand_vec(b * m, 41);
        let w = rand_vec(m * n, 42);
        for cfg in [StoxConfig::default(), cfg_small()] {
            let mut mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
            assert!(mvm.is_integer_kernel());
            mvm.set_mac_backend(MacBackend::Scalar).unwrap();
            let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
            let want = mvm.run_sequential(&a, b, &conv, 17);
            let want_ps = mvm.collect_ps(&a, b);
            for backend in [
                MacBackend::Avx2,
                MacBackend::Neon,
                MacBackend::Portable,
            ] {
                if !backend.available() {
                    assert!(mvm.set_mac_backend(backend).is_err());
                    continue;
                }
                mvm.set_mac_backend(backend).unwrap();
                assert_eq!(mvm.mac_backend(), backend);
                assert_eq!(
                    mvm.run_sequential(&a, b, &conv, 17),
                    want,
                    "{} vs scalar ({})",
                    backend.label(),
                    cfg.tag()
                );
                assert_eq!(mvm.collect_ps(&a, b), want_ps, "{} probe", backend.label());
            }
        }
    }

    /// The i16 accumulation tier must be bit-identical to i32 whenever the
    /// gate admits it, and refuse configs whose PS bound doesn't fit.
    #[test]
    fn i16_tier_matches_i32_and_gates() {
        let (b, m, n) = (2usize, 150usize, 19usize);
        let a = rand_vec(b * m, 43);
        let w = rand_vec(m * n, 44);
        let cfg = StoxConfig::default(); // 4w4a4bs: bound 3840 ≤ i16::MAX
        assert!(cfg.int16_kernel_ok());
        let mut mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        assert!(mvm.i16_tier(), "qualifying config selects the i16 tier");
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        let o16 = mvm.run_sequential(&a, b, &conv, 19);
        let ps16 = mvm.collect_ps(&a, b);
        mvm.set_i16_tier(false).unwrap();
        assert_eq!(mvm.run_sequential(&a, b, &conv, 19), o16, "i16 == i32");
        assert_eq!(mvm.collect_ps(&a, b), ps16, "i16 probe == i32 probe");
        mvm.set_i16_tier(true).unwrap();
        // a bound past i16::MAX must refuse the tier (and never self-select)
        let wide = StoxConfig { a_stream_bits: 4, ..cfg };
        assert!(wide.int_kernel_ok() && !wide.int16_kernel_ok());
        let mut big = StoxMvm::program(&w, m, n, wide).unwrap();
        assert!(!big.i16_tier());
        assert!(big.set_i16_tier(true).is_err());
    }

    /// Per-image fused conv with absolute patch offsets — the pipelined
    /// forward's kernel — concatenates to exactly the whole-batch fused
    /// conv, bit for bit (the RNG counter contract is keyed by absolute
    /// patch index, not by call granularity).
    #[test]
    fn offset_conv_per_image_matches_whole_batch() {
        let (b, h, w, cin, cout) = (3usize, 6usize, 5usize, 3usize, 7usize);
        let x = rand_vec(b * h * w * cin, 45);
        let wts = rand_vec(3 * 3 * cin * cout, 46);
        for (r_arr, stride) in [(16usize, 1usize), (8, 2)] {
            let cfg = StoxConfig { r_arr, w_slice_bits: 1, ..Default::default() };
            let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
            let mvm = StoxMvm::program(&wts, 3 * 3 * cin, cout, cfg).unwrap();
            let mut arena = ConvArena::new();
            let acts = decompose_activations(&mut arena, &x, b, h, w, cin, &cfg);
            let (want, ho, wo) = mvm.run_conv_digits(&acts, 3, 3, stride, &conv, 51);
            let mut got = Vec::with_capacity(want.len());
            let mut img_arena = ConvArena::new();
            for bi in 0..b {
                let xi = &x[bi * h * w * cin..(bi + 1) * h * w * cin];
                let ai = decompose_activations(&mut img_arena, xi, 1, h, w, cin, &cfg);
                let (oi, ho2, wo2) = mvm.run_conv_digits_offset(
                    &ai, 3, 3, stride, &conv, 51, bi * ho * wo,
                );
                assert_eq!((ho, wo), (ho2, wo2));
                got.extend(oi);
            }
            assert_eq!(got, want, "r_arr {r_arr} stride {stride}");
        }
    }

    /// The fused-conv capture (ISSUE 6 carried follow-up) == im2col +
    /// `run_capture` over `batch = patches`, bit for bit on both outputs
    /// and captured PS — across subarray splits, strides, and batch sizes
    /// large enough to exercise the parallel chunked path.
    #[test]
    fn fused_conv_capture_matches_im2col_capture() {
        let (h, w, cin, cout) = (6usize, 5usize, 3usize, 7usize);
        let wts = rand_vec(3 * 3 * cin * cout, 36);
        for (b, r_arr, stride) in [(1usize, 16usize, 1usize), (2, 8, 2), (4, 64, 1)] {
            let x = rand_vec(b * h * w * cin, 35);
            let cfg = StoxConfig { r_arr, w_slice_bits: 1, ..Default::default() };
            let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
            let mvm = StoxMvm::program(&wts, 3 * 3 * cin, cout, cfg).unwrap();

            let (patches, ho, wo) = im2col(&x, b, h, w, cin, 3, 3, stride);
            let (want_out, want_ps) =
                mvm.run_capture(&patches, b * ho * wo, &conv, 41);

            let mut arena = ConvArena::new();
            let acts = decompose_activations(&mut arena, &x, b, h, w, cin, &cfg);
            let (out, ps, ho2, wo2) =
                mvm.run_conv_digits_capture(&acts, 3, 3, stride, &conv, 41);
            assert_eq!((ho, wo), (ho2, wo2));
            assert_eq!(out, want_out, "b {b} r_arr {r_arr} stride {stride}: out");
            assert_eq!(ps, want_ps, "b {b} r_arr {r_arr} stride {stride}: ps");
            // the plain fused path is untouched by the capture plumbing
            let (plain, _, _) = mvm.run_conv_digits(&acts, 3, 3, stride, &conv, 41);
            assert_eq!(plain, out, "capture must not perturb the forward");
        }
    }
}
