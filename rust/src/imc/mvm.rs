//! Algorithm 1 end-to-end: the StoX crossbar MVM, bit-identical with the
//! python oracle (`ref.stox_mvm`) when driven by the stochastic MTJ
//! converter.
//!
//! [`StoxMvm`] is the production shape: weights are quantized, sliced and
//! partitioned into subarrays **once** (crossbar programming), then many
//! activations run through [`StoxMvm::run`].  `stox_mvm` is the one-shot
//! convenience used by tests.
//!
//! The kernel is generic over [`PsConvert`]: conversion happens one PS
//! *column slice* at a time (`convert_slice_at`), so converter dispatch is
//! hoisted out of the inner loop and implementations vectorize freely.

use super::convert::PsConvert;
use super::quant::{self, StoxConfig};
use crate::stats::rng::CounterRng;

/// A crossbar-programmed weight matrix ready for repeated MVMs.
pub struct StoxMvm {
    pub cfg: StoxConfig,
    pub m: usize,
    pub n: usize,
    n_arrs: usize,
    /// weight slice digits: `[k][j]` → row-major `[r_arr × n]` f32
    /// (digits are small odd integers, exact in f32).
    wd: Vec<Vec<Vec<f32>>>,
}

impl StoxMvm {
    /// Program the crossbar: quantize + slice + partition `w` ([M×N],
    /// values in [-1,1], row-major).
    pub fn program(w: &[f32], m: usize, n: usize, cfg: StoxConfig) -> crate::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(w.len() == m * n, "weight shape mismatch");
        let n_arrs = cfg.n_arrs(m);
        let n_slices = cfg.n_slices();
        let mut digits = vec![0i32; n_slices];

        let mut wd =
            vec![vec![vec![0.0f32; cfg.r_arr * n]; n_slices]; n_arrs];
        for r in 0..m {
            let k = r / cfg.r_arr;
            let rr = r % cfg.r_arr;
            for c in 0..n {
                let u = quant::quantize_unit(w[r * n + c], cfg.w_bits);
                quant::signed_digits(u, cfg.w_bits, cfg.w_slice_bits, &mut digits);
                for (j, &d) in digits.iter().enumerate() {
                    wd[k][j][rr * n + c] = d as f32;
                }
            }
        }
        // rows beyond m stay 0 (absent cells contribute no current)
        Ok(Self { cfg, m, n, n_arrs, wd })
    }

    pub fn n_arrs(&self) -> usize {
        self.n_arrs
    }

    /// Weight digits of subarray `k`, slice `j` (row-major [r_arr × n]) —
    /// exposed for the non-ideality wrapper.
    pub(crate) fn slice(&self, k: usize, j: usize) -> &[f32] {
        &self.wd[k][j]
    }

    /// Run a batch of activations (`a`: [B×M] row-major, values in [-1,1])
    /// through the crossbar with the given PS converter; returns [B×N].
    ///
    /// Hot-path structure (EXPERIMENTS.md §Perf): each weight slice is
    /// streamed over its rows **once**, accumulating the partial sums of
    /// all `I` input streams simultaneously — `I×` less weight traffic
    /// than the naive per-(stream, slice) loop, and the inner kernel is a
    /// branch-free `ps[i][c] += x_i · w[c]` that vectorizes.
    pub fn run<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        batch: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        // Batch rows are independent (the RNG counter space is keyed by
        // b), so large batches fan out across cores; per-element results
        // are bit-identical to the sequential path.
        let threads = crate::util::pool::default_threads();
        if batch >= 2 * threads && threads > 1 {
            let chunk = batch.div_ceil(threads);
            let n_chunks = batch.div_ceil(chunk);
            let parts = crate::util::pool::par_map(n_chunks, threads, |ci| {
                let b0 = ci * chunk;
                let b1 = ((ci + 1) * chunk).min(batch);
                self.run_range(a, b0, b1, conv, seed)
            });
            let mut out = Vec::with_capacity(batch * self.n);
            for p in parts {
                out.extend(p);
            }
            return out;
        }
        self.run_range(a, 0, batch, conv, seed)
    }

    /// Sequential kernel over batch rows [b0, b1).
    fn run_range<C: PsConvert + ?Sized>(
        &self,
        a: &[f32],
        b0: usize,
        b1: usize,
        conv: &C,
        seed: u32,
    ) -> Vec<f32> {
        let batch = b1 - b0;
        debug_assert!(a.len() >= b1 * self.m);
        let cfg = &self.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let samples = conv.samples() as f32;
        let rng = CounterRng::new(seed);
        let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
        let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);
        let lev = (((1u64 << cfg.a_bits) - 1) * ((1u64 << cfg.w_bits) - 1)) as f32;
        let norm = 1.0 / (lev * self.n_arrs as f32 * samples);
        let inv_r = 1.0 / cfg.r_arr as f32;

        let mut out = vec![0.0f32; batch * self.n];
        // activation digits of one (b, k) stripe, row-major [r][i] so the
        // inner loop reads them contiguously
        let mut xd = vec![0.0f32; cfg.r_arr * i_n];
        let mut digits = vec![0i32; i_n];
        // per-stream PS accumulators [i][n] (I·N f32 — L1-resident)
        let mut ps = vec![0.0f32; i_n * self.n];
        // per-slice scratch: normalized PS in, converted values out
        let mut psn = vec![0.0f32; self.n];
        let mut cv = vec![0.0f32; self.n];

        for b in b0..b1 {
            for k in 0..self.n_arrs {
                // decompose this subarray's activation stripe
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                for rr in 0..rows {
                    let u = quant::quantize_unit(a[b * self.m + row0 + rr], cfg.a_bits);
                    quant::signed_digits(u, cfg.a_bits, cfg.a_stream_bits, &mut digits);
                    for (i, &d) in digits.iter().enumerate() {
                        xd[rr * i_n + i] = d as f32;
                    }
                }
                for j in 0..j_n {
                    ps.iter_mut().for_each(|v| *v = 0.0);
                    let w_sl = &self.wd[k][j];
                    // one pass over the slice rows feeds every stream
                    for rr in 0..rows {
                        let wrow = &w_sl[rr * self.n..(rr + 1) * self.n];
                        let xr = &xd[rr * i_n..rr * i_n + i_n];
                        for (i, &x) in xr.iter().enumerate() {
                            let acc = &mut ps[i * self.n..(i + 1) * self.n];
                            for (p, &wv) in acc.iter_mut().zip(wrow) {
                                *p += x * wv;
                            }
                        }
                    }
                    for i in 0..i_n {
                        let scale = sa[i] * sw[j] * norm;
                        let ps_i = &ps[i * self.n..(i + 1) * self.n];
                        for (pn, &p) in psn.iter_mut().zip(ps_i) {
                            *pn = p * inv_r;
                        }
                        // canonical counter layout shared with python
                        // (frozen contract): base(c) = (((b·K + k)·N + c)·I
                        // + i)·J + j, so the whole column slice is
                        // (base(0), stride I·J) — wrapping arithmetic is
                        // congruent mod 2³² wherever the truncation lands.
                        let base0 = ((((b * self.n_arrs + k) * self.n) * i_n
                            + i) as u32)
                            .wrapping_mul(j_n as u32)
                            .wrapping_add(j as u32);
                        let stride = (i_n * j_n) as u32;
                        conv.convert_slice_at(i, j, &psn, &mut cv, base0, stride, &rng);
                        let orow =
                            &mut out[(b - b0) * self.n..(b - b0 + 1) * self.n];
                        for (o, &v) in orow.iter_mut().zip(cv.iter()) {
                            *o += v * scale;
                        }
                    }
                }
            }
        }
        out
    }
}

impl StoxMvm {
    /// Enumerate all normalized array-level partial sums for a batch
    /// (the Fig. 4 distribution probe).  Order: [b][k][i][j][col].
    pub fn collect_ps(&self, a: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(a.len(), batch * self.m);
        let cfg = &self.cfg;
        let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
        let inv_r = 1.0 / cfg.r_arr as f32;
        let mut out =
            Vec::with_capacity(batch * self.n_arrs * i_n * j_n * self.n);
        let mut xd = vec![vec![0.0f32; cfg.r_arr]; i_n];
        let mut digits = vec![0i32; i_n];
        let mut ps_row = vec![0.0f32; self.n];
        for b in 0..batch {
            for k in 0..self.n_arrs {
                let row0 = k * cfg.r_arr;
                let rows = (self.m - row0).min(cfg.r_arr);
                for i in 0..i_n {
                    xd[i][rows..].iter_mut().for_each(|v| *v = 0.0);
                }
                for rr in 0..rows {
                    let u = quant::quantize_unit(a[b * self.m + row0 + rr], cfg.a_bits);
                    quant::signed_digits(u, cfg.a_bits, cfg.a_stream_bits, &mut digits);
                    for (i, &d) in digits.iter().enumerate() {
                        xd[i][rr] = d as f32;
                    }
                }
                for i in 0..i_n {
                    for j in 0..j_n {
                        ps_row.iter_mut().for_each(|v| *v = 0.0);
                        let w_sl = &self.wd[k][j];
                        for rr in 0..rows {
                            let x = xd[i][rr];
                            if x == 0.0 {
                                continue;
                            }
                            let wrow = &w_sl[rr * self.n..(rr + 1) * self.n];
                            for (p, &wv) in ps_row.iter_mut().zip(wrow) {
                                *p += x * wv;
                            }
                        }
                        out.extend(ps_row.iter().map(|p| p * inv_r));
                    }
                }
            }
        }
        out
    }
}

/// One-shot Algorithm 1 (program + run); mirrors `ref.stox_mvm`.
pub fn stox_mvm<C: PsConvert + ?Sized>(
    a: &[f32],
    w: &[f32],
    batch: usize,
    m: usize,
    n: usize,
    cfg: StoxConfig,
    conv: &C,
    seed: u32,
) -> crate::Result<Vec<f32>> {
    Ok(StoxMvm::program(w, m, n, cfg)?.run(a, batch, conv, seed))
}

/// im2col patch extraction, NHWC, SAME-style padding, (kh, kw, cin) feature
/// order — identical to `stox_layers._im2col` so rows map to crossbars the
/// same way on both sides.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
) -> (Vec<f32>, usize, usize) {
    let pad = (kh - 1) / 2;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w_ + 2 * pad - kw) / stride + 1;
    let m = kh * kw * c;
    let mut out = vec![0.0f32; b * ho * wo * m];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let dst0 = ((bi * ho + oy) * wo + ox) * m;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w_ as isize {
                            continue;
                        }
                        let src0 = ((bi * h + iy as usize) * w_ + ix as usize) * c;
                        let dst = dst0 + (ky * kw + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src0..src0 + c]);
                    }
                }
            }
        }
    }
    (out, ho, wo)
}

/// Crossbar-mapped convolution: im2col + Algorithm 1 (`stox_conv2d` in
/// python).  `w` is [kh,kw,cin,cout] row-major and must already be
/// normalized into [-1,1].
#[allow(clippy::too_many_arguments)]
pub fn stox_conv2d<C: PsConvert + ?Sized>(
    x: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    cin: usize,
    weights: &[f32],
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    cfg: StoxConfig,
    conv: &C,
    seed: u32,
) -> crate::Result<(Vec<f32>, usize, usize)> {
    let (patches, ho, wo) = im2col(x, b, h, w_, cin, kh, kw, stride);
    let m = kh * kw * cin;
    let mvm = StoxMvm::program(weights, m, cout, cfg)?;
    let out = mvm.run(&patches, b * ho * wo, conv, seed);
    Ok((out, ho, wo))
}

#[cfg(test)]
mod tests {
    use super::super::converters::PsConverter;
    use super::*;

    fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
        let rng = CounterRng::new(seed);
        (0..n).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect()
    }

    fn cfg_small() -> StoxConfig {
        StoxConfig { r_arr: 64, w_slice_bits: 1, ..Default::default() }
    }

    #[test]
    fn ideal_matches_quantized_matmul() {
        let (b, m, n) = (3, 100, 7);
        let a = rand_vec(b * m, 1);
        let w = rand_vec(m * n, 2);
        let cfg = StoxConfig { a_bits: 8, w_bits: 8, r_arr: 64, w_slice_bits: 1, ..Default::default() };
        let got = stox_mvm(&a, &w, b, m, n, cfg, &PsConverter::IdealAdc, 0).unwrap();
        // reference: plain f64 matmul / (n_arrs * r_arr)
        let k = cfg.n_arrs(m);
        for bi in 0..b {
            for c in 0..n {
                let mut acc = 0.0f64;
                for r in 0..m {
                    acc += a[bi * m + r] as f64 * w[r * n + c] as f64;
                }
                let want = acc / (k * cfg.r_arr) as f64;
                let g = got[bi * n + c] as f64;
                assert!((g - want).abs() < 2e-2, "b{bi} c{c}: {g} vs {want}");
            }
        }
    }

    #[test]
    fn output_bounded() {
        let (b, m, n) = (2, 300, 5);
        let a = rand_vec(b * m, 3);
        let w = rand_vec(m * n, 4);
        for conv in [
            PsConverter::IdealAdc,
            PsConverter::SenseAmp,
            PsConverter::ExpectedMtj { alpha: 4.0 },
            PsConverter::StochasticMtj { alpha: 4.0, n_samples: 3 },
            PsConverter::QuantAdc { bits: 4 },
        ] {
            let out =
                stox_mvm(&a, &w, b, m, n, cfg_small(), &conv, 5).unwrap();
            for &v in &out {
                assert!(v.abs() <= 1.0 + 1e-5, "{conv:?} -> {v}");
            }
        }
    }

    #[test]
    fn stochastic_deterministic_per_seed() {
        let (b, m, n) = (2, 90, 4);
        let a = rand_vec(b * m, 5);
        let w = rand_vec(m * n, 6);
        let c = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 2 };
        let o1 = stox_mvm(&a, &w, b, m, n, cfg_small(), &c, 42).unwrap();
        let o2 = stox_mvm(&a, &w, b, m, n, cfg_small(), &c, 42).unwrap();
        let o3 = stox_mvm(&a, &w, b, m, n, cfg_small(), &c, 43).unwrap();
        assert_eq!(o1, o2);
        assert_ne!(o1, o3);
    }

    #[test]
    fn stochastic_converges_to_expected() {
        let (b, m, n) = (1, 64, 6);
        let a = rand_vec(b * m, 7);
        let w = rand_vec(m * n, 8);
        let cfg = StoxConfig { alpha: 2.0, ..cfg_small() };
        let exp = stox_mvm(&a, &w, b, m, n, cfg, &PsConverter::ExpectedMtj { alpha: 2.0 }, 0)
            .unwrap();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let mut acc = vec![0.0f32; n];
        let trials = 300;
        for s in 0..trials {
            let o = mvm.run(
                &a,
                b,
                &PsConverter::StochasticMtj { alpha: 2.0, n_samples: 4 },
                s,
            );
            for (ac, v) in acc.iter_mut().zip(o) {
                *ac += v / trials as f32;
            }
        }
        for (e, g) in exp.iter().zip(&acc) {
            assert!((e - g).abs() < 0.02, "{e} vs {g}");
        }
    }

    #[test]
    fn more_samples_reduce_variance() {
        let (b, m, n) = (1, 128, 8);
        let a = rand_vec(b * m, 9);
        let w = rand_vec(m * n, 10);
        let cfg = StoxConfig { alpha: 2.0, r_arr: 128, w_slice_bits: 1, ..Default::default() };
        let exp =
            stox_mvm(&a, &w, b, m, n, cfg, &PsConverter::ExpectedMtj { alpha: 2.0 }, 0)
                .unwrap();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let mse = |ns: u32| -> f32 {
            let o = mvm.run(
                &a,
                b,
                &PsConverter::StochasticMtj { alpha: 2.0, n_samples: ns },
                3,
            );
            o.iter().zip(&exp).map(|(g, e)| (g - e) * (g - e)).sum::<f32>()
                / n as f32
        };
        let (e1, e4, e16) = (mse(1), mse(4), mse(16));
        assert!(e1 > e4 && e4 > e16, "{e1} {e4} {e16}");
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1: patches == input
        let x: Vec<f32> = (0..2 * 3 * 3 * 2).map(|i| i as f32).collect();
        let (p, ho, wo) = im2col(&x, 2, 3, 3, 2, 1, 1, 1);
        assert_eq!((ho, wo), (3, 3));
        assert_eq!(p, x);
    }

    #[test]
    fn im2col_shapes_and_padding() {
        let x = vec![1.0f32; 1 * 4 * 4 * 3];
        let (p, ho, wo) = im2col(&x, 1, 4, 4, 3, 3, 3, 1);
        assert_eq!((ho, wo), (4, 4));
        assert_eq!(p.len(), 16 * 27);
        // corner patch: 4 of 9 taps in-bounds
        let corner = &p[0..27];
        let nonzero = corner.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nonzero, 4 * 3);
        // strided
        let (_, ho2, wo2) = im2col(&x, 1, 4, 4, 3, 3, 3, 2);
        assert_eq!((ho2, wo2), (2, 2));
    }

    #[test]
    fn conv_shapes() {
        let x = rand_vec(1 * 8 * 8 * 4, 11);
        let w = rand_vec(3 * 3 * 4 * 6, 12);
        let cfg = StoxConfig { r_arr: 36, ..Default::default() };
        let (out, ho, wo) = stox_conv2d(
            &x, 1, 8, 8, 4, &w, 3, 3, 6, 2, cfg, &PsConverter::IdealAdc, 0,
        )
        .unwrap();
        assert_eq!((ho, wo), (4, 4));
        assert_eq!(out.len(), 1 * 4 * 4 * 6);
    }

    #[test]
    fn programming_rejects_bad_shapes() {
        assert!(StoxMvm::program(&[0.0; 10], 3, 4, StoxConfig::default()).is_err());
    }

    #[test]
    fn parallel_batch_matches_sequential() {
        // the fan-out path must be bit-identical to run_range(0, batch)
        let (m, n) = (96usize, 10usize);
        let batch = 64usize; // large enough to trigger the parallel path
        let a = rand_vec(batch * m, 21);
        let w = rand_vec(m * n, 22);
        let cfg = cfg_small();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let conv = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 3 };
        let par = mvm.run(&a, batch, &conv, 5);
        let seq = mvm.run_range(&a, 0, batch, &conv, 5);
        assert_eq!(par, seq);
    }
}
