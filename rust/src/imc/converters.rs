//! The legacy closed converter enum, kept as the scalar *reference
//! implementation* and golden-test fixture vocabulary.
//!
//! New code should construct converters through
//! [`super::convert::PsConverterSpec`] and run them through the
//! [`super::convert::PsConvert`] trait (the enum implements the trait by
//! delegating to the slice-vectorized converter structs, so either path is
//! bit-identical — `tests/converter_equiv.rs` enforces it).
//!
//! * [`PsConverter::IdealAdc`] — infinite-precision readout (HPFA-style
//!   functional reference; energy model separately charges FP ADC cost).
//! * [`PsConverter::QuantAdc`] — N-bit SAR ADC (midtread uniform over the
//!   normalized PS range); used for the sparse / low-bit ADC baselines.
//! * [`PsConverter::SenseAmp`] — deterministic 1-bit sign readout
//!   ("1b-SA", the HPF+1b-SA baseline of the paper).
//! * [`PsConverter::StochasticMtj`] — the paper's contribution: ±1 reads
//!   with `P(+1) = (tanh(α·ps)+1)/2`, `n_samples` reads counted
//!   (Eq. 1 + §3.2.3 multi-sampling).
//! * [`PsConverter::ExpectedMtj`] — infinite-sample limit `tanh(α·ps)`
//!   (training-time surrogate; also the variance-free reference).

use super::convert::{
    ExpectedMtjConv, IdealAdcConv, PsConvert, PsSurrogate, QuantAdcConv, SenseAmpConv,
    StochasticMtjConv,
};
use crate::arch::components::PsProcessing;
use crate::stats::rng::CounterRng;

/// The closed PS-converter family, kept as the scalar reference
/// implementation for the open [`PsConvert`] trait (see the module doc;
/// equivalence is pinned by `tests/converter_equiv.rs`).  Registry-only
/// converters (`sparse`, `inhomo`) have no variant here on purpose.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PsConverter {
    /// Infinite-precision readout (HPFA-style functional reference).
    IdealAdc,
    /// N-bit SAR ADC, midtread uniform over the normalized PS range.
    QuantAdc {
        /// ADC resolution in bits.
        bits: u32,
    },
    /// Deterministic 1-bit sign readout ("1b-SA").
    SenseAmp,
    /// Stochastic SOT-MTJ: ±1 reads with `P(+1) = (tanh(α·ps)+1)/2`,
    /// `n_samples` reads summed (Eq. 1 + §3.2.3 multi-sampling).
    StochasticMtj {
        /// Eq. 1 tanh slope.
        alpha: f32,
        /// Temporal reads per conversion.
        n_samples: u32,
    },
    /// Infinite-sample limit `tanh(α·ps)` (training-time surrogate).
    ExpectedMtj {
        /// Eq. 1 tanh slope.
        alpha: f32,
    },
}

impl PsConverter {
    /// Number of temporal samples this converter consumes per PS.
    pub fn samples(&self) -> u32 {
        match self {
            PsConverter::StochasticMtj { n_samples, .. } => *n_samples,
            _ => 1,
        }
    }

    /// Convert one normalized partial sum (`ps ∈ [-1, 1]`) — the scalar
    /// reference path (the slice-vectorized hot path lives in
    /// [`super::convert`]; equivalence is property-tested).
    ///
    /// `counter_base` is the canonical event index of this PS element
    /// (shared layout with python, see `ref.ps_counter_base`); the `rng`
    /// carries the pre-mixed seed.
    #[inline]
    pub fn convert(&self, ps: f32, counter_base: u32, rng: &CounterRng) -> f32 {
        match *self {
            PsConverter::IdealAdc => ps,
            PsConverter::QuantAdc { bits } => {
                // midtread uniform quantizer over [-1, 1]
                let levels = ((1u64 << bits) - 1) as f32;
                let u = ((ps.clamp(-1.0, 1.0) + 1.0) * 0.5 * levels).round_ties_even();
                2.0 * u / levels - 1.0
            }
            PsConverter::SenseAmp => {
                if ps >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
            PsConverter::ExpectedMtj { alpha } => (alpha * ps).tanh(),
            PsConverter::StochasticMtj { alpha, n_samples } => {
                let p = 0.5 * ((alpha * ps).tanh() + 1.0);
                // u < p  ⟺  draw24 < ceil(p·2²⁴): u is k·2⁻²⁴ exactly and
                // the f64 scaling of an f32 p by 2²⁴ is exact, so the
                // integer comparison is bit-equivalent to the python side
                // while skipping the per-sample int→float conversion.
                let thr = ((p as f64) * 16_777_216.0).ceil() as u32;
                let mut total = 0i32;
                for s in 0..n_samples {
                    let c = counter_base.wrapping_mul(n_samples).wrapping_add(s);
                    total += if rng.draw24(c) < thr { 1 } else { -1 };
                }
                total as f32
            }
        }
    }
}

/// The enum rides on the open trait by delegating each slice call to the
/// matching slice-vectorized converter struct: one match per PS column
/// slice instead of one per element, and a single shared implementation
/// of every conversion rule.
impl PsConvert for PsConverter {
    fn convert_slice(
        &self,
        ps: &[f32],
        out: &mut [f32],
        counter_base: u32,
        counter_stride: u32,
        rng: &CounterRng,
    ) {
        match *self {
            PsConverter::IdealAdc => {
                IdealAdcConv.convert_slice(ps, out, counter_base, counter_stride, rng)
            }
            PsConverter::QuantAdc { bits } => {
                QuantAdcConv { bits }.convert_slice(ps, out, counter_base, counter_stride, rng)
            }
            PsConverter::SenseAmp => {
                SenseAmpConv.convert_slice(ps, out, counter_base, counter_stride, rng)
            }
            PsConverter::ExpectedMtj { alpha } => {
                ExpectedMtjConv { alpha }.convert_slice(ps, out, counter_base, counter_stride, rng)
            }
            PsConverter::StochasticMtj { alpha, n_samples } => StochasticMtjConv {
                alpha,
                n_samples,
            }
            .convert_slice(ps, out, counter_base, counter_stride, rng),
        }
    }

    fn samples(&self) -> u32 {
        PsConverter::samples(self)
    }

    fn surrogate(&self) -> PsSurrogate {
        match *self {
            PsConverter::IdealAdc => IdealAdcConv.surrogate(),
            PsConverter::QuantAdc { bits } => QuantAdcConv { bits }.surrogate(),
            PsConverter::SenseAmp => SenseAmpConv.surrogate(),
            PsConverter::ExpectedMtj { alpha } => ExpectedMtjConv { alpha }.surrogate(),
            PsConverter::StochasticMtj { alpha, n_samples } => {
                StochasticMtjConv { alpha, n_samples }.surrogate()
            }
        }
    }

    fn cost_key(&self) -> PsProcessing {
        match *self {
            PsConverter::IdealAdc => IdealAdcConv.cost_key(),
            PsConverter::QuantAdc { bits } => QuantAdcConv { bits }.cost_key(),
            PsConverter::SenseAmp => SenseAmpConv.cost_key(),
            PsConverter::ExpectedMtj { alpha } => ExpectedMtjConv { alpha }.cost_key(),
            PsConverter::StochasticMtj { alpha, n_samples } => {
                StochasticMtjConv { alpha, n_samples }.cost_key()
            }
        }
    }

    fn label(&self) -> String {
        match *self {
            PsConverter::IdealAdc => IdealAdcConv.label(),
            PsConverter::QuantAdc { bits } => QuantAdcConv { bits }.label(),
            PsConverter::SenseAmp => SenseAmpConv.label(),
            PsConverter::ExpectedMtj { alpha } => ExpectedMtjConv { alpha }.label(),
            PsConverter::StochasticMtj { alpha, n_samples } => {
                StochasticMtjConv { alpha, n_samples }.label()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> CounterRng {
        CounterRng::new(9)
    }

    #[test]
    fn ideal_is_identity() {
        assert_eq!(PsConverter::IdealAdc.convert(0.37, 0, &rng()), 0.37);
    }

    #[test]
    fn sense_amp_sign() {
        let sa = PsConverter::SenseAmp;
        assert_eq!(sa.convert(0.4, 0, &rng()), 1.0);
        assert_eq!(sa.convert(-0.4, 0, &rng()), -1.0);
        assert_eq!(sa.convert(0.0, 0, &rng()), 1.0); // matches ref.py ps>=0
    }

    #[test]
    fn quant_adc_precision() {
        let adc = PsConverter::QuantAdc { bits: 8 };
        for i in 0..100 {
            let ps = i as f32 / 50.0 - 1.0;
            let q = adc.convert(ps, 0, &rng());
            assert!((q - ps).abs() <= 1.0 / 255.0 + 1e-6);
        }
        // 1-bit ADC degenerates to {-1, +1}
        let adc1 = PsConverter::QuantAdc { bits: 1 };
        assert_eq!(adc1.convert(0.6, 0, &rng()), 1.0);
        assert_eq!(adc1.convert(-0.6, 0, &rng()), -1.0);
    }

    #[test]
    fn stochastic_counts_are_odd_and_bounded() {
        let mtj = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 5 };
        for c in 0..200 {
            let v = mtj.convert(0.1, c, &rng());
            assert!(v.abs() <= 5.0);
            assert_eq!((v as i32).rem_euclid(2), 1, "odd sum of 5 ±1");
        }
    }

    #[test]
    fn stochastic_rate_tracks_tanh() {
        let mtj = PsConverter::StochasticMtj { alpha: 2.0, n_samples: 1 };
        for &x in &[-0.5f32, -0.1, 0.0, 0.2, 0.6] {
            let n = 20_000;
            let mean: f32 = (0..n).map(|c| mtj.convert(x, c, &rng())).sum::<f32>()
                / n as f32;
            assert!(
                (mean - (2.0 * x).tanh()).abs() < 0.03,
                "x={x} mean={mean}"
            );
        }
    }

    #[test]
    fn expected_is_sample_mean_limit() {
        let alpha = 3.0;
        let exp = PsConverter::ExpectedMtj { alpha };
        let mtj = PsConverter::StochasticMtj { alpha, n_samples: 64 };
        let ps = 0.23;
        let mut acc = 0.0;
        let trials = 500u32;
        for t in 0..trials {
            acc += mtj.convert(ps, t, &rng()) / 64.0;
        }
        let emp = acc / trials as f32;
        assert!((emp - exp.convert(ps, 0, &rng())).abs() < 0.01);
    }

    #[test]
    fn deterministic_given_counter() {
        let mtj = PsConverter::StochasticMtj { alpha: 4.0, n_samples: 3 };
        assert_eq!(mtj.convert(0.2, 77, &rng()), mtj.convert(0.2, 77, &rng()));
    }
}
