//! Explicit-SIMD backends for the integer digit-plane MAC.
//!
//! [`mac_i32`]/[`mac_i16`] compute one column slice of Algorithm 1's
//! partial sums, `ps[c] = Σ_r xd[r][stream] · w_pl[r][c]`, dispatching on
//! a [`MacBackend`] chosen once at crossbar programming time:
//!
//! * **Scalar** — the pinned bit-exact reference (the PR-4 blocked i32
//!   MAC, verbatim); always available and selected automatically when
//!   nothing wider is.
//! * **Avx2 / Neon** — `target_feature`-gated `std::arch` kernels behind
//!   the default `simd` cargo feature (AVX2 is runtime-detected on
//!   x86_64; NEON is baseline on aarch64).
//! * **Portable** — nightly-only `std::simd` kernel behind the
//!   `portable-simd` feature; preferred when compiled in.
//!
//! Every backend is **exact**: digit products and all `r_arr`-bounded
//! prefix sums are integers, integer addition is associative, so lane
//! reordering cannot change a single bit relative to the scalar kernel
//! (`tests/proptests.rs` pins this across shapes, configs, and every
//! registry converter).  The `i16` tier applies the same argument one
//! width down: when [`StoxConfig::int16_kernel_ok`] holds, every prefix
//! sum fits an `i16` accumulator — double the lanes per register — and
//! the final widen-to-`i32` store is lossless.
//!
//! `STOX_SIMD` (`auto|scalar|avx2|neon|portable`) overrides the choice
//! for perf runs; like `STOX_THREADS`, an unknown or unavailable value
//! fails loudly rather than silently measuring the wrong kernel.
//!
//! [`StoxConfig::int16_kernel_ok`]: super::quant::StoxConfig::int16_kernel_ok

/// One MAC backend of the integer digit-plane kernel.  All variants exist
/// on every build so `STOX_SIMD` parsing and bench labels are uniform;
/// [`MacBackend::available`] reports whether the current build *and* host
/// can run one, and the dispatchers fall back to the bit-identical scalar
/// kernel for variants compiled out of this binary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacBackend {
    /// Pinned bit-exact reference (blocked i32 MAC).
    Scalar,
    /// `std::arch` x86_64 kernel (`#[target_feature(enable = "avx2")]`).
    Avx2,
    /// `std::arch` aarch64 kernel (NEON is baseline on aarch64).
    Neon,
    /// `std::simd` kernel (`portable-simd` feature, nightly-only).
    Portable,
}

impl MacBackend {
    /// Stable lowercase name — the `STOX_SIMD` vocabulary and the label
    /// benches record next to their timings.
    pub fn label(self) -> &'static str {
        match self {
            MacBackend::Scalar => "scalar",
            MacBackend::Avx2 => "avx2",
            MacBackend::Neon => "neon",
            MacBackend::Portable => "portable",
        }
    }

    /// Whether this backend can run on the current build + host.
    pub fn available(self) -> bool {
        match self {
            MacBackend::Scalar => true,
            MacBackend::Avx2 => avx2_available(),
            MacBackend::Neon => cfg!(all(feature = "simd", target_arch = "aarch64")),
            MacBackend::Portable => cfg!(feature = "portable-simd"),
        }
    }

    /// The backend crossbar programming selects: the `STOX_SIMD` override
    /// when set (panics on unknown values or unavailable backends — see
    /// [`parse_stox_simd`]), else the widest available kernel.
    ///
    /// Each selection bumps the process-global `simd.select.<label>`
    /// counter ([`crate::obs::global`]).  Backend choice is
    /// host-dependent, so this counter lives only in the global registry
    /// — never in the model-local registries the scenario goldens pin.
    pub fn detect() -> MacBackend {
        let b = Self::detect_uncounted();
        crate::obs::global().counter(&format!("simd.select.{}", b.label())).incr();
        b
    }

    fn detect_uncounted() -> MacBackend {
        if let Ok(v) = std::env::var("STOX_SIMD") {
            if let Some(b) = parse_stox_simd(&v).unwrap() {
                assert!(
                    b.available(),
                    "STOX_SIMD={} requested, but that backend is not available in this \
                     build/host (cargo feature or CPU support missing)",
                    b.label()
                );
                return b;
            }
        }
        Self::auto()
    }

    fn auto() -> MacBackend {
        if MacBackend::Portable.available() {
            MacBackend::Portable
        } else if MacBackend::Avx2.available() {
            MacBackend::Avx2
        } else if MacBackend::Neon.available() {
            MacBackend::Neon
        } else {
            MacBackend::Scalar
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn avx2_available() -> bool {
    false
}

/// Parse a `STOX_SIMD` override: `auto` (or empty) means "no override",
/// otherwise a [`MacBackend::label`].  Unknown values are an error
/// carrying the offending value — perf runs must not quietly fall back
/// and measure the wrong kernel.
pub fn parse_stox_simd(v: &str) -> crate::Result<Option<MacBackend>> {
    Ok(Some(match v.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => return Ok(None),
        "scalar" => MacBackend::Scalar,
        "avx2" => MacBackend::Avx2,
        "neon" => MacBackend::Neon,
        "portable" => MacBackend::Portable,
        _ => anyhow::bail!(
            "invalid STOX_SIMD value '{v}': expected auto|scalar|avx2|neon|portable"
        ),
    }))
}

// ---------------------------------------------------------------------
// i32 tier
// ---------------------------------------------------------------------

/// Blocked i8×i8→i32 MAC of activation stream `stream` against one weight
/// slice plane: `ps[c] = Σ_r xd[r·i_n + stream] · w_pl[r·n + c]` for
/// `c < n`.  Exact on every backend (integer addition is associative);
/// backends compiled out of this build run the scalar reference.
#[allow(clippy::too_many_arguments)]
pub fn mac_i32(
    backend: MacBackend,
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    debug_assert!(w_pl.len() >= rows * n && ps.len() >= n);
    match backend {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: Avx2 is only selected when available() saw AVX2 support
        MacBackend::Avx2 => unsafe { mac_i32_avx2(w_pl, xd, rows, i_n, stream, n, ps) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: NEON is baseline on aarch64
        MacBackend::Neon => unsafe { mac_i32_neon(w_pl, xd, rows, i_n, stream, n, ps) },
        #[cfg(feature = "portable-simd")]
        MacBackend::Portable => mac_i32_portable(w_pl, xd, rows, i_n, stream, n, ps),
        #[allow(unreachable_patterns)]
        _ => mac_i32_scalar(w_pl, xd, rows, i_n, stream, n, ps),
    }
}

/// The pinned scalar reference (PR-4 kernel, verbatim): fixed blocks of
/// `MAC_BLK` i32 register accumulators so LLVM unrolls and vectorizes the
/// column loop; zero activation digits skip their row entirely
/// (signed-digit decomposition makes in-range digits odd — the skip fires
/// for structurally absent rows and custom sparse operands, and costs one
/// predictable branch when dense).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mac_i32_scalar(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    const MAC_BLK: usize = 16;
    let mut c0 = 0usize;
    while c0 + MAC_BLK <= n {
        let mut acc = [0i32; MAC_BLK];
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let x = x as i32;
            let w = &w_pl[rr * n + c0..rr * n + c0 + MAC_BLK];
            for (a, &wv) in acc.iter_mut().zip(w) {
                *a += x * wv as i32;
            }
        }
        ps[c0..c0 + MAC_BLK].copy_from_slice(&acc);
        c0 += MAC_BLK;
    }
    if c0 < n {
        let rem = n - c0;
        let mut acc = [0i32; MAC_BLK];
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let x = x as i32;
            let w = &w_pl[rr * n + c0..rr * n + c0 + rem];
            for (a, &wv) in acc.iter_mut().zip(w) {
                *a += x * wv as i32;
            }
        }
        ps[c0..n].copy_from_slice(&acc[..rem]);
    }
}

/// AVX2 i32 kernel: 16 columns per iteration in two 8-lane `__m256i`
/// accumulators; `i8` weights sign-extend through `_mm256_cvtepi8_epi32`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn mac_i32_avx2(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    use std::arch::x86_64::*;
    let mut c0 = 0usize;
    while c0 + 16 <= n {
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let xv = _mm256_set1_epi32(x as i32);
            let w = _mm_loadu_si128(w_pl.as_ptr().add(rr * n + c0) as *const __m128i);
            let wlo = _mm256_cvtepi8_epi32(w);
            let whi = _mm256_cvtepi8_epi32(_mm_srli_si128::<8>(w));
            acc0 = _mm256_add_epi32(acc0, _mm256_mullo_epi32(wlo, xv));
            acc1 = _mm256_add_epi32(acc1, _mm256_mullo_epi32(whi, xv));
        }
        _mm256_storeu_si256(ps.as_mut_ptr().add(c0) as *mut __m256i, acc0);
        _mm256_storeu_si256(ps.as_mut_ptr().add(c0 + 8) as *mut __m256i, acc1);
        c0 += 16;
    }
    if c0 < n {
        mac_i32_tail(w_pl, xd, rows, i_n, stream, n, c0, ps);
    }
}

/// NEON i32 kernel: 16 columns per iteration in four 4-lane `int32x4_t`
/// accumulators via the widening multiply-accumulate `vmlal_n_s16`.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn mac_i32_neon(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    use std::arch::aarch64::*;
    let mut c0 = 0usize;
    while c0 + 16 <= n {
        let mut a0 = vdupq_n_s32(0);
        let mut a1 = vdupq_n_s32(0);
        let mut a2 = vdupq_n_s32(0);
        let mut a3 = vdupq_n_s32(0);
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let w8 = vld1q_s8(w_pl.as_ptr().add(rr * n + c0));
            let wlo = vmovl_s8(vget_low_s8(w8));
            let whi = vmovl_s8(vget_high_s8(w8));
            a0 = vmlal_n_s16(a0, vget_low_s16(wlo), x as i16);
            a1 = vmlal_n_s16(a1, vget_high_s16(wlo), x as i16);
            a2 = vmlal_n_s16(a2, vget_low_s16(whi), x as i16);
            a3 = vmlal_n_s16(a3, vget_high_s16(whi), x as i16);
        }
        vst1q_s32(ps.as_mut_ptr().add(c0), a0);
        vst1q_s32(ps.as_mut_ptr().add(c0 + 4), a1);
        vst1q_s32(ps.as_mut_ptr().add(c0 + 8), a2);
        vst1q_s32(ps.as_mut_ptr().add(c0 + 12), a3);
        c0 += 16;
    }
    if c0 < n {
        mac_i32_tail(w_pl, xd, rows, i_n, stream, n, c0, ps);
    }
}

/// `std::simd` i32 kernel (nightly): 16 lanes, `i8 → i32` lane cast.
#[cfg(feature = "portable-simd")]
#[allow(clippy::too_many_arguments)]
fn mac_i32_portable(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    use std::simd::prelude::*;
    const L: usize = 16;
    let mut c0 = 0usize;
    while c0 + L <= n {
        let mut acc = Simd::<i32, L>::splat(0);
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let w = Simd::<i8, L>::from_slice(&w_pl[rr * n + c0..rr * n + c0 + L]);
            acc += w.cast::<i32>() * Simd::splat(x as i32);
        }
        acc.copy_to_slice(&mut ps[c0..c0 + L]);
        c0 += L;
    }
    if c0 < n {
        mac_i32_tail(w_pl, xd, rows, i_n, stream, n, c0, ps);
    }
}

/// Scalar tail over columns [c0, n) — shared by every wide i32 kernel.
#[cfg(any(feature = "simd", feature = "portable-simd"))]
#[allow(clippy::too_many_arguments)]
fn mac_i32_tail(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    c0: usize,
    ps: &mut [i32],
) {
    for p in ps[c0..n].iter_mut() {
        *p = 0;
    }
    for rr in 0..rows {
        let x = xd[rr * i_n + stream];
        if x == 0 {
            continue;
        }
        let x = x as i32;
        for (p, &wv) in ps[c0..n].iter_mut().zip(&w_pl[rr * n + c0..rr * n + n]) {
            *p += x * wv as i32;
        }
    }
}

// ---------------------------------------------------------------------
// i16 tier
// ---------------------------------------------------------------------

/// The `i16` accumulation tier of [`mac_i32`]: identical contract and
/// bit-identical results, but partial sums accumulate in `i16` (twice the
/// lanes per register) and widen losslessly to `i32` on store.  **Callers
/// must guarantee [`StoxConfig::int16_kernel_ok`]** — the worst-case
/// column bound then caps every intermediate prefix sum at `i16::MAX`,
/// so no accumulation step can overflow on any backend.
///
/// [`StoxConfig::int16_kernel_ok`]: super::quant::StoxConfig::int16_kernel_ok
#[allow(clippy::too_many_arguments)]
pub fn mac_i16(
    backend: MacBackend,
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    debug_assert!(w_pl.len() >= rows * n && ps.len() >= n);
    match backend {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: Avx2 is only selected when available() saw AVX2 support
        MacBackend::Avx2 => unsafe { mac_i16_avx2(w_pl, xd, rows, i_n, stream, n, ps) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: NEON is baseline on aarch64
        MacBackend::Neon => unsafe { mac_i16_neon(w_pl, xd, rows, i_n, stream, n, ps) },
        #[cfg(feature = "portable-simd")]
        MacBackend::Portable => mac_i16_portable(w_pl, xd, rows, i_n, stream, n, ps),
        #[allow(unreachable_patterns)]
        _ => mac_i16_scalar(w_pl, xd, rows, i_n, stream, n, ps),
    }
}

/// Scalar `i16` tier: the reference blocked MAC with `i16` accumulators
/// (widened on store) — LLVM packs twice the lanes per vector register.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mac_i16_scalar(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    const MAC_BLK: usize = 32;
    let mut c0 = 0usize;
    while c0 + MAC_BLK <= n {
        let mut acc = [0i16; MAC_BLK];
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let x = x as i16;
            let w = &w_pl[rr * n + c0..rr * n + c0 + MAC_BLK];
            for (a, &wv) in acc.iter_mut().zip(w) {
                *a += x * wv as i16;
            }
        }
        for (p, &a) in ps[c0..c0 + MAC_BLK].iter_mut().zip(&acc) {
            *p = a as i32;
        }
        c0 += MAC_BLK;
    }
    if c0 < n {
        let rem = n - c0;
        let mut acc = [0i16; MAC_BLK];
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let x = x as i16;
            let w = &w_pl[rr * n + c0..rr * n + c0 + rem];
            for (a, &wv) in acc.iter_mut().zip(w) {
                *a += x * wv as i16;
            }
        }
        for (p, &a) in ps[c0..n].iter_mut().zip(&acc[..rem]) {
            *p = a as i32;
        }
    }
}

/// AVX2 `i16` tier: 16 columns per 256-bit accumulator (vs 8 on the i32
/// tier); digit products fit `i16` (`|x|·|w| ≤ 127·127`) and prefix sums
/// are bounded by the caller's `int16_kernel_ok` guarantee.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn mac_i16_avx2(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    use std::arch::x86_64::*;
    let mut c0 = 0usize;
    while c0 + 16 <= n {
        let mut acc = _mm256_setzero_si256();
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let xv = _mm256_set1_epi16(x as i16);
            let w = _mm_loadu_si128(w_pl.as_ptr().add(rr * n + c0) as *const __m128i);
            let w16 = _mm256_cvtepi8_epi16(w);
            acc = _mm256_add_epi16(acc, _mm256_mullo_epi16(w16, xv));
        }
        let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(acc));
        let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(acc));
        _mm256_storeu_si256(ps.as_mut_ptr().add(c0) as *mut __m256i, lo);
        _mm256_storeu_si256(ps.as_mut_ptr().add(c0 + 8) as *mut __m256i, hi);
        c0 += 16;
    }
    if c0 < n {
        mac_i16_scalar_tail(w_pl, xd, rows, i_n, stream, n, c0, ps);
    }
}

/// NEON `i16` tier: 16 columns in two 8-lane `int16x8_t` accumulators via
/// the non-widening `vmlaq_n_s16`, widened to i32 on store.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn mac_i16_neon(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    use std::arch::aarch64::*;
    let mut c0 = 0usize;
    while c0 + 16 <= n {
        let mut a0 = vdupq_n_s16(0);
        let mut a1 = vdupq_n_s16(0);
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let w8 = vld1q_s8(w_pl.as_ptr().add(rr * n + c0));
            a0 = vmlaq_n_s16(a0, vmovl_s8(vget_low_s8(w8)), x as i16);
            a1 = vmlaq_n_s16(a1, vmovl_s8(vget_high_s8(w8)), x as i16);
        }
        vst1q_s32(ps.as_mut_ptr().add(c0), vmovl_s16(vget_low_s16(a0)));
        vst1q_s32(ps.as_mut_ptr().add(c0 + 4), vmovl_s16(vget_high_s16(a0)));
        vst1q_s32(ps.as_mut_ptr().add(c0 + 8), vmovl_s16(vget_low_s16(a1)));
        vst1q_s32(ps.as_mut_ptr().add(c0 + 12), vmovl_s16(vget_high_s16(a1)));
        c0 += 16;
    }
    if c0 < n {
        mac_i16_scalar_tail(w_pl, xd, rows, i_n, stream, n, c0, ps);
    }
}

/// `std::simd` `i16` tier (nightly): 32 `i16` lanes, lossless lane cast
/// to `i32` on store.
#[cfg(feature = "portable-simd")]
#[allow(clippy::too_many_arguments)]
fn mac_i16_portable(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    ps: &mut [i32],
) {
    use std::simd::prelude::*;
    const L: usize = 32;
    let mut c0 = 0usize;
    while c0 + L <= n {
        let mut acc = Simd::<i16, L>::splat(0);
        for rr in 0..rows {
            let x = xd[rr * i_n + stream];
            if x == 0 {
                continue;
            }
            let w = Simd::<i8, L>::from_slice(&w_pl[rr * n + c0..rr * n + c0 + L]);
            acc += w.cast::<i16>() * Simd::splat(x as i16);
        }
        acc.cast::<i32>().copy_to_slice(&mut ps[c0..c0 + L]);
        c0 += L;
    }
    if c0 < n {
        mac_i16_scalar_tail(w_pl, xd, rows, i_n, stream, n, c0, ps);
    }
}

/// `i16`-accumulating scalar tail over columns [c0, n) — shared by the
/// wide i16 kernels so the tier's arithmetic stays uniform.
#[cfg(any(feature = "simd", feature = "portable-simd"))]
#[allow(clippy::too_many_arguments)]
fn mac_i16_scalar_tail(
    w_pl: &[i8],
    xd: &[i8],
    rows: usize,
    i_n: usize,
    stream: usize,
    n: usize,
    c0: usize,
    ps: &mut [i32],
) {
    let rem = n - c0;
    let mut acc = vec![0i16; rem];
    for rr in 0..rows {
        let x = xd[rr * i_n + stream];
        if x == 0 {
            continue;
        }
        let x = x as i16;
        for (a, &wv) in acc.iter_mut().zip(&w_pl[rr * n + c0..rr * n + n]) {
            *a += x * wv as i16;
        }
    }
    for (p, &a) in ps[c0..n].iter_mut().zip(&acc) {
        *p = a as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random digits in [-hi, hi] with zeros mixed in.
    fn digits(len: usize, seed: u32, hi: i32) -> Vec<i8> {
        let mut s = seed.wrapping_mul(2_654_435_761).wrapping_add(12345);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
                let span = 2 * hi + 1;
                (((s >> 16) as i32 % span) - hi) as i8
            })
            .collect()
    }

    fn backends() -> Vec<MacBackend> {
        [MacBackend::Scalar, MacBackend::Avx2, MacBackend::Neon, MacBackend::Portable]
            .into_iter()
            .filter(|b| b.available())
            .collect()
    }

    #[test]
    fn every_backend_matches_scalar_i32() {
        for &(rows, i_n, n) in
            &[(0usize, 1usize, 16usize), (1, 1, 1), (5, 4, 7), (64, 4, 16), (64, 2, 33), (17, 1, 64)]
        {
            let w = digits(rows.max(1) * n, 1, 15);
            let xd = digits(rows.max(1) * i_n, 2, 15);
            for stream in 0..i_n {
                let mut want = vec![0i32; n];
                mac_i32_scalar(&w, &xd, rows, i_n, stream, n, &mut want);
                for b in backends() {
                    let mut got = vec![-1i32; n];
                    mac_i32(b, &w, &xd, rows, i_n, stream, n, &mut got);
                    assert_eq!(got, want, "{} rows={rows} i_n={i_n} n={n}", b.label());
                }
            }
        }
    }

    #[test]
    fn i16_tier_matches_i32_on_every_backend() {
        // digit magnitudes ≤ 15, rows ≤ 64 → worst prefix sum 14400 < i16::MAX
        for &(rows, i_n, n) in
            &[(64usize, 4usize, 16usize), (64, 1, 33), (5, 2, 7), (0, 1, 40), (33, 4, 64)]
        {
            let w = digits(rows.max(1) * n, 3, 15);
            let xd = digits(rows.max(1) * i_n, 4, 15);
            for stream in 0..i_n {
                let mut want = vec![0i32; n];
                mac_i32_scalar(&w, &xd, rows, i_n, stream, n, &mut want);
                for b in backends() {
                    let mut got = vec![-1i32; n];
                    mac_i16(b, &w, &xd, rows, i_n, stream, n, &mut got);
                    assert_eq!(got, want, "i16/{} rows={rows} i_n={i_n} n={n}", b.label());
                }
            }
        }
    }

    #[test]
    fn zero_digit_rows_are_skipped_consistently() {
        let (rows, i_n, n) = (32usize, 2usize, 20usize);
        let w = digits(rows * n, 5, 7);
        let mut xd = digits(rows * i_n, 6, 7);
        for r in (0..rows).step_by(3) {
            xd[r * i_n] = 0;
        }
        let mut want = vec![0i32; n];
        mac_i32_scalar(&w, &xd, rows, i_n, 0, n, &mut want);
        for b in backends() {
            let mut got = vec![0i32; n];
            mac_i32(b, &w, &xd, rows, i_n, 0, n, &mut got);
            assert_eq!(got, want, "{}", b.label());
            mac_i16(b, &w, &xd, rows, i_n, 0, n, &mut got);
            assert_eq!(got, want, "i16/{}", b.label());
        }
    }

    #[test]
    fn parse_stox_simd_vocabulary() {
        assert_eq!(parse_stox_simd("auto").unwrap(), None);
        assert_eq!(parse_stox_simd("").unwrap(), None);
        assert_eq!(parse_stox_simd("scalar").unwrap(), Some(MacBackend::Scalar));
        assert_eq!(parse_stox_simd(" AVX2 ").unwrap(), Some(MacBackend::Avx2));
        assert_eq!(parse_stox_simd("neon").unwrap(), Some(MacBackend::Neon));
        assert_eq!(parse_stox_simd("portable").unwrap(), Some(MacBackend::Portable));
        let err = parse_stox_simd("sse9").unwrap_err().to_string();
        assert!(err.contains("STOX_SIMD") && err.contains("sse9"), "{err}");
    }

    #[test]
    fn detect_returns_an_available_backend() {
        // pure availability invariants — detect() itself reads the env, so
        // only sanity-check its result rather than mutating STOX_SIMD
        assert!(MacBackend::Scalar.available());
        let b = MacBackend::detect();
        assert!(b.available(), "{}", b.label());
        assert_eq!(parse_stox_simd(b.label()).unwrap(), Some(b));
    }
}
