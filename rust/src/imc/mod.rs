//! Functional in-memory-computing crossbar model (Algorithm 1).
//!
//! Bit-identical with the python oracle `python/compile/kernels/ref.py`:
//! same quantizer (round-half-even), same signed digit decomposition, same
//! row partitioning, same counter-based stochastic sampling.  Exactness is
//! enforced by golden-vector tests generated from the python side
//! (`rust/tests/parity.rs`).

pub mod converters;
pub mod mvm;
pub mod nonideal;
pub mod quant;

pub use converters::PsConverter;
pub use mvm::{im2col, stox_conv2d, stox_mvm, StoxMvm};
pub use nonideal::{Nonideality, NonidealCrossbar};
pub use quant::StoxConfig;
