//! Functional in-memory-computing crossbar model (Algorithm 1).
//!
//! Bit-identical with the python oracle `python/compile/kernels/ref.py`:
//! same quantizer (round-half-even), same signed digit decomposition, same
//! row partitioning, same counter-based stochastic sampling.  Exactness is
//! enforced by golden-vector tests generated from the python side
//! (`rust/tests/parity.rs`).
//!
//! The hot path is the **integer digit-plane kernel** ([`mvm`]): `i8`
//! weight-slice planes, `i8` activation digit stripes, `i32` PS
//! accumulation — exact, hence still bit-identical with the oracle — plus
//! a fused digit-domain convolution ([`StoxMvm::run_conv_digits`]) that
//! decomposes each input pixel once instead of kh·kw times.
//!
//! PS conversion is an **open, slice-vectorized API** ([`convert`]):
//!
//! * [`PsConvert`] — the trait; converts a whole PS column slice per call
//!   (`convert_slice_at`), reports its temporal [`PsConvert::samples`] and
//!   its [`PsConvert::cost_key`] (the `arch/energy.rs` hook);
//! * [`PsConverterSpec`] + [`ConverterRegistry`] — the single parsing
//!   (`FromStr`/json) and construction path used by `model/infer.rs`,
//!   `main.rs`, examples and benches; [`default_registry`] carries the
//!   in-tree family (ideal / quant / sparse ADC, 1b-SA, expected MTJ,
//!   stochastic MTJ, §3.2.3 inhomogeneous MTJ), `register` adds more;
//! * [`PsConverter`] — the legacy closed enum, kept as the scalar
//!   reference implementation (it implements [`PsConvert`] by delegating
//!   to the slice converters; `tests/converter_equiv.rs` pins the
//!   equivalence on the parity fixtures).

pub mod convert;
pub mod converters;
pub mod mvm;
pub mod nonideal;
pub mod quant;
pub mod simd;

pub use convert::{
    default_registry, ConverterRegistry, ExpectedMtjConv, IdealAdcConv, InhomogeneousMtjConv,
    PsConvert, PsConverterSpec, PsIntCache, PsSurrogate, QuantAdcConv, SenseAmpConv,
    SparseAdcConv, StochasticMtjConv,
};
pub use converters::PsConverter;
pub use mvm::{
    decompose_activations, im2col, stox_conv2d, stox_mvm, ActivationDigits, ConvArena, StoxMvm,
};
pub use nonideal::{Nonideality, NonidealCrossbar};
pub use quant::StoxConfig;
pub use simd::MacBackend;
