//! Weight-stationary tile scheduler.
//!
//! Each DNN layer owns a fixed set of tiles (ISAAC-style weight-stationary
//! placement from [`crate::arch::tile`]); a batch of images flows through
//! the layers in order.  The scheduler advances a *simulated hardware
//! clock*: layer `l` of image-batch `t` can start only when (a) layer
//! `l-1` of the same batch has produced its activations, and (b) layer
//! `l`'s tiles have finished batch `t-1` (pipelined across batches, the
//! steady-state of Fig. 8 writ large).  Per execution it charges the
//! energy of the mapped actions, so serving yields the same pJ/inference
//! as the Fig. 9 rollup.

use crate::arch::components::{ComponentCosts, PsProcessing};
use crate::arch::energy::{DesignConfig, evaluate_design};
use crate::arch::mapper::LayerShape;
use crate::arch::pipeline::PipelineModel;

/// Per-layer static schedule data.
struct LayerSlot {
    /// simulated latency of one batch-element pass through this layer (ns)
    latency_ns: f64,
    /// energy per inference through this layer (pJ)
    energy_pj: f64,
    /// when this layer's tiles become free (ns, simulated clock)
    tile_free_at: f64,
}

/// The scheduler: owns the simulated clock and per-layer tile state.
pub struct TileScheduler {
    layers: Vec<LayerSlot>,
    pub design: DesignConfig,
    /// makespan of everything scheduled so far (ns)
    pub horizon_ns: f64,
}

/// Result of scheduling one batch.
#[derive(Debug, Clone)]
pub struct ScheduleResult {
    /// simulated completion time of the batch (ns, absolute clock)
    pub finish_ns: f64,
    /// simulated latency of this batch through the whole network (ns)
    pub span_ns: f64,
    /// energy charged (pJ)
    pub energy_pj: f64,
}

impl TileScheduler {
    pub fn new(
        costs: &ComponentCosts,
        design: DesignConfig,
        shapes: &[LayerShape],
    ) -> Self {
        let report = evaluate_design(costs, &design, shapes);
        let pipe = PipelineModel { costs: *costs, ..Default::default() };
        let layers = shapes
            .iter()
            .zip(&report.per_layer)
            .enumerate()
            .map(|(idx, (shape, rep))| {
                let ps = if idx == 0 || !shape.stochastic {
                    design.first_layer_ps
                } else {
                    design.ps
                };
                let mapped =
                    crate::arch::mapper::map_layer(shape, &design.stox, design.c_arr);
                let _ = ps as PsProcessing;
                LayerSlot {
                    latency_ns: pipe.layer_latency_ns(&mapped, ps),
                    energy_pj: rep.energy_pj,
                    tile_free_at: 0.0,
                }
            })
            .collect();
        Self { layers, design, horizon_ns: 0.0 }
    }

    /// Schedule one batch of `batch` images arriving at simulated time
    /// `arrival_ns`; batching amortizes weight-stationary reuse so the
    /// pipeline streams `batch` inputs back-to-back through each layer.
    pub fn schedule_batch(&mut self, batch: usize, arrival_ns: f64) -> ScheduleResult {
        let mut ready = arrival_ns; // activations-available time
        let mut energy = 0.0;
        for slot in &mut self.layers {
            let start = ready.max(slot.tile_free_at);
            // batch elements stream through; pipeline beat amortized, so
            // batch latency ≈ latency of one + (batch-1) beats ≈ linear.
            let busy = slot.latency_ns * batch as f64;
            let finish = start + busy;
            slot.tile_free_at = finish;
            ready = finish;
            energy += slot.energy_pj * batch as f64;
        }
        self.horizon_ns = self.horizon_ns.max(ready);
        ScheduleResult {
            finish_ns: ready,
            span_ns: ready - arrival_ns,
            energy_pj: energy,
        }
    }

    /// Steady-state throughput bound: 1 / (slowest layer busy time per
    /// image) — the pipeline bottleneck (inferences per second).
    pub fn throughput_bound_per_s(&self) -> f64 {
        let slowest = self
            .layers
            .iter()
            .map(|l| l.latency_ns)
            .fold(0.0f64, f64::max);
        if slowest <= 0.0 {
            0.0
        } else {
            1e9 / slowest
        }
    }

    /// Single-image simulated network latency (ns).
    pub fn single_latency_ns(&self) -> f64 {
        self.layers.iter().map(|l| l.latency_ns).sum()
    }

    /// Energy per single inference (pJ).
    pub fn energy_per_inference_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.energy_pj).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imc::StoxConfig;
    use crate::model::zoo;

    fn sched(design: DesignConfig) -> TileScheduler {
        TileScheduler::new(
            &ComponentCosts::default(),
            design,
            &zoo::resnet20_cifar(),
        )
    }

    #[test]
    fn single_batch_span_is_sum_of_layers() {
        let mut s = sched(DesignConfig::stox(StoxConfig::default(), 1, true));
        let r = s.schedule_batch(1, 0.0);
        assert!((r.span_ns - s.single_latency_ns()).abs() < 1e-6);
        assert!((r.energy_pj - s.energy_per_inference_pj()).abs() < 1e-6);
    }

    #[test]
    fn back_to_back_batches_pipeline() {
        let mut s = sched(DesignConfig::stox(StoxConfig::default(), 1, true));
        let r1 = s.schedule_batch(1, 0.0);
        let r2 = s.schedule_batch(1, 0.0);
        // second batch waits only on the first layer's tiles, not on the
        // full span of batch 1
        assert!(r2.finish_ns > r1.finish_ns);
        assert!(r2.finish_ns < 2.0 * r1.finish_ns);
    }

    #[test]
    fn mtj_throughput_beats_adc() {
        let stox = sched(DesignConfig::stox(StoxConfig::default(), 1, true));
        let hpfa = sched(DesignConfig::hpfa());
        assert!(stox.throughput_bound_per_s() > hpfa.throughput_bound_per_s());
    }

    #[test]
    fn energy_matches_fig9_rollup() {
        let design = DesignConfig::stox(StoxConfig::default(), 1, true);
        let report = evaluate_design(
            &ComponentCosts::default(),
            &design,
            &zoo::resnet20_cifar(),
        );
        let s = sched(design);
        assert!((s.energy_per_inference_pj() - report.energy_pj).abs() < 1e-6);
    }

    #[test]
    fn batching_scales_energy_linearly() {
        let mut s = sched(DesignConfig::stox(StoxConfig::default(), 1, true));
        let r1 = s.schedule_batch(1, 0.0);
        let mut s2 = sched(DesignConfig::stox(StoxConfig::default(), 1, true));
        let r4 = s2.schedule_batch(4, 0.0);
        assert!((r4.energy_pj / r1.energy_pj - 4.0).abs() < 1e-9);
    }
}
