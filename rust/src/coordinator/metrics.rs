//! Serving metrics: wall-clock latency percentiles, throughput, batch
//! occupancy, plus the *simulated hardware* counters charged by the tile
//! scheduler (energy pJ / latency ns per inference on the modeled IMC).

use crate::stats::{Histogram, LatencyHistogram};
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// wall-clock end-to-end request latency (µs) — shares the
    /// [`LatencyHistogram`] implementation with the replica tier's
    /// `ShardStats`, on the legacy f32 recording path so the report is
    /// byte-identical with the pre-dedupe hand-rolled histogram
    latency_us: LatencyHistogram,
    /// batch sizes at execution
    batch_occupancy: Histogram,
    pub requests: u64,
    pub batches: u64,
    /// executor-error batch resubmits (`ServeConfig::max_retries` policy)
    pub retries: u64,
    /// simulated IMC hardware charges
    pub hw_energy_pj: f64,
    pub hw_latency_ns: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            // up to 60 s at 5 ms resolution: interpret-mode pallas backends
            // run hundreds of ms per batch, and queue waits accumulate
            latency_us: LatencyHistogram::new(60_000_000.0, 12_000),
            batch_occupancy: Histogram::new(0.0, 64.0, 64),
            requests: 0,
            batches: 0,
            retries: 0,
            hw_energy_pj: 0.0,
            hw_latency_ns: 0.0,
        }
    }

    pub fn record_batch(&mut self, batch: usize, latencies: &[Duration]) {
        self.batches += 1;
        self.requests += latencies.len() as u64;
        self.batch_occupancy.add(batch as f32);
        for l in latencies {
            self.latency_us.record_us_f32(l.as_secs_f32() * 1e6);
        }
    }

    pub fn record_hw(&mut self, energy_pj: f64, latency_ns: f64) {
        self.hw_energy_pj += energy_pj;
        self.hw_latency_ns += latency_ns;
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    pub fn latency_percentile_us(&self, p: f64) -> f32 {
        self.latency_us.percentile_us(p)
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_occupancy.mean()
    }

    pub fn report(&self) -> MetricsReport {
        MetricsReport {
            requests: self.requests,
            batches: self.batches,
            retries: self.retries,
            throughput_rps: self.throughput_rps(),
            p50_us: self.latency_percentile_us(50.0),
            p95_us: self.latency_percentile_us(95.0),
            p99_us: self.latency_percentile_us(99.0),
            mean_batch: self.mean_batch(),
            hw_energy_pj: self.hw_energy_pj,
            hw_latency_ns: self.hw_latency_ns,
            hw_energy_per_req_pj: if self.requests > 0 {
                self.hw_energy_pj / self.requests as f64
            } else {
                0.0
            },
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: u64,
    pub batches: u64,
    pub retries: u64,
    pub throughput_rps: f64,
    pub p50_us: f32,
    pub p95_us: f32,
    pub p99_us: f32,
    pub mean_batch: f64,
    pub hw_energy_pj: f64,
    pub hw_latency_ns: f64,
    pub hw_energy_per_req_pj: f64,
}

impl std::fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests        : {}", self.requests)?;
        writeln!(f, "batches         : {} (mean occupancy {:.2})", self.batches, self.mean_batch)?;
        if self.retries > 0 {
            writeln!(f, "batch retries   : {}", self.retries)?;
        }
        writeln!(f, "throughput      : {:.1} req/s", self.throughput_rps)?;
        writeln!(
            f,
            "latency p50/p95/p99 : {:.0} / {:.0} / {:.0} µs",
            self.p50_us, self.p95_us, self.p99_us
        )?;
        writeln!(
            f,
            "simulated IMC   : {:.3} µJ total, {:.3} nJ/request, {:.3} ms busy",
            self.hw_energy_pj / 1e6,
            self.hw_energy_per_req_pj / 1e3,
            self.hw_latency_ns / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        m.record_batch(
            4,
            &[
                Duration::from_micros(100),
                Duration::from_micros(200),
                Duration::from_micros(300),
                Duration::from_micros(400),
            ],
        );
        m.record_hw(1000.0, 500.0);
        let r = m.report();
        assert_eq!(r.requests, 4);
        assert_eq!(r.batches, 1);
        // bin width is 5 ms: sub-millisecond latencies resolve to bin 0
        assert!(r.p50_us >= 0.0 && r.p50_us < 5_000.0);
        assert_eq!(r.hw_energy_per_req_pj, 250.0);
        assert!(format!("{r}").contains("requests"));
    }

    #[test]
    fn empty_metrics_dont_panic() {
        let r = Metrics::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.hw_energy_per_req_pj, 0.0);
    }
}
