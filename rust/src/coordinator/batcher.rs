//! Size-or-deadline dynamic batcher.
//!
//! Requests accumulate until either the target batch size is reached or
//! the oldest request has waited `max_wait`; the flushed batch is then
//! padded (by replication) up to the nearest AOT-compiled batch variant.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// preferred (largest) batch size
    pub target_batch: usize,
    /// flush deadline for the oldest queued request
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { target_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// One queued inference request.
#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
    pub id: u64,
}

/// A flushed batch.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<Pending<T>>,
    /// why the batch was cut
    pub reason: FlushReason,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FlushReason {
    Size,
    Deadline,
    Drain,
}

/// Deterministic, testable batching core (no tokio dependency; the server
/// wraps it in an async loop).
pub struct DynamicBatcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Pending<T>>,
    next_id: u64,
}

impl<T> DynamicBatcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, queue: VecDeque::new(), next_id: 0 }
    }

    pub fn push(&mut self, payload: T, now: Instant) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending { payload, enqueued: now, id });
        id
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Time until the oldest request's deadline (None if queue empty).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            let waited = now.duration_since(p.enqueued);
            self.cfg.max_wait.saturating_sub(waited)
        })
    }

    /// Flush policy: full batch → Size; oldest waited ≥ max_wait → Deadline.
    pub fn try_flush(&mut self, now: Instant) -> Option<Batch<T>> {
        if self.queue.len() >= self.cfg.target_batch {
            let items = self.drain(self.cfg.target_batch);
            return Some(Batch { items, reason: FlushReason::Size });
        }
        if let Some(front) = self.queue.front() {
            if now.duration_since(front.enqueued) >= self.cfg.max_wait {
                let n = self.queue.len().min(self.cfg.target_batch);
                let items = self.drain(n);
                return Some(Batch { items, reason: FlushReason::Deadline });
            }
        }
        None
    }

    /// Unconditional flush (shutdown path).
    pub fn drain_all(&mut self) -> Option<Batch<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.cfg.target_batch);
        let items = self.drain(n);
        Some(Batch { items, reason: FlushReason::Drain })
    }

    fn drain(&mut self, n: usize) -> Vec<Pending<T>> {
        self.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_on_size() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        let now = t0();
        b.push(1, now);
        b.push(2, now);
        assert!(b.try_flush(now).is_none());
        b.push(3, now);
        let batch = b.try_flush(now).unwrap();
        assert_eq!(batch.reason, FlushReason::Size);
        assert_eq!(batch.items.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        let now = t0();
        b.push("x", now);
        assert!(b.try_flush(now).is_none());
        let later = now + Duration::from_millis(6);
        let batch = b.try_flush(later).unwrap();
        assert_eq!(batch.reason, FlushReason::Deadline);
        assert_eq!(batch.items.len(), 1);
    }

    #[test]
    fn size_cut_leaves_remainder() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        let now = t0();
        for i in 0..5 {
            b.push(i, now);
        }
        let batch = b.try_flush(now).unwrap();
        assert_eq!(batch.items.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn ids_monotone() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = t0();
        let a = b.push((), now);
        let c = b.push((), now);
        assert!(c > a);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 8,
            max_wait: Duration::from_millis(10),
        });
        let now = t0();
        assert!(b.next_deadline(now).is_none());
        b.push((), now);
        let d1 = b.next_deadline(now).unwrap();
        let d2 = b.next_deadline(now + Duration::from_millis(4)).unwrap();
        assert!(d2 < d1);
    }

    #[test]
    fn drain_all() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        assert!(b.drain_all().is_none());
        b.push(1, t0());
        let batch = b.drain_all().unwrap();
        assert_eq!(batch.reason, FlushReason::Drain);
        assert_eq!(batch.items.len(), 1);
    }

    /// A deadline cut with interleaved pushes keeps FIFO order: requests
    /// pushed at different times (including one arriving *after* the
    /// oldest request's deadline already passed) flush oldest-first in
    /// push order, never reordered by arrival jitter.
    #[test]
    fn deadline_flush_keeps_fifo_order_with_interleaved_pushes() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        let now = t0();
        let a = b.push("a", now);
        let c = b.push("b", now + Duration::from_millis(2));
        // "c" arrives after "a" has already exceeded its deadline
        let e = b.push("c", now + Duration::from_millis(6));
        let batch = b.try_flush(now + Duration::from_millis(7)).unwrap();
        assert_eq!(batch.reason, FlushReason::Deadline);
        let ids: Vec<u64> = batch.items.iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![a, c, e], "deadline cut is oldest-first FIFO");
        assert!(b.is_empty());
    }

    /// When the queue exceeds the target at a deadline check, the Size cut
    /// wins and the remainder keeps its own (younger) deadline: a fresh
    /// request left behind must not flush until its own max_wait passes.
    #[test]
    fn size_cut_takes_priority_and_remainder_keeps_own_deadline() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 2,
            max_wait: Duration::from_millis(5),
        });
        let now = t0();
        b.push(0, now);
        b.push(1, now);
        b.push(2, now + Duration::from_millis(6));
        let first = b.try_flush(now + Duration::from_millis(6)).unwrap();
        assert_eq!(first.reason, FlushReason::Size);
        assert_eq!(first.items.len(), 2);
        // the interleaved push is younger than max_wait: no flush yet
        assert!(b.try_flush(now + Duration::from_millis(7)).is_none());
        let second = b.try_flush(now + Duration::from_millis(12)).unwrap();
        assert_eq!(second.reason, FlushReason::Deadline);
        assert_eq!(second.items[0].id, 2);
    }

    /// Shutdown drains the whole backlog as `Drain` batches of at most
    /// `target_batch`, in FIFO order, then reports empty — the contract
    /// the server (and the replica tier) rely on when the request channel
    /// closes.
    #[test]
    fn shutdown_drain_empties_backlog_in_target_sized_batches() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            target_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        let now = t0();
        for i in 0..5 {
            b.push(i, now);
        }
        let mut seen = Vec::new();
        let mut sizes = Vec::new();
        while let Some(batch) = b.drain_all() {
            assert_eq!(batch.reason, FlushReason::Drain);
            sizes.push(batch.items.len());
            seen.extend(batch.items.iter().map(|p| p.payload));
        }
        assert_eq!(sizes, vec![2, 2, 1]);
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        assert!(b.is_empty() && b.drain_all().is_none());
    }
}
