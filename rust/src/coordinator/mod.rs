//! L3 serving coordinator: request queue → dynamic batcher → tile
//! scheduler → PJRT (or native) execution, with latency/throughput
//! metrics.
//!
//! The paper's system contribution is the crossbar datapath, so the
//! coordinator is shaped like an IMC inference server (ISAAC/PUMA mold):
//!
//! * [`batcher`] — size-or-deadline dynamic batching onto the AOT-compiled
//!   batch variants;
//! * [`scheduler`] — weight-stationary tile scheduler: tracks per-tile
//!   busy time using the Fig. 8 pipeline model and charges energy per
//!   layer execution, so every served request also produces *simulated
//!   hardware* latency/energy (the bridge between serving and Fig. 9);
//! * [`server`] — the tokio run loop tying queue, batcher, executor and
//!   metrics together;
//! * [`metrics`] — wall-clock latency percentiles, throughput, and the
//!   simulated hardware counters.

pub mod batcher;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::Metrics;
pub use scheduler::TileScheduler;
pub use server::{ServeConfig, Server};
