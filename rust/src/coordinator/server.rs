//! The serving run loop: queue → dynamic batcher → executor → replies.
//!
//! Implemented on std threads + channels (this environment is offline, no
//! tokio): the server thread owns the batcher and executor; clients submit
//! [`Request`]s over an mpsc channel and receive [`Reply`]s on per-request
//! oneshot channels.  The executor is pluggable: the PJRT engine (AOT
//! artifacts, the production path), the native crossbar model
//! (hardware-exact, used for validation and sensitivity), or a mock.

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::scheduler::TileScheduler;
use crate::model::NativeModel;
use crate::runtime::Engine;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A single inference request: one image (flattened NHWC) + reply slot.
pub struct Request {
    pub image: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
}

/// One reply per request.
///
/// # The `result` error contract (fail-loud batches)
///
/// `result` is `Err(message)` when the executor failed on the batch this
/// request rode in.  The server's `execute_batch` guarantees, for every
/// submitted [`Request`], exactly one of:
///
/// * `Ok(logits)` — the batch executed; `logits` is this request's slice
///   of the batch output, or
/// * `Err(message)` — the executor kept failing through the configured
///   retry budget; **every** member of the failed batch receives the same
///   message, and the batch is *not* silently re-queued beyond that.
///
/// A reply channel is therefore never dropped with a pending `recv()` —
/// clients can block on [`std::sync::mpsc::Receiver::recv`] without a
/// timeout (the pre-PR-1 behaviour dropped the channel on executor error,
/// deadlocking clients).  Transient failures can be absorbed server-side
/// with [`ServeConfig::max_retries`] (bounded in-place resubmit, default
/// off); anything beyond that budget is the caller's policy decision:
/// inspect the `Err` and resubmit if desired.  [`Reply::logits`] converts
/// the error side into `anyhow::Error` for `?`-style call sites.
#[derive(Debug, Clone)]
pub struct Reply {
    /// Per-request logits, or the executor failure message (see the
    /// error contract above).
    pub result: Result<Vec<f32>, String>,
    /// Wall-clock time from batch execution start to reply.
    pub latency: Duration,
    /// Size of the batch this request was executed in.
    pub batch: usize,
    /// `DEGRADED` flag: the replica tier served this request in brown-out
    /// mode — on the short-sampling degraded converters — to shed cost
    /// under overload.  The logits are real (not an error) but were
    /// computed at reduced sampling fidelity; always `false` on the
    /// single-server path.
    pub degraded: bool,
}

impl Reply {
    /// The logits, or the executor failure as an error.
    pub fn logits(&self) -> crate::Result<&[f32]> {
        match &self.result {
            Ok(l) => Ok(l),
            Err(e) => Err(anyhow::anyhow!("executor error: {e}")),
        }
    }
}

/// Batch executor abstraction.
pub trait Executor {
    /// Run `batch` images (concatenated) and return per-image logits.
    fn execute(&self, images: &[f32], batch: usize, seed: u32) -> crate::Result<Vec<f32>>;
    fn classes(&self) -> usize;
    fn image_elems(&self) -> usize;
    /// Preferred max batch.
    fn max_batch(&self) -> usize;
}

/// Pad a non-variant batch of `batch` images up to `target` rows by
/// replicating the last image (the padding contract documented on the
/// batcher).  Callers truncate the logits back to `batch` rows; per-row
/// stochastic draws are keyed by row index, so the real rows are
/// unaffected by what rides in the pad slots.
pub fn replicate_pad(images: &[f32], batch: usize, target: usize, elems: usize) -> Vec<f32> {
    assert!(batch >= 1 && batch <= target, "pad {batch} -> {target}");
    assert_eq!(images.len(), batch * elems);
    let mut padded = Vec::with_capacity(target * elems);
    padded.extend_from_slice(images);
    let last = &images[(batch - 1) * elems..batch * elems];
    for _ in batch..target {
        padded.extend_from_slice(last);
    }
    padded
}

/// PJRT-backed executor (the production path).
pub struct PjrtExecutor {
    pub engine: Engine,
    pub classes: usize,
    pub image_elems: usize,
}

impl Executor for PjrtExecutor {
    fn execute(&self, images: &[f32], batch: usize, seed: u32) -> crate::Result<Vec<f32>> {
        let handle = self
            .engine
            .best_model_for(batch)
            .ok_or_else(|| anyhow::anyhow!("no compiled model"))?;
        let hb = handle.batch;
        if hb == batch {
            return handle.infer(images, seed);
        }
        if hb > batch {
            // pad by replication to the compiled variant, truncate logits
            let padded = replicate_pad(images, batch, hb, self.image_elems);
            let out = handle.infer(&padded, seed)?;
            return Ok(out[..batch * self.classes].to_vec());
        }
        // hb < batch: run in chunks
        let mut out = Vec::with_capacity(batch * self.classes);
        let mut i = 0;
        while i < batch {
            let n = hb.min(batch - i);
            let chunk = &images[i * self.image_elems..(i + n) * self.image_elems];
            let sub = self.execute(chunk, n, seed.wrapping_add(i as u32))?;
            out.extend(sub);
            i += n;
        }
        Ok(out)
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn image_elems(&self) -> usize {
        self.image_elems
    }

    fn max_batch(&self) -> usize {
        self.engine.batch_sizes().last().copied().unwrap_or(1)
    }
}

/// Native crossbar-model executor (validation path).
pub struct NativeExecutor {
    pub model: NativeModel,
}

impl Executor for NativeExecutor {
    fn execute(&self, images: &[f32], batch: usize, seed: u32) -> crate::Result<Vec<f32>> {
        Ok(self.model.forward(images, batch, seed))
    }

    fn classes(&self) -> usize {
        self.model.num_classes
    }

    fn image_elems(&self) -> usize {
        self.model.image_size * self.model.image_size * self.model.in_channels
    }

    fn max_batch(&self) -> usize {
        // the native model chunks internally per forward pass, so the
        // configured `BatcherConfig::target_batch` is the only cap —
        // returning usize::MAX lets `Server::run`'s min() pass it through
        // (a hardcoded 8 here used to silently clamp `--target-batch`)
        usize::MAX
    }
}

/// Typed rejection of a nonsensical serving configuration, raised by
/// [`ServeConfig::validate`] / `ReplicaConfig::validate` at parse time —
/// a zero queue depth or zero-replica tier would otherwise misbehave at
/// runtime (reject every request, or panic deep in the dispatch loop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigError {
    /// `target_batch` of 0: no batch could ever form.
    ZeroTargetBatch,
    /// A replica tier with no shards.
    ZeroReplicas,
    /// `queue_depth` of 0: admission control would reject every request.
    ZeroQueueDepth,
    /// A deadline of zero (or negative, saturated to zero at parse):
    /// every request would expire before execution.
    ZeroDeadline,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroTargetBatch => {
                write!(f, "invalid config: target_batch must be >= 1")
            }
            ConfigError::ZeroReplicas => {
                write!(f, "invalid config: replicas must be >= 1")
            }
            ConfigError::ZeroQueueDepth => write!(
                f,
                "invalid config: queue_depth must be >= 1 (0 would reject every request)"
            ),
            ConfigError::ZeroDeadline => write!(
                f,
                "invalid config: deadline must be positive (every request would expire)"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

#[derive(Clone)]
pub struct ServeConfig {
    pub batcher: BatcherConfig,
    pub seed: u32,
    /// Bounded retry of transiently failing batches (the ROADMAP
    /// retry/requeue policy): when the executor returns `Err`, the batch
    /// is re-executed in place up to `max_retries` more times (same
    /// images, same seed — the failure contract is about infrastructure
    /// hiccups, not stochastic draws) before the whole batch fails
    /// loudly per the [`Reply`] error contract.  `0` (the default)
    /// preserves the strict fail-loud behaviour; retries are counted in
    /// [`super::metrics::Metrics::retries`].
    pub max_retries: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), seed: 0, max_retries: 0 }
    }
}

impl ServeConfig {
    /// Fail-loud validation, called by the CLI/harness right after
    /// parsing (the constructor signature is unchanged — a literal can
    /// still build any config, e.g. for tests probing edge behaviour).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.batcher.target_batch == 0 {
            return Err(ConfigError::ZeroTargetBatch);
        }
        Ok(())
    }
}

/// The server: owns the executor, optional tile scheduler (simulated
/// hardware accounting) and metrics.
pub struct Server {
    executor: Box<dyn Executor>,
    cfg: ServeConfig,
    pub metrics: Arc<Mutex<Metrics>>,
    scheduler: Option<Arc<Mutex<TileScheduler>>>,
}

impl Server {
    pub fn new(executor: Box<dyn Executor>, cfg: ServeConfig) -> Self {
        Self {
            executor,
            cfg,
            metrics: Arc::new(Mutex::new(Metrics::new())),
            scheduler: None,
        }
    }

    /// Attach a tile scheduler so every executed batch also charges
    /// simulated IMC time/energy.
    pub fn with_scheduler(mut self, sched: TileScheduler) -> Self {
        self.scheduler = Some(Arc::new(Mutex::new(sched)));
        self
    }

    fn execute_batch(&self, batch: Batch<Request>, seed: u32) {
        let n = batch.items.len();
        let classes = self.executor.classes();
        let mut images = Vec::with_capacity(n * self.executor.image_elems());
        for p in &batch.items {
            images.extend_from_slice(&p.payload.image);
        }
        let t0 = Instant::now();
        let mut attempt = 0u32;
        let logits = loop {
            match self.executor.execute(&images, n, seed) {
                Ok(l) => break l,
                Err(e) if attempt < self.cfg.max_retries => {
                    // bounded in-place resubmit of the failed batch
                    // (transient-error policy; see ServeConfig::max_retries)
                    attempt += 1;
                    eprintln!(
                        "executor error (retry {attempt}/{}): {e}",
                        self.cfg.max_retries
                    );
                    self.metrics.lock().unwrap().retries += 1;
                }
                Err(e) => {
                    // fail the whole batch *loudly*: every pending request
                    // gets an error reply instead of a dropped channel
                    // (clients would otherwise block forever on recv()).
                    let msg = e.to_string();
                    eprintln!("executor error: {msg}");
                    let now = Instant::now();
                    for p in batch.items.into_iter() {
                        let _ = p.payload.reply.send(Reply {
                            result: Err(msg.clone()),
                            latency: now.duration_since(t0),
                            batch: n,
                            degraded: false,
                        });
                    }
                    return;
                }
            }
        };
        let now = Instant::now();

        if let Some(sched) = &self.scheduler {
            let mut s = sched.lock().unwrap();
            let arrival = s.horizon_ns;
            let r = s.schedule_batch(n, arrival);
            self.metrics.lock().unwrap().record_hw(r.energy_pj, r.span_ns);
        }

        let mut latencies = Vec::with_capacity(n);
        for (i, p) in batch.items.into_iter().enumerate() {
            let lat = now.duration_since(p.enqueued);
            latencies.push(lat);
            let _ = p.payload.reply.send(Reply {
                result: Ok(logits[i * classes..(i + 1) * classes].to_vec()),
                latency: now.duration_since(t0),
                batch: n,
                degraded: false,
            });
        }
        self.metrics.lock().unwrap().record_batch(n, &latencies);
    }

    /// Run loop: consume requests until the channel closes, then drain.
    ///
    /// PJRT handles are not `Send`, so the server runs on the thread that
    /// created the executor (typically main); clients submit from other
    /// threads via the channel.
    pub fn run(&self, rx: mpsc::Receiver<Request>) {
        let mut batcher = DynamicBatcher::new(BatcherConfig {
            target_batch: self
                .cfg
                .batcher
                .target_batch
                .min(self.executor.max_batch()),
            ..self.cfg.batcher
        });
        let mut seed = self.cfg.seed;
        let mut closed = false;
        while !closed {
            let now = Instant::now();
            if let Some(batch) = batcher.try_flush(now) {
                seed = seed.wrapping_add(1);
                self.execute_batch(batch, seed);
                continue;
            }
            let wait = batcher
                .next_deadline(now)
                .unwrap_or(Duration::from_millis(50));
            match rx.recv_timeout(wait) {
                Ok(req) => {
                    batcher.push(req, Instant::now());
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
            }
        }
        while let Some(batch) = batcher.drain_all() {
            seed = seed.wrapping_add(1);
            self.execute_batch(batch, seed);
        }
    }
}

/// Convenience client: submit every image of a test set through a running
/// server; returns the per-request reply receivers in submission order
/// (call `recv()` on each to wait for its [`Reply`]).
pub fn submit_all(
    tx: &mpsc::Sender<Request>,
    images: impl Iterator<Item = Vec<f32>>,
) -> Vec<mpsc::Receiver<Reply>> {
    let mut rxs = Vec::new();
    for image in images {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request { image, reply: rtx }).expect("server alive");
        rxs.push(rrx);
    }
    rxs
}

#[cfg(test)]
mod tests {
    use super::*;

    struct MockExec {
        classes: usize,
        elems: usize,
    }

    impl Executor for MockExec {
        fn execute(&self, _images: &[f32], batch: usize, _seed: u32) -> crate::Result<Vec<f32>> {
            Ok((0..batch * self.classes).map(|i| i as f32).collect())
        }
        fn classes(&self) -> usize {
            self.classes
        }
        fn image_elems(&self) -> usize {
            self.elems
        }
        fn max_batch(&self) -> usize {
            4
        }
    }

    #[test]
    fn serve_config_validation_rejects_zero_target_batch() {
        let mut cfg = ServeConfig::default();
        assert!(cfg.validate().is_ok(), "the default config is valid");
        cfg.batcher.target_batch = 0;
        assert_eq!(cfg.validate(), Err(ConfigError::ZeroTargetBatch));
        // the typed error renders a parse-time-worthy message
        let msg = ConfigError::ZeroTargetBatch.to_string();
        assert!(msg.contains("target_batch"), "{msg}");
    }

    #[test]
    fn serves_requests_end_to_end() {
        let server = Server::new(
            Box::new(MockExec { classes: 10, elems: 4 }),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                seed: 0,
                max_retries: 0,
            },
        );
        let (tx, rx) = mpsc::channel();
        // client on a side thread; server loop on this thread (the PJRT
        // production shape)
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, (0..10).map(|_| vec![0.0f32; 4]));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();

        let mut got = 0;
        for r in replies {
            let rep = r.recv().unwrap();
            assert_eq!(rep.logits().unwrap().len(), 10);
            got += 1;
        }
        assert_eq!(got, 10);
        let m = server.metrics.lock().unwrap().report();
        assert_eq!(m.requests, 10);
        assert!(m.batches >= 3); // 10 requests at batch ≤ 4
    }

    #[test]
    fn chunking_logic() {
        let e = MockExec { classes: 2, elems: 3 };
        let out = e.execute(&vec![0.0; 7 * 3], 7, 0).unwrap();
        assert_eq!(out.len(), 14);
    }

    /// Replication padding at non-variant batch sizes: real rows are
    /// copied verbatim, pad rows replicate the last image, and a batch
    /// already at the variant size is returned unchanged.
    #[test]
    fn replicate_pad_non_variant_sizes() {
        // 3 images of 2 elems → variant 4: pad row repeats image 2
        let imgs = [0.0, 0.1, 1.0, 1.1, 2.0, 2.1];
        let p = replicate_pad(&imgs, 3, 4, 2);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..6], &imgs);
        assert_eq!(&p[6..], &[2.0, 2.1]);

        // 5 → 8: three pad rows, all replicas of image 4
        let imgs: Vec<f32> = (0..5 * 3).map(|i| i as f32).collect();
        let p = replicate_pad(&imgs, 5, 8, 3);
        assert_eq!(p.len(), 24);
        assert_eq!(&p[..15], &imgs[..]);
        for r in 5..8 {
            assert_eq!(&p[r * 3..(r + 1) * 3], &imgs[12..15]);
        }

        // already at the variant size: identity
        let p = replicate_pad(&imgs, 5, 5, 3);
        assert_eq!(p, imgs);
    }

    /// Executor with no preferred batch cap (the NativeExecutor shape
    /// after the max_batch fix): `--target-batch` above the old hardcoded
    /// 8 must take effect end-to-end.
    struct UncappedExec;

    impl Executor for UncappedExec {
        fn execute(&self, _images: &[f32], batch: usize, _seed: u32) -> crate::Result<Vec<f32>> {
            Ok(vec![0.0; batch * 10])
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    /// Regression (ISSUE 6 satellite): `NativeExecutor::max_batch()` used
    /// to hardcode 8, so a `target_batch` of 16 was silently clamped and
    /// no batch ever exceeded 8 requests.  With an uncapped executor, 32
    /// pre-queued requests must flush as full batches of 16.
    #[test]
    fn target_batch_above_eight_takes_effect() {
        let server = Server::new(
            Box::new(UncappedExec),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 16,
                    max_wait: Duration::from_secs(10),
                },
                seed: 0,
                max_retries: 0,
            },
        );
        let (tx, rx) = mpsc::channel();
        // pre-queue all 32 requests before the server starts so the size
        // trigger (not the deadline) cuts every batch
        let replies = submit_all(&tx, (0..32).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for r in replies {
            let rep = r.recv().unwrap();
            assert_eq!(rep.batch, 16, "batches must reach the configured 16");
        }
        let m = server.metrics.lock().unwrap().report();
        assert_eq!(m.requests, 32);
        assert_eq!(m.batches, 2, "32 requests at target 16 → 2 batches");
    }

    struct FailingExec;

    impl Executor for FailingExec {
        fn execute(&self, _images: &[f32], _batch: usize, _seed: u32) -> crate::Result<Vec<f32>> {
            Err(anyhow::anyhow!("injected executor failure"))
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            4
        }
    }

    /// Executor that fails its first `fail_first` batches, then recovers —
    /// the transient-error shape (e.g. a PJRT hiccup) behind the ROADMAP
    /// retry/requeue question.
    struct FlakyExec {
        calls: std::sync::atomic::AtomicUsize,
        fail_first: usize,
    }

    impl Executor for FlakyExec {
        fn execute(&self, _images: &[f32], batch: usize, _seed: u32) -> crate::Result<Vec<f32>> {
            let call = self
                .calls
                .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if call < self.fail_first {
                anyhow::bail!("transient executor failure #{call}");
            }
            Ok((0..batch * 10).map(|i| i as f32).collect())
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            4
        }
    }

    /// Documents the `Reply.result` retry-worthiness contract with
    /// evidence (ROADMAP): a *transient* executor error fails exactly the
    /// batch it hit — every member gets a per-reply `Err` carrying the
    /// message — and does NOT poison the server loop: subsequent batches
    /// execute normally and their requests get `Ok` logits.  A caller can
    /// therefore implement retry by resubmitting only the `Err` replies.
    #[test]
    fn transient_executor_error_does_not_poison_later_batches() {
        let server = Server::new(
            Box::new(FlakyExec {
                calls: std::sync::atomic::AtomicUsize::new(0),
                fail_first: 1,
            }),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                seed: 0,
                max_retries: 0,
            },
        );
        let (tx, rx) = mpsc::channel();
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, (0..12).map(|_| vec![0.0f32; 4]));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();
        assert_eq!(replies.len(), 12);

        let mut errs = 0usize;
        let mut oks = 0usize;
        let mut seen_ok_after_err = false;
        for r in replies {
            // every reply is delivered (never a dropped channel), failed
            // batch or not
            let rep = r.recv().expect("reply delivered, not abandoned");
            match &rep.result {
                Err(e) => {
                    assert!(e.contains("transient executor failure"), "{e}");
                    errs += 1;
                }
                Ok(logits) => {
                    assert_eq!(logits.len(), 10);
                    if errs > 0 {
                        seen_ok_after_err = true;
                    }
                    oks += 1;
                }
            }
        }
        // exactly the first batch failed (≤ target_batch requests — the
        // batcher may flush early under scheduling jitter); every other
        // batch executed normally
        assert!(
            (1..=4).contains(&errs),
            "exactly one batch (1..=4 requests) fails loudly, got {errs}"
        );
        assert_eq!(oks, 12 - errs, "later batches are not poisoned");
        assert!(
            seen_ok_after_err,
            "successful batches must follow the failed one in submission order"
        );
    }

    /// The bounded retry policy: with `max_retries >=` the transient
    /// failure count, a flaky executor eventually succeeds and **every**
    /// request gets `Ok` logits — no error replies, retries counted in
    /// the metrics.
    #[test]
    fn transient_failures_are_retried_to_success() {
        let server = Server::new(
            Box::new(FlakyExec {
                calls: std::sync::atomic::AtomicUsize::new(0),
                fail_first: 2,
            }),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                seed: 0,
                max_retries: 3,
            },
        );
        let (tx, rx) = mpsc::channel();
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, (0..8).map(|_| vec![0.0f32; 4]));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();
        assert_eq!(replies.len(), 8);
        for r in replies {
            let rep = r.recv().expect("reply delivered");
            assert_eq!(
                rep.result.expect("retried to success").len(),
                10,
                "every request succeeds after bounded retries"
            );
        }
        let m = server.metrics.lock().unwrap().report();
        assert_eq!(m.retries, 2, "both transient failures were retried");
        assert_eq!(m.requests, 8);
    }

    /// A permanently failing executor still fails loudly: the retry cap
    /// is exhausted, every member of the batch receives the error reply,
    /// and exactly `max_retries` resubmits are charged per batch.
    #[test]
    fn permanent_failures_exhaust_retries_and_fail_loudly() {
        let server = Server::new(
            Box::new(FailingExec),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                seed: 0,
                max_retries: 2,
            },
        );
        let (tx, rx) = mpsc::channel();
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, (0..4).map(|_| vec![0.0f32; 4]));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();
        for r in replies {
            let rep = r.recv().expect("reply delivered, not abandoned");
            let err = rep.result.expect_err("executor is permanently down");
            assert!(err.contains("injected executor failure"), "{err}");
        }
        let m = server.metrics.lock().unwrap().report();
        // every failed batch burned exactly max_retries resubmits (the
        // batcher may have split the 4 requests into 1..=4 batches)
        assert!(m.retries >= 2, "retry cap exercised: {}", m.retries);
        assert_eq!(m.retries % 2, 0, "2 retries per failed batch");
        assert!(m.retries <= 8, "at most 4 batches × 2 retries");
    }

    /// Regression: a failing executor used to silently drop every pending
    /// Reply, leaving clients blocked forever on `recv()`.  Now each
    /// request of the failed batch receives an error reply.
    #[test]
    fn failed_batch_replies_error_to_every_request() {
        let server = Server::new(
            Box::new(FailingExec),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                seed: 0,
                max_retries: 0,
            },
        );
        let (tx, rx) = mpsc::channel();
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, (0..10).map(|_| vec![0.0f32; 4]));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();
        assert_eq!(replies.len(), 10);
        for r in replies {
            // recv() must succeed — the reply channel was not dropped —
            // and carry the executor error
            let rep = r.recv().expect("reply delivered, not abandoned");
            let err = rep.result.expect_err("executor failed");
            assert!(err.contains("injected executor failure"), "{err}");
            assert!(rep.logits().is_err());
        }
    }
}
