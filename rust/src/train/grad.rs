//! Layer-level backward math of the §3.3 training reduction.
//!
//! # The digit-STE VJP of one crossbar MVM
//!
//! The expected forward of one layer is
//!
//! ```text
//! out[b,c] = Σ_{k,i,j} (sa_i·sw_j / (lev·K)) · T(ps[b,k,i,j,c])
//! ps[b,k,i,j,c] = (1/r_arr) Σ_r x_i(a_q[b,r]) · t_j(w_q[r,c])
//! ```
//!
//! with `T` the converter's surrogate transfer curve
//! ([`crate::imc::PsSurrogate`]), `sa_i = 2^{i·As}`, `sw_j = 2^{j·Ws}`,
//! `lev = La·Lw`, `La = 2^Ab − 1`, `Lw = 2^Wb − 1`.  The signed digits
//! obey the exact recombination identity `Σ_i sa_i·x_i = La·a_q`; the
//! straight-through convention allocates the slope across digits
//! proportionally to significance, which (uniquely) gives every stream
//! the *same* slope `∂x_i/∂a_q = 2^As − 1` (and `∂t_j/∂w_q = 2^Ws − 1`):
//! the allocation weights `sa_i / Σ_i' sa_i'` cancel the per-digit scale
//! and the total reproduces the identity's `La`.  With `D = T'` evaluated
//! at the *captured* per-slice PS, the VJP collapses to
//!
//! ```text
//! ∂L/∂a_q[b,r∈k] = (2^As−1)/(lev·K·r_arr) · Σ_c g[b,c] ·
//!                  Σ_j t_j[r,c] · (Σ_i sa_i·sw_j·D[b,k,i,j,c])
//! ∂L/∂w_q[r∈k,c] = (2^Ws−1)/(lev·K·r_arr) · Σ_b g[b,c] ·
//!                  Σ_i x_i[b,r] · (Σ_j sa_i·sw_j·D[b,k,i,j,c])
//! ```
//!
//! which reduces exactly to the paper's collapsed Eq. 5 surrogate
//! (`(1/K)·T(α·a_q@w_q/r_arr)` with STE quantizers) whenever the
//! per-slice gains are uniform — e.g. the ideal readout, or the tanh
//! family in its linear region — and generalizes it with per-slice
//! saturation awareness otherwise.  `python/compile/gen_grad_golden.py`
//! implements the same equations in numpy; `rust/tests/grad_equiv.rs`
//! pins both sides within 1e-5.

use crate::imc::{quant, PsConvert, StoxConfig};

/// Gradients of one crossbar MVM: wrt the im2col patches (before the
/// caller's clip STE) and wrt the *normalized* weights (before the
/// caller's `1/scale` chain through weight normalization).
pub struct MatmulGrads {
    /// ∂L/∂patches, `[batch × M]`.
    pub d_patches: Vec<f32>,
    /// ∂L/∂w_normalized, `[M × N]`.
    pub d_w: Vec<f32>,
}

/// Backward of one crossbar-mapped MVM under the §3.3 surrogate.
///
/// * `patches` — the activations fed forward (`[batch × m]`; values are
///   quantizer-clamped on the forward, so pre- or post-clip values give
///   identical digits);
/// * `wn` — normalized weights (`[m × n]`, in `[-1, 1]`);
/// * `ps` — the captured normalized per-slice PS in the canonical
///   `[b][k][i][j][col]` layout of [`crate::imc::StoxMvm::run_capture`];
/// * `g` — upstream `∂L/∂out`, `[batch × n]`.
///
/// The converter's [`PsConvert::grad_slice_at`] supplies the per-slice
/// surrogate derivative, so every registry converter — including ones
/// with significance-aware schedules — trains through the same path.
#[allow(clippy::too_many_arguments)]
pub fn stox_matmul_backward(
    patches: &[f32],
    wn: &[f32],
    batch: usize,
    m: usize,
    n: usize,
    cfg: &StoxConfig,
    conv: &dyn PsConvert,
    ps: &[f32],
    g: &[f32],
) -> MatmulGrads {
    let (i_n, j_n) = (cfg.n_streams(), cfg.n_slices());
    let k_n = cfg.n_arrs(m);
    debug_assert_eq!(patches.len(), batch * m);
    debug_assert_eq!(wn.len(), m * n);
    debug_assert_eq!(g.len(), batch * n);
    debug_assert_eq!(ps.len(), batch * k_n * i_n * j_n * n);

    let la = ((1u64 << cfg.a_bits) - 1) as f32;
    let lw = ((1u64 << cfg.w_bits) - 1) as f32;
    let lev = la * lw;
    // digit-STE slopes (module doc): uniform across streams/slices
    let slope_a = ((1u64 << cfg.a_stream_bits) - 1) as f32;
    let slope_w = ((1u64 << cfg.w_slice_bits) - 1) as f32;
    let denom = lev * k_n as f32 * cfg.r_arr as f32;
    let ca = slope_a / denom;
    let cw = slope_w / denom;
    let sa = quant::digit_scales(cfg.a_bits, cfg.a_stream_bits);
    let sw = quant::digit_scales(cfg.w_bits, cfg.w_slice_bits);

    // weight-slice digits, recomputed once from wn: [r][c][j]
    let mut tdig = vec![0i32; m * n * j_n];
    let mut dj = vec![0i32; j_n];
    for r in 0..m {
        for c in 0..n {
            let u = quant::quantize_unit(wn[r * n + c], cfg.w_bits);
            quant::signed_digits(u, cfg.w_bits, cfg.w_slice_bits, &mut dj);
            for (j, &d) in dj.iter().enumerate() {
                tdig[(r * n + c) * j_n + j] = d;
            }
        }
    }

    let mut d_patches = vec![0.0f32; batch * m];
    let mut d_w = vec![0.0f32; m * n];
    let mut dslice = vec![0.0f32; n];
    // significance-weighted surrogate gains of one (b, k) group:
    // aw[j][c] = Σ_i sa_i·sw_j·D,  ww[i][c] = Σ_j sa_i·sw_j·D
    let mut aw = vec![0.0f32; j_n * n];
    let mut ww = vec![0.0f32; i_n * n];
    let mut di = vec![0i32; i_n];

    for b in 0..batch {
        for k in 0..k_n {
            let row0 = k * cfg.r_arr;
            let rows = (m - row0).min(cfg.r_arr);
            aw.iter_mut().for_each(|v| *v = 0.0);
            ww.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..i_n {
                for j in 0..j_n {
                    let off = (((b * k_n + k) * i_n + i) * j_n + j) * n;
                    conv.grad_slice_at(i, j, &ps[off..off + n], &mut dslice);
                    let s = sa[i] * sw[j];
                    for (c, &d) in dslice.iter().enumerate() {
                        let v = s * d;
                        aw[j * n + c] += v;
                        ww[i * n + c] += v;
                    }
                }
            }
            for rr in 0..rows {
                let r = row0 + rr;
                // ∂L/∂patches[b, r]
                let mut acc = 0.0f32;
                for c in 0..n {
                    let gc = g[b * n + c];
                    if gc == 0.0 {
                        continue;
                    }
                    let mut t = 0.0f32;
                    for j in 0..j_n {
                        t += aw[j * n + c] * tdig[(r * n + c) * j_n + j] as f32;
                    }
                    acc += gc * t;
                }
                d_patches[b * m + r] = ca * acc;
                // ∂L/∂wn[r, c]
                let u = quant::quantize_unit(patches[b * m + r], cfg.a_bits);
                quant::signed_digits(u, cfg.a_bits, cfg.a_stream_bits, &mut di);
                for c in 0..n {
                    let gc = g[b * n + c];
                    if gc == 0.0 {
                        continue;
                    }
                    let mut x = 0.0f32;
                    for i in 0..i_n {
                        x += ww[i * n + c] * di[i] as f32;
                    }
                    d_w[r * n + c] += cw * gc * x;
                }
            }
        }
    }
    MatmulGrads { d_patches, d_w }
}

/// Straight-through clip: zero the gradient wherever the forward input
/// fell outside `[-1, 1]` (the `act_clip` + quantizer STE of Eq. 5; the
/// boundary is inclusive, matching `jnp.clip`'s VJP).
pub fn apply_clip_ste(d_x: &mut [f32], x: &[f32]) {
    debug_assert_eq!(d_x.len(), x.len());
    for (d, &v) in d_x.iter_mut().zip(x) {
        if v.abs() > 1.0 {
            *d = 0.0;
        }
    }
}

/// Adjoint of [`crate::imc::im2col`]: scatter patch gradients back onto
/// the input image (`+=` over overlapping taps; out-of-bounds taps drop).
#[allow(clippy::too_many_arguments)]
pub fn im2col_backward(
    d_patches: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
) -> Vec<f32> {
    let pad = (kh - 1) / 2;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w_ + 2 * pad - kw) / stride + 1;
    let m = kh * kw * c;
    debug_assert_eq!(d_patches.len(), b * ho * wo * m);
    let mut dx = vec![0.0f32; b * h * w_ * c];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let src0 = ((bi * ho + oy) * wo + ox) * m;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w_ as isize {
                            continue;
                        }
                        let dst0 = ((bi * h + iy as usize) * w_ + ix as usize) * c;
                        let src = src0 + (ky * kw + kx) * c;
                        for ci in 0..c {
                            dx[dst0 + ci] += d_patches[src + ci];
                        }
                    }
                }
            }
        }
    }
    dx
}

/// Backward of the full-precision first-layer conv
/// ([`crate::model::infer::fp_conv2d`]): plain linear adjoints.
#[allow(clippy::too_many_arguments)]
pub fn fp_conv2d_backward(
    x: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    cin: usize,
    weights: &[f32], // [kh,kw,cin,cout]
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    g: &[f32], // [b,ho,wo,cout]
) -> (Vec<f32>, Vec<f32>) {
    let pad = (kh - 1) / 2;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w_ + 2 * pad - kw) / stride + 1;
    debug_assert_eq!(g.len(), b * ho * wo * cout);
    let mut dx = vec![0.0f32; b * h * w_ * cin];
    let mut dw = vec![0.0f32; kh * kw * cin * cout];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                let gr = &g[((bi * ho + oy) * wo + ox) * cout..][..cout];
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w_ as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w_ + ix as usize) * cin;
                        for ci in 0..cin {
                            let wbase = ((ky * kw + kx) * cin + ci) * cout;
                            let xv = x[src + ci];
                            let mut acc = 0.0f32;
                            for (co, &gv) in gr.iter().enumerate() {
                                acc += gv * weights[wbase + co];
                                dw[wbase + co] += gv * xv;
                            }
                            dx[src + ci] += acc;
                        }
                    }
                }
            }
        }
    }
    (dx, dw)
}

/// Saved context of one train-mode BatchNorm application.
pub struct BnTape {
    /// Normalized activations `(x − µ)·inv_std`.
    pub xhat: Vec<f32>,
    /// Per-channel `1/√(var + 1e-5)`.
    pub inv_std: Vec<f32>,
    /// Elements per channel (the normalization count N).
    pub count: usize,
}

/// Train-mode BatchNorm: normalize by batch statistics, update running
/// stats with momentum (stop-gradient, like `stox_layers.batch_norm`).
pub fn bn_forward_train(
    x: &[f32],
    channels: usize,
    gamma: &[f32],
    beta: &[f32],
    running_mean: &mut [f32],
    running_var: &mut [f32],
    momentum: f32,
) -> (Vec<f32>, BnTape) {
    let count = x.len() / channels;
    let mut mean = vec![0.0f64; channels];
    for (i, &v) in x.iter().enumerate() {
        mean[i % channels] += v as f64;
    }
    for mu in mean.iter_mut() {
        *mu /= count as f64;
    }
    let mut var = vec![0.0f64; channels];
    for (i, &v) in x.iter().enumerate() {
        let d = v as f64 - mean[i % channels];
        var[i % channels] += d * d;
    }
    for vv in var.iter_mut() {
        *vv /= count as f64;
    }
    let inv_std: Vec<f32> =
        var.iter().map(|&v| 1.0 / ((v as f32) + 1e-5).sqrt()).collect();
    let mut xhat = vec![0.0f32; x.len()];
    let mut y = vec![0.0f32; x.len()];
    for (i, &v) in x.iter().enumerate() {
        let c = i % channels;
        let hn = (v - mean[c] as f32) * inv_std[c];
        xhat[i] = hn;
        y[i] = hn * gamma[c] + beta[c];
    }
    for c in 0..channels {
        running_mean[c] = momentum * running_mean[c] + (1.0 - momentum) * mean[c] as f32;
        running_var[c] = momentum * running_var[c] + (1.0 - momentum) * var[c] as f32;
    }
    (y, BnTape { xhat, inv_std, count })
}

/// Standard train-mode BatchNorm backward (running stats are
/// stop-gradient): returns `(∂L/∂x, ∂L/∂γ, ∂L/∂β)`.
pub fn bn_backward(
    tape: &BnTape,
    gamma: &[f32],
    gy: &[f32],
    channels: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let count = tape.count as f32;
    let mut dbeta = vec![0.0f32; channels];
    let mut dgamma = vec![0.0f32; channels];
    for (i, &gv) in gy.iter().enumerate() {
        let c = i % channels;
        dbeta[c] += gv;
        dgamma[c] += gv * tape.xhat[i];
    }
    let mut gx = vec![0.0f32; gy.len()];
    for (i, &gv) in gy.iter().enumerate() {
        let c = i % channels;
        gx[i] = gamma[c] * tape.inv_std[c] / count
            * (count * gv - dbeta[c] - tape.xhat[i] * dgamma[c]);
    }
    (gx, dgamma, dbeta)
}

/// Softmax cross-entropy head: mean loss over the batch and its exact
/// gradient `(softmax − onehot)/batch`.
pub fn softmax_ce(
    logits: &[f32],
    labels: &[i32],
    batch: usize,
    classes: usize,
) -> (f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), batch * classes);
    let mut dlogits = vec![0.0f32; batch * classes];
    let mut loss = 0.0f64;
    for bi in 0..batch {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0.0f32;
        for &v in row {
            denom += (v - mx).exp();
        }
        let label = labels[bi] as usize;
        loss += (denom.ln() - (row[label] - mx)) as f64;
        for c in 0..classes {
            let p = (row[c] - mx).exp() / denom;
            dlogits[bi * classes + c] =
                (p - if c == label { 1.0 } else { 0.0 }) / batch as f32;
        }
    }
    ((loss / batch as f64) as f32, dlogits)
}

/// SGD with momentum and weight decay, the `train.py` update:
/// `v ← µ·v + g + wd·p`, `p ← p − lr·v`.
pub fn sgd_update(
    p: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    momentum: f32,
    weight_decay: f32,
) {
    debug_assert_eq!(p.len(), v.len());
    debug_assert_eq!(p.len(), g.len());
    for ((pi, vi), &gi) in p.iter_mut().zip(v.iter_mut()).zip(g) {
        let vn = momentum * *vi + gi + weight_decay * *pi;
        *vi = vn;
        *pi -= lr * vn;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imc::{im2col, PsConverterSpec, StoxConfig, StoxMvm};
    use crate::stats::rng::CounterRng;

    fn rand_vec(n: usize, seed: u32, lo: f32, hi: f32) -> Vec<f32> {
        let rng = CounterRng::new(seed);
        (0..n).map(|i| rng.uniform_in(i as u32, lo, hi)).collect()
    }

    fn dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    /// <im2col(x), P> == <x, im2col_backward(P)> — exact adjointness.
    #[test]
    fn im2col_backward_is_adjoint() {
        let (b, h, w, c) = (2usize, 5usize, 4usize, 3usize);
        for (kh, stride) in [(3usize, 1usize), (3, 2), (1, 1)] {
            let x = rand_vec(b * h * w * c, 1, -1.0, 1.0);
            let (px, ho, wo) = im2col(&x, b, h, w, c, kh, kh, stride);
            let p = rand_vec(b * ho * wo * kh * kh * c, 2, -1.0, 1.0);
            let dx = im2col_backward(&p, b, h, w, c, kh, kh, stride);
            let lhs = dot(&px, &p);
            let rhs = dot(&x, &dx);
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "kh {kh} stride {stride}: {lhs} vs {rhs}"
            );
        }
    }

    /// fp conv backward matches central finite differences of the conv.
    #[test]
    fn fp_conv_backward_matches_finite_difference() {
        use crate::model::infer::fp_conv2d;
        let (b, h, w, cin, cout) = (1usize, 4usize, 4usize, 2usize, 3usize);
        let x = rand_vec(b * h * w * cin, 3, -1.0, 1.0);
        let wt = rand_vec(3 * 3 * cin * cout, 4, -0.5, 0.5);
        let (out, ho, wo) = fp_conv2d(&x, b, h, w, cin, &wt, 3, 3, cout, 1);
        let g = rand_vec(out.len(), 5, -1.0, 1.0);
        let (dx, dw) = fp_conv2d_backward(&x, b, h, w, cin, &wt, 3, 3, cout, 1, &g);
        let _ = (ho, wo);
        let eps = 1e-3f32;
        let loss = |xv: &[f32], wv: &[f32]| -> f64 {
            let (o, _, _) = fp_conv2d(xv, b, h, w, cin, wv, 3, 3, cout, 1);
            dot(&o, &g)
        };
        for idx in [0usize, 7, x.len() - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (loss(&xp, &wt) - loss(&xm, &wt)) / (2.0 * eps as f64);
            assert!((fd - dx[idx] as f64).abs() < 1e-2, "dx[{idx}]: {fd} vs {}", dx[idx]);
        }
        for idx in [0usize, 11, wt.len() - 1] {
            let mut wp = wt.clone();
            wp[idx] += eps;
            let mut wm = wt.clone();
            wm[idx] -= eps;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!((fd - dw[idx] as f64).abs() < 1e-2, "dw[{idx}]: {fd} vs {}", dw[idx]);
        }
    }

    /// BN backward matches finite differences of the train-mode forward
    /// (batch statistics included in the derivative).
    #[test]
    fn bn_backward_matches_finite_difference() {
        let channels = 3usize;
        let x = rand_vec(4 * channels, 6, -2.0, 2.0);
        let gamma = rand_vec(channels, 7, 0.5, 1.5);
        let beta = rand_vec(channels, 8, -0.5, 0.5);
        let g = rand_vec(x.len(), 9, -1.0, 1.0);
        let fwd = |xv: &[f32]| -> f64 {
            let mut rm = vec![0.0f32; channels];
            let mut rv = vec![1.0f32; channels];
            let (y, _) = bn_forward_train(xv, channels, &gamma, &beta, &mut rm, &mut rv, 0.9);
            dot(&y, &g)
        };
        let mut rm = vec![0.0f32; channels];
        let mut rv = vec![1.0f32; channels];
        let (_, tape) =
            bn_forward_train(&x, channels, &gamma, &beta, &mut rm, &mut rv, 0.9);
        let (gx, dgamma, dbeta) = bn_backward(&tape, &gamma, &g, channels);
        let eps = 1e-3f32;
        for idx in [0usize, 5, x.len() - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (fwd(&xp) - fwd(&xm)) / (2.0 * eps as f64);
            assert!((fd - gx[idx] as f64).abs() < 1e-2, "gx[{idx}]: {fd} vs {}", gx[idx]);
        }
        // dgamma/dbeta by construction: Σ g·xhat and Σ g per channel
        for c in 0..channels {
            let want_beta: f32 =
                g.iter().enumerate().filter(|(i, _)| i % channels == c).map(|(_, &v)| v).sum();
            assert!((dbeta[c] - want_beta).abs() < 1e-4);
        }
        assert_eq!(dgamma.len(), channels);
        // running stats moved toward the batch stats
        assert!(rm.iter().any(|&v| v != 0.0));
    }

    /// Softmax-CE: gradient rows sum to zero, loss drops along -grad.
    #[test]
    fn softmax_ce_gradient_sums_to_zero_and_descends() {
        let (batch, classes) = (3usize, 5usize);
        let logits = rand_vec(batch * classes, 10, -2.0, 2.0);
        let labels = vec![0i32, 3, 4];
        let (loss, dl) = softmax_ce(&logits, &labels, batch, classes);
        assert!(loss > 0.0);
        for bi in 0..batch {
            let s: f32 = dl[bi * classes..(bi + 1) * classes].iter().sum();
            assert!(s.abs() < 1e-5, "row {bi} sums to {s}");
        }
        let stepped: Vec<f32> =
            logits.iter().zip(&dl).map(|(&l, &d)| l - 0.1 * d).collect();
        let (loss2, _) = softmax_ce(&stepped, &labels, batch, classes);
        assert!(loss2 < loss, "{loss2} !< {loss}");
    }

    /// For the ideal converter the digit-STE VJP is the exact gradient of
    /// the collapsed linear forward `a_q@w_q/(K·r)` — check against finite
    /// differences of the *hardware* forward at interior (non-boundary)
    /// points, where quantizer staircases average out over the FD window.
    #[test]
    fn ideal_backward_matches_collapsed_linear_chain() {
        let (batch, m, n) = (2usize, 40usize, 5usize);
        let cfg = StoxConfig {
            a_bits: 8,
            w_bits: 8,
            w_slice_bits: 2,
            r_arr: 32,
            ..Default::default()
        };
        let a = rand_vec(batch * m, 11, -0.9, 0.9);
        let w = rand_vec(m * n, 12, -0.9, 0.9);
        let g = rand_vec(batch * n, 13, -1.0, 1.0);
        let spec: PsConverterSpec = "ideal".parse().unwrap();
        let conv = spec.build(&cfg).unwrap();
        let mvm = StoxMvm::program(&w, m, n, cfg).unwrap();
        let (_, ps) = mvm.run_capture(&a, batch, conv.as_ref(), 0);
        let grads =
            stox_matmul_backward(&a, &w, batch, m, n, &cfg, conv.as_ref(), &ps, &g);
        // exact collapsed gradient: d out[b,c]/d a[b,r] = w_q[r,c]/(K·r_arr)
        let k_n = cfg.n_arrs(m) as f32;
        for (idx, (&got, &av)) in grads.d_patches.iter().zip(&a).enumerate() {
            let b = idx / m;
            let r = idx % m;
            let mut want = 0.0f32;
            for c in 0..n {
                let u = quant::quantize_unit(w[r * n + c], cfg.w_bits);
                let wq = quant::dequantize_unit(u, cfg.w_bits);
                want += g[b * n + c] * wq / (k_n * cfg.r_arr as f32);
            }
            let _ = av;
            assert!(
                (got - want).abs() < 1e-5,
                "d_a[{idx}] {got} vs collapsed {want}"
            );
        }
        assert_eq!(grads.d_w.len(), m * n);
    }

    /// Clip STE zeroes exactly the out-of-range coordinates.
    #[test]
    fn clip_ste_masks_out_of_range() {
        let x = [0.5f32, -1.0, 1.0, 1.5, -2.0];
        let mut d = [1.0f32; 5];
        apply_clip_ste(&mut d, &x);
        assert_eq!(d, [1.0, 1.0, 1.0, 0.0, 0.0]);
    }

    /// SGD update follows the python convention exactly.
    #[test]
    fn sgd_matches_python_update_rule() {
        let mut p = vec![1.0f32, -2.0];
        let mut v = vec![0.5f32, 0.0];
        let g = vec![0.1f32, -0.2];
        sgd_update(&mut p, &mut v, &g, 0.1, 0.9, 0.01);
        // v = 0.9*0.5 + 0.1 + 0.01*1 = 0.56; p = 1 - 0.1*0.56
        assert!((v[0] - 0.56).abs() < 1e-6);
        assert!((p[0] - (1.0 - 0.056)).abs() < 1e-6);
        assert!((v[1] - (-0.2 - 0.02)).abs() < 1e-6);
    }
}
