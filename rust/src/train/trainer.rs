//! The training tape: hardware-exact forward with per-layer context
//! capture, reverse walk, SGD — over the same layer stack as
//! [`crate::model::NativeModel`], but holding *raw* (unquantized,
//! unnormalized) parameters that the optimizer updates.
//!
//! Forward per stochastic conv layer (train mode): normalize weights
//! (per-tensor max-abs, stop-gradient scale), program the crossbar
//! ([`StoxMvm::program`] — weights change every step, so programming is
//! per-step by construction), im2col, and run the layer's *actual*
//! registry converter with fresh per-(step, layer) sampling seeds while
//! capturing every per-slice PS ([`StoxMvm::run_capture`]).  Backward
//! evaluates the converter's surrogate at exactly those PS values
//! ([`grad::stox_matmul_backward`]) and chains through train-mode BN,
//! the residual shortcuts, global pooling and the FC head.  Parameters
//! update as soon as their layer's backward completes — no later layer's
//! backward reads an earlier layer's parameters, so this is equivalent
//! to the all-grads-then-update convention of `python/compile/train.py`.

use super::grad::{
    self, apply_clip_ste, bn_backward, bn_forward_train, fp_conv2d_backward, im2col_backward,
    sgd_update, softmax_ce, BnTape,
};
use super::TrainConfig;
use crate::imc::{
    decompose_activations, im2col, ConvArena, PsConvert, PsConverterSpec, StoxConfig, StoxMvm,
};
use crate::model::infer::{fp_conv2d, layer_seed};
use crate::model::weights::{Manifest, WeightStore};
use crate::stats::rng::CounterRng;

/// One trainable conv layer (crossbar-mapped, or the full-precision HPF
/// first layer) with its SGD velocity and built converter.
pub struct ConvParam {
    /// Raw weights `[kh, kw, cin, cout]`, updated in place.
    pub w: Vec<f32>,
    vel: Vec<f32>,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    layer_idx: usize,
    /// false → full-precision (HPF) first layer.
    stochastic: bool,
    spec: PsConverterSpec,
    converter: Box<dyn PsConvert>,
}

/// Trainable BatchNorm affine + running statistics.
pub struct BnParam {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
    vgamma: Vec<f32>,
    vbeta: Vec<f32>,
}

/// Saved forward context of one conv layer.
struct ConvTape {
    /// Layer input (pre-clip), NHWC.
    x: Vec<f32>,
    h: usize,
    w: usize,
    /// im2col patches fed to the crossbar (empty for the FP first layer).
    patches: Vec<f32>,
    /// Captured normalized per-slice PS (`run_capture` layout).
    ps: Vec<f32>,
    /// Normalized weights programmed this step (empty for FP).
    wn: Vec<f32>,
    /// Stop-gradient normalization scale (max|w| + 1e-8).
    scale: f32,
    ho: usize,
    wo: usize,
}

/// Saved forward context of one residual block.
struct BlockTape {
    tc1: ConvTape,
    tb1: BnTape,
    tc2: ConvTape,
    tb2: BnTape,
    in_h: usize,
    in_w: usize,
    in_c: usize,
    stride: usize,
    cout: usize,
}

/// Deterministic loss trajectory and provenance of one training run.
#[derive(Debug, Clone)]
pub struct TrainRecord {
    /// Per-step training loss (bit-reproducible for a given seed).
    pub losses: Vec<f32>,
    /// Mean loss of the final `min(steps, 5)` steps.
    pub final_loss: f32,
    pub steps: usize,
    pub seed: u32,
    /// Canonical converter spec the stochastic body trained with.
    pub body_spec: String,
}

/// PS-quantization-aware trainer over a loaded checkpoint.
pub struct Trainer {
    pub cfg: StoxConfig,
    pub hp: TrainConfig,
    pub num_classes: usize,
    pub image_size: usize,
    pub in_channels: usize,
    pub first_qf: bool,
    pub conv1: ConvParam,
    pub bn1: BnParam,
    /// blocks\[stage\]\[block\] = (conv1, bn1, conv2, bn2, stride)
    pub blocks: Vec<Vec<(ConvParam, BnParam, ConvParam, BnParam, usize)>>,
    pub fc_w: Vec<f32>,
    pub fc_b: Vec<f32>,
    pub w3: usize,
    vfc_w: Vec<f32>,
    vfc_b: Vec<f32>,
    body_spec: PsConverterSpec,
    overridden: bool,
}

impl Trainer {
    /// Initialize from a loaded checkpoint (same pytree paths as
    /// `NativeModel::load_with_config`), at hardware config `cfg` and —
    /// when `converter_override` is set — with every stochastic layer's
    /// converter swapped to that spec (what the exported manifest then
    /// carries as its trained `mode`).
    pub fn new(
        manifest: &Manifest,
        store: &WeightStore,
        cfg: StoxConfig,
        converter_override: Option<&PsConverterSpec>,
        hp: TrainConfig,
    ) -> crate::Result<Self> {
        cfg.validate()?;
        let spec = &manifest.spec;
        let first_qf = spec.first_layer == "qf";
        let body_spec = match converter_override {
            Some(s) => s.clone(),
            None => PsConverterSpec::from_mode(&spec.stox.mode, cfg.alpha, cfg.n_samples)?,
        };
        let samples_for = |layer_idx: usize| -> u32 {
            if layer_idx == 0 {
                return spec.first_layer_samples;
            }
            if let Some(ls) = &spec.layer_samples {
                for (li, s) in ls {
                    if *li == layer_idx {
                        return *s;
                    }
                }
            }
            cfg.n_samples
        };
        let mk_conv = |w: &[f32],
                       shape: &[usize],
                       stride: usize,
                       layer_idx: usize,
                       stochastic: bool,
                       mode: &str|
         -> crate::Result<ConvParam> {
            let layer_spec = if stochastic {
                match converter_override {
                    Some(s) => s.clone(),
                    None => {
                        PsConverterSpec::from_mode(mode, cfg.alpha, samples_for(layer_idx))?
                    }
                }
            } else {
                PsConverterSpec::IdealAdc
            };
            let converter = layer_spec.build(&cfg)?;
            Ok(ConvParam {
                w: w.to_vec(),
                vel: vec![0.0; w.len()],
                kh: shape[0],
                kw: shape[1],
                cin: shape[2],
                cout: shape[3],
                stride,
                layer_idx,
                stochastic,
                spec: layer_spec,
                converter,
            })
        };
        let bn = |prefix: &str| -> crate::Result<BnParam> {
            let (_, gamma) = store.param(&format!("{prefix}['gamma']"))?;
            let (_, beta) = store.param(&format!("{prefix}['beta']"))?;
            let (_, mean) = store.state(&format!("{prefix}['mean']"))?;
            let (_, var) = store.state(&format!("{prefix}['var']"))?;
            Ok(BnParam {
                gamma: gamma.to_vec(),
                beta: beta.to_vec(),
                mean: mean.to_vec(),
                var: var.to_vec(),
                vgamma: vec![0.0; gamma.len()],
                vbeta: vec![0.0; beta.len()],
            })
        };

        let (c1_shape, c1_data) = store.param("['conv1']")?;
        let first_mode = spec
            .first_layer_mode
            .clone()
            .unwrap_or_else(|| spec.stox.mode.clone());
        let conv1 = mk_conv(c1_data, c1_shape, 1, 0, first_qf, &first_mode)?;
        let bn1 = bn("['bn1']")?;

        let mut layer_idx = 1usize;
        let mut blocks = Vec::new();
        for s in 0..3 {
            let mut stage = Vec::new();
            for b in 0..spec.blocks_per_stage {
                let stride = if s > 0 && b == 0 { 2 } else { 1 };
                let p = format!("['stages'][{s}][{b}]");
                let (sh1, w1) = store.param(&format!("{p}['conv1']"))?;
                let c1 = mk_conv(w1, sh1, stride, layer_idx, true, &spec.stox.mode)?;
                layer_idx += 1;
                let b1 = bn(&format!("{p}['bn1']"))?;
                let (sh2, w2) = store.param(&format!("{p}['conv2']"))?;
                let c2 = mk_conv(w2, sh2, 1, layer_idx, true, &spec.stox.mode)?;
                layer_idx += 1;
                let b2 = bn(&format!("{p}['bn2']"))?;
                stage.push((c1, b1, c2, b2, stride));
            }
            blocks.push(stage);
        }

        let (fcw_shape, fcw) = store.param("['fc_w']")?;
        let (_, fcb) = store.param("['fc_b']")?;
        Ok(Self {
            cfg,
            hp,
            num_classes: spec.num_classes,
            image_size: spec.image_size,
            in_channels: spec.in_channels,
            first_qf,
            conv1,
            bn1,
            blocks,
            vfc_w: vec![0.0; fcw.len()],
            vfc_b: vec![0.0; fcb.len()],
            fc_w: fcw.to_vec(),
            fc_b: fcb.to_vec(),
            w3: fcw_shape[0],
            body_spec,
            overridden: converter_override.is_some(),
        })
    }

    /// Whether a `--converter` override replaced every stochastic layer's
    /// converter (in which case the checkpoint's per-layer sampling
    /// overrides were not in effect and must not be re-exported).
    pub fn converter_overridden(&self) -> bool {
        self.overridden
    }

    /// Canonical spec string of the trained stochastic body — the `mode`
    /// the exported manifest carries.
    pub fn body_mode(&self) -> String {
        self.body_spec.to_string()
    }

    /// Spec string of the first layer ("ideal" for HPF models).
    pub fn first_mode(&self) -> String {
        self.conv1.spec.to_string()
    }

    /// (jax-keystr name → tensor view) of every trained tensor — the
    /// export vocabulary, mirroring the loader paths exactly.
    pub fn named_tensors(&self) -> Vec<(String, &[f32])> {
        let mut out: Vec<(String, &[f32])> = vec![
            ("['params']['conv1']".into(), self.conv1.w.as_slice()),
            ("['params']['bn1']['gamma']".into(), self.bn1.gamma.as_slice()),
            ("['params']['bn1']['beta']".into(), self.bn1.beta.as_slice()),
        ];
        for (s, stage) in self.blocks.iter().enumerate() {
            for (b, (c1, b1, c2, b2, _)) in stage.iter().enumerate() {
                let p = format!("['params']['stages'][{s}][{b}]");
                out.push((format!("{p}['conv1']"), c1.w.as_slice()));
                out.push((format!("{p}['bn1']['gamma']"), b1.gamma.as_slice()));
                out.push((format!("{p}['bn1']['beta']"), b1.beta.as_slice()));
                out.push((format!("{p}['conv2']"), c2.w.as_slice()));
                out.push((format!("{p}['bn2']['gamma']"), b2.gamma.as_slice()));
                out.push((format!("{p}['bn2']['beta']"), b2.beta.as_slice()));
            }
        }
        out.push(("['params']['fc_w']".into(), self.fc_w.as_slice()));
        out.push(("['params']['fc_b']".into(), self.fc_b.as_slice()));
        out.push(("['states']['bn1']['mean']".into(), self.bn1.mean.as_slice()));
        out.push(("['states']['bn1']['var']".into(), self.bn1.var.as_slice()));
        for (s, stage) in self.blocks.iter().enumerate() {
            for (b, (_, b1, _, b2, _)) in stage.iter().enumerate() {
                let p = format!("['states']['stages'][{s}][{b}]");
                out.push((format!("{p}['bn1']['mean']"), b1.mean.as_slice()));
                out.push((format!("{p}['bn1']['var']"), b1.var.as_slice()));
                out.push((format!("{p}['bn2']['mean']"), b2.mean.as_slice()));
                out.push((format!("{p}['bn2']['var']"), b2.var.as_slice()));
            }
        }
        out
    }

    fn conv_forward(
        op: &ConvParam,
        cfg: &StoxConfig,
        x: &[f32],
        b: usize,
        h: usize,
        w: usize,
        step_seed: u32,
    ) -> crate::Result<(Vec<f32>, ConvTape)> {
        if !op.stochastic {
            let (out, ho, wo) =
                fp_conv2d(x, b, h, w, op.cin, &op.w, op.kh, op.kw, op.cout, op.stride);
            return Ok((
                out,
                ConvTape {
                    x: x.to_vec(),
                    h,
                    w,
                    patches: Vec::new(),
                    ps: Vec::new(),
                    wn: Vec::new(),
                    scale: 1.0,
                    ho,
                    wo,
                },
            ));
        }
        let scale = op.w.iter().fold(0.0f32, |m, v| m.max(v.abs())) + 1e-8;
        let wn: Vec<f32> = op.w.iter().map(|v| v / scale).collect();
        // quantize_unit clamps, so im2col of the raw input produces the
        // same digits as the clipped copy (the NativeModel parity note)
        let (patches, ho, wo) = im2col(x, b, h, w, op.cin, op.kh, op.kw, op.stride);
        let m = op.kh * op.kw * op.cin;
        let mvm = StoxMvm::program(&wn, m, op.cout, *cfg)?;
        let seed = layer_seed(step_seed, op.layer_idx as u32);
        // fused digit-domain forward + capture when the integer kernel is
        // in play (bit-identical to im2col + run_capture, pinned in
        // mvm.rs and below); the im2col patches stay on the tape either
        // way — the backward consumes them
        let (out, ps) = if mvm.is_integer_kernel() {
            let mut arena = ConvArena::new();
            let acts = decompose_activations(&mut arena, x, b, h, w, op.cin, cfg);
            let (out, ps, cho, cwo) = mvm.run_conv_digits_capture(
                &acts,
                op.kh,
                op.kw,
                op.stride,
                op.converter.as_ref(),
                seed,
            );
            debug_assert_eq!((cho, cwo), (ho, wo));
            (out, ps)
        } else {
            mvm.run_capture(&patches, b * ho * wo, op.converter.as_ref(), seed)
        };
        Ok((out, ConvTape { x: x.to_vec(), h, w, patches, ps, wn, scale, ho, wo }))
    }

    /// Backward of one conv layer; returns (∂L/∂input, raw weight grad).
    fn conv_backward(
        op: &ConvParam,
        cfg: &StoxConfig,
        tape: &ConvTape,
        g: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        let b = tape.x.len() / (tape.h * tape.w * op.cin);
        if !op.stochastic {
            return fp_conv2d_backward(
                &tape.x, b, tape.h, tape.w, op.cin, &op.w, op.kh, op.kw, op.cout,
                op.stride, g,
            );
        }
        let m = op.kh * op.kw * op.cin;
        let grads = grad::stox_matmul_backward(
            &tape.patches,
            &tape.wn,
            b * tape.ho * tape.wo,
            m,
            op.cout,
            cfg,
            op.converter.as_ref(),
            &tape.ps,
            g,
        );
        let mut dx = im2col_backward(
            &grads.d_patches, b, tape.h, tape.w, op.cin, op.kh, op.kw, op.stride,
        );
        // act_clip + quantizer STE on the layer input
        apply_clip_ste(&mut dx, &tape.x);
        // chain through w_n = w / stop_grad(scale)
        let inv = 1.0 / tape.scale;
        let dw: Vec<f32> = grads.d_w.iter().map(|v| v * inv).collect();
        (dx, dw)
    }

    /// One SGD step on a batch (NHWC images in [-1,1], integer labels);
    /// returns (loss, batch accuracy).
    pub fn step(
        &mut self,
        x: &[f32],
        y: &[i32],
        batch: usize,
        it: usize,
        lr: f32,
    ) -> crate::Result<(f32, f64)> {
        let step_seed = self.hp.seed.wrapping_add(it as u32);
        let cfg = self.cfg;
        let bn_momentum = 0.9f32;
        let (mom, wd) = (self.hp.momentum, self.hp.weight_decay);

        // ---------------- forward ----------------
        let (h0, t_conv1) = Self::conv_forward(
            &self.conv1, &cfg, x, batch, self.image_size, self.image_size, step_seed,
        )?;
        let c1out = self.conv1.cout;
        let (mut h, t_bn1) = bn_forward_train(
            &h0,
            c1out,
            &self.bn1.gamma,
            &self.bn1.beta,
            &mut self.bn1.mean,
            &mut self.bn1.var,
            bn_momentum,
        );
        let mut hh = t_conv1.ho;
        let mut ww = t_conv1.wo;
        let mut c = c1out;

        let mut tapes: Vec<Vec<BlockTape>> = Vec::new();
        for si in 0..self.blocks.len() {
            let mut stage_tapes = Vec::new();
            for bi in 0..self.blocks[si].len() {
                let stride = self.blocks[si][bi].4;
                let cout = self.blocks[si][bi].0.cout;
                let shortcut = shortcut_fwd(&h, batch, hh, ww, c, cout, stride);
                let (o1, tc1) =
                    Self::conv_forward(&self.blocks[si][bi].0, &cfg, &h, batch, hh, ww, step_seed)?;
                let blk = &mut self.blocks[si][bi];
                let (o1b, tb1) = bn_forward_train(
                    &o1,
                    cout,
                    &blk.1.gamma,
                    &blk.1.beta,
                    &mut blk.1.mean,
                    &mut blk.1.var,
                    bn_momentum,
                );
                let (h1, w1) = (tc1.ho, tc1.wo);
                let (o2, tc2) = Self::conv_forward(
                    &self.blocks[si][bi].2, &cfg, &o1b, batch, h1, w1, step_seed,
                )?;
                let blk = &mut self.blocks[si][bi];
                let (mut o2b, tb2) = bn_forward_train(
                    &o2,
                    cout,
                    &blk.3.gamma,
                    &blk.3.beta,
                    &mut blk.3.mean,
                    &mut blk.3.var,
                    bn_momentum,
                );
                for (o, s) in o2b.iter_mut().zip(&shortcut) {
                    *o += s;
                }
                let (h2, w2) = (tc2.ho, tc2.wo);
                stage_tapes.push(BlockTape {
                    tc1,
                    tb1,
                    tc2,
                    tb2,
                    in_h: hh,
                    in_w: ww,
                    in_c: c,
                    stride,
                    cout,
                });
                h = o2b;
                hh = h2;
                ww = w2;
                c = cout;
            }
            tapes.push(stage_tapes);
        }

        // global average pool + FC
        let hw = (hh * ww) as f32;
        let classes = self.num_classes;
        let mut pooled = vec![0.0f32; batch * c];
        for bi in 0..batch {
            for p in 0..hh * ww {
                for ch in 0..c {
                    pooled[bi * c + ch] += h[(bi * hh * ww + p) * c + ch];
                }
            }
        }
        for v in pooled.iter_mut() {
            *v /= hw;
        }
        let mut logits = vec![0.0f32; batch * classes];
        for bi in 0..batch {
            for k in 0..classes {
                let mut acc = self.fc_b[k];
                for ch in 0..self.w3 {
                    acc += pooled[bi * c + ch] * self.fc_w[ch * classes + k];
                }
                logits[bi * classes + k] = acc;
            }
        }
        let (loss, dlogits) = softmax_ce(&logits, y, batch, classes);
        let mut correct = 0usize;
        for bi in 0..batch {
            let row = &logits[bi * classes..(bi + 1) * classes];
            let mut pred = 0usize;
            for (k, &v) in row.iter().enumerate() {
                if v > row[pred] {
                    pred = k;
                }
            }
            if pred as i32 == y[bi] {
                correct += 1;
            }
        }
        let acc = correct as f64 / batch as f64;

        // ---------------- backward + in-place SGD ----------------
        let mut d_fc_w = vec![0.0f32; self.fc_w.len()];
        let mut d_fc_b = vec![0.0f32; classes];
        let mut d_pooled = vec![0.0f32; batch * c];
        for bi in 0..batch {
            for k in 0..classes {
                let gv = dlogits[bi * classes + k];
                d_fc_b[k] += gv;
                for ch in 0..self.w3 {
                    d_fc_w[ch * classes + k] += pooled[bi * c + ch] * gv;
                    d_pooled[bi * c + ch] += self.fc_w[ch * classes + k] * gv;
                }
            }
        }
        let mut gh = vec![0.0f32; h.len()];
        for bi in 0..batch {
            for p in 0..hh * ww {
                for ch in 0..c {
                    gh[(bi * hh * ww + p) * c + ch] = d_pooled[bi * c + ch] / hw;
                }
            }
        }
        sgd_update(&mut self.fc_w, &mut self.vfc_w, &d_fc_w, lr, mom, wd);
        sgd_update(&mut self.fc_b, &mut self.vfc_b, &d_fc_b, lr, mom, wd);

        for si in (0..self.blocks.len()).rev() {
            for bi in (0..self.blocks[si].len()).rev() {
                let sv = &tapes[si][bi];
                let g_short =
                    shortcut_bwd(&gh, batch, sv.in_h, sv.in_w, sv.in_c, sv.cout, sv.stride);
                let blk = &self.blocks[si][bi];
                let (g_o2, dg2, db2) = bn_backward(&sv.tb2, &blk.3.gamma, &gh, sv.cout);
                let (g_mid, dw2) = Self::conv_backward(&blk.2, &cfg, &sv.tc2, &g_o2);
                let (g_o1, dg1, db1) = bn_backward(&sv.tb1, &blk.1.gamma, &g_mid, sv.cout);
                let (mut g_in, dw1) = Self::conv_backward(&blk.0, &cfg, &sv.tc1, &g_o1);
                for (gi, gs) in g_in.iter_mut().zip(&g_short) {
                    *gi += gs;
                }
                let blk = &mut self.blocks[si][bi];
                sgd_update(&mut blk.0.w, &mut blk.0.vel, &dw1, lr, mom, wd);
                sgd_update(&mut blk.1.gamma, &mut blk.1.vgamma, &dg1, lr, mom, wd);
                sgd_update(&mut blk.1.beta, &mut blk.1.vbeta, &db1, lr, mom, wd);
                sgd_update(&mut blk.2.w, &mut blk.2.vel, &dw2, lr, mom, wd);
                sgd_update(&mut blk.3.gamma, &mut blk.3.vgamma, &dg2, lr, mom, wd);
                sgd_update(&mut blk.3.beta, &mut blk.3.vbeta, &db2, lr, mom, wd);
                gh = g_in;
            }
        }

        let (g_h0, dg, db) = bn_backward(&t_bn1, &self.bn1.gamma, &gh, c1out);
        let (_, dw) = Self::conv_backward(&self.conv1, &cfg, &t_conv1, &g_h0);
        sgd_update(&mut self.conv1.w, &mut self.conv1.vel, &dw, lr, mom, wd);
        sgd_update(&mut self.bn1.gamma, &mut self.bn1.vgamma, &dg, lr, mom, wd);
        sgd_update(&mut self.bn1.beta, &mut self.bn1.vbeta, &db, lr, mom, wd);

        Ok((loss, acc))
    }

    /// Cosine-decayed (or constant) learning rate of step `it`.
    pub fn lr_at(&self, it: usize) -> f32 {
        if self.hp.cosine_lr {
            (self.hp.lr as f64
                * 0.5
                * (1.0 + (std::f64::consts::PI * it as f64 / self.hp.steps as f64).cos()))
                as f32
        } else {
            self.hp.lr
        }
    }

    /// Run the configured number of steps over a `testset.bin`-format
    /// labeled set (`images`: `[n × H·W·C]` NHWC in [-1,1]).  Batches are
    /// sampled with replacement from a dedicated counter-RNG stream, so
    /// the whole trajectory is a pure function of `(data, hp)`.
    pub fn train(
        &mut self,
        images: &[f32],
        labels: &[i32],
        n: usize,
    ) -> crate::Result<TrainRecord> {
        anyhow::ensure!(n > 0, "empty training set");
        anyhow::ensure!(self.hp.batch > 0 && self.hp.steps > 0, "steps/batch >= 1");
        let img_sz = self.image_size * self.image_size * self.in_channels;
        anyhow::ensure!(images.len() >= n * img_sz, "image buffer too small");
        anyhow::ensure!(labels.len() >= n, "label buffer too small");
        let mut losses = Vec::with_capacity(self.hp.steps);
        for it in 0..self.hp.steps {
            let idx = batch_indices(self.hp.seed, it, self.hp.batch, n);
            let mut xb = Vec::with_capacity(self.hp.batch * img_sz);
            let mut yb = Vec::with_capacity(self.hp.batch);
            for &i in &idx {
                xb.extend_from_slice(&images[i * img_sz..(i + 1) * img_sz]);
                yb.push(labels[i]);
            }
            let lr = self.lr_at(it);
            let (loss, bacc) = self.step(&xb, &yb, self.hp.batch, it, lr)?;
            losses.push(loss);
            if self.hp.log_every > 0
                && (it % self.hp.log_every == 0 || it + 1 == self.hp.steps)
            {
                println!("  step {it:4} lr {lr:.4} loss {loss:.4} batch-acc {bacc:.3}");
            }
        }
        let tail = losses.len().min(5);
        let final_loss = losses[losses.len() - tail..].iter().sum::<f32>() / tail as f32;
        Ok(TrainRecord {
            losses,
            final_loss,
            steps: self.hp.steps,
            seed: self.hp.seed,
            body_spec: self.body_mode(),
        })
    }
}

/// Parameter-free ResNet shortcut (strided subsample + zero channel pad),
/// mirroring `model::infer`'s forward.
fn shortcut_fwd(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    stride: usize,
) -> Vec<f32> {
    let ho = h / stride;
    let wo = w / stride;
    let mut out = vec![0.0f32; b * ho * wo * cout];
    for bi in 0..b {
        for y in 0..ho {
            for xx in 0..wo {
                let src = ((bi * h + y * stride) * w + xx * stride) * cin;
                let dst = ((bi * ho + y) * wo + xx) * cout;
                out[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
            }
        }
    }
    out
}

/// Adjoint of [`shortcut_fwd`].
fn shortcut_bwd(
    g: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    stride: usize,
) -> Vec<f32> {
    let ho = h / stride;
    let wo = w / stride;
    let mut dx = vec![0.0f32; b * h * w * cin];
    for bi in 0..b {
        for y in 0..ho {
            for xx in 0..wo {
                let src = ((bi * h + y * stride) * w + xx * stride) * cin;
                let dst = ((bi * ho + y) * wo + xx) * cout;
                for ci in 0..cin {
                    dx[src + ci] += g[dst + ci];
                }
            }
        }
    }
    dx
}

/// Deterministic with-replacement batch sampling over the committed
/// `testset.bin` format: index `s` of step `it` draws
/// `draw24(it·batch + s) mod n` from a dedicated counter stream
/// (mirrored by `python/compile/train_fixture.py`).
pub fn batch_indices(seed: u32, it: usize, batch: usize, n: usize) -> Vec<usize> {
    let rng = CounterRng::new(seed ^ 0x0DA7_A5E1);
    (0..batch)
        .map(|s| (rng.draw24((it * batch + s) as u32) as usize) % n)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shortcut_backward_is_adjoint() {
        let rng = CounterRng::new(5);
        let (b, h, w, cin, cout, stride) = (2usize, 4usize, 4usize, 3usize, 5usize, 2usize);
        let x: Vec<f32> =
            (0..b * h * w * cin).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect();
        let s = shortcut_fwd(&x, b, h, w, cin, cout, stride);
        let g: Vec<f32> = (0..s.len())
            .map(|i| rng.uniform_in((10_000 + i) as u32, -1.0, 1.0))
            .collect();
        let dx = shortcut_bwd(&g, b, h, w, cin, cout, stride);
        let lhs: f64 = s.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn batch_indices_deterministic_and_in_range() {
        let a = batch_indices(7, 3, 4, 8);
        let b = batch_indices(7, 3, 4, 8);
        assert_eq!(a, b);
        assert!(a.iter().all(|&i| i < 8));
        assert_ne!(batch_indices(7, 4, 4, 8), a, "steps draw fresh indices");
        assert_ne!(batch_indices(8, 3, 4, 8), a, "seed changes the draw");
    }

    /// The training forward now rides the fused digit-domain conv (ISSUE 6
    /// carried follow-up): its activations and captured PS must be
    /// bit-identical to the legacy im2col + `run_capture` tape, and the
    /// im2col patches must still be on the tape for the backward.
    #[test]
    fn conv_forward_fused_matches_im2col_capture_tape() {
        let (b, h, w, cin, cout) = (2usize, 5usize, 4usize, 3usize, 6usize);
        let rng = CounterRng::new(77);
        let x: Vec<f32> =
            (0..b * h * w * cin).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect();
        let wts: Vec<f32> = (0..3 * 3 * cin * cout)
            .map(|i| rng.uniform_in((50_000 + i) as u32, -0.5, 0.5))
            .collect();
        let cfg = StoxConfig { r_arr: 16, w_slice_bits: 1, ..Default::default() };
        let spec: PsConverterSpec = "stox:alpha=4,samples=2".parse().unwrap();
        let op = ConvParam {
            w: wts.clone(),
            vel: vec![0.0; wts.len()],
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride: 1,
            layer_idx: 1,
            stochastic: true,
            spec: spec.clone(),
            converter: spec.build(&cfg).unwrap(),
        };
        let (out, tape) = Trainer::conv_forward(&op, &cfg, &x, b, h, w, 9).unwrap();

        // legacy tape: im2col + run_capture at the same layer seed
        let scale = wts.iter().fold(0.0f32, |m, v| m.max(v.abs())) + 1e-8;
        let wn: Vec<f32> = wts.iter().map(|v| v / scale).collect();
        let (patches, ho, wo) = im2col(&x, b, h, w, cin, 3, 3, 1);
        let mvm = StoxMvm::program(&wn, 3 * 3 * cin, cout, cfg).unwrap();
        assert!(mvm.is_integer_kernel(), "fixture must exercise the fused path");
        let seed = layer_seed(9, 1);
        let (want, want_ps) =
            mvm.run_capture(&patches, b * ho * wo, op.converter.as_ref(), seed);
        assert_eq!(out, want, "fused training forward != legacy capture");
        assert_eq!(tape.ps, want_ps, "fused capture != legacy capture");
        assert_eq!(tape.patches, patches, "im2col patches stay on the tape");
        assert_eq!((tape.ho, tape.wo), (ho, wo));
    }
}
