//! Checkpoint export in the existing manifest format: the trained
//! tensors are written back in the *loaded manifest's tensor order*
//! (same jax-keystr names, same shapes), the spec's `stox` block is
//! rewritten to the trained hardware config with `mode` set to the
//! canonical trained converter spec, and the test set rides along — so
//! [`crate::model::NativeModel::load_with_config`] (and `Manifest::load`
//! before it) round-trips the export through the `ConverterRegistry`
//! with no `--converter` override anywhere.
//!
//! The artifact is fully deterministic: no timestamps, loss floats
//! serialized through the canonical JSON writer — two runs with the same
//! seed produce byte-identical `manifest.json` + `weights.bin` (the CI
//! `train-smoke` job diffs exactly that).

use super::trainer::{TrainRecord, Trainer};
use crate::model::weights::Manifest;
use crate::util::json::Json;
use std::path::Path;

fn layers_json(manifest: &Manifest) -> Json {
    Json::Arr(
        manifest
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("name", Json::Str(l.name.clone())),
                    ("kh", Json::Num(l.kh as f64)),
                    ("kw", Json::Num(l.kw as f64)),
                    ("cin", Json::Num(l.cin as f64)),
                    ("cout", Json::Num(l.cout as f64)),
                    ("h_out", Json::Num(l.h_out as f64)),
                    ("w_out", Json::Num(l.w_out as f64)),
                    ("stride", Json::Num(l.stride as f64)),
                    ("stochastic", Json::Bool(l.stochastic)),
                ])
            })
            .collect(),
    )
}

/// Write `manifest.json`, `weights.bin` and a copy of the test set into
/// `dir` — a checkpoint directory loadable by `Manifest::load` +
/// `WeightStore::load` + `TestSet::load`.
pub fn export_checkpoint(
    trainer: &Trainer,
    manifest: &Manifest,
    record: &TrainRecord,
    dir: &Path,
) -> crate::Result<()> {
    std::fs::create_dir_all(dir)?;
    let named = trainer.named_tensors();
    let lookup = |name: &str| -> crate::Result<&[f32]> {
        named
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .ok_or_else(|| anyhow::anyhow!("export: trainer has no tensor '{name}'"))
    };

    // weights.bin in the loaded manifest's tensor order
    let mut blob: Vec<u8> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut offset = 0usize;
    for t in &manifest.weights.tensors {
        let data = lookup(&t.name)?;
        anyhow::ensure!(
            data.len() == t.numel,
            "export: tensor '{}' has {} elements, manifest says {}",
            t.name,
            data.len(),
            t.numel
        );
        for v in data {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        entries.push(Json::obj(vec![
            ("name", Json::Str(t.name.clone())),
            (
                "shape",
                Json::Arr(t.shape.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("offset", Json::Num(offset as f64)),
            ("numel", Json::Num(t.numel as f64)),
        ]));
        offset += t.numel;
    }
    std::fs::write(dir.join("weights.bin"), &blob)?;

    // test set rides along so the export is self-contained
    let ts = &manifest.testset;
    std::fs::copy(manifest.dir.join(&ts.file), dir.join(&ts.file))?;

    let spec = &manifest.spec;
    let cfg = trainer.cfg;
    let stox = Json::obj(vec![
        ("a_bits", Json::Num(cfg.a_bits as f64)),
        ("w_bits", Json::Num(cfg.w_bits as f64)),
        ("a_stream_bits", Json::Num(cfg.a_stream_bits as f64)),
        ("w_slice_bits", Json::Num(cfg.w_slice_bits as f64)),
        ("r_arr", Json::Num(cfg.r_arr as f64)),
        ("n_samples", Json::Num(cfg.n_samples as f64)),
        ("alpha", Json::Num(cfg.alpha as f64)),
        // the round-trip hinge: the trained converter spec, resolved by
        // the registry at load time with no CLI override
        ("mode", Json::Str(trainer.body_mode())),
    ]);
    // the first layer's trained converter spec, recorded explicitly so a
    // QF checkpoint whose conv1 trained under a distinct mode (or read
    // count) reloads with exactly that converter — `first_mode()` is the
    // canonical full spec string, read-count parameters included
    let first_layer_mode = if spec.first_layer == "qf" {
        Json::Str(trainer.first_mode())
    } else {
        Json::Null
    };
    // per-layer sampling overrides were in effect only when no
    // `--converter` override replaced them — re-export them verbatim then
    let layer_samples = match (&spec.layer_samples, trainer.converter_overridden()) {
        (Some(ls), false) => Json::Arr(
            ls.iter()
                .map(|(li, s)| {
                    Json::Arr(vec![Json::Num(*li as f64), Json::Num(*s as f64)])
                })
                .collect(),
        ),
        _ => Json::Null,
    };
    let spec_json = Json::obj(vec![
        ("name", Json::Str(format!("{}-trained", spec.name))),
        ("num_classes", Json::Num(spec.num_classes as f64)),
        ("in_channels", Json::Num(spec.in_channels as f64)),
        ("image_size", Json::Num(spec.image_size as f64)),
        ("base_width", Json::Num(spec.base_width as f64)),
        ("width_mult", Json::Num(spec.width_mult)),
        ("blocks_per_stage", Json::Num(spec.blocks_per_stage as f64)),
        ("stox", stox),
        ("first_layer", Json::Str(spec.first_layer.clone())),
        ("first_layer_samples", Json::Num(spec.first_layer_samples as f64)),
        ("first_layer_mode", first_layer_mode),
        ("layer_samples", layer_samples),
    ]);

    // loss curve subsampled to <= 100 points, like train.py records
    let stride = (record.losses.len() / 100).max(1);
    let curve: Vec<Json> = record
        .losses
        .iter()
        .step_by(stride)
        .map(|&l| Json::Num(l as f64))
        .collect();
    let record_json = Json::obj(vec![
        ("note", Json::Str("stox-cli train export".into())),
        ("seed", Json::Num(record.seed as f64)),
        ("steps", Json::Num(record.steps as f64)),
        ("final_loss", Json::Num(record.final_loss as f64)),
        ("trained_with", Json::Str(record.body_spec.clone())),
        ("loss_curve", Json::Arr(curve)),
    ]);

    let manifest_json = Json::obj(vec![
        ("spec", spec_json),
        ("checkpoint_record", record_json),
        ("layers", layers_json(manifest)),
        ("models", Json::Arr(Vec::new())),
        (
            "weights",
            Json::obj(vec![
                ("file", Json::Str("weights.bin".into())),
                ("tensors", Json::Arr(entries)),
                ("total_f32", Json::Num(offset as f64)),
            ]),
        ),
        (
            "testset",
            Json::obj(vec![
                ("file", Json::Str(ts.file.clone())),
                ("dataset", Json::Str(ts.dataset.clone())),
                ("n", Json::Num(ts.n as f64)),
                (
                    "image_shape",
                    Json::Arr(ts.image_shape.iter().map(|&s| Json::Num(s as f64)).collect()),
                ),
            ]),
        ),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest_json.to_string())?;
    Ok(())
}
