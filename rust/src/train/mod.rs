//! PS-quantization-aware training (§3.3): reverse-mode backprop over the
//! stochastic digit-plane forward path.
//!
//! The paper's accuracy story rests on *training through* the stochastic
//! PS conversion: the forward pass is the exact hardware model of
//! Algorithm 1 (quantize → bit-slice/stream → per-subarray partial sums →
//! stochastic conversion → shift-and-add), while the backward pass treats
//! the converter as its expected `tanh(α·ps)` transfer curve (Eq. 5's
//! straight-through reduction).  This module closes the loop natively:
//!
//! * [`grad`] — the layer-level backward math: the digit-STE VJP of one
//!   crossbar MVM ([`grad::stox_matmul_backward`], evaluated at the
//!   per-slice PS captured by [`crate::imc::StoxMvm::run_capture`] and
//!   routed through the converter's [`crate::imc::PsConvert::grad_slice_at`]
//!   hook), im2col scatter, train-mode BatchNorm, the clip STE, and the
//!   softmax cross-entropy head;
//! * [`trainer`] — the tape: a [`trainer::Trainer`] mirrors the
//!   `NativeModel` layer stack with raw (unquantized) parameters, runs
//!   the hardware-exact forward recording per-layer context, walks it in
//!   reverse, and applies SGD with momentum + weight decay under
//!   deterministic seeded batch sampling over the committed `testset.bin`
//!   format;
//! * [`export`] — checkpoint export in the existing manifest format, so
//!   [`crate::model::NativeModel::load_with_config`] round-trips the
//!   trained weights through the `ConverterRegistry` with no `--converter`
//!   override (the manifest's `mode` string carries the trained spec).
//!
//! Everything is bit-reproducible per `(seed, hyperparameters)`: batch
//! sampling uses the shared counter RNG, the forward uses the frozen
//! per-(step, layer) seed derivation, and no wall-clock state enters the
//! exported artifact.  `python/compile/gen_grad_golden.py` mirrors the
//! gradient conventions op-for-op; `rust/tests/grad_equiv.rs` pins the
//! two sides within 1e-5.

pub mod export;
pub mod grad;
pub mod trainer;

pub use export::export_checkpoint;
pub use trainer::{TrainRecord, Trainer};

/// Hyperparameters of one training run (mirrors `python/compile/train.py`'s
/// `TrainHP` conventions: SGD update `v ← µ·v + g + wd·p`, `p ← p − lr·v`,
/// cosine learning-rate decay, fresh sampling seeds every step).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer steps.
    pub steps: usize,
    /// Images per step (sampled with replacement, counter-RNG keyed).
    pub batch: usize,
    /// Peak learning rate.
    pub lr: f32,
    /// SGD momentum µ.
    pub momentum: f32,
    /// L2 weight decay folded into the velocity update.
    pub weight_decay: f32,
    /// Master seed: batch sampling, per-step MTJ sampling streams.
    pub seed: u32,
    /// Cosine-decay the learning rate over `steps` (else constant).
    pub cosine_lr: bool,
    /// Print a progress line every `log_every` steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 100,
            batch: 4,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 5e-4,
            seed: 0,
            cosine_lr: true,
            log_every: 0,
        }
    }
}
