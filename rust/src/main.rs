//! `stox-cli` — the StoX-Net leader binary.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md §5):
//!
//! * `serve`       — run the serving engine over the exported test set
//!                   (PJRT artifacts on the request path) and report
//!                   accuracy + latency/throughput + simulated IMC cost;
//!                   `--replicas N` (with `--native`) runs the sharded
//!                   replica tier instead: N replicas over one set of
//!                   programmed crossbars, admission control, work
//!                   stealing, SLO metrics as JSON;
//! * `loadgen`     — closed-loop Poisson load generator: sweeps offered
//!                   arrival rates to saturation against the replica tier
//!                   and emits the throughput–latency curve as
//!                   `BENCH_serving.json`;
//! * `device-sim`  — Fig. 2 / Table 1: LLG switching curve, tanh fit,
//!                   converter energy/latency/area;
//! * `table2`      — the component cost table;
//! * `fig4`        — PS distribution of the StoX-trained model;
//! * `sensitivity` — Fig. 5 Monte-Carlo layer perturbation;
//! * `fig8`        — pipeline occupancy comparison;
//! * `fig9a`/`fig9b` — hardware-efficiency rollups;
//! * `accuracy`    — native crossbar-model accuracy on the test set
//!                   (`--converter` runs any registered PS-converter spec);
//! * `infer`       — deterministic counter-snapshot inference: native model
//!                   over the test set with hardware counters attached;
//!                   writes the name-sorted snapshot JSON (byte-identical
//!                   across same-seed runs — the CI `obs-smoke` contract);
//! * `train`       — PS-quantization-aware training (§3.3): hardware-exact
//!                   stochastic forward, tanh-surrogate backward, SGD;
//!                   exports a manifest-format checkpoint that reloads
//!                   through the registry with no converter override;
//! * `sweep`       — registry-driven accuracy × energy Pareto sweep: every
//!                   registered converter spec (plus MTJ sample-length and
//!                   ADC bit-width grids) evaluated for task accuracy and
//!                   joined with the Fig. 9 cost rollup (JSON/CSV + table);
//! * `test`        — run the declarative scenario suite (`scenarios/*.yaml`
//!                   through `harness::run_suite`): summary table,
//!                   `scenarios_report.json`, non-zero exit on mismatch;
//! * `converters`  — list the PS-converter registry (the open PsConvert API);
//! * `tables`      — pretty-print the python training sweeps (Tables 3/4,
//!                   Fig. 7) from `python/results/*.json`.

use std::path::PathBuf;
use stox_net::arch::components::ComponentCosts;
use stox_net::arch::energy::{evaluate_network, DesignConfig};
use stox_net::arch::pipeline::PipelineModel;
use stox_net::arch::sweep::argmax;
use stox_net::coordinator::server::{
    submit_all, Executor, NativeExecutor, PjrtExecutor,
};
use stox_net::coordinator::{BatcherConfig, ServeConfig, Server, TileScheduler};
use stox_net::device::llg::LlgParams;
use stox_net::device::mtj::{SotMtj, SwitchingCurve};
use stox_net::device::MtjConverter;
use stox_net::imc::{PsConvert, PsConverterSpec, StoxConfig};
use stox_net::model::weights::TestSet;
use stox_net::model::{zoo, Manifest, NativeModel, WeightStore};
use stox_net::obs::{span, CounterRegistry, TraceLevel};
use stox_net::runtime::Engine;
use stox_net::serve::{run_sweep, LoadGenConfig, ReplicaConfig, ReplicaServer};
use stox_net::stats::Histogram;
use stox_net::util::cli::Args;
use stox_net::util::json::Json;

const USAGE: &str = "stox-cli <command> [--artifacts DIR] [flags]

commands:
  serve        [--requests N] [--batch B] [--max-wait-ms MS] [--native]
               [--converter SPEC]   (SPEC: name[:k=v,..], e.g. stox:samples=4,
                                     sparse:bits=4, inhomo:base=1,extra=3)
               [--replicas N] [--queue-depth N] [--deadline-ms MS] [--slo-ms MS]
               [--trace PATH]
               (--replicas > 1 runs the sharded replica tier — requires
                --native; prints the per-shard/aggregate SLO metrics JSON;
                --trace records request-path spans and writes them to PATH
                as Chrome trace JSON — level Request by default, STOX_TRACE
                overrides with off|request|layer|kernel, fail-loud)
  loadgen      [--replicas N] [--start-rps R] [--growth G] [--steps N]
               [--requests-per-rate N] [--sat-frac F] [--target-batch B]
               [--max-wait-ms MS] [--queue-depth N] [--deadline-ms MS]
               [--slo-ms MS] [--seed S] [--pace-seed S] [--converter SPEC]
               (Poisson arrivals swept to saturation against the replica
                tier; writes BENCH_serving.json to STOX_BENCH_DIR)
  device-sim   [--points N] [--trials N]
  table2
  fig4         [--images N]
  sensitivity  [--sigma S] [--trials N] [--images N]
  fig8         [--cols N] [--adc-share N] [--samples N]
  fig9a
  fig9b
  accuracy     [--images N] [--batch B] [--converter SPEC]
  infer        [--images N] [--batch B] [--seed S] [--converter SPEC]
               [--precision TAG] [--out PATH]
               (native model with deterministic hardware counters attached;
                writes the name-sorted counter snapshot JSON to PATH —
                byte-identical across same-seed runs, which the CI
                obs-smoke job asserts with cmp)
  train        [--out DIR] [--steps N] [--batch B] [--lr L] [--momentum M]
               [--weight-decay W] [--seed S] [--const-lr] [--log-every N]
               [--precision TAG] [--converter SPEC]
               (PS-quantization-aware training over the artifacts'
                testset.bin: exact stochastic forward, Eq. 5 surrogate
                backward; bit-reproducible per --seed; exports DIR as a
                manifest-format checkpoint whose mode is the trained
                converter spec, reloadable with no --converter override)
  sweep        [--images N] [--seed S] [--samples GRID] [--bits GRID]
               [--precision TAGS] [--specs A;B;..]
               [--workload resnet20|resnet18|resnet50]
               [--threads N] [--out DIR] [--model] [--measured]
               (--measured re-runs every golden-workload cell with hardware
                counters attached and prints predicted-vs-measured energy
                per cell with a relative-error column; exact — non-
                stochastic-cost — converters must agree within 1%)
               (GRID: comma/range list, e.g. 1,2,4..8; TAGS: comma list of
                XwYa[Zbs] precision tags, e.g. 4w4a4bs,8w8a4bs — the full
                Fig. 9a design matrix of precision x converter; --model
                scores checkpoint accuracy from --artifacts instead of the
                built-in golden workload, loading + programming the weights
                exactly once per precision tag)
  test         [--suite DIR] [--filter SUBSTR] [--update] [--report PATH]
               (run the declarative scenario suite — default DIR
                scenarios/; --update (or UPDATE_SCENARIOS=1) re-blesses
                goldens; writes PATH (default scenarios_report.json) and
                exits non-zero if any scenario fails)
  converters   (list the registered PS-converter modes)
  tables       [--results DIR]
  nonideal     (crossbar non-ideality ablation: variation/IR-drop/noise
                plus hard faults — stuck cells, stuck MTJs, drift, dropout)
  chaos        [--severities LIST] [--loads LIST] [--replicas N]
               [--target-batch B] [--seed S] [--max-requeues N]
               [--brownout] [--brownout-spec SPEC] [--converter SPEC]
               (fault-injection sweep against the self-healing replica
                tier: transient-error severity x offered load; prints the
                reply ledger per leg and writes BENCH_chaos.json to
                STOX_BENCH_DIR — byte-identical across same-seed runs)";

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let artifacts = PathBuf::from(args.string("artifacts", "artifacts"));
    match args.subcommand.as_deref() {
        Some("serve") => serve(&artifacts, &args),
        Some("loadgen") => loadgen_cmd(&artifacts, &args),
        Some("device-sim") => device_sim(
            args.usize("points", 21),
            args.u32("trials", 200),
        ),
        Some("table2") => table2(),
        Some("fig4") => fig4(&artifacts, args.usize("images", 64)),
        Some("sensitivity") => sensitivity(
            &artifacts,
            args.f32("sigma", 0.15),
            args.u32("trials", 4),
            args.usize("images", 128),
        ),
        Some("fig8") => {
            println!(
                "{}",
                PipelineModel::default().render_fig8(
                    args.usize("cols", 128),
                    args.usize("adc-share", 8),
                    args.u32("samples", 1),
                )
            );
            Ok(())
        }
        Some("fig9a") => fig9a(),
        Some("fig9b") => fig9b(),
        Some("accuracy") => accuracy(
            &artifacts,
            args.usize("images", 256),
            args.usize("batch", 8),
            args.get("converter").map(|s| s.to_string()),
        ),
        Some("infer") => infer_cmd(&artifacts, &args),
        Some("train") => train_cmd(&artifacts, &args),
        Some("sweep") => sweep(&artifacts, &args),
        Some("test") => test_cmd(&args),
        Some("converters") => converters(),
        Some("tables") => tables(&PathBuf::from(
            args.string("results", "python/results"),
        )),
        Some("nonideal") => nonideal_ablation(),
        Some("chaos") => chaos_cmd(&artifacts, &args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn serve(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    let requests = args.usize("requests", 512);
    let batch = args.usize("batch", 8);
    let max_wait_ms = args.u64("max-wait-ms", 5);
    let native = args.flag("native");
    let converter = args.get("converter").map(|s| s.to_string());
    let replicas = args.usize("replicas", 1);
    // --trace PATH turns the span collector on and names the export file;
    // STOX_TRACE picks the level (fail-loud on unknown values), defaulting
    // to Request — one event per admission/batch/execute/steal/hedge edge
    let trace_out = args.get("trace").map(|s| s.to_string());
    if trace_out.is_some() {
        span::install(span::level_from_env(TraceLevel::Request)?);
    }
    let manifest = Manifest::load(artifacts)?;
    let test = TestSet::load(&manifest)?;
    let spec = &manifest.spec;
    let elems = spec.image_size * spec.image_size * spec.in_channels;
    let stox_cfg = spec.stox_config();

    // --converter swaps the functional converter, which only the native
    // executor can do (PJRT artifacts bake the trained converter into the
    // compiled graph) — refuse rather than report energy for a converter
    // that never ran.
    anyhow::ensure!(
        converter.is_none() || native,
        "--converter requires --native (PJRT artifacts run the trained converter)"
    );
    // the registry is the single parse/construct path: the manifest's
    // trained mode by default, any `--converter` spec as an override
    let body_spec = match &converter {
        Some(s) => PsConverterSpec::from_mode(s, stox_cfg.alpha, stox_cfg.n_samples)?,
        None => spec.body_converter_spec()?,
    };
    // with_converter_spec overrides every crossbar-mapped layer, including
    // a stochastic (QF) first layer — keep the accounting in lockstep
    let first_spec = if converter.is_some() && spec.first_layer == "qf" {
        body_spec.clone()
    } else {
        spec.first_layer_spec()?
    };

    // serving design point: energy accounting derived from the converter
    // specs actually running (PsConvert::cost_key)
    let design = DesignConfig::from_specs(stox_cfg, &body_spec, &first_spec)?;
    let sched =
        TileScheduler::new(&ComponentCosts::default(), design, &manifest.layers);
    println!(
        "simulated IMC: {:.2} nJ/inference, {:.1} µs/inference, {:.0} inf/s bound",
        sched.energy_per_inference_pj() / 1e3,
        sched.single_latency_ns() / 1e3,
        sched.throughput_bound_per_s(),
    );

    // --replicas > 1 runs the sharded replica tier: N replicas over one
    // set of programmed crossbars, central batch formation (bit-identical
    // to the single-server loop), admission control + SLO metrics
    if replicas > 1 {
        anyhow::ensure!(
            native,
            "--replicas requires --native (PJRT handles are not Send across shard threads)"
        );
        let store = WeightStore::load(&manifest)?;
        let mut model = NativeModel::load(&manifest, &store)?;
        if converter.is_some() {
            model = model.with_converter_spec(&body_spec)?;
            println!("native converter override: {body_spec}");
        }
        let cfg = ReplicaConfig {
            replicas,
            batcher: BatcherConfig {
                target_batch: batch,
                max_wait: std::time::Duration::from_millis(max_wait_ms),
            },
            seed: 0,
            queue_depth: args.usize("queue-depth", 1024),
            deadline: args
                .get("deadline-ms")
                .map(|_| std::time::Duration::from_millis(args.u64("deadline-ms", 0))),
            slo: std::time::Duration::from_millis(args.u64("slo-ms", 50)),
            steal: true,
            resilience: stox_net::serve::ResilienceConfig::default(),
        };
        // fail loudly on degenerate flag combinations before spawning
        cfg.validate()?;
        let rserver = ReplicaServer::from_native(&model, cfg);
        let n = requests.min(test.n);
        let images: Vec<Vec<f32>> = (0..n).map(|i| test.image(i).to_vec()).collect();
        let (tx, rx) = std::sync::mpsc::channel();
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, images.into_iter());
            drop(tx);
            replies
        });
        rserver.run(rx);
        let replies = client.join().unwrap();
        let (mut correct, mut served, mut shed) = (0usize, 0usize, 0usize);
        for (i, r) in replies.into_iter().enumerate() {
            let rep = r.recv()?;
            match &rep.result {
                Ok(logits) => {
                    served += 1;
                    if argmax(logits) as i32 == test.labels[i] {
                        correct += 1;
                    }
                }
                Err(_) => shed += 1,
            }
        }
        println!(
            "accuracy: {}/{} served = {:.2}% ({} shed by admission/deadline)",
            correct,
            served,
            100.0 * correct as f64 / served.max(1) as f64,
            shed
        );
        println!("{}", rserver.metrics.to_json().to_string());
        if let Some(path) = &trace_out {
            export_trace(path)?;
        }
        return Ok(());
    }

    let executor: Box<dyn Executor> = if native {
        let store = WeightStore::load(&manifest)?;
        let mut model = NativeModel::load(&manifest, &store)?;
        if converter.is_some() {
            model = model.with_converter_spec(&body_spec)?;
            println!("native converter override: {body_spec}");
        }
        Box::new(NativeExecutor { model })
    } else {
        let engine = Engine::load(&manifest)?;
        println!("PJRT platform: {}", engine.platform);
        Box::new(PjrtExecutor {
            engine,
            classes: spec.num_classes,
            image_elems: elems,
        })
    };

    let serve_cfg = ServeConfig {
        batcher: BatcherConfig {
            target_batch: batch,
            max_wait: std::time::Duration::from_millis(max_wait_ms),
        },
        seed: 0,
        // absorb transient executor hiccups before failing a batch
        max_retries: 2,
    };
    serve_cfg.validate()?;
    let server = Server::new(executor, serve_cfg).with_scheduler(sched);

    let n = requests.min(test.n);
    let (tx, rx) = std::sync::mpsc::channel();
    // client thread submits; server loop runs here (PJRT is not Send)
    let images: Vec<Vec<f32>> = (0..n).map(|i| test.image(i).to_vec()).collect();
    let client = std::thread::spawn(move || {
        let replies = submit_all(&tx, images.into_iter());
        drop(tx);
        replies
    });
    server.run(rx);
    let replies = client.join().unwrap();

    let mut correct = 0usize;
    for (i, r) in replies.into_iter().enumerate() {
        let rep = r.recv()?;
        let pred = argmax(rep.logits()?);
        if pred as i32 == test.labels[i] {
            correct += 1;
        }
    }
    println!(
        "accuracy: {}/{} = {:.2}%",
        correct,
        n,
        100.0 * correct as f64 / n as f64
    );
    println!("{}", server.metrics.lock().unwrap().report());
    if let Some(path) = &trace_out {
        export_trace(path)?;
    }
    Ok(())
}

/// Drain the installed span collector and write the Chrome trace JSON.
fn export_trace(path: &str) -> anyhow::Result<()> {
    let events = span::drain();
    span::write_chrome_trace(path, &events)?;
    println!("wrote {} trace events to {path}", events.len());
    Ok(())
}

/// Closed-loop Poisson load generator against the sharded replica tier:
/// sweeps offered arrival rates (geometric growth) to saturation and
/// writes the throughput–latency curve as `BENCH_serving.json` (the same
/// artifact format the perf benches emit; `STOX_BENCH_DIR` redirects it).
fn loadgen_cmd(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let store = WeightStore::load(&manifest)?;
    let test = TestSet::load(&manifest)?;
    let mut model = NativeModel::load(&manifest, &store)?;
    if let Some(c) = args.get("converter") {
        let spec = PsConverterSpec::from_mode(
            c,
            manifest.spec.stox.alpha,
            manifest.spec.stox.n_samples,
        )?;
        println!("converter override: {spec}");
        model = model.with_converter_spec(&spec)?;
    }
    let cfg = ReplicaConfig {
        replicas: args.usize("replicas", 2),
        batcher: BatcherConfig {
            target_batch: args.usize("target-batch", 8),
            max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms", 5)),
        },
        seed: args.u32("seed", 0),
        queue_depth: args.usize("queue-depth", 1024),
        deadline: args
            .get("deadline-ms")
            .map(|_| std::time::Duration::from_millis(args.u64("deadline-ms", 0))),
        slo: std::time::Duration::from_millis(args.u64("slo-ms", 50)),
        steal: true,
        resilience: stox_net::serve::ResilienceConfig::default(),
    };
    cfg.validate()?;
    let lg = LoadGenConfig {
        start_rps: args.f64("start-rps", 64.0),
        growth: args.f64("growth", 2.0),
        steps: args.usize("steps", 6),
        requests_per_step: args.usize("requests-per-rate", 64),
        saturation_frac: args.f64("sat-frac", 0.9),
        seed: args.u32("pace-seed", 7),
    };
    println!(
        "loadgen: {} replicas, target batch {}, queue depth {}, SLO {} ms; \
         sweeping from {:.0} rps x{:.1} up to {} steps",
        cfg.replicas,
        cfg.batcher.target_batch,
        cfg.queue_depth,
        cfg.slo.as_millis(),
        lg.start_rps,
        lg.growth,
        lg.steps,
    );
    let images: Vec<Vec<f32>> = (0..test.n).map(|i| test.image(i).to_vec()).collect();
    let (points, suite) = run_sweep(&model, &cfg, &images, &lg);
    let knee = points.iter().map(|p| p.achieved_rps).fold(0.0f64, f64::max);
    println!(
        "\n{:>12} {:>12} {:>10} {:>10} {:>10} {:>8} {:>9}",
        "offered", "achieved", "p50 µs", "p99 µs", "p999 µs", "slo", "rejected"
    );
    for p in &points {
        println!(
            "{:>12.1} {:>12.1} {:>10.0} {:>10.0} {:>10.0} {:>8.3} {:>9}",
            p.offered_rps,
            p.achieved_rps,
            p.p50_us,
            p.p99_us,
            p.p999_us,
            p.slo_attainment,
            p.rejected
        );
    }
    println!("saturation throughput: {knee:.1} req/s over {} rate points", points.len());
    suite.write_json()?;
    Ok(())
}

fn device_sim(points: usize, trials: u32) -> anyhow::Result<()> {
    let mtj = SotMtj::default();
    let conv = MtjConverter::default();
    println!("== Table 1 device ==");
    println!(
        "R_LRS = {:.1} kΩ, R_HRS = {:.1} kΩ (TMR {:.1})",
        mtj.r_lrs / 1e3,
        mtj.r_hrs() / 1e3,
        mtj.tmr
    );
    println!(
        "R_HM  = {:.0} Ω, read margin = {:.3} V",
        mtj.r_hm(),
        mtj.read_margin()
    );
    let llg = LlgParams::default();
    println!("thermal stability Δ = {:.1}", llg.thermal_stability());
    println!("\n== Fig. 2: switching probability vs write current ==");
    let curve = SwitchingCurve::extract(llg, &mtj, points, trials, 42);
    for (i, p) in curve.currents.iter().zip(&curve.prob) {
        let bar = "#".repeat((p * 40.0).round() as usize);
        println!("{:>7.1} µA | {bar:<40} {p:.3}", i * 1e6);
    }
    let (alpha, sse) = curve.fit_tanh_alpha(mtj.i_write_max);
    println!("tanh fit: alpha = {alpha:.2} (sse {sse:.4}) — Eq. 1 abstraction");
    println!("\n== converter costs (Table 2 row) ==");
    println!(
        "energy/conversion (derived) : {:.2} fJ",
        conv.energy_per_conversion() * 1e15
    );
    println!("energy/conversion (paper)   : 6.14 fJ");
    println!("latency                     : {:.1} ns", conv.latency() * 1e9);
    println!("area (28nm-scaled)          : {:.2} µm²", conv.area_um2());
    Ok(())
}

fn table2() -> anyhow::Result<()> {
    let c = ComponentCosts::default();
    println!("== Table 2: energy and area of simulated hardware components ==");
    println!("{:<22} {:>14} {:>14}", "Component", "Energy (pJ)", "Area (µm²)");
    let rows: Vec<(&str, f64, f64)> = vec![
        ("DAC", c.dac_energy_pj, c.dac_area_um2),
        ("Xbar cell (1b)", c.cell_energy_1b_pj, c.cell_area_um2),
        ("Xbar cell (2b)", c.cell_energy_2b_pj, c.cell_area_um2),
        ("ADC (FP)", c.adc_fp_energy_pj, c.adc_fp_area_um2),
        ("ADC (sparse)", c.adc_sparse_energy_pj, c.adc_sparse_area_um2),
        ("MTJ-converter", c.mtj_energy_pj, c.mtj_area_um2),
        ("1b sense amp", c.sa_energy_pj, c.sa_area_um2),
        ("shift-and-add", c.sna_energy_pj, c.sna_area_um2),
    ];
    for (name, e, a) in rows {
        println!("{name:<22} {e:>14.4} {a:>14.4}");
    }
    Ok(())
}

fn fig4(artifacts: &PathBuf, images: usize) -> anyhow::Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let store = WeightStore::load(&manifest)?;
    let test = TestSet::load(&manifest)?;
    let mut model = NativeModel::load(&manifest, &store)?;
    let n = images.min(test.n);

    model.ps_probe = Some(std::sync::Mutex::new(Histogram::new(-1.0, 1.0, 41)));
    let img_sz = test.h * test.w * test.c;
    let mut i = 0;
    while i < n {
        let b = 8.min(n - i);
        let _ = model.forward(&test.images[i * img_sz..(i + b) * img_sz], b, 1);
        i += b;
    }
    let probe = model.ps_probe.take().unwrap().into_inner().unwrap();
    println!("== Fig. 4: distribution of normalized array-level PS (StoX-trained) ==");
    println!("{}", probe.render(60));
    let central: f64 = probe
        .centers()
        .iter()
        .zip(probe.density())
        .filter(|(c, _)| c.abs() < 0.25)
        .map(|(_, d)| d)
        .sum();
    println!(
        "mean {:+.4}, std {:.4}, {} samples; mass in |ps|<0.25: {:.1}%",
        probe.mean(),
        probe.std(),
        probe.count(),
        100.0 * central
    );
    println!("(train the f7-1bsa-hpf checkpoint and re-export to compare the SA-trained distribution)");
    Ok(())
}

fn sensitivity(
    artifacts: &PathBuf,
    sigma: f32,
    trials: u32,
    images: usize,
) -> anyhow::Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let store = WeightStore::load(&manifest)?;
    let test = TestSet::load(&manifest)?;
    let model = NativeModel::load(&manifest, &store)?;
    let n = images.min(test.n);
    let base = model.accuracy(&test.images, &test.labels, n, 8, 777);
    println!("== Fig. 5: Monte-Carlo layer-wise sensitivity (σ = {sigma}) ==");
    println!("baseline accuracy: {base:.4}");
    for layer in 0..model.n_conv_layers() {
        let mut acc = 0.0;
        for t in 0..trials {
            let p = model.perturb_layer(layer, sigma, 1000 + layer as u32 * 97 + t);
            acc += p.accuracy(&test.images, &test.labels, n, 8, 777);
        }
        let drop = base - acc / trials as f64;
        let bar = "#".repeat((drop.max(0.0) * 200.0).round() as usize);
        println!("layer {layer:2} | {bar:<40} drop {drop:+.4}");
    }
    Ok(())
}

fn fig9a() -> anyhow::Result<()> {
    let costs = ComponentCosts::default();
    let layers = zoo::resnet20_cifar();
    let base = StoxConfig::default();
    let designs = vec![
        DesignConfig::hpfa(),
        DesignConfig::sfa(),
        DesignConfig::stox(base, 1, true),
        DesignConfig::stox(base, 4, true),
        DesignConfig::stox(base, 8, true),
        DesignConfig::stox_mix(
            base,
            true,
            &[
                ("s0b0c1", 4),
                ("s0b0c2", 4),
                ("s0b1c1", 2),
                ("s0b1c2", 2),
                ("s0b2c1", 2),
            ],
        ),
        DesignConfig::stox(StoxConfig { w_slice_bits: 1, ..base }, 1, true),
    ];
    let results = evaluate_network(&costs, &designs, &layers);
    let hpfa = results[0].0.clone();
    println!("== Fig. 9a: ResNet-20/CIFAR, normalized to HPFA ==");
    println!(
        "{:<24} {:>10} {:>10} {:>10} {:>10} {:>9}",
        "design", "energy", "latency", "area", "EDP gain", "xbars"
    );
    for (r, _) in &results {
        println!(
            "{:<24} {:>9.3}x {:>9.3}x {:>9.3}x {:>9.1}x {:>9}",
            r.name,
            hpfa.energy_pj / r.energy_pj,
            hpfa.latency_ns / r.latency_ns,
            hpfa.area_um2 / r.area_um2,
            hpfa.edp_pj_ns / r.edp_pj_ns,
            r.xbars,
        );
    }
    let sfa = &results[1].0;
    let stox1 = &results[2].0;
    println!(
        "\nheadline: EDP vs HPFA = {:.0}x, vs SFA = {:.0}x (paper: up to 130x / 24x)",
        hpfa.edp_pj_ns / stox1.edp_pj_ns,
        sfa.edp_pj_ns / stox1.edp_pj_ns,
    );
    Ok(())
}

fn fig9b() -> anyhow::Result<()> {
    let costs = ComponentCosts::default();
    println!("== Fig. 9b: EDP improvement vs HPFA per workload ==");
    for (name, layers) in [
        ("ResNet-20 / CIFAR-10", zoo::resnet20_cifar()),
        ("ResNet-18 / Tiny-ImageNet", zoo::resnet18_tiny()),
        ("ResNet-50 / Tiny-ImageNet", zoo::resnet50_tiny()),
    ] {
        let designs = vec![
            DesignConfig::hpfa(),
            DesignConfig::stox(StoxConfig::default(), 1, true),
            DesignConfig::stox(StoxConfig::default(), 4, true),
        ];
        let results = evaluate_network(&costs, &designs, &layers);
        let hpfa = &results[0].0;
        println!(
            "{:<28} MACs {:>7.1}M  EDP gain: 1-QF {:>6.1}x, 4-QF {:>6.1}x",
            name,
            zoo::total_macs(&layers) as f64 / 1e6,
            hpfa.edp_pj_ns / results[1].0.edp_pj_ns,
            hpfa.edp_pj_ns / results[2].0.edp_pj_ns,
        );
    }
    Ok(())
}

fn accuracy(
    artifacts: &PathBuf,
    images: usize,
    batch: usize,
    converter: Option<String>,
) -> anyhow::Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let store = WeightStore::load(&manifest)?;
    let test = TestSet::load(&manifest)?;
    let mut model = NativeModel::load(&manifest, &store)?;
    if let Some(c) = &converter {
        let spec = PsConverterSpec::from_mode(
            c,
            manifest.spec.stox.alpha,
            manifest.spec.stox.n_samples,
        )?;
        println!("converter override: {spec}");
        model = model.with_converter_spec(&spec)?;
    }
    let n = images.min(test.n);
    let t0 = std::time::Instant::now();
    let acc = model.accuracy(&test.images, &test.labels, n, batch, 0);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "native crossbar-model accuracy: {:.2}% over {} images ({:.1} img/s)",
        acc * 100.0,
        n,
        n as f64 / dt
    );
    let text = std::fs::read_to_string(manifest.dir.join("manifest.json"))?;
    if let Some(pyacc) = Json::parse(&text)
        .ok()
        .and_then(|j| j.at(&["checkpoint_record", "test_acc"]).and_then(|v| v.as_f64()))
    {
        println!("python-side checkpoint accuracy (manifest): {:.2}%", 100.0 * pyacc);
    }
    Ok(())
}

/// Deterministic counter-snapshot inference: load the native model (at
/// the trained config or an explicit `--precision` tag), attach a fresh
/// [`CounterRegistry`] while the crossbars are still exclusively owned,
/// run the first `--images` test images at a fixed seed, and write the
/// name-sorted counter snapshot JSON to `--out`.  Everything in the file
/// is workload-determined — no timing, no host identity — so two
/// same-seed runs produce byte-identical files; the CI `obs-smoke` job
/// asserts exactly that with `cmp`.
fn infer_cmd(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    let images = args.usize("images", 32);
    let batch = args.usize("batch", 8);
    let seed = args.u32("seed", 0);
    let out = args.string("out", "counters.json");
    let manifest = Manifest::load(artifacts)?;
    let store = WeightStore::load(&manifest)?;
    let test = TestSet::load(&manifest)?;
    let cfg = match args.get("precision") {
        Some(tag) => manifest.spec.precision_config(tag)?,
        None => manifest.spec.stox_config(),
    };
    let mut model = NativeModel::load_with_config(&manifest, &store, cfg)?;
    if let Some(c) = args.get("converter") {
        let spec = PsConverterSpec::from_mode(c, cfg.alpha, cfg.n_samples)?;
        println!("converter override: {spec}");
        model = model.with_converter_spec(&spec)?;
    }
    // counters attach while this model still owns its crossbars
    // exclusively — after any converter override, before any view/share
    // would clone the Arcs
    let reg = CounterRegistry::new();
    model.attach_counters(&reg)?;
    let n = images.min(test.n);
    let acc = model.accuracy(&test.images, &test.labels, n, batch, seed);
    let snap = reg.snapshot();
    println!(
        "accuracy: {:.2}% over {n} images (seed {seed}); {} counters recorded",
        acc * 100.0,
        snap.len()
    );
    let total_macs: u64 = snap
        .iter()
        .filter(|(name, _)| name.ends_with(".macs"))
        .map(|(_, v)| v)
        .sum();
    println!("total digit-plane MACs: {total_macs}");
    let body = Json::obj(vec![
        ("images", Json::Num(n as f64)),
        ("batch", Json::Num(batch as f64)),
        ("seed", Json::Num(seed as f64)),
        ("accuracy", Json::Num(acc)),
        ("counters", reg.to_json()),
    ]);
    let mut s = body.to_string();
    s.push('\n');
    std::fs::write(&out, s)?;
    println!("wrote counter snapshot to {out}");
    Ok(())
}

/// PS-quantization-aware training (§3.3) over the artifacts' committed
/// test-set file: hardware-exact stochastic forward with per-slice PS
/// capture, tanh-surrogate backward, SGD with momentum under
/// deterministic seeded batch sampling.  Exports a manifest-format
/// checkpoint whose `mode` is the trained converter spec, then reloads
/// it through `NativeModel::load_with_config` (registry path, no
/// override) and reports its accuracy — the round-trip the CI
/// `train-smoke` job asserts.
fn train_cmd(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    use stox_net::train::{export_checkpoint, TrainConfig, Trainer};

    let manifest = Manifest::load(artifacts)?;
    let store = WeightStore::load(&manifest)?;
    let test = TestSet::load(&manifest)?;
    let cfg = match args.get("precision") {
        Some(tag) => manifest.spec.precision_config(tag)?,
        None => manifest.spec.stox_config(),
    };
    let conv_override = match args.get("converter") {
        Some(s) => Some(PsConverterSpec::from_mode(s, cfg.alpha, cfg.n_samples)?),
        None => None,
    };
    let hp = TrainConfig {
        steps: args.usize("steps", 100),
        batch: args.usize("batch", 4),
        lr: args.f32("lr", 0.05),
        momentum: args.f32("momentum", 0.9),
        weight_decay: args.f32("weight-decay", 5e-4),
        seed: args.u32("seed", 0),
        cosine_lr: !args.flag("const-lr"),
        log_every: args.usize("log-every", 10),
    };
    let mut trainer = Trainer::new(&manifest, &store, cfg, conv_override.as_ref(), hp)?;
    println!(
        "training {} ({} steps, batch {}, lr {}, seed {}) with body converter '{}'",
        manifest.spec.name,
        trainer.hp.steps,
        trainer.hp.batch,
        trainer.hp.lr,
        trainer.hp.seed,
        trainer.body_mode(),
    );
    let t0 = std::time::Instant::now();
    let record = trainer.train(&test.images, &test.labels, test.n)?;
    println!(
        "trained {} steps in {:.1}s: loss {:.4} -> {:.4}",
        record.steps,
        t0.elapsed().as_secs_f64(),
        record.losses.first().copied().unwrap_or(f32::NAN),
        record.final_loss,
    );

    let out = PathBuf::from(args.string("out", "train-out"));
    export_checkpoint(&trainer, &manifest, &record, &out)?;
    // round-trip: reload through the registry with no override anywhere
    let m2 = Manifest::load(&out)?;
    let s2 = WeightStore::load(&m2)?;
    let model = NativeModel::load(&m2, &s2)?;
    let t2 = TestSet::load(&m2)?;
    let acc = model.accuracy(&t2.images, &t2.labels, t2.n, 8, 0);
    println!(
        "exported {} (mode '{}'); reloaded checkpoint scores {:.2}% on the {} committed images",
        out.display(),
        m2.spec.stox.mode,
        100.0 * acc,
        t2.n
    );
    Ok(())
}

/// List the registered PS-converter modes (the open end of the PsConvert
/// API): everything here can be passed to `--converter` and runs
/// end-to-end with matched energy accounting.
fn converters() -> anyhow::Result<()> {
    use stox_net::imc::default_registry;
    let cfg = StoxConfig::default();
    println!("== registered PS converters (spec grammar: name[:k=v,..]) ==");
    for name in default_registry().names() {
        let spec = PsConverterSpec::from_mode(name, cfg.alpha, cfg.n_samples)?;
        let built = spec.build(&cfg)?;
        println!(
            "{name:<10} default spec {:<28} label {}",
            spec.to_string(),
            built.label()
        );
    }
    Ok(())
}

/// Run the declarative scenario suite (`harness::run_suite`): print the
/// summary table, write the machine-readable report, exit non-zero on any
/// failing scenario so CI gates on it.
fn test_cmd(args: &Args) -> anyhow::Result<()> {
    use stox_net::harness::{run_suite, SuiteOptions};
    let suite = PathBuf::from(args.string("suite", "scenarios"));
    let report_path = PathBuf::from(args.string("report", "scenarios_report.json"));
    let opts = SuiteOptions {
        filter: args.get("filter").map(|s| s.to_string()),
        update: args.flag("update"),
    };
    let report = run_suite(&suite, &opts)?;
    print!("{}", report.render_table());
    std::fs::write(&report_path, report.to_json().to_string())?;
    println!("report: {}", report_path.display());
    if report.blessed() > 0 {
        println!(
            "{} scenario(s) blessed goldens this run — commit them and re-run to verify",
            report.blessed()
        );
    }
    anyhow::ensure!(
        report.ok(),
        "{} of {} scenarios failed (see table above and *.actual.json snapshots)",
        report.failed(),
        report.results.len()
    );
    Ok(())
}

/// Registry-driven accuracy × energy Pareto sweep over the full Fig. 9a
/// design matrix: precision tags (`--precision 4w4a4bs,8w8a4bs`) crossed
/// with every registered converter spec plus MTJ sample-length / ADC
/// bit-width grids, task accuracy joined with the cost rollup via
/// `cost_key()`, one joint non-dominated front marked, JSON/CSV artifacts
/// optionally written.  With `--model`, the checkpoint is loaded and
/// programmed exactly once per precision tag and every converter spec
/// shares the programmed crossbars (`share_with_converter_spec`).
fn sweep(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    use stox_net::arch::sweep::{
        default_grid, measure_grid, parse_grid, parse_precision_tags,
        render_measured_table, run_matrix_sweep, GoldenWorkload,
    };

    let images = args.usize("images", 64);
    let seed = args.u32("seed", 0);
    let threads =
        args.usize("threads", stox_net::util::pool::default_threads());
    let samples = parse_grid(&args.string("samples", "1,2,4,8,16,32"))?;
    let bits = parse_grid(&args.string("bits", "1..8"))?;
    let workload = args.string("workload", "resnet20");
    let layers = match workload.as_str() {
        "resnet20" | "resnet20_cifar" => zoo::resnet20_cifar(),
        "resnet18" | "resnet18_tiny" => zoo::resnet18_tiny(),
        "resnet50" | "resnet50_tiny" => zoo::resnet50_tiny(),
        w => anyhow::bail!(
            "unknown sweep workload '{w}' (resnet20|resnet18|resnet50)"
        ),
    };

    // base hardware config: the trained manifest's when scoring a
    // checkpoint (--model), the paper's 4w4a4bs default otherwise; the
    // precision axis derives tag configs from it (r_arr/alpha carry over)
    let manifest = if args.flag("model") {
        Some(Manifest::load(artifacts)?)
    } else {
        None
    };
    let base_cfg = manifest
        .as_ref()
        .map(|m| m.spec.stox_config())
        .unwrap_or_default();
    let tag_cfgs: Vec<StoxConfig> = match args.get("precision") {
        Some(tags) => parse_precision_tags(tags, &base_cfg)?,
        None => vec![base_cfg],
    };

    // converter axis: one default grid per tag, plus user additions
    // (';'-separated — canonical specs contain commas)
    let mut grid: Vec<(StoxConfig, Vec<PsConverterSpec>)> = Vec::new();
    for cfg in &tag_cfgs {
        let mut specs = default_grid(cfg, &samples, &bits);
        if let Some(extra) = args.get("specs") {
            for tok in extra.split(';').filter(|t| !t.trim().is_empty()) {
                let s = PsConverterSpec::from_mode(tok, cfg.alpha, cfg.n_samples)?;
                if !specs.iter().any(|e| e.to_string() == s.to_string()) {
                    specs.push(s);
                }
            }
        }
        grid.push((*cfg, specs));
    }
    let n_cells: usize = grid.iter().map(|(_, s)| s.len()).sum();
    println!(
        "sweeping {} precision tag(s) x converter specs = {} design points over {workload} \
         ({threads} threads, seed {seed})",
        tag_cfgs.len(),
        n_cells,
    );

    let result = if let Some(manifest) = &manifest {
        let store = WeightStore::load(manifest)?;
        let test = TestSet::load(manifest)?;
        let n = images.min(test.n);
        // exactly one weight load + one programming pass per precision
        // tag; every converter spec then shares the programmed crossbars
        let models: Vec<NativeModel> = tag_cfgs
            .iter()
            .map(|cfg| NativeModel::load_with_config(manifest, &store, *cfg))
            .collect::<anyhow::Result<Vec<_>>>()?;
        run_matrix_sweep(&grid, &layers, &workload, seed, threads, |ti, spec| {
            let view = models[ti].share_with_converter_spec(spec)?;
            Ok(view.accuracy(&test.images, &test.labels, n, 8, 777))
        })?
    } else {
        // one golden workload (programmed once) per tag, shared by specs
        let workloads: Vec<GoldenWorkload> = tag_cfgs
            .iter()
            .map(|cfg| GoldenWorkload::new(*cfg, images, seed))
            .collect::<anyhow::Result<Vec<_>>>()?;
        run_matrix_sweep(&grid, &layers, &workload, seed, threads, |ti, spec| {
            let gw = &workloads[ti];
            Ok(gw.accuracy(spec.build(gw.cfg())?.as_ref()))
        })?
    };

    println!("{}", result.render_table());

    // --measured: re-run every cell on the golden workload with hardware
    // counters attached and cross-check the counter-priced energy against
    // the analytical model cell by cell (EXPERIMENTS.md §Observability)
    let measured = if args.flag("measured") {
        let cells = measure_grid(&grid, images, seed)?;
        println!("{}", render_measured_table(&cells));
        let worst_exact = cells
            .iter()
            .filter(|c| !c.stochastic_cost)
            .map(|c| c.rel_err)
            .fold(0.0f64, f64::max);
        println!(
            "worst exact-converter relative error: {:.4}% (bound 1%)",
            100.0 * worst_exact
        );
        anyhow::ensure!(
            worst_exact <= 0.01,
            "measured energy disagrees with the analytical model by {:.3}% \
             on an exact converter (bound 1%)",
            100.0 * worst_exact
        );
        Some(cells)
    } else {
        None
    };

    if let Some(dir) = args.get("out") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let json_path = dir.join("sweep.json");
        std::fs::write(&json_path, result.to_json().to_string())?;
        let csv_path = dir.join("sweep.csv");
        std::fs::write(&csv_path, result.to_csv())?;
        println!("wrote {} and {}", json_path.display(), csv_path.display());
        if let Some(cells) = &measured {
            let path = dir.join("measured.json");
            let j = Json::obj(vec![(
                "cells",
                Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
            )]);
            std::fs::write(&path, j.to_string())?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

fn tables(results: &PathBuf) -> anyhow::Result<()> {
    for preset in ["table3", "table4", "fig7"] {
        let path = results.join(format!("{preset}.json"));
        if !path.exists() {
            println!("({preset}: no results yet — run `make train-tables`)");
            continue;
        }
        let v = Json::parse(&std::fs::read_to_string(&path)?)?;
        println!("== {preset} ==");
        println!(
            "{:<24} {:>10} {:>8} {:>10} {:>8}",
            "run", "tag", "samples", "first", "acc %"
        );
        for run in v.get("runs").and_then(|r| r.as_arr()).unwrap_or(&[]) {
            println!(
                "{:<24} {:>10} {:>8} {:>10} {:>8.2}",
                run.get("name").and_then(|x| x.as_str()).unwrap_or("?"),
                run.get("tag").and_then(|x| x.as_str()).unwrap_or("?"),
                run.get("n_samples").and_then(|x| x.as_f64()).unwrap_or(0.0),
                run.get("first_layer").and_then(|x| x.as_str()).unwrap_or("?"),
                run.get("test_acc").and_then(|x| x.as_f64()).unwrap_or(f64::NAN)
                    * 100.0,
            );
        }
    }
    Ok(())
}

/// Crossbar non-ideality ablation: RMS MVM error vs severity, showing
/// that multi-sampling also averages out *analog* noise (robustness
/// extension, DESIGN.md).
fn nonideal_ablation() -> anyhow::Result<()> {
    use stox_net::imc::{Nonideality, NonidealCrossbar, StoxMvm};
    use stox_net::stats::rng::CounterRng;

    let (b, m, n) = (4usize, 576usize, 64usize);
    let rng = CounterRng::new(3);
    let a: Vec<f32> = (0..b * m).map(|i| rng.uniform_in(i as u32, -1.0, 1.0)).collect();
    let w: Vec<f32> =
        (0..m * n).map(|i| rng.uniform_in((b * m + i) as u32, -1.0, 1.0)).collect();
    let cfg = StoxConfig::default();
    // all converters through the registry — the same construction path
    // the serving stack uses
    let build = |s: &str| -> anyhow::Result<Box<dyn PsConvert>> {
        PsConverterSpec::from_mode(s, cfg.alpha, cfg.n_samples)?.build(&cfg)
    };
    let ideal = StoxMvm::program(&w, m, n, cfg)?
        .run(&a, b, build("expected")?.as_ref(), 0);

    let rms = |xb: &NonidealCrossbar, conv: &dyn PsConvert, seeds: u32| -> f64 {
        let mut acc = 0.0f64;
        for s in 0..seeds {
            let o = xb.run(&a, b, conv, s);
            acc += o
                .iter()
                .zip(&ideal)
                .map(|(g, t)| ((g - t) as f64).powi(2))
                .sum::<f64>()
                / o.len() as f64;
        }
        (acc / seeds as f64).sqrt()
    };

    println!("== crossbar non-ideality ablation (RMS MVM error vs ideal) ==");
    println!(
        "{:<34} {:>10} {:>10} {:>10}",
        "severity", "1b-SA", "MTJ x1", "MTJ x4"
    );
    let cases = [
        ("ideal", Nonideality::default()),
        ("sigma_g 10%", Nonideality { sigma_g: 0.10, ..Default::default() }),
        ("sigma_g 25%", Nonideality { sigma_g: 0.25, ..Default::default() }),
        ("IR drop 10%", Nonideality { ir_drop: 0.10, ..Default::default() }),
        ("read noise 0.05", Nonideality { sigma_read: 0.05, ..Default::default() }),
        (
            "all combined",
            Nonideality {
                sigma_g: 0.10,
                ir_drop: 0.05,
                sigma_read: 0.03,
                ..Default::default()
            },
        ),
        // hard faults: dead devices, not parameter spread
        ("stuck-at-0 cells 5%", Nonideality { stuck_zero: 0.05, ..Default::default() }),
        ("stuck-at-0 cells 20%", Nonideality { stuck_zero: 0.20, ..Default::default() }),
        ("stuck-at-1 cells 5%", Nonideality { stuck_one: 0.05, ..Default::default() }),
        ("stuck MTJ converters 10%", Nonideality { stuck_mtj: 0.10, ..Default::default() }),
        (
            "drift 0.2 @ t=1",
            Nonideality { drift: 0.2, drift_time: 1.0, ..Default::default() },
        ),
        (
            "sample dropout 10%",
            Nonideality { sample_dropout: 0.10, ..Default::default() },
        ),
    ];
    let conv_sa = build("sa")?;
    let conv_m1 = build("stox:samples=1")?;
    let conv_m4 = build("stox:samples=4")?;
    for (name, sev) in cases {
        let xb = NonidealCrossbar::program(&w, m, n, cfg, sev, 11)?;
        let sa = rms(&xb, conv_sa.as_ref(), 4);
        let m1 = rms(&xb, conv_m1.as_ref(), 4);
        let m4 = rms(&xb, conv_m4.as_ref(), 4);
        println!("{name:<34} {sa:>10.5} {m1:>10.5} {m4:>10.5}");
    }
    println!("\n(multi-sampling averages analog read noise as well as MTJ");
    println!(" stochasticity — the robustness argument of §3.2.3 extended)");
    Ok(())
}

/// Chaos sweep: injected fault severity × offered load against the
/// self-healing replica tier.  Every leg runs a fresh tier with health
/// tracking, eviction + lossless requeue, and (optionally) brown-out
/// enabled, under a uniform transient-error [`stox_net::serve::FaultPlan`].
/// The reply ledger per leg (ok / degraded / errors / rejected /
/// requeued + an output checksum) is printed and written as
/// `BENCH_chaos.json` — deterministic per `--seed`, so two same-seed runs
/// produce byte-identical artifacts (the CI `chaos-smoke` contract).
fn chaos_cmd(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    use stox_net::serve::{run_chaos, ChaosConfig};

    let manifest = Manifest::load(artifacts)?;
    let store = WeightStore::load(&manifest)?;
    let mut model = NativeModel::load(&manifest, &store)?;
    if let Some(c) = args.get("converter") {
        let spec = PsConverterSpec::from_mode(
            c,
            manifest.spec.stox.alpha,
            manifest.spec.stox.n_samples,
        )?;
        println!("converter override: {spec}");
        model = model.with_converter_spec(&spec)?;
    }

    let parse_f64s = |key: &str, dflt: &str| -> anyhow::Result<Vec<f64>> {
        args.string(key, dflt)
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                t.trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("bad --{key} entry '{t}': {e}"))
            })
            .collect()
    };
    let parse_usizes = |key: &str, dflt: &str| -> anyhow::Result<Vec<usize>> {
        args.string(key, dflt)
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("bad --{key} entry '{t}': {e}"))
            })
            .collect()
    };
    let cfg = ChaosConfig {
        severities: parse_f64s("severities", "0.0,0.1,0.3")?,
        loads: parse_usizes("loads", "32")?,
        replicas: args.usize("replicas", 2),
        target_batch: args.usize("target-batch", 4),
        seed: args.u32("seed", 7),
        max_requeues: args.u32("max-requeues", 3),
        brownout: args.flag("brownout"),
        brownout_spec: args.string("brownout-spec", "stox:samples=1"),
    };
    anyhow::ensure!(!cfg.severities.is_empty(), "--severities must be non-empty");
    anyhow::ensure!(!cfg.loads.is_empty(), "--loads must be non-empty");
    println!(
        "chaos sweep: {} severities x {} loads, {} replicas, target batch {}, \
         seed {}{}",
        cfg.severities.len(),
        cfg.loads.len(),
        cfg.replicas,
        cfg.target_batch,
        cfg.seed,
        if cfg.brownout {
            format!(", brown-out via '{}'", cfg.brownout_spec)
        } else {
            String::new()
        },
    );

    let (points, suite) = run_chaos(&model, &cfg)?;
    println!(
        "\n{:>9} {:>6} {:>6} {:>9} {:>7} {:>9} {:>9} {:>8} {:>6} {:>14}",
        "severity", "load", "ok", "degraded", "errors", "rejected", "requeued",
        "evicted", "reint", "checksum"
    );
    for p in &points {
        println!(
            "{:>9.3} {:>6} {:>6} {:>9} {:>7} {:>9} {:>9} {:>8} {:>6} {:>14.4}",
            p.severity,
            p.load,
            p.ok,
            p.degraded,
            p.errors,
            p.rejected,
            p.requeued,
            p.evicted,
            p.reintegrated,
            p.checksum,
        );
    }
    // the fault-free leg must account for every request with zero errors
    for p in points.iter().filter(|p| p.severity == 0.0) {
        anyhow::ensure!(
            p.ok + p.rejected + p.deadline_exceeded == p.load as u64 && p.errors == 0,
            "fault-free leg must serve cleanly: {p:?}"
        );
    }
    suite.write_json()?;
    Ok(())
}
