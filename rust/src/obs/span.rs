//! Request-path spans with Chrome-trace export.
//!
//! Span schema: every recorded event carries a `name`, a category
//! (`cat`), a microsecond timestamp relative to collector install, and —
//! for complete (`ph: "X"`) events — a duration; instant (`ph: "i"`)
//! events mark edges like admission rejects, steals, hedges and
//! requeues.  Events buffer in a per-thread [`SpanRecorder`] and flush
//! into the global collector when the buffer fills, when the thread
//! exits, or on [`flush_thread`] — recording never takes the collector
//! lock on the per-span fast path until a flush.
//!
//! Three gates, all of which must be open for a span to record:
//!
//! 1. the default `obs` cargo feature (off → [`enabled`] is a constant
//!    `false` and every guard compiles to a no-op),
//! 2. a collector installed via [`install`] (e.g. by
//!    `stox-cli serve --trace out.json`),
//! 3. the event's [`TraceLevel`] at or below the installed level.
//!
//! Levels nest: `request` covers the serving tier (admission → queue
//! wait → batch formation → shard dispatch → reply), `layer` adds
//! per-layer execute spans inside the model forward, and `kernel` adds
//! per-stripe MAC/convert phase spans inside the digit-plane kernel
//! (high event volume — debugging runs only).  The `STOX_TRACE`
//! environment variable selects the level and fails loudly on unknown
//! values, mirroring the `STOX_SIMD` contract ([`parse_stox_trace`]).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// How much of the request path records, in nesting order.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum TraceLevel {
    /// Record nothing (the installed-collector idle state).
    Off = 0,
    /// Serving-tier request path: admission, queue wait, batch
    /// formation, shard dispatch, execute, reply, steal/hedge/requeue.
    Request = 1,
    /// [`TraceLevel::Request`] plus per-layer execute spans in the model
    /// forward.
    Layer = 2,
    /// [`TraceLevel::Layer`] plus per-stripe MAC/convert phase spans in
    /// the digit-plane kernel (high volume; debugging runs only).
    Kernel = 3,
}

impl TraceLevel {
    /// The `STOX_TRACE` spelling of this level.
    pub fn label(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Request => "request",
            TraceLevel::Layer => "layer",
            TraceLevel::Kernel => "kernel",
        }
    }

    fn from_u8(v: u8) -> TraceLevel {
        match v {
            1 => TraceLevel::Request,
            2 => TraceLevel::Layer,
            3 => TraceLevel::Kernel,
            _ => TraceLevel::Off,
        }
    }
}

/// Parse a `STOX_TRACE` override: `""`/`auto` defer to the caller's
/// default, anything else must name a [`TraceLevel`].  Unknown values are
/// an error carrying the offending string — tracing runs must not
/// quietly record at the wrong level (the `STOX_SIMD` contract).
pub fn parse_stox_trace(v: &str) -> crate::Result<Option<TraceLevel>> {
    match v.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => Ok(None),
        "off" => Ok(Some(TraceLevel::Off)),
        "request" => Ok(Some(TraceLevel::Request)),
        "layer" => Ok(Some(TraceLevel::Layer)),
        "kernel" => Ok(Some(TraceLevel::Kernel)),
        _ => anyhow::bail!(
            "invalid STOX_TRACE value '{v}': expected auto|off|request|layer|kernel"
        ),
    }
}

/// Resolve the trace level: `STOX_TRACE` when set (fail-loud on unknown
/// values), else `default`.
pub fn level_from_env(default: TraceLevel) -> crate::Result<TraceLevel> {
    match std::env::var("STOX_TRACE") {
        Ok(v) => Ok(parse_stox_trace(&v)?.unwrap_or(default)),
        Err(_) => Ok(default),
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(0);
static STATE: OnceLock<TraceState> = OnceLock::new();

struct TraceState {
    epoch: Instant,
    sink: Mutex<Vec<TraceEvent>>,
    next_tid: AtomicU64,
}

/// Install the process collector (idempotent) and set the level.  The
/// collector epoch (trace time zero) is fixed on first install.
pub fn install(level: TraceLevel) {
    STATE.get_or_init(|| TraceState {
        epoch: Instant::now(),
        sink: Mutex::new(Vec::new()),
        next_tid: AtomicU64::new(1),
    });
    set_level(level);
}

/// Change the recording level (no-op gate when no collector installed).
pub fn set_level(level: TraceLevel) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The currently set level (regardless of collector installation).
pub fn current_level() -> TraceLevel {
    TraceLevel::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Whether an event at `min` would record right now.  Constant `false`
/// without the `obs` cargo feature — guard construction and any
/// formatting work behind this check compile away.
#[inline]
pub fn enabled(min: TraceLevel) -> bool {
    #[cfg(feature = "obs")]
    {
        current_level() >= min && STATE.get().is_some()
    }
    #[cfg(not(feature = "obs"))]
    {
        let _ = min;
        false
    }
}

/// One recorded event (Chrome trace-event semantics).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Event name (span or instant label).
    pub name: String,
    /// Category: `serve`, `model`, or `kernel`.
    pub cat: &'static str,
    /// `'X'` (complete) or `'i'` (instant).
    pub ph: char,
    /// Microseconds since collector install.
    pub ts_us: f64,
    /// Duration in microseconds (complete events; 0 for instants).
    pub dur_us: f64,
    /// Recorder thread id (assigned per thread at first record).
    pub tid: u64,
    /// Optional single numeric argument (e.g. batch size).
    pub arg: Option<(&'static str, f64)>,
}

/// Per-thread event buffer: spans push here without touching the global
/// collector lock; the buffer flushes when full, on [`flush_thread`], and
/// when the owning thread exits (TLS destructor).
pub struct SpanRecorder {
    tid: u64,
    buf: Vec<TraceEvent>,
}

const FLUSH_AT: usize = 1024;

impl SpanRecorder {
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        if let Some(state) = STATE.get() {
            state.sink.lock().unwrap().append(&mut self.buf);
        }
    }
}

impl Drop for SpanRecorder {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static RECORDER: RefCell<Option<SpanRecorder>> = const { RefCell::new(None) };
}

fn ts_us(state: &TraceState, t: Instant) -> f64 {
    t.checked_duration_since(state.epoch)
        .unwrap_or_default()
        .as_secs_f64()
        * 1e6
}

fn record(state: &TraceState, mut ev: TraceEvent) {
    RECORDER.with(|r| {
        let mut r = r.borrow_mut();
        let rec = r.get_or_insert_with(|| SpanRecorder {
            tid: state.next_tid.fetch_add(1, Ordering::Relaxed),
            buf: Vec::new(),
        });
        ev.tid = rec.tid;
        rec.buf.push(ev);
        if rec.buf.len() >= FLUSH_AT {
            rec.flush();
        }
    });
}

/// Scoped span guard: records one complete event (begin at construction,
/// end at drop).  Inert when its level was not [`enabled`].
#[must_use = "a span records its duration on drop; bind it to a guard"]
pub struct Span(Option<SpanLive>);

struct SpanLive {
    name: String,
    cat: &'static str,
    start: Instant,
    arg: Option<(&'static str, f64)>,
}

impl Span {
    /// Attach one numeric argument (shows under `args` in the trace UI).
    pub fn arg(mut self, key: &'static str, v: f64) -> Span {
        if let Some(l) = self.0.as_mut() {
            l.arg = Some((key, v));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(l) = self.0.take() {
            let Some(state) = STATE.get() else { return };
            record(
                state,
                TraceEvent {
                    ts_us: ts_us(state, l.start),
                    dur_us: l.start.elapsed().as_secs_f64() * 1e6,
                    name: l.name,
                    cat: l.cat,
                    ph: 'X',
                    tid: 0,
                    arg: l.arg,
                },
            );
        }
    }
}

/// Begin a span with a static name.
pub fn span(min: TraceLevel, name: &'static str, cat: &'static str) -> Span {
    if !enabled(min) {
        return Span(None);
    }
    Span(Some(SpanLive { name: name.to_string(), cat, start: Instant::now(), arg: None }))
}

/// Begin a span with a lazily formatted name (the closure only runs when
/// the level is enabled, so call sites pay nothing with tracing off).
pub fn span_with<F: FnOnce() -> String>(min: TraceLevel, cat: &'static str, name: F) -> Span {
    if !enabled(min) {
        return Span(None);
    }
    Span(Some(SpanLive { name: name(), cat, start: Instant::now(), arg: None }))
}

/// Record a complete event whose start was captured earlier (e.g. queue
/// wait measured from enqueue time), ending now.
pub fn complete_from(min: TraceLevel, name: &'static str, cat: &'static str, start: Instant) {
    if !enabled(min) {
        return;
    }
    let Some(state) = STATE.get() else { return };
    record(
        state,
        TraceEvent {
            ts_us: ts_us(state, start),
            dur_us: start.elapsed().as_secs_f64() * 1e6,
            name: name.to_string(),
            cat,
            ph: 'X',
            tid: 0,
            arg: None,
        },
    );
}

/// Record an instant event (an edge: reject, steal, hedge, requeue).
pub fn instant(
    min: TraceLevel,
    name: &'static str,
    cat: &'static str,
    arg: Option<(&'static str, f64)>,
) {
    if !enabled(min) {
        return;
    }
    let Some(state) = STATE.get() else { return };
    record(
        state,
        TraceEvent {
            ts_us: ts_us(state, Instant::now()),
            dur_us: 0.0,
            name: name.to_string(),
            cat,
            ph: 'i',
            tid: 0,
            arg,
        },
    );
}

/// Flush the calling thread's recorder into the collector.  Worker
/// threads flush automatically on exit; the main thread calls this (via
/// [`drain`]) before export.
pub fn flush_thread() {
    RECORDER.with(|r| {
        if let Some(rec) = r.borrow_mut().as_mut() {
            rec.flush();
        }
    });
}

/// Flush the calling thread, then take every event collected so far.
pub fn drain() -> Vec<TraceEvent> {
    let Some(state) = STATE.get() else { return Vec::new() };
    flush_thread();
    std::mem::take(&mut *state.sink.lock().unwrap())
}

/// Render events as a Chrome `chrome://tracing` / Perfetto JSON object.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let evs = events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", Json::Str(e.name.clone())),
                ("cat", Json::Str(e.cat.to_string())),
                ("ph", Json::Str(e.ph.to_string())),
                ("ts", Json::Num(e.ts_us)),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(e.tid as f64)),
            ];
            if e.ph == 'X' {
                fields.push(("dur", Json::Num(e.dur_us)));
            } else if e.ph == 'i' {
                // instant scope: thread
                fields.push(("s", Json::Str("t".to_string())));
            }
            if let Some((k, v)) = e.arg {
                fields.push(("args", Json::obj(vec![(k, Json::Num(v))])));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Write events to `path` as Chrome trace JSON.
pub fn write_chrome_trace(path: &str, events: &[TraceEvent]) -> crate::Result<()> {
    let mut s = chrome_trace_json(events).to_string();
    s.push('\n');
    std::fs::write(path, s)
        .map_err(|e| anyhow::anyhow!("writing trace to {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stox_trace_parses_all_levels_and_defers_auto() {
        assert_eq!(parse_stox_trace("").unwrap(), None);
        assert_eq!(parse_stox_trace("auto").unwrap(), None);
        assert_eq!(parse_stox_trace("off").unwrap(), Some(TraceLevel::Off));
        assert_eq!(parse_stox_trace(" Request ").unwrap(), Some(TraceLevel::Request));
        assert_eq!(parse_stox_trace("layer").unwrap(), Some(TraceLevel::Layer));
        assert_eq!(parse_stox_trace("kernel").unwrap(), Some(TraceLevel::Kernel));
    }

    #[test]
    fn stox_trace_fails_loudly_with_offending_value() {
        for bad in ["on", "1", "full", "serve"] {
            let err = parse_stox_trace(bad).unwrap_err().to_string();
            assert!(err.contains("STOX_TRACE"), "{err}");
            assert!(err.contains(bad), "error must carry the value: {err}");
        }
    }

    #[test]
    fn levels_nest_in_order() {
        assert!(TraceLevel::Off < TraceLevel::Request);
        assert!(TraceLevel::Request < TraceLevel::Layer);
        assert!(TraceLevel::Layer < TraceLevel::Kernel);
    }

    #[test]
    fn chrome_trace_json_shape() {
        let events = vec![
            TraceEvent {
                name: "execute".into(),
                cat: "serve",
                ph: 'X',
                ts_us: 10.0,
                dur_us: 5.5,
                tid: 1,
                arg: Some(("batch", 4.0)),
            },
            TraceEvent {
                name: "steal".into(),
                cat: "serve",
                ph: 'i',
                ts_us: 20.0,
                dur_us: 0.0,
                tid: 2,
                arg: None,
            },
        ];
        let j = chrome_trace_json(&events);
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(evs[0].get("dur").and_then(|v| v.as_f64()), Some(5.5));
        assert_eq!(
            evs[0].at(&["args", "batch"]).and_then(|v| v.as_f64()),
            Some(4.0)
        );
        assert_eq!(evs[1].get("ph").and_then(|v| v.as_str()), Some("i"));
        assert_eq!(evs[1].get("s").and_then(|v| v.as_str()), Some("t"));
        assert_eq!(j.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    }

    // one test owns all global-level mutation (LEVEL is process state;
    // parallel tests toggling it would race each other)
    #[cfg(feature = "obs")]
    #[test]
    fn span_gating_and_recording() {
        // below-threshold and Off-level guards are inert
        set_level(TraceLevel::Off);
        drop(span(TraceLevel::Request, "obs_test_gated", "serve"));
        install(TraceLevel::Request);
        // a Layer-level span must not record at Request
        drop(span(TraceLevel::Layer, "obs_test_gated", "model"));
        {
            let _g = span(TraceLevel::Request, "obs_test_span", "serve").arg("batch", 3.0);
        }
        instant(TraceLevel::Request, "obs_test_edge", "serve", None);
        // other tests may be recording concurrently — assert containment,
        // not exact contents
        let evs = drain();
        assert!(!evs.iter().any(|e| e.name == "obs_test_gated"));
        assert!(evs.iter().any(|e| e.ph == 'X' && e.name == "obs_test_span"));
        assert!(evs.iter().any(|e| e.ph == 'i' && e.name == "obs_test_edge"));
        set_level(TraceLevel::Off);
    }
}
