//! Unified telemetry plane: deterministic hardware counters and
//! request-path spans.
//!
//! Two sub-planes with different contracts:
//!
//! * [`counters`] — named monotonic `u64` counters grouped in
//!   [`CounterRegistry`] sets.  The counting path is a single relaxed
//!   `fetch_add` on a pre-resolved [`Counter`] handle (lock-free); a
//!   handle that was never attached to a registry is a no-op, so
//!   un-instrumented runs pay one predictable branch.  Counter totals are
//!   sums of per-task contributions derived from the counter-RNG
//!   execution contract, so wherever the per-task work is deterministic
//!   the totals are too — two same-seed runs snapshot byte-identically
//!   (see [`CounterRegistry::to_json`]).  Counters are *not* gated by the
//!   `obs` cargo feature: they are data-plane invariants that the
//!   scenario goldens pin across every feature combination CI builds.
//! * [`span`] — per-thread [`SpanRecorder`] buffers of begin/end events
//!   behind scoped [`Span`] guards, exported as Chrome `chrome://tracing`
//!   JSON (`stox-cli serve --trace out.json`).  Recording is compiled to
//!   a no-op unless the default `obs` cargo feature is on, and records
//!   nothing unless a collector is installed ([`span::install`]) *and*
//!   the requested [`TraceLevel`] is enabled — the digit-plane hot path
//!   keeps its bench-enforced <2% overhead bound with tracing off.
//!
//! The `STOX_TRACE` environment variable selects the trace level
//! (`auto|off|request|layer|kernel`) and fails loudly on anything else,
//! mirroring the `STOX_SIMD` contract ([`span::parse_stox_trace`]).

pub mod counters;
pub mod span;

pub use counters::{global, Counter, CounterRegistry};
pub use span::{Span, SpanRecorder, TraceLevel};
