//! Lock-free monotonic counter plane.
//!
//! A [`CounterRegistry`] is a named set of `u64` cells.  Registration
//! (name → cell) takes a `Mutex` once per counter at *setup* time; the
//! counting path is a single relaxed `fetch_add` on a pre-resolved
//! [`Counter`] handle — lock-free and wait-free.  A default-constructed
//! [`Counter`] (never attached to a registry) is a no-op, so hot kernels
//! carry their handles unconditionally and pay one predictable branch
//! when telemetry is off.
//!
//! Determinism: totals are sums of per-task contributions and `u64`
//! addition commutes, so totals are independent of worker scheduling
//! wherever the per-task contributions are themselves deterministic (the
//! counter-RNG execution contract guarantees this for the digit-plane
//! kernel and converter layers).  [`CounterRegistry::to_json`] renders a
//! snapshot as a sorted-key JSON object (`Json::Obj` is a `BTreeMap`), so
//! two same-seed runs serialize byte-identically.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// A named set of monotonic counters.  Cheap to create; models attach one
/// per inference context so concurrent runs never cross-contaminate.
#[derive(Default)]
pub struct CounterRegistry {
    cells: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

impl CounterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve (registering on first use) the named counter.  Call at
    /// setup time and keep the returned handle — resolution locks, but
    /// counting through the handle does not.
    pub fn counter(&self, name: &str) -> Counter {
        let mut cells = self.cells.lock().unwrap();
        let cell = cells
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)));
        Counter(Some(Arc::clone(cell)))
    }

    /// Current value of `name` (0 when never registered).
    pub fn get(&self, name: &str) -> u64 {
        self.cells
            .lock()
            .unwrap()
            .get(name)
            .map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// Name-sorted `(name, value)` snapshot.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.cells
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Snapshot as a JSON object.  Keys sort (`Json::Obj` is a
    /// `BTreeMap`), so two same-seed runs serialize byte-identically —
    /// the contract the `infer_counters_*` scenario goldens pin.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.snapshot()
                .into_iter()
                // counts stay far below 2^53, so f64 holds them exactly
                // and the writer prints them as integers
                .map(|(k, v)| (k, Json::Num(v as f64)))
                .collect(),
        )
    }
}

/// Pre-resolved handle to one registry cell.  The default handle is
/// detached and counts nothing.
#[derive(Clone, Default)]
pub struct Counter(Option<Arc<AtomicU64>>);

impl Counter {
    /// A handle that counts nothing (what un-instrumented runs carry).
    pub const fn disabled() -> Self {
        Counter(None)
    }

    /// Whether this handle is attached to a registry cell.
    #[inline]
    pub fn is_attached(&self) -> bool {
        self.0.is_some()
    }

    /// Add `n` (relaxed; totals are order-independent).
    #[inline]
    pub fn add(&self, n: u64) {
        if let Some(c) = &self.0 {
            c.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Add 1.
    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    /// Current value (0 when detached).
    pub fn get(&self) -> u64 {
        self.0.as_ref().map_or(0, |c| c.load(Ordering::Relaxed))
    }
}

/// Process-global registry for host-level counters that do not belong to
/// any one model — e.g. `simd.select.<backend>` (which MAC backend
/// [`crate::imc::simd::MacBackend::detect`] picked at crossbar-programming
/// time).  Host-dependent by design, so it is reported by the CLI but
/// never pinned by scenario goldens.
pub fn global() -> &'static CounterRegistry {
    static GLOBAL: OnceLock<CounterRegistry> = OnceLock::new();
    GLOBAL.get_or_init(CounterRegistry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detached_counter_is_a_noop() {
        let c = Counter::disabled();
        c.add(5);
        c.incr();
        assert!(!c.is_attached());
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn attached_counter_accumulates() {
        let reg = CounterRegistry::new();
        let c = reg.counter("a.macs");
        assert!(c.is_attached());
        c.add(3);
        c.incr();
        assert_eq!(c.get(), 4);
        assert_eq!(reg.get("a.macs"), 4);
        assert_eq!(reg.get("never.registered"), 0);
    }

    #[test]
    fn same_name_resolves_to_same_cell() {
        let reg = CounterRegistry::new();
        reg.counter("x").add(1);
        reg.counter("x").add(2);
        assert_eq!(reg.get("x"), 3);
    }

    #[test]
    fn snapshot_is_name_sorted_and_json_is_integer_valued() {
        let reg = CounterRegistry::new();
        reg.counter("b").add(2);
        reg.counter("a").add(1);
        reg.counter("c").add(3);
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, ["a", "b", "c"]);
        assert_eq!(reg.to_json().to_string(), r#"{"a":1,"b":2,"c":3}"#);
    }

    #[test]
    fn concurrent_adds_sum_exactly() {
        let reg = CounterRegistry::new();
        let c = reg.counter("hits");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.incr();
                    }
                });
            }
        });
        assert_eq!(reg.get("hits"), 4000);
    }
}
