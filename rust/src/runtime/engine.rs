//! The PJRT execution engine: one compiled executable per artifact.

use crate::model::weights::Manifest;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

/// A compiled model executable + its I/O geometry.
pub struct ModelHandle {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub in_elems: usize,
    pub out_elems: usize,
    in_dims: Vec<i64>,
    pub compile_ms: f64,
}

impl ModelHandle {
    /// Execute on a batch of images (NHWC flattened) with a sampling seed.
    /// Returns the logits.
    pub fn infer(&self, images: &[f32], seed: u32) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(
            images.len() == self.in_elems,
            "expected {} input elements, got {}",
            self.in_elems,
            images.len()
        );
        let x = xla::Literal::vec1(images);
        // the AOT fn signature is (x[B,H,W,C], seed u32) -> (logits,)
        let x = self.reshape_input(x)?;
        let seed_lit = xla::Literal::scalar(seed);
        let result = self.exe.execute::<xla::Literal>(&[x, seed_lit])?[0][0]
            .to_literal_sync()?;
        let tuple = result.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }

    fn reshape_input(&self, x: xla::Literal) -> crate::Result<xla::Literal> {
        Ok(x.reshape(&self.in_dims)?)
    }
}

/// The runtime engine: PJRT client + compiled executables by batch size.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    models: HashMap<usize, ModelHandle>,
    pub platform: String,
}

impl Engine {
    /// Create the CPU PJRT client and compile every model artifact listed
    /// in the manifest.
    pub fn load(manifest: &Manifest) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let platform = client.platform_name();
        let mut models = HashMap::new();
        let spec = &manifest.spec;
        let img_elems = spec.image_size * spec.image_size * spec.in_channels;
        for entry in &manifest.models {
            let path = manifest.dir.join(&entry.file);
            let handle = Self::compile_model(
                &client,
                &path,
                entry.batch,
                [
                    entry.batch as i64,
                    spec.image_size as i64,
                    spec.image_size as i64,
                    spec.in_channels as i64,
                ],
                entry.batch * img_elems,
                entry.batch * spec.num_classes,
            )?;
            models.insert(entry.batch, handle);
        }
        Ok(Self { client, models, platform })
    }

    fn compile_model(
        client: &xla::PjRtClient,
        path: &Path,
        batch: usize,
        in_dims: [i64; 4],
        in_elems: usize,
        out_elems: usize,
    ) -> crate::Result<ModelHandle> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(ModelHandle {
            exe,
            batch,
            in_elems,
            out_elems,
            in_dims: in_dims.to_vec(),
            compile_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Available serving batch sizes (sorted).
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.models.keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn model(&self, batch: usize) -> Option<&ModelHandle> {
        self.models.get(&batch)
    }

    /// Largest compiled batch ≤ `n`, falling back to the smallest.
    pub fn best_model_for(&self, n: usize) -> Option<&ModelHandle> {
        let sizes = self.batch_sizes();
        let pick = sizes
            .iter()
            .rev()
            .find(|&&b| b <= n)
            .or_else(|| sizes.first())?;
        self.models.get(pick)
    }
}

impl ModelHandle {
    pub fn output_classes(&self) -> usize {
        self.out_elems / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<Manifest> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json")
            .exists()
            .then(|| Manifest::load(p).unwrap())
    }

    #[test]
    fn engine_loads_and_infers() {
        let Some(m) = artifacts() else { return };
        let engine = Engine::load(&m).unwrap();
        assert!(!engine.batch_sizes().is_empty());
        let h = engine.model(1).unwrap();
        let img = vec![0.1f32; h.in_elems];
        let logits = h.infer(&img, 7).unwrap();
        assert_eq!(logits.len(), h.out_elems);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn inference_seed_determinism() {
        let Some(m) = artifacts() else { return };
        let engine = Engine::load(&m).unwrap();
        let h = engine.model(1).unwrap();
        let img = vec![0.3f32; h.in_elems];
        let l1 = h.infer(&img, 5).unwrap();
        let l2 = h.infer(&img, 5).unwrap();
        let l3 = h.infer(&img, 6).unwrap();
        assert_eq!(l1, l2, "same seed → same stochastic bits");
        assert_ne!(l1, l3, "different seed → different sampling");
    }

    #[test]
    fn best_model_selection() {
        let Some(m) = artifacts() else { return };
        let engine = Engine::load(&m).unwrap();
        assert_eq!(engine.best_model_for(8).unwrap().batch, 8);
        assert_eq!(engine.best_model_for(3).unwrap().batch, 1);
        assert_eq!(engine.best_model_for(100).unwrap().batch, 8);
    }

    #[test]
    fn wrong_input_size_rejected() {
        let Some(m) = artifacts() else { return };
        let engine = Engine::load(&m).unwrap();
        let h = engine.model(1).unwrap();
        assert!(h.infer(&[0.0; 3], 0).is_err());
    }
}
