//! PJRT runtime: loads the HLO-text artifacts produced by the python AOT
//! path and executes them on the request path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`) — jax ≥0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Python never runs here: after `make artifacts`, the binary is
//! self-contained.

pub mod engine;

pub use engine::{Engine, ModelHandle};
