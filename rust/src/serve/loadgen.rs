//! Closed-loop load generator with open-loop Poisson arrivals.
//!
//! Each rate point submits `requests_per_step` requests on a Poisson
//! arrival schedule (inter-arrival `−ln(1−u)/λ`, drawn from the
//! deterministic counter RNG so a sweep is reproducible), waits for every
//! reply (closed loop), and reads the throughput/latency/SLO columns off
//! the replica server's [`crate::serve::ServeMetrics`].  The sweep
//! doubles the offered
//! rate until saturation — achieved throughput falling below
//! `saturation_frac ×` offered, or admission control shedding load — and
//! serializes the curve as `BENCH_serving.json` through
//! [`crate::util::bench::BenchSuite`] (per-case timing columns plus the
//! serving extras; schema in README §Serving).

use super::replica::{ReplicaConfig, ReplicaServer};
use crate::coordinator::server::submit_all;
use crate::model::NativeModel;
use crate::stats::rng::CounterRng;
use crate::util::bench::{BenchResult, BenchSuite};
use crate::util::json::Json;
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// First offered arrival rate (requests/s).
    pub start_rps: f64,
    /// Rate multiplier between sweep steps.
    pub growth: f64,
    /// Maximum number of rate points.
    pub steps: usize,
    /// Requests submitted per rate point.
    pub requests_per_step: usize,
    /// Saturation cut: stop once achieved < `saturation_frac` × offered.
    pub saturation_frac: f64,
    /// Pacing seed (rate point `i` paces with `seed + i`).
    pub seed: u32,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            start_rps: 64.0,
            growth: 2.0,
            steps: 6,
            requests_per_step: 64,
            saturation_frac: 0.9,
            seed: 7,
        }
    }
}

/// One point of the throughput–latency curve.
#[derive(Debug, Clone)]
pub struct RatePoint {
    pub offered_rps: f64,
    /// Successfully served requests / wall-clock of the whole point.
    pub achieved_rps: f64,
    pub requests: usize,
    pub ok: u64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
    pub mean_us: f64,
    pub min_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub slo_attainment: f64,
}

fn pct_or_zero(v: f32) -> f64 {
    if v.is_finite() { v as f64 } else { 0.0 }
}

/// Run one rate point against a fresh replica server over `model`.
///
/// `images` are cycled to fill `n` requests.  The pacing schedule is
/// absolute (each request has a precomputed send time), so a slow server
/// does not throttle the offered load — the open-loop half of the
/// harness; the closed-loop half waits for every reply before returning.
pub fn run_rate(
    model: &NativeModel,
    cfg: &ReplicaConfig,
    images: &[Vec<f32>],
    rate: f64,
    n: usize,
    pace_seed: u32,
) -> RatePoint {
    assert!(rate > 0.0 && n > 0 && !images.is_empty());
    let server = ReplicaServer::from_native(model, cfg.clone());
    let (tx, rx) = mpsc::channel();
    let imgs: Vec<Vec<f32>> = (0..n).map(|i| images[i % images.len()].clone()).collect();
    let t_start = Instant::now();
    let client = std::thread::spawn(move || {
        let rng = CounterRng::new(pace_seed);
        let t0 = Instant::now();
        let mut sched = Duration::ZERO;
        let mut replies = Vec::with_capacity(n);
        for (i, image) in imgs.into_iter().enumerate() {
            let u = rng.uniform(i as u32).min(0.999_999);
            sched += Duration::from_secs_f64((-(1.0 - u as f64).ln()) / rate);
            if let Some(rem) = sched.checked_sub(t0.elapsed()) {
                std::thread::sleep(rem);
            }
            replies.extend(submit_all(&tx, std::iter::once(image)));
        }
        drop(tx);
        replies
    });
    server.run(rx);
    let replies = client.join().unwrap();

    let mut ok = 0u64;
    for r in replies {
        let rep = r.recv().expect("reply delivered, never dropped");
        if rep.result.is_ok() {
            ok += 1;
        }
    }
    let wall = t_start.elapsed().as_secs_f64().max(1e-9);
    let m = &server.metrics;
    RatePoint {
        offered_rps: rate,
        achieved_rps: ok as f64 / wall,
        requests: n,
        ok,
        rejected: m.rejected(),
        deadline_exceeded: m.deadline_exceeded(),
        mean_us: m.mean_latency_us(),
        min_us: m.min_latency_us(),
        p50_us: pct_or_zero(m.latency_percentile_us(50.0)),
        p95_us: pct_or_zero(m.latency_percentile_us(95.0)),
        p99_us: pct_or_zero(m.latency_percentile_us(99.0)),
        p999_us: pct_or_zero(m.latency_percentile_us(99.9)),
        slo_attainment: m.slo_attainment(),
    }
}

/// Sweep offered rates to saturation; returns the curve and the
/// `BENCH_serving` suite (call
/// [`BenchSuite::write_json`]/[`BenchSuite::write_json_to`] to emit the
/// artifact).
pub fn run_sweep(
    model: &NativeModel,
    cfg: &ReplicaConfig,
    images: &[Vec<f32>],
    lg: &LoadGenConfig,
) -> (Vec<RatePoint>, BenchSuite) {
    let mut suite = BenchSuite::new("serving");
    let mut points: Vec<RatePoint> = Vec::new();
    let mut rate = lg.start_rps;
    for step in 0..lg.steps {
        let p = run_rate(
            model,
            cfg,
            images,
            rate,
            lg.requests_per_step,
            lg.seed.wrapping_add(step as u32),
        );
        println!(
            "loadgen: offered {:>8.1} rps → achieved {:>8.1} rps  p99 {:>8.0} µs  \
             slo {:.3}  rejected {}",
            p.offered_rps, p.achieved_rps, p.p99_us, p.slo_attainment, p.rejected
        );
        suite.record_with(rate_point_result(&p), rate_point_extras(&p, cfg.replicas));
        let saturated = p.rejected > 0
            || p.deadline_exceeded > 0
            || p.achieved_rps < lg.saturation_frac * p.offered_rps;
        points.push(p);
        if saturated {
            break;
        }
        rate *= lg.growth;
    }
    (points, suite)
}

fn us(v: f64) -> Duration {
    Duration::from_secs_f64(v.max(0.0) * 1e-6)
}

fn rate_point_result(p: &RatePoint) -> BenchResult {
    BenchResult {
        name: format!("rate-{:.0}rps", p.offered_rps),
        iters: p.requests,
        mean: us(p.mean_us),
        p50: us(p.p50_us),
        p95: us(p.p95_us),
        min: us(p.min_us),
    }
}

fn rate_point_extras(p: &RatePoint, replicas: usize) -> Vec<(String, Json)> {
    vec![
        ("replicas".into(), Json::Num(replicas as f64)),
        ("offered_rps".into(), Json::Num(p.offered_rps)),
        ("achieved_rps".into(), Json::Num(p.achieved_rps)),
        ("ok".into(), Json::Num(p.ok as f64)),
        ("rejected".into(), Json::Num(p.rejected as f64)),
        ("deadline_exceeded".into(), Json::Num(p.deadline_exceeded as f64)),
        ("p99_us".into(), Json::Num(p.p99_us)),
        ("p999_us".into(), Json::Num(p.p999_us)),
        ("slo_attainment".into(), Json::Num(p.slo_attainment)),
    ]
}
