//! Deterministic fault injection for the serving tier, and the `chaos`
//! sweep driver built on it.
//!
//! A [`FaultPlan`] describes per-shard infrastructure faults — crash
//! windows, transient per-batch error probability, latency spikes, and a
//! corrupted-logits mode — and a [`FaultInjector`] evaluates the plan at
//! batch-execution time.  Every stochastic decision is drawn from the
//! counter-keyed [`CounterRng`], keyed by the *batch seed and attempt
//! number* rather than by wall clock or shard assignment, so a fault
//! schedule replays bit-identically run after run: the same batches fail,
//! the same requeues happen, the same requests succeed.
//!
//! Two fault classes, two determinism strengths:
//!
//! * **transient errors** are keyed by `(job seed, attempt)` only — which
//!   shard a batch happened to land on never enters the draw, so counts
//!   of ok/error/requeued replies are reproducible even though shard
//!   assignment is racy.  [`run_chaos`] sweeps this severity axis and its
//!   `BENCH_chaos.json` is byte-identical across runs of the same seed.
//! * **crash windows / latency spikes / corruption** are per-shard state
//!   (the crash counter counts batches *executed on that shard*), so
//!   which batches they hit depends on scheduling.  The per-request
//!   invariants (exactly one reply; bit-identical logits after
//!   self-healing) still hold and are pinned by the `chaos` harness
//!   scenarios — but aggregate counts under these faults are not
//!   byte-stable, so the chaos sweep artifact does not include them.
//!
//! With the plan disabled ([`FaultPlan::disabled`], the default) the
//! injector is completely inert and the serving path is bit-identical to
//! the fault-free tier.

use super::health::ResilienceConfig;
use super::replica::{ReplicaConfig, ReplicaServer};
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::server::submit_all;
use crate::imc::PsConverterSpec;
use crate::model::NativeModel;
use crate::stats::rng::CounterRng;
use crate::util::bench::{BenchResult, BenchSuite};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::Duration;

/// Faults configured for one shard; the default is benign (no faults).
#[derive(Debug, Clone, Default)]
pub struct ShardFaults {
    /// Shard errors every batch from its `crash_at_batch`-th executed
    /// batch (0-based) onward …
    pub crash_at_batch: Option<u64>,
    /// … until (exclusive) its `recover_at_batch`-th executed batch;
    /// `None` = the shard never comes back.
    pub recover_at_batch: Option<u64>,
    /// Per-(batch, attempt) probability of an injected transient error,
    /// drawn shard-independently from the plan RNG.
    pub transient_error_prob: f32,
    /// Added execution latency when a spike fires.
    pub latency_spike: Option<Duration>,
    /// Per-batch probability that [`ShardFaults::latency_spike`] fires.
    pub latency_spike_prob: f32,
    /// Deterministically corrupt this shard's logits (a silently-wrong
    /// replica, as opposed to a loudly-failing one).
    pub corrupt_logits: bool,
}

impl ShardFaults {
    pub fn is_benign(&self) -> bool {
        self.crash_at_batch.is_none()
            && self.transient_error_prob == 0.0
            && (self.latency_spike.is_none() || self.latency_spike_prob == 0.0)
            && !self.corrupt_logits
    }
}

/// A full fault schedule: one [`ShardFaults`] per shard plus the RNG seed
/// every probabilistic draw is keyed under.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u32,
    pub shards: Vec<ShardFaults>,
}

impl FaultPlan {
    /// The inert plan (no shards, no faults) — the default for every
    /// server; guarantees bit-identity with the fault-free path.
    pub fn disabled() -> Self {
        Self { seed: 0, shards: Vec::new() }
    }

    /// The same transient-error probability on every shard — the
    /// severity axis of the chaos sweep.  Because the draw is keyed by
    /// `(job seed, attempt)` and not by shard, uniform plans produce
    /// reproducible reply counts regardless of scheduling.
    pub fn uniform_transient(seed: u32, replicas: usize, prob: f32) -> Self {
        Self {
            seed,
            shards: (0..replicas)
                .map(|_| ShardFaults { transient_error_prob: prob, ..Default::default() })
                .collect(),
        }
    }

    pub fn is_disabled(&self) -> bool {
        self.shards.iter().all(|s| s.is_benign())
    }

    fn for_shard(&self, si: usize) -> ShardFaults {
        self.shards.get(si).cloned().unwrap_or_default()
    }
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::disabled()
    }
}

/// What the injector decided for one batch execution.
#[derive(Debug, Default)]
pub struct FaultDecision {
    /// Sleep this long before executing (a straggler shard).
    pub spike: Option<Duration>,
    /// Fail the batch with this message instead of executing.
    pub error: Option<String>,
    /// Execute, then corrupt the logits.
    pub corrupt: bool,
}

/// Evaluates a [`FaultPlan`] at execution time; holds the per-shard
/// executed-batch counters that drive crash windows.
pub struct FaultInjector {
    plan: FaultPlan,
    executed: Vec<AtomicU64>,
}

const TRANSIENT_SALT: u32 = 0x00FA_0017;
const SPIKE_SALT: u32 = 0x00FA_5B1E;
const CORRUPT_SALT: u32 = 0x0BAD_F00D;

impl FaultInjector {
    pub fn new(plan: FaultPlan, replicas: usize) -> Self {
        Self { plan, executed: (0..replicas).map(|_| AtomicU64::new(0)).collect() }
    }

    pub fn enabled(&self) -> bool {
        !self.plan.is_disabled()
    }

    /// Counter key for a `(job seed, attempt)` pair: requeued attempts of
    /// the same batch get independent draws, but the key never involves
    /// the executing shard.
    fn attempt_counter(job_seed: u32, attempt: u32) -> u32 {
        job_seed.wrapping_add(attempt.wrapping_mul(0x9E37_79B9))
    }

    /// Decide the fate of one batch execution on `si`.  Also advances the
    /// shard's executed-batch counter (crash windows count every batch
    /// the shard was asked to run, probes included).
    pub fn decide(&self, si: usize, job_seed: u32, attempt: u32) -> FaultDecision {
        if !self.enabled() {
            return FaultDecision::default();
        }
        let f = self.plan.for_shard(si);
        let k = self.executed[si].fetch_add(1, Ordering::SeqCst);
        let mut d = FaultDecision::default();
        if let Some(at) = f.crash_at_batch {
            let recovered = f.recover_at_batch.map(|r| k >= r).unwrap_or(false);
            if k >= at && !recovered {
                d.error = Some(format!("injected fault: shard {si} crashed (batch {k})"));
                return d;
            }
        }
        let c = Self::attempt_counter(job_seed, attempt);
        if f.transient_error_prob > 0.0 {
            let rng = CounterRng::new(self.plan.seed ^ TRANSIENT_SALT);
            if rng.uniform(c) < f.transient_error_prob {
                d.error = Some("injected fault: transient batch error".to_string());
                return d;
            }
        }
        if let (Some(spike), p) = (f.latency_spike, f.latency_spike_prob) {
            if p > 0.0 {
                let rng = CounterRng::new(self.plan.seed ^ SPIKE_SALT);
                if rng.uniform(c) < p {
                    d.spike = Some(spike);
                }
            }
        }
        d.corrupt = f.corrupt_logits;
        d
    }

    /// Deterministically corrupt a batch's logits (keyed by the plan seed
    /// and the job seed — reproducible garbage, not random garbage).
    pub fn corrupt(&self, logits: &mut [f32], job_seed: u32) {
        let rng = CounterRng::new(self.plan.seed ^ CORRUPT_SALT);
        for (i, v) in logits.iter_mut().enumerate() {
            *v = -*v + rng.uniform_in(job_seed.wrapping_add(i as u32), -1.0, 1.0);
        }
    }
}

/// Configuration of the `stox-cli chaos` sweep: fault severity (uniform
/// transient-error probability) × offered load (pre-queued burst size).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Transient-error probabilities to sweep (0.0 = the fault-free leg).
    pub severities: Vec<f64>,
    /// Pre-queued request-burst sizes to sweep.
    pub loads: Vec<usize>,
    pub replicas: usize,
    pub target_batch: usize,
    pub seed: u32,
    /// Requeue budget per batch under injected faults.
    pub max_requeues: u32,
    /// Run every leg in brown-out: execute on short-sampling degraded
    /// converters (`DEGRADED`-flagged replies).
    pub brownout: bool,
    /// Converter spec of the degraded executors (brown-out legs).
    pub brownout_spec: String,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            severities: vec![0.0, 0.1, 0.3],
            loads: vec![32],
            replicas: 2,
            target_batch: 4,
            seed: 7,
            max_requeues: 3,
            brownout: false,
            brownout_spec: "stox:samples=1".to_string(),
        }
    }
}

/// One (severity, load) leg of the chaos sweep.  The first nine fields
/// are deterministic per seed; `evicted`/`reintegrated` depend on which
/// shard absorbed the injected errors and are reported for inspection
/// but excluded from the byte-stable bench artifact.
#[derive(Debug, Clone)]
pub struct ChaosPoint {
    pub severity: f64,
    pub load: usize,
    pub ok: u64,
    pub degraded: u64,
    pub errors: u64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
    pub requeued: u64,
    pub checksum: f64,
    pub evicted: u64,
    pub reintegrated: u64,
}

/// Sweep fault severity × offered load over a fresh self-healing replica
/// tier per leg; returns the points and the `BENCH_chaos.json` suite.
///
/// Determinism contract: every recorded extra (and the checksum over all
/// `Ok` logits) is a pure function of `(model, cfg)` — timings are
/// zeroed, loads are pre-queued (no pacing), fault draws are keyed
/// shard-independently — so two runs of the same seed emit byte-identical
/// artifacts (CI `chaos-smoke` byte-compares them).
pub fn run_chaos(
    model: &NativeModel,
    cfg: &ChaosConfig,
) -> crate::Result<(Vec<ChaosPoint>, BenchSuite)> {
    let degraded = if cfg.brownout {
        let spec = PsConverterSpec::from_mode(&cfg.brownout_spec, 4.0, 1)?;
        Some(model.share_with_converter_spec(&spec)?)
    } else {
        None
    };
    let mut points = Vec::new();
    let mut suite = BenchSuite::new("chaos");
    for &severity in &cfg.severities {
        for &load in &cfg.loads {
            let p = run_chaos_leg(model, degraded.as_ref(), cfg, severity, load)?;
            let extras = vec![
                ("severity".to_string(), Json::Num(p.severity)),
                ("load".to_string(), Json::Num(p.load as f64)),
                ("replicas".to_string(), Json::Num(cfg.replicas as f64)),
                ("ok".to_string(), Json::Num(p.ok as f64)),
                ("degraded".to_string(), Json::Num(p.degraded as f64)),
                ("errors".to_string(), Json::Num(p.errors as f64)),
                ("rejected".to_string(), Json::Num(p.rejected as f64)),
                (
                    "deadline_exceeded".to_string(),
                    Json::Num(p.deadline_exceeded as f64),
                ),
                ("requeued".to_string(), Json::Num(p.requeued as f64)),
                ("checksum".to_string(), Json::Num(p.checksum)),
            ];
            // timings are deliberately zeroed: the artifact pins *what
            // happened*, not how fast, so same-seed runs byte-compare
            let r = BenchResult {
                name: format!("sev{severity}_load{load}"),
                iters: 1,
                mean: Duration::ZERO,
                p50: Duration::ZERO,
                p95: Duration::ZERO,
                min: Duration::ZERO,
            };
            suite.record_with(r, extras);
            points.push(p);
        }
    }
    Ok((points, suite))
}

fn run_chaos_leg(
    model: &NativeModel,
    degraded: Option<&NativeModel>,
    cfg: &ChaosConfig,
    severity: f64,
    load: usize,
) -> crate::Result<ChaosPoint> {
    let rcfg = ReplicaConfig {
        replicas: cfg.replicas,
        batcher: BatcherConfig {
            target_batch: cfg.target_batch,
            // pre-queued burst: batches are cut by size (and the final
            // drain), never by a wall-clock deadline
            max_wait: Duration::from_secs(3600),
        },
        seed: cfg.seed,
        queue_depth: load.max(1),
        deadline: None,
        slo: Duration::from_secs(5),
        steal: true,
        resilience: ResilienceConfig {
            enabled: true,
            evict_consecutive: 2,
            probe_interval: 4,
            max_requeues: cfg.max_requeues,
            // brown-out threshold 0: with a pre-queued burst, outstanding
            // is always > 0 at execution time, so *every* batch of a
            // brown-out leg degrades — deterministically
            brownout_queue: if cfg.brownout { Some(0) } else { None },
            ..Default::default()
        },
    };
    rcfg.validate()?;
    let mut server = ReplicaServer::from_native(model, rcfg)
        .with_fault_plan(FaultPlan::uniform_transient(cfg.seed, cfg.replicas, severity as f32));
    if let Some(dm) = degraded {
        server = server.with_degraded_native(dm);
    }

    let elems = model.image_size * model.image_size * model.in_channels;
    let data_rng = CounterRng::new(cfg.seed ^ 0x0C4A_0500);
    let (tx, rx) = mpsc::channel();
    let replies = submit_all(
        &tx,
        (0..load).map(|r| {
            (0..elems)
                .map(|e| data_rng.uniform_in((r * elems + e) as u32, -1.0, 1.0))
                .collect()
        }),
    );
    drop(tx);
    server.run(rx);

    let mut p = ChaosPoint {
        severity,
        load,
        ok: 0,
        degraded: 0,
        errors: 0,
        rejected: 0,
        deadline_exceeded: 0,
        requeued: server.metrics.requeued(),
        checksum: 0.0,
        evicted: server.metrics.evicted(),
        reintegrated: server.metrics.reintegrated(),
    };
    for r in replies {
        let rep = r.recv().map_err(|_| anyhow::anyhow!("dropped reply channel"))?;
        match rep.result {
            Ok(logits) => {
                p.ok += 1;
                if rep.degraded {
                    p.degraded += 1;
                }
                p.checksum += logits.iter().map(|&v| v as f64).sum::<f64>();
            }
            Err(e) if e == super::replica::REJECTED => p.rejected += 1,
            Err(e) if e == super::replica::DEADLINE_EXCEEDED => p.deadline_exceeded += 1,
            Err(_) => p.errors += 1,
        }
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plan_is_inert() {
        let inj = FaultInjector::new(FaultPlan::disabled(), 2);
        assert!(!inj.enabled());
        for s in 0..2 {
            for b in 0..10u32 {
                let d = inj.decide(s, b, 0);
                assert!(d.error.is_none() && d.spike.is_none() && !d.corrupt);
            }
        }
    }

    #[test]
    fn decisions_are_deterministic_and_shard_independent() {
        let plan = FaultPlan::uniform_transient(9, 3, 0.5);
        let a = FaultInjector::new(plan.clone(), 3);
        let b = FaultInjector::new(plan, 3);
        for seed in 0..64u32 {
            for attempt in 0..3u32 {
                let da = a.decide(seed as usize % 3, seed, attempt);
                // a *different* shard must reach the identical verdict —
                // transient draws are keyed (seed, attempt) only
                let db = b.decide((seed as usize + 1) % 3, seed, attempt);
                assert_eq!(da.error, db.error, "seed {seed} attempt {attempt}");
            }
        }
    }

    #[test]
    fn transient_severity_scales_error_rate() {
        let count = |prob: f32| -> usize {
            let inj = FaultInjector::new(FaultPlan::uniform_transient(3, 1, prob), 1);
            (0..1000u32).filter(|&s| inj.decide(0, s, 0).error.is_some()).count()
        };
        assert_eq!(count(0.0), 0);
        let lo = count(0.1);
        let hi = count(0.6);
        assert!(lo > 30 && lo < 250, "≈10% of draws fail: {lo}");
        assert!(hi > 2 * lo, "higher severity fails more: {hi} vs {lo}");
    }

    #[test]
    fn requeued_attempts_get_independent_draws() {
        let inj = FaultInjector::new(FaultPlan::uniform_transient(3, 1, 0.5), 1);
        let outcomes: Vec<bool> =
            (0..16u32).map(|a| inj.decide(0, 42, a).error.is_some()).collect();
        assert!(outcomes.iter().any(|&e| e) && outcomes.iter().any(|&e| !e),
            "attempts must not all share one fate: {outcomes:?}");
    }

    #[test]
    fn crash_window_opens_and_closes_on_the_shard_batch_counter() {
        let plan = FaultPlan {
            seed: 0,
            shards: vec![ShardFaults {
                crash_at_batch: Some(1),
                recover_at_batch: Some(3),
                ..Default::default()
            }],
        };
        let inj = FaultInjector::new(plan, 1);
        let crashed: Vec<bool> =
            (0..5u32).map(|b| inj.decide(0, b, 0).error.is_some()).collect();
        assert_eq!(crashed, vec![false, true, true, false, false]);
    }

    #[test]
    fn corruption_is_deterministic_and_visible() {
        let plan = FaultPlan {
            seed: 5,
            shards: vec![ShardFaults { corrupt_logits: true, ..Default::default() }],
        };
        let inj = FaultInjector::new(plan, 1);
        assert!(inj.decide(0, 1, 0).corrupt);
        let clean = vec![1.0f32, -2.0, 3.0];
        let mut a = clean.clone();
        let mut b = clean.clone();
        inj.corrupt(&mut a, 11);
        inj.corrupt(&mut b, 11);
        assert_eq!(a, b, "same key ⇒ same garbage");
        assert_ne!(a, clean, "corruption must actually perturb");
        let mut c = clean.clone();
        inj.corrupt(&mut c, 12);
        assert_ne!(a, c, "different job seed ⇒ different garbage");
    }

    #[test]
    fn latency_spike_probability_gates_the_spike() {
        let mk = |p: f32| FaultPlan {
            seed: 1,
            shards: vec![ShardFaults {
                latency_spike: Some(Duration::from_millis(5)),
                latency_spike_prob: p,
                ..Default::default()
            }],
        };
        let always = FaultInjector::new(mk(1.0), 1);
        assert!((0..16u32).all(|s| always.decide(0, s, 0).spike.is_some()));
        let never = FaultInjector::new(mk(0.0), 1);
        assert!((0..16u32).all(|s| never.decide(0, s, 0).spike.is_none()));
    }
}
