//! The sharded replica server: N executors over one programming pass,
//! continuous batching, admission control, and work stealing.
//!
//! Supersedes the single-[`crate::coordinator::Server`] run loop for
//! native-executor serving.  One dispatcher thread (the caller of
//! [`ReplicaServer::run`]) owns the request channel and the
//! [`DynamicBatcher`]; formed batches are stamped with sequence-ordered
//! seeds and placed round-robin onto per-shard work queues, where replica
//! workers execute them — stealing from the longest sibling backlog when
//! their own queue runs dry.
//!
//! # Bit-identity with the single server
//!
//! Batch *formation* is centralized and FIFO, and batch `seq` executes
//! with seed `cfg.seed.wrapping_add(seq)` (`seq` counting from 1) —
//! exactly the `seed.wrapping_add(1)`-per-batch discipline of
//! [`crate::coordinator::Server::run`].  Which shard executes a batch
//! never enters the computation: replicas share the programmed crossbars
//! ([`crate::model::NativeModel::replica_view`]) and the native forward is
//! deterministic per `(images, batch, seed)`.  N-replica serving is
//! therefore bit-identical to the single server for the same requests and
//! seed (pinned by `rust/tests/serve.rs`), while execution parallelizes
//! across shards.
//!
//! Batch *execution* additionally runs layer-pipelined on each shard:
//! `NativeModel::forward` fans the batch's images out to workers that
//! each carry one image through every layer (layer k of image i overlaps
//! layer k−1 of image i+1).  The pipelined forward is bit-identical to
//! the sequential one — the RNG counter contract keys every draw by
//! absolute patch index — so it changes shard throughput, never replies
//! (`replica_view` carries the pipeline switch, so a model with
//! `set_pipeline(false)` serves sequentially on every shard).
//!
//! # Admission control and deadlines
//!
//! The queue is bounded: at most [`ReplicaConfig::queue_depth`] requests
//! may be outstanding (queued or executing); requests beyond that receive
//! an immediate `Err(`[`REJECTED`]`)` reply instead of queueing without
//! bound.  With a [`ReplicaConfig::deadline`], requests that age past it
//! before execution are dropped from their batch at dispatch time with an
//! `Err(`[`DEADLINE_EXCEEDED`]`)` reply.  Either way the reply channel is
//! never dropped — the fail-loud contract of
//! [`crate::coordinator::server::Reply`] extends to the replica tier.

use super::metrics::ServeMetrics;
use crate::coordinator::batcher::{Batch, BatcherConfig, DynamicBatcher, Pending};
use crate::coordinator::server::{Executor, NativeExecutor, Reply, Request};
use crate::model::NativeModel;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Reply error message for requests turned away by admission control.
pub const REJECTED: &str = "rejected: admission queue full";

/// Reply error message for requests that aged past their deadline while
/// queued.
pub const DEADLINE_EXCEEDED: &str = "deadline exceeded before execution";

#[derive(Clone)]
pub struct ReplicaConfig {
    /// Number of replica shards (executors).
    pub replicas: usize,
    pub batcher: BatcherConfig,
    /// Base seed; batch `seq` executes with `seed.wrapping_add(seq)`.
    pub seed: u32,
    /// Admission bound: max requests outstanding (queued + executing).
    pub queue_depth: usize,
    /// Per-request deadline, checked at batch dispatch; `None` disables.
    pub deadline: Option<Duration>,
    /// SLO latency target for the attainment counters.
    pub slo: Duration,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        Self {
            replicas: 2,
            batcher: BatcherConfig::default(),
            seed: 0,
            queue_depth: 1024,
            deadline: None,
            slo: Duration::from_millis(50),
        }
    }
}

/// A formed batch awaiting execution on some shard.
struct Job {
    seed: u32,
    items: Vec<Pending<Request>>,
    /// shard the dispatcher assigned it to (executed elsewhere ⇒ stolen)
    home: usize,
}

/// One shard's work queue (Mutex + Condvar; std-only, no tokio).
struct ShardQueue {
    q: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

impl ShardQueue {
    fn new() -> Self {
        Self { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn push(&self, job: Job) {
        self.q.lock().unwrap().push_back(job);
        self.cv.notify_one();
    }
}

/// N-replica serving tier over any `Executor + Sync` (one executor per
/// shard; use [`ReplicaServer::from_native`] to shard a [`NativeModel`]
/// through its `Arc`-shared programming pass).
pub struct ReplicaServer<E: Executor + Sync> {
    shards: Vec<E>,
    cfg: ReplicaConfig,
    pub metrics: Arc<ServeMetrics>,
}

impl ReplicaServer<NativeExecutor> {
    /// Shard a native model into `cfg.replicas` replica views sharing the
    /// programmed crossbars — program once, serve everywhere.
    pub fn from_native(model: &NativeModel, cfg: ReplicaConfig) -> Self {
        let shards = (0..cfg.replicas.max(1))
            .map(|_| NativeExecutor { model: model.replica_view() })
            .collect();
        Self::new(shards, cfg)
    }
}

impl<E: Executor + Sync> ReplicaServer<E> {
    /// One executor per shard; `cfg.replicas` is overridden by
    /// `shards.len()`.
    pub fn new(shards: Vec<E>, mut cfg: ReplicaConfig) -> Self {
        assert!(!shards.is_empty(), "at least one replica shard");
        cfg.replicas = shards.len();
        let metrics = Arc::new(ServeMetrics::new(shards.len(), cfg.slo));
        Self { shards, cfg, metrics }
    }

    pub fn config(&self) -> &ReplicaConfig {
        &self.cfg
    }

    /// Run loop: consume requests until the channel closes, then drain
    /// the batcher and wait for every shard to finish its backlog.
    ///
    /// The dispatcher runs on the calling thread; shard workers run on
    /// scoped threads, so `run` returns only after every admitted request
    /// has received its reply.
    pub fn run(&self, rx: mpsc::Receiver<Request>) {
        let queues: Vec<ShardQueue> = (0..self.shards.len()).map(|_| ShardQueue::new()).collect();
        let done = AtomicBool::new(false);
        let outstanding = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for (si, exec) in self.shards.iter().enumerate() {
                let queues = &queues;
                let done = &done;
                let outstanding = &outstanding;
                let metrics = &self.metrics;
                scope.spawn(move || {
                    shard_worker(si, exec, queues, done, outstanding, metrics)
                });
            }
            self.dispatch_loop(rx, &queues, &outstanding);
            done.store(true, Ordering::SeqCst);
            for q in &queues {
                q.cv.notify_all();
            }
        });
    }

    /// Central batch formation — the single-server run loop, minus
    /// execution: admitted requests accumulate in the batcher; formed
    /// batches get the next sequence seed and go to a shard queue.
    fn dispatch_loop(
        &self,
        rx: mpsc::Receiver<Request>,
        queues: &[ShardQueue],
        outstanding: &AtomicUsize,
    ) {
        let mut batcher = DynamicBatcher::new(BatcherConfig {
            target_batch: self.cfg.batcher.target_batch.min(self.shards[0].max_batch()),
            ..self.cfg.batcher
        });
        let mut seq: u32 = 0;
        let mut rr = 0usize;
        let mut closed = false;
        while !closed {
            let now = Instant::now();
            if let Some(batch) = batcher.try_flush(now) {
                seq = seq.wrapping_add(1);
                self.dispatch(batch, self.cfg.seed.wrapping_add(seq), queues, &mut rr, outstanding);
                continue;
            }
            let wait = batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
            match rx.recv_timeout(wait) {
                Ok(req) => {
                    if outstanding.load(Ordering::SeqCst) >= self.cfg.queue_depth {
                        // bounded queue: explicit rejection, never an
                        // unbounded backlog or a dropped reply channel
                        self.metrics.record_rejected();
                        let _ = req.reply.send(Reply {
                            result: Err(REJECTED.to_string()),
                            latency: Duration::ZERO,
                            batch: 0,
                        });
                    } else {
                        outstanding.fetch_add(1, Ordering::SeqCst);
                        batcher.push(req, Instant::now());
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
            }
        }
        while let Some(batch) = batcher.drain_all() {
            seq = seq.wrapping_add(1);
            self.dispatch(batch, self.cfg.seed.wrapping_add(seq), queues, &mut rr, outstanding);
        }
    }

    /// Expire overdue requests, then queue the remainder round-robin.
    fn dispatch(
        &self,
        batch: Batch<Request>,
        seed: u32,
        queues: &[ShardQueue],
        rr: &mut usize,
        outstanding: &AtomicUsize,
    ) {
        let mut items = batch.items;
        if let Some(dl) = self.cfg.deadline {
            let now = Instant::now();
            let (live, dead): (Vec<_>, Vec<_>) = items
                .into_iter()
                .partition(|p| now.duration_since(p.enqueued) <= dl);
            for p in dead {
                self.metrics.record_deadline_exceeded();
                let _ = p.payload.reply.send(Reply {
                    result: Err(DEADLINE_EXCEEDED.to_string()),
                    latency: now.duration_since(p.enqueued),
                    batch: 0,
                });
                outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            items = live;
        }
        if items.is_empty() {
            return;
        }
        let shard = *rr % queues.len();
        *rr += 1;
        queues[shard].push(Job { seed, items, home: shard });
    }
}

/// Shard worker: drain own queue, steal from the longest sibling backlog
/// when dry, exit once the dispatcher is done and every queue is empty.
fn shard_worker<E: Executor>(
    si: usize,
    exec: &E,
    queues: &[ShardQueue],
    done: &AtomicBool,
    outstanding: &AtomicUsize,
    metrics: &ServeMetrics,
) {
    loop {
        let job = queues[si].q.lock().unwrap().pop_front();
        let job = match job {
            Some(j) => Some(j),
            None => steal(si, queues),
        };
        match job {
            Some(job) => execute_job(si, exec, job, outstanding, metrics),
            None => {
                if done.load(Ordering::SeqCst)
                    && queues.iter().all(|q| q.q.lock().unwrap().is_empty())
                {
                    return;
                }
                let guard = queues[si].q.lock().unwrap();
                let _unused = queues[si].cv.wait_timeout(guard, Duration::from_millis(1)).unwrap();
            }
        }
    }
}

/// Steal the newest job from the sibling with the longest backlog.
fn steal(si: usize, queues: &[ShardQueue]) -> Option<Job> {
    let mut best: Option<(usize, usize)> = None;
    for (qi, q) in queues.iter().enumerate() {
        if qi == si {
            continue;
        }
        let len = q.q.lock().unwrap().len();
        if len > 0 && best.map(|(_, bl)| len > bl).unwrap_or(true) {
            best = Some((qi, len));
        }
    }
    let (qi, _) = best?;
    queues[qi].q.lock().unwrap().pop_back()
}

/// Execute one batch and reply to every member (the fail-loud contract:
/// `Ok` logits or the executor's error, never a dropped channel).
fn execute_job<E: Executor>(
    si: usize,
    exec: &E,
    job: Job,
    outstanding: &AtomicUsize,
    metrics: &ServeMetrics,
) {
    let n = job.items.len();
    let classes = exec.classes();
    let stolen = job.home != si;
    let mut images = Vec::with_capacity(n * exec.image_elems());
    for p in &job.items {
        images.extend_from_slice(&p.payload.image);
    }
    let t0 = Instant::now();
    match exec.execute(&images, n, job.seed) {
        Ok(logits) => {
            let now = Instant::now();
            let mut latencies = Vec::with_capacity(n);
            for (i, p) in job.items.into_iter().enumerate() {
                let lat = now.duration_since(p.enqueued);
                latencies.push(lat);
                let _ = p.payload.reply.send(Reply {
                    result: Ok(logits[i * classes..(i + 1) * classes].to_vec()),
                    latency: now.duration_since(t0),
                    batch: n,
                });
                outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            metrics.record_batch(si, n, &latencies, stolen);
        }
        Err(e) => {
            let msg = e.to_string();
            eprintln!("shard {si} executor error: {msg}");
            let now = Instant::now();
            for p in job.items {
                let _ = p.payload.reply.send(Reply {
                    result: Err(msg.clone()),
                    latency: now.duration_since(t0),
                    batch: n,
                });
                outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            metrics.record_error_batch(si);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{submit_all, ServeConfig, Server};

    /// Mock whose output depends on (batch, seed) — any divergence in
    /// batch formation or seed sequencing between the single server and
    /// the replica tier shows up as a value mismatch.
    struct SeededExec;

    impl Executor for SeededExec {
        fn execute(&self, _images: &[f32], batch: usize, seed: u32) -> crate::Result<Vec<f32>> {
            Ok((0..batch * 10)
                .map(|i| seed as f32 * 1000.0 + batch as f32 * 100.0 + i as f32)
                .collect())
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    /// Executor that sleeps per batch — drives backlog for the admission
    /// and stealing tests.
    struct SlowExec(Duration);

    impl Executor for SlowExec {
        fn execute(&self, _images: &[f32], batch: usize, _seed: u32) -> crate::Result<Vec<f32>> {
            std::thread::sleep(self.0);
            Ok(vec![0.0; batch * 10])
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    fn cfg(target: usize, depth: usize) -> ReplicaConfig {
        ReplicaConfig {
            replicas: 2,
            batcher: BatcherConfig {
                target_batch: target,
                max_wait: Duration::from_millis(1),
            },
            seed: 5,
            queue_depth: depth,
            deadline: None,
            slo: Duration::from_secs(1),
        }
    }

    /// Pre-queued requests produce identical replies from the single
    /// server and the 3-replica tier: same batch composition, same seed
    /// sequence, regardless of which shard executed which batch.
    #[test]
    fn replica_tier_matches_single_server_bit_for_bit() {
        let n = 10usize; // 3 size-cut batches + 1 drain batch at target 3
        let serve = |replies: Vec<mpsc::Receiver<Reply>>| -> Vec<Vec<f32>> {
            replies
                .into_iter()
                .map(|r| r.recv().unwrap().result.unwrap())
                .collect()
        };

        let single = Server::new(
            Box::new(SeededExec),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 3,
                    max_wait: Duration::from_secs(10),
                },
                seed: 5,
                max_retries: 0,
            },
        );
        let (tx, rx) = mpsc::channel();
        let want_rx = submit_all(&tx, (0..n).map(|_| vec![0.0f32; 4]));
        drop(tx);
        single.run(rx);
        let want = serve(want_rx);

        let replica = ReplicaServer::new(
            vec![SeededExec, SeededExec, SeededExec],
            ReplicaConfig {
                batcher: BatcherConfig {
                    target_batch: 3,
                    max_wait: Duration::from_secs(10),
                },
                seed: 5,
                ..cfg(3, 1024)
            },
        );
        let (tx, rx) = mpsc::channel();
        let got_rx = submit_all(&tx, (0..n).map(|_| vec![0.0f32; 4]));
        drop(tx);
        replica.run(rx);
        let got = serve(got_rx);

        assert_eq!(got, want, "replica tier must be bit-identical");
        assert_eq!(replica.metrics.requests(), n as u64);
        assert_eq!(replica.metrics.batches(), 4, "3 size cuts + 1 drain");
    }

    /// Admission control: with a slow executor and a shallow queue, the
    /// overflow gets explicit `Err(REJECTED)` replies — the client always
    /// receives a reply, never a dropped channel.
    #[test]
    fn admission_control_rejects_overflow_with_explicit_replies() {
        let server = ReplicaServer::new(
            vec![SlowExec(Duration::from_millis(20)), SlowExec(Duration::from_millis(20))],
            cfg(1, 4),
        );
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..32).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        let mut ok = 0u64;
        let mut rejected = 0u64;
        for r in replies {
            let rep = r.recv().expect("reply delivered, never dropped");
            match rep.result {
                Ok(logits) => {
                    assert_eq!(logits.len(), 10);
                    ok += 1;
                }
                Err(e) => {
                    assert_eq!(e, REJECTED);
                    rejected += 1;
                }
            }
        }
        assert_eq!(ok + rejected, 32);
        assert!(rejected > 0, "shallow queue must shed load");
        assert!(ok >= 4, "admitted requests are served");
        assert_eq!(server.metrics.rejected(), rejected);
        assert_eq!(server.metrics.requests(), ok);
    }

    /// Deadline enforcement: requests older than the deadline at dispatch
    /// get `Err(DEADLINE_EXCEEDED)` and are counted, not executed.
    #[test]
    fn overdue_requests_get_deadline_exceeded_replies() {
        let server = ReplicaServer::new(
            vec![SeededExec, SeededExec],
            ReplicaConfig {
                batcher: BatcherConfig {
                    target_batch: 8,
                    // the flush deadline is far beyond the request deadline
                    max_wait: Duration::from_millis(60),
                },
                deadline: Some(Duration::from_millis(10)),
                ..cfg(8, 1024)
            },
        );
        let (tx, rx) = mpsc::channel();
        // the client keeps the channel open past the flush deadline so the
        // batch is cut by max_wait (60 ms) — well past the 10 ms request
        // deadline — rather than by an immediate shutdown drain
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, (0..3).map(|_| vec![0.0f32; 4]));
            std::thread::sleep(Duration::from_millis(120));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();
        for r in replies {
            let rep = r.recv().expect("reply delivered");
            assert_eq!(rep.result.unwrap_err(), DEADLINE_EXCEEDED);
        }
        assert_eq!(server.metrics.deadline_exceeded(), 3);
        assert_eq!(server.metrics.requests(), 0);
    }

    /// Work stealing: a fast shard drains a slow sibling's backlog —
    /// stolen batches are counted and every request still gets `Ok`.
    #[test]
    fn idle_shard_steals_from_slow_sibling_backlog() {
        let server = ReplicaServer::new(
            vec![SlowExec(Duration::from_millis(25)), SlowExec(Duration::from_millis(0))],
            cfg(1, 1024),
        );
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..16).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for r in replies {
            assert!(r.recv().unwrap().result.is_ok());
        }
        assert_eq!(server.metrics.requests(), 16);
        assert!(
            server.metrics.stolen_batches() > 0,
            "the fast shard must have stolen from the slow shard's queue"
        );
    }

    /// A failing shard executor fails its batch loudly (every member gets
    /// the error reply) without wedging the run loop.
    struct FailingExec;

    impl Executor for FailingExec {
        fn execute(&self, _i: &[f32], _b: usize, _s: u32) -> crate::Result<Vec<f32>> {
            Err(anyhow::anyhow!("injected shard failure"))
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    #[test]
    fn failing_shard_replies_error_to_every_member() {
        let server = ReplicaServer::new(vec![FailingExec, FailingExec], cfg(4, 1024));
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..8).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for r in replies {
            let rep = r.recv().expect("reply delivered, not abandoned");
            assert!(rep.result.unwrap_err().contains("injected shard failure"));
        }
        assert!(server.metrics.requests() == 0);
        assert!(server.metrics.to_json().get("shards").is_some());
    }
}
