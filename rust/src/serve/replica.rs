//! The sharded replica server: N executors over one programming pass,
//! continuous batching, admission control, work stealing — and, when
//! [`ResilienceConfig::enabled`] is set, the self-healing plane: health
//! tracking, shard eviction with lossless requeue, probe-based
//! reintegration, hedged dispatch of stragglers, and brown-out
//! degradation.
//!
//! Supersedes the single-[`crate::coordinator::Server`] run loop for
//! native-executor serving.  One dispatcher thread (the caller of
//! [`ReplicaServer::run`]) owns the request channel and the
//! [`DynamicBatcher`]; formed batches are stamped with sequence-ordered
//! seeds and placed round-robin onto per-shard work queues, where replica
//! workers execute them — stealing from the longest sibling backlog when
//! their own queue runs dry.
//!
//! # Bit-identity with the single server
//!
//! Batch *formation* is centralized and FIFO, and batch `seq` executes
//! with seed `cfg.seed.wrapping_add(seq)` (`seq` counting from 1) —
//! exactly the `seed.wrapping_add(1)`-per-batch discipline of
//! [`crate::coordinator::Server::run`].  Which shard executes a batch
//! never enters the computation: replicas share the programmed crossbars
//! ([`crate::model::NativeModel::replica_view`]) and the native forward is
//! deterministic per `(images, batch, seed)`.  N-replica serving is
//! therefore bit-identical to the single server for the same requests and
//! seed (pinned by `rust/tests/serve.rs`), while execution parallelizes
//! across shards.
//!
//! The same property is what makes self-healing *lossless*: a requeued or
//! hedged batch carries its original seed, so re-executing it on any
//! shard reproduces the exact logits the failed execution would have
//! produced.  Under a crash fault, surviving requests receive replies
//! bit-identical to the fault-free run (pinned by
//! `crashing_shard_heals_and_stays_bit_identical`).
//!
//! # Admission control and deadlines
//!
//! The queue is bounded: at most [`ReplicaConfig::queue_depth`] requests
//! may be outstanding (queued or executing); requests beyond that receive
//! an immediate `Err(`[`REJECTED`]`)` reply instead of queueing without
//! bound.  With a [`ReplicaConfig::deadline`], requests that age past it
//! before execution are dropped from their batch at dispatch time with an
//! `Err(`[`DEADLINE_EXCEEDED`]`)` reply.  Either way the reply channel is
//! never dropped — the fail-loud contract of
//! [`crate::coordinator::server::Reply`] extends to the replica tier.
//!
//! # Self-healing (the robustness plane)
//!
//! With resilience enabled, a failed batch is requeued to a healthy
//! sibling (budget [`ResilienceConfig::max_requeues`]); a shard whose
//! consecutive-error count or error-rate EWMA trips the policy is
//! *evicted* — its queue is drained and redistributed — and periodically
//! *probed* for reintegration.  An idle healthy shard *hedges* a
//! straggler batch (same seed — first response wins, deduplicated by
//! request id, so a request still gets exactly one reply).  Under
//! brown-out, batches execute on the degraded short-sampling executors
//! and replies carry `degraded: true`.  Every reply path decrements the
//! outstanding count exactly once per request, so the exactly-one-reply
//! contract survives any fault schedule.

use super::fault::{FaultInjector, FaultPlan};
use super::health::{HealthTracker, ResilienceConfig};
use super::metrics::ServeMetrics;
use crate::coordinator::batcher::{Batch, BatcherConfig, DynamicBatcher, Pending};
use crate::coordinator::server::{ConfigError, Executor, NativeExecutor, Reply, Request};
use crate::model::NativeModel;
use crate::obs::{span, TraceLevel};
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Reply error message for requests turned away by admission control.
pub const REJECTED: &str = "rejected: admission queue full";

/// Reply error message for requests that aged past their deadline while
/// queued.
pub const DEADLINE_EXCEEDED: &str = "deadline exceeded before execution";

#[derive(Clone)]
pub struct ReplicaConfig {
    /// Number of replica shards (executors).
    pub replicas: usize,
    pub batcher: BatcherConfig,
    /// Base seed; batch `seq` executes with `seed.wrapping_add(seq)`.
    pub seed: u32,
    /// Admission bound: max requests outstanding (queued + executing).
    pub queue_depth: usize,
    /// Per-request deadline, checked at batch dispatch; `None` disables.
    pub deadline: Option<Duration>,
    /// SLO latency target for the attainment counters.
    pub slo: Duration,
    /// Work stealing (idle shard drains the longest sibling backlog);
    /// on by default, switched off by the chaos tests that need strict
    /// queue-to-shard affinity.
    pub steal: bool,
    /// The self-healing policy; disabled by default (bit-identical to
    /// the pre-resilience tier).
    pub resilience: ResilienceConfig,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        Self {
            replicas: 2,
            batcher: BatcherConfig::default(),
            seed: 0,
            queue_depth: 1024,
            deadline: None,
            slo: Duration::from_millis(50),
            steal: true,
            resilience: ResilienceConfig::default(),
        }
    }
}

impl ReplicaConfig {
    /// Fail-loud validation, called by the CLI/harness right after
    /// parsing: a zero queue depth would reject every request, zero
    /// replicas cannot serve, a zero deadline expires everything, and a
    /// zero target batch never forms one.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.replicas == 0 {
            return Err(ConfigError::ZeroReplicas);
        }
        if self.queue_depth == 0 {
            return Err(ConfigError::ZeroQueueDepth);
        }
        if self.batcher.target_batch == 0 {
            return Err(ConfigError::ZeroTargetBatch);
        }
        if self.deadline == Some(Duration::ZERO) {
            return Err(ConfigError::ZeroDeadline);
        }
        Ok(())
    }
}

/// A formed batch awaiting execution on some shard.
struct Job {
    seed: u32,
    items: Vec<Pending<Request>>,
    /// shard the dispatcher assigned it to (executed elsewhere ⇒ stolen)
    home: usize,
    /// requeue generation: 0 on first dispatch, +1 per post-failure
    /// requeue (independent fault draws, bounded by `max_requeues`)
    attempt: u32,
    /// reintegration probe — routed to an evicted shard on purpose
    probe: bool,
}

/// One shard's work queue (Mutex + Condvar; std-only, no tokio).
struct ShardQueue {
    q: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

impl ShardQueue {
    fn new() -> Self {
        Self { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn push(&self, job: Job) {
        self.q.lock().unwrap().push_back(job);
        self.cv.notify_one();
    }
}

/// Copy of one in-flight request a hedge can answer (the original
/// [`Pending`] stays with the executing worker; senders and images are
/// cheaply cloneable).
struct HedgeItem {
    id: u64,
    image: Vec<f32>,
    reply: mpsc::Sender<Reply>,
    enqueued: Instant,
}

/// An in-flight batch advertised for hedging: seed + item copies, plus a
/// claim flag so at most one sibling re-executes it.
struct InFlight {
    seed: u32,
    started: Instant,
    items: Vec<HedgeItem>,
    taken: AtomicBool,
}

/// Per-shard registry of the batch each worker is currently executing.
struct HedgeBoard {
    slots: Vec<Mutex<Option<Arc<InFlight>>>>,
}

impl HedgeBoard {
    fn new(replicas: usize) -> Self {
        Self { slots: (0..replicas).map(|_| Mutex::new(None)).collect() }
    }

    fn register(&self, si: usize, job: &Job) {
        let inflight = Arc::new(InFlight {
            seed: job.seed,
            started: Instant::now(),
            items: job
                .items
                .iter()
                .map(|p| HedgeItem {
                    id: p.id,
                    image: p.payload.image.clone(),
                    reply: p.payload.reply.clone(),
                    enqueued: p.enqueued,
                })
                .collect(),
            taken: AtomicBool::new(false),
        });
        *self.slots[si].lock().unwrap() = Some(inflight);
    }

    fn clear(&self, si: usize) {
        *self.slots[si].lock().unwrap() = None;
    }
}

/// Everything a worker or the dispatcher needs, bundled so the execution
/// paths stay readable (one context reference instead of ten arguments).
struct RunCtx<'a, E: Executor + Sync> {
    cfg: &'a ReplicaConfig,
    shards: &'a [E],
    /// degraded (short-sampling) executors, one per shard — brown-out
    degraded: Option<&'a [E]>,
    queues: &'a [ShardQueue],
    done: &'a AtomicBool,
    outstanding: &'a AtomicUsize,
    metrics: &'a ServeMetrics,
    health: &'a HealthTracker,
    injector: &'a FaultInjector,
    hedge: &'a HedgeBoard,
    /// request ids already answered — consulted only when hedging is on
    /// (the one path where two executions race for the same reply)
    replied: &'a Mutex<HashSet<u64>>,
}

/// N-replica serving tier over any `Executor + Sync` (one executor per
/// shard; use [`ReplicaServer::from_native`] to shard a [`NativeModel`]
/// through its `Arc`-shared programming pass).
pub struct ReplicaServer<E: Executor + Sync> {
    shards: Vec<E>,
    /// brown-out executors (same shard count); `None` disables brown-out
    degraded: Option<Vec<E>>,
    cfg: ReplicaConfig,
    plan: FaultPlan,
    pub metrics: Arc<ServeMetrics>,
}

impl ReplicaServer<NativeExecutor> {
    /// Shard a native model into `cfg.replicas` replica views sharing the
    /// programmed crossbars — program once, serve everywhere.
    pub fn from_native(model: &NativeModel, cfg: ReplicaConfig) -> Self {
        let shards = (0..cfg.replicas.max(1))
            .map(|_| NativeExecutor { model: model.replica_view() })
            .collect();
        Self::new(shards, cfg)
    }

    /// Attach brown-out executors sharing `model`'s programming pass
    /// (typically a [`crate::model::NativeModel::share_with_converter_spec`]
    /// view with a shorter sampling length).
    pub fn with_degraded_native(self, model: &NativeModel) -> Self {
        let shards: Vec<NativeExecutor> = (0..self.cfg.replicas)
            .map(|_| NativeExecutor { model: model.replica_view() })
            .collect();
        self.with_degraded_shards(shards)
    }
}

impl<E: Executor + Sync> ReplicaServer<E> {
    /// One executor per shard; `cfg.replicas` is overridden by
    /// `shards.len()`.
    pub fn new(shards: Vec<E>, mut cfg: ReplicaConfig) -> Self {
        assert!(!shards.is_empty(), "at least one replica shard");
        cfg.replicas = shards.len();
        let metrics = Arc::new(ServeMetrics::new(shards.len(), cfg.slo));
        Self { shards, degraded: None, cfg, plan: FaultPlan::disabled(), metrics }
    }

    /// Inject a fault plan (testing / chaos engineering).  The disabled
    /// plan — the default — is completely inert.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.plan = plan;
        self
    }

    /// Attach brown-out executors, one per shard.  Batches execute on
    /// them (with `degraded: true` replies) whenever more than
    /// [`ResilienceConfig::brownout_queue`] requests are outstanding.
    pub fn with_degraded_shards(mut self, degraded: Vec<E>) -> Self {
        assert_eq!(
            degraded.len(),
            self.shards.len(),
            "one degraded executor per shard"
        );
        self.degraded = Some(degraded);
        self
    }

    pub fn config(&self) -> &ReplicaConfig {
        &self.cfg
    }

    /// Run loop: consume requests until the channel closes, then drain
    /// the batcher and wait until every admitted request has its reply.
    ///
    /// The dispatcher runs on the calling thread; shard workers run on
    /// scoped threads, so `run` returns only after every admitted request
    /// has received its reply — under any fault schedule (workers exit on
    /// `done && outstanding == 0`, so requeued or hedged work can never
    /// be orphaned by an early queue-empty exit).
    pub fn run(&self, rx: mpsc::Receiver<Request>) {
        let queues: Vec<ShardQueue> = (0..self.shards.len()).map(|_| ShardQueue::new()).collect();
        let done = AtomicBool::new(false);
        let outstanding = AtomicUsize::new(0);
        let health = HealthTracker::new(self.shards.len(), self.cfg.resilience.clone());
        let injector = FaultInjector::new(self.plan.clone(), self.shards.len());
        let hedge = HedgeBoard::new(self.shards.len());
        let replied = Mutex::new(HashSet::new());
        let ctx = RunCtx {
            cfg: &self.cfg,
            shards: &self.shards,
            degraded: self.degraded.as_deref(),
            queues: &queues,
            done: &done,
            outstanding: &outstanding,
            metrics: self.metrics.as_ref(),
            health: &health,
            injector: &injector,
            hedge: &hedge,
            replied: &replied,
        };
        std::thread::scope(|scope| {
            for si in 0..self.shards.len() {
                let ctx = &ctx;
                scope.spawn(move || shard_worker(ctx, si));
            }
            self.dispatch_loop(rx, &ctx);
            done.store(true, Ordering::SeqCst);
            for q in &queues {
                q.cv.notify_all();
            }
        });
    }

    /// Central batch formation — the single-server run loop, minus
    /// execution: admitted requests accumulate in the batcher; formed
    /// batches get the next sequence seed and go to a shard queue.
    fn dispatch_loop(&self, rx: mpsc::Receiver<Request>, ctx: &RunCtx<'_, E>) {
        let mut batcher = DynamicBatcher::new(BatcherConfig {
            target_batch: self.cfg.batcher.target_batch.min(self.shards[0].max_batch()),
            ..self.cfg.batcher
        });
        let mut seq: u32 = 0;
        let mut rr = 0usize;
        let mut dseq = 0u64;
        let mut closed = false;
        while !closed {
            let now = Instant::now();
            if let Some(batch) = batcher.try_flush(now) {
                seq = seq.wrapping_add(1);
                self.dispatch(batch, self.cfg.seed.wrapping_add(seq), ctx, &mut rr, &mut dseq);
                continue;
            }
            let wait = batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
            match rx.recv_timeout(wait) {
                Ok(req) => {
                    if ctx.outstanding.load(Ordering::SeqCst) >= self.cfg.queue_depth {
                        // bounded queue: explicit rejection, never an
                        // unbounded backlog or a dropped reply channel
                        self.metrics.record_rejected();
                        span::instant(TraceLevel::Request, "admission.reject", "serve", None);
                        let _ = req.reply.send(Reply {
                            result: Err(REJECTED.to_string()),
                            latency: Duration::ZERO,
                            batch: 0,
                            degraded: false,
                        });
                    } else {
                        ctx.outstanding.fetch_add(1, Ordering::SeqCst);
                        batcher.push(req, Instant::now());
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
            }
        }
        while let Some(batch) = batcher.drain_all() {
            seq = seq.wrapping_add(1);
            self.dispatch(batch, self.cfg.seed.wrapping_add(seq), ctx, &mut rr, &mut dseq);
        }
    }

    /// Expire overdue requests, then queue the remainder: round-robin
    /// over healthy shards, with every `probe_interval`-th dispatch
    /// routed to an evicted shard as a reintegration probe.
    fn dispatch(
        &self,
        batch: Batch<Request>,
        seed: u32,
        ctx: &RunCtx<'_, E>,
        rr: &mut usize,
        dseq: &mut u64,
    ) {
        let _sp = span::span(TraceLevel::Request, "dispatch", "serve")
            .arg("batch", batch.items.len() as f64);
        let mut items = batch.items;
        if let Some(dl) = self.cfg.deadline {
            let now = Instant::now();
            let (live, dead): (Vec<_>, Vec<_>) = items
                .into_iter()
                .partition(|p| now.duration_since(p.enqueued) <= dl);
            for p in dead {
                self.metrics.record_deadline_exceeded();
                span::instant(TraceLevel::Request, "deadline.exceeded", "serve", None);
                let _ = p.payload.reply.send(Reply {
                    result: Err(DEADLINE_EXCEEDED.to_string()),
                    latency: now.duration_since(p.enqueued),
                    batch: 0,
                    degraded: false,
                });
                ctx.outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            items = live;
        }
        if items.is_empty() {
            return;
        }
        let res = &self.cfg.resilience;
        let mut shard = *rr % ctx.queues.len();
        *rr += 1;
        let mut probe = false;
        if res.enabled {
            let evicted = ctx.health.evicted_list();
            let interval = res.probe_interval as u64;
            if !evicted.is_empty() && interval > 0 && *dseq % interval == 0 {
                shard = evicted[((*dseq / interval) as usize) % evicted.len()];
                probe = true;
                self.metrics.record_probe();
            } else if !ctx.health.is_up(shard) {
                shard = ctx.health.next_healthy(shard).unwrap_or(shard);
            }
        }
        *dseq += 1;
        ctx.queues[shard].push(Job { seed, items, home: shard, attempt: 0, probe });
    }
}

/// Send one reply and decrement the outstanding count — the single
/// choke-point enforcing exactly-one-reply-per-request.  With hedging on,
/// the first execution to claim the request id wins; returns whether this
/// call actually answered.
fn send_reply<E: Executor + Sync>(
    ctx: &RunCtx<'_, E>,
    id: u64,
    tx: &mpsc::Sender<Reply>,
    reply: Reply,
) -> bool {
    if ctx.cfg.resilience.hedge && !ctx.replied.lock().unwrap().insert(id) {
        return false; // a hedge (or the original) already answered
    }
    let _ = tx.send(reply);
    ctx.outstanding.fetch_sub(1, Ordering::SeqCst);
    true
}

/// Shard worker: drain own queue, steal from the longest healthy sibling
/// backlog when dry, hedge a straggler when still idle, and exit once the
/// dispatcher is done and no request is left outstanding.
fn shard_worker<E: Executor + Sync>(ctx: &RunCtx<'_, E>, si: usize) {
    loop {
        let job = ctx.queues[si].q.lock().unwrap().pop_front();
        let job = match job {
            Some(j) => Some(j),
            None if ctx.cfg.steal && ctx.health.is_up(si) => steal(ctx, si),
            None => None,
        };
        match job {
            Some(job) => execute_job(ctx, si, job),
            None => {
                if ctx.cfg.resilience.hedge && ctx.health.is_up(si) {
                    if let Some(f) = claim_straggler(ctx, si) {
                        execute_hedge(ctx, si, f);
                        continue;
                    }
                }
                // exit on outstanding == 0 (not queue-empty): requeued or
                // hedged work must never be orphaned by a worker exodus
                if ctx.done.load(Ordering::SeqCst)
                    && ctx.outstanding.load(Ordering::SeqCst) == 0
                {
                    return;
                }
                let guard = ctx.queues[si].q.lock().unwrap();
                let _unused =
                    ctx.queues[si].cv.wait_timeout(guard, Duration::from_millis(1)).unwrap();
            }
        }
    }
}

/// Steal the newest job from the healthy sibling with the longest
/// backlog (evicted shards' queues hold only probes — leave them be).
fn steal<E: Executor + Sync>(ctx: &RunCtx<'_, E>, si: usize) -> Option<Job> {
    let mut best: Option<(usize, usize)> = None;
    for (qi, q) in ctx.queues.iter().enumerate() {
        if qi == si || !ctx.health.is_up(qi) {
            continue;
        }
        let len = q.q.lock().unwrap().len();
        if len > 0 && best.map(|(_, bl)| len > bl).unwrap_or(true) {
            best = Some((qi, len));
        }
    }
    let (qi, _) = best?;
    let job = ctx.queues[qi].q.lock().unwrap().pop_back();
    if job.is_some() {
        span::instant(TraceLevel::Request, "steal", "serve", Some(("from", qi as f64)));
    }
    job
}

/// Find the oldest hedge-eligible in-flight batch on another shard: in
/// flight longer than `hedge_after` and `hedge_factor ×` its shard's
/// batch-latency EWMA, and not yet claimed by another hedge.
fn claim_straggler<E: Executor + Sync>(ctx: &RunCtx<'_, E>, si: usize) -> Option<Arc<InFlight>> {
    let res = &ctx.cfg.resilience;
    for (qi, slot) in ctx.hedge.slots.iter().enumerate() {
        if qi == si {
            continue;
        }
        let guard = slot.lock().unwrap();
        if let Some(f) = guard.as_ref() {
            let ewma_us = ctx.metrics.latency_ewma_us(qi);
            let adaptive = Duration::from_micros((res.hedge_factor * ewma_us).max(0.0) as u64);
            let threshold = res.hedge_after.max(adaptive);
            if f.started.elapsed() >= threshold && !f.taken.swap(true, Ordering::SeqCst) {
                return Some(Arc::clone(f));
            }
        }
    }
    None
}

/// Re-execute a claimed straggler batch with its original seed; only a
/// *successful* hedge answers (through the dedup gate) — errors are left
/// to the original execution's loud-failure path.
fn execute_hedge<E: Executor + Sync>(ctx: &RunCtx<'_, E>, si: usize, f: Arc<InFlight>) {
    ctx.metrics.record_hedged();
    span::instant(TraceLevel::Request, "hedge", "serve", Some(("shard", si as f64)));
    let exec = &ctx.shards[si];
    let n = f.items.len();
    let classes = exec.classes();
    let mut images = Vec::with_capacity(n * exec.image_elems());
    for it in &f.items {
        images.extend_from_slice(&it.image);
    }
    let t0 = Instant::now();
    let hedged = {
        let _sp = span::span(TraceLevel::Request, "execute", "serve").arg("batch", n as f64);
        exec.execute(&images, n, f.seed)
    };
    if let Ok(logits) = hedged {
        let now = Instant::now();
        let mut latencies = Vec::new();
        let mut queue_us = Vec::new();
        let mut service_us = Vec::new();
        for (i, it) in f.items.iter().enumerate() {
            let reply = Reply {
                result: Ok(logits[i * classes..(i + 1) * classes].to_vec()),
                latency: now.duration_since(t0),
                batch: n,
                degraded: false,
            };
            if send_reply(ctx, it.id, &it.reply, reply) {
                latencies.push(now.duration_since(it.enqueued));
                queue_us.push(t0.duration_since(it.enqueued).as_secs_f64() * 1e6);
                service_us.push(now.duration_since(t0).as_secs_f64() * 1e6);
            }
        }
        if !latencies.is_empty() {
            ctx.metrics.record_hedge_win();
            ctx.metrics.record_batch(si, latencies.len(), &latencies, true);
            ctx.metrics.record_decomposition(si, &queue_us, &service_us);
        }
    }
}

/// Redistribute an evicted shard's queued work to healthy siblings —
/// lossless: jobs keep their seed and attempt count (queued work did not
/// fail; it just can't stay where it was).
fn drain_evicted_queue<E: Executor + Sync>(ctx: &RunCtx<'_, E>, si: usize) {
    let drained: Vec<Job> = ctx.queues[si].q.lock().unwrap().drain(..).collect();
    for (i, mut job) in drained.into_iter().enumerate() {
        let target = ctx.health.next_healthy(si + 1 + i).unwrap_or(si);
        job.home = target;
        ctx.queues[target].push(job);
    }
}

/// Execute one batch and reply to every member (the fail-loud contract:
/// `Ok` logits or a loud error, never a dropped channel) — threading the
/// fault injector, health tracking, brown-out, and requeue machinery.
fn execute_job<E: Executor + Sync>(ctx: &RunCtx<'_, E>, si: usize, job: Job) {
    let n = job.items.len();
    let stolen = job.home != si;
    let res = &ctx.cfg.resilience;
    // brown-out: under overload, execute on the degraded (short-sampling)
    // executors and flag the replies
    let brownout = match (ctx.degraded, res.brownout_queue) {
        (Some(_), Some(th)) => ctx.outstanding.load(Ordering::SeqCst) > th,
        _ => false,
    };
    let exec: &E = if brownout {
        &ctx.degraded.expect("brownout implies degraded shards")[si]
    } else {
        &ctx.shards[si]
    };
    let classes = exec.classes();
    let mut images = Vec::with_capacity(n * exec.image_elems());
    for p in &job.items {
        images.extend_from_slice(&p.payload.image);
    }

    // advertise for hedging before any (possibly slow) execution
    let hedgeable = res.hedge && !job.probe;
    if hedgeable {
        ctx.hedge.register(si, &job);
    }
    let decision = ctx.injector.decide(si, job.seed, job.attempt);
    if let Some(spike) = decision.spike {
        std::thread::sleep(spike);
    }
    // queue wait ends where execution begins: one trace event per batch,
    // measured from its oldest member's enqueue time
    if let Some(oldest) = job.items.iter().map(|p| p.enqueued).min() {
        span::complete_from(TraceLevel::Request, "queue_wait", "serve", oldest);
    }
    let t0 = Instant::now();
    let result = {
        let _sp = span::span(TraceLevel::Request, "execute", "serve").arg("batch", n as f64);
        match decision.error {
            Some(msg) => Err(anyhow::anyhow!(msg)),
            None => exec.execute(&images, n, job.seed).map(|mut logits| {
                if decision.corrupt {
                    ctx.injector.corrupt(&mut logits, job.seed);
                }
                logits
            }),
        }
    };
    if hedgeable {
        ctx.hedge.clear(si);
    }

    match result {
        Ok(logits) => {
            if ctx.health.record_success(si) {
                ctx.metrics.record_reintegrated();
            }
            let now = Instant::now();
            let mut latencies = Vec::with_capacity(n);
            let mut queue_us = Vec::with_capacity(n);
            let mut service_us = Vec::with_capacity(n);
            for (i, p) in job.items.into_iter().enumerate() {
                let reply = Reply {
                    result: Ok(logits[i * classes..(i + 1) * classes].to_vec()),
                    latency: now.duration_since(t0),
                    batch: n,
                    degraded: brownout,
                };
                if send_reply(ctx, p.id, &p.payload.reply, reply) {
                    latencies.push(now.duration_since(p.enqueued));
                    // queue + service sums to the end-to-end latency above
                    queue_us.push(t0.duration_since(p.enqueued).as_secs_f64() * 1e6);
                    service_us.push(now.duration_since(t0).as_secs_f64() * 1e6);
                }
            }
            if !latencies.is_empty() {
                ctx.metrics.record_batch(si, latencies.len(), &latencies, stolen);
                ctx.metrics.record_decomposition(si, &queue_us, &service_us);
                if brownout {
                    ctx.metrics.record_degraded(latencies.len() as u64);
                }
            }
        }
        Err(e) => {
            let msg = e.to_string();
            eprintln!("shard {si} executor error: {msg}");
            ctx.metrics.record_error_batch(si);
            if ctx.health.record_failure(si, ctx.metrics.error_ewma(si)) {
                ctx.metrics.record_evicted();
                span::instant(TraceLevel::Request, "evict", "serve", Some(("shard", si as f64)));
                drain_evicted_queue(ctx, si);
            }
            if ctx.health.enabled() && job.attempt < res.max_requeues {
                // lossless requeue: same seed (bit-identical re-execution
                // on any shard), next attempt, first healthy sibling
                ctx.metrics.record_requeued();
                span::instant(TraceLevel::Request, "requeue", "serve", None);
                let target = ctx.health.next_healthy(si + 1).unwrap_or(si);
                ctx.queues[target].push(Job {
                    seed: job.seed,
                    items: job.items,
                    home: target,
                    attempt: job.attempt + 1,
                    probe: false,
                });
            } else {
                let now = Instant::now();
                for p in job.items {
                    let reply = Reply {
                        result: Err(msg.clone()),
                        latency: now.duration_since(t0),
                        batch: n,
                        degraded: false,
                    };
                    send_reply(ctx, p.id, &p.payload.reply, reply);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::fault::ShardFaults;
    use super::*;
    use crate::coordinator::server::{submit_all, ServeConfig, Server};

    /// Mock whose output depends on (batch, seed) — any divergence in
    /// batch formation or seed sequencing between the single server and
    /// the replica tier shows up as a value mismatch.
    struct SeededExec;

    impl Executor for SeededExec {
        fn execute(&self, _images: &[f32], batch: usize, seed: u32) -> crate::Result<Vec<f32>> {
            Ok((0..batch * 10)
                .map(|i| seed as f32 * 1000.0 + batch as f32 * 100.0 + i as f32)
                .collect())
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    /// Executor that sleeps per batch — drives backlog for the admission
    /// and stealing tests.
    struct SlowExec(Duration);

    impl Executor for SlowExec {
        fn execute(&self, _images: &[f32], batch: usize, _seed: u32) -> crate::Result<Vec<f32>> {
            std::thread::sleep(self.0);
            Ok(vec![0.0; batch * 10])
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    fn cfg(target: usize, depth: usize) -> ReplicaConfig {
        ReplicaConfig {
            replicas: 2,
            batcher: BatcherConfig {
                target_batch: target,
                max_wait: Duration::from_millis(1),
            },
            seed: 5,
            queue_depth: depth,
            deadline: None,
            slo: Duration::from_secs(1),
            steal: true,
            resilience: ResilienceConfig::default(),
        }
    }

    #[test]
    fn replica_config_validation_rejects_degenerate_configs() {
        assert!(ReplicaConfig::default().validate().is_ok());
        let mut c = cfg(4, 16);
        c.replicas = 0;
        assert_eq!(c.validate(), Err(ConfigError::ZeroReplicas));
        let mut c = cfg(4, 16);
        c.queue_depth = 0;
        assert_eq!(c.validate(), Err(ConfigError::ZeroQueueDepth));
        let mut c = cfg(4, 16);
        c.batcher.target_batch = 0;
        assert_eq!(c.validate(), Err(ConfigError::ZeroTargetBatch));
        let mut c = cfg(4, 16);
        c.deadline = Some(Duration::ZERO);
        assert_eq!(c.validate(), Err(ConfigError::ZeroDeadline));
        // a positive deadline is fine
        c.deadline = Some(Duration::from_millis(1));
        assert!(c.validate().is_ok());
    }

    /// Pre-queued requests produce identical replies from the single
    /// server and the 3-replica tier: same batch composition, same seed
    /// sequence, regardless of which shard executed which batch.
    #[test]
    fn replica_tier_matches_single_server_bit_for_bit() {
        let n = 10usize; // 3 size-cut batches + 1 drain batch at target 3
        let serve = |replies: Vec<mpsc::Receiver<Reply>>| -> Vec<Vec<f32>> {
            replies
                .into_iter()
                .map(|r| r.recv().unwrap().result.unwrap())
                .collect()
        };

        let single = Server::new(
            Box::new(SeededExec),
            ServeConfig {
                batcher: BatcherConfig {
                    target_batch: 3,
                    max_wait: Duration::from_secs(10),
                },
                seed: 5,
                max_retries: 0,
            },
        );
        let (tx, rx) = mpsc::channel();
        let want_rx = submit_all(&tx, (0..n).map(|_| vec![0.0f32; 4]));
        drop(tx);
        single.run(rx);
        let want = serve(want_rx);

        let replica = ReplicaServer::new(
            vec![SeededExec, SeededExec, SeededExec],
            ReplicaConfig {
                batcher: BatcherConfig {
                    target_batch: 3,
                    max_wait: Duration::from_secs(10),
                },
                seed: 5,
                ..cfg(3, 1024)
            },
        );
        let (tx, rx) = mpsc::channel();
        let got_rx = submit_all(&tx, (0..n).map(|_| vec![0.0f32; 4]));
        drop(tx);
        replica.run(rx);
        let got = serve(got_rx);

        assert_eq!(got, want, "replica tier must be bit-identical");
        assert_eq!(replica.metrics.requests(), n as u64);
        assert_eq!(replica.metrics.batches(), 4, "3 size cuts + 1 drain");
    }

    /// Admission control: with a slow executor and a shallow queue, the
    /// overflow gets explicit `Err(REJECTED)` replies — the client always
    /// receives a reply, never a dropped channel.
    #[test]
    fn admission_control_rejects_overflow_with_explicit_replies() {
        let server = ReplicaServer::new(
            vec![SlowExec(Duration::from_millis(20)), SlowExec(Duration::from_millis(20))],
            cfg(1, 4),
        );
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..32).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        let mut ok = 0u64;
        let mut rejected = 0u64;
        for r in replies {
            let rep = r.recv().expect("reply delivered, never dropped");
            match rep.result {
                Ok(logits) => {
                    assert_eq!(logits.len(), 10);
                    assert!(!rep.degraded, "no brown-out configured");
                    ok += 1;
                }
                Err(e) => {
                    assert_eq!(e, REJECTED);
                    rejected += 1;
                }
            }
        }
        assert_eq!(ok + rejected, 32);
        assert!(rejected > 0, "shallow queue must shed load");
        assert!(ok >= 4, "admitted requests are served");
        assert_eq!(server.metrics.rejected(), rejected);
        assert_eq!(server.metrics.requests(), ok);
    }

    /// Deadline enforcement: requests older than the deadline at dispatch
    /// get `Err(DEADLINE_EXCEEDED)` and are counted, not executed.
    #[test]
    fn overdue_requests_get_deadline_exceeded_replies() {
        let server = ReplicaServer::new(
            vec![SeededExec, SeededExec],
            ReplicaConfig {
                batcher: BatcherConfig {
                    target_batch: 8,
                    // the flush deadline is far beyond the request deadline
                    max_wait: Duration::from_millis(60),
                },
                deadline: Some(Duration::from_millis(10)),
                ..cfg(8, 1024)
            },
        );
        let (tx, rx) = mpsc::channel();
        // the client keeps the channel open past the flush deadline so the
        // batch is cut by max_wait (60 ms) — well past the 10 ms request
        // deadline — rather than by an immediate shutdown drain
        let client = std::thread::spawn(move || {
            let replies = submit_all(&tx, (0..3).map(|_| vec![0.0f32; 4]));
            std::thread::sleep(Duration::from_millis(120));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();
        for r in replies {
            let rep = r.recv().expect("reply delivered");
            assert_eq!(rep.result.unwrap_err(), DEADLINE_EXCEEDED);
        }
        assert_eq!(server.metrics.deadline_exceeded(), 3);
        assert_eq!(server.metrics.requests(), 0);
    }

    /// Work stealing: a fast shard drains a slow sibling's backlog —
    /// stolen batches are counted and every request still gets `Ok`.
    #[test]
    fn idle_shard_steals_from_slow_sibling_backlog() {
        let server = ReplicaServer::new(
            vec![SlowExec(Duration::from_millis(25)), SlowExec(Duration::from_millis(0))],
            cfg(1, 1024),
        );
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..16).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for r in replies {
            assert!(r.recv().unwrap().result.is_ok());
        }
        assert_eq!(server.metrics.requests(), 16);
        assert!(
            server.metrics.stolen_batches() > 0,
            "the fast shard must have stolen from the slow shard's queue"
        );
    }

    /// A failing shard executor fails its batch loudly (every member gets
    /// the error reply) without wedging the run loop.
    struct FailingExec;

    impl Executor for FailingExec {
        fn execute(&self, _i: &[f32], _b: usize, _s: u32) -> crate::Result<Vec<f32>> {
            Err(anyhow::anyhow!("injected shard failure"))
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    #[test]
    fn failing_shard_replies_error_to_every_member() {
        let server = ReplicaServer::new(vec![FailingExec, FailingExec], cfg(4, 1024));
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..8).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for r in replies {
            let rep = r.recv().expect("reply delivered, not abandoned");
            assert!(rep.result.unwrap_err().contains("injected shard failure"));
        }
        assert!(server.metrics.requests() == 0);
        assert!(server.metrics.to_json().get("shards").is_some());
    }

    /// Expected reply of request `r` under the deterministic `target=1`
    /// schedule: request r rides batch r+1 (seed 5 + r + 1) alone.
    fn seeded_want(r: usize) -> Vec<f32> {
        let seed = 5 + 1 + r as f32;
        (0..10).map(|i| seed * 1000.0 + 100.0 + i as f32).collect()
    }

    /// The headline self-healing invariant: with shard 0 configured to
    /// crash on every batch, eviction + lossless requeue deliver **every**
    /// request `Ok` — with logits bit-identical to the fault-free run
    /// (requeued batches keep their seed, and the executor is
    /// deterministic per (batch, seed)).
    #[test]
    fn crashing_shard_heals_and_stays_bit_identical() {
        let plan = FaultPlan {
            seed: 0,
            shards: vec![
                ShardFaults { crash_at_batch: Some(0), ..Default::default() },
                ShardFaults::default(),
            ],
        };
        let mut c = cfg(1, 1024);
        c.steal = false;
        c.resilience = ResilienceConfig {
            enabled: true,
            evict_consecutive: 1,
            probe_interval: 0, // no probes: the shard never recovers
            max_requeues: 2,
            ..Default::default()
        };
        let server =
            ReplicaServer::new(vec![SeededExec, SeededExec], c).with_fault_plan(plan);
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..10).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for (r, rx) in replies.into_iter().enumerate() {
            let rep = rx.recv().expect("exactly one reply per request");
            assert_eq!(
                rep.result.expect("healed to Ok"),
                seeded_want(r),
                "request {r}: bit-identical to the fault-free run"
            );
            assert!(rx.try_recv().is_err(), "no second reply for request {r}");
        }
        assert_eq!(server.metrics.evicted(), 1, "the crashing shard was evicted");
        assert!(server.metrics.requeued() >= 1, "failed work was requeued");
        assert_eq!(server.metrics.requests(), 10);
        let j = server.metrics.to_json();
        let res = j.get("resilience").expect("resilience counters in the JSON");
        assert_eq!(res.get("evicted").and_then(|v| v.as_usize()), Some(1));
    }

    /// Eviction + probe-based reintegration converges: a shard that
    /// crashes for its first two batches and then recovers is evicted,
    /// probed, and reintegrated — while every request still gets its
    /// bit-exact `Ok` reply.
    #[test]
    fn evicted_shard_is_probed_and_reintegrated_after_recovery() {
        let plan = FaultPlan {
            seed: 0,
            shards: vec![
                ShardFaults {
                    crash_at_batch: Some(0),
                    recover_at_batch: Some(2),
                    ..Default::default()
                },
                ShardFaults::default(),
            ],
        };
        let mut c = cfg(1, 1024);
        c.steal = false;
        c.resilience = ResilienceConfig {
            enabled: true,
            evict_consecutive: 1,
            probe_interval: 2,
            max_requeues: 3,
            ..Default::default()
        };
        let server =
            ReplicaServer::new(vec![SeededExec, SeededExec], c).with_fault_plan(plan);
        let (tx, rx) = mpsc::channel();
        // two waves: the first gets shard 0 evicted; the pause gives the
        // workers time to do it; the second wave carries the probes that
        // reintegrate the recovered shard
        let client = std::thread::spawn(move || {
            let mut replies = submit_all(&tx, (0..4).map(|_| vec![0.0f32; 4]));
            std::thread::sleep(Duration::from_millis(60));
            replies.extend(submit_all(&tx, (0..8).map(|_| vec![0.0f32; 4])));
            drop(tx);
            replies
        });
        server.run(rx);
        let replies = client.join().unwrap();
        for (r, rx) in replies.into_iter().enumerate() {
            let rep = rx.recv().expect("exactly one reply per request");
            assert_eq!(
                rep.result.expect("self-healing keeps every request Ok"),
                seeded_want(r),
                "request {r}"
            );
            assert!(rx.try_recv().is_err(), "no second reply for request {r}");
        }
        assert_eq!(server.metrics.evicted(), 1);
        assert_eq!(
            server.metrics.reintegrated(),
            1,
            "the recovered shard must rejoin the rotation"
        );
        assert!(server.metrics.probes() >= 1, "reintegration came from a probe");
        assert_eq!(server.metrics.requests(), 12);
    }

    /// Hedged dispatch: a latency-spiked shard's in-flight batch is
    /// re-executed by its idle sibling with the same seed; the hedge
    /// answers first, the late original is deduplicated — each request
    /// gets exactly one (bit-correct) reply.
    #[test]
    fn straggler_batch_is_hedged_first_response_wins() {
        let plan = FaultPlan {
            seed: 0,
            shards: vec![
                ShardFaults {
                    latency_spike: Some(Duration::from_millis(150)),
                    latency_spike_prob: 1.0,
                    ..Default::default()
                },
                ShardFaults::default(),
            ],
        };
        let mut c = cfg(2, 1024);
        c.steal = false;
        c.resilience = ResilienceConfig {
            enabled: true,
            hedge: true,
            hedge_after: Duration::from_millis(10),
            ..Default::default()
        };
        let server =
            ReplicaServer::new(vec![SeededExec, SeededExec], c).with_fault_plan(plan);
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..2).map(|_| vec![0.0f32; 4]));
        drop(tx);
        let t0 = Instant::now();
        server.run(rx);
        let elapsed = t0.elapsed();
        // batch 1 (seed 6, size 2): both members answered by the hedge,
        // with the exact logits the original would have produced
        for (i, rx) in replies.into_iter().enumerate() {
            let rep = rx.recv().expect("exactly one reply");
            let logits = rep.result.expect("hedge answered Ok");
            assert_eq!(logits.len(), 10);
            assert_eq!(logits[0], 6200.0 + 10.0 * i as f32, "seed 6, batch 2, member {i}");
            assert!(rx.try_recv().is_err(), "dedup: no second reply");
        }
        assert_eq!(server.metrics.hedged(), 1, "the straggler was hedged");
        assert_eq!(server.metrics.hedge_wins(), 1, "and the hedge answered first");
        assert_eq!(server.metrics.requests(), 2);
        // the run still waits for the spiked original to finish (scoped
        // threads join), but replies went out at hedge speed
        assert!(elapsed >= Duration::from_millis(10));
    }

    /// Degraded executor standing in for the short-sampling brown-out
    /// view: recognizably different output.
    struct DegradedExec;

    impl Executor for DegradedExec {
        fn execute(&self, _i: &[f32], batch: usize, _s: u32) -> crate::Result<Vec<f32>> {
            Ok(vec![-1.0; batch * 10])
        }
        fn classes(&self) -> usize {
            10
        }
        fn image_elems(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
    }

    /// Brown-out: over the outstanding threshold, batches run on the
    /// degraded executors and every reply is flagged `degraded` — load is
    /// shed by cheaper sampling, not by dropping requests.
    #[test]
    fn brownout_serves_degraded_flagged_replies() {
        let mut c = cfg(2, 1024);
        c.resilience = ResilienceConfig {
            enabled: true,
            // threshold 0: any pre-queued burst puts the tier in brown-out
            brownout_queue: Some(0),
            ..Default::default()
        };
        let server = ReplicaServer::new(vec![SeededExec, SeededExec], c)
            .with_degraded_shards(vec![DegradedExec, DegradedExec]);
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..4).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for rx in replies {
            let rep = rx.recv().expect("reply delivered");
            assert!(rep.degraded, "brown-out replies carry the DEGRADED flag");
            assert_eq!(rep.result.unwrap(), vec![-1.0; 10], "degraded executor ran");
        }
        assert_eq!(server.metrics.degraded(), 4);
        let j = server.metrics.to_json();
        let res = j.get("resilience").unwrap();
        assert_eq!(res.get("degraded").and_then(|v| v.as_usize()), Some(4));
    }

    /// A fault plan on a server with resilience *disabled* still fails
    /// loudly (error replies, no requeue) — fault injection does not
    /// depend on the healing machinery.
    #[test]
    fn fault_plan_without_resilience_fails_loudly() {
        let plan = FaultPlan {
            seed: 0,
            shards: vec![
                ShardFaults { crash_at_batch: Some(0), ..Default::default() },
                ShardFaults { crash_at_batch: Some(0), ..Default::default() },
            ],
        };
        let server =
            ReplicaServer::new(vec![SeededExec, SeededExec], cfg(4, 1024)).with_fault_plan(plan);
        let (tx, rx) = mpsc::channel();
        let replies = submit_all(&tx, (0..8).map(|_| vec![0.0f32; 4]));
        drop(tx);
        server.run(rx);
        for r in replies {
            let rep = r.recv().expect("reply delivered");
            assert!(rep.result.unwrap_err().contains("injected fault"));
        }
        assert_eq!(server.metrics.requeued(), 0, "no healing without resilience");
        assert_eq!(server.metrics.evicted(), 0);
    }
}
