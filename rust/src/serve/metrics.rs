//! Per-shard + aggregate serving metrics: latency percentiles through
//! p999, batch occupancy, work-stealing counters, and SLO attainment —
//! exported as JSON (the `BENCH_serving.json` sidecar schema documented in
//! README §Serving).
//!
//! Unlike the single-server [`crate::coordinator::Metrics`], every counter
//! here is shard-addressable: the dispatcher records admission decisions
//! (rejected / deadline-exceeded) and each shard worker records the
//! batches it executed — including ones it *stole* from a sibling's
//! backlog — so the JSON report shows both the aggregate curve and how
//! evenly the replicas shared the load.

use crate::stats::{Histogram, LatencyHistogram};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Smoothing factor of the per-shard health EWMAs (error rate and batch
/// latency): `ewma ← (1−α)·ewma + α·sample`.  The eviction policy in
/// [`super::health`] compares the error-rate EWMA against
/// [`super::health::ResilienceConfig::error_ewma_evict`].
pub const EWMA_ALPHA: f64 = 0.2;

/// Counters and latency distribution of one replica shard.
#[derive(Debug)]
pub struct ShardStats {
    /// request latency (enqueue → reply), microseconds
    latency_us: LatencyHistogram,
    /// queue-wait component of request latency (enqueue → execution
    /// start), microseconds — the telemetry plane's decomposition
    queue_wait_us: LatencyHistogram,
    /// service component of request latency (execution start → reply),
    /// microseconds
    service_us: LatencyHistogram,
    batch_occupancy: Histogram,
    pub requests: u64,
    pub batches: u64,
    /// batches this shard executed that were dispatched to a sibling
    pub stolen_batches: u64,
    /// batches whose executor returned `Err` (every member got the error
    /// reply; see the [`crate::coordinator::server::Reply`] contract)
    pub error_batches: u64,
    /// EWMA of the per-batch error indicator (1 = failed, 0 = ok) — the
    /// health signal eviction reads
    pub error_ewma: f64,
    /// EWMA of per-batch mean request latency (µs) — the straggler
    /// signal hedged dispatch reads
    pub latency_ewma_us: f64,
}

impl ShardStats {
    fn new() -> Self {
        Self {
            // 0..10 s at 500 µs resolution: fine enough for p999 at the
            // latencies the native executor produces; the queue/service
            // components share the shape so their percentiles compare
            latency_us: LatencyHistogram::new(10_000_000.0, 20_000),
            queue_wait_us: LatencyHistogram::new(10_000_000.0, 20_000),
            service_us: LatencyHistogram::new(10_000_000.0, 20_000),
            // one bin per occupancy 0..=256: the range must extend past the
            // largest legal batch (256) because Histogram's upper edge is
            // exclusive — with `new(0, 256, 256)` a full 256-occupancy
            // batch fell into `over` instead of the last bin
            batch_occupancy: Histogram::new(0.0, 257.0, 257),
            requests: 0,
            batches: 0,
            stolen_batches: 0,
            error_batches: 0,
            error_ewma: 0.0,
            latency_ewma_us: 0.0,
        }
    }

    fn record(&mut self, batch: usize, latencies: &[Duration], stolen: bool) {
        self.requests += batch as u64;
        self.batches += 1;
        if stolen {
            self.stolen_batches += 1;
        }
        self.batch_occupancy.add(batch as f32);
        let mut sum_us = 0.0f64;
        for l in latencies {
            // accumulate in f64 end-to-end: at µs scale an f32 cast
            // quantizes to ~0.06 µs steps by 1 s and misreports min/p999
            let us = l.as_secs_f64() * 1e6;
            self.latency_us.record_us(us);
            sum_us += us;
        }
        self.error_ewma *= 1.0 - EWMA_ALPHA; // sample 0: the batch succeeded
        if !latencies.is_empty() {
            let mean = sum_us / latencies.len() as f64;
            self.latency_ewma_us = (1.0 - EWMA_ALPHA) * self.latency_ewma_us + EWMA_ALPHA * mean;
        }
    }

    fn note_error(&mut self) {
        self.error_batches += 1;
        self.error_ewma = (1.0 - EWMA_ALPHA) * self.error_ewma + EWMA_ALPHA; // sample 1
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_occupancy.mean()
    }

    /// Request-latency percentile (µs) under the documented
    /// [`Histogram::percentile`] interpolation rule: `NaN` before any
    /// request completes, `p` clamped to `[0, 100]`, `p = 0`/`p = 100`
    /// answering at the edges of the occupied bins.
    pub fn latency_percentile_us(&self, p: f64) -> f32 {
        self.latency_us.percentile_us(p)
    }

    pub fn mean_latency_us(&self) -> f64 {
        self.latency_us.mean_us()
    }

    /// Smallest observed request latency (µs); 0 when nothing recorded.
    pub fn min_latency_us(&self) -> f64 {
        self.latency_us.min_us()
    }

    fn record_split(&mut self, queue_us: &[f64], service_us: &[f64]) {
        for &q in queue_us {
            self.queue_wait_us.record_us(q);
        }
        for &s in service_us {
            self.service_us.record_us(s);
        }
    }
}

/// Aggregate + per-shard serving metrics with SLO attainment.
///
/// Shared (`Arc`) between the dispatcher and every shard worker; the
/// aggregate `total` is updated alongside each shard so percentile
/// queries never need to merge histograms.
pub struct ServeMetrics {
    started: Instant,
    slo: Duration,
    shards: Vec<Mutex<ShardStats>>,
    total: Mutex<ShardStats>,
    rejected: AtomicU64,
    deadline_exceeded: AtomicU64,
    slo_ok: AtomicU64,
    slo_miss: AtomicU64,
    // self-healing counters (the resilience block of the JSON report)
    evicted: AtomicU64,
    reintegrated: AtomicU64,
    requeued: AtomicU64,
    probes: AtomicU64,
    hedged: AtomicU64,
    hedge_wins: AtomicU64,
    degraded: AtomicU64,
}

impl ServeMetrics {
    pub fn new(replicas: usize, slo: Duration) -> Self {
        Self {
            started: Instant::now(),
            slo,
            shards: (0..replicas).map(|_| Mutex::new(ShardStats::new())).collect(),
            total: Mutex::new(ShardStats::new()),
            rejected: AtomicU64::new(0),
            deadline_exceeded: AtomicU64::new(0),
            slo_ok: AtomicU64::new(0),
            slo_miss: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            reintegrated: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            hedged: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
        }
    }

    /// Record a successfully executed batch on `shard`: per-request
    /// latencies feed the percentile histograms and the SLO attainment
    /// counters (latency ≤ SLO target → ok, else miss).
    pub fn record_batch(&self, shard: usize, batch: usize, latencies: &[Duration], stolen: bool) {
        self.shards[shard].lock().unwrap().record(batch, latencies, stolen);
        self.total.lock().unwrap().record(batch, latencies, stolen);
        for l in latencies {
            if *l <= self.slo {
                self.slo_ok.fetch_add(1, Ordering::Relaxed);
            } else {
                self.slo_miss.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Record the queue-wait vs service-time decomposition of a batch's
    /// requests on `shard` (µs components; `queue + service` equals the
    /// request latency fed to [`ServeMetrics::record_batch`]).  Kept as a
    /// separate call so reply paths that cannot attribute the split (error
    /// replies, rejected requests) simply skip it.
    pub fn record_decomposition(&self, shard: usize, queue_us: &[f64], service_us: &[f64]) {
        self.shards[shard].lock().unwrap().record_split(queue_us, service_us);
        self.total.lock().unwrap().record_split(queue_us, service_us);
    }

    /// Record a batch whose executor failed (it will be requeued or its
    /// members get error replies); feeds the error-rate EWMA eviction
    /// reads.
    pub fn record_error_batch(&self, shard: usize) {
        self.shards[shard].lock().unwrap().note_error();
        self.total.lock().unwrap().note_error();
    }

    /// Admission control turned a request away at the queue head.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A queued request aged past its deadline before execution.
    pub fn record_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    /// A shard was evicted from the dispatch rotation.
    pub fn record_evicted(&self) {
        self.evicted.fetch_add(1, Ordering::Relaxed);
    }

    /// An evicted shard passed a probe and rejoined the rotation.
    pub fn record_reintegrated(&self) {
        self.reintegrated.fetch_add(1, Ordering::Relaxed);
    }

    /// A failed batch was requeued onto a healthy shard (lossless).
    pub fn record_requeued(&self) {
        self.requeued.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch was routed to an evicted shard as a reintegration probe.
    pub fn record_probe(&self) {
        self.probes.fetch_add(1, Ordering::Relaxed);
    }

    /// An in-flight straggler batch was hedged to a sibling shard.
    pub fn record_hedged(&self) {
        self.hedged.fetch_add(1, Ordering::Relaxed);
    }

    /// A hedged execution answered at least one request first.
    pub fn record_hedge_win(&self) {
        self.hedge_wins.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` requests were served in brown-out (degraded) mode.
    pub fn record_degraded(&self, n: u64) {
        self.degraded.fetch_add(n, Ordering::Relaxed);
    }

    pub fn replicas(&self) -> usize {
        self.shards.len()
    }

    /// Requests that executed successfully (across all shards).
    pub fn requests(&self) -> u64 {
        self.total.lock().unwrap().requests
    }

    pub fn batches(&self) -> u64 {
        self.total.lock().unwrap().batches
    }

    pub fn stolen_batches(&self) -> u64 {
        self.total.lock().unwrap().stolen_batches
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn deadline_exceeded(&self) -> u64 {
        self.deadline_exceeded.load(Ordering::Relaxed)
    }

    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    pub fn reintegrated(&self) -> u64 {
        self.reintegrated.load(Ordering::Relaxed)
    }

    pub fn requeued(&self) -> u64 {
        self.requeued.load(Ordering::Relaxed)
    }

    pub fn probes(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }

    pub fn hedged(&self) -> u64 {
        self.hedged.load(Ordering::Relaxed)
    }

    pub fn hedge_wins(&self) -> u64 {
        self.hedge_wins.load(Ordering::Relaxed)
    }

    pub fn degraded(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Shard `si`'s error-rate EWMA (the eviction signal).
    pub fn error_ewma(&self, si: usize) -> f64 {
        self.shards[si].lock().unwrap().error_ewma
    }

    /// Shard `si`'s batch-latency EWMA in µs (the straggler signal).
    pub fn latency_ewma_us(&self, si: usize) -> f64 {
        self.shards[si].lock().unwrap().latency_ewma_us
    }

    pub fn slo_ok(&self) -> u64 {
        self.slo_ok.load(Ordering::Relaxed)
    }

    pub fn slo_miss(&self) -> u64 {
        self.slo_miss.load(Ordering::Relaxed)
    }

    /// Fraction of executed requests that met the SLO (1.0 when none ran).
    pub fn slo_attainment(&self) -> f64 {
        let ok = self.slo_ok() as f64;
        let miss = self.slo_miss() as f64;
        if ok + miss == 0.0 {
            1.0
        } else {
            ok / (ok + miss)
        }
    }

    /// Executed requests per second of server lifetime.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.requests() as f64 / secs
        }
    }

    /// Aggregate request-latency percentile in microseconds (NaN before
    /// any request completes — the histogram contract).
    pub fn latency_percentile_us(&self, p: f64) -> f32 {
        self.total.lock().unwrap().latency_percentile_us(p)
    }

    pub fn mean_latency_us(&self) -> f64 {
        self.total.lock().unwrap().mean_latency_us()
    }

    pub fn min_latency_us(&self) -> f64 {
        self.total.lock().unwrap().min_latency_us()
    }

    /// Aggregate queue-wait percentile in µs (NaN before any
    /// decomposition was recorded).
    pub fn queue_wait_percentile_us(&self, p: f64) -> f32 {
        self.total.lock().unwrap().queue_wait_us.percentile_us(p)
    }

    pub fn mean_queue_wait_us(&self) -> f64 {
        self.total.lock().unwrap().queue_wait_us.mean_us()
    }

    /// Aggregate service-time percentile in µs (NaN before any
    /// decomposition was recorded).
    pub fn service_percentile_us(&self, p: f64) -> f32 {
        self.total.lock().unwrap().service_us.percentile_us(p)
    }

    pub fn mean_service_us(&self) -> f64 {
        self.total.lock().unwrap().service_us.mean_us()
    }

    pub fn mean_batch(&self) -> f64 {
        self.total.lock().unwrap().mean_batch()
    }

    /// The JSON report (schema in README §Serving): aggregate counters,
    /// p50/p99/p999 latency, SLO attainment, and one object per shard.
    pub fn to_json(&self) -> Json {
        let num_or_null = |v: f32| -> Json {
            if v.is_finite() {
                Json::Num(v as f64)
            } else {
                Json::Null
            }
        };
        let pct = |p: f64| -> Json { num_or_null(self.latency_percentile_us(p)) };
        let shards: Vec<Json> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let s = s.lock().unwrap();
                let p99 = s.latency_percentile_us(99.0);
                Json::obj(vec![
                    ("shard", Json::Num(i as f64)),
                    ("requests", Json::Num(s.requests as f64)),
                    ("batches", Json::Num(s.batches as f64)),
                    ("stolen_batches", Json::Num(s.stolen_batches as f64)),
                    ("error_batches", Json::Num(s.error_batches as f64)),
                    ("error_ewma", Json::Num(s.error_ewma)),
                    ("latency_ewma_us", Json::Num(s.latency_ewma_us)),
                    ("mean_batch", Json::Num(s.mean_batch())),
                    (
                        "p99_us",
                        if p99.is_finite() {
                            Json::Num(p99 as f64)
                        } else {
                            Json::Null
                        },
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas() as f64)),
            ("requests", Json::Num(self.requests() as f64)),
            ("batches", Json::Num(self.batches() as f64)),
            ("stolen_batches", Json::Num(self.stolen_batches() as f64)),
            ("rejected", Json::Num(self.rejected() as f64)),
            ("deadline_exceeded", Json::Num(self.deadline_exceeded() as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("mean_batch", Json::Num(self.mean_batch())),
            (
                "latency_us",
                Json::obj(vec![
                    ("mean", Json::Num(self.mean_latency_us())),
                    ("p50", pct(50.0)),
                    ("p99", pct(99.0)),
                    ("p999", pct(99.9)),
                ]),
            ),
            // queue-wait vs service decomposition (telemetry plane): the
            // two components sum to the request latency above
            (
                "queue_wait_us",
                Json::obj(vec![
                    ("mean", Json::Num(self.mean_queue_wait_us())),
                    ("p50", num_or_null(self.queue_wait_percentile_us(50.0))),
                    ("p99", num_or_null(self.queue_wait_percentile_us(99.0))),
                ]),
            ),
            (
                "service_us",
                Json::obj(vec![
                    ("mean", Json::Num(self.mean_service_us())),
                    ("p50", num_or_null(self.service_percentile_us(50.0))),
                    ("p99", num_or_null(self.service_percentile_us(99.0))),
                ]),
            ),
            (
                "slo",
                Json::obj(vec![
                    ("target_us", Json::Num(self.slo.as_secs_f64() * 1e6)),
                    ("ok", Json::Num(self.slo_ok() as f64)),
                    ("miss", Json::Num(self.slo_miss() as f64)),
                    ("attainment", Json::Num(self.slo_attainment())),
                ]),
            ),
            (
                "resilience",
                Json::obj(vec![
                    ("evicted", Json::Num(self.evicted() as f64)),
                    ("reintegrated", Json::Num(self.reintegrated() as f64)),
                    ("requeued", Json::Num(self.requeued() as f64)),
                    ("probes", Json::Num(self.probes() as f64)),
                    ("hedged", Json::Num(self.hedged() as f64)),
                    ("hedge_wins", Json::Num(self.hedge_wins() as f64)),
                    ("degraded", Json::Num(self.degraded() as f64)),
                ]),
            ),
            ("shards", Json::Arr(shards)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_shard_and_aggregate_counters() {
        let m = ServeMetrics::new(2, Duration::from_millis(10));
        m.record_batch(0, 2, &[Duration::from_millis(1), Duration::from_millis(2)], false);
        m.record_batch(1, 1, &[Duration::from_millis(50)], true);
        m.record_rejected();
        m.record_deadline_exceeded();
        assert_eq!(m.requests(), 3);
        assert_eq!(m.batches(), 2);
        assert_eq!(m.stolen_batches(), 1);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.deadline_exceeded(), 1);
        // SLO at 10 ms: two under, one (50 ms) over
        assert_eq!(m.slo_ok(), 2);
        assert_eq!(m.slo_miss(), 1);
        assert!((m.slo_attainment() - 2.0 / 3.0).abs() < 1e-12);
        assert!(m.latency_percentile_us(99.9) > 1_000.0);
        assert!(m.min_latency_us() >= 500.0);
    }

    #[test]
    fn json_schema_fields_present() {
        let m = ServeMetrics::new(2, Duration::from_millis(5));
        m.record_batch(0, 1, &[Duration::from_millis(1)], false);
        let j = m.to_json();
        assert_eq!(j.get("replicas").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("requests").and_then(|v| v.as_usize()), Some(1));
        let lat = j.get("latency_us").unwrap();
        assert!(lat.get("p50").and_then(|v| v.as_f64()).is_some());
        assert!(lat.get("p999").and_then(|v| v.as_f64()).is_some());
        let slo = j.get("slo").unwrap();
        assert_eq!(slo.get("ok").and_then(|v| v.as_usize()), Some(1));
        assert!(slo.get("attainment").and_then(|v| v.as_f64()).unwrap() > 0.99);
        let shards = j.get("shards").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("requests").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(shards[1].get("requests").and_then(|v| v.as_usize()), Some(0));
        // roundtrip through the serializer
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("replicas").and_then(|v| v.as_usize()), Some(2));
    }

    #[test]
    fn full_occupancy_batch_lands_in_last_bin_not_over() {
        let m = ServeMetrics::new(1, Duration::from_millis(10));
        let lat: Vec<Duration> = (0..256).map(|_| Duration::from_millis(1)).collect();
        m.record_batch(0, 256, &lat, false);
        let t = m.total.lock().unwrap();
        assert_eq!(t.batch_occupancy.over, 0, "occupancy 256 must stay in range");
        assert_eq!(t.batch_occupancy.bins()[256], 1, "one bin per occupancy 0..=256");
        assert!((t.mean_batch() - 256.0).abs() < 1e-9);
    }

    #[test]
    fn min_latency_keeps_f64_precision() {
        let m = ServeMetrics::new(1, Duration::from_millis(10));
        // 1.234567891011 s = 1_234_567.891011 µs — not representable in f32
        let d = Duration::from_nanos(1_234_567_891);
        m.record_batch(0, 1, &[d], false);
        let min = m.min_latency_us();
        assert!((min - 1_234_567.891).abs() < 1e-3, "min={min}");
        assert_ne!(min, min as f32 as f64, "f32 would have rounded this");
    }

    #[test]
    fn error_ewma_rises_on_errors_and_decays_on_successes() {
        let m = ServeMetrics::new(1, Duration::from_millis(10));
        assert_eq!(m.error_ewma(0), 0.0);
        m.record_error_batch(0);
        let one = m.error_ewma(0);
        assert!((one - EWMA_ALPHA).abs() < 1e-12, "{one}");
        m.record_error_batch(0);
        let two = m.error_ewma(0);
        assert!(two > one, "consecutive errors push the EWMA up");
        m.record_batch(0, 1, &[Duration::from_millis(1)], false);
        assert!(m.error_ewma(0) < two, "a success decays it");
        // many successes drive it toward zero, never below
        for _ in 0..200 {
            m.record_batch(0, 1, &[Duration::from_millis(1)], false);
        }
        assert!(m.error_ewma(0) >= 0.0 && m.error_ewma(0) < 1e-6);
    }

    #[test]
    fn latency_ewma_tracks_batch_latency() {
        let m = ServeMetrics::new(1, Duration::from_millis(10));
        assert_eq!(m.latency_ewma_us(0), 0.0);
        for _ in 0..60 {
            m.record_batch(0, 1, &[Duration::from_millis(2)], false);
        }
        let ewma = m.latency_ewma_us(0);
        assert!((ewma - 2000.0).abs() < 10.0, "converges to ~2 ms: {ewma}");
    }

    #[test]
    fn resilience_counters_round_trip_through_json() {
        let m = ServeMetrics::new(2, Duration::from_millis(10));
        m.record_evicted();
        m.record_reintegrated();
        m.record_requeued();
        m.record_requeued();
        m.record_probe();
        m.record_hedged();
        m.record_hedge_win();
        m.record_degraded(3);
        assert_eq!(m.evicted(), 1);
        assert_eq!(m.reintegrated(), 1);
        assert_eq!(m.requeued(), 2);
        assert_eq!(m.probes(), 1);
        assert_eq!(m.hedged(), 1);
        assert_eq!(m.hedge_wins(), 1);
        assert_eq!(m.degraded(), 3);
        let j = m.to_json();
        let r = j.get("resilience").expect("resilience block in the report");
        assert_eq!(r.get("evicted").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(r.get("requeued").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(r.get("degraded").and_then(|v| v.as_usize()), Some(3));
        let shards = j.get("shards").and_then(|s| s.as_arr()).unwrap();
        assert!(shards[0].get("error_ewma").and_then(|v| v.as_f64()).is_some());
        assert!(shards[0].get("latency_ewma_us").and_then(|v| v.as_f64()).is_some());
    }

    #[test]
    fn empty_metrics_report_null_percentiles() {
        let m = ServeMetrics::new(1, Duration::from_millis(5));
        assert!(m.latency_percentile_us(50.0).is_nan());
        let j = m.to_json();
        assert_eq!(j.get("latency_us").unwrap().get("p50"), Some(&Json::Null));
        assert_eq!(j.get("queue_wait_us").unwrap().get("p50"), Some(&Json::Null));
        assert_eq!(j.get("service_us").unwrap().get("p50"), Some(&Json::Null));
        assert_eq!(m.slo_attainment(), 1.0);
    }

    // pins the ShardStats::latency_percentile_us edge-case contract (the
    // Histogram::percentile interpolation rule at 500 µs bin width)
    #[test]
    fn latency_percentile_edge_cases() {
        let m = ServeMetrics::new(1, Duration::from_millis(10));
        // empty histogram → NaN at every p (JSON reports null)
        assert!(m.latency_percentile_us(0.0).is_nan());
        assert!(m.latency_percentile_us(100.0).is_nan());
        // single sample: 1 ms lands in bin [1000, 1500) µs; p=0 answers
        // the bin's left edge, p=50 its center, p=100 its right edge
        m.record_batch(0, 1, &[Duration::from_millis(1)], false);
        assert_eq!(m.latency_percentile_us(0.0), 1000.0);
        assert_eq!(m.latency_percentile_us(50.0), 1250.0);
        assert_eq!(m.latency_percentile_us(100.0), 1500.0);
        // p clamps to [0, 100]: out-of-domain p answers at the data's
        // edges, never the histogram's 10^7 µs upper bound
        assert_eq!(m.latency_percentile_us(-5.0), 1000.0);
        assert_eq!(m.latency_percentile_us(200.0), 1500.0);
    }

    #[test]
    fn queue_service_decomposition_components_sum_to_latency() {
        let m = ServeMetrics::new(2, Duration::from_millis(10));
        // request latency 3 ms = 1 ms queued + 2 ms executing
        m.record_batch(1, 1, &[Duration::from_millis(3)], false);
        m.record_decomposition(1, &[1000.0], &[2000.0]);
        assert!((m.mean_queue_wait_us() - 1000.0).abs() < 1e-9);
        assert!((m.mean_service_us() - 2000.0).abs() < 1e-9);
        assert!(
            (m.mean_queue_wait_us() + m.mean_service_us() - m.mean_latency_us()).abs() < 1e-9
        );
        // percentiles resolve within the 500 µs bins
        assert_eq!(m.queue_wait_percentile_us(50.0), 1250.0);
        assert_eq!(m.service_percentile_us(50.0), 2250.0);
        let j = m.to_json();
        let q = j.get("queue_wait_us").unwrap();
        assert!((q.get("mean").and_then(|v| v.as_f64()).unwrap() - 1000.0).abs() < 1e-9);
        assert!(q.get("p99").and_then(|v| v.as_f64()).is_some());
        let s = j.get("service_us").unwrap();
        assert!((s.get("mean").and_then(|v| v.as_f64()).unwrap() - 2000.0).abs() < 1e-9);
    }
}
