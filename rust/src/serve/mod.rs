//! Sharded replica serving tier.
//!
//! Supersedes the single-threaded [`crate::coordinator::Server`] loop for
//! deployment-shaped workloads: N model replicas share one set of
//! programmed crossbars through the `Arc` seam
//! ([`crate::model::NativeModel::replica_view`] — program once, serve
//! everywhere), a single admission-controlled request queue feeds a
//! continuous batcher, and formed batches fan out across replica shards
//! with work stealing so an idle shard drains a slow sibling's backlog.
//!
//! Determinism contract: batches are formed centrally (FIFO order) and
//! seeded by sequence number, so for the same request stream, seed, and
//! batcher config the tier is **bit-identical** to the single `Server` —
//! regardless of replica count or which shard executed which batch
//! (pinned by `replica_tier_matches_single_server_bit_for_bit`).
//!
//! Layout:
//! - [`replica`] — [`ReplicaServer`]: shard workers, admission control
//!   (bounded outstanding depth → explicit [`REJECTED`] replies),
//!   per-request deadlines ([`DEADLINE_EXCEEDED`]), work stealing.
//! - [`metrics`] — [`ServeMetrics`]: per-shard + aggregate counters,
//!   p50/p99/p999 latency, SLO attainment, JSON export.
//! - [`loadgen`] — Poisson-arrival closed-loop harness sweeping offered
//!   rates to saturation; emits `BENCH_serving.json`.
//! - [`health`] — [`ResilienceConfig`] + [`HealthTracker`]: the
//!   self-healing policy (eviction on consecutive errors or error-EWMA,
//!   probe-based reintegration, hedging and brown-out knobs).
//! - [`fault`] — [`FaultPlan`]/[`FaultInjector`]: deterministic fault
//!   injection (crash windows, transient errors, latency spikes,
//!   corrupted logits), and [`run_chaos`] — the severity × load sweep
//!   behind `stox-cli chaos` (`BENCH_chaos.json`).
//!
//! Self-healing extends the determinism contract rather than weakening
//! it: requeued and hedged batches carry their original seed, so a batch
//! re-executed on *any* shard reproduces the exact logits the failed
//! execution would have produced.  Under a crash fault the surviving
//! replies are bit-identical to the fault-free run, and every admitted
//! request receives exactly one reply under any fault schedule.

pub mod fault;
pub mod health;
pub mod loadgen;
pub mod metrics;
pub mod replica;

pub use fault::{run_chaos, ChaosConfig, ChaosPoint, FaultInjector, FaultPlan, ShardFaults};
pub use health::{HealthTracker, ResilienceConfig};
pub use loadgen::{run_rate, run_sweep, LoadGenConfig, RatePoint};
pub use metrics::{ServeMetrics, EWMA_ALPHA};
pub use replica::{ReplicaConfig, ReplicaServer, DEADLINE_EXCEEDED, REJECTED};
