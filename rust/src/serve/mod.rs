//! Sharded replica serving tier.
//!
//! Supersedes the single-threaded [`crate::coordinator::Server`] loop for
//! deployment-shaped workloads: N model replicas share one set of
//! programmed crossbars through the `Arc` seam
//! ([`crate::model::NativeModel::replica_view`] — program once, serve
//! everywhere), a single admission-controlled request queue feeds a
//! continuous batcher, and formed batches fan out across replica shards
//! with work stealing so an idle shard drains a slow sibling's backlog.
//!
//! Determinism contract: batches are formed centrally (FIFO order) and
//! seeded by sequence number, so for the same request stream, seed, and
//! batcher config the tier is **bit-identical** to the single `Server` —
//! regardless of replica count or which shard executed which batch
//! (pinned by `replica_tier_matches_single_server_bit_for_bit`).
//!
//! Layout:
//! - [`replica`] — [`ReplicaServer`]: shard workers, admission control
//!   (bounded outstanding depth → explicit [`REJECTED`] replies),
//!   per-request deadlines ([`DEADLINE_EXCEEDED`]), work stealing.
//! - [`metrics`] — [`ServeMetrics`]: per-shard + aggregate counters,
//!   p50/p99/p999 latency, SLO attainment, JSON export.
//! - [`loadgen`] — Poisson-arrival closed-loop harness sweeping offered
//!   rates to saturation; emits `BENCH_serving.json`.

pub mod loadgen;
pub mod metrics;
pub mod replica;

pub use loadgen::{run_rate, run_sweep, LoadGenConfig, RatePoint};
pub use metrics::ServeMetrics;
pub use replica::{ReplicaConfig, ReplicaServer, DEADLINE_EXCEEDED, REJECTED};
