//! Per-shard health tracking and the self-healing policy knobs.
//!
//! The replica tier watches each shard's error behaviour (consecutive
//! failed batches plus the error-rate EWMA maintained by
//! [`super::metrics::ServeMetrics`]) and **evicts** shards that look
//! unhealthy: the dispatcher stops routing new batches to them, their
//! queued work is redistributed losslessly to healthy siblings, and every
//! [`super::ResilienceConfig::probe_interval`]-th dispatched batch is sent
//! to an evicted shard as a *probe* — a success reintegrates the shard
//! into the rotation.  The tracker never evicts the last healthy shard: a
//! degenerate cluster keeps limping on its only replica rather than
//! stalling with no executor at all.
//!
//! Everything here is policy state only — it never touches seeds or batch
//! formation, so enabling resilience cannot change *what* a request's
//! logits are, only *where* (and how often) they get computed.  With
//! [`ResilienceConfig::enabled`] false (the default) the tracker is inert
//! and the tier behaves exactly like the PR-6 serving path.

use std::sync::Mutex;
use std::time::Duration;

/// Self-healing policy for the replica tier; all response machinery
/// defaults to **off** so a default-configured [`super::ReplicaServer`]
/// is bit-identical to the pre-resilience serving path.
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Master switch: health tracking, eviction, requeue, probes.
    pub enabled: bool,
    /// Evict a shard after this many *consecutive* failed batches.
    pub evict_consecutive: u32,
    /// Evict when the shard's error-rate EWMA exceeds this threshold
    /// (per-batch error indicator smoothed by
    /// [`super::metrics::EWMA_ALPHA`]).
    pub error_ewma_evict: f64,
    /// Route every Nth dispatched batch to an evicted shard as a
    /// reintegration probe (0 disables probing).
    pub probe_interval: u32,
    /// Budget of requeues per batch after a shard failure before the
    /// batch fails loudly to every member.
    pub max_requeues: u32,
    /// Hedged dispatch: an idle healthy shard re-executes a straggling
    /// in-flight batch; first response wins (dedup by request id).
    pub hedge: bool,
    /// Minimum in-flight age before a batch is hedge-eligible.
    pub hedge_after: Duration,
    /// A batch is also a straggler once it is in flight longer than
    /// `hedge_factor ×` its shard's batch-latency EWMA.
    pub hedge_factor: f64,
    /// Brown-out threshold: when more than this many requests are
    /// outstanding, batches execute on the degraded (short-sampling)
    /// executors and replies carry `degraded: true`.  `None` disables.
    pub brownout_queue: Option<usize>,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            evict_consecutive: 3,
            error_ewma_evict: 0.5,
            probe_interval: 8,
            max_requeues: 2,
            hedge: false,
            hedge_after: Duration::from_millis(50),
            hedge_factor: 4.0,
            brownout_queue: None,
        }
    }
}

struct ShardHealth {
    up: bool,
    consecutive_errors: u32,
}

/// Shared health state: one slot per shard, updated by whichever worker
/// executed a batch on that shard.
pub struct HealthTracker {
    cfg: ResilienceConfig,
    shards: Vec<Mutex<ShardHealth>>,
}

impl HealthTracker {
    pub fn new(replicas: usize, cfg: ResilienceConfig) -> Self {
        Self {
            cfg,
            shards: (0..replicas)
                .map(|_| Mutex::new(ShardHealth { up: true, consecutive_errors: 0 }))
                .collect(),
        }
    }

    /// Whether the self-healing machinery is active at all.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Is `si` currently in the dispatch rotation?  Always true when the
    /// tracker is disabled.
    pub fn is_up(&self, si: usize) -> bool {
        !self.cfg.enabled || self.shards[si].lock().unwrap().up
    }

    /// A batch succeeded on `si`; returns true when this *reintegrated*
    /// an evicted shard (a probe, or stale work, came back healthy).
    pub fn record_success(&self, si: usize) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        let mut s = self.shards[si].lock().unwrap();
        s.consecutive_errors = 0;
        let reintegrated = !s.up;
        s.up = true;
        reintegrated
    }

    /// A batch failed on `si`; `error_ewma` is the shard's current
    /// error-rate EWMA (already including this failure).  Returns true
    /// when this call *evicted* the shard (up → down transition).  The
    /// last healthy shard is never evicted.
    pub fn record_failure(&self, si: usize, error_ewma: f64) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        // count healthy shards without holding si's lock (lock ordering:
        // only ever one shard lock at a time)
        let healthy = self.healthy_count();
        let mut s = self.shards[si].lock().unwrap();
        s.consecutive_errors += 1;
        if !s.up {
            return false; // already evicted (a failed probe)
        }
        let trip = s.consecutive_errors >= self.cfg.evict_consecutive
            || error_ewma > self.cfg.error_ewma_evict;
        if trip && healthy > 1 {
            s.up = false;
            return true;
        }
        false
    }

    pub fn healthy_count(&self) -> usize {
        if !self.cfg.enabled {
            return self.shards.len();
        }
        self.shards.iter().filter(|s| s.lock().unwrap().up).count()
    }

    /// Currently evicted shard indices (empty when disabled).
    pub fn evicted_list(&self) -> Vec<usize> {
        if !self.cfg.enabled {
            return Vec::new();
        }
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.lock().unwrap().up)
            .map(|(i, _)| i)
            .collect()
    }

    /// First healthy shard scanning cyclically from `start`; `None` only
    /// in the (unreachable by policy) all-evicted state.
    pub fn next_healthy(&self, start: usize) -> Option<usize> {
        let n = self.shards.len();
        (0..n).map(|d| (start + d) % n).find(|&si| self.is_up(si))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled(evict_consecutive: u32) -> ResilienceConfig {
        ResilienceConfig { enabled: true, evict_consecutive, ..Default::default() }
    }

    #[test]
    fn disabled_tracker_is_inert() {
        let t = HealthTracker::new(2, ResilienceConfig::default());
        assert!(!t.enabled());
        assert!(!t.record_failure(0, 1.0), "disabled: never evicts");
        assert!(t.is_up(0));
        assert_eq!(t.healthy_count(), 2);
        assert!(t.evicted_list().is_empty());
    }

    #[test]
    fn consecutive_errors_evict_then_success_reintegrates() {
        let t = HealthTracker::new(3, enabled(2));
        assert!(!t.record_failure(1, 0.0), "first failure: below threshold");
        assert!(t.is_up(1));
        assert!(t.record_failure(1, 0.0), "second consecutive failure evicts");
        assert!(!t.is_up(1));
        assert_eq!(t.evicted_list(), vec![1]);
        assert_eq!(t.healthy_count(), 2);
        // a failed probe on an already-evicted shard is not a new eviction
        assert!(!t.record_failure(1, 0.0));
        // a successful probe reintegrates
        assert!(t.record_success(1));
        assert!(t.is_up(1));
        // and a success on an already-healthy shard is not a reintegration
        assert!(!t.record_success(1));
    }

    #[test]
    fn error_ewma_above_threshold_evicts_immediately() {
        let t = HealthTracker::new(2, enabled(100));
        assert!(t.record_failure(0, 0.9), "EWMA over 0.5 trips eviction");
    }

    #[test]
    fn interleaved_success_resets_the_consecutive_counter() {
        let t = HealthTracker::new(2, enabled(2));
        assert!(!t.record_failure(0, 0.0));
        t.record_success(0);
        assert!(!t.record_failure(0, 0.0), "counter was reset by success");
        assert!(t.is_up(0));
    }

    #[test]
    fn last_healthy_shard_is_never_evicted() {
        let t = HealthTracker::new(2, enabled(1));
        assert!(t.record_failure(0, 1.0));
        // shard 1 is now the last healthy shard: it keeps limping
        assert!(!t.record_failure(1, 1.0));
        assert!(t.is_up(1));
        assert_eq!(t.next_healthy(0), Some(1));
        assert_eq!(t.next_healthy(1), Some(1));
    }
}
